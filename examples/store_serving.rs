//! Domain example: serve tensors to many concurrent readers straight from
//! a compressed APackStore — the deployment APack targets (paper §V: data
//! stays compressed at rest, decode happens on demand on the memory path;
//! cf. EIE serving inference from a compressed weight store).
//!
//! Packs a zoo subset into a **sharded** store (hash-partitioned shard
//! files, like a store too large for one file), then hammers it through a
//! [`StoreHandle`] from several threads doing random `get_range` /
//! `get_chunk` reads, verifying every result against a reference decode.
//! Reads go through the zero-copy mmap backend, so no IO lock is touched.
//!
//! ```sh
//! cargo run --release --example store_serving [threads] [reads-per-thread] [shards]
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use apack_repro::coordinator::PartitionPolicy;
use apack_repro::models::zoo::model_by_name;
use apack_repro::store::{pack_model_zoo_sharded, StoreHandle};
use apack_repro::util::Rng64;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let threads: usize =
        std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let reads_per_thread: usize =
        std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(400);
    let shards: usize =
        std::env::args().nth(3).map(|s| s.parse()).transpose()?.unwrap_or(4);

    let path = std::env::temp_dir()
        .join(format!("apack_store_serving_{}.apackstore.d", std::process::id()));
    let models: Vec<_> = ["resnet18", "ncf", "bilstm", "alexnet_eyeriss"]
        .iter()
        .map(|n| model_by_name(n).expect("zoo model"))
        .collect();
    let policy = PartitionPolicy { substreams: 16, min_per_stream: 512 };
    let summary = pack_model_zoo_sharded(&path, &models, 8192, policy, shards)?;
    println!(
        "packed {} tensors / {} chunks into {} shard files, {:.1} KiB ({:.2}x vs raw)",
        summary.tensors,
        summary.chunks,
        summary.shards,
        summary.file_bytes as f64 / 1024.0,
        summary.compression_ratio()
    );

    let store = Arc::new(StoreHandle::open(&path)?);
    let names: Vec<String> =
        store.tensor_names().into_iter().map(str::to_string).collect();

    // Reference decode of every tensor (fresh handle: warms nothing).
    let reference: HashMap<String, Vec<u32>> = {
        let check = StoreHandle::open(&path)?;
        names.iter().map(|n| (n.clone(), check.get_tensor(n).unwrap())).collect()
    };
    let reference = Arc::new(reference);

    let t0 = Instant::now();
    let mut served_values = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for tid in 0..threads {
            let store = Arc::clone(&store);
            let reference = Arc::clone(&reference);
            let names = &names;
            handles.push(scope.spawn(move || {
                let mut rng = Rng64::new(0x5E17E + tid as u64);
                let mut served = 0u64;
                for _ in 0..reads_per_thread {
                    let name = &names[rng.below(names.len() as u64) as usize];
                    let expect = &reference[name];
                    let meta = store.meta(name).unwrap();
                    if meta.chunks.is_empty() {
                        continue;
                    }
                    if rng.chance(0.5) {
                        // Random range read (a slice of a layer's weights,
                        // as a sharded inference server would fetch).
                        let n = meta.n_values;
                        let lo = rng.below(n);
                        let hi = (lo + 1 + rng.below(n - lo)).min(n);
                        let got = store.get_range(name, lo..hi).unwrap();
                        assert_eq!(got, expect[lo as usize..hi as usize], "{name} {lo}..{hi}");
                        served += hi - lo;
                    } else {
                        let ci = rng.below(meta.chunks.len() as u64) as usize;
                        let covered = meta.chunk_value_range(ci);
                        let got = store.get_chunk(name, ci).unwrap();
                        assert_eq!(
                            got.as_slice(),
                            &expect[covered.start as usize..covered.end as usize],
                            "{name} chunk {ci}"
                        );
                        served += covered.end - covered.start;
                    }
                }
                served
            }));
        }
        for h in handles {
            served_values += h.join().expect("reader thread");
        }
    });
    let dt = t0.elapsed();

    let stats = store.stats();
    let total_reads = (threads * reads_per_thread) as f64;
    println!(
        "{threads} threads × {reads_per_thread} reads over {} shard(s): {served_values} \
         values served in {dt:?} ({:.0} reads/s, {:.1} Mvalues/s)",
        store.shard_count(),
        total_reads / dt.as_secs_f64(),
        served_values as f64 / dt.as_secs_f64() / 1e6
    );
    println!(
        "cache: {} hits / {} misses ({:.0}% hit rate); {:.2} MiB compressed read via {} \
         backend, {} chunks decoded",
        stats.cache_hits,
        stats.cache_misses,
        100.0 * stats.hit_rate(),
        stats.bytes_read as f64 / (1 << 20) as f64,
        stats.backend.name(),
        stats.chunks_decoded
    );
    println!("all reads verified against reference decode — serving is lossless");
    drop(store);
    std::fs::remove_dir_all(&path).ok();
    Ok(())
}
