//! Domain example: serve tensors to many concurrent clients straight from
//! a compressed APackStore — the deployment APack targets (paper §V: data
//! stays compressed at rest, decode happens on demand on the memory path;
//! cf. EIE serving inference from a compressed weight store).
//!
//! Packs a zoo subset into a **sharded** store, then runs closed-loop
//! client threads through a [`ServingEngine`] — the batching,
//! admission-controlled request layer — instead of hammering the
//! `StoreHandle` directly: requests queue into a bounded worker pool,
//! concurrent duplicate chunk decodes coalesce into single flights, the
//! hot-set prefetcher warms the LRU ahead of demand, and overload sheds
//! with a typed `Error::Overloaded` rather than unbounded latency. Every
//! response is verified bit-exact against a reference decode.
//!
//! ```sh
//! cargo run --release --example store_serving [clients] [requests-per-client] [shards]
//! ```

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use apack_repro::coordinator::PartitionPolicy;
use apack_repro::models::zoo::model_by_name;
use apack_repro::serving::{PrefetchConfig, ServingConfig, ServingEngine};
use apack_repro::store::{pack_model_zoo_sharded, StoreHandle};
use apack_repro::util::Rng64;
use apack_repro::Error;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let clients: usize =
        std::env::args().nth(1).map(|s| s.parse()).transpose()?.unwrap_or(8);
    let requests_per_client: usize =
        std::env::args().nth(2).map(|s| s.parse()).transpose()?.unwrap_or(400);
    let shards: usize =
        std::env::args().nth(3).map(|s| s.parse()).transpose()?.unwrap_or(4);

    let path = std::env::temp_dir()
        .join(format!("apack_store_serving_{}.apackstore.d", std::process::id()));
    let models: Vec<_> = ["resnet18", "ncf", "bilstm", "alexnet_eyeriss"]
        .iter()
        .map(|n| model_by_name(n).expect("zoo model"))
        .collect();
    let policy = PartitionPolicy { substreams: 16, min_per_stream: 512 };
    let summary = pack_model_zoo_sharded(&path, &models, 8192, policy, shards)?;
    println!(
        "packed {} tensors / {} chunks into {} shard files, {:.1} KiB ({:.2}x vs raw)",
        summary.tensors,
        summary.chunks,
        summary.shards,
        summary.file_bytes as f64 / 1024.0,
        summary.compression_ratio()
    );

    let store = Arc::new(StoreHandle::open(&path)?);
    let names: Vec<String> = store.tensor_names();

    // Reference decode of every tensor (fresh handle: warms nothing).
    let reference: HashMap<String, Vec<u32>> = {
        let check = StoreHandle::open(&path)?;
        names.iter().map(|n| (n.clone(), check.get_tensor(n).unwrap())).collect()
    };

    // The serving engine replaces the hand-rolled reader threads of the
    // pre-serving version of this example: clients block on tickets while
    // a bounded worker pool decodes, coalesces and prefetches.
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            queue_depth: 256,
            coalescing: true,
            prefetch: Some(PrefetchConfig::default()),
            ..ServingConfig::default()
        },
    )?;
    println!(
        "serving: {} workers, queue depth {}, coalescing on, prefetch on",
        engine.config().workers,
        engine.config().queue_depth
    );

    let t0 = Instant::now();
    let mut served_values = 0u64;
    let mut shed_requests = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for tid in 0..clients {
            let engine = &engine;
            let reference = &reference;
            let names = &names;
            handles.push(scope.spawn(move || {
                let mut rng = Rng64::new(0x5E17E + tid as u64);
                let (mut served, mut shed) = (0u64, 0u64);
                for _ in 0..requests_per_client {
                    let name = &names[rng.below(names.len() as u64) as usize];
                    let expect = &reference[name];
                    let meta = engine.store().meta(name).unwrap();
                    if meta.chunks.is_empty() {
                        continue;
                    }
                    let result = if rng.chance(0.5) {
                        // Random range read (a slice of a layer's weights,
                        // as a sharded inference server would fetch).
                        let n = meta.n_values;
                        let lo = rng.below(n);
                        let hi = (lo + 1 + rng.below(n - lo)).min(n);
                        engine.get_range(name, lo..hi).map(|got| {
                            assert_eq!(
                                got.as_slice(),
                                &expect[lo as usize..hi as usize],
                                "{name} {lo}..{hi}"
                            );
                            hi - lo
                        })
                    } else {
                        let ci = rng.below(meta.chunks.len() as u64) as usize;
                        let covered = meta.chunk_value_range(ci);
                        engine.get_chunk(name, ci).map(|got| {
                            assert_eq!(
                                got.as_slice(),
                                &expect[covered.start as usize..covered.end as usize],
                                "{name} chunk {ci}"
                            );
                            covered.end - covered.start
                        })
                    };
                    match result {
                        Ok(n) => served += n,
                        Err(Error::Overloaded { .. }) => shed += 1,
                        Err(e) => panic!("serving read failed: {e}"),
                    }
                }
                (served, shed)
            }));
        }
        for handle in handles {
            let (served, shed) = handle.join().expect("client thread");
            served_values += served;
            shed_requests += shed;
        }
    });
    let dt = t0.elapsed();

    let total_requests = (clients * requests_per_client) as f64;
    println!(
        "{clients} clients × {requests_per_client} requests over {} shard(s): \
         {served_values} values served in {dt:?} ({:.0} requests/s, {:.1} Mvalues/s, \
         {shed_requests} shed)",
        store.shard_count(),
        total_requests / dt.as_secs_f64(),
        served_values as f64 / dt.as_secs_f64() / 1e6
    );
    println!("{}", engine.metrics().render());
    let stats = engine.stats();
    println!(
        "store: {} hits / {} misses ({:.0}% hit rate); {:.2} MiB compressed via {} \
         backend, {} chunks decoded, {} prefetched",
        stats.cache_hits,
        stats.cache_misses,
        100.0 * stats.hit_rate(),
        stats.bytes_read as f64 / (1 << 20) as f64,
        stats.backend.name(),
        stats.chunks_decoded,
        stats.prefetched_chunks
    );
    println!("all responses verified against reference decode — serving is lossless");
    drop(engine);
    drop(store);
    std::fs::remove_dir_all(&path).ok();
    Ok(())
}
