//! Quickstart: compress and decompress one tensor with APack.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::{Coordinator, PartitionPolicy};
use apack_repro::models::distributions::ValueProfile;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A synthetic post-ReLU int8 activation tensor: 55% zeros plus a
    // decaying tail — the kind of stream APack sees at the memory
    // controller (paper Fig 2).
    let values = ValueProfile::ReluActivation { sparsity: 0.55, q: 0.92, noise_floor: 0.01 }
        .sample(8, 1 << 20, 1);

    // Profile → generate the 16-row table (paper §VI) → encode into the
    // symbol + offset dual stream (paper §IV), sharded over 64 substreams
    // like the 64-engine hardware deployment (paper §V-B).
    let mut coord = Coordinator::new(PartitionPolicy::default());
    let compressed = coord.compress(8, &values, TensorKind::Activations, None)?;

    println!("generated table:\n{}", compressed.table.render());
    println!(
        "{} values: {} -> {} bits  ({:.3} bits/value, ratio {:.2}x, {} shards)",
        compressed.n_values,
        compressed.n_values * 8,
        compressed.footprint_bits(),
        compressed.footprint_bits() as f64 / compressed.n_values as f64,
        compressed.compression_ratio(),
        compressed.shards.len(),
    );

    // Lossless roundtrip.
    let decoded = coord.decompress(&compressed)?;
    assert_eq!(decoded, values);
    println!("roundtrip OK — lossless");
    Ok(())
}
