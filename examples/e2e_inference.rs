//! End-to-end driver (DESIGN.md §5 "E2E"): real int8-CNN inference through
//! the AOT-lowered JAX/Pallas model on the PJRT CPU client, with APack on
//! the simulated off-chip path — weights are *decoded from APack
//! containers* before being fed to the accelerator, per-layer activations
//! are captured and compressed with profiled tables.
//!
//! Requires `make artifacts` first.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use std::path::PathBuf;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let artifacts = std::env::args()
        .nth(1)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"));
    let report = apack_repro::eval::e2e::run(&artifacts, 4)?;
    // The run prints its own summary; assert the headline invariants here
    // so the example doubles as an integration check.
    assert!(report.acts_norm() < 1.0, "activations must compress");
    assert!(!report.weights.is_empty() && !report.activations.is_empty());
    println!("\ne2e OK");
    Ok(())
}
