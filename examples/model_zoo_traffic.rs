//! Domain example: per-layer traffic anatomy of one model under APack —
//! where the bytes go, which layers compress best, and why (the analysis
//! behind the paper's §VII-A discussion of quantizer families).
//!
//! ```sh
//! cargo run --release --example model_zoo_traffic [model]
//! ```

use apack_repro::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use apack_repro::apack::{Histogram, encoder::ApackEncoder};
use apack_repro::eval::{EVAL_SEED, PROFILE_SAMPLES, SAMPLE_CAP};
use apack_repro::models::trace::ModelTrace;
use apack_repro::models::zoo::model_by_name;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "alexnet_eyeriss".to_string());
    let cfg = model_by_name(&name).ok_or_else(|| format!("unknown model {name}"))?;
    let trace = ModelTrace::synthesize(&cfg, SAMPLE_CAP, PROFILE_SAMPLES, EVAL_SEED);

    println!(
        "{} ({:?}, {}b)\n{:<6} {:>12} {:>9} {:>9} {:>10} {:>9}",
        cfg.name, cfg.family, cfg.bits, "layer", "w elems", "w b/v", "w spars", "a elems", "a b/v"
    );
    let mut w_raw = 0u64;
    let mut w_bits = 0.0f64;
    for (i, l) in trace.layers.iter().enumerate() {
        let wh = Histogram::from_values(cfg.bits, &l.weights);
        let wt = generate_table(&wh, TensorKind::Weights, &TableGenConfig::for_bits(cfg.bits))?;
        let (_, sb, _, ob) = ApackEncoder::encode_all(&wt, &l.weights)?;
        let w_bpv = (sb + ob) as f64 / l.weights.len() as f64;
        w_raw += l.weight_elems * cfg.bits as u64;
        w_bits += w_bpv * l.weight_elems as f64;

        let (a_bpv, a_elems) = if l.activations.is_empty() {
            (f64::NAN, 0)
        } else {
            let ah = Histogram::from_values(cfg.bits, &l.act_profile_samples);
            let at =
                generate_table(&ah, TensorKind::Activations, &TableGenConfig::for_bits(cfg.bits))?;
            let (_, sb, _, ob) = ApackEncoder::encode_all(&at, &l.activations)?;
            ((sb + ob) as f64 / l.activations.len() as f64, l.act_elems)
        };
        println!(
            "{:<6} {:>12} {:>9.3} {:>9.3} {:>10} {:>9.3}",
            i,
            l.weight_elems,
            w_bpv,
            wh.sparsity(),
            a_elems,
            a_bpv
        );
    }
    println!(
        "\nweights total: {:.3} bits/value vs {} raw -> normalized {:.3}",
        w_bits / (w_raw / cfg.bits as u64) as f64,
        cfg.bits,
        w_bits / w_raw as f64
    );
    Ok(())
}
