//! Domain example: size an edge accelerator's memory system with APack.
//!
//! The paper's pitch to system designers (§I): "APack reduces the amount
//! of off-chip memory and thus the cost needed to meet a desired
//! performance target." This example sweeps DRAM bandwidth for one model
//! and reports the latency/energy with and without APack — showing the
//! bandwidth a designer can shave while holding performance.
//!
//! ```sh
//! cargo run --release --example accelerator_sim [model]
//! ```

use apack_repro::eval::study::{CompressionStudy, Scheme};
use apack_repro::models::zoo::model_by_name;
use apack_repro::simulator::accelerator::{AcceleratorConfig, AcceleratorSim, TrafficScaling};
use apack_repro::simulator::energy::EnergyModel;
use apack_repro::simulator::engine::EngineArrayConfig;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "resnet18".to_string());
    let model = model_by_name(&name).ok_or_else(|| format!("unknown model {name}"))?;
    println!("model: {} ({:.2} GMACs)", model.name, model.total_macs() as f64 / 1e9);

    // Per-layer compression from the shared study (APack scheme).
    let study = CompressionStudy::run(
        &[model.clone()],
        &[Scheme::Baseline, Scheme::Apack],
    );
    let mc = study.get(&name, Scheme::Apack).unwrap();

    println!(
        "\n{:<10} {:>12} {:>12} {:>10} {:>12} {:>12}",
        "BW (GB/s)", "base (ms)", "apack (ms)", "speedup", "base (mJ)", "apack (mJ)"
    );
    for bw_scale in [0.25, 0.5, 0.75, 1.0, 1.5, 2.0] {
        let mut cfg = AcceleratorConfig::paper();
        cfg.dram.mt_per_s = (3200.0 * bw_scale) as u64;
        cfg.dram.tck_mhz = cfg.dram.mt_per_s / 2;
        let sim = AcceleratorSim::new(cfg);
        let base = sim.simulate_model(&model, &|_| TrafficScaling::NONE);
        let apack = sim.simulate_model(&model, &|i| {
            let lc = mc.per_layer[i];
            TrafficScaling { weights: lc.weights_norm, activations: lc.acts_norm }
        });
        let tb = AcceleratorSim::total_time(&base);
        let ta = AcceleratorSim::total_time(&apack);
        let em_base = EnergyModel::new(&sim, None);
        let em_ap = EnergyModel::new(&sim, Some(EngineArrayConfig::paper_64()));
        let eb = em_base.inference_energy(&base, tb).total_j();
        let ea = em_ap.inference_energy(&apack, ta).total_j();
        println!(
            "{:<10.1} {:>12.3} {:>12.3} {:>9.2}x {:>12.3} {:>12.3}",
            cfg.dram.peak_bandwidth() / 1e9,
            tb * 1e3,
            ta * 1e3,
            tb / ta,
            eb * 1e3,
            ea * 1e3
        );
    }
    println!(
        "\nreading: APack at reduced bandwidth matches the baseline at full bandwidth\n\
         wherever the compressed memory time stays under the compute time."
    );
    Ok(())
}
