//! APackStore hot-path bench: random access into a packed store.
//!
//! Sections:
//! 1. full-tensor decode, cold cache (all chunks from disk, parallel);
//! 2. **multi-threaded `get_range` scaling** — the same total read work
//!    spread over 1..N reader threads, on the mmap backend and the file
//!    backend, caches disabled. With the io mutex gone, throughput must
//!    grow with threads (this is the regression guard for the lock-free
//!    `ChunkSource` path); per-backend `bytes_read` is printed so the two
//!    paths are directly comparable in one run;
//! 3. cached vs uncached chunk reads (what the LRU buys on repeat traffic);
//! 4. a sharded store of the same tensors: per-shard parallel verify and
//!    concurrent reads through the same `StoreHandle` surface.
//!
//! Pass `--quick` (CI does) for a small store and few iterations.

use std::time::{Duration, Instant};

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::PartitionPolicy;
use apack_repro::models::distributions::ValueProfile;
use apack_repro::store::{Backend, ShardedStoreWriter, StoreHandle, StoreWriter};
use apack_repro::util::bench::Bench;
use apack_repro::util::Rng64;

/// Total random `get_range` reads spread across the reader threads, and
/// the values served — fixed work per scaling point so the wall-clock
/// trend is the scaling signal.
fn range_read_pass(
    store: &StoreHandle,
    threads: usize,
    total_reads: usize,
    span: u64,
    n_values: u64,
    names: &[String],
) -> (Duration, u64) {
    let reads_per_thread = total_reads.div_ceil(threads);
    let t0 = Instant::now();
    let mut served = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for tid in 0..threads {
            handles.push(scope.spawn(move || {
                let mut rng = Rng64::new(0xBE57 ^ ((tid as u64) << 8));
                let mut acc = 0u64;
                for _ in 0..reads_per_thread {
                    let name = &names[rng.below(names.len() as u64) as usize];
                    let lo = rng.below(n_values - span);
                    acc += store.get_range(name, lo..lo + span).unwrap().len() as u64;
                }
                acc
            }));
        }
        for h in handles {
            served += h.join().expect("reader thread");
        }
    });
    (t0.elapsed(), served)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let (n_tensors, n_values, bench, total_reads) = if quick {
        (2usize, 200_000usize, Bench::quick(), 64usize)
    } else {
        (8, 1_000_000, Bench::default(), 256)
    };
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut thread_points = vec![1usize, 2, 4, 8];
    thread_points.retain(|&t| t <= avail.max(2));
    if quick {
        thread_points = vec![1, avail.clamp(2, 4)];
    }

    let path = std::env::temp_dir()
        .join(format!("apack_bench_store_{}.apackstore", std::process::id()));
    let shard_dir = std::env::temp_dir()
        .join(format!("apack_bench_store_{}.apackstore.d", std::process::id()));
    let policy = PartitionPolicy::default(); // 64 chunks per tensor

    // Build the single-file store: n_tensors × n_values activation tensors.
    let tensors: Vec<(String, Vec<u32>)> = (0..n_tensors)
        .map(|i| {
            let values =
                ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
                    .sample(8, n_values, 1000 + i as u64);
            (format!("tensor{i}"), values)
        })
        .collect();
    let mut writer = StoreWriter::create(&path, policy).expect("create store");
    for (name, values) in &tensors {
        writer.add_tensor(name, 8, values, TensorKind::Activations).expect("add tensor");
    }
    let summary = writer.finish().expect("finish store");
    println!(
        "store: {} tensors, {} chunks, {:.1} MiB on disk ({:.2}x vs raw){}\n",
        summary.tensors,
        summary.chunks,
        summary.file_bytes as f64 / (1 << 20) as f64,
        summary.compression_ratio(),
        if quick { "  [quick]" } else { "" }
    );
    let names: Vec<String> = tensors.iter().map(|(n, _)| n.clone()).collect();

    let store = StoreHandle::open(&path).expect("open store");
    let meta = store.meta("tensor0").expect("meta");
    let chunks_per_tensor = meta.chunks.len();
    let per_chunk = meta.values_per_chunk;
    let span = 4 * per_chunk;

    // 1. Full-tensor decode, cold cache.
    let s = bench.run("store get_tensor full (cold cache, mmap)", || {
        store.clear_cache();
        store.get_tensor("tensor0").unwrap()
    });
    println!("{}", s.report(Some(n_values as u64)));

    // 2. Multi-threaded get_range scaling, caches OFF, both backends.
    println!(
        "\nget_range scaling: {total_reads} random {span}-value reads, caches off \
         ({avail} cores)"
    );
    for backend in [Backend::Mmap, Backend::File] {
        let uncached = StoreHandle::open_with(&path, backend, 0).expect("open uncached");
        let mut t1 = None;
        for &threads in &thread_points {
            let (dt, served) = range_read_pass(
                &uncached,
                threads,
                total_reads,
                span,
                n_values as u64,
                &names,
            );
            let mvals = served as f64 / dt.as_secs_f64() / 1e6;
            let speedup = match t1 {
                None => {
                    t1 = Some(dt);
                    1.0
                }
                Some(base) => base.as_secs_f64() / dt.as_secs_f64(),
            };
            println!(
                "  {:<5} backend  {threads:>2} threads  {dt:>10.3?}  {mvals:>8.1} Mvalues/s  \
                 {speedup:>5.2}x vs 1 thread",
                backend.name()
            );
        }
        let stats = uncached.stats();
        println!(
            "  {:<5} backend  bytes_read {} ({:.1} MiB compressed), {} chunks decoded",
            backend.name(),
            stats.bytes_read,
            stats.bytes_read as f64 / (1 << 20) as f64,
            stats.chunks_decoded
        );
    }

    // 3. Random single-chunk reads: uncached vs cache-warm.
    let reads = 64usize;
    let mut rng = Rng64::new(7);
    let keys: Vec<(String, usize)> = (0..reads)
        .map(|_| {
            (
                format!("tensor{}", rng.below(n_tensors as u64)),
                rng.below(chunks_per_tensor as u64) as usize,
            )
        })
        .collect();
    let s = bench.run("store get_chunk ×64 random (uncached)", || {
        store.clear_cache();
        let mut acc = 0u64;
        for (name, ci) in &keys {
            acc += store.get_chunk(name, *ci).unwrap().len() as u64;
        }
        acc
    });
    println!("\n{}", s.report(Some((reads as u64) * per_chunk)));
    for (name, ci) in &keys {
        store.get_chunk(name, *ci).unwrap();
    }
    let s = bench.run("store get_chunk ×64 random (cached)", || {
        let mut acc = 0u64;
        for (name, ci) in &keys {
            acc += store.get_chunk(name, *ci).unwrap().len() as u64;
        }
        acc
    });
    println!("{}", s.report(Some((reads as u64) * per_chunk)));
    let stats = store.stats();
    println!(
        "single-file session: {:.1} MiB compressed via {} backend, {} decodes, \
         hit rate {:.0}%, decode {:.1} MB/s/thread, scratch reuse {:.0}%",
        stats.bytes_read as f64 / (1 << 20) as f64,
        stats.backend.name(),
        stats.chunks_decoded,
        100.0 * stats.hit_rate(),
        stats.decode_mb_per_s(),
        100.0 * stats.scratch_reuse_rate()
    );
    drop(store);

    // 4. The same tensors as a sharded store: parallel verify + reads.
    let shards = if quick { 2 } else { 4 };
    let mut sw = ShardedStoreWriter::create(&shard_dir, shards, policy).expect("shard writer");
    for (name, values) in &tensors {
        sw.add_tensor(name, 8, values, TensorKind::Activations).expect("add tensor");
    }
    let ssum = sw.finish().expect("finish sharded");
    // Cache off, like section 2: this point must measure the concurrent
    // sharded IO path, not LRU hits.
    let sharded =
        StoreHandle::open_with(&shard_dir, Backend::Mmap, 0).expect("open sharded");
    println!(
        "\nsharded store: {} shard files, {} tensors, {:.1} MiB",
        ssum.shards,
        ssum.tensors,
        ssum.file_bytes as f64 / (1 << 20) as f64
    );
    let s = bench.run("sharded verify (per-shard parallel)", || {
        sharded.verify().unwrap()
    });
    println!("{}", s.report(Some(ssum.file_bytes)));
    let threads = *thread_points.last().unwrap();
    let (dt, served) =
        range_read_pass(&sharded, threads, total_reads, span, n_values as u64, &names);
    println!(
        "sharded get_range  {threads:>2} threads  {dt:>10.3?}  {:>8.1} Mvalues/s",
        served as f64 / dt.as_secs_f64() / 1e6
    );
    let sstats = sharded.stats();
    println!(
        "sharded session: decode {:.1} MB/s/thread over {} values, scratch reuse {:.0}% \
         (verify storms recycle their buffers)",
        sstats.decode_mb_per_s(),
        sstats.values_decoded,
        100.0 * sstats.scratch_reuse_rate()
    );

    drop(sharded);
    std::fs::remove_file(&path).ok();
    std::fs::remove_dir_all(&shard_dir).ok();
}
