//! APackStore hot-path bench: random access into a packed store — full
//! tensor decode, uncached vs. cached chunk reads, and cross-chunk range
//! reads. The cached/uncached split shows what the LRU buys on the serving
//! path (repeat reads skip both disk and the arithmetic decoder).

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::PartitionPolicy;
use apack_repro::models::distributions::ValueProfile;
use apack_repro::store::{StoreReader, StoreWriter};
use apack_repro::util::bench::Bench;
use apack_repro::util::Rng64;

fn main() {
    let path = std::env::temp_dir()
        .join(format!("apack_bench_store_{}.apackstore", std::process::id()));
    let n_tensors = 8usize;
    let n_values = 1_000_000usize;
    let policy = PartitionPolicy::default(); // 64 chunks per tensor

    // Build the store once: 8 × 1M-value activation tensors.
    let mut writer = StoreWriter::create(&path, policy).expect("create store");
    for i in 0..n_tensors {
        let values =
            ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
                .sample(8, n_values, 1000 + i as u64);
        writer
            .add_tensor(&format!("tensor{i}"), 8, &values, TensorKind::Activations)
            .expect("add tensor");
    }
    let summary = writer.finish().expect("finish store");
    println!(
        "store: {} tensors, {} chunks, {:.1} MiB on disk ({:.2}x vs raw)\n",
        summary.tensors,
        summary.chunks,
        summary.file_bytes as f64 / (1 << 20) as f64,
        summary.compression_ratio()
    );

    let reader = StoreReader::open(&path).expect("open store");
    let meta = reader.meta("tensor0").expect("meta");
    let chunks_per_tensor = meta.chunks.len();
    let per_chunk = meta.values_per_chunk;
    let bench = Bench::default();

    // Full-tensor decode, cold cache (all 64 chunks from disk, parallel).
    let s = bench.run("store get_tensor 1M values (cold cache)", || {
        reader.clear_cache();
        reader.get_tensor("tensor0").unwrap()
    });
    println!("{}", s.report(Some(n_values as u64)));

    // Random single-chunk reads, uncached: every read hits disk + decoder.
    let reads = 64usize;
    let mut rng = Rng64::new(7);
    let uncached_keys: Vec<(String, usize)> = (0..reads)
        .map(|_| {
            (
                format!("tensor{}", rng.below(n_tensors as u64)),
                rng.below(chunks_per_tensor as u64) as usize,
            )
        })
        .collect();
    let s = bench.run("store get_chunk ×64 random (uncached)", || {
        reader.clear_cache();
        let mut acc = 0u64;
        for (name, ci) in &uncached_keys {
            acc += reader.get_chunk(name, *ci).unwrap().len() as u64;
        }
        acc
    });
    println!("{}", s.report(Some((reads as u64) * per_chunk)));

    // The same reads, cache warm: pure LRU hits.
    for (name, ci) in &uncached_keys {
        reader.get_chunk(name, *ci).unwrap();
    }
    let s = bench.run("store get_chunk ×64 random (cached)", || {
        let mut acc = 0u64;
        for (name, ci) in &uncached_keys {
            acc += reader.get_chunk(name, *ci).unwrap().len() as u64;
        }
        acc
    });
    println!("{}", s.report(Some((reads as u64) * per_chunk)));

    // Cross-chunk range reads (4 chunks per read), uncached.
    let span = 4 * per_chunk;
    let ranges: Vec<(String, u64)> = (0..16)
        .map(|_| {
            let name = format!("tensor{}", rng.below(n_tensors as u64));
            let lo = rng.below((n_values as u64) - span);
            (name, lo)
        })
        .collect();
    let s = bench.run("store get_range 4-chunk span ×16 (uncached)", || {
        reader.clear_cache();
        let mut acc = 0u64;
        for (name, lo) in &ranges {
            acc += reader.get_range(name, *lo..*lo + span).unwrap().len() as u64;
        }
        acc
    });
    println!("{}", s.report(Some(16 * span)));

    let stats = reader.stats();
    println!(
        "\ncumulative: {:.1} MiB compressed read, {} chunks decoded, {} cache hits / {} misses",
        stats.bytes_read as f64 / (1 << 20) as f64,
        stats.chunks_decoded,
        stats.cache_hits,
        stats.cache_misses
    );
    drop(reader);
    std::fs::remove_file(&path).ok();
}
