//! Bench + regeneration of paper Fig 8 (overall energy efficiency;
//! paper: APack 1.37x, ShapeShifter 1.23x).

use apack_repro::eval::{fig8, CompressionStudy};
use apack_repro::util::bench::Bench;

fn main() {
    let study = CompressionStudy::full();
    let bench = Bench::quick();
    let s = bench.run("fig8: energy-efficiency model over perf-study models", || {
        fig8::fig8_rows(&study).len()
    });
    println!("{}", s.report(None));
    println!("{}", fig8::render(&study));
}
