//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. Table rows: 16 (paper) vs 8 vs 32-equivalent — footprint impact of
//!    the coarse range table vs a full per-value table (entropy bound).
//! 2. Search depth (Listing 1 DEPTH_MAX): 0 (uniform) / 1 / 2 (paper).
//! 3. Probability-count width: 10 bits (paper) vs the entropy bound.
//! 4. Substream count: footprint overhead + parallel speedup of sharding.

use apack_repro::apack::encoder::ApackEncoder;
use apack_repro::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use apack_repro::apack::Histogram;
use apack_repro::coordinator::{Coordinator, PartitionPolicy};
use apack_repro::models::distributions::ValueProfile;
use apack_repro::simulator::memsys::{even_substreams, simulate, MemSysConfig};
use apack_repro::util::bench::Bench;

fn bits_with_cfg(hist: &Histogram, values: &[u32], cfg: &TableGenConfig) -> f64 {
    let t = generate_table(hist, TensorKind::Activations, cfg).unwrap();
    let (_, sb, _, ob) = ApackEncoder::encode_all(&t, values).unwrap();
    (sb + ob) as f64 / values.len() as f64
}

fn main() {
    let n = 1 << 20;
    let profile = ValueProfile::ReluActivation { sparsity: 0.55, q: 0.92, noise_floor: 0.02 };
    let values = profile.sample(8, n, 7);
    let hist = Histogram::from_values(8, &values);
    println!("tensor: {n} values, exact entropy {:.3} b/v (ideal AC bound)\n", hist.entropy());

    // --- Ablation: search depth.
    for depth in [0u32, 1, 2, 3] {
        let cfg = TableGenConfig { depth_max: depth, ..TableGenConfig::default() };
        let bpv = if depth == 0 {
            // depth 0 = uniform table, no search.
            let t = apack_repro::apack::SymbolTable::uniform(8);
            let (_, sb, _, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
            (sb + ob) as f64 / values.len() as f64
        } else {
            bits_with_cfg(&hist, &values, &cfg)
        };
        println!("search depth {depth}: {bpv:.3} bits/value");
    }

    // --- Ablation: search threshold.
    for thr in [0.999f64, 0.99, 0.9] {
        let cfg = TableGenConfig { threshold: thr, ..TableGenConfig::default() };
        println!("threshold {thr}: {:.3} bits/value", bits_with_cfg(&hist, &values, &cfg));
    }

    // --- Ablation: quantization width (paper: "APack naturally rewards
    // quantization" — non-uniformity persists at 4/6/8 bits).
    println!();
    for bits in [4u32, 6, 8] {
        let qp = ValueProfile::TwoSidedGeometric { q: 0.8, noise_floor: 0.01 };
        let qv = qp.sample(bits, 1 << 18, 11);
        let qh = Histogram::from_values(bits, &qv);
        let t = generate_table(&qh, TensorKind::Weights, &TableGenConfig::for_bits(bits)).unwrap();
        let (_, sb, _, ob) = ApackEncoder::encode_all(&t, &qv).unwrap();
        let bpv = (sb + ob) as f64 / qv.len() as f64;
        println!(
            "quantized to {bits}b: {bpv:.3} bits/value (ratio {:.2}x, entropy {:.3})",
            bits as f64 / bpv,
            qh.entropy()
        );
    }

    // --- Ablation: engine replication vs effective bandwidth (the §V-B
    // sizing trade, via the transaction-level memsys model).
    println!();
    for engines in [8usize, 16, 32, 64, 128] {
        let cfg = MemSysConfig { engines, ..MemSysConfig::paper() };
        let r = simulate(&cfg, &even_substreams(16_000_000, 4.0, engines));
        println!(
            "{engines:>4} engines: {:.1} values/cycle, channel util {:.2}, engine util {:.2}",
            r.throughput(),
            r.channel_utilization,
            r.engine_utilization
        );
    }
    println!();

    // --- Ablation: substream count (footprint + wall time).
    let table =
        generate_table(&hist, TensorKind::Activations, &TableGenConfig::default()).unwrap();
    let bench = Bench::quick();
    for streams in [1u32, 4, 16, 64, 256] {
        let mut coord =
            Coordinator::new(PartitionPolicy { substreams: streams, min_per_stream: 1 });
        let sc = coord.compress_with_table(table.clone(), &values).unwrap();
        let s = bench.run(&format!("decode {streams} substreams"), || {
            coord.decompress(&sc).unwrap()
        });
        println!(
            "{}   footprint {:.4} bits/value",
            s.report(Some(n as u64)),
            sc.footprint_bits() as f64 / n as f64
        );
    }
}
