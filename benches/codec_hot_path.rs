//! Hot-path throughput bench: software encoder/decoder values/s and GB/s,
//! single-stream and through the parallel coordinator — the §Perf numbers
//! in EXPERIMENTS.md come from this target.

use apack_repro::apack::bitstream::BitReader;
use apack_repro::apack::decoder::{ApackDecoder, ResolveMode};
use apack_repro::apack::encoder::ApackEncoder;
use apack_repro::apack::tablegen::{table_for_tensor, TensorKind};
use apack_repro::coordinator::{Coordinator, PartitionPolicy};
use apack_repro::models::distributions::ValueProfile;
use apack_repro::util::bench::Bench;

fn main() {
    let n = 4_000_000usize;
    let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, n, 42);
    let table = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let bench = Bench::default();

    // Single-stream encode.
    let s = bench.run("encode single-stream (4M values)", || {
        ApackEncoder::encode_all(&table, &values).unwrap()
    });
    println!("{}", s.report(Some(n as u64)));

    // Single-stream decode, both resolver models.
    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&table, &values).unwrap();
    for mode in [ResolveMode::Division, ResolveMode::RowScan] {
        let s = bench.run(&format!("decode single-stream {mode:?}"), || {
            let mut dec =
                ApackDecoder::new(&table, BitReader::new(&sym, sb)).unwrap().with_mode(mode);
            let mut ofs_r = BitReader::new(&ofs, ob);
            let mut acc = 0u64;
            for _ in 0..n {
                acc += dec.decode_value(&mut ofs_r).unwrap() as u64;
            }
            acc
        });
        println!("{}", s.report(Some(n as u64)));
    }

    // Parallel coordinator (64 substreams).
    let mut coord = Coordinator::new(PartitionPolicy::default());
    let s = bench.run("coordinator encode (64 substreams)", || {
        coord.compress_with_table(table.clone(), &values).unwrap()
    });
    println!("{}", s.report(Some(n as u64)));

    let sc = coord.compress_with_table(table.clone(), &values).unwrap();
    let s = bench.run("coordinator decode (64 substreams)", || coord.decompress(&sc).unwrap());
    println!("{}", s.report(Some(n as u64)));

    // Table generation cost (the offline profiling step).
    let s = bench.run("table generation (Listing 1 search)", || {
        table_for_tensor(8, &values[..65536], TensorKind::Activations).unwrap()
    });
    println!("{}", s.report(None));
}
