//! Hot-path throughput bench: software encoder/decoder values/s and GB/s —
//! single-stream (per-value reference vs. block `decode_into`, every
//! `ResolveMode`), through the parallel coordinator, and over the store
//! chunk-body paths (v1 single-stream vs. v2 lane bodies across the lane
//! sweep — scalar SoA, SIMD lane-kernel, and threaded).
//!
//! Thin wrapper over [`apack_repro::eval::hot_path`]: the harness asserts
//! every decode configuration bit-exact against the encoder input before
//! timing it, then writes the machine-readable `BENCH_codec_hot_path.json`
//! at the package root (uploaded as a CI artifact) so decode throughput is
//! a tracked number PR over PR.
//!
//! Pass `--quick` (CI does) for fewer iterations; the workload stays the
//! reference 4M-value ReLU-activation tensor either way. Table-generation
//! cost (the offline profiling step) is timed here too since it is not
//! part of the JSON schema.

use std::path::Path;
use std::time::Instant;

use apack_repro::apack::bitstream::BitReader;
use apack_repro::apack::decoder::{ApackDecoder, ResolveMode};
use apack_repro::apack::encoder::ApackEncoder;
use apack_repro::apack::tablegen::{table_for_tensor, TensorKind};
use apack_repro::eval::hot_path::{self, HotPathConfig};
use apack_repro::models::distributions::ValueProfile;
use apack_repro::obs;
use apack_repro::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { HotPathConfig::quick() } else { HotPathConfig::full() };

    let report = hot_path::run(&cfg);
    print!("{}", report.render());

    // Persist the artifact BEFORE the regression gate below: a failing run
    // is exactly when the recorded numbers matter.
    let path = Path::new(hot_path::REPORT_FILE);
    report.write_json(path).expect("write bench JSON");
    println!("wrote {}", path.display());

    // Release-profile regression floor: the block+Lut fast path must beat
    // the per-value RowScan baseline outright (the ISSUE-4 target is ≥2×;
    // the hard gate is kept at >1× so shared CI runners don't flake, and
    // the exact ratio is tracked in the JSON artifact PR over PR).
    assert!(
        report.speedup_block_lut_vs_per_value_rowscan > 1.0,
        "block Lut decode ({:.2}x) regressed below the per-value RowScan baseline",
        report.speedup_block_lut_vs_per_value_rowscan
    );

    // ISSUE-7 gate: the chunk-body v2 threaded lane decode (16 lanes) must
    // beat the v1 single-stream store-body path it replaces. Like the gate
    // above, the hard floor is >1× (the exact ratio is tracked in the JSON
    // artifact); the per-lane-count SoA and threaded entries are all in
    // the report for inspection.
    assert!(
        report.speedup_body_v2_threaded16_vs_v1 > 1.0,
        "body v2 threaded 16-lane decode ({:.2}x) regressed below the v1 \
         single-stream store-body baseline",
        report.speedup_body_v2_threaded16_vs_v1
    );

    // ISSUE-9 gate, x86_64 only (other architectures may resolve the SIMD
    // kernel to the scalar loop, where the ratio is noise around 1×): the
    // 16-lane SIMD lane-parallel kernel must beat the scalar SoA loop on
    // the same body. Hard floor >1×, exact ratio tracked in the JSON.
    #[cfg(target_arch = "x86_64")]
    assert!(
        report.speedup_body_v2_simd16_vs_soa16 > 1.0,
        "body v2 SIMD 16-lane decode ({:.2}x) regressed below the scalar \
         SoA 16-lane baseline",
        report.speedup_body_v2_simd16_vs_soa16
    );

    // Table generation cost (the offline Listing-1 search), outside the
    // JSON schema but worth watching.
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, 65_536, 42);
    let s = bench.run("table generation (Listing 1 search)", || {
        table_for_tensor(8, &values, TensorKind::Activations).unwrap()
    });
    println!("{}", s.report(None));

    tracing_overhead_gate(quick);
    attribution_overhead_gate(quick);
}

/// Observability overhead gate (ISSUE 6): the span site inside the block
/// `decode_into` fast path must stay within 3% of an untraced decode —
/// disabled, its whole cost is one relaxed atomic load; enabled, one span
/// is recorded per block decode into a per-thread ring. Enabled and
/// disabled runs are interleaved round-by-round and compared best-of-N so
/// runner noise lands on both sides of the ratio equally, plus a small
/// absolute epsilon so sub-millisecond jitter cannot flake a shared CI
/// runner.
fn tracing_overhead_gate(quick: bool) {
    let n = 1_000_000;
    let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, n, 7);
    let table = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&table, &values).unwrap();
    let mut out = vec![0u32; n];
    let decode_once = |out: &mut [u32]| {
        let mut dec = ApackDecoder::new(&table, BitReader::new(&sym, sb))
            .unwrap()
            .with_mode(ResolveMode::Lut);
        let mut ofs_r = BitReader::new(&ofs, ob);
        dec.decode_into(out, &mut ofs_r).unwrap();
    };

    obs::disable();
    obs::drain();
    decode_once(&mut out); // warmup
    assert_eq!(out, values, "overhead-gate decode diverged");

    let rounds: usize = if quick { 7 } else { 15 };
    let (mut best_off, mut best_on) = (u64::MAX, u64::MAX);
    for _ in 0..rounds {
        obs::disable();
        let t = Instant::now();
        decode_once(&mut out);
        best_off = best_off.min(t.elapsed().as_nanos() as u64);

        obs::enable();
        let t = Instant::now();
        decode_once(&mut out);
        best_on = best_on.min(t.elapsed().as_nanos() as u64);
    }
    obs::disable();
    let spans = obs::drain().len();
    assert!(spans >= rounds, "enabled rounds recorded {spans} spans, expected >= {rounds}");

    let overhead = best_on as f64 / best_off.max(1) as f64 - 1.0;
    println!(
        "tracing overhead gate: block Lut decode {:+.2}% enabled vs disabled \
         (best of {rounds}: {best_on} ns vs {best_off} ns, {spans} spans recorded)",
        100.0 * overhead
    );
    assert!(
        best_on as f64 <= best_off as f64 * 1.03 + 100_000.0,
        "tracing-enabled block decode ({best_on} ns) exceeds the 3% overhead \
         budget over disabled ({best_off} ns)"
    );
}

/// Attribution-layer overhead gate (ISSUE 8): the same block-Lut decode
/// measured through the **store reader** — every read is a cache-disabled
/// demand decode that updates the per-chunk heatmap counters and, when
/// tracing is on, records the full span path the profile folds. The whole
/// attribution stack (heatmap shards + spans) must stay within the same
/// 3% budget as the bare tracer gate above, interleaved best-of-N with
/// the same absolute epsilon against shared-runner jitter.
fn attribution_overhead_gate(quick: bool) {
    use apack_repro::coordinator::PartitionPolicy;
    use apack_repro::store::{BodyConfig, StoreHandle, StoreWriter};

    let n = 1_000_000usize;
    let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, n, 7);
    let path = std::env::temp_dir()
        .join(format!("apack_attr_gate_{}.apackstore", std::process::id()));
    // One v1 single-stream chunk: the store-body counterpart of the
    // block-Lut decode the tracer gate times.
    let policy = PartitionPolicy { substreams: 1, min_per_stream: n };
    let mut w = StoreWriter::create_with(&path, policy, BodyConfig::v1())
        .expect("create gate store");
    w.add_tensor("t", 8, &values, TensorKind::Activations).expect("pack gate tensor");
    w.finish().expect("finish gate store");
    // Cache budget 0: every get_chunk is a demand miss straight through
    // decode + heatmap accounting.
    let store = StoreHandle::open_with(&path, Default::default(), 0).expect("open gate store");
    let decode_once = || {
        let got = store.get_chunk("t", 0).expect("gate chunk decode");
        assert_eq!(got.len(), n);
    };

    obs::disable();
    obs::drain();
    decode_once(); // warmup; also checks the path works at all

    let rounds: usize = if quick { 7 } else { 15 };
    let (mut best_off, mut best_on) = (u64::MAX, u64::MAX);
    for _ in 0..rounds {
        obs::disable();
        let t = Instant::now();
        decode_once();
        best_off = best_off.min(t.elapsed().as_nanos() as u64);

        obs::enable();
        let t = Instant::now();
        decode_once();
        best_on = best_on.min(t.elapsed().as_nanos() as u64);
    }
    obs::disable();
    let spans = obs::drain().len();
    assert!(spans >= rounds, "enabled rounds recorded {spans} spans, expected >= {rounds}");

    // The heatmap saw every read (warmup + both sides of every round).
    let heat = store.heatmap();
    assert_eq!(heat.len(), 1, "gate store has one chunk");
    assert_eq!(heat[0].demand_misses, (1 + 2 * rounds) as u64);
    drop(store);
    std::fs::remove_file(&path).ok();

    let overhead = best_on as f64 / best_off.max(1) as f64 - 1.0;
    println!(
        "attribution overhead gate: store chunk decode {:+.2}% enabled vs disabled \
         (best of {rounds}: {best_on} ns vs {best_off} ns, {spans} spans recorded)",
        100.0 * overhead
    );
    assert!(
        best_on as f64 <= best_off as f64 * 1.03 + 100_000.0,
        "attribution-enabled store decode ({best_on} ns) exceeds the 3% overhead \
         budget over disabled ({best_off} ns)"
    );
}
