//! Hot-path throughput bench: software encoder/decoder values/s and GB/s —
//! single-stream (per-value reference vs. block `decode_into`, every
//! `ResolveMode`) and through the parallel coordinator.
//!
//! Thin wrapper over [`apack_repro::eval::hot_path`]: the harness asserts
//! every decode configuration bit-exact against the encoder input before
//! timing it, then writes the machine-readable `BENCH_codec_hot_path.json`
//! at the package root (uploaded as a CI artifact) so decode throughput is
//! a tracked number PR over PR.
//!
//! Pass `--quick` (CI does) for fewer iterations; the workload stays the
//! reference 4M-value ReLU-activation tensor either way. Table-generation
//! cost (the offline profiling step) is timed here too since it is not
//! part of the JSON schema.

use std::path::Path;

use apack_repro::apack::tablegen::{table_for_tensor, TensorKind};
use apack_repro::eval::hot_path::{self, HotPathConfig};
use apack_repro::models::distributions::ValueProfile;
use apack_repro::util::bench::Bench;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { HotPathConfig::quick() } else { HotPathConfig::full() };

    let report = hot_path::run(&cfg);
    print!("{}", report.render());

    // Persist the artifact BEFORE the regression gate below: a failing run
    // is exactly when the recorded numbers matter.
    let path = Path::new(hot_path::REPORT_FILE);
    report.write_json(path).expect("write bench JSON");
    println!("wrote {}", path.display());

    // Release-profile regression floor: the block+Lut fast path must beat
    // the per-value RowScan baseline outright (the ISSUE-4 target is ≥2×;
    // the hard gate is kept at >1× so shared CI runners don't flake, and
    // the exact ratio is tracked in the JSON artifact PR over PR).
    assert!(
        report.speedup_block_lut_vs_per_value_rowscan > 1.0,
        "block Lut decode ({:.2}x) regressed below the per-value RowScan baseline",
        report.speedup_block_lut_vs_per_value_rowscan
    );

    // Table generation cost (the offline Listing-1 search), outside the
    // JSON schema but worth watching.
    let bench = if quick { Bench::quick() } else { Bench::default() };
    let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, 65_536, 42);
    let s = bench.run("table generation (Listing 1 search)", || {
        table_for_tensor(8, &values, TensorKind::Activations).unwrap()
    });
    println!("{}", s.report(None));
}
