//! Bench + regeneration of paper Fig 7 (overall speedup on the
//! TensorCore accelerator; paper: APack 1.44x, ShapeShifter 1.30x).

use apack_repro::eval::{fig7, CompressionStudy};
use apack_repro::util::bench::Bench;

fn main() {
    let study = CompressionStudy::full();
    let bench = Bench::quick();
    let s = bench.run("fig7: accelerator simulation over perf-study models", || {
        fig7::fig7_rows(&study).len()
    });
    println!("{}", s.report(None));
    println!("{}", fig7::render(&study));
}
