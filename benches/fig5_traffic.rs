//! Bench + regeneration of paper Fig 5 (normalized off-chip traffic,
//! activations and weights, all five schemes over the 24-model zoo).
//!
//! The timed section runs a 4-model subset (the full study is run once for
//! the rendered figure — it is the same code path, just 6× the models).

use apack_repro::eval::study::Scheme;
use apack_repro::eval::{fig5, CompressionStudy};
use apack_repro::models::zoo::model_by_name;
use apack_repro::util::bench::Bench;

fn main() {
    let subset: Vec<_> = ["resnet18", "mobilenet_v1", "q8bert", "alexnet_eyeriss"]
        .iter()
        .map(|n| model_by_name(n).unwrap())
        .collect();
    let bench = Bench::quick();
    let s = bench.run("fig5: 4-model x 5-scheme traffic study", || {
        CompressionStudy::run(&subset, &Scheme::ALL).results.len()
    });
    println!("{}", s.report(None));

    println!("\nrunning the full 24-model study once for the figure...");
    let study = CompressionStudy::full();
    println!("{}", fig5::render(&study));
}
