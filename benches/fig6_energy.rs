//! Bench + regeneration of paper Fig 6 (normalized off-chip energy).

use apack_repro::eval::{fig6, CompressionStudy};
use apack_repro::util::bench::Bench;

fn main() {
    let study = CompressionStudy::full();
    let bench = Bench::quick();
    let s = bench.run("fig6: off-chip energy model over zoo", || fig6::fig6_rows(&study).len());
    println!("{}", s.report(None));
    println!("{}", fig6::render(&study));
}
