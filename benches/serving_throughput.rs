//! Serving-layer bench: closed-loop clients through a `ServingEngine`.
//!
//! Sections:
//! 1. **worker × queue-depth sweep** — closed-loop clients issuing a
//!    duplicate-heavy mix of chunk/range requests; reports requests/s and
//!    the p50/p95/p99 latency histogram per configuration, coalescing on
//!    vs off side by side.
//! 2. **coalescing demonstration** (asserted, so CI fails loudly on
//!    regression): a burst of duplicate single-chunk requests against an
//!    uncached store decodes measurably fewer chunks with coalescing ON
//!    than OFF, while every response stays bit-exact.
//! 3. **saturation demonstration** (asserted): a tiny queue in front of
//!    slow full-tensor requests sheds via `Error::Overloaded` instead of
//!    queueing without bound, and every admitted request still answers
//!    bit-exactly; a zero deadline sheds at pop with
//!    `deadline_expired = true`.
//!
//! Pass `--quick` (CI does) for a small store and few iterations.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use apack_repro::apack::tablegen::TensorKind;
use apack_repro::coordinator::PartitionPolicy;
use apack_repro::models::distributions::ValueProfile;
use apack_repro::serving::{Request, ServingConfig, ServingEngine, Ticket};
use apack_repro::store::{Backend, StoreHandle, StoreWriter};
use apack_repro::util::Rng64;
use apack_repro::Error;

/// Closed-loop pass: `clients` threads × `requests` blocking requests,
/// every response verified bit-exact against the reference decode.
/// Returns (wall time, completed, shed, values served).
fn client_pass(
    engine: &ServingEngine,
    reference: &HashMap<String, Vec<u32>>,
    names: &[String],
    clients: usize,
    requests: usize,
    hot_fraction: f64,
) -> (Duration, u64, u64, u64) {
    let t0 = Instant::now();
    let mut completed = 0u64;
    let mut shed = 0u64;
    let mut served = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for tid in 0..clients {
            handles.push(scope.spawn(move || {
                let mut rng = Rng64::new(0x5EED ^ ((tid as u64) << 16));
                let (mut completed, mut shed, mut served) = (0u64, 0u64, 0u64);
                for _ in 0..requests {
                    let name = &names[rng.below(names.len() as u64) as usize];
                    let expect = &reference[name];
                    let meta = engine.store().meta(name).unwrap();
                    let result = if rng.f64() < hot_fraction {
                        // Hot set: chunk 0 of this tensor — maximally
                        // duplicate-heavy traffic.
                        engine.get_chunk(name, 0).map(|v| {
                            let covered = meta.chunk_value_range(0);
                            assert_eq!(
                                v.as_slice(),
                                &expect[covered.start as usize..covered.end as usize],
                                "{name} hot chunk"
                            );
                            v.len() as u64
                        })
                    } else if rng.chance(0.5) {
                        let n = meta.n_values;
                        let lo = rng.below(n);
                        let span = 1 + rng.below((n - lo).min(8192));
                        engine.get_range(name, lo..lo + span).map(|v| {
                            assert_eq!(
                                v.as_slice(),
                                &expect[lo as usize..(lo + span) as usize],
                                "{name} {lo}+{span}"
                            );
                            v.len() as u64
                        })
                    } else {
                        let ci = rng.below(meta.chunks.len() as u64) as usize;
                        engine.get_chunk(name, ci).map(|v| {
                            let covered = meta.chunk_value_range(ci);
                            assert_eq!(
                                v.as_slice(),
                                &expect[covered.start as usize..covered.end as usize],
                                "{name} chunk {ci}"
                            );
                            v.len() as u64
                        })
                    };
                    match result {
                        Ok(n) => {
                            completed += 1;
                            served += n;
                        }
                        Err(Error::Overloaded { .. }) => shed += 1,
                        Err(e) => panic!("serving read failed: {e}"),
                    }
                }
                (completed, shed, served)
            }));
        }
        for handle in handles {
            let (c, s, v) = handle.join().expect("client thread");
            completed += c;
            shed += s;
            served += v;
        }
    });
    (t0.elapsed(), completed, shed, served)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick" || a == "quick");
    let (n_tensors, n_values, clients, requests, burst) = if quick {
        (2usize, 150_000usize, 8usize, 60usize, 192usize)
    } else {
        (4, 600_000, 16, 400, 512)
    };
    let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);

    // Build the store and the reference decode.
    let path = std::env::temp_dir()
        .join(format!("apack_bench_serving_{}.apackstore", std::process::id()));
    let policy = PartitionPolicy::default();
    let tensors: Vec<(String, Vec<u32>)> = (0..n_tensors)
        .map(|i| {
            let values =
                ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
                    .sample(8, n_values, 9000 + i as u64);
            (format!("tensor{i}"), values)
        })
        .collect();
    let mut writer = StoreWriter::create(&path, policy).expect("create store");
    for (name, values) in &tensors {
        writer.add_tensor(name, 8, values, TensorKind::Activations).expect("add tensor");
    }
    let summary = writer.finish().expect("finish store");
    println!(
        "store: {} tensors, {} chunks, {:.1} MiB ({:.2}x vs raw){}\n",
        summary.tensors,
        summary.chunks,
        summary.file_bytes as f64 / (1 << 20) as f64,
        summary.compression_ratio(),
        if quick { "  [quick]" } else { "" }
    );
    let names: Vec<String> = tensors.iter().map(|(n, _)| n.clone()).collect();
    let reference: HashMap<String, Vec<u32>> = tensors.into_iter().collect();

    // 1. Worker × queue-depth sweep, coalescing on vs off.
    let mut worker_points = vec![2usize, 4, 8];
    worker_points.retain(|&w| w <= avail.max(2));
    if quick {
        worker_points = vec![avail.clamp(2, 4)];
    }
    println!(
        "closed-loop sweep: {clients} clients × {requests} requests, 80% hot-set \
         ({avail} cores)"
    );
    for &workers in &worker_points {
        for queue_depth in [64usize, 256] {
            for coalescing in [false, true] {
                let store = Arc::new(StoreHandle::open(&path).expect("open store"));
                let engine = ServingEngine::start(
                    Arc::clone(&store),
                    ServingConfig {
                        workers,
                        queue_depth,
                        coalescing,
                        deadline: None,
                        prefetch: None,
                        slo: None,
                    },
                )
                .expect("start engine");
                let (dt, completed, shed, served) =
                    client_pass(&engine, &reference, &names, clients, requests, 0.8);
                let m = engine.metrics();
                println!(
                    "  {workers} workers  depth {queue_depth:>3}  coalescing {:>3}  \
                     {:>8.0} req/s  {:>7.1} Mvalues/s  {completed} ok / {shed} shed  \
                     coalesced {:>5}  p50 {:?} p95 {:?} p99 {:?}",
                    if coalescing { "on" } else { "off" },
                    (completed + shed) as f64 / dt.as_secs_f64(),
                    served as f64 / dt.as_secs_f64() / 1e6,
                    m.coalesced_decodes,
                    m.latency.p50,
                    m.latency.p95,
                    m.latency.p99,
                );
            }
        }
    }

    // 2. Coalescing demonstration: a duplicate burst against an UNCACHED
    // store. Every request targets the same chunk, so with coalescing off
    // each one decodes (burst decodes total); with it on, concurrent
    // duplicates share flights and the decode count collapses.
    println!("\ncoalescing: {burst} duplicate requests of one chunk, cache off");
    let burst_workers = avail.clamp(2, 8);
    let mut decoded = [0u64; 2];
    for (mode, coalescing) in [false, true].into_iter().enumerate() {
        let store = Arc::new(
            StoreHandle::open_with(&path, Backend::Mmap, 0).expect("open uncached"),
        );
        let engine = ServingEngine::start(
            Arc::clone(&store),
            ServingConfig {
                workers: burst_workers,
                queue_depth: burst + 8,
                coalescing,
                deadline: None,
                prefetch: None,
                slo: None,
            },
        )
        .expect("start engine");
        let expect = &reference["tensor0"];
        let covered = store.meta("tensor0").expect("meta").chunk_value_range(0);
        let tickets: Vec<Ticket> = (0..burst)
            .map(|_| {
                engine
                    .submit(Request::Chunk { tensor: "tensor0".to_string(), chunk: 0 })
                    .expect("burst fits the queue")
            })
            .collect();
        for ticket in tickets {
            let got = ticket.wait().expect("burst decode");
            assert_eq!(
                got.as_slice(),
                &expect[covered.start as usize..covered.end as usize],
                "coalesced responses must stay bit-exact"
            );
        }
        let stats = engine.stats();
        decoded[mode] = stats.chunks_decoded;
        println!(
            "  coalescing {:>3}: {} chunks decoded, {} coalesced, {} compressed bytes",
            if coalescing { "on" } else { "off" },
            stats.chunks_decoded,
            stats.coalesced_reads,
            stats.bytes_read
        );
    }
    assert_eq!(decoded[0], burst as u64, "cache off + coalescing off: every request decodes");
    assert!(
        decoded[1] < decoded[0] * 3 / 4,
        "coalescing must measurably cut decodes: on {} vs off {}",
        decoded[1],
        decoded[0]
    );
    println!(
        "  => {:.1}x fewer decodes with coalescing on",
        decoded[0] as f64 / decoded[1].max(1) as f64
    );

    // 3. Saturation: a tiny queue in front of slow full-tensor decodes
    // shed via Error::Overloaded instead of queueing without bound.
    println!("\nsaturation: 1 worker, queue depth 4, full-tensor request flood");
    let store = Arc::new(StoreHandle::open(&path).expect("open store"));
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            workers: 1,
            queue_depth: 4,
            coalescing: true,
            deadline: None,
            prefetch: None,
            slo: None,
        },
    )
    .expect("start engine");
    let flood = if quick { 60 } else { 200 };
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for _ in 0..flood {
        match engine.submit(Request::Tensor { tensor: "tensor0".to_string() }) {
            Ok(ticket) => admitted.push(ticket),
            Err(Error::Overloaded { queue_depth, deadline_expired }) => {
                assert_eq!(queue_depth, 4);
                assert!(!deadline_expired);
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let expect = &reference["tensor0"];
    let admitted_count = admitted.len() as u64;
    for ticket in admitted {
        assert_eq!(
            ticket.wait().expect("admitted request").as_slice(),
            &expect[..],
            "admitted requests still answer bit-exactly under overload"
        );
    }
    assert!(shed > 0, "a {flood}-request flood must overflow a 4-deep queue");
    assert_eq!(admitted_count + shed, flood as u64);
    let m = engine.metrics();
    assert_eq!(m.shed_queue_full, shed);
    println!(
        "  {admitted_count} admitted (all bit-exact), {shed} shed via Error::Overloaded, \
         peak queue depth {}",
        m.queue_depth_max
    );
    drop(engine);

    // Zero deadline: everything queued sheds at pop, typed as such.
    let engine = ServingEngine::start(
        Arc::clone(&store),
        ServingConfig {
            workers: 1,
            queue_depth: 64,
            coalescing: true,
            deadline: Some(Duration::ZERO),
            prefetch: None,
            slo: None,
        },
    )
    .expect("start engine");
    let mut deadline_shed = 0u64;
    for _ in 0..8 {
        match engine.get_chunk("tensor0", 0) {
            Err(Error::Overloaded { deadline_expired: true, .. }) => deadline_shed += 1,
            other => panic!("zero deadline must shed, got {other:?}"),
        }
    }
    assert_eq!(deadline_shed, 8);
    println!("  zero-deadline requests: all {deadline_shed} shed with deadline_expired");

    drop(engine);
    drop(store);
    std::fs::remove_file(&path).ok();
    println!("\nserving bench OK: coalescing reduces decodes, overload sheds typed errors");
}
