//! Ingest throughput bench: tablegen (seed vs. incremental search),
//! encoder (per-value vs. block) and end-to-end `pack_model_zoo` (serial
//! vs. pipelined) values/s and MB/s — the write-path mirror of
//! `codec_hot_path`.
//!
//! Thin wrapper over [`apack_repro::eval::ingest`]: the harness asserts
//! every equivalence *before* timing anything — incremental tablegen must
//! produce byte-identical tables to the seed search, the block encoder
//! must emit bit-identical streams to the per-value reference (and those
//! streams must round-trip decode), and the pipelined packer must write
//! byte-identical store files to the serial packer (which must pass
//! `verify`). It then writes the machine-readable `BENCH_store_pack.json`
//! at the package root (uploaded as a CI artifact) so ingest throughput is
//! a tracked number PR over PR.
//!
//! Pass `--quick` (CI does) for fewer iterations and a smaller pack.

use std::path::Path;

use apack_repro::eval::ingest::{self, IngestConfig};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { IngestConfig::quick() } else { IngestConfig::full() };

    let report = ingest::run(&cfg);
    print!("{}", report.render());

    // Persist the artifact BEFORE the regression gates below: a failing
    // run is exactly when the recorded numbers matter.
    let path = Path::new(ingest::REPORT_FILE);
    report.write_json(path).expect("write bench JSON");
    println!("wrote {}", path.display());

    // Release-profile regression floors (same shape as the codec_hot_path
    // gate): the block encoder must beat the per-value baseline outright,
    // and the pipelined packer must improve on the serial baseline
    // measured in this same run. The exact ratios are tracked in the JSON
    // artifact PR over PR.
    assert!(
        report.speedup_block_vs_per_value_encode > 1.0,
        "block encode ({:.2}x) regressed below the per-value baseline",
        report.speedup_block_vs_per_value_encode
    );
    assert!(
        report.speedup_pipelined_vs_serial_pack > 1.0,
        "pipelined pack ({:.2}x) regressed below the serial baseline",
        report.speedup_pipelined_vs_serial_pack
    );
    // The incremental search is informational here (it is exact-equivalence
    // gated inside the harness); print it loudly instead of gating so a
    // noisy shared runner cannot flake CI on it.
    println!(
        "incremental tablegen speedup: {:.2}x",
        report.speedup_incremental_vs_seed_tablegen
    );
}
