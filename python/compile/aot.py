"""AOT lowering: JAX/Pallas model -> HLO **text** + weights + manifest.

HLO text (NOT ``lowered.compiler_ir('hlo')``/``.serialize()``) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs (into --out-dir, default ../artifacts):
  model.hlo.txt   the lowered module (input + weights as parameters)
  <layer>_w.bin   int8 weight bytes, C order
  <layer>_m.bin   int32 multiplier bytes, little-endian
  manifest.json   argument order/shapes for the rust runtime
"""

import argparse
import functools
import json
import os

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelSpec, example_args, forward, init_weights


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=2022)
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    spec = ModelSpec()
    weights = init_weights(spec, args.seed)

    fn = functools.partial(forward, spec)
    lowered = jax.jit(fn).lower(*example_args(spec, weights))
    hlo = to_hlo_text(lowered)
    with open(os.path.join(out_dir, "model.hlo.txt"), "w") as f:
        f.write(hlo)

    manifest = {
        "hlo": "model.hlo.txt",
        "input_shape": list(spec.input_shape),
        "bits": 8,
        "weights": [],
        "outputs": ["logits"] + [f"act_{l.name}" for l in spec.layers[:-1]],
    }
    for l in spec.layers:
        w, m = weights[l.name]
        wf, mf = f"{l.name}_w.bin", f"{l.name}_m.bin"
        w.tofile(os.path.join(out_dir, wf))
        m.astype("<i4").tofile(os.path.join(out_dir, mf))
        manifest["weights"].append({"name": f"{l.name}_w", "shape": list(w.shape), "file": wf})
        manifest["weights"].append(
            {"name": f"{l.name}_m", "shape": list(m.shape), "file": mf, "dtype": "int32"}
        )
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(hlo)} chars of HLO + {len(manifest['weights'])} weight blobs to {out_dir}")


if __name__ == "__main__":
    main()
