"""Pure-jnp correctness oracles for the Pallas kernels and the quantized
CNN building blocks. These define the semantics; the Pallas path must match
them exactly (integer arithmetic, no tolerance)."""

import jax.numpy as jnp


def requant_ref(acc, m, shift, relu=False):
    """Requantize an int32 accumulator: per-channel multiply, rounding
    right shift (round half up), optional ReLU, clamp to int8."""
    scaled = acc.astype(jnp.int32) * m.astype(jnp.int32)
    rounded = (scaled + (1 << (shift - 1))) >> shift
    if relu:
        rounded = jnp.maximum(rounded, 0)
    return jnp.clip(rounded, -128, 127).astype(jnp.int8)


def qmatmul_ref(x, w, m, shift=16, relu=False):
    """Reference quantized matmul (int8 x int8 -> int8)."""
    acc = jnp.matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    return requant_ref(acc, m[None, :], shift, relu)


def im2col_ref(x, kh, kw, stride=1, pad=0):
    """NCHW -> (N*HO*WO, C*kh*kw) patch matrix, zero padding."""
    n, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            patch = xp[:, :, i : i + stride * ho : stride, j : j + stride * wo : stride]
            cols.append(patch.reshape(n, c, ho * wo))
    # (kh*kw, N, C, HO*WO) -> (N, HO*WO, C, kh*kw)
    stacked = jnp.stack(cols, axis=0)
    stacked = stacked.transpose(1, 3, 2, 0)
    return stacked.reshape(n * ho * wo, c * kh * kw), (n, ho, wo)


def qconv2d_ref(x, w, m, stride=1, pad=0, shift=16, relu=False):
    """Reference quantized conv (NCHW, OIHW) via im2col + qmatmul_ref."""
    cout, cin, kh, kw = w.shape
    cols, (n, ho, wo) = im2col_ref(x, kh, kw, stride, pad)
    wm = w.transpose(1, 2, 3, 0).reshape(cin * kh * kw, cout)
    y = qmatmul_ref(cols, wm, m, shift, relu)  # (N*HO*WO, Cout)
    return y.reshape(n, ho, wo, cout).transpose(0, 3, 1, 2)
