"""L1 Pallas kernel: tiled int8 x int8 -> int32 matmul with fused
integer requantization — the compute hot-spot of the quantized CNN that
generates APack's traffic (the role tensor cores play in the paper's
accelerator, Table III).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's substrate
is a GPU-style tensor-core array; on the TPU-flavored Pallas side we tile
for the MXU instead — (bm, bn) output tiles staged through VMEM via
BlockSpec, int32 accumulation via ``preferred_element_type``, and the
requantize (multiply + rounding shift + clamp) fused before the store so
the int32 accumulator never leaves VMEM.

``interpret=True`` is mandatory here: real TPU lowering emits a Mosaic
custom-call that the CPU PJRT client cannot execute. Numerics are validated
against ``ref.py`` by ``python/tests/test_qmatmul.py`` (hypothesis sweep).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmatmul_kernel(x_ref, w_ref, m_ref, out_ref, *, shift: int, relu: bool):
    """One (bm, bn) output tile: full-K matmul + fused requantize.

    x_ref: (bm, K) int8 tile, w_ref: (K, bn) int8 tile, m_ref: (1, bn)
    int32 per-output-channel multipliers. Requant: y = clamp(
    round_half_up(acc * m / 2**shift), -128, 127).
    """
    acc = jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scaled = acc * m_ref[0, :][None, :]
    # Rounding right shift (round half up), in pure integer arithmetic.
    rounded = (scaled + (1 << (shift - 1))) >> shift
    if relu:
        rounded = jnp.maximum(rounded, 0)
    out_ref[...] = jnp.clip(rounded, -128, 127).astype(jnp.int8)


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("shift", "relu", "bm", "bn"))
def qmatmul(x, w, m, *, shift: int = 16, relu: bool = False, bm: int = 128, bn: int = 128):
    """Quantized matmul: ``requant(x @ w, m, shift)``.

    Args:
      x: (M, K) int8 activations.
      w: (K, N) int8 weights.
      m: (N,) int32 per-channel requant multipliers.
      shift: rounding right-shift applied after the multiply.
      relu: fuse a ReLU before the clamp.
      bm/bn: output tile sizes (MXU-shaped 128x128 by default; shrunk to
        the padded problem size for small layers).

    Returns: (M, N) int8.
    """
    assert x.dtype == jnp.int8 and w.dtype == jnp.int8, (x.dtype, w.dtype)
    assert m.dtype == jnp.int32
    assert x.shape[1] == w.shape[0]
    assert w.shape[1] == m.shape[0]
    assert 1 <= shift < 31
    M, K = x.shape
    N = w.shape[1]
    bm = min(bm, max(8, M))
    bn = min(bn, max(8, N))
    xp = _pad_to(x, bm, 0)
    wp = _pad_to(w, bn, 1)
    mp = _pad_to(m.reshape(1, -1), bn, 1)
    Mp, Np = xp.shape[0], wp.shape[1]
    out = pl.pallas_call(
        partial(_qmatmul_kernel, shift=shift, relu=relu),
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int8),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, mp)
    return out[:M, :N]
