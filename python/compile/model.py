"""L2: the quantized CNN whose weights/activations generate APack's
off-chip traffic. Forward pass only (inference), int8 quantized with
per-layer integer requantization; every convolution and linear layer runs
through the L1 Pallas ``qmatmul`` kernel (conv via im2col), so the whole
network lowers into one HLO module containing the kernel.

The PJRT boundary uses int32 tensors (the rust ``xla`` crate has no i8
literal type); values stay in int8 range and are cast at the edges.

The forward pass returns ``(logits, act_1, ..., act_L)`` — every
intermediate int8 activation tensor — so the rust coordinator can capture
a real activation trace to compress (the role of the PyTorch layer hooks
in the paper's §VII trace collection).
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from .kernels.qmatmul import qmatmul
from .kernels.ref import im2col_ref


@dataclass(frozen=True)
class ConvSpec:
    name: str
    cin: int
    cout: int
    k: int
    stride: int
    pad: int
    relu: bool = True


@dataclass(frozen=True)
class FcSpec:
    name: str
    cin: int
    cout: int
    relu: bool = True


@dataclass(frozen=True)
class ModelSpec:
    """The small int8 CNN of the e2e driver: 3 convs + 2 FCs on a
    (batch, 3, 16, 16) input — big enough to exercise every layer type,
    small enough for interpret-mode Pallas."""

    batch: int = 4
    in_hw: int = 16
    shift: int = 16
    layers: Tuple = field(
        default_factory=lambda: (
            ConvSpec("conv1", 3, 8, 3, 1, 1),
            ConvSpec("conv2", 8, 16, 3, 2, 1),
            ConvSpec("conv3", 16, 16, 3, 1, 1),
            FcSpec("fc1", 16 * 8 * 8, 32),
            FcSpec("fc2", 32, 10, relu=False),
        )
    )

    @property
    def input_shape(self):
        return (self.batch, 3, self.in_hw, self.in_hw)


def init_weights(spec: ModelSpec, seed: int = 2022):
    """Deterministic int8 weights + int32 requant multipliers per layer.

    Weights are drawn from a clipped discrete normal — the two-sided
    near-zero-heavy distribution real quantized checkpoints show. The
    requant multipliers are *calibrated*: a synthetic batch flows through
    the network layer by layer and each layer's multiplier is set so the
    99th-percentile |accumulator| maps near the top of the int8 range —
    the standard post-training-quantization recipe, keeping every layer's
    activations informative instead of saturating to zero.
    """
    from .kernels.ref import im2col_ref as _im2col, requant_ref as _requant

    rng = np.random.default_rng(seed)
    weights = {}
    x = jnp.asarray(
        rng.integers(-64, 64, size=spec.input_shape).astype(np.int8)
    )  # calibration batch
    for l in spec.layers:
        if isinstance(l, ConvSpec):
            shape = (l.cout, l.cin, l.k, l.k)
        else:
            shape = (l.cin, l.cout)
        w = np.clip(np.round(rng.normal(0.0, 14.0, size=shape)), -127, 127).astype(np.int8)
        # Calibration: raw int32 accumulator for this layer.
        if isinstance(l, ConvSpec):
            cols, (n, ho, wo) = _im2col(x, l.k, l.k, l.stride, l.pad)
            wm = jnp.asarray(w).transpose(1, 2, 3, 0).reshape(l.cin * l.k * l.k, l.cout)
            acc = jnp.matmul(cols.astype(jnp.int32), wm.astype(jnp.int32))
        else:
            flat = x.reshape(x.shape[0], -1)
            acc = jnp.matmul(
                flat.astype(jnp.int32), jnp.asarray(w).reshape(l.cin, l.cout).astype(jnp.int32)
            )
        p99 = float(np.percentile(np.abs(np.asarray(acc)), 99)) or 1.0
        m_val = max(1, min((1 << 30) // max(1, int(p99)),
                           int(round((1 << spec.shift) * 100.0 / p99))))
        m = np.full((l.cout,), m_val, dtype=np.int32)
        weights[l.name] = (w, m)
        # Produce this layer's int8 output for the next calibration step.
        y = _requant(acc, jnp.asarray(m)[None, :], spec.shift, l.relu)
        if isinstance(l, ConvSpec):
            x = y.reshape(n, ho, wo, l.cout).transpose(0, 3, 1, 2)
        else:
            x = y
    return weights


def forward(spec: ModelSpec, x_i32, *packed):
    """The jitted forward pass.

    Args:
      x_i32: (B, 3, H, W) int32 input (int8-range values).
      packed: alternating (w, m) int32 arrays per layer, in spec order
        (weights carried as int32 at the boundary, cast to int8 inside).

    Returns a tuple: (logits_i32, act1_i32, ..., actL_i32).
    """
    acts = []
    x = x_i32.astype(jnp.int8)
    i = 0
    for l in spec.layers:
        w = packed[i].astype(jnp.int8)
        m = packed[i + 1].astype(jnp.int32)
        i += 2
        if isinstance(l, ConvSpec):
            cols, (n, ho, wo) = im2col_ref(x, l.k, l.k, l.stride, l.pad)
            wm = w.transpose(1, 2, 3, 0).reshape(l.cin * l.k * l.k, l.cout)
            y = qmatmul(cols, wm, m, shift=spec.shift, relu=l.relu)
            x = y.reshape(n, ho, wo, l.cout).transpose(0, 3, 1, 2)
        else:
            flat = x.reshape(x.shape[0], -1)
            x = qmatmul(flat, w.reshape(l.cin, l.cout), m, shift=spec.shift, relu=l.relu)
        acts.append(x.astype(jnp.int32))
    logits = acts.pop()  # last layer's output is the logits
    return tuple([logits] + acts)


def example_args(spec: ModelSpec, weights) -> List:
    """Abstract args for jax.jit(...).lower(): input + packed weights."""
    import jax

    args = [jax.ShapeDtypeStruct(spec.input_shape, jnp.int32)]
    for l in spec.layers:
        w, m = weights[l.name]
        args.append(jax.ShapeDtypeStruct(w.shape, jnp.int32))
        args.append(jax.ShapeDtypeStruct(m.shape, jnp.int32))
    return args
