"""AOT pipeline: HLO text generation, manifest schema, and numerical
equivalence of the lowered module with the eager forward pass."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text
from compile.model import ModelSpec, example_args, forward, init_weights


def test_hlo_text_is_parseable_hlo(tmp_path):
    spec = ModelSpec()
    weights = init_weights(spec)
    import functools

    lowered = jax.jit(functools.partial(forward, spec)).lower(*example_args(spec, weights))
    hlo = to_hlo_text(lowered)
    assert "ENTRY" in hlo and "HloModule" in hlo
    # The tuple return carries 1 logits + 4 activation outputs.
    assert hlo.count("parameter(") >= 11  # input + 10 weight args


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert (out / manifest["hlo"]).exists()
    assert manifest["input_shape"] == [4, 3, 16, 16]
    for w in manifest["weights"]:
        f = out / w["file"]
        assert f.exists(), w
        elems = int(np.prod(w["shape"]))
        per = 4 if w.get("dtype") == "int32" else 1
        assert f.stat().st_size == elems * per, w


def test_lowered_module_matches_eager():
    spec = ModelSpec()
    weights = init_weights(spec)
    import functools

    fn = functools.partial(forward, spec)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.integers(-64, 64, spec.input_shape), jnp.int32)
    packed = []
    for l in spec.layers:
        w, m = weights[l.name]
        packed += [jnp.asarray(w, jnp.int32), jnp.asarray(m, jnp.int32)]
    eager = fn(x, *packed)
    compiled = jax.jit(fn)(x, *packed)
    for a, b in zip(eager, compiled):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
