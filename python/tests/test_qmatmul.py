"""L1 correctness: the Pallas qmatmul kernel must match the pure-jnp
oracle *exactly* (integer arithmetic) across shapes, tiles, shifts and
value distributions — the hypothesis sweep required by DESIGN.md inv. 7."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qmatmul import qmatmul
from compile.kernels.ref import qmatmul_ref


def _check(x, w, m, shift, relu, bm=128, bn=128):
    got = np.asarray(qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(m),
                             shift=shift, relu=relu, bm=bm, bn=bn))
    want = np.asarray(qmatmul_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(m),
                                  shift=shift, relu=relu))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    m_dim=st.integers(1, 70),
    k_dim=st.integers(1, 48),
    n_dim=st.integers(1, 40),
    shift=st.integers(4, 24),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference_across_shapes(m_dim, k_dim, n_dim, shift, relu, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (m_dim, k_dim)).astype(np.int8)
    w = rng.integers(-128, 128, (k_dim, n_dim)).astype(np.int8)
    mult = rng.integers(1, 1 << 12, (n_dim,)).astype(np.int32)
    _check(x, w, mult, shift, relu)


@settings(max_examples=10, deadline=None)
@given(bm=st.sampled_from([8, 16, 128]), bn=st.sampled_from([8, 32, 128]),
       seed=st.integers(0, 1000))
def test_tile_size_invariance(bm, bn, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (50, 17)).astype(np.int8)
    w = rng.integers(-128, 128, (17, 23)).astype(np.int8)
    mult = rng.integers(1, 4096, (23,)).astype(np.int32)
    _check(x, w, mult, 12, True, bm=bm, bn=bn)


def test_extreme_values_saturate_correctly():
    x = np.full((4, 8), -128, np.int8)
    w = np.full((8, 4), -128, np.int8)
    mult = np.full((4,), 1 << 10, np.int32)
    _check(x, w, mult, 8, False)   # massive positive accumulator -> clamp 127
    w2 = np.full((8, 4), 127, np.int8)
    _check(x, w2, mult, 8, False)  # massive negative -> clamp -128


def test_relu_zeroes_negatives():
    rng = np.random.default_rng(3)
    x = rng.integers(-128, 128, (16, 16)).astype(np.int8)
    w = rng.integers(-128, 128, (16, 16)).astype(np.int8)
    mult = np.full((16,), 600, np.int32)
    out = np.asarray(qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(mult),
                             shift=16, relu=True))
    assert (out >= 0).all()


def test_rounding_is_half_up():
    # acc*m = 1<<(shift-1) exactly -> rounds to 1, not 0.
    x = np.array([[1]], np.int8)
    w = np.array([[1]], np.int8)
    shift = 8
    mult = np.array([1 << (shift - 1)], np.int32)
    out = np.asarray(qmatmul(jnp.asarray(x), jnp.asarray(w), jnp.asarray(mult), shift=shift))
    assert out[0, 0] == 1


def test_rejects_bad_dtypes():
    with pytest.raises(AssertionError):
        qmatmul(jnp.zeros((4, 4), jnp.int32), jnp.zeros((4, 4), jnp.int8),
                jnp.ones((4,), jnp.int32))
