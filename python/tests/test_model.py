"""L2 correctness: quantized CNN forward pass — shapes, determinism,
activation health, and conv-vs-reference equivalence."""

import jax.numpy as jnp
import numpy as np

from compile.kernels.ref import qconv2d_ref
from compile.model import ConvSpec, ModelSpec, forward, init_weights


def _packed(spec, weights):
    out = []
    for l in spec.layers:
        w, m = weights[l.name]
        out += [jnp.asarray(w, jnp.int32), jnp.asarray(m, jnp.int32)]
    return out


def _run(seed=5):
    spec = ModelSpec()
    weights = init_weights(spec)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-64, 64, spec.input_shape), jnp.int32)
    return spec, weights, x, forward(spec, x, *_packed(spec, weights))


def test_output_shapes():
    spec, _, _, outs = _run()
    assert outs[0].shape == (spec.batch, 10)
    assert outs[1].shape == (spec.batch, 8, 16, 16)
    assert outs[2].shape == (spec.batch, 16, 8, 8)
    assert outs[3].shape == (spec.batch, 16, 8, 8)
    assert outs[4].shape == (spec.batch, 32)
    assert len(outs) == 1 + len(spec.layers) - 1


def test_values_stay_in_int8_range():
    _, _, _, outs = _run()
    for o in outs:
        a = np.asarray(o)
        assert a.min() >= -128 and a.max() <= 127


def test_deterministic():
    _, _, _, o1 = _run(7)
    _, _, _, o2 = _run(7)
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_logits_are_informative():
    # Calibrated multipliers must not saturate the network to zero.
    _, _, _, outs = _run()
    logits = np.asarray(outs[0])
    assert np.abs(logits).max() > 5
    assert len(np.unique(logits)) > 4


def test_relu_layers_are_sparse_and_nonnegative():
    spec, _, _, outs = _run()
    for o, l in zip(outs[1:], spec.layers[:-1]):
        a = np.asarray(o)
        assert (a >= 0).all(), f"{l.name} has negatives despite ReLU"
        assert 0.05 < (a == 0).mean() < 0.95, f"{l.name} sparsity degenerate"


def test_first_conv_matches_reference():
    spec, weights, x, outs = _run()
    l = spec.layers[0]
    assert isinstance(l, ConvSpec)
    w, m = weights[l.name]
    want = qconv2d_ref(
        jnp.asarray(x, jnp.int32).astype(jnp.int8),
        jnp.asarray(w), jnp.asarray(m),
        stride=l.stride, pad=l.pad, shift=spec.shift, relu=l.relu,
    )
    np.testing.assert_array_equal(np.asarray(outs[1]), np.asarray(want, np.int32))
