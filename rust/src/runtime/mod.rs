//! PJRT runtime: loads the AOT-lowered JAX/Pallas model (HLO **text**, see
//! `python/compile/aot.py` — jax ≥ 0.5 emits serialized protos with 64-bit
//! ids that xla_extension 0.5.1 rejects; the text parser reassigns ids) and
//! executes it from the rust request path. Python never runs at inference
//! time: `make artifacts` produces `artifacts/*.hlo.txt` plus a JSON
//! manifest and raw weight blobs once, and this module does the rest.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Manifest describing an AOT artifact: argument order/shapes and the
/// quantization scales the coordinator needs to interpret the tensors.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// HLO text file, relative to the manifest.
    pub hlo: String,
    /// Model input (first argument) shape, e.g. `[8, 3, 32, 32]`.
    pub input_shape: Vec<usize>,
    /// Weight arguments in call order: name, shape, and the raw `.bin`
    /// file (int8 little-endian) holding the baked values.
    pub weights: Vec<WeightSpec>,
    /// Names of the outputs in tuple order: logits then per-layer
    /// activations.
    pub outputs: Vec<String>,
    /// Per-output activation bit width (8 for the int8 CNN).
    pub bits: u32,
}

/// One weight argument.
#[derive(Debug, Clone)]
pub struct WeightSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
    /// Element storage in the .bin file: "int8" (default) or "int32"
    /// (little-endian). Requant multipliers use int32.
    pub dtype: String,
}

impl WeightSpec {
    /// Element count of this weight tensor.
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    /// Bytes per stored element.
    pub fn elem_bytes(&self) -> usize {
        if self.dtype == "int32" {
            4
        } else {
            1
        }
    }

    /// True for int8 data tensors (the ones APack compresses).
    pub fn is_int8(&self) -> bool {
        self.dtype != "int32"
    }
}

impl ArtifactManifest {
    /// Parse a manifest from JSON text (schema written by aot.py).
    pub fn from_json(data: &str) -> Result<Self> {
        let bad = |m: String| Error::Runtime(format!("manifest: {m}"));
        let j = Json::parse(data).map_err(bad)?;
        let str_field = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| bad(format!("missing string field '{key}'")))
        };
        let shape_of = |v: &Json| -> Result<Vec<usize>> {
            v.as_arr()
                .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                .ok_or_else(|| bad("bad shape array".into()))
        };
        let weights = j
            .get("weights")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| bad("missing 'weights'".into()))?
            .iter()
            .map(|w| {
                Ok(WeightSpec {
                    name: w
                        .get("name")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| bad("weight missing name".into()))?
                        .to_string(),
                    shape: shape_of(
                        w.get("shape").ok_or_else(|| bad("weight missing shape".into()))?,
                    )?,
                    file: w
                        .get("file")
                        .and_then(|v| v.as_str())
                        .ok_or_else(|| bad("weight missing file".into()))?
                        .to_string(),
                    dtype: w
                        .get("dtype")
                        .and_then(|v| v.as_str())
                        .unwrap_or("int8")
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let outputs = j
            .get("outputs")
            .and_then(|v| v.as_arr())
            .map(|a| a.iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
            .unwrap_or_default();
        Ok(Self {
            hlo: str_field("hlo")?,
            input_shape: shape_of(
                j.get("input_shape").ok_or_else(|| bad("missing 'input_shape'".into()))?,
            )?,
            weights,
            outputs,
            bits: j.get("bits").and_then(|v| v.as_usize()).unwrap_or(8) as u32,
        })
    }

    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: &Path) -> Result<(Self, PathBuf)> {
        let path = dir.join("manifest.json");
        let data = std::fs::read_to_string(&path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        Ok((Self::from_json(&data)?, dir.to_path_buf()))
    }
}

/// A compiled model on the PJRT CPU client.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    pub manifest: ArtifactManifest,
    dir: PathBuf,
}

impl CompiledModel {
    /// Load HLO text + manifest from `artifacts_dir` and compile on the
    /// PJRT CPU client.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let (manifest, dir) = ArtifactManifest::load(artifacts_dir)?;
        let client =
            xla::PjRtClient::cpu().map_err(|e| Error::Runtime(format!("pjrt cpu: {e}")))?;
        let hlo_path = dir.join(&manifest.hlo);
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .map_err(|e| Error::Runtime(format!("parse {}: {e}", hlo_path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| Error::Runtime(format!("compile: {e}")))?;
        Ok(Self { exe, manifest, dir })
    }

    /// Read a weight blob (int8 bytes as stored by aot.py), widened to the
    /// i32 element type the PJRT boundary uses (the vendored xla crate has
    /// no i8 literal support; values stay in int8 range).
    pub fn load_weight(&self, spec: &WeightSpec) -> Result<Vec<i32>> {
        let path = self.dir.join(&spec.file);
        let bytes = std::fs::read(&path)
            .map_err(|e| Error::Runtime(format!("read {}: {e}", path.display())))?;
        if bytes.len() != spec.elems() * spec.elem_bytes() {
            return Err(Error::Runtime(format!(
                "{}: {} bytes, expected {}",
                spec.name,
                bytes.len(),
                spec.elems() * spec.elem_bytes()
            )));
        }
        Ok(if spec.is_int8() {
            bytes.iter().map(|&b| b as i8 as i32).collect()
        } else {
            bytes
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        })
    }

    /// Execute the model: `input` in NCHW order, `weights` in manifest
    /// order (i32 elements holding int8-range values). Returns one i32
    /// tensor per manifest output.
    pub fn run(&self, input: &[i32], weights: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        let rt = |e: xla::Error| Error::Runtime(format!("execute: {e}"));
        let mut args: Vec<xla::Literal> = Vec::with_capacity(1 + weights.len());
        let in_dims: Vec<i64> =
            self.manifest.input_shape.iter().map(|&d| d as i64).collect();
        args.push(xla::Literal::vec1(input).reshape(&in_dims).map_err(rt)?);
        for (spec, w) in self.manifest.weights.iter().zip(weights) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            args.push(xla::Literal::vec1(w.as_slice()).reshape(&dims).map_err(rt)?);
        }
        let mut result = self.exe.execute::<xla::Literal>(&args).map_err(rt)?[0][0]
            .to_literal_sync()
            .map_err(rt)?;
        let tuple = result.decompose_tuple().map_err(rt)?;
        let mut out = Vec::with_capacity(tuple.len());
        for lit in tuple {
            out.push(lit.to_vec::<i32>().map_err(rt)?);
        }
        Ok(out)
    }
}

/// Convert an int8-range tensor (stored as i32 at the PJRT boundary) to
/// the unsigned two's-complement byte stream the codec operates on (APack
/// sees raw fixed-point bytes, §IV).
pub fn i8_to_u32_stream(values: &[i32]) -> Vec<u32> {
    values.iter().map(|&v| v as u8 as u32).collect()
}

/// Inverse of [`i8_to_u32_stream`].
pub fn u32_stream_to_i8(values: &[u32]) -> Vec<i32> {
    values.iter().map(|&v| v as u8 as i8 as i32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn i8_stream_roundtrip() {
        let v: Vec<i32> = (-128i32..=127).collect();
        let s = i8_to_u32_stream(&v);
        assert!(s.iter().all(|&x| x < 256));
        assert_eq!(u32_stream_to_i8(&s), v);
        // Two's complement: -1 → 0xFF.
        assert_eq!(i8_to_u32_stream(&[-1])[0], 0xFF);
    }

    #[test]
    fn manifest_parse_from_json() {
        let doc = r#"{
            "hlo": "model.hlo.txt",
            "input_shape": [8, 3, 32, 32],
            "bits": 8,
            "weights": [
                {"name": "conv1", "shape": [16, 3, 3, 3], "file": "conv1.bin"}
            ],
            "outputs": ["logits", "act0"]
        }"#;
        let m = ArtifactManifest::from_json(doc).unwrap();
        assert_eq!(m.weights[0].elems(), 16 * 3 * 3 * 3);
        assert_eq!(m.input_shape, vec![8, 3, 32, 32]);
        assert_eq!(m.outputs.len(), 2);
        assert_eq!(m.bits, 8);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(ArtifactManifest::from_json("{}").is_err());
        assert!(ArtifactManifest::from_json(r#"{"hlo": "x", "weights": []}"#).is_err());
    }
}
