//! ShapeShifter baseline (Delmas Lascorz et al., MICRO'19), as configured
//! in the paper's evaluation (§VII): values are processed in groups of
//! `G = 8`; each group stores a `log2(P_max)`-bit precision field `P` (the
//! minimal container width for the group), a G-bit zero bit-vector, and
//! the non-zero values at `P` bits each.
//!
//! Per the APack paper's §II description, ShapeShifter "does not store
//! prefixes of 0s (group near zero) or 1s (group near 255)" — i.e. it
//! drops the *sign-extension* prefix of two's-complement values. A value's
//! needed width is thus the shortest suffix that sign-extends back to the
//! original byte (`0xFE` → 2 bits, `0x01` → 2 bits, `0x7F` → 8 bits), and
//! the group container `P` is the max over its non-zero lanes.
//!
//! Footprint per group = `log2(P_max) + G + nnz × P` bits. We implement
//! the full reversible codec and use its exact footprint in the traffic
//! study (Fig 5).

/// ShapeShifter configuration.
#[derive(Debug, Clone, Copy)]
pub struct ShapeShifterConfig {
    /// Group size (paper uses 8, verified best for their models).
    pub group: usize,
    /// Maximum precision per value (8 for the 8-bit-optimized variant).
    pub p_max: u32,
    /// Whether the zero bit-vector is used to elide zero values.
    pub zero_vector: bool,
    /// Treat values as two's complement and drop sign-extension prefixes
    /// (prefixes of 0s *and* 1s, the published design); `false` keeps the
    /// magnitude-only variant for ablation.
    pub twos_complement: bool,
}

impl ShapeShifterConfig {
    /// The 8-bit-optimized variant evaluated in the paper.
    pub fn paper_8b() -> Self {
        Self { group: 8, p_max: 8, zero_vector: true, twos_complement: true }
    }

    /// Generic variant for a bit width.
    pub fn for_bits(bits: u32) -> Self {
        Self { group: 8, p_max: bits.max(1), zero_vector: true, twos_complement: true }
    }

    /// Variant without zero elision (stores all G values at P bits).
    pub fn no_zero_vector(bits: u32) -> Self {
        Self { group: 8, p_max: bits, zero_vector: false, twos_complement: true }
    }

    /// Magnitude-only ablation variant (no 1s-prefix removal).
    pub fn magnitude_only(bits: u32) -> Self {
        Self { group: 8, p_max: bits, zero_vector: true, twos_complement: false }
    }

    /// Bits for the per-group precision field.
    pub fn prec_field_bits(&self) -> u32 {
        32 - (self.p_max - 1).leading_zeros() // log2 rounded up, e.g. 3 for P_max=8
    }
}

/// One encoded group.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SsGroup {
    /// Minimal precision for the group's non-zero values (1..=P_max). 0 is
    /// used for an all-zero group when the zero vector is enabled.
    pub precision: u32,
    /// Zero bit-vector (one bit per lane, true = zero); empty when
    /// disabled.
    pub zeros: Vec<bool>,
    /// The stored values (non-zero lanes only when zero_vector, else all).
    pub values: Vec<u32>,
    /// Number of lanes in this (possibly final, short) group.
    pub lanes: usize,
}

/// Needed container width for one value.
fn needed_bits(v: u32, cfg: &ShapeShifterConfig) -> u32 {
    if cfg.twos_complement {
        // Shortest suffix that sign-extends back to the original p_max-bit
        // value: strip leading 0s (positive) or leading 1s (negative),
        // keeping one sign bit.
        let w = cfg.p_max;
        let sign = (v >> (w - 1)) & 1;
        let mut need = w;
        while need > 1 {
            let top = (v >> (need - 2)) & 1; // would-be sign bit one shorter
            if top != sign {
                break;
            }
            need -= 1;
        }
        need
    } else {
        (32 - v.leading_zeros()).max(1)
    }
}

fn min_precision(values: &[u32], cfg: &ShapeShifterConfig) -> u32 {
    values.iter().map(|&v| needed_bits(v, cfg)).max().unwrap_or(0).max(1)
}

/// Sign-extend the low `p` bits of `stored` to `p_max` bits.
fn sign_extend(stored: u32, p: u32, cfg: &ShapeShifterConfig) -> u32 {
    if !cfg.twos_complement || p >= cfg.p_max {
        return stored;
    }
    let sign = (stored >> (p - 1)) & 1;
    if sign == 1 {
        let mask = ((1u32 << cfg.p_max) - 1) & !((1u32 << p) - 1);
        stored | mask
    } else {
        stored
    }
}

/// Encode a tensor into ShapeShifter groups.
pub fn ss_encode(values: &[u32], cfg: &ShapeShifterConfig) -> Vec<SsGroup> {
    values
        .chunks(cfg.group)
        .map(|chunk| {
            if cfg.zero_vector {
                let zeros: Vec<bool> = chunk.iter().map(|&v| v == 0).collect();
                let nz: Vec<u32> = chunk.iter().copied().filter(|&v| v != 0).collect();
                let precision = if nz.is_empty() { 0 } else { min_precision(&nz, cfg) };
                // Store only the P-bit suffix of each value.
                let mask = if precision >= 32 { u32::MAX } else { (1u32 << precision) - 1 };
                let stored: Vec<u32> = nz.iter().map(|&v| v & mask).collect();
                SsGroup { precision, zeros, values: stored, lanes: chunk.len() }
            } else {
                let precision = min_precision(chunk, cfg);
                let mask = if precision >= 32 { u32::MAX } else { (1u32 << precision) - 1 };
                SsGroup {
                    precision,
                    zeros: Vec::new(),
                    values: chunk.iter().map(|&v| v & mask).collect(),
                    lanes: chunk.len(),
                }
            }
        })
        .collect()
}

/// Invert [`ss_encode`].
pub fn ss_decode(groups: &[SsGroup], cfg: &ShapeShifterConfig) -> Vec<u32> {
    let mut out = Vec::new();
    for g in groups {
        if cfg.zero_vector {
            let mut it = g.values.iter();
            for &z in &g.zeros {
                out.push(if z {
                    0
                } else {
                    sign_extend(*it.next().expect("zero-vector mismatch"), g.precision, cfg)
                });
            }
        } else {
            out.extend(g.values.iter().map(|&v| sign_extend(v, g.precision, cfg)));
        }
    }
    out
}

/// Exact compressed footprint in bits.
pub fn ss_compressed_bits(values: &[u32], cfg: &ShapeShifterConfig) -> u64 {
    ss_encode(values, cfg)
        .iter()
        .map(|g| {
            let mut bits = cfg.prec_field_bits() as u64;
            if cfg.zero_vector {
                bits += g.lanes as u64; // the zero bit-vector
            }
            bits += g.values.len() as u64 * g.precision as u64;
            bits
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ShapeShifterConfig {
        ShapeShifterConfig::paper_8b()
    }

    #[test]
    fn roundtrip_mixed() {
        let v: Vec<u32> = vec![0, 1, 255, 0, 0, 12, 7, 0, 130, 0, 0, 0, 0, 0, 0, 0, 3];
        let g = ss_encode(&v, &cfg());
        assert_eq!(ss_decode(&g, &cfg()), v);
    }

    #[test]
    fn roundtrip_no_zero_vector() {
        let c = ShapeShifterConfig::no_zero_vector(8);
        let v: Vec<u32> = (0..100).map(|i| (i * 31) % 256).collect();
        let g = ss_encode(&v, &c);
        assert_eq!(ss_decode(&g, &c), v);
    }

    #[test]
    fn group_precision_is_minimal() {
        // Two's complement: 3 = 0b011 needs 3 bits (leading sign 0 kept).
        let v = vec![0, 0, 3, 1, 0, 0, 0, 2];
        let g = ss_encode(&v, &cfg());
        assert_eq!(g[0].precision, 3);
        // footprint: 3 (prec) + 8 (zero vec) + 3 values × 3 bits = 20
        assert_eq!(ss_compressed_bits(&v, &cfg()), 20);
        // Magnitude-only variant packs the same group at 2 bits.
        let mo = ShapeShifterConfig::magnitude_only(8);
        assert_eq!(ss_encode(&v, &mo)[0].precision, 2);
        assert_eq!(ss_decode(&ss_encode(&v, &mo), &mo), v);
    }

    #[test]
    fn ones_prefixes_compress_like_zero_prefixes() {
        // Near-255 values (small negatives) need few bits: 0xFE = -2 → 2.
        let v = vec![0xFEu32, 0xFF, 0xFD, 0xFE, 0xFF, 0xFE, 0xFF, 0xFD];
        let g = ss_encode(&v, &cfg());
        assert_eq!(g[0].precision, 3); // 0xFD = -3 → '101' (3 bits)
        assert_eq!(ss_decode(&g, &cfg()), v);
    }

    #[test]
    fn all_zero_group_costs_header_only() {
        let v = vec![0u32; 8];
        assert_eq!(ss_compressed_bits(&v, &cfg()), 3 + 8);
        assert_eq!(ss_decode(&ss_encode(&v, &cfg()), &cfg()), v);
    }

    #[test]
    fn one_large_value_penalizes_whole_group() {
        // The paper's key observation: one max-magnitude value forces all
        // other lanes to the full container — encoding efficiency lost.
        // 0x7F (+127) needs all 8 bits; the 1s ride along at 8 bits each.
        let v = vec![0x7Fu32, 1, 1, 1, 1, 1, 1, 1];
        let bits = ss_compressed_bits(&v, &cfg());
        assert_eq!(bits, 3 + 8 + 8 * 8);
        assert!(bits > 8 * 8); // worse than raw
        assert_eq!(ss_decode(&ss_encode(&v, &cfg()), &cfg()), v);
    }

    #[test]
    fn short_final_group() {
        let v = vec![1u32, 2, 3]; // fewer than G lanes
        let g = ss_encode(&v, &cfg());
        assert_eq!(g[0].lanes, 3);
        assert_eq!(ss_decode(&g, &cfg()), v);
    }

    #[test]
    fn compresses_low_magnitude_data() {
        let v: Vec<u32> = (0..800).map(|i| (i % 4) as u32).collect();
        let bits = ss_compressed_bits(&v, &cfg());
        assert!(bits < 8 * v.len() as u64 / 2, "{bits}");
    }
}
