//! Run-length baselines (paper §VII, "Compression Methods"):
//!
//! - **RLE** encodes values as `(value, distance)` tuples where `distance`
//!   is the number of *additional* identical values following, capped at 15
//!   (4 bits of overhead per tuple).
//! - **RLEZ** encodes `(value, distance)` where `distance` counts the zeros
//!   following the value, again capped at 15 — the classic zero-run scheme
//!   of Eyeriss/EIE/Cambricon that the paper compares against.
//!
//! Both are exact, reversible codecs; the `*_compressed_bits` helpers give
//! the footprint the traffic study (Fig 5) uses.

/// Maximum run distance per tuple (4-bit field).
pub const MAX_DISTANCE: u32 = 15;

/// RLE-encode: tuples of `(value, extra_repeats ≤ 15)`.
pub fn rle_encode(values: &[u32]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < values.len() {
        let v = values[i];
        let mut run = 0u32;
        while run < MAX_DISTANCE && i + 1 + (run as usize) < values.len()
            && values[i + 1 + (run as usize)] == v
        {
            run += 1;
        }
        out.push((v, run));
        i += 1 + run as usize;
    }
    out
}

/// Invert [`rle_encode`].
pub fn rle_decode(tuples: &[(u32, u32)]) -> Vec<u32> {
    let mut out = Vec::new();
    for &(v, run) in tuples {
        for _ in 0..=run {
            out.push(v);
        }
    }
    out
}

/// Compressed footprint in bits for RLE on `bits`-wide values: each tuple
/// costs `bits + 4`.
pub fn rle_compressed_bits(values: &[u32], bits: u32) -> u64 {
    rle_encode(values).len() as u64 * (bits as u64 + 4)
}

/// RLEZ-encode: tuples of `(value, zeros_following ≤ 15)`. A run of zeros
/// longer than 15 continues with a `(0, k)` tuple, mirroring the EIE-style
/// format.
pub fn rlez_encode(values: &[u32]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < values.len() {
        let v = values[i];
        let mut zeros = 0u32;
        while zeros < MAX_DISTANCE && i + 1 + (zeros as usize) < values.len()
            && values[i + 1 + (zeros as usize)] == 0
        {
            zeros += 1;
        }
        out.push((v, zeros));
        i += 1 + zeros as usize;
    }
    out
}

/// Invert [`rlez_encode`].
pub fn rlez_decode(tuples: &[(u32, u32)]) -> Vec<u32> {
    let mut out = Vec::new();
    for &(v, zeros) in tuples {
        out.push(v);
        for _ in 0..zeros {
            out.push(0);
        }
    }
    out
}

/// Compressed footprint in bits for RLEZ.
pub fn rlez_compressed_bits(values: &[u32], bits: u32) -> u64 {
    rlez_encode(values).len() as u64 * (bits as u64 + 4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rle_roundtrip_mixed() {
        let v = vec![5, 5, 5, 0, 0, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 7, 1];
        assert_eq!(rle_decode(&rle_encode(&v)), v);
    }

    #[test]
    fn rlez_roundtrip_long_zero_runs() {
        let mut v = vec![9u32];
        v.extend(std::iter::repeat(0).take(100));
        v.push(3);
        v.extend(std::iter::repeat(0).take(31));
        assert_eq!(rlez_decode(&rlez_encode(&v)), v);
    }

    #[test]
    fn rle_run_cap_respected() {
        let v = vec![1u32; 40];
        let t = rle_encode(&v);
        assert!(t.iter().all(|&(_, d)| d <= MAX_DISTANCE));
        // 40 values = 16+16+8 → 3 tuples
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn rle_expands_incompressible_data() {
        // No repetition: every value becomes a tuple → bits*len + 4*len,
        // i.e. traffic *increases*, as the paper observes for weights.
        let v: Vec<u32> = (0..1000).map(|i| (i * 17) % 256).collect();
        let bits = rle_compressed_bits(&v, 8);
        assert!(bits > 8 * v.len() as u64);
    }

    #[test]
    fn rlez_wins_on_sparse_data() {
        let mut v = Vec::new();
        for i in 0..1000u32 {
            v.push(if i % 10 == 0 { i % 256 } else { 0 });
        }
        let bits = rlez_compressed_bits(&v, 8);
        assert!(bits < 8 * v.len() as u64 / 2);
        assert_eq!(rlez_decode(&rlez_encode(&v)), v);
    }

    #[test]
    fn empty_input() {
        assert!(rle_encode(&[]).is_empty());
        assert!(rlez_encode(&[]).is_empty());
        assert_eq!(rle_compressed_bits(&[], 8), 0);
    }
}
