//! Comparison codecs from paper §VII: run-length encoding (RLE), zero
//! run-length encoding (RLEZ) and ShapeShifter.

pub mod rle;
pub mod shapeshifter;

pub use rle::{rle_compressed_bits, rle_decode, rle_encode, rlez_compressed_bits, rlez_decode, rlez_encode};
pub use shapeshifter::{ss_compressed_bits, ss_decode, ss_encode, ShapeShifterConfig};
