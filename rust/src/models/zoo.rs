//! The model zoo: the 24 networks of paper Table II, described layer by
//! layer so the traffic (Fig 5), energy (Fig 6) and performance (Figs 7–8)
//! studies see realistic per-layer byte volumes and MAC counts.
//!
//! Layer dimensions follow the published architectures (standard ImageNet /
//! COCO / NLP configurations); see DESIGN.md §3 — the *values* inside the
//! tensors are synthesized per quantizer family, the *shapes* are real.


use super::distributions::ValueProfile;

/// Quantizer family (Table II "Quantizer" column), which selects the value
/// distribution family for weights and activations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantFamily {
    /// Torchvision pre-quantized int8 — noisy full-range weights.
    Torchvision,
    /// IntelAI int8 — skewed weights; activations remain float in the
    /// released models, so the paper (and we) study weights only.
    IntelAi,
    /// IntelLabs Distiller int8 (Q8BERT, NCF).
    Distiller,
    /// MLPerf int8.
    MlPerf,
    /// Per-layer profiled quantization (bilstm, SegNet, ResNet18-Q).
    PerLayer,
    /// PACT int4 (first/last layers int8).
    Pact4,
    /// Energy-aware pruned + per-layer int8 (AlexNet/GoogLeNet Eyeriss).
    Pruned,
}

/// One layer's shape; enough to derive MACs and tensor element counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerShape {
    /// Standard convolution over `h×w` input with `cin→cout` channels,
    /// `k×k` kernel, stride `s` (same padding).
    Conv { cin: u32, cout: u32, k: u32, s: u32, h: u32, w: u32 },
    /// Depthwise convolution (`c` channels, `k×k`, stride `s`).
    DwConv { c: u32, k: u32, s: u32, h: u32, w: u32 },
    /// Fully connected / linear `cin→cout`, batched over `n` positions
    /// (tokens, detection anchors, …).
    Fc { cin: u32, cout: u32, n: u32 },
    /// Recurrent cell step: `input+hidden → gates`, run for `t` steps
    /// (both directions folded into `t` for bidirectional nets).
    Rnn { input: u32, hidden: u32, gates: u32, t: u32 },
    /// Embedding lookup: `n` lookups of `dim`-wide rows from a
    /// `vocab×dim` table. MAC-free but weight-traffic-heavy.
    Embedding { vocab: u32, dim: u32, n: u32 },
}

impl LayerShape {
    /// Multiply-accumulate operations for this layer.
    pub fn macs(&self) -> u64 {
        match *self {
            LayerShape::Conv { cin, cout, k, s, h, w } => {
                let (ho, wo) = (h.div_ceil(s) as u64, w.div_ceil(s) as u64);
                ho * wo * cout as u64 * cin as u64 * (k as u64) * (k as u64)
            }
            LayerShape::DwConv { c, k, s, h, w } => {
                let (ho, wo) = (h.div_ceil(s) as u64, w.div_ceil(s) as u64);
                ho * wo * c as u64 * (k as u64) * (k as u64)
            }
            LayerShape::Fc { cin, cout, n } => cin as u64 * cout as u64 * n as u64,
            LayerShape::Rnn { input, hidden, gates, t } => {
                (input as u64 + hidden as u64) * hidden as u64 * gates as u64 * t as u64
            }
            LayerShape::Embedding { .. } => 0,
        }
    }

    /// Weight (parameter) element count.
    pub fn weight_elems(&self) -> u64 {
        match *self {
            LayerShape::Conv { cin, cout, k, .. } => {
                cin as u64 * cout as u64 * (k as u64) * (k as u64)
            }
            LayerShape::DwConv { c, k, .. } => c as u64 * (k as u64) * (k as u64),
            LayerShape::Fc { cin, cout, .. } => cin as u64 * cout as u64,
            LayerShape::Rnn { input, hidden, gates, .. } => {
                (input as u64 + hidden as u64) * hidden as u64 * gates as u64
            }
            LayerShape::Embedding { vocab, dim, .. } => vocab as u64 * dim as u64,
        }
    }

    /// Input activation element count.
    pub fn input_elems(&self) -> u64 {
        match *self {
            LayerShape::Conv { cin, h, w, .. } => cin as u64 * h as u64 * w as u64,
            LayerShape::DwConv { c, h, w, .. } => c as u64 * h as u64 * w as u64,
            LayerShape::Fc { cin, n, .. } => cin as u64 * n as u64,
            LayerShape::Rnn { input, t, .. } => input as u64 * t as u64,
            LayerShape::Embedding { n, .. } => n as u64,
        }
    }

    /// Output activation element count.
    pub fn output_elems(&self) -> u64 {
        match *self {
            LayerShape::Conv { cout, k: _, s, h, w, .. } => {
                cout as u64 * h.div_ceil(s) as u64 * w.div_ceil(s) as u64
            }
            LayerShape::DwConv { c, s, h, w, .. } => {
                c as u64 * h.div_ceil(s) as u64 * w.div_ceil(s) as u64
            }
            LayerShape::Fc { cout, n, .. } => cout as u64 * n as u64,
            LayerShape::Rnn { hidden, t, .. } => hidden as u64 * t as u64,
            LayerShape::Embedding { dim, n, .. } => dim as u64 * n as u64,
        }
    }
}

/// A network from Table II.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: &'static str,
    pub family: QuantFamily,
    /// Default weight/activation bit width.
    pub bits: u32,
    /// Per-layer bit-width overrides (empty = uniform `bits`). Used by
    /// ResNet18-PACT, which the paper quantizes "to 4b except for the
    /// first and last layers which remain in 8b" (§VII).
    pub layer_bits: Vec<u32>,
    pub layers: Vec<LayerShape>,
    /// Weight value distribution.
    pub weight_profile: ValueProfile,
    /// Activation value distribution (`None` = activations not studied —
    /// IntelAI models keep float activations, §VII).
    pub act_profile: Option<ValueProfile>,
    /// Whether this model's trace is "compatible with the ShapeShifter
    /// simulator" and hence appears in Figs 7/8 (the paper limits the
    /// performance study to that subset).
    pub in_perf_study: bool,
}

impl ModelConfig {
    /// Bit width of layer `i`.
    pub fn bits_for(&self, i: usize) -> u32 {
        self.layer_bits.get(i).copied().unwrap_or(self.bits)
    }

    /// Total MACs over all layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Total weight elements.
    pub fn total_weights(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_elems()).sum()
    }
}

// ---------------------------------------------------------------------------
// Block builders (keep the 24 configs faithful but compact).
// ---------------------------------------------------------------------------

fn conv(cin: u32, cout: u32, k: u32, s: u32, h: u32, w: u32) -> LayerShape {
    LayerShape::Conv { cin, cout, k, s, h, w }
}
fn dw(c: u32, k: u32, s: u32, h: u32, w: u32) -> LayerShape {
    LayerShape::DwConv { c, k, s, h, w }
}
fn fc(cin: u32, cout: u32) -> LayerShape {
    LayerShape::Fc { cin, cout, n: 1 }
}

/// Basic ResNet block (two 3×3 convs) at a spatial size.
fn resnet_basic(c: u32, h: u32) -> Vec<LayerShape> {
    vec![conv(c, c, 3, 1, h, h), conv(c, c, 3, 1, h, h)]
}

/// ResNet bottleneck (1×1 reduce, 3×3, 1×1 expand).
fn resnet_bottleneck(cin: u32, mid: u32, h: u32) -> Vec<LayerShape> {
    vec![conv(cin, mid, 1, 1, h, h), conv(mid, mid, 3, 1, h, h), conv(mid, mid * 4, 1, 1, h, h)]
}

fn resnet18_layers() -> Vec<LayerShape> {
    let mut l = vec![conv(3, 64, 7, 2, 224, 224)];
    for _ in 0..2 {
        l.extend(resnet_basic(64, 56));
    }
    l.push(conv(64, 128, 3, 2, 56, 56));
    l.push(conv(128, 128, 3, 1, 28, 28));
    l.extend(resnet_basic(128, 28));
    l.push(conv(128, 256, 3, 2, 28, 28));
    l.push(conv(256, 256, 3, 1, 14, 14));
    l.extend(resnet_basic(256, 14));
    l.push(conv(256, 512, 3, 2, 14, 14));
    l.push(conv(512, 512, 3, 1, 7, 7));
    l.extend(resnet_basic(512, 7));
    l.push(fc(512, 1000));
    l
}

fn resnet50_layers() -> Vec<LayerShape> {
    let mut l = vec![conv(3, 64, 7, 2, 224, 224), conv(64, 64, 1, 1, 56, 56)];
    for _ in 0..3 {
        l.extend(resnet_bottleneck(256, 64, 56));
    }
    for _ in 0..4 {
        l.extend(resnet_bottleneck(512, 128, 28));
    }
    for _ in 0..6 {
        l.extend(resnet_bottleneck(1024, 256, 14));
    }
    for _ in 0..3 {
        l.extend(resnet_bottleneck(2048, 512, 7));
    }
    l.push(fc(2048, 1000));
    l
}

fn resnet101_layers() -> Vec<LayerShape> {
    let mut l = vec![conv(3, 64, 7, 2, 224, 224)];
    for _ in 0..3 {
        l.extend(resnet_bottleneck(256, 64, 56));
    }
    for _ in 0..4 {
        l.extend(resnet_bottleneck(512, 128, 28));
    }
    for _ in 0..23 {
        l.extend(resnet_bottleneck(1024, 256, 14));
    }
    for _ in 0..3 {
        l.extend(resnet_bottleneck(2048, 512, 7));
    }
    l.push(fc(2048, 1000));
    l
}

fn resnext101_layers() -> Vec<LayerShape> {
    // 32×8d: grouped 3×3 modelled as a conv with cin/32 effective depth.
    let mut l = vec![conv(3, 64, 7, 2, 224, 224)];
    let stage = |cin: u32, mid: u32, h: u32| {
        vec![
            conv(cin, mid, 1, 1, h, h),
            conv(mid / 32, mid, 3, 1, h, h), // grouped conv: per-group cin
            conv(mid, cin.max(mid * 2), 1, 1, h, h),
        ]
    };
    for _ in 0..3 {
        l.extend(stage(256, 256, 56));
    }
    for _ in 0..4 {
        l.extend(stage(512, 512, 28));
    }
    for _ in 0..23 {
        l.extend(stage(1024, 1024, 14));
    }
    for _ in 0..3 {
        l.extend(stage(2048, 2048, 7));
    }
    l.push(fc(2048, 1000));
    l
}

/// GoogLeNet inception module at (h, cin) with the canonical branch widths.
fn inception(cin: u32, b1: u32, b3r: u32, b3: u32, b5r: u32, b5: u32, pp: u32, h: u32) -> Vec<LayerShape> {
    vec![
        conv(cin, b1, 1, 1, h, h),
        conv(cin, b3r, 1, 1, h, h),
        conv(b3r, b3, 3, 1, h, h),
        conv(cin, b5r, 1, 1, h, h),
        conv(b5r, b5, 5, 1, h, h),
        conv(cin, pp, 1, 1, h, h),
    ]
}

fn googlenet_layers() -> Vec<LayerShape> {
    let mut l = vec![conv(3, 64, 7, 2, 224, 224), conv(64, 64, 1, 1, 56, 56), conv(64, 192, 3, 1, 56, 56)];
    l.extend(inception(192, 64, 96, 128, 16, 32, 32, 28));
    l.extend(inception(256, 128, 128, 192, 32, 96, 64, 28));
    l.extend(inception(480, 192, 96, 208, 16, 48, 64, 14));
    l.extend(inception(512, 160, 112, 224, 24, 64, 64, 14));
    l.extend(inception(512, 128, 128, 256, 24, 64, 64, 14));
    l.extend(inception(512, 112, 144, 288, 32, 64, 64, 14));
    l.extend(inception(528, 256, 160, 320, 32, 128, 128, 14));
    l.extend(inception(832, 256, 160, 320, 32, 128, 128, 7));
    l.extend(inception(832, 384, 192, 384, 48, 128, 128, 7));
    l.push(fc(1024, 1000));
    l
}

fn inception_v3_layers() -> Vec<LayerShape> {
    let mut l = vec![
        conv(3, 32, 3, 2, 299, 299),
        conv(32, 32, 3, 1, 149, 149),
        conv(32, 64, 3, 1, 147, 147),
        conv(64, 80, 1, 1, 73, 73),
        conv(80, 192, 3, 1, 73, 73),
    ];
    // Three coarse inception stages at 35/17/8 (representative widths).
    for _ in 0..3 {
        l.extend(inception(288, 64, 48, 64, 64, 96, 64, 35));
    }
    for _ in 0..4 {
        l.extend(inception(768, 192, 128, 192, 128, 192, 192, 17));
    }
    for _ in 0..2 {
        l.extend(inception(1280, 320, 384, 384, 448, 384, 192, 8));
    }
    l.push(fc(2048, 1000));
    l
}

fn inception_v4_layers() -> Vec<LayerShape> {
    let mut l = inception_v3_layers();
    l.pop();
    // v4 adds more 17×17 blocks.
    for _ in 0..3 {
        l.extend(inception(1024, 192, 128, 192, 128, 192, 128, 17));
    }
    l.push(fc(1536, 1000));
    l
}

/// MobileNet v1 separable block.
fn mbv1_block(c: u32, cout: u32, s: u32, h: u32) -> Vec<LayerShape> {
    vec![dw(c, 3, s, h, h), conv(c, cout, 1, 1, h / s, h / s)]
}

fn mobilenet_v1_layers() -> Vec<LayerShape> {
    let mut l = vec![conv(3, 32, 3, 2, 224, 224)];
    l.extend(mbv1_block(32, 64, 1, 112));
    l.extend(mbv1_block(64, 128, 2, 112));
    l.extend(mbv1_block(128, 128, 1, 56));
    l.extend(mbv1_block(128, 256, 2, 56));
    l.extend(mbv1_block(256, 256, 1, 28));
    l.extend(mbv1_block(256, 512, 2, 28));
    for _ in 0..5 {
        l.extend(mbv1_block(512, 512, 1, 14));
    }
    l.extend(mbv1_block(512, 1024, 2, 14));
    l.extend(mbv1_block(1024, 1024, 1, 7));
    l.push(fc(1024, 1000));
    l
}

/// MobileNet v2 inverted residual: expand 1×1, dw 3×3, project 1×1.
fn mbv2_block(cin: u32, exp: u32, cout: u32, s: u32, h: u32) -> Vec<LayerShape> {
    vec![conv(cin, exp, 1, 1, h, h), dw(exp, 3, s, h, h), conv(exp, cout, 1, 1, h / s, h / s)]
}

fn mobilenet_v2_layers() -> Vec<LayerShape> {
    let mut l = vec![conv(3, 32, 3, 2, 224, 224), dw(32, 3, 1, 112, 112), conv(32, 16, 1, 1, 112, 112)];
    l.extend(mbv2_block(16, 96, 24, 2, 112));
    l.extend(mbv2_block(24, 144, 24, 1, 56));
    l.extend(mbv2_block(24, 144, 32, 2, 56));
    for _ in 0..2 {
        l.extend(mbv2_block(32, 192, 32, 1, 28));
    }
    l.extend(mbv2_block(32, 192, 64, 2, 28));
    for _ in 0..3 {
        l.extend(mbv2_block(64, 384, 64, 1, 14));
    }
    for _ in 0..3 {
        l.extend(mbv2_block(64, 384, 96, 1, 14));
    }
    l.extend(mbv2_block(96, 576, 160, 2, 14));
    for _ in 0..2 {
        l.extend(mbv2_block(160, 960, 160, 1, 7));
    }
    l.extend(mbv2_block(160, 960, 320, 1, 7));
    l.push(conv(320, 1280, 1, 1, 7, 7));
    l.push(fc(1280, 1000));
    l
}

fn mobilenet_v3_layers() -> Vec<LayerShape> {
    // Large variant, SE layers folded into the 1×1s they gate.
    let mut l = vec![conv(3, 16, 3, 2, 224, 224), dw(16, 3, 1, 112, 112), conv(16, 16, 1, 1, 112, 112)];
    l.extend(mbv2_block(16, 64, 24, 2, 112));
    l.extend(mbv2_block(24, 72, 24, 1, 56));
    l.extend(mbv2_block(24, 72, 40, 2, 56));
    for _ in 0..2 {
        l.extend(mbv2_block(40, 120, 40, 1, 28));
    }
    l.extend(mbv2_block(40, 240, 80, 2, 28));
    for _ in 0..3 {
        l.extend(mbv2_block(80, 200, 80, 1, 14));
    }
    l.extend(mbv2_block(80, 480, 112, 1, 14));
    l.extend(mbv2_block(112, 672, 160, 2, 14));
    for _ in 0..2 {
        l.extend(mbv2_block(160, 960, 160, 1, 7));
    }
    l.push(conv(160, 960, 1, 1, 7, 7));
    l.push(fc(960, 1280));
    l.push(fc(1280, 1000));
    l
}

fn shufflenet_v2_layers() -> Vec<LayerShape> {
    // 1× variant; shuffle units as 1×1 + dw3×3 + 1×1 on half the channels.
    let unit = |c: u32, h: u32| vec![conv(c / 2, c / 2, 1, 1, h, h), dw(c / 2, 3, 1, h, h), conv(c / 2, c / 2, 1, 1, h, h)];
    let mut l = vec![conv(3, 24, 3, 2, 224, 224)];
    for _ in 0..4 {
        l.extend(unit(116, 28));
    }
    for _ in 0..8 {
        l.extend(unit(232, 14));
    }
    for _ in 0..4 {
        l.extend(unit(464, 7));
    }
    l.push(conv(464, 1024, 1, 1, 7, 7));
    l.push(fc(1024, 1000));
    l
}

fn alexnet_layers() -> Vec<LayerShape> {
    // conv2/4/5 are 2-way grouped in the original AlexNet: modelled with
    // the per-group input depth (halves both MACs and weights, as real).
    vec![
        conv(3, 96, 11, 4, 227, 227),
        conv(48, 256, 5, 1, 27, 27),
        conv(256, 384, 3, 1, 13, 13),
        conv(192, 384, 3, 1, 13, 13),
        conv(192, 256, 3, 1, 13, 13),
        LayerShape::Fc { cin: 9216, cout: 4096, n: 1 },
        fc(4096, 4096),
        fc(4096, 1000),
    ]
}

/// A transformer encoder layer (hidden H, FFN 4H, S tokens): QKV + output
/// projections + 2 FFN matmuls.
fn transformer_layer(hidden: u32, seq: u32) -> Vec<LayerShape> {
    vec![
        LayerShape::Fc { cin: hidden, cout: hidden * 3, n: seq },
        LayerShape::Fc { cin: hidden, cout: hidden, n: seq },
        LayerShape::Fc { cin: hidden, cout: hidden * 4, n: seq },
        LayerShape::Fc { cin: hidden * 4, cout: hidden, n: seq },
    ]
}

fn q8bert_layers() -> Vec<LayerShape> {
    let mut l = vec![LayerShape::Embedding { vocab: 30522, dim: 768, n: 128 }];
    for _ in 0..12 {
        l.extend(transformer_layer(768, 128));
    }
    l.push(LayerShape::Fc { cin: 768, cout: 2, n: 1 });
    l
}

fn ncf_layers() -> Vec<LayerShape> {
    vec![
        LayerShape::Embedding { vocab: 138493, dim: 64, n: 1024 },
        LayerShape::Embedding { vocab: 26744, dim: 64, n: 1024 },
        LayerShape::Fc { cin: 128, cout: 256, n: 1024 },
        LayerShape::Fc { cin: 256, cout: 128, n: 1024 },
        LayerShape::Fc { cin: 128, cout: 64, n: 1024 },
        LayerShape::Fc { cin: 128, cout: 1, n: 1024 },
    ]
}

fn wide_deep_layers() -> Vec<LayerShape> {
    vec![
        LayerShape::Embedding { vocab: 100000, dim: 64, n: 512 },
        LayerShape::Fc { cin: 1024, cout: 1024, n: 512 },
        LayerShape::Fc { cin: 1024, cout: 512, n: 512 },
        LayerShape::Fc { cin: 512, cout: 256, n: 512 },
        LayerShape::Fc { cin: 256, cout: 1, n: 512 },
    ]
}

fn bilstm_layers() -> Vec<LayerShape> {
    // Image-captioning BiLSTM: CNN features -> 2-layer bidirectional LSTM.
    vec![
        LayerShape::Fc { cin: 2048, cout: 512, n: 1 },
        LayerShape::Rnn { input: 512, hidden: 512, gates: 4, t: 40 }, // fw+bw folded
        LayerShape::Rnn { input: 1024, hidden: 512, gates: 4, t: 40 },
        LayerShape::Fc { cin: 1024, cout: 9568, n: 20 }, // vocab projection
    ]
}

fn segnet_layers() -> Vec<LayerShape> {
    // VGG-ish encoder + mirrored decoder on 360×480 CamVid frames.
    let mut l = Vec::new();
    let dims = [(3u32, 64u32, 360u32), (64, 64, 360), (64, 128, 180), (128, 128, 180), (128, 256, 90), (256, 256, 90), (256, 512, 45), (512, 512, 45)];
    for &(cin, cout, h) in &dims {
        l.push(conv(cin, cout, 3, 1, h, h * 4 / 3));
    }
    // Decoder mirror.
    for &(cin, cout, h) in dims.iter().rev() {
        l.push(conv(cout, cin.max(12), 3, 1, h, h * 4 / 3));
    }
    l
}

fn ssd_mobilenet_layers() -> Vec<LayerShape> {
    let mut l = mobilenet_v1_layers();
    l.pop(); // drop classifier
    // SSD heads over 6 feature maps.
    for &(c, h, anchors) in &[(512u32, 19u32, 3u32), (1024, 10, 6), (512, 5, 6), (256, 3, 6), (256, 2, 6), (128, 1, 6)] {
        l.push(conv(c, anchors * 4, 3, 1, h, h));
        l.push(conv(c, anchors * 91, 3, 1, h, h));
    }
    l
}

fn ssd_resnet34_layers() -> Vec<LayerShape> {
    let mut l = vec![conv(3, 64, 7, 2, 1200, 1200)];
    for _ in 0..3 {
        l.extend(resnet_basic(64, 300));
    }
    l.push(conv(64, 128, 3, 2, 300, 300));
    for _ in 0..4 {
        l.extend(resnet_basic(128, 150));
    }
    l.push(conv(128, 256, 3, 2, 150, 150));
    for _ in 0..6 {
        l.extend(resnet_basic(256, 75));
    }
    for &(c, h, anchors) in &[(256u32, 38u32, 4u32), (512, 19, 6), (512, 10, 6), (256, 5, 6), (256, 3, 4), (256, 1, 4)] {
        l.push(conv(c, anchors * 4, 3, 1, h, h));
        l.push(conv(c, anchors * 81, 3, 1, h, h));
    }
    l
}

fn rfcn_resnet101_layers() -> Vec<LayerShape> {
    let mut l = resnet101_layers();
    l.pop();
    // RPN + position-sensitive score maps.
    l.push(conv(1024, 512, 3, 1, 38, 63));
    l.push(conv(512, 9 * 2, 1, 1, 38, 63));
    l.push(conv(512, 9 * 4, 1, 1, 38, 63));
    l.push(conv(2048, 7 * 7 * 81, 1, 1, 38, 63));
    l
}

// ---------------------------------------------------------------------------
// The zoo.
// ---------------------------------------------------------------------------

fn weights_profile(family: QuantFamily) -> ValueProfile {
    match family {
        QuantFamily::Torchvision => ValueProfile::TwoSidedGeometric { q: 0.90, noise_floor: 0.12 },
        QuantFamily::IntelAi => ValueProfile::TwoSidedGeometric { q: 0.78, noise_floor: 0.01 },
        QuantFamily::Distiller => ValueProfile::TwoSidedGeometric { q: 0.82, noise_floor: 0.02 },
        QuantFamily::MlPerf => ValueProfile::TwoSidedGeometric { q: 0.85, noise_floor: 0.04 },
        QuantFamily::PerLayer => ValueProfile::TwoSidedGeometric { q: 0.74, noise_floor: 0.008 },
        QuantFamily::Pact4 => ValueProfile::TwoSidedGeometric { q: 0.62, noise_floor: 0.01 },
        QuantFamily::Pruned => ValueProfile::Sparse { sparsity: 0.85, q: 0.75 },
    }
}

fn relu_acts(sparsity: f64, q: f64) -> Option<ValueProfile> {
    Some(ValueProfile::ReluActivation { sparsity, q, noise_floor: 0.01 })
}

/// All 24 models of Table II.
pub fn all_models() -> Vec<ModelConfig> {
    use QuantFamily::*;
    let m = |name, family, bits, layers: Vec<LayerShape>, act, perf| ModelConfig {
        name,
        family,
        bits,
        layer_bits: Vec::new(),
        layers,
        weight_profile: weights_profile(family),
        act_profile: act,
        in_perf_study: perf,
    };
    // ResNet18-PACT: int4 body, int8 first and last layers (§VII).
    let pact = {
        let layers = resnet18_layers();
        let n = layers.len();
        let mut layer_bits = vec![4u32; n];
        layer_bits[0] = 8;
        layer_bits[n - 1] = 8;
        ModelConfig {
            name: "resnet18_pact",
            family: Pact4,
            bits: 4,
            layer_bits,
            layers,
            weight_profile: weights_profile(Pact4),
            act_profile: relu_acts(0.45, 0.80),
            in_perf_study: true,
        }
    };
    vec![
        m("googlenet", Torchvision, 8, googlenet_layers(), relu_acts(0.55, 0.93), true),
        m("inception_v3", Torchvision, 8, inception_v3_layers(), relu_acts(0.52, 0.93), false),
        m("mobilenet_v2", Torchvision, 8, mobilenet_v2_layers(), relu_acts(0.42, 0.95), true),
        m("mobilenet_v3", Torchvision, 8, mobilenet_v3_layers(), relu_acts(0.38, 0.96), true),
        m("resnet18", Torchvision, 8, resnet18_layers(), relu_acts(0.50, 0.94), true),
        m("resnet50", Torchvision, 8, resnet50_layers(), relu_acts(0.55, 0.93), true),
        m("resnext101", Torchvision, 8, resnext101_layers(), relu_acts(0.62, 0.90), false),
        m("shufflenet_v2", Torchvision, 8, shufflenet_v2_layers(), relu_acts(0.45, 0.95), true),
        // IntelAI: weights only (float activations in the released models).
        m("inception_v4", IntelAi, 8, inception_v4_layers(), None, false),
        m("mobilenet_v1", IntelAi, 8, mobilenet_v1_layers(), None, false),
        m("resnet101", IntelAi, 8, resnet101_layers(), None, false),
        m("rfcn_resnet101", IntelAi, 8, rfcn_resnet101_layers(), None, false),
        m("ssd_resnet34", IntelAi, 8, ssd_resnet34_layers(), None, false),
        m("wide_deep", IntelAi, 8, wide_deep_layers(), None, false),
        // NLP / recommendation / detection / captioning / segmentation.
        m("q8bert", Distiller, 8, q8bert_layers(),
          Some(ValueProfile::TwoSidedGeometric { q: 0.88, noise_floor: 0.03 }), true),
        m("ncf", Distiller, 8, ncf_layers(), relu_acts(0.35, 0.90), true),
        pact,
        m("ssd_mobilenet", MlPerf, 8, ssd_mobilenet_layers(), relu_acts(0.45, 0.94), true),
        m("mobilenet", MlPerf, 8, mobilenet_v1_layers(), relu_acts(0.40, 0.95), true),
        m("bilstm", PerLayer, 8, bilstm_layers(),
          Some(ValueProfile::TwoSidedGeometric { q: 0.80, noise_floor: 0.015 }), true),
        m("segnet", PerLayer, 8, segnet_layers(), relu_acts(0.48, 0.93), true),
        m("resnet18_q", PerLayer, 8, resnet18_layers(), relu_acts(0.52, 0.92), true),
        m("alexnet_eyeriss", Pruned, 8, alexnet_layers(), relu_acts(0.65, 0.88), true),
        m("googlenet_eyeriss", Pruned, 8, googlenet_layers(), relu_acts(0.60, 0.90), true),
    ]
}

/// Look up a model by name.
pub fn model_by_name(name: &str) -> Option<ModelConfig> {
    all_models().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_has_all_24_models() {
        assert_eq!(all_models().len(), 24);
        let names: std::collections::HashSet<_> = all_models().iter().map(|m| m.name).collect();
        assert_eq!(names.len(), 24, "duplicate names");
    }

    #[test]
    fn mac_counts_are_plausible() {
        // Published MAC counts (±50% tolerance — our configs approximate
        // pooling/padding): ResNet18 ≈ 1.8 G, ResNet50 ≈ 4.1 G,
        // MobileNetV1 ≈ 0.57 G, AlexNet ≈ 0.72 G, GoogLeNet ≈ 1.5 G.
        let check = |name: &str, expected: f64| {
            let m = model_by_name(name).unwrap();
            let macs = m.total_macs() as f64;
            assert!(
                (macs / expected - 1.0).abs() < 0.5,
                "{name}: {macs:.2e} vs expected {expected:.2e}"
            );
        };
        check("resnet18", 1.8e9);
        check("resnet50", 4.1e9);
        check("mobilenet_v1", 5.7e8);
        check("alexnet_eyeriss", 7.2e8);
        check("googlenet", 1.5e9);
    }

    #[test]
    fn weight_counts_are_plausible() {
        // Parameters: ResNet18 ≈ 11.7 M, ResNet50 ≈ 25.6 M, AlexNet ≈ 61 M,
        // MobileNetV1 ≈ 4.2 M (conv+fc only; we tolerate ±40%).
        let check = |name: &str, expected: f64| {
            let m = model_by_name(name).unwrap();
            let w = m.total_weights() as f64;
            assert!(
                (w / expected - 1.0).abs() < 0.4,
                "{name}: {w:.2e} vs expected {expected:.2e}"
            );
        };
        check("resnet18", 11.7e6);
        check("resnet50", 25.6e6);
        check("alexnet_eyeriss", 61e6);
        check("mobilenet_v1", 4.2e6);
    }

    #[test]
    fn layer_arithmetic_consistency() {
        for m in all_models() {
            for (i, l) in m.layers.iter().enumerate() {
                assert!(l.weight_elems() > 0, "{} layer {i} no weights", m.name);
                assert!(l.input_elems() > 0 && l.output_elems() > 0, "{} layer {i}", m.name);
                if !matches!(l, LayerShape::Embedding { .. }) {
                    assert!(l.macs() > 0, "{} layer {i} no MACs", m.name);
                }
            }
        }
    }

    #[test]
    fn intel_models_have_no_activation_profile() {
        for m in all_models() {
            if m.family == QuantFamily::IntelAi {
                assert!(m.act_profile.is_none(), "{}", m.name);
            } else {
                assert!(m.act_profile.is_some(), "{}", m.name);
            }
        }
    }

    #[test]
    fn pact_model_is_4bit_with_8bit_ends() {
        let m = model_by_name("resnet18_pact").unwrap();
        assert_eq!(m.bits, 4);
        assert_eq!(m.bits_for(0), 8);
        assert_eq!(m.bits_for(m.layers.len() - 1), 8);
        assert_eq!(m.bits_for(1), 4);
    }
}
