//! Model zoo, synthetic value distributions and trace capture — the data
//! substrate standing in for the paper's proprietary quantized-model traces
//! (see DESIGN.md §3 for the substitution rationale).

pub mod distributions;
pub mod trace;
pub mod zoo;

pub use trace::{LayerTrace, ModelTrace};
pub use zoo::{all_models, model_by_name, LayerShape, ModelConfig, QuantFamily};
