//! Trace capture: materialized per-layer weight/activation tensors.
//!
//! Mirrors the paper's trace-collection flow (§VII): weights are dumped
//! once per layer; activations are sampled over several inputs and pooled
//! into the profiling histogram, then *fresh* activations (a different
//! seed — a different "input image") are compressed with the profiled
//! table. Tensors larger than `sample_cap` are sampled; footprints scale by
//! the true element count (value distributions are i.i.d. per layer by
//! construction, so a sample's bits/value is an unbiased estimate).


use super::distributions::ValueProfile;
use super::zoo::ModelConfig;
use crate::apack::Histogram;

/// One layer's materialized tensors.
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub layer_idx: usize,
    pub bits: u32,
    /// Sampled weight values.
    pub weights: Vec<u32>,
    /// True number of weight elements (≥ `weights.len()`).
    pub weight_elems: u64,
    /// Sampled input-activation values for *profiling*: the per-input
    /// draws concatenated in input order, empty if the model's
    /// activations are not studied.
    pub act_profile_samples: Vec<u32>,
    /// Values drawn per profiling input — `act_profile_samples` is the
    /// concatenation of [`act_samples_per_input`](Self::act_samples_per_input)-sized
    /// per-input runs (0 when activations are not studied), so consumers
    /// can pool per-input histograms without re-deriving the split.
    pub act_samples_per_input: usize,
    /// Fresh activation values standing in for the measured inference
    /// input (same distribution, different seed).
    pub activations: Vec<u32>,
    /// True number of input-activation elements.
    pub act_elems: u64,
}

/// A fully synthesized model trace.
#[derive(Debug, Clone)]
pub struct ModelTrace {
    pub name: String,
    pub bits: u32,
    pub layers: Vec<LayerTrace>,
}

/// Per-layer jitter applied to profile parameters so layers differ (real
/// layer distributions vary around the model-level family).
fn jitter_profile(p: ValueProfile, layer: usize) -> ValueProfile {
    // Deterministic ±10% modulation of the main skew parameter.
    let f = 1.0 + 0.1 * (((layer as f64 * 2.399963) .sin()) as f64);
    match p {
        ValueProfile::TwoSidedGeometric { q, noise_floor } => ValueProfile::TwoSidedGeometric {
            q: (q * f).clamp(0.05, 0.995),
            noise_floor,
        },
        ValueProfile::Sparse { sparsity, q } => ValueProfile::Sparse {
            sparsity: (sparsity * f).clamp(0.0, 0.97),
            q,
        },
        ValueProfile::ReluActivation { sparsity, q, noise_floor } => {
            ValueProfile::ReluActivation {
                sparsity: (sparsity * f).clamp(0.0, 0.95),
                q,
                noise_floor,
            }
        }
        ValueProfile::Uniform => ValueProfile::Uniform,
    }
}

impl ModelTrace {
    /// Synthesize a trace for a model. `sample_cap` bounds the number of
    /// values materialized per tensor; `profile_samples` is the number of
    /// pooled activation profiling inputs (paper: up to 9).
    pub fn synthesize(cfg: &ModelConfig, sample_cap: usize, profile_samples: usize, seed: u64) -> Self {
        let mut layers = Vec::with_capacity(cfg.layers.len());
        for (i, shape) in cfg.layers.iter().enumerate() {
            let bits = cfg.bits_for(i);
            let w_elems = shape.weight_elems();
            let a_elems = shape.input_elems();
            let w_n = (w_elems as usize).min(sample_cap);
            let a_n = (a_elems as usize).min(sample_cap);
            let wp = jitter_profile(cfg.weight_profile, i);
            let weights = wp.sample(bits, w_n, seed ^ (i as u64) << 1);
            let (act_profile_samples_v, act_per_input, activations) = match cfg.act_profile {
                Some(ap) => {
                    let ap = jitter_profile(ap, i);
                    // Pool `profile_samples` smaller draws for the table.
                    let per = (a_n / profile_samples.max(1)).max(256).min(a_n.max(1));
                    let mut pooled = Vec::with_capacity(per * profile_samples);
                    for s in 0..profile_samples {
                        pooled.extend(ap.sample(
                            bits,
                            per,
                            seed ^ 0xA11C_E000 ^ ((i as u64) << 8) ^ s as u64,
                        ));
                    }
                    // Fresh "measurement" input: disjoint seed.
                    let fresh =
                        ap.sample(bits, a_n, seed ^ 0xF4E5_1000 ^ ((i as u64) << 8));
                    (pooled, per, fresh)
                }
                None => (Vec::new(), 0, Vec::new()),
            };
            layers.push(LayerTrace {
                layer_idx: i,
                bits,
                weights,
                weight_elems: w_elems,
                act_profile_samples: act_profile_samples_v,
                act_samples_per_input: act_per_input,
                activations,
                act_elems: a_elems,
            });
        }
        Self { name: cfg.name.to_string(), bits: cfg.bits, layers }
    }

    /// Histogram of a layer's profiling activations.
    pub fn act_profile_histogram(&self, layer: usize) -> Histogram {
        let l = &self.layers[layer];
        Histogram::from_values(l.bits, &l.act_profile_samples)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    #[test]
    fn synthesize_respects_caps_and_counts() {
        let cfg = model_by_name("resnet18").unwrap();
        let t = ModelTrace::synthesize(&cfg, 4096, 3, 1);
        assert_eq!(t.layers.len(), cfg.layers.len());
        for (l, shape) in t.layers.iter().zip(&cfg.layers) {
            assert!(l.weights.len() <= 4096);
            assert_eq!(l.weight_elems, shape.weight_elems());
            assert!(l.activations.len() <= 4096);
            assert_eq!(l.act_elems, shape.input_elems());
        }
    }

    #[test]
    fn intel_models_have_empty_activations() {
        let cfg = model_by_name("resnet101").unwrap();
        let t = ModelTrace::synthesize(&cfg, 1024, 3, 1);
        assert!(t.layers.iter().all(|l| l.activations.is_empty()));
    }

    #[test]
    fn profiling_and_fresh_activations_differ_but_match_distribution() {
        let cfg = model_by_name("resnet18").unwrap();
        let t = ModelTrace::synthesize(&cfg, 8192, 5, 3);
        let l = &t.layers[2];
        assert_ne!(l.act_profile_samples, l.activations);
        let hp = Histogram::from_values(8, &l.act_profile_samples);
        let hf = Histogram::from_values(8, &l.activations);
        assert!((hp.sparsity() - hf.sparsity()).abs() < 0.08);
    }

    #[test]
    fn traces_are_deterministic() {
        let cfg = model_by_name("ncf").unwrap();
        let a = ModelTrace::synthesize(&cfg, 1000, 2, 9);
        let b = ModelTrace::synthesize(&cfg, 1000, 2, 9);
        assert_eq!(a.layers[0].weights, b.layers[0].weights);
    }

    #[test]
    fn four_bit_model_values_fit_per_layer() {
        let cfg = model_by_name("resnet18_pact").unwrap();
        let t = ModelTrace::synthesize(&cfg, 2048, 2, 5);
        for (i, l) in t.layers.iter().enumerate() {
            let max = 1u32 << cfg.bits_for(i);
            assert!(l.weights.iter().all(|&v| v < max), "layer {i}");
            assert!(l.activations.iter().all(|&v| v < max), "layer {i}");
        }
        // First layer keeps int8 range per the paper.
        assert_eq!(t.layers[0].bits, 8);
        assert_eq!(t.layers[1].bits, 4);
    }
}
