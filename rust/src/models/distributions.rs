//! Synthetic value-distribution generators.
//!
//! The paper profiles 24 real quantized models; those checkpoints and their
//! GPU traces are not available here, so each model's weight/activation
//! value streams are synthesized from the distribution *families* the paper
//! describes per quantizer (§VII-A):
//!
//! - **Torchvision int8**: values cluster near zero and near the top of the
//!   range (two's-complement negatives), but "the lower bits tend to be
//!   noisy" — the quantizer uses the full range whether needed or not. We
//!   model this as a two-sided discretized geometric around zero plus a
//!   uniform noise floor.
//! - **IntelAI int8**: "more skewed distributions for weights" — same shape
//!   with a sharper decay and a much smaller noise floor.
//! - **Pruned** (Eyeriss AlexNet/GoogLeNet): a large spike at zero
//!   (70–90 % sparsity) over a skewed remainder.
//! - **PACT int4 / per-layer trimmed**: the same shapes on narrower value
//!   spaces.
//! - **ReLU activations**: a zero spike (the well-known activation
//!   sparsity) plus a one-sided decaying tail; **attention/recurrent
//!   activations** (Q8BERT, BILSTM) are two-sided like Fig 2.
//!
//! All sampling is deterministic given a seed (xoshiro256**), so every
//! figure is exactly reproducible.

use crate::util::Rng64;

/// Parameterized distribution over a `bits`-wide unsigned value space.
/// Signed families place negatives at the top of the range (two's
/// complement), matching the quantized-integer streams APack sees.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ValueProfile {
    /// Two-sided discretized geometric around 0 (signed, two's complement)
    /// with a uniform noise floor: `p(k) ∝ (1-floor)·q^|k| + floor/2^bits`.
    TwoSidedGeometric {
        /// Decay per step away from zero, in (0, 1). Smaller = more skewed.
        q: f64,
        /// Fraction of probability mass spread uniformly (quantizer noise).
        noise_floor: f64,
    },
    /// Zero spike + two-sided geometric remainder (pruned weights).
    Sparse {
        /// Probability of exact zero.
        sparsity: f64,
        /// Decay of the non-zero remainder.
        q: f64,
    },
    /// Zero spike + one-sided geometric tail (post-ReLU activations).
    ReluActivation {
        /// Probability of exact zero.
        sparsity: f64,
        /// Decay per step above zero.
        q: f64,
        /// Uniform noise floor fraction.
        noise_floor: f64,
    },
    /// Uniform over the whole space (worst case; sanity baseline).
    Uniform,
}

impl ValueProfile {
    /// Probability mass function over the `2^bits` values.
    pub fn pmf(&self, bits: u32) -> Vec<f64> {
        let n = 1usize << bits;
        let mut p = vec![0.0f64; n];
        match *self {
            ValueProfile::Uniform => {
                p.fill(1.0 / n as f64);
            }
            ValueProfile::TwoSidedGeometric { q, noise_floor } => {
                // Signed magnitude |k| for two's-complement value v.
                let half = n as i64 / 2;
                let mut norm = 0.0;
                for (v, pv) in p.iter_mut().enumerate() {
                    let k = if (v as i64) < half { v as i64 } else { v as i64 - n as i64 };
                    *pv = q.powi(k.unsigned_abs() as i32);
                    norm += *pv;
                }
                for pv in p.iter_mut() {
                    *pv = (1.0 - noise_floor) * *pv / norm + noise_floor / n as f64;
                }
            }
            ValueProfile::Sparse { sparsity, q } => {
                let base = ValueProfile::TwoSidedGeometric { q, noise_floor: 0.002 }.pmf(bits);
                // Remove the zero bucket from the remainder, renormalize.
                let rem: f64 = base.iter().skip(1).sum::<f64>() + 0.0;
                for (v, pv) in p.iter_mut().enumerate() {
                    *pv = if v == 0 {
                        sparsity
                    } else {
                        (1.0 - sparsity) * base[v] / rem
                    };
                }
            }
            ValueProfile::ReluActivation { sparsity, q, noise_floor } => {
                let mut norm = 0.0;
                for (v, pv) in p.iter_mut().enumerate().skip(1) {
                    *pv = q.powi(v as i32);
                    norm += *pv;
                }
                for (v, pv) in p.iter_mut().enumerate() {
                    *pv = if v == 0 {
                        sparsity + noise_floor / n as f64
                    } else {
                        (1.0 - sparsity - noise_floor) * *pv / norm + noise_floor / n as f64
                    };
                }
                // pmf of index 0 double-counted the floor; renormalize.
                let s: f64 = p.iter().sum();
                for pv in p.iter_mut() {
                    *pv /= s;
                }
            }
        }
        p
    }

    /// Deterministically sample `count` values.
    pub fn sample(&self, bits: u32, count: usize, seed: u64) -> Vec<u32> {
        let pmf = self.pmf(bits);
        let mut cdf = Vec::with_capacity(pmf.len());
        let mut acc = 0.0;
        for &p in &pmf {
            acc += p;
            cdf.push(acc);
        }
        let mut rng = Rng64::new(seed);
        (0..count)
            .map(|_| {
                let u: f64 = rng.f64() * acc;
                cdf.partition_point(|&c| c < u).min(pmf.len() - 1) as u32
            })
            .collect()
    }

    /// Expected value-stream entropy in bits/value — used to sanity-check
    /// generated tensors against their analytic family.
    pub fn entropy(&self, bits: u32) -> f64 {
        self.pmf(bits).iter().filter(|&&p| p > 0.0).map(|&p| -p * p.log2()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::Histogram;

    #[test]
    fn pmfs_normalize() {
        for profile in [
            ValueProfile::Uniform,
            ValueProfile::TwoSidedGeometric { q: 0.9, noise_floor: 0.05 },
            ValueProfile::Sparse { sparsity: 0.8, q: 0.8 },
            ValueProfile::ReluActivation { sparsity: 0.5, q: 0.95, noise_floor: 0.01 },
        ] {
            for bits in [4u32, 8] {
                let s: f64 = profile.pmf(bits).iter().sum();
                assert!((s - 1.0).abs() < 1e-9, "{profile:?} bits={bits} sums to {s}");
                assert!(profile.pmf(bits).iter().all(|&p| p >= 0.0));
            }
        }
    }

    #[test]
    fn two_sided_clusters_at_both_ends() {
        let p = ValueProfile::TwoSidedGeometric { q: 0.85, noise_floor: 0.02 }.pmf(8);
        let low: f64 = p[..8].iter().sum();
        let high: f64 = p[248..].iter().sum();
        let mid: f64 = p[64..192].iter().sum();
        assert!(low > 0.3, "low mass {low}");
        assert!(high > 0.25, "high mass {high}");
        assert!(mid < 0.1, "mid mass {mid}");
    }

    #[test]
    fn sampling_matches_pmf() {
        let profile = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.9, noise_floor: 0.01 };
        let values = profile.sample(8, 100_000, 42);
        let h = Histogram::from_values(8, &values);
        assert!((h.sparsity() - 0.5).abs() < 0.02, "sparsity {}", h.sparsity());
        // Empirical entropy close to analytic.
        assert!((h.entropy() - profile.entropy(8)).abs() < 0.2);
    }

    #[test]
    fn sampling_is_deterministic() {
        let profile = ValueProfile::Sparse { sparsity: 0.7, q: 0.8 };
        assert_eq!(profile.sample(8, 1000, 7), profile.sample(8, 1000, 7));
        assert_ne!(profile.sample(8, 1000, 7), profile.sample(8, 1000, 8));
    }

    #[test]
    fn skew_ordering_of_entropies() {
        // IntelAI-style (sharp, low noise) < Torchvision-style (noisy) <
        // uniform.
        let intel = ValueProfile::TwoSidedGeometric { q: 0.75, noise_floor: 0.005 }.entropy(8);
        let tv = ValueProfile::TwoSidedGeometric { q: 0.9, noise_floor: 0.12 }.entropy(8);
        let uni = ValueProfile::Uniform.entropy(8);
        assert!(intel < tv && tv < uni, "{intel} {tv} {uni}");
    }

    #[test]
    fn pruned_entropy_is_tiny() {
        let e = ValueProfile::Sparse { sparsity: 0.9, q: 0.7 }.entropy(8);
        assert!(e < 1.5, "{e}");
    }
}
