//! Evaluation hook for the APackStore: per-model store footprint vs. raw
//! size. Packs the zoo (synthesized traces, same sampling as the Fig 5
//! study) into one store file, reads the footer back, and reports what a
//! deployment would actually hold at rest — compressed payload, index
//! overhead, and the end-to-end ratio per model.

use std::path::Path;

use crate::coordinator::PartitionPolicy;
use crate::error::Result;
use crate::eval::study::geomean;
use crate::models::zoo::{all_models, ModelConfig};
use crate::store::{pack_model_zoo, StoreHandle};

use super::render_table;

/// Per-model rollup extracted from a packed store.
#[derive(Debug, Clone)]
pub struct ModelStoreFootprint {
    pub model: String,
    pub tensors: usize,
    pub chunks: usize,
    /// Raw (uncompressed) bits of every stored tensor.
    pub raw_bits: u64,
    /// Compressed chunk payload bytes on disk.
    pub stored_bytes: u64,
}

impl ModelStoreFootprint {
    /// Raw size / stored size.
    pub fn ratio(&self) -> f64 {
        self.raw_bits as f64 / (self.stored_bytes as f64 * 8.0)
    }
}

/// Group a packed store's tensors by their `"{model}/..."` name prefix.
/// Works uniformly over single-file and sharded stores.
pub fn footprints_from_store(store: &StoreHandle) -> Vec<ModelStoreFootprint> {
    let mut out: Vec<ModelStoreFootprint> = Vec::new();
    for t in store.tensor_metas() {
        let model = t.name.split('/').next().unwrap_or(&t.name).to_string();
        let idx = match out.iter().position(|f| f.model == model) {
            Some(i) => i,
            None => {
                out.push(ModelStoreFootprint {
                    model,
                    tensors: 0,
                    chunks: 0,
                    raw_bits: 0,
                    stored_bytes: 0,
                });
                out.len() - 1
            }
        };
        let entry = &mut out[idx];
        entry.tensors += 1;
        entry.chunks += t.chunks.len();
        entry.raw_bits += t.raw_bits();
        entry.stored_bytes += t.compressed_bytes();
    }
    out
}

/// Pack `models` into a store at `path` and render the footprint report.
pub fn report_at(path: &Path, models: &[ModelConfig], sample_cap: usize) -> Result<String> {
    let summary = pack_model_zoo(path, models, sample_cap, PartitionPolicy::default())?;
    let store = StoreHandle::open(path)?;
    let footprints = footprints_from_store(&store);

    let rows: Vec<Vec<String>> = footprints
        .iter()
        .map(|f| {
            vec![
                f.model.clone(),
                f.tensors.to_string(),
                f.chunks.to_string(),
                format!("{:.1}", f.raw_bits as f64 / 8.0 / 1024.0),
                format!("{:.1}", f.stored_bytes as f64 / 1024.0),
                format!("{:.2}x", f.ratio()),
            ]
        })
        .collect();
    let mut s = render_table(
        "Store footprint vs raw per model (sampled tensors)",
        &["model", "tensors", "chunks", "raw KiB", "stored KiB", "ratio"],
        &rows,
    );
    let ratios: Vec<f64> = footprints.iter().map(|f| f.ratio()).collect();
    s.push_str(&format!(
        "\nstore file: {} tensors, {} chunks, {:.1} KiB total ({:.2}x vs raw; \
         geomean per-model ratio {:.2}x)\n",
        summary.tensors,
        summary.chunks,
        summary.file_bytes as f64 / 1024.0,
        summary.compression_ratio(),
        geomean(&ratios),
    ));
    Ok(s)
}

/// Pack the full 24-model zoo into a temp file, render, clean up.
pub fn render(sample_cap: usize) -> Result<String> {
    let path = std::env::temp_dir()
        .join(format!("apack_store_report_{}.apackstore", std::process::id()));
    let result = report_at(&path, &all_models(), sample_cap);
    std::fs::remove_file(&path).ok();
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    #[test]
    fn report_covers_models_and_compresses() {
        let path = std::env::temp_dir()
            .join(format!("apack_store_report_test_{}.apackstore", std::process::id()));
        let models = vec![model_by_name("ncf").unwrap(), model_by_name("bilstm").unwrap()];
        let text = report_at(&path, &models, 2048).unwrap();
        assert!(text.contains("ncf"));
        assert!(text.contains("bilstm"));

        let store = StoreHandle::open(&path).unwrap();
        let fps = footprints_from_store(&store);
        assert_eq!(fps.len(), 2);
        for f in &fps {
            assert!(f.raw_bits > 0 && f.stored_bytes > 0);
            assert!(f.ratio() > 1.0, "{}: ratio {}", f.model, f.ratio());
        }
        drop(store);
        std::fs::remove_file(&path).ok();
    }
}
