//! Evaluation harness: regenerates every table and figure of the paper's
//! §VII (see DESIGN.md §5 for the experiment index).
//!
//! - [`table1`] — the example symbol/probability table (paper Table I).
//! - [`fig2`] — cumulative value distributions.
//! - [`fig5`] — normalized off-chip traffic, activations (5a) and
//!   weights (5b), for Baseline / RLE / RLEZ / ShapeShifter / APack.
//! - [`area_power`] — §VII-B silicon numbers and the DRAM-power overhead.
//! - [`fig6`] — normalized off-chip energy.
//! - [`fig7`] — overall speedup on the TensorCore accelerator.
//! - [`fig8`] — overall energy efficiency.
//!
//! - [`store_report`] — APackStore footprint vs. raw per model: what the
//!   zoo weighs at rest when packed into one compressed store file.
//! - [`hot_path`] — codec hot-path throughput harness (per-mode, per-value
//!   vs. block decode) emitting `BENCH_codec_hot_path.json`.
//! - [`ingest`] — write-path throughput harness (tablegen seed vs.
//!   incremental, per-value vs. block encode, serial vs. pipelined zoo
//!   pack) emitting `BENCH_store_pack.json`.
//!
//! All figures derive from one shared [`CompressionStudy`] so the traffic,
//! energy and performance numbers are mutually consistent.

pub mod area_power;
pub mod e2e;
pub mod fig2;
pub mod hot_path;
pub mod ingest;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod store_report;
pub mod study;
pub mod table1;

pub use study::{CompressionStudy, LayerCompression, ModelCompression, Scheme};

/// Fixed seed for every evaluation run — figures are exactly reproducible.
pub const EVAL_SEED: u64 = 0xA9AC_2022;

/// Values sampled per tensor for codec measurements (footprints scale to
/// the true element counts; see `models::trace`).
pub const SAMPLE_CAP: usize = 16 * 1024;

/// Activation profiling inputs pooled per layer (paper: up to 9).
pub const PROFILE_SAMPLES: usize = 9;

/// Render a markdown-ish table from headers + rows (used by the CLI and
/// bench output so every figure prints in one consistent format).
pub fn render_table(title: &str, headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let mut s = format!("\n== {title} ==\n");
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let header_cells: Vec<String> = headers.iter().map(|h| h.to_string()).collect();
    s.push_str(&fmt_row(&header_cells, &widths));
    s.push('\n');
    s.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    s.push('\n');
    for row in rows {
        s.push_str(&fmt_row(row, &widths));
        s.push('\n');
    }
    s
}
