//! Fig 2: cumulative distribution of weight and activation values for
//! Layer 10 of Q8BERT and Layer 1 of BILSTM.

use crate::apack::Histogram;
use crate::models::trace::ModelTrace;
use crate::models::zoo::model_by_name;

use super::{EVAL_SEED, PROFILE_SAMPLES, SAMPLE_CAP};

/// One CDF series, downsampled to `points` for plotting/printing.
#[derive(Debug, Clone)]
pub struct CdfSeries {
    pub label: String,
    pub points: Vec<(u32, f64)>,
}

fn series(label: &str, values: &[u32], bits: u32, points: usize) -> CdfSeries {
    let h = Histogram::from_values(bits, values);
    let cdf = h.cdf();
    let step = (cdf.len() / points.max(1)).max(1);
    let mut pts: Vec<(u32, f64)> = cdf.iter().step_by(step).copied().collect();
    // Always include the final point so the series ends at 1.0.
    if pts.last() != cdf.last() {
        pts.push(*cdf.last().expect("non-empty cdf"));
    }
    CdfSeries { label: label.to_string(), points: pts }
}

/// Build the four Fig 2 series (weights + activations for the two layers).
pub fn fig2_series() -> Vec<CdfSeries> {
    let mut out = Vec::new();
    for (model, layer) in [("q8bert", 10usize), ("bilstm", 1usize)] {
        let cfg = model_by_name(model).expect("zoo model");
        let trace = ModelTrace::synthesize(&cfg, SAMPLE_CAP, PROFILE_SAMPLES, EVAL_SEED);
        let l = &trace.layers[layer.min(trace.layers.len() - 1)];
        out.push(series(&format!("{model} L{layer} weights"), &l.weights, cfg.bits, 32));
        if !l.activations.is_empty() {
            out.push(series(
                &format!("{model} L{layer} activations"),
                &l.activations,
                cfg.bits,
                32,
            ));
        }
    }
    out
}

/// Render the series as text (value → cumulative fraction).
pub fn render() -> String {
    let mut s = String::from("\n== Fig 2: cumulative value distributions ==\n");
    for series in fig2_series() {
        s.push_str(&format!("\n{}:\n", series.label));
        for (v, f) in &series.points {
            let bar = "#".repeat((f * 40.0) as usize);
            s.push_str(&format!("  {v:>5}  {f:5.3}  {bar}\n"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_has_far_from_uniform_distributions() {
        let series = fig2_series();
        assert!(series.len() >= 3);
        for s in &series {
            // Monotone, ends near 1.
            let last = s.points.last().unwrap().1;
            assert!(last > 0.9, "{}: CDF ends at {last}", s.label);
            // "Around half of the values tend to be close to zero":
            // CDF at ~1/8 of the range should already exceed 0.3.
            let early = s
                .points
                .iter()
                .find(|(v, _)| *v >= 32)
                .map(|(_, f)| *f)
                .unwrap_or(0.0);
            assert!(early > 0.3, "{}: early mass {early}", s.label);
        }
    }
}
