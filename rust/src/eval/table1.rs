//! Table I regeneration: the symbol + probability-count table APack's
//! generator produces for a BILSTM weight layer.

use crate::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::apack::{Histogram, SymbolTable};
use crate::models::trace::ModelTrace;
use crate::models::zoo::model_by_name;

use super::{EVAL_SEED, PROFILE_SAMPLES, SAMPLE_CAP};

/// Generate the table for a model's layer-`layer` weights.
pub fn table_for(model: &str, layer: usize, kind: TensorKind) -> Option<SymbolTable> {
    let cfg = model_by_name(model)?;
    let trace = ModelTrace::synthesize(&cfg, SAMPLE_CAP, PROFILE_SAMPLES, EVAL_SEED);
    let l = trace.layers.get(layer)?;
    let values = match kind {
        TensorKind::Weights => &l.weights,
        TensorKind::Activations => &l.activations,
    };
    if values.is_empty() {
        return None;
    }
    let hist = Histogram::from_values(cfg.bits, values);
    generate_table(&hist, kind, &TableGenConfig::for_bits(cfg.bits)).ok()
}

/// Render the Table I analogue (BILSTM layer-1 weights).
pub fn render() -> String {
    let mut s =
        String::from("\n== Table I: symbol & probability count table, bilstm L1 weights ==\n");
    match table_for("bilstm", 1, TensorKind::Weights) {
        Some(t) => s.push_str(&t.render()),
        None => s.push_str("(unavailable)\n"),
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::NUM_ROWS;

    #[test]
    fn bilstm_table_shape_matches_paper_qualitatively() {
        let t = table_for("bilstm", 1, TensorKind::Weights).unwrap();
        // Paper Table I properties: row 0 starts at 0 with high
        // probability, top row near 0xFF with high probability, most mass
        // at the two extremes of the value space.
        let p0 = t.probability(0);
        let p_last = t.probability(NUM_ROWS - 1);
        assert!(p0 > 0.2, "row0 p = {p0}\n{}", t.render());
        assert!(p_last > 0.1, "last row p = {p_last}\n{}", t.render());
        // Middle of the value space carries little probability.
        let mid: f64 = (0..NUM_ROWS)
            .filter(|&i| t.rows()[i].v_min >= 0x20 && t.rows()[i].v_max <= 0xDF)
            .map(|i| t.probability(i))
            .sum();
        assert!(mid < 0.2, "middle mass {mid}\n{}", t.render());
    }

    #[test]
    fn activation_table_generation_works_too() {
        let t = table_for("bilstm", 1, TensorKind::Activations).unwrap();
        for i in 0..NUM_ROWS {
            assert!(t.rows()[i].hi_cnt >= t.lo_cnt(i));
        }
    }
}
