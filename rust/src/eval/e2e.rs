//! End-to-end driver: real inference through the AOT-lowered JAX/Pallas
//! model on the PJRT CPU client, with APack on the simulated off-chip path.
//!
//! Flow per batch (mirroring Fig 1):
//! 1. weights live "off-chip" as APack containers — they are decoded
//!    through the coordinator's engine pool before being fed to the
//!    accelerator (the PJRT executable);
//! 2. the model runs, producing logits plus every intermediate int8
//!    activation tensor;
//! 3. activations are compressed with tables profiled on the *first*
//!    batch only (the paper's profiling assumption) and the traffic
//!    reduction + simulated speedup/energy are reported.

use std::path::Path;

use crate::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::apack::{Histogram, SymbolTable};
use crate::coordinator::{Coordinator, PartitionPolicy};
use crate::error::{Error, Result};
use crate::runtime::{i8_to_u32_stream, u32_stream_to_i8, CompiledModel};
use crate::simulator::dram::{DramConfig, DramPowerModel};

/// Per-tensor report line.
#[derive(Debug, Clone)]
pub struct TensorReport {
    pub name: String,
    pub elems: usize,
    pub raw_bits: u64,
    pub apack_bits: u64,
}

impl TensorReport {
    pub fn ratio(&self) -> f64 {
        self.raw_bits as f64 / self.apack_bits.max(1) as f64
    }
}

/// Results of the run (consumed by the example, the CLI and tests).
#[derive(Debug, Clone, Default)]
pub struct E2eReport {
    pub weights: Vec<TensorReport>,
    pub activations: Vec<TensorReport>,
    pub batches: usize,
    pub logits_checksum: i64,
}

impl E2eReport {
    fn norm(reports: &[TensorReport]) -> f64 {
        let raw: u64 = reports.iter().map(|r| r.raw_bits).sum();
        let comp: u64 = reports.iter().map(|r| r.apack_bits).sum();
        comp as f64 / raw.max(1) as f64
    }

    /// Normalized weight traffic (compressed / raw).
    pub fn weights_norm(&self) -> f64 {
        Self::norm(&self.weights)
    }

    /// Normalized activation traffic.
    pub fn acts_norm(&self) -> f64 {
        Self::norm(&self.activations)
    }
}

/// Deterministic synthetic input batch (int8 "image" data).
pub fn synth_input(n: usize, seed: u64) -> Vec<i32> {
    let mut state = seed.wrapping_mul(0x9E3779B97F4A7C15) | 1;
    (0..n)
        .map(|_| {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((state >> 56) as u8 as i8 as i32) / 2 // mild dynamic range
        })
        .collect()
}

/// Run the driver. Returns the report; prints a human-readable summary.
pub fn run(artifacts: &Path, batches: usize) -> Result<E2eReport> {
    let model = CompiledModel::load(artifacts).map_err(|e| {
        Error::Runtime(format!(
            "{e}\nhint: run `make artifacts` first to AOT-compile the JAX/Pallas model"
        ))
    })?;
    println!(
        "loaded model: input {:?}, {} weight tensors, {} outputs",
        model.manifest.input_shape,
        model.manifest.weights.len(),
        model.manifest.outputs.len()
    );

    let mut coord = Coordinator::new(PartitionPolicy::default());
    let mut report = E2eReport { batches, ..Default::default() };

    // --- Weights: compress once, then DECODE on the request path before
    // feeding the accelerator (proves the off-chip roundtrip).
    let mut decoded_weights: Vec<Vec<i32>> = Vec::new();
    for spec in &model.manifest.weights {
        let w = model.load_weight(spec)?;
        if !spec.is_int8() {
            // Requant multipliers: tiny int32 side tables, not part of the
            // compressed weight traffic.
            decoded_weights.push(w);
            continue;
        }
        let stream = i8_to_u32_stream(&w);
        let sc = coord.compress(8, &stream, TensorKind::Weights, None)?;
        let decoded = coord.decompress(&sc)?;
        assert_eq!(decoded, stream, "weight roundtrip must be lossless");
        report.weights.push(TensorReport {
            name: spec.name.clone(),
            elems: w.len(),
            raw_bits: (w.len() * 8) as u64,
            apack_bits: sc.footprint_bits(),
        });
        decoded_weights.push(u32_stream_to_i8(&decoded));
    }

    // --- Inference batches: profile activation tables on batch 0, apply
    // to later batches (fresh data).
    let in_elems: usize = model.manifest.input_shape.iter().product();
    let mut act_tables: Vec<Option<SymbolTable>> = Vec::new();
    let mut logits_checksum: i64 = 0;
    for b in 0..batches {
        let input = synth_input(in_elems, 0xE2E0 + b as u64);
        let outputs = model.run(&input, &decoded_weights)?;
        // Output 0 = logits; the rest are per-layer activations.
        logits_checksum =
            logits_checksum.wrapping_add(outputs[0].iter().map(|&v| v as i64).sum::<i64>());
        for (oi, act) in outputs.iter().enumerate().skip(1) {
            let stream = i8_to_u32_stream(act);
            if b == 0 {
                // Profile pass: build the table.
                let h = Histogram::from_values(8, &stream);
                let t = generate_table(&h, TensorKind::Activations, &TableGenConfig::for_bits(8))
                    .ok();
                act_tables.push(t);
                continue;
            }
            let name = model
                .manifest
                .outputs
                .get(oi)
                .cloned()
                .unwrap_or_else(|| format!("act{oi}"));
            let table = act_tables
                .get(oi - 1)
                .and_then(|t| t.clone())
                .ok_or_else(|| Error::Runtime(format!("no table for output {oi}")))?;
            let sc = coord.compress_with_table(table, &stream)?;
            let decoded = coord.decompress(&sc)?;
            assert_eq!(decoded, stream, "activation roundtrip must be lossless");
            report.activations.push(TensorReport {
                name: format!("{name}@b{b}"),
                elems: act.len(),
                raw_bits: (act.len() * 8) as u64,
                apack_bits: sc.footprint_bits(),
            });
        }
    }
    report.logits_checksum = logits_checksum;

    // --- Summary.
    println!("\nweights ({} tensors):", report.weights.len());
    for r in &report.weights {
        println!("  {:<12} {:>9} elems  ratio {:.2}x", r.name, r.elems, r.ratio());
    }
    println!(
        "weights normalized traffic: {:.3} (ratio {:.2}x)",
        report.weights_norm(),
        1.0 / report.weights_norm()
    );
    println!(
        "activations normalized traffic over {} batches: {:.3} (ratio {:.2}x, {} tensors)",
        batches.saturating_sub(1),
        report.acts_norm(),
        1.0 / report.acts_norm(),
        report.activations.len()
    );

    // Off-chip energy estimate for the measured traffic.
    let dram = DramPowerModel::new(DramConfig::ddr4_3200_dual());
    let raw_bytes: u64 = (report
        .weights
        .iter()
        .map(|r| r.raw_bits)
        .sum::<u64>()
        + report.activations.iter().map(|r| r.raw_bits).sum::<u64>())
        / 8;
    let comp_bytes: u64 = (report
        .weights
        .iter()
        .map(|r| r.apack_bits)
        .sum::<u64>()
        + report.activations.iter().map(|r| r.apack_bits).sum::<u64>())
        / 8;
    let e_base = dram.traffic_energy(raw_bytes, 0, 0.0).total_j();
    let e_comp = dram.traffic_energy(comp_bytes, 0, 0.0).total_j();
    println!(
        "off-chip DRAM energy: {:.2} uJ -> {:.2} uJ ({:.1}% saved)",
        e_base * 1e6,
        e_comp * 1e6,
        (1.0 - e_comp / e_base) * 100.0
    );
    println!("logits checksum: {}", report.logits_checksum);
    Ok(report)
}
