//! Fig 7: overall speedup of the TensorCore accelerator with off-chip
//! compression, versus the uncompressed baseline. The study covers the
//! model subset the paper runs through the ShapeShifter-compatible
//! simulator (`in_perf_study` in the zoo).

use crate::models::zoo::{all_models, ModelConfig};
use crate::simulator::accelerator::{AcceleratorConfig, AcceleratorSim, TrafficScaling};

use super::study::{geomean, CompressionStudy, Scheme};
use super::render_table;

/// Inference latency for one model under a scheme's per-layer scaling.
pub fn latency_s(study: &CompressionStudy, cfg: &ModelConfig, scheme: Scheme) -> f64 {
    let sim = AcceleratorSim::new(AcceleratorConfig::paper());
    let mc = study.get(cfg.name, scheme).expect("model in study");
    let results = sim.simulate_model(cfg, &|i| {
        let lc = mc.per_layer[i];
        TrafficScaling { weights: lc.weights_norm, activations: lc.acts_norm }
    });
    AcceleratorSim::total_time(&results)
}

/// Models in the performance study.
pub fn perf_models() -> Vec<ModelConfig> {
    all_models().into_iter().filter(|m| m.in_perf_study).collect()
}

/// Rows: model, SS speedup, APack speedup.
pub fn fig7_rows(study: &CompressionStudy) -> Vec<Vec<String>> {
    perf_models()
        .iter()
        .filter(|cfg| study.get(cfg.name, Scheme::Baseline).is_some())
        .map(|cfg| {
            let base = latency_s(study, cfg, Scheme::Baseline);
            let ss = base / latency_s(study, cfg, Scheme::ShapeShifter);
            let ap = base / latency_s(study, cfg, Scheme::Apack);
            vec![cfg.name.to_string(), format!("{ss:.3}"), format!("{ap:.3}")]
        })
        .collect()
}

/// Mean speedups `(shapeshifter, apack)` — the paper's headline numbers
/// are SS 1.30×, APack 1.44×.
pub fn mean_speedups(study: &CompressionStudy) -> (f64, f64) {
    let rows = fig7_rows(study);
    let col = |i: usize| {
        geomean(&rows.iter().filter_map(|r| r[i].parse::<f64>().ok()).collect::<Vec<_>>())
    };
    (col(1), col(2))
}

/// Render Fig 7.
pub fn render(study: &CompressionStudy) -> String {
    let mut out = render_table(
        "Fig 7: overall speedup vs baseline accelerator (higher is better)",
        &["model", "ShapeShifter", "APack"],
        &fig7_rows(study),
    );
    let (ss, ap) = mean_speedups(study);
    out.push_str(&format!(
        "geomean speedup: ShapeShifter {ss:.3}x (paper 1.30x), APack {ap:.3}x (paper 1.44x)\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    #[test]
    fn apack_speedup_at_least_shapeshifter() {
        let models = vec![model_by_name("ncf").unwrap(), model_by_name("bilstm").unwrap()];
        let study = CompressionStudy::run(
            &models,
            &[Scheme::Baseline, Scheme::ShapeShifter, Scheme::Apack],
        );
        for cfg in &models {
            let base = latency_s(&study, cfg, Scheme::Baseline);
            let ss = base / latency_s(&study, cfg, Scheme::ShapeShifter);
            let ap = base / latency_s(&study, cfg, Scheme::Apack);
            assert!(ap >= 1.0, "{}: APack slows down? {ap}", cfg.name);
            assert!(ap >= ss - 1e-9, "{}: APack {ap} < SS {ss}", cfg.name);
        }
    }
}
