//! §VII-B silicon figures: per-engine and array area/power, the component
//! breakdown, and the comparison against DRAM power at 90 % utilization
//! (paper: 64 engines = 1.14 mm², 179.2 mW, 4.7 % of DRAM power).

use crate::simulator::dram::{DramConfig, DramPowerModel};
use crate::simulator::engine::{EngineArrayConfig, EngineSilicon};

/// Computed area/power summary.
#[derive(Debug, Clone)]
pub struct AreaPowerSummary {
    pub encoder_area_mm2: f64,
    pub decoder_area_mm2: f64,
    pub encoder_power_mw: f64,
    pub decoder_power_mw: f64,
    pub array_area_mm2: f64,
    pub array_power_mw: f64,
    pub dram_power_w_at_90: f64,
    pub overhead_fraction: f64,
}

/// Compute the summary for the paper's 64-engine deployment. The paper's
/// aggregate (1.14 mm² / 179.2 mW) counts 64 engines total (encoders +
/// decoders), i.e. 32 pairs.
pub fn summary() -> AreaPowerSummary {
    let si = EngineSilicon::paper_65nm();
    let arr = EngineArrayConfig::paper_64();
    let pairs = arr.engines as f64 / 2.0;
    let array_area = pairs * (si.encoder_area_mm2 + si.decoder_area_mm2);
    let array_power = pairs * (si.encoder_power_mw + si.decoder_power_mw);
    let dram = DramPowerModel::new(DramConfig::ddr4_3200_dual());
    let dram_w = dram.power_at_utilization(0.9);
    AreaPowerSummary {
        encoder_area_mm2: si.encoder_area_mm2,
        decoder_area_mm2: si.decoder_area_mm2,
        encoder_power_mw: si.encoder_power_mw,
        decoder_power_mw: si.decoder_power_mw,
        array_area_mm2: array_area,
        array_power_mw: array_power,
        dram_power_w_at_90: dram_w,
        overhead_fraction: array_power * 1e-3 / dram_w,
    }
}

/// Render the §VII-B numbers.
pub fn render() -> String {
    let s = summary();
    let mut out = String::from("\n== Area & power (65 nm, paper §VII-B) ==\n");
    out.push_str(&format!(
        "encoder: {:.3} mm2, {:.2} mW (paper: 0.020 mm2, 2.80 mW)\n",
        s.encoder_area_mm2, s.encoder_power_mw
    ));
    out.push_str(&format!(
        "decoder: {:.3} mm2, {:.2} mW (paper: 0.017 mm2, 2.65 mW)\n",
        s.decoder_area_mm2, s.decoder_power_mw
    ));
    out.push_str(&format!(
        "64-engine array: {:.2} mm2, {:.1} mW (paper: 1.14 mm2, 179.2 mW)\n",
        s.array_area_mm2, s.array_power_mw
    ));
    out.push_str(&format!(
        "DRAM power @90% util: {:.2} W -> engine overhead {:.1}% (paper: 4.7%)\n",
        s.dram_power_w_at_90,
        s.overhead_fraction * 100.0
    ));
    out.push_str("\nper-engine component breakdown (analytic):\n");
    for (name, frac) in EngineSilicon::paper_65nm().component_breakdown() {
        out.push_str(&format!("  {name:<44} {:.0}%\n", frac * 100.0));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn array_matches_paper_numbers() {
        let s = summary();
        assert!((s.array_area_mm2 / 1.14 - 1.0).abs() < 0.06, "{}", s.array_area_mm2);
        assert!((s.array_power_mw / 179.2 - 1.0).abs() < 0.06, "{}", s.array_power_mw);
    }

    #[test]
    fn overhead_fraction_near_paper() {
        let s = summary();
        // Paper: 4.7%. Our DRAM model is independent, so accept 2–10%.
        assert!(
            (0.02..0.10).contains(&s.overhead_fraction),
            "overhead {}",
            s.overhead_fraction
        );
    }
}
