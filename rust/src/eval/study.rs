//! The shared compression study: per-model, per-layer footprints under
//! every scheme, computed once and reused by Figs 5–8.

use crate::apack::container::META_BYTES;
use crate::apack::encoder::ApackEncoder;
use crate::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::apack::Histogram;
use crate::baselines::{rle_compressed_bits, rlez_compressed_bits, ss_compressed_bits, ShapeShifterConfig};
use crate::models::trace::ModelTrace;
use crate::models::zoo::{all_models, ModelConfig};

use super::{EVAL_SEED, PROFILE_SAMPLES, SAMPLE_CAP};

/// A compression scheme in the study.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    Baseline,
    Rle,
    Rlez,
    ShapeShifter,
    Apack,
}

impl Scheme {
    /// The Fig 5 legend order.
    pub const ALL: [Scheme; 5] =
        [Scheme::Baseline, Scheme::Rle, Scheme::Rlez, Scheme::ShapeShifter, Scheme::Apack];

    pub fn label(&self) -> &'static str {
        match self {
            Scheme::Baseline => "Baseline",
            Scheme::Rle => "RLE",
            Scheme::Rlez => "RLEZ",
            Scheme::ShapeShifter => "ShapeShifter",
            Scheme::Apack => "APack",
        }
    }
}

/// Per-layer compression outcome: normalized bits/value per tensor kind.
#[derive(Debug, Clone, Copy)]
pub struct LayerCompression {
    /// Compressed weight bits / raw weight bits (1.0 = no gain).
    pub weights_norm: f64,
    /// Compressed activation bits / raw bits (1.0 when not studied).
    pub acts_norm: f64,
}

/// Per-model aggregate for one scheme.
#[derive(Debug, Clone)]
pub struct ModelCompression {
    pub model: String,
    pub scheme: Scheme,
    pub per_layer: Vec<LayerCompression>,
    /// Traffic-weighted normalized weight footprint (Fig 5b bar).
    pub weights_norm: f64,
    /// Traffic-weighted normalized activation footprint (Fig 5a bar), NaN
    /// if activations are not studied for this model.
    pub acts_norm: f64,
}

impl ModelCompression {
    /// Compression ratio (raw/compressed) for weights.
    pub fn weights_ratio(&self) -> f64 {
        1.0 / self.weights_norm
    }

    /// Compression ratio for activations.
    pub fn acts_ratio(&self) -> f64 {
        1.0 / self.acts_norm
    }
}

/// Footprint in bits of one sampled tensor under one scheme, **scaled to
/// the full tensor size**. `profile` is the profiling histogram used for
/// APack's table (activations use pooled samples; weights use the tensor
/// itself, as in the paper).
fn scheme_bits(
    scheme: Scheme,
    bits: u32,
    sample: &[u32],
    full_elems: u64,
    profile: &Histogram,
    kind: TensorKind,
) -> f64 {
    if sample.is_empty() || full_elems == 0 {
        return 0.0;
    }
    let scale = full_elems as f64 / sample.len() as f64;
    let raw_per_tensor = |stream_bits: f64| stream_bits * scale;
    match scheme {
        Scheme::Baseline => (full_elems * bits as u64) as f64,
        Scheme::Rle => raw_per_tensor(rle_compressed_bits(sample, bits) as f64),
        Scheme::Rlez => raw_per_tensor(rlez_compressed_bits(sample, bits) as f64),
        Scheme::ShapeShifter => {
            raw_per_tensor(ss_compressed_bits(sample, &ShapeShifterConfig::for_bits(bits)) as f64)
        }
        Scheme::Apack => {
            let table = match generate_table(profile, kind, &TableGenConfig::for_bits(bits)) {
                Ok(t) => t,
                Err(_) => return (full_elems * bits as u64) as f64,
            };
            match ApackEncoder::encode_all(&table, sample) {
                Ok((_, sym_bits, _, ofs_bits)) => {
                    raw_per_tensor((sym_bits + ofs_bits) as f64) + (META_BYTES * 8) as f64
                }
                // A profiled table can miss a fresh value only if
                // count-stealing was skipped (weights); fall back to raw.
                Err(_) => (full_elems * bits as u64) as f64,
            }
        }
    }
}

/// The full study over the zoo.
#[derive(Debug, Clone)]
pub struct CompressionStudy {
    pub results: Vec<ModelCompression>,
}

impl CompressionStudy {
    /// Run the study over `models` (default: the whole zoo) × `schemes`.
    pub fn run(models: &[ModelConfig], schemes: &[Scheme]) -> Self {
        let results: Vec<ModelCompression> = crate::util::par_map(models, |cfg| {
            let trace = ModelTrace::synthesize(cfg, SAMPLE_CAP, PROFILE_SAMPLES, EVAL_SEED);
            schemes
                .iter()
                .map(|&scheme| Self::study_model(cfg, &trace, scheme))
                .collect::<Vec<ModelCompression>>()
        })
        .into_iter()
        .flatten()
        .collect();
        Self { results }
    }

    /// Default full study (all 24 models × all 5 schemes).
    pub fn full() -> Self {
        Self::run(&all_models(), &Scheme::ALL)
    }

    fn study_model(cfg: &ModelConfig, trace: &ModelTrace, scheme: Scheme) -> ModelCompression {
        let mut per_layer = Vec::with_capacity(trace.layers.len());
        let mut w_comp = 0.0;
        let mut w_raw = 0.0;
        let mut a_comp = 0.0;
        let mut a_raw = 0.0;
        for l in &trace.layers {
            let bits = l.bits;
            // Weights: table profiled from the tensor itself (§VI — a
            // single pass suffices since weights are static).
            let w_hist = Histogram::from_values(bits, &l.weights);
            let wc =
                scheme_bits(scheme, bits, &l.weights, l.weight_elems, &w_hist, TensorKind::Weights);
            let wr = (l.weight_elems * bits as u64) as f64;
            // Activations: table profiled from pooled samples, applied to
            // the fresh tensor.
            let (ac, ar) = if l.activations.is_empty() {
                (0.0, 0.0)
            } else {
                let a_hist = Histogram::from_values(bits, &l.act_profile_samples);
                (
                    scheme_bits(
                        scheme,
                        bits,
                        &l.activations,
                        l.act_elems,
                        &a_hist,
                        TensorKind::Activations,
                    ),
                    (l.act_elems * bits as u64) as f64,
                )
            };
            per_layer.push(LayerCompression {
                weights_norm: if wr > 0.0 { (wc / wr).max(1e-6) } else { 1.0 },
                acts_norm: if ar > 0.0 { (ac / ar).max(1e-6) } else { 1.0 },
            });
            w_comp += wc;
            w_raw += wr;
            a_comp += ac;
            a_raw += ar;
        }
        ModelCompression {
            model: cfg.name.to_string(),
            scheme,
            per_layer,
            weights_norm: if w_raw > 0.0 { w_comp / w_raw } else { 1.0 },
            acts_norm: if a_raw > 0.0 { a_comp / a_raw } else { f64::NAN },
        }
    }

    /// Result for a (model, scheme) pair.
    pub fn get(&self, model: &str, scheme: Scheme) -> Option<&ModelCompression> {
        self.results.iter().find(|r| r.model == model && r.scheme == scheme)
    }

    /// Geometric-mean normalized traffic across models for a scheme.
    pub fn mean_weights_norm(&self, scheme: Scheme) -> f64 {
        let vals: Vec<f64> = self
            .results
            .iter()
            .filter(|r| r.scheme == scheme)
            .map(|r| r.weights_norm)
            .collect();
        geomean(&vals)
    }

    /// Geometric-mean normalized activation traffic (studied models only).
    pub fn mean_acts_norm(&self, scheme: Scheme) -> f64 {
        let vals: Vec<f64> = self
            .results
            .iter()
            .filter(|r| r.scheme == scheme && !r.acts_norm.is_nan())
            .map(|r| r.acts_norm)
            .collect();
        geomean(&vals)
    }
}

/// Geometric mean.
pub fn geomean(vals: &[f64]) -> f64 {
    if vals.is_empty() {
        return f64::NAN;
    }
    (vals.iter().map(|v| v.ln()).sum::<f64>() / vals.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    fn mini_study() -> CompressionStudy {
        let models = vec![
            model_by_name("resnet18").unwrap(),
            model_by_name("alexnet_eyeriss").unwrap(),
            model_by_name("resnet101").unwrap(),
        ];
        CompressionStudy::run(&models, &Scheme::ALL)
    }

    #[test]
    fn apack_always_reduces_traffic() {
        // The paper's robustness claim: APack never increases traffic.
        let s = mini_study();
        for r in s.results.iter().filter(|r| r.scheme == Scheme::Apack) {
            assert!(r.weights_norm < 1.0, "{}: weights {}", r.model, r.weights_norm);
            if !r.acts_norm.is_nan() {
                assert!(r.acts_norm < 1.0, "{}: acts {}", r.model, r.acts_norm);
            }
        }
    }

    #[test]
    fn apack_beats_all_baselines() {
        let s = mini_study();
        for model in ["resnet18", "alexnet_eyeriss", "resnet101"] {
            let apack = s.get(model, Scheme::Apack).unwrap().weights_norm;
            for other in [Scheme::Rle, Scheme::Rlez, Scheme::ShapeShifter] {
                let o = s.get(model, other).unwrap().weights_norm;
                assert!(
                    apack <= o + 1e-9,
                    "{model}: APack {apack:.3} vs {other:?} {o:.3}"
                );
            }
        }
    }

    #[test]
    fn rle_expands_unpruned_weights() {
        // Paper: "RLE and RLEZ result in increasing traffic for weights" on
        // Torchvision models.
        let s = mini_study();
        let r = s.get("resnet18", Scheme::Rle).unwrap();
        assert!(r.weights_norm > 1.0, "{}", r.weights_norm);
    }

    #[test]
    fn pruned_models_compress_most() {
        let s = mini_study();
        let pruned = s.get("alexnet_eyeriss", Scheme::Apack).unwrap().weights_norm;
        let tv = s.get("resnet18", Scheme::Apack).unwrap().weights_norm;
        assert!(pruned < tv, "pruned {pruned} vs torchvision {tv}");
        assert!(pruned < 0.35, "pruned weights norm {pruned}");
    }

    #[test]
    fn baseline_norm_is_one() {
        let s = mini_study();
        for r in s.results.iter().filter(|r| r.scheme == Scheme::Baseline) {
            assert!((r.weights_norm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }
}
