//! Codec hot-path measurement harness: single-stream and 64-substream
//! encode/decode throughput across every [`ResolveMode`] and both decode
//! granularities (per-value reference vs. block `decode_into`), plus the
//! store chunk-body paths — v1 single-stream bodies against v2
//! interleaved lane bodies over the [`LANE_SWEEP`] (scalar SoA, SIMD
//! lane-kernel, and threaded decoders) — with machine-readable JSON
//! output so decode throughput is a tracked, regression-guarded number
//! PR over PR (ISSUE 4, ISSUE 7, ISSUE 9; DESIGN.md §8, §11, §13).
//!
//! Shared by `benches/codec_hot_path.rs` (release-build numbers, uploaded
//! as a CI artifact) and the tier-1 `hot_path_report` integration test
//! (bit-exactness gate + JSON emission on every `cargo test` run, labeled
//! with the build profile so debug numbers are never mistaken for release
//! throughput). Every decode measurement is checked bit-exact against the
//! input tensor — the fast path cannot silently diverge while getting
//! faster.

use std::collections::BTreeMap;
use std::path::Path;

use crate::apack::bitstream::BitReader;
use crate::apack::container::{encode_body, BodyView};
use crate::apack::decoder::{ApackDecoder, ResolveMode};
use crate::apack::encoder::ApackEncoder;
use crate::apack::lanes::{encode_body_v2, BodyV2View};
use crate::apack::simd::DecodeKernel;
use crate::apack::tablegen::{table_for_tensor, TensorKind};
use crate::coordinator::{Coordinator, PartitionPolicy};
use crate::models::distributions::ValueProfile;
use crate::obs::rates;
use crate::util::bench::Bench;
use crate::util::json::Json;

/// The canonical JSON artifact name (repo root / CI artifact).
pub const REPORT_FILE: &str = "BENCH_codec_hot_path.json";

/// Lane counts swept for the chunk-body v2 decode measurements
/// (EXPERIMENTS.md lane-count sweep).
pub const LANE_SWEEP: [u8; 6] = [1, 4, 8, 16, 32, 64];

/// Harness configuration.
pub struct HotPathConfig {
    /// Workload size (the reference workload is 4M ReLU-activation values).
    pub n_values: usize,
    /// Substream count for the coordinator measurements.
    pub substreams: u32,
    pub warmup: usize,
    pub iters: usize,
}

impl HotPathConfig {
    /// The full reference configuration (4M values, 64 substreams).
    pub fn full() -> Self {
        Self { n_values: 4_000_000, substreams: 64, warmup: 2, iters: 10 }
    }

    /// CI configuration: same workload, fewer iterations.
    pub fn quick() -> Self {
        Self { iters: 5, warmup: 1, ..Self::full() }
    }

    /// Tier-1 test configuration: small enough for a debug build.
    pub fn tiny() -> Self {
        Self { n_values: 200_000, substreams: 16, warmup: 1, iters: 2 }
    }
}

/// One measured configuration.
pub struct HotPathEntry {
    /// e.g. `decode/block/Lut` or `coordinator/decode/64-substream`.
    pub name: String,
    pub median_ns: u64,
    pub values_per_s: f64,
    /// Throughput in GB/s of raw model values (one byte per 8-bit value,
    /// matching the paper's traffic accounting).
    pub gb_per_s: f64,
}

/// The full harness result.
pub struct HotPathReport {
    pub n_values: usize,
    pub substreams: u32,
    /// `release` or `debug` — debug numbers are real but not comparable.
    pub profile: &'static str,
    pub entries: Vec<HotPathEntry>,
    /// The tentpole ratio: block `decode_into` in the default (`Lut`) mode
    /// over the pre-existing per-value `RowScan` baseline, single-stream.
    pub speedup_block_lut_vs_per_value_rowscan: f64,
    /// Chunk-body v2 ratio: threaded 16-lane body decode over the v1
    /// single-stream body decode (the ISSUE 7 CI gate — lane fan-out must
    /// beat the sequential store-body path it replaces).
    pub speedup_body_v2_threaded16_vs_v1: f64,
    /// SIMD kernel ratio: 16-lane v2 body decode with the lane-parallel
    /// SIMD kernel over the same body through the scalar SoA loop (the
    /// ISSUE 9 CI gate on x86_64 — vectorized lane stepping must beat
    /// the scalar loop it specializes).
    pub speedup_body_v2_simd16_vs_soa16: f64,
}

impl HotPathReport {
    /// Entry lookup by name.
    pub fn entry(&self, name: &str) -> Option<&HotPathEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Serialize to the BENCH JSON schema.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("codec_hot_path".to_string()));
        root.insert(
            "workload".to_string(),
            Json::Str("relu_activation_8b_seed42".to_string()),
        );
        root.insert("n_values".to_string(), Json::Num(self.n_values as f64));
        root.insert("substreams".to_string(), Json::Num(self.substreams as f64));
        root.insert("profile".to_string(), Json::Str(self.profile.to_string()));
        root.insert(
            "speedup_block_lut_vs_per_value_rowscan".to_string(),
            Json::Num(self.speedup_block_lut_vs_per_value_rowscan),
        );
        root.insert(
            "speedup_body_v2_threaded16_vs_v1".to_string(),
            Json::Num(self.speedup_body_v2_threaded16_vs_v1),
        );
        root.insert(
            "speedup_body_v2_simd16_vs_soa16".to_string(),
            Json::Num(self.speedup_body_v2_simd16_vs_soa16),
        );
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.name.clone()));
                m.insert("median_ns".to_string(), Json::Num(e.median_ns as f64));
                m.insert("values_per_s".to_string(), Json::Num(e.values_per_s));
                m.insert("gb_per_s".to_string(), Json::Num(e.gb_per_s));
                Json::Obj(m)
            })
            .collect();
        root.insert("results".to_string(), Json::Arr(entries));
        Json::Obj(root)
    }

    /// Write the JSON artifact (the bench and the tier-1 test both write
    /// [`REPORT_FILE`] at the package root).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
    }

    /// Human-readable per-entry lines (the bench's stdout report).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for e in &self.entries {
            s.push_str(&format!(
                "{:<44} {:>12.1} Mvalues/s  {:>8.3} GB/s  ({} ns median)\n",
                e.name,
                e.values_per_s / 1e6,
                e.gb_per_s,
                e.median_ns
            ));
        }
        s.push_str(&format!(
            "block Lut vs per-value RowScan (single-stream): {:.2}x\n",
            self.speedup_block_lut_vs_per_value_rowscan
        ));
        s.push_str(&format!(
            "body v2 threaded 16-lane vs v1 single-stream body: {:.2}x\n",
            self.speedup_body_v2_threaded16_vs_v1
        ));
        s.push_str(&format!(
            "body v2 SIMD 16-lane vs scalar SoA 16-lane: {:.2}x ({} kernel)\n",
            self.speedup_body_v2_simd16_vs_soa16,
            DecodeKernel::Simd.active_label()
        ));
        s
    }
}

fn entry(name: &str, median_ns: u64, n: usize) -> HotPathEntry {
    HotPathEntry {
        name: name.to_string(),
        median_ns,
        values_per_s: rates::per_sec(n as f64, median_ns),
        gb_per_s: rates::gb_per_s(n as f64, median_ns),
    }
}

/// Run the harness: measure every configuration, assert every decode
/// bit-exact against the input tensor (panics on divergence — this is the
/// regression gate CI leans on), and return the report.
pub fn run(cfg: &HotPathConfig) -> HotPathReport {
    let n = cfg.n_values;
    let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
        .sample(8, n, 42);
    let table = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
    let bench = Bench { warmup: cfg.warmup, iters: cfg.iters };
    let mut entries = Vec::new();

    // Single-stream encode.
    let s = bench.run("encode/single-stream", || {
        ApackEncoder::encode_all(&table, &values).unwrap()
    });
    entries.push(entry("encode/single-stream", s.median.as_nanos() as u64, n));

    let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&table, &values).unwrap();

    // Single-stream decode: per-value reference and block fast path, every
    // resolver. Bit-exactness is asserted once per configuration BEFORE
    // timing (so the gate cannot be optimized out of the measurement and
    // the compare cost never skews the throughput numbers).
    let decode_per_value = |mode: ResolveMode| {
        let mut dec =
            ApackDecoder::new(&table, BitReader::new(&sym, sb)).unwrap().with_mode(mode);
        let mut ofs_r = BitReader::new(&ofs, ob);
        let mut out = vec![0u32; n];
        for slot in out.iter_mut() {
            *slot = dec.decode_value(&mut ofs_r).unwrap();
        }
        out
    };
    let decode_block = |mode: ResolveMode| {
        let mut dec =
            ApackDecoder::new(&table, BitReader::new(&sym, sb)).unwrap().with_mode(mode);
        let mut ofs_r = BitReader::new(&ofs, ob);
        let mut out = vec![0u32; n];
        dec.decode_into(&mut out, &mut ofs_r).unwrap();
        out
    };
    for mode in ResolveMode::ALL {
        assert_eq!(decode_per_value(mode), values, "per-value {mode:?} diverged");
        assert_eq!(
            decode_block(mode),
            values,
            "block {mode:?} diverged from the per-value reference"
        );

        let name = format!("decode/per-value/{mode:?}");
        let s = bench.run(&name, || decode_per_value(mode));
        entries.push(entry(&name, s.median.as_nanos() as u64, n));

        let name = format!("decode/block/{mode:?}");
        let s = bench.run(&name, || decode_block(mode));
        entries.push(entry(&name, s.median.as_nanos() as u64, n));
    }

    // Parallel coordinator (block decode through Container::decode_into,
    // shards landing in disjoint sub-slices of one output buffer).
    let mut coord = Coordinator::new(PartitionPolicy {
        substreams: cfg.substreams,
        ..PartitionPolicy::default()
    });
    let name = format!("coordinator/encode/{}-substream", cfg.substreams);
    let s = bench.run(&name, || coord.compress_with_table(table.clone(), &values).unwrap());
    entries.push(entry(&name, s.median.as_nanos() as u64, n));

    let sc = coord.compress_with_table(table.clone(), &values).unwrap();
    assert_eq!(coord.decompress(&sc).unwrap(), values, "coordinator decode diverged");
    let name = format!("coordinator/decode/{}-substream", cfg.substreams);
    let s = bench.run(&name, || coord.decompress(&sc).unwrap());
    entries.push(entry(&name, s.median.as_nanos() as u64, n));

    // Store chunk bodies: the v1 single-stream framing every pre-v2 store
    // used vs. the v2 interleaved lane bodies across the lane sweep, both
    // the single-thread struct-of-arrays decoder and the threaded
    // lane-per-sub-slice decoder. Bit-exactness asserted before timing,
    // as above.
    let body_v1 = encode_body(&table, &values).unwrap();
    let decode_v1 = || {
        let mut out = vec![0u32; n];
        BodyView::parse(&body_v1).unwrap().decode_into(&table, &mut out).unwrap();
        out
    };
    assert_eq!(decode_v1(), values, "store-body v1 decode diverged");
    let s = bench.run("store-body/decode/v1-block", decode_v1);
    entries.push(entry("store-body/decode/v1-block", s.median.as_nanos() as u64, n));

    for lanes in LANE_SWEEP {
        let body = encode_body_v2(&table, &values, lanes).unwrap();
        // `v2-soa` is pinned to the scalar kernel so it stays the fixed
        // baseline the SIMD gate divides against, independent of the
        // `APACK_DECODE_KERNEL` environment the harness runs under.
        let decode_soa = || {
            let mut out = vec![0u32; n];
            BodyV2View::parse(&body)
                .unwrap()
                .decode_into_with(&table, &mut out, DecodeKernel::Scalar)
                .unwrap();
            out
        };
        let decode_simd = || {
            let mut out = vec![0u32; n];
            BodyV2View::parse(&body)
                .unwrap()
                .decode_into_with(&table, &mut out, DecodeKernel::Simd)
                .unwrap();
            out
        };
        let decode_threaded = || {
            let mut out = vec![0u32; n];
            BodyV2View::parse(&body)
                .unwrap()
                .decode_into_threaded(&table, &mut out, 0)
                .unwrap();
            out
        };
        assert_eq!(decode_soa(), values, "store-body v2 SoA {lanes}-lane diverged");
        assert_eq!(
            decode_simd(),
            values,
            "store-body v2 SIMD {lanes}-lane diverged from the scalar loop"
        );
        assert_eq!(decode_threaded(), values, "store-body v2 threaded {lanes}-lane diverged");

        let name = format!("store-body/decode/v2-soa/{lanes}-lane");
        let s = bench.run(&name, decode_soa);
        entries.push(entry(&name, s.median.as_nanos() as u64, n));

        let name = format!("store-body/decode/v2-simd/{lanes}-lane");
        let s = bench.run(&name, decode_simd);
        entries.push(entry(&name, s.median.as_nanos() as u64, n));

        let name = format!("store-body/decode/v2-threaded/{lanes}-lane");
        let s = bench.run(&name, decode_threaded);
        entries.push(entry(&name, s.median.as_nanos() as u64, n));
    }

    let baseline = entries
        .iter()
        .find(|e| e.name == "decode/per-value/RowScan")
        .map(|e| e.values_per_s)
        .unwrap_or(f64::INFINITY);
    let fast = entries
        .iter()
        .find(|e| e.name == "decode/block/Lut")
        .map(|e| e.values_per_s)
        .unwrap_or(0.0);
    let body_v1_rate = entries
        .iter()
        .find(|e| e.name == "store-body/decode/v1-block")
        .map(|e| e.values_per_s)
        .unwrap_or(f64::INFINITY);
    let body_v2_rate = entries
        .iter()
        .find(|e| e.name == "store-body/decode/v2-threaded/16-lane")
        .map(|e| e.values_per_s)
        .unwrap_or(0.0);
    let soa16_rate = entries
        .iter()
        .find(|e| e.name == "store-body/decode/v2-soa/16-lane")
        .map(|e| e.values_per_s)
        .unwrap_or(f64::INFINITY);
    let simd16_rate = entries
        .iter()
        .find(|e| e.name == "store-body/decode/v2-simd/16-lane")
        .map(|e| e.values_per_s)
        .unwrap_or(0.0);
    HotPathReport {
        n_values: n,
        substreams: cfg.substreams,
        profile: if cfg!(debug_assertions) { "debug" } else { "release" },
        entries,
        speedup_block_lut_vs_per_value_rowscan: fast / baseline,
        speedup_body_v2_threaded16_vs_v1: body_v2_rate / body_v1_rate,
        speedup_body_v2_simd16_vs_soa16: simd16_rate / soa16_rate,
    }
}
