//! Ingest (write-path) measurement harness: tablegen, encode and
//! end-to-end `pack_model_zoo` throughput, with machine-readable JSON
//! output so ingest speed is a tracked, regression-guarded number PR over
//! PR — the write-side mirror of [`super::hot_path`] (ISSUE 5; DESIGN.md
//! §9).
//!
//! Shared by `benches/store_pack.rs` (release-build numbers, uploaded as a
//! CI artifact) and the tier-1 `ingest_report` integration test (JSON
//! emission on every `cargo test` run, profile-labeled). Correctness is
//! asserted **before** anything is timed:
//!
//! - the incremental tablegen search must produce byte-identical tables
//!   to the seed (full-recompute) search,
//! - the block encoder must emit bit-identical streams to the per-value
//!   reference, and those streams must round-trip decode to the input,
//! - the pipelined packer must write byte-identical store files to the
//!   serial packer, and the packed store must pass `verify` (CRC + full
//!   decode of every chunk).

use std::collections::BTreeMap;
use std::path::Path;

use crate::apack::bitstream::{BitReader, BitWriter};
use crate::apack::decoder::ApackDecoder;
use crate::apack::encoder::ApackEncoder;
use crate::apack::tablegen::{
    generate_table, generate_table_seed, TableGenConfig, TensorKind,
};
use crate::apack::{Histogram, SymbolTable};
use crate::coordinator::PartitionPolicy;
use crate::models::distributions::ValueProfile;
use crate::models::zoo::{model_by_name, ModelConfig};
use crate::obs::rates;
use crate::store::{pack_model_zoo_with, PackOptions, StoreReader};
use crate::util::bench::Bench;
use crate::util::json::Json;

/// The canonical JSON artifact name (repo root / CI artifact).
pub const REPORT_FILE: &str = "BENCH_store_pack.json";

/// Zoo models used for the end-to-end pack measurement, smallest first so
/// `pack_models` scales the workload monotonically.
const PACK_MODELS: [&str; 6] =
    ["ncf", "bilstm", "alexnet_eyeriss", "mobilenet_v1", "resnet18", "googlenet"];

/// Harness configuration.
pub struct IngestConfig {
    /// Values per codec measurement tensor.
    pub n_values: usize,
    pub warmup: usize,
    pub iters: usize,
    /// Include the 16-bit (coarse-stride search) cases — on for the
    /// release bench, off for the debug tier-1 run where the seed search
    /// baseline is slow.
    pub wide: bool,
    /// Zoo models in the end-to-end pack measurement.
    pub pack_models: usize,
    /// `sample_cap` for the pack measurement.
    pub pack_sample_cap: usize,
}

impl IngestConfig {
    /// The full reference configuration.
    pub fn full() -> Self {
        Self {
            n_values: 2_000_000,
            warmup: 2,
            iters: 10,
            wide: true,
            pack_models: 6,
            pack_sample_cap: 16_384,
        }
    }

    /// CI configuration: same workloads, fewer iterations.
    pub fn quick() -> Self {
        Self { warmup: 1, iters: 3, pack_models: 4, pack_sample_cap: 8192, ..Self::full() }
    }

    /// Tier-1 test configuration: small enough for a debug build.
    pub fn tiny() -> Self {
        Self {
            n_values: 100_000,
            warmup: 1,
            iters: 2,
            wide: false,
            pack_models: 2,
            pack_sample_cap: 1024,
        }
    }
}

/// One measured configuration.
pub struct IngestEntry {
    /// e.g. `encode/block/8b-relu` or `pack/pipelined`.
    pub name: String,
    pub median_ns: u64,
    pub values_per_s: f64,
    /// Raw-value throughput in MB/s (`bits/8` bytes per value).
    pub mb_per_s: f64,
}

/// The full harness result.
pub struct IngestReport {
    pub n_values: usize,
    /// `release` or `debug` — debug numbers are real but not comparable.
    pub profile: &'static str,
    pub entries: Vec<IngestEntry>,
    /// Block `encode_into` over the per-value `encode_value` loop
    /// (8-bit ReLU tensor, single stream) — the tentpole encode ratio.
    pub speedup_block_vs_per_value_encode: f64,
    /// Incremental boundary search over the seed full-recompute search
    /// (8-bit ReLU histogram).
    pub speedup_incremental_vs_seed_tablegen: f64,
    /// Pipelined `pack_model_zoo` over the serial packer, same models,
    /// same run.
    pub speedup_pipelined_vs_serial_pack: f64,
}

impl IngestReport {
    /// Entry lookup by name.
    pub fn entry(&self, name: &str) -> Option<&IngestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Serialize to the BENCH JSON schema.
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("bench".to_string(), Json::Str("store_pack".to_string()));
        root.insert(
            "workload".to_string(),
            Json::Str("ingest_tablegen_encode_pack_seed42".to_string()),
        );
        root.insert("n_values".to_string(), Json::Num(self.n_values as f64));
        root.insert("profile".to_string(), Json::Str(self.profile.to_string()));
        root.insert(
            "speedup_block_vs_per_value_encode".to_string(),
            Json::Num(self.speedup_block_vs_per_value_encode),
        );
        root.insert(
            "speedup_incremental_vs_seed_tablegen".to_string(),
            Json::Num(self.speedup_incremental_vs_seed_tablegen),
        );
        root.insert(
            "speedup_pipelined_vs_serial_pack".to_string(),
            Json::Num(self.speedup_pipelined_vs_serial_pack),
        );
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(e.name.clone()));
                m.insert("median_ns".to_string(), Json::Num(e.median_ns as f64));
                m.insert("values_per_s".to_string(), Json::Num(e.values_per_s));
                m.insert("mb_per_s".to_string(), Json::Num(e.mb_per_s));
                Json::Obj(m)
            })
            .collect();
        root.insert("results".to_string(), Json::Arr(entries));
        Json::Obj(root)
    }

    /// Write the JSON artifact (the bench and the tier-1 test both write
    /// [`REPORT_FILE`] at the package root).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")
    }

    /// Human-readable per-entry lines (the bench's stdout report).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for e in &self.entries {
            s.push_str(&format!(
                "{:<40} {:>12.2} Mvalues/s  {:>9.1} MB/s  ({} ns median)\n",
                e.name,
                e.values_per_s / 1e6,
                e.mb_per_s,
                e.median_ns
            ));
        }
        s.push_str(&format!(
            "block vs per-value encode (8b relu):        {:.2}x\n\
             incremental vs seed tablegen (8b relu):     {:.2}x\n\
             pipelined vs serial pack_model_zoo:         {:.2}x\n",
            self.speedup_block_vs_per_value_encode,
            self.speedup_incremental_vs_seed_tablegen,
            self.speedup_pipelined_vs_serial_pack
        ));
        s
    }
}

fn entry(name: &str, median_ns: u64, n: usize, bits: u32) -> IngestEntry {
    IngestEntry {
        name: name.to_string(),
        median_ns,
        values_per_s: rates::per_sec(n as f64, median_ns),
        mb_per_s: rates::mb_per_s(n as f64 * (bits as f64 / 8.0), median_ns),
    }
}

/// Encode with the per-value reference loop (the pre-block baseline).
fn encode_per_value(table: &SymbolTable, values: &[u32]) -> (Vec<u8>, usize, Vec<u8>, usize) {
    let mut enc = ApackEncoder::new(table);
    let mut sym = BitWriter::with_capacity_bits(values.len() * 4);
    let mut ofs = BitWriter::with_capacity_bits(values.len() * 4);
    for &v in values {
        enc.encode_value(v, &mut sym, &mut ofs).unwrap();
    }
    enc.finish(&mut sym);
    let (sb, sbits) = sym.finish();
    let (ob, obits) = ofs.finish();
    (sb, sbits, ob, obits)
}

/// Run the harness: assert every equivalence, then measure tablegen /
/// encode per bit-width and profile plus the end-to-end zoo pack, and
/// return the report.
pub fn run(cfg: &IngestConfig) -> IngestReport {
    let bench = Bench { warmup: cfg.warmup, iters: cfg.iters };
    let mut entries = Vec::new();

    // (tag, bits, profile) cases — the 8b ReLU case carries the headline
    // speedups.
    let mut cases: Vec<(&str, u32, ValueProfile)> = vec![
        (
            "4b-relu",
            4,
            ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 },
        ),
        (
            "8b-relu",
            8,
            ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 },
        ),
        ("8b-weights", 8, ValueProfile::TwoSidedGeometric { q: 0.9, noise_floor: 0.01 }),
    ];
    if cfg.wide {
        cases.push(("16b-sparse", 16, ValueProfile::Sparse { sparsity: 0.6, q: 0.85 }));
    }

    let mut tablegen_seed_vps = 0.0;
    let mut tablegen_inc_vps = 0.0;
    let mut encode_pv_vps = 0.0;
    let mut encode_blk_vps = 0.0;

    for (tag, bits, profile) in cases {
        let values = profile.sample(bits, cfg.n_values, 42);
        let n = values.len();
        let hist = Histogram::from_values(bits, &values);
        let tg_cfg = TableGenConfig::for_bits(bits);

        // Gate: incremental search == seed search, byte for byte.
        let table = generate_table(&hist, TensorKind::Activations, &tg_cfg).unwrap();
        let seed_table = generate_table_seed(&hist, TensorKind::Activations, &tg_cfg).unwrap();
        assert_eq!(
            table.to_bytes(),
            seed_table.to_bytes(),
            "{tag}: incremental tablegen diverged from the seed search"
        );

        let name = format!("tablegen/seed/{tag}");
        let s = bench.run(&name, || {
            generate_table_seed(&hist, TensorKind::Activations, &tg_cfg).unwrap()
        });
        let e = entry(&name, s.median.as_nanos() as u64, n, bits);
        if tag == "8b-relu" {
            tablegen_seed_vps = e.values_per_s;
        }
        entries.push(e);

        let name = format!("tablegen/incremental/{tag}");
        let s = bench
            .run(&name, || generate_table(&hist, TensorKind::Activations, &tg_cfg).unwrap());
        let e = entry(&name, s.median.as_nanos() as u64, n, bits);
        if tag == "8b-relu" {
            tablegen_inc_vps = e.values_per_s;
        }
        entries.push(e);

        // Gate: block encoder bit-identical to the per-value reference,
        // and the stream round-trips.
        let reference = encode_per_value(&table, &values);
        let block = ApackEncoder::encode_all(&table, &values).unwrap();
        assert_eq!(block, reference, "{tag}: block encoder diverged from per-value");
        let (sym, sb, ofs, ob) = block;
        let mut ofs_r = BitReader::new(&ofs, ob);
        let decoded =
            ApackDecoder::decode_all(&table, BitReader::new(&sym, sb), &mut ofs_r, n).unwrap();
        assert_eq!(decoded, values, "{tag}: encoded stream failed to round-trip");

        let name = format!("encode/per-value/{tag}");
        let s = bench.run(&name, || encode_per_value(&table, &values));
        let e = entry(&name, s.median.as_nanos() as u64, n, bits);
        if tag == "8b-relu" {
            encode_pv_vps = e.values_per_s;
        }
        entries.push(e);

        let name = format!("encode/block/{tag}");
        let s = bench.run(&name, || ApackEncoder::encode_all(&table, &values).unwrap());
        let e = entry(&name, s.median.as_nanos() as u64, n, bits);
        if tag == "8b-relu" {
            encode_blk_vps = e.values_per_s;
        }
        entries.push(e);
    }

    // End-to-end zoo pack: serial vs pipelined, same models, same run.
    let models: Vec<ModelConfig> = PACK_MODELS
        .iter()
        .take(cfg.pack_models.clamp(1, PACK_MODELS.len()))
        .map(|n| model_by_name(n).expect("pack model in zoo"))
        .collect();
    let policy = PartitionPolicy { substreams: 16, min_per_stream: 512 };
    let dir = std::env::temp_dir();
    let serial_path = dir.join(format!("apack_ingest_serial_{}.apackstore", std::process::id()));
    let piped_path = dir.join(format!("apack_ingest_piped_{}.apackstore", std::process::id()));
    let serial_opts = PackOptions { pipelined: false, ..PackOptions::default() };
    let piped_opts = PackOptions::default();

    // Gate: identical bytes, and the packed store verifies (CRC + decode).
    let summary =
        pack_model_zoo_with(&serial_path, &models, cfg.pack_sample_cap, policy, &serial_opts)
            .unwrap();
    pack_model_zoo_with(&piped_path, &models, cfg.pack_sample_cap, policy, &piped_opts).unwrap();
    assert_eq!(
        std::fs::read(&serial_path).unwrap(),
        std::fs::read(&piped_path).unwrap(),
        "pipelined pack bytes diverged from serial"
    );
    StoreReader::open(&piped_path).unwrap().verify().unwrap();
    let pack_values = summary.pack.values as usize;
    let pack_bits = (summary.raw_bits / summary.pack.values.max(1)) as u32;

    let s = bench.run("pack/serial", || {
        pack_model_zoo_with(&serial_path, &models, cfg.pack_sample_cap, policy, &serial_opts)
            .unwrap()
    });
    let serial_entry = entry("pack/serial", s.median.as_nanos() as u64, pack_values, pack_bits);
    let s = bench.run("pack/pipelined", || {
        pack_model_zoo_with(&piped_path, &models, cfg.pack_sample_cap, policy, &piped_opts)
            .unwrap()
    });
    let piped_entry =
        entry("pack/pipelined", s.median.as_nanos() as u64, pack_values, pack_bits);
    let pack_speedup = piped_entry.values_per_s / serial_entry.values_per_s.max(1e-12);
    entries.push(serial_entry);
    entries.push(piped_entry);
    std::fs::remove_file(&serial_path).ok();
    std::fs::remove_file(&piped_path).ok();

    IngestReport {
        n_values: cfg.n_values,
        profile: if cfg!(debug_assertions) { "debug" } else { "release" },
        entries,
        speedup_block_vs_per_value_encode: encode_blk_vps / encode_pv_vps.max(1e-12),
        speedup_incremental_vs_seed_tablegen: tablegen_inc_vps / tablegen_seed_vps.max(1e-12),
        speedup_pipelined_vs_serial_pack: pack_speedup,
    }
}
