//! Fig 5: normalized off-chip traffic per model, activations (5a) and
//! weights (5b), for Baseline / RLE / RLEZ / ShapeShifter / APack.

use super::study::{CompressionStudy, Scheme};
use super::render_table;

/// Fig 5a rows: one per model with studied activations.
pub fn fig5a_rows(study: &CompressionStudy) -> Vec<Vec<String>> {
    let models: Vec<&str> = {
        let mut seen = Vec::new();
        for r in &study.results {
            if !seen.contains(&r.model.as_str()) && !r.acts_norm.is_nan() {
                seen.push(r.model.as_str());
            }
        }
        seen
    };
    models
        .iter()
        .map(|m| {
            let mut row = vec![m.to_string()];
            for s in Scheme::ALL {
                let v = study.get(m, s).map(|r| r.acts_norm).unwrap_or(f64::NAN);
                row.push(format!("{v:.3}"));
            }
            row
        })
        .collect()
}

/// Fig 5b rows: one per model (weights are studied for all).
pub fn fig5b_rows(study: &CompressionStudy) -> Vec<Vec<String>> {
    let models: Vec<&str> = {
        let mut seen = Vec::new();
        for r in &study.results {
            if !seen.contains(&r.model.as_str()) {
                seen.push(r.model.as_str());
            }
        }
        seen
    };
    models
        .iter()
        .map(|m| {
            let mut row = vec![m.to_string()];
            for s in Scheme::ALL {
                let v = study.get(m, s).map(|r| r.weights_norm).unwrap_or(f64::NAN);
                row.push(format!("{v:.3}"));
            }
            row
        })
        .collect()
}

/// Render both panels plus the summary row the paper highlights (§I:
/// weights → 60%, activations → 48% of baseline on average).
pub fn render(study: &CompressionStudy) -> String {
    let headers = ["model", "Baseline", "RLE", "RLEZ", "ShapeShifter", "APack"];
    let mut out = render_table(
        "Fig 5a: normalized off-chip traffic — ACTIVATIONS (lower is better)",
        &headers,
        &fig5a_rows(study),
    );
    out.push_str(&render_table(
        "Fig 5b: normalized off-chip traffic — WEIGHTS (lower is better)",
        &headers,
        &fig5b_rows(study),
    ));
    out.push_str("\n== Summary (paper §I: weights 60%, activations 48% on average) ==\n");
    for s in Scheme::ALL {
        out.push_str(&format!(
            "{:<13} weights mean {:.3}  (ratio {:.2}x)   activations mean {:.3}  (ratio {:.2}x)\n",
            s.label(),
            study.mean_weights_norm(s),
            1.0 / study.mean_weights_norm(s),
            study.mean_acts_norm(s),
            1.0 / study.mean_acts_norm(s),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    #[test]
    fn rows_have_all_schemes() {
        let models =
            vec![model_by_name("ncf").unwrap(), model_by_name("mobilenet_v1").unwrap()];
        let s = CompressionStudy::run(&models, &Scheme::ALL);
        let a = fig5a_rows(&s);
        let b = fig5b_rows(&s);
        // mobilenet_v1 (IntelAI) has no activation row; both have weights.
        assert_eq!(a.len(), 1);
        assert_eq!(b.len(), 2);
        assert_eq!(a[0].len(), 6);
        let text = render(&s);
        assert!(text.contains("APack"));
        assert!(text.contains("ncf"));
    }
}
