//! Fig 8: overall energy efficiency (baseline energy / scheme energy,
//! including on-chip compute + SRAM + DRAM + engine overhead). Paper
//! headline: APack 1.37×, ShapeShifter 1.23×.

use crate::models::zoo::ModelConfig;
use crate::simulator::accelerator::{AcceleratorConfig, AcceleratorSim, TrafficScaling};
use crate::simulator::energy::EnergyModel;
use crate::simulator::engine::EngineArrayConfig;

use super::fig7::perf_models;
use super::study::{geomean, CompressionStudy, Scheme};
use super::render_table;

/// Total inference energy (J) for a model under a scheme.
pub fn total_energy(study: &CompressionStudy, cfg: &ModelConfig, scheme: Scheme) -> f64 {
    let sim = AcceleratorSim::new(AcceleratorConfig::paper());
    let mc = study.get(cfg.name, scheme).expect("model in study");
    let results = sim.simulate_model(cfg, &|i| {
        let lc = mc.per_layer[i];
        TrafficScaling { weights: lc.weights_norm, activations: lc.acts_norm }
    });
    let total_time = AcceleratorSim::total_time(&results);
    let engines = match scheme {
        Scheme::Baseline => None,
        _ => Some(EngineArrayConfig::paper_64()),
    };
    let em = EnergyModel::new(&sim, engines);
    em.inference_energy(&results, total_time).total_j()
}

/// Rows: model, SS efficiency, APack efficiency (baseline/scheme).
pub fn fig8_rows(study: &CompressionStudy) -> Vec<Vec<String>> {
    perf_models()
        .iter()
        .filter(|cfg| study.get(cfg.name, Scheme::Baseline).is_some())
        .map(|cfg| {
            let base = total_energy(study, cfg, Scheme::Baseline);
            let ss = base / total_energy(study, cfg, Scheme::ShapeShifter);
            let ap = base / total_energy(study, cfg, Scheme::Apack);
            vec![cfg.name.to_string(), format!("{ss:.3}"), format!("{ap:.3}")]
        })
        .collect()
}

/// Mean efficiencies `(shapeshifter, apack)`.
pub fn mean_efficiencies(study: &CompressionStudy) -> (f64, f64) {
    let rows = fig8_rows(study);
    let col = |i: usize| {
        geomean(&rows.iter().filter_map(|r| r[i].parse::<f64>().ok()).collect::<Vec<_>>())
    };
    (col(1), col(2))
}

/// Render Fig 8.
pub fn render(study: &CompressionStudy) -> String {
    let mut out = render_table(
        "Fig 8: overall energy efficiency vs baseline (higher is better)",
        &["model", "ShapeShifter", "APack"],
        &fig8_rows(study),
    );
    let (ss, ap) = mean_efficiencies(study);
    out.push_str(&format!(
        "geomean efficiency: ShapeShifter {ss:.3}x (paper 1.23x), APack {ap:.3}x (paper 1.37x)\n"
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    #[test]
    fn apack_boosts_efficiency_for_all_models() {
        // Paper: "APack boosts the energy efficiency over the baseline
        // accelerator for all the experimented models."
        let models = vec![
            model_by_name("alexnet_eyeriss").unwrap(),
            model_by_name("q8bert").unwrap(),
        ];
        let study = CompressionStudy::run(
            &models,
            &[Scheme::Baseline, Scheme::ShapeShifter, Scheme::Apack],
        );
        for cfg in &models {
            let base = total_energy(&study, cfg, Scheme::Baseline);
            let ap = total_energy(&study, cfg, Scheme::Apack);
            assert!(ap < base, "{}: {ap} !< {base}", cfg.name);
        }
    }
}
