//! Fig 6: off-chip memory energy normalized to the no-compression
//! baseline. Weights and input activations of each layer are read once
//! from off-chip (the paper's edge-inference assumption, §VII-B), outputs
//! written once; APack adds its engine power while data streams.

use crate::models::zoo::{all_models, ModelConfig};
use crate::simulator::accelerator::{AcceleratorConfig, AcceleratorSim, TrafficScaling};
use crate::simulator::dram::DramPowerModel;
use crate::simulator::engine::EngineArrayConfig;

use super::study::{CompressionStudy, Scheme};
use super::render_table;

/// Off-chip energy (J) for one model under one scheme's per-layer scaling.
pub fn offchip_energy(
    study: &CompressionStudy,
    cfg: &ModelConfig,
    scheme: Scheme,
    with_engines: bool,
) -> f64 {
    let sim = AcceleratorSim::new(AcceleratorConfig::paper());
    let mc = study.get(cfg.name, scheme).expect("model in study");
    // Per-layer scaling is deliberately NOT clamped at 1.0: a scheme that
    // *expands* traffic (RLE on unpruned weights, Fig 5b) must pay for it.
    let results = sim.simulate_model(cfg, &|i| {
        let lc = mc.per_layer.get(i).copied().unwrap_or(crate::eval::LayerCompression {
            weights_norm: 1.0,
            acts_norm: 1.0,
        });
        TrafficScaling { weights: lc.weights_norm, activations: lc.acts_norm }
    });
    let total_time = AcceleratorSim::total_time(&results);
    let read: u64 = results.iter().map(|r| r.dram_read_bytes).sum();
    let write: u64 = results.iter().map(|r| r.dram_write_bytes).sum();
    let dram = DramPowerModel::new(sim.cfg.dram);
    let mut e = dram.traffic_energy(read, write, total_time).total_j();
    if with_engines {
        let engines = EngineArrayConfig::paper_64();
        let mem_time: f64 = results.iter().map(|r| r.memory_s).sum();
        e += engines.total_power_mw() * 1e-3 * mem_time;
    }
    e
}

/// Rows: model, normalized off-chip energy for SS and APack (vs baseline).
pub fn fig6_rows(study: &CompressionStudy) -> Vec<Vec<String>> {
    all_models()
        .iter()
        .filter(|cfg| study.get(cfg.name, Scheme::Baseline).is_some())
        .map(|cfg| {
            let base = offchip_energy(study, cfg, Scheme::Baseline, false);
            let ss = offchip_energy(study, cfg, Scheme::ShapeShifter, true) / base;
            let ap = offchip_energy(study, cfg, Scheme::Apack, true) / base;
            vec![cfg.name.to_string(), format!("{ss:.3}"), format!("{ap:.3}")]
        })
        .collect()
}

/// Render Fig 6.
pub fn render(study: &CompressionStudy) -> String {
    let rows = fig6_rows(study);
    let mut out = render_table(
        "Fig 6: normalized off-chip energy (lower is better)",
        &["model", "ShapeShifter", "APack"],
        &rows,
    );
    let mean = |col: usize| {
        let vals: Vec<f64> =
            rows.iter().filter_map(|r| r[col].parse::<f64>().ok()).collect();
        super::study::geomean(&vals)
    };
    out.push_str(&format!(
        "geomean: ShapeShifter {:.3}, APack {:.3}\n",
        mean(1),
        mean(2)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    #[test]
    fn apack_saves_offchip_energy() {
        let models = vec![
            model_by_name("alexnet_eyeriss").unwrap(),
            model_by_name("ncf").unwrap(),
        ];
        let study = CompressionStudy::run(&models, &[Scheme::Baseline, Scheme::Apack]);
        for cfg in &models {
            let base = offchip_energy(&study, cfg, Scheme::Baseline, false);
            let ap = offchip_energy(&study, cfg, Scheme::Apack, true);
            assert!(ap < base, "{}: {ap} vs {base}", cfg.name);
        }
        // Pruned AlexNet saves much more than NCF (paper: 91% vs 13–50%).
        let a = offchip_energy(&study, &models[0], Scheme::Apack, true)
            / offchip_energy(&study, &models[0], Scheme::Baseline, false);
        let n = offchip_energy(&study, &models[1], Scheme::Apack, true)
            / offchip_energy(&study, &models[1], Scheme::Baseline, false);
        assert!(a < n, "alexnet {a:.3} should save more than ncf {n:.3}");
    }
}
