//! # apack-repro
//!
//! Full-system reproduction of **APack: Off-Chip, Lossless Data Compression
//! for Efficient Deep Learning Inference** (Delmas Lascorz, Mahmoud,
//! Moshovos; cs.AR 2022).
//!
//! APack losslessly compresses fixed-point DNN weight/activation tensors on
//! the path between the on-chip memory hierarchy and the off-chip DRAM
//! controller. Each value `v` is mapped through a 16-entry partition of the
//! value space into a `(symbol, offset)` pair where `v = v_min[symbol] +
//! offset`; the symbol stream is arithmetically coded with 10-bit probability
//! counts and 16-bit finite-precision range registers (the hardware algorithm
//! of paper §V), while the offset stream stores `OL[symbol]` raw bits per
//! value. A profiling-driven heuristic (paper §VI, Listing 1) chooses the
//! partition per tensor.
//!
//! The crate contains, per DESIGN.md:
//!
//! - [`apack`] — the codec itself: bit-exact hardware-model encoder/decoder,
//!   table generation, histograms, stream containers.
//! - [`baselines`] — the comparison codecs of paper §VII: RLE, RLEZ and
//!   ShapeShifter.
//! - [`models`] — the 24-network model zoo of Table II plus synthetic
//!   value-distribution generators standing in for the proprietary traces.
//! - [`simulator`] — DDR4-3200 DRAM power/timing model, APack engine
//!   cycle/area/power model, and the TensorCore accelerator model of
//!   Table III.
//! - [`coordinator`] — the L3 runtime: substream partitioning, parallel
//!   engine pool, metrics.
//! - [`store`] — APackStore: a persistent, random-access compressed tensor
//!   store. Named tensors in one file, independently decodable CRC-checked
//!   chunks, one shared table per tensor, O(1) `get_tensor` /
//!   `get_chunk` / `get_range` with an LRU chunk cache; pipelined,
//!   stage-timed zoo ingest.
//! - [`serving`] — the request layer over the store: bounded-queue worker
//!   pool, chunk-level single-flight coalescing, admission control with
//!   typed overload shedding, hot-set prefetch and latency metrics.
//! - [`obs`] — the observability substrate: structured span tracer
//!   (request + ingest paths, near-zero cost disabled), named metrics
//!   registry backing `ReadStats`/`PackStats`/`MetricsSnapshot`, and
//!   Chrome-trace / Prometheus / JSONL exporters.
//! - [`runtime`] — PJRT client that loads the AOT-lowered JAX/Pallas model
//!   (HLO text) and runs real inference to produce activation traces.
//! - [`eval`] — regeneration harness for every table and figure in the
//!   paper's evaluation section.

pub mod apack;
pub mod baselines;
pub mod coordinator;
pub mod error;
pub mod eval;
pub mod models;
pub mod obs;
pub mod runtime;
pub mod serving;
pub mod simulator;
pub mod store;
pub mod util;

pub use error::{Error, Result};
