//! [`ServingEngine`]: the bounded-queue worker pool that fronts one
//! [`StoreHandle`].
//!
//! See the module docs of [`crate::serving`] for the request lifecycle
//! (queue → coalesce → decode → respond) and the prefetch loop.
//!
//! Concurrency model — std only, per the crate's no-deps rule:
//!
//! - The queue is a `Mutex<VecDeque>` plus a condvar; `submit` never
//!   blocks (it either enqueues or sheds with
//!   [`Error::Overloaded`]) and workers park on the condvar when idle.
//! - Each request carries a one-shot response slot (mutex + condvar) the
//!   client blocks on in [`Ticket::wait`]; workers fill it exactly once.
//! - Shutdown is drain-then-join: dropping the engine flags shutdown and
//!   wakes everyone; workers keep popping until the queue is empty, so
//!   every admitted request is answered — a `Ticket` can always be
//!   waited on, even after the engine is gone.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::obs::{
    self, Counter, Gauge, LatencyHistogram, ManualSpan, MetricsRegistry, RegistrySnapshot,
    RequestOutcome, RequestRecord, SloConfig, SloStatus, SloTracker, Stage,
};
use crate::store::{StoreHandle, StoreVariant};
use crate::util::Rng64;

use super::metrics::MetricsSnapshot;
use super::prefetch::{HotSet, PrefetchConfig};
use super::singleflight::{ChunkResult, SingleFlight};

/// One serving request against the store.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Request {
    /// One chunk of a tensor (the response shares the cached `Arc`).
    Chunk { tensor: String, chunk: usize },
    /// A value range of a tensor, assembled from its covering chunks.
    Range { tensor: String, range: Range<u64> },
    /// A full tensor.
    Tensor { tensor: String },
}

impl Request {
    /// The tensor this request reads.
    pub fn tensor(&self) -> &str {
        match self {
            Request::Chunk { tensor, .. }
            | Request::Range { tensor, .. }
            | Request::Tensor { tensor } => tensor,
        }
    }
}

/// Engine tuning knobs.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Worker threads decoding requests (default: available parallelism).
    pub workers: usize,
    /// Admission bound: `submit` sheds with [`Error::Overloaded`] once
    /// this many requests are queued.
    pub queue_depth: usize,
    /// Collapse concurrent duplicate `(tensor, chunk)` decodes into one
    /// flight (see [`SingleFlight`]).
    pub coalescing: bool,
    /// Default per-request deadline, measured from submit. A request
    /// still queued when its deadline passes is shed at pop time instead
    /// of being decoded late.
    pub deadline: Option<Duration>,
    /// Hot-set prefetcher; `None` disables the prefetch thread.
    pub prefetch: Option<PrefetchConfig>,
    /// SLO objectives (latency + availability burn-rate windows,
    /// [`crate::obs::slo`]); `None` disables tracking.
    pub slo: Option<SloConfig>,
}

impl Default for ServingConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            queue_depth: 256,
            coalescing: true,
            deadline: None,
            prefetch: None,
            slo: None,
        }
    }
}

/// One-shot response slot shared between a [`Ticket`] and the worker
/// answering it.
struct Slot {
    result: Mutex<Option<ChunkResult>>,
    ready: Condvar,
}

impl Slot {
    fn new() -> Self {
        Self { result: Mutex::new(None), ready: Condvar::new() }
    }

    fn fill(&self, result: ChunkResult) {
        *self.result.lock().expect("serving response lock") = Some(result);
        self.ready.notify_all();
    }
}

/// Handle on an admitted request. Outlives the engine: every admitted
/// request is answered even through shutdown, so `wait` never hangs.
pub struct Ticket {
    slot: Arc<Slot>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<Arc<Vec<u32>>> {
        let mut slot = self.slot.result.lock().expect("serving response lock");
        loop {
            if let Some(result) = slot.take() {
                return result;
            }
            slot = self.slot.ready.wait(slot).expect("serving response lock");
        }
    }

    /// The response if it already arrived (non-blocking; takes it, so a
    /// later `wait` would block — check `Some` before discarding).
    pub fn try_wait(&self) -> Option<ChunkResult> {
        self.slot.result.lock().expect("serving response lock").take()
    }
}

/// A queued request with its admission timestamp, response slot and
/// (when tracing is on) the request span carried across to the worker.
struct Queued {
    request: Request,
    slot: Arc<Slot>,
    enqueued: Instant,
    deadline: Option<Duration>,
    trace_span: Option<ManualSpan>,
}

/// State shared by the engine handle, its workers and the prefetcher.
struct Shared {
    store: Arc<StoreHandle>,
    config: ServingConfig,
    queue: Mutex<VecDeque<Queued>>,
    queue_cv: Condvar,
    shutdown: AtomicBool,
    flight: SingleFlight,
    hotset: HotSet,
    /// The prefetch thread parks here between scans so shutdown can wake
    /// it immediately instead of waiting out the interval.
    prefetch_park: (Mutex<()>, Condvar),
    /// `serving.*` metrics (DESIGN.md §10 glossary); the fields below are
    /// pre-registered handles so the hot path never takes the map lock.
    registry: MetricsRegistry,
    submitted: Arc<Counter>,
    completed: Arc<Counter>,
    shed_queue_full: Arc<Counter>,
    shed_deadline: Arc<Counter>,
    coalesced: Arc<Counter>,
    retries: Arc<Counter>,
    queue_depth: Arc<Gauge>,
    queue_depth_max: Arc<Gauge>,
    latency: Arc<LatencyHistogram>,
    /// SLO burn-rate tracker ([`crate::obs::slo`]); present iff
    /// configured.
    slo: Option<SloTracker>,
    /// Per-request outcome records for the tail exemplar sampler
    /// ([`crate::obs::sampler`]), bounded at [`OUTCOME_RING`]. Only fed
    /// while span tracing is on — an outcome is useless to the sampler
    /// without its span tree.
    outcomes: Mutex<VecDeque<RequestRecord>>,
}

/// Outcome records kept for exemplar sampling (oldest dropped first).
const OUTCOME_RING: usize = 1 << 16;

impl Shared {
    /// Refresh the live-queue gauge, then snapshot `serving.*` and fold
    /// in the store's `store.*` registry view plus the SLO gauges.
    fn registry_snapshot(&self) -> RegistrySnapshot {
        self.queue_depth.set(self.queue.lock().expect("serving queue lock").len() as u64);
        let mut snap = self.registry.snapshot();
        snap.merge(&self.store.registry_snapshot());
        if let Some(slo) = &self.slo {
            slo.status().overlay_gauges(&mut snap);
        }
        snap
    }

    /// Record one request outcome: into the SLO tracker (always, when
    /// configured) and into the exemplar outcome ring (only when the
    /// request had a span id, i.e. tracing was on at submit).
    fn record_outcome(&self, span_id: u64, outcome: RequestOutcome, latency: Duration) {
        if let Some(slo) = &self.slo {
            slo.record(outcome, latency);
        }
        if span_id != 0 {
            let mut ring = self.outcomes.lock().expect("serving outcome lock");
            if ring.len() >= OUTCOME_RING {
                ring.pop_front();
            }
            ring.push_back(RequestRecord {
                span_id,
                latency_ns: latency.as_nanos() as u64,
                outcome,
            });
        }
    }
}

/// A batching, admission-controlled serving layer over one
/// [`StoreHandle`]. See [`crate::serving`] for the architecture.
pub struct ServingEngine {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    prefetcher: Option<JoinHandle<()>>,
}

impl ServingEngine {
    /// Spawn the worker pool (and prefetch thread, if configured) over
    /// `store`.
    pub fn start(store: Arc<StoreHandle>, config: ServingConfig) -> Result<Self> {
        if config.workers == 0 {
            return Err(Error::Config("serving engine needs at least one worker".into()));
        }
        if config.queue_depth == 0 {
            return Err(Error::Config(
                "serving queue depth must be at least one request".into(),
            ));
        }
        let prefetch_cfg = config.prefetch.clone();
        let slo = config.slo.map(SloTracker::new);
        let registry = MetricsRegistry::new();
        let shared = Arc::new(Shared {
            store,
            config,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            flight: SingleFlight::new(),
            hotset: HotSet::new(),
            prefetch_park: (Mutex::new(()), Condvar::new()),
            submitted: registry.counter("serving.submitted"),
            completed: registry.counter("serving.completed"),
            shed_queue_full: registry.counter("serving.shed_queue_full"),
            shed_deadline: registry.counter("serving.shed_deadline"),
            coalesced: registry.counter("serving.coalesced_decodes"),
            retries: registry.counter("serving.retries"),
            queue_depth: registry.gauge("serving.queue_depth"),
            queue_depth_max: registry.gauge("serving.queue_depth_max"),
            latency: registry.histogram("serving.latency_ns"),
            registry,
            slo,
            outcomes: Mutex::new(VecDeque::new()),
        });
        let workers = (0..shared.config.workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("apack-serve-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn serving worker")
            })
            .collect();
        let prefetcher = prefetch_cfg.map(|cfg| {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("apack-prefetch".to_string())
                .spawn(move || prefetch_loop(&shared, &cfg))
                .expect("spawn serving prefetcher")
        });
        Ok(Self { shared, workers, prefetcher })
    }

    /// Admit a request with the engine's default deadline. Non-blocking:
    /// returns [`Error::Overloaded`] instead of queueing past
    /// `queue_depth`.
    pub fn submit(&self, request: Request) -> Result<Ticket> {
        self.submit_with_deadline(request, self.shared.config.deadline)
    }

    /// Admit a request with an explicit deadline (`None` = no deadline),
    /// overriding the engine default.
    pub fn submit_with_deadline(
        &self,
        request: Request,
        deadline: Option<Duration>,
    ) -> Result<Ticket> {
        let shared = &self.shared;
        // Request span: begun here, carried to the worker, finished when
        // the response slot fills (or at shed). Admit covers this
        // function's admission-control section, under the request.
        let trace_span = ManualSpan::begin(Stage::Request);
        let req_id = trace_span.as_ref().map(|s| s.id()).unwrap_or(0);
        let admit = obs::span_under(Stage::Admit, req_id, 0);
        let slot = Arc::new(Slot::new());
        let depth = {
            let mut queue = shared.queue.lock().expect("serving queue lock");
            if queue.len() >= shared.config.queue_depth {
                drop(queue);
                shared.shed_queue_full.inc();
                shared.record_outcome(req_id, RequestOutcome::ShedQueueFull, Duration::ZERO);
                drop(admit);
                if let Some(span) = trace_span {
                    span.finish();
                }
                return Err(Error::Overloaded {
                    queue_depth: shared.config.queue_depth,
                    deadline_expired: false,
                });
            }
            queue.push_back(Queued {
                request,
                slot: Arc::clone(&slot),
                enqueued: Instant::now(),
                deadline,
                trace_span,
            });
            queue.len()
        };
        shared.queue_depth_max.set_max(depth as u64);
        shared.submitted.inc();
        shared.queue_cv.notify_one();
        Ok(Ticket { slot })
    }

    /// Blocking convenience: submit + wait.
    pub fn get(&self, request: Request) -> Result<Arc<Vec<u32>>> {
        self.submit(request)?.wait()
    }

    /// Blocking chunk read through the serving path.
    pub fn get_chunk(&self, tensor: &str, chunk: usize) -> Result<Arc<Vec<u32>>> {
        self.get(Request::Chunk { tensor: tensor.to_string(), chunk })
    }

    /// Blocking range read through the serving path.
    pub fn get_range(&self, tensor: &str, range: Range<u64>) -> Result<Arc<Vec<u32>>> {
        self.get(Request::Range { tensor: tensor.to_string(), range })
    }

    /// Blocking full-tensor read through the serving path.
    pub fn get_tensor(&self, tensor: &str) -> Result<Arc<Vec<u32>>> {
        self.get(Request::Tensor { tensor: tensor.to_string() })
    }

    /// The store this engine serves.
    pub fn store(&self) -> &Arc<StoreHandle> {
        &self.shared.store
    }

    /// The engine's configuration.
    pub fn config(&self) -> &ServingConfig {
        &self.shared.config
    }

    /// Point-in-time serving counters — a [`MetricsSnapshot`] view over
    /// the engine's `serving.*` registry.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.queue_depth.set(
            self.shared.queue.lock().expect("serving queue lock").len() as u64,
        );
        let mut snap = MetricsSnapshot::from_snapshot(&self.shared.registry_snapshot());
        snap.slo = self.slo_status();
        snap
    }

    /// Point-in-time SLO status (`None` when no SLO is configured).
    pub fn slo_status(&self) -> Option<SloStatus> {
        self.shared.slo.as_ref().map(|t| t.status())
    }

    /// Copy of the per-request outcome records accumulated while span
    /// tracing was on — join against [`crate::obs::drain`]ed events with
    /// [`crate::obs::collect_exemplars`] to build tail exemplars.
    pub fn request_outcomes(&self) -> Vec<RequestRecord> {
        self.shared.outcomes.lock().expect("serving outcome lock").iter().copied().collect()
    }

    /// The full registry snapshot: this engine's `serving.*` metrics
    /// merged with the store's `store.*` view — what the Prometheus and
    /// JSONL exporters serialize.
    pub fn registry_snapshot(&self) -> RegistrySnapshot {
        self.shared.registry_snapshot()
    }

    /// A `'static` snapshot source for [`crate::obs::SnapshotStream`]:
    /// clones the shared state so the stream thread outlives this
    /// borrow.
    pub fn snapshot_source(
        &self,
    ) -> impl Fn() -> RegistrySnapshot + Send + Sync + 'static {
        let shared = Arc::clone(&self.shared);
        move || shared.registry_snapshot()
    }

    /// The store's read counters with this engine's serving counters
    /// folded in (`coalesced_reads`, `shed_requests`;
    /// `prefetched_chunks` is counted by the store itself).
    pub fn stats(&self) -> crate::store::ReadStats {
        let mut stats = self.shared.store.stats();
        stats.coalesced_reads += self.shared.coalesced.get();
        stats.shed_requests +=
            self.shared.shed_queue_full.get() + self.shared.shed_deadline.get();
        stats
    }
}

impl Drop for ServingEngine {
    /// Drain-then-join shutdown: workers answer every queued request
    /// before exiting, so no admitted `Ticket` is left hanging.
    fn drop(&mut self) {
        // Flag shutdown while holding the queue mutex: a worker checks the
        // flag under that mutex before parking, so the store can never
        // slip between its check and its wait (lost-wakeup race). The
        // prefetcher's park uses wait_timeout and self-recovers within
        // one interval, so its notify needs no such ceremony.
        {
            let _queue = self.shared.queue.lock().expect("serving queue lock");
            self.shared.shutdown.store(true, Ordering::Release);
        }
        self.shared.queue_cv.notify_all();
        self.shared.prefetch_park.1.notify_all();
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
        if let Some(handle) = self.prefetcher.take() {
            let _ = handle.join();
        }
    }
}

/// Worker: pop → deadline check → decode (coalesced) → respond.
fn worker_loop(shared: &Shared) {
    loop {
        let item = {
            let mut queue = shared.queue.lock().expect("serving queue lock");
            loop {
                if let Some(item) = queue.pop_front() {
                    break item;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                queue = shared.queue_cv.wait(queue).expect("serving queue lock");
            }
        };
        let req_id = item.trace_span.as_ref().map(|s| s.id()).unwrap_or(0);
        // Queue wait: from the submit-side enqueue instant to now, on
        // this worker. An enqueue that predates the trace epoch clamps.
        obs::record(Stage::QueueWait, req_id, item.enqueued, Instant::now(), 0);
        if let Some(deadline) = item.deadline {
            if item.enqueued.elapsed() >= deadline {
                shared.shed_deadline.inc();
                shared.record_outcome(
                    req_id,
                    RequestOutcome::ShedDeadline,
                    item.enqueued.elapsed(),
                );
                item.slot.fill(Err(Error::Overloaded {
                    queue_depth: shared.config.queue_depth,
                    deadline_expired: true,
                }));
                if let Some(span) = item.trace_span {
                    span.finish();
                }
                continue;
            }
        }
        let result = {
            let _exec = obs::span_under(Stage::Execute, req_id, 0);
            execute(shared, &item.request)
        };
        let latency = item.enqueued.elapsed();
        shared.latency.record(latency);
        shared.completed.inc();
        let outcome =
            if result.is_ok() { RequestOutcome::Ok } else { RequestOutcome::Error };
        shared.record_outcome(req_id, outcome, latency);
        let served = result.as_ref().map(|v| v.len() as u64).unwrap_or(0);
        item.slot.fill(result);
        if let Some(span) = item.trace_span {
            span.finish_with(served);
        }
    }
}

/// Bounded re-issues of a chunk decode after the store layer reports a
/// transient failure (its own per-read retries already exhausted).
const SERVING_TRANSIENT_RETRIES: usize = 2;

/// Decode one request against the store.
///
/// The generation snapshot is pinned once per request: a concurrent
/// `reload` or online compaction swaps the handle under us, but every
/// chunk of this response decodes from the same generation.
fn execute(shared: &Shared, request: &Request) -> Result<Arc<Vec<u32>>> {
    let store = shared.store.pin();
    match request {
        Request::Chunk { tensor, chunk } => decode_chunk(shared, &store, tensor, *chunk),
        Request::Range { tensor, range } => {
            assemble_range(shared, &store, tensor, range.clone())
        }
        Request::Tensor { tensor } => {
            let n_values = store.meta(tensor)?.n_values;
            assemble_range(shared, &store, tensor, 0..n_values)
        }
    }
}

/// One chunk through hot-set tracking and (when enabled) the
/// single-flight table, with bounded retries for transient IO errors.
fn decode_chunk(
    shared: &Shared,
    store: &StoreVariant,
    tensor: &str,
    chunk: usize,
) -> Result<Arc<Vec<u32>>> {
    if shared.config.prefetch.is_some() {
        shared.hotset.touch(tensor, chunk);
    }
    // One span per (tensor, chunk) resolution: the leader's decode or a
    // follower's wait. The store's ChunkIo/Decode spans nest under it on
    // the leader's thread.
    let _sf = obs::span(Stage::SingleFlight);
    let mut attempt = 0;
    loop {
        let result = if shared.config.coalescing {
            let (result, coalesced) =
                shared.flight.run(tensor, chunk, || store.get_chunk(tensor, chunk));
            if coalesced {
                shared.coalesced.inc();
            }
            result
        } else {
            store.get_chunk(tensor, chunk)
        };
        match result {
            Err(err) if err.is_transient() && attempt < SERVING_TRANSIENT_RETRIES => {
                attempt += 1;
                shared.retries.inc();
                // Jittered backoff so coalesced retriers don't stampede
                // the same chunk in lockstep.
                let mut rng = Rng64::new(0x5E7A_11ED ^ ((chunk as u64) << 8) ^ attempt as u64);
                std::thread::sleep(Duration::from_micros(
                    (50 + rng.below(200)) * attempt as u64,
                ));
            }
            other => return other,
        }
    }
}

/// A value range assembled from its covering chunks, each fetched through
/// [`decode_chunk`] so duplicate-heavy range traffic coalesces too.
/// Chunks decode sequentially within one request — parallelism comes from
/// the worker pool, not from fan-out inside a request.
fn assemble_range(
    shared: &Shared,
    store: &StoreVariant,
    tensor: &str,
    range: Range<u64>,
) -> Result<Arc<Vec<u32>>> {
    let meta = store.meta(tensor)?;
    if range.start > range.end || range.end > meta.n_values {
        return Err(Error::Store(format!(
            "tensor {tensor}: range {}..{} out of bounds (n_values {})",
            range.start, range.end, meta.n_values
        )));
    }
    if range.start == range.end {
        return Ok(Arc::new(Vec::new()));
    }
    let first = meta.chunk_for_value(range.start);
    let last = meta.chunk_for_value(range.end - 1);
    if first == last {
        let covered = meta.chunk_value_range(first);
        if covered.start == range.start && covered.end == range.end {
            // Whole-chunk range (single-chunk tensors take this path too):
            // the response IS the cached chunk — share the Arc, copy
            // nothing.
            return decode_chunk(shared, store, tensor, first);
        }
    }
    let mut copy_out = obs::span(Stage::CopyOut);
    let mut out = Vec::with_capacity((range.end - range.start) as usize);
    for ci in first..=last {
        let part = decode_chunk(shared, store, tensor, ci)?;
        let covered = meta.chunk_value_range(ci);
        let lo = range.start.max(covered.start) - covered.start;
        let hi = range.end.min(covered.end) - covered.start;
        out.extend_from_slice(&part[lo as usize..hi as usize]);
    }
    copy_out.set_count(out.len() as u64);
    Ok(Arc::new(out))
}

/// Prefetch thread: park on the interval (shutdown-wakeable), scan the
/// hot set, warm the store cache. Racing a demand decode is harmless —
/// `prefetch_chunk` is a no-op on resident chunks.
fn prefetch_loop(shared: &Shared, cfg: &PrefetchConfig) {
    loop {
        {
            let park = shared.prefetch_park.0.lock().expect("prefetch park lock");
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            let _unused = shared
                .prefetch_park
                .1
                .wait_timeout(park, cfg.interval)
                .expect("prefetch park lock");
        }
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let hottest = shared.hotset.hottest(cfg.top_k, cfg.min_touches);
        // Span only non-empty sweeps: an idle 2ms-interval prefetcher
        // would otherwise flood the trace with empty scans.
        let _scan = if hottest.is_empty() {
            None
        } else {
            Some(obs::span_n(Stage::Prefetch, hottest.len() as u64))
        };
        for (tensor, chunk, _touches) in hottest {
            if shared.shutdown.load(Ordering::Acquire) {
                return;
            }
            // Unknown-tensor races can't happen (the hot set only holds
            // names that decoded once); IO errors surface on the demand
            // path too, so the prefetcher just moves on.
            let _ = shared.store.prefetch_chunk(&tensor, chunk as usize);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::tablegen::TensorKind;
    use crate::coordinator::PartitionPolicy;
    use crate::models::distributions::ValueProfile;
    use crate::store::StoreWriter;

    fn build_store(tag: &str, n: usize) -> (std::path::PathBuf, Vec<u32>) {
        let path = std::env::temp_dir()
            .join(format!("apack_engine_{}_{tag}.apackstore", std::process::id()));
        let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, 42);
        let policy = PartitionPolicy { substreams: 8, min_per_stream: 128 };
        let mut writer = StoreWriter::create(&path, policy).unwrap();
        writer.add_tensor("t", 8, &values, TensorKind::Activations).unwrap();
        writer.finish().unwrap();
        (path, values)
    }

    #[test]
    fn serves_bit_exact_through_every_request_kind() {
        let (path, values) = build_store("kinds", 10_000);
        let store = Arc::new(StoreHandle::open(&path).unwrap());
        let engine = ServingEngine::start(
            Arc::clone(&store),
            ServingConfig { workers: 2, ..ServingConfig::default() },
        )
        .unwrap();

        assert_eq!(engine.get_tensor("t").unwrap().as_slice(), &values[..]);
        assert_eq!(
            engine.get_range("t", 100..2345).unwrap().as_slice(),
            &values[100..2345]
        );
        assert!(engine.get_range("t", 5000..5000).unwrap().is_empty());
        let meta = store.meta("t").unwrap();
        let covered = meta.chunk_value_range(3);
        assert_eq!(
            engine.get_chunk("t", 3).unwrap().as_slice(),
            &values[covered.start as usize..covered.end as usize]
        );

        // Errors surface through the ticket, not as hangs or panics.
        assert!(engine.get_tensor("absent").is_err());
        assert!(engine.get_chunk("t", 999).is_err());
        assert!(engine.get_range("t", 5..4).is_err());
        assert!(engine.get_range("t", 0..999_999).is_err());

        let m = engine.metrics();
        assert_eq!(m.submitted, 8);
        assert_eq!(m.completed, 8, "error responses complete too");
        assert_eq!(m.shed_total(), 0);
        assert_eq!(m.latency.count, 8);
        drop(engine);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_degenerate_configs() {
        let (path, _) = build_store("cfg", 2_000);
        let store = Arc::new(StoreHandle::open(&path).unwrap());
        assert!(ServingEngine::start(
            Arc::clone(&store),
            ServingConfig { workers: 0, ..ServingConfig::default() }
        )
        .is_err());
        assert!(ServingEngine::start(
            store,
            ServingConfig { queue_depth: 0, ..ServingConfig::default() }
        )
        .is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn drop_drains_admitted_tickets() {
        let (path, values) = build_store("drain", 20_000);
        let store = Arc::new(StoreHandle::open(&path).unwrap());
        let engine = ServingEngine::start(
            store,
            ServingConfig { workers: 2, queue_depth: 64, ..ServingConfig::default() },
        )
        .unwrap();
        let tickets: Vec<Ticket> = (0..16)
            .map(|i| {
                engine
                    .submit(Request::Range {
                        tensor: "t".to_string(),
                        range: (i * 1000)..(i * 1000 + 500),
                    })
                    .unwrap()
            })
            .collect();
        drop(engine); // joins workers only after the queue is drained
        for (i, ticket) in tickets.into_iter().enumerate() {
            let got = ticket.wait().unwrap();
            let lo = i * 1000;
            assert_eq!(got.as_slice(), &values[lo..lo + 500], "request {i}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn slo_flips_to_breaching_under_saturation() {
        let (path, _) = build_store("slo", 4_000);
        let store = Arc::new(StoreHandle::open(&path).unwrap());
        let slo = SloConfig {
            latency_target: Duration::from_secs(1),
            ..SloConfig::default()
        };

        // Healthy run: generous latency target, no sheds — no burn.
        let engine = ServingEngine::start(
            Arc::clone(&store),
            ServingConfig { workers: 2, slo: Some(slo), ..ServingConfig::default() },
        )
        .unwrap();
        for _ in 0..20 {
            engine.get_chunk("t", 0).unwrap();
        }
        let status = engine.metrics().slo.expect("slo configured");
        assert!(!status.breaching());
        assert_eq!(status.availability.total, 20);
        assert_eq!(status.availability.good, 20);
        drop(engine);

        // Saturation: a zero deadline sheds every request at pop time, so
        // the availability budget burns far past the threshold in both
        // windows and the status flips to breaching.
        let engine = ServingEngine::start(
            store,
            ServingConfig {
                workers: 1,
                deadline: Some(Duration::ZERO),
                slo: Some(slo),
                ..ServingConfig::default()
            },
        )
        .unwrap();
        for _ in 0..20 {
            let err = engine.get_chunk("t", 0).unwrap_err();
            assert!(matches!(err, Error::Overloaded { deadline_expired: true, .. }));
        }
        let status = engine.slo_status().expect("slo configured");
        assert!(status.availability.breaching, "all-shed traffic must breach");
        assert!(status.breaching());
        assert_eq!(status.latency.total, 0, "sheds never feed the latency SLI");
        // The breach also lands in the exporter-facing gauges.
        let snap = engine.registry_snapshot();
        assert_eq!(snap.gauge("serving.slo_breaching"), 1);
        drop(engine);
        std::fs::remove_file(&path).ok();
    }
}
