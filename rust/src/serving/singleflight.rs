//! Single-flight table: chunk-level coalescing of concurrent duplicate
//! decodes.
//!
//! Under hot-set traffic many in-flight requests resolve to the same
//! `(tensor, chunk)`. Without coalescing each of them arithmetic-decodes
//! the chunk independently — N× the work for one result (the LRU only
//! helps *after* the first decode completes). The single-flight table
//! gives every key at most one decode in flight: the first caller (the
//! **leader**) runs the decode; callers that arrive while it is running
//! (**followers**) block on the flight's condvar and share the leader's
//! `Arc`'d result. A caller that arrives after the flight completed
//! simply starts a new one — the table never caches results, it only
//! collapses *concurrent* duplicates (the [`crate::store::ChunkCache`]
//! owns temporal reuse).
//!
//! The leader publishes its result (success or error) before unlisting
//! the key, so followers can never block on a completed flight.
//!
//! Error sharing is deliberately asymmetric. *Permanent* errors (corrupt
//! chunk, missing tensor) are `Clone` and shared like values — one bad
//! chunk fails every coalesced request identically, and re-decoding it
//! would only reproduce the failure. *Transient* errors
//! ([`crate::error::Error::is_transient`]) are **not** adopted by
//! followers: the leader's IO hiccup says nothing about whether a fresh
//! attempt would succeed, so a follower that observes one re-enters the
//! table and retries independently (becoming the next leader, or
//! following a newer flight), up to [`MAX_TRANSIENT_REJOINS`] times.
//! The leader itself always returns its own result verbatim — its
//! retry policy lives in the serving engine, not here.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::error::Result;

/// How many times a follower re-enters the table after observing another
/// leader's transient failure before giving up and returning it.
const MAX_TRANSIENT_REJOINS: usize = 4;

/// Decoded chunk shared between coalesced requests.
pub type ChunkResult = Result<Arc<Vec<u32>>>;

/// One in-flight decode: the leader fills `result`, followers wait on
/// `done`.
struct Flight {
    result: Mutex<Option<ChunkResult>>,
    done: Condvar,
}

/// The table of in-flight `(tensor, chunk)` decodes.
pub struct SingleFlight {
    inflight: Mutex<HashMap<(String, u32), Arc<Flight>>>,
}

impl Default for SingleFlight {
    fn default() -> Self {
        Self::new()
    }
}

impl SingleFlight {
    pub fn new() -> Self {
        Self { inflight: Mutex::new(HashMap::new()) }
    }

    /// Decode `(tensor, chunk)` through the table: run `decode` if no
    /// flight is up, otherwise wait for the in-flight one. Returns the
    /// shared result plus whether this call was coalesced onto another
    /// caller's flight (`true` only for followers).
    ///
    /// `decode` must not panic: a leader that unwinds would strand its
    /// followers (store decode paths report all failures as `Err`).
    pub fn run(
        &self,
        tensor: &str,
        chunk: usize,
        decode: impl FnOnce() -> ChunkResult,
    ) -> (ChunkResult, bool) {
        let key = (tensor.to_string(), chunk as u32);
        // Held in an Option so a follower that re-enters after a transient
        // failure can still lead a fresh flight with it.
        let mut decode = Some(decode);
        let mut rejoins = 0;
        loop {
            let (flight, leader) = {
                let mut map = self.inflight.lock().expect("single-flight table lock");
                match map.get(&key) {
                    Some(f) => (Arc::clone(f), false),
                    None => {
                        let f = Arc::new(Flight {
                            result: Mutex::new(None),
                            done: Condvar::new(),
                        });
                        map.insert(key.clone(), Arc::clone(&f));
                        (f, true)
                    }
                }
            };
            if leader {
                let run = decode.take().expect("each caller leads at most once");
                let result = run();
                *flight.result.lock().expect("single-flight result lock") =
                    Some(result.clone());
                flight.done.notify_all();
                // Publish before unlisting: a caller holding the flight Arc
                // reads the stored result; a caller arriving after the remove
                // starts a fresh flight.
                self.inflight.lock().expect("single-flight table lock").remove(&key);
                return (result, false);
            }
            let result = {
                let mut slot = flight.result.lock().expect("single-flight result lock");
                while slot.is_none() {
                    slot = flight.done.wait(slot).expect("single-flight result lock");
                }
                slot.as_ref().expect("loop exits on Some").clone()
            };
            match result {
                // Another leader's transient IO failure is not ours to
                // adopt — re-enter the table and try independently.
                Err(err) if err.is_transient() && rejoins < MAX_TRANSIENT_REJOINS => {
                    rejoins += 1;
                }
                shared => return (shared, true),
            }
        }
    }

    /// Number of decodes currently in flight (diagnostics).
    pub fn inflight_len(&self) -> usize {
        self.inflight.lock().expect("single-flight table lock").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Barrier;
    use std::time::Duration;

    #[test]
    fn concurrent_duplicates_share_one_decode() {
        let flight = SingleFlight::new();
        let decodes = AtomicU64::new(0);
        let coalesced = AtomicU64::new(0);
        let barrier = Barrier::new(8);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    barrier.wait();
                    let (res, joined) = flight.run("t", 3, || {
                        decodes.fetch_add(1, Ordering::Relaxed);
                        // Long enough that every barrier-released peer
                        // arrives while this flight is still up.
                        std::thread::sleep(Duration::from_millis(100));
                        Ok(Arc::new(vec![7u32, 8, 9]))
                    });
                    assert_eq!(res.unwrap().as_slice(), &[7, 8, 9]);
                    if joined {
                        coalesced.fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert_eq!(decodes.load(Ordering::Relaxed), 1, "one leader only");
        assert_eq!(coalesced.load(Ordering::Relaxed), 7, "everyone else follows");
        assert_eq!(flight.inflight_len(), 0, "table drains");
    }

    #[test]
    fn distinct_keys_do_not_coalesce() {
        let flight = SingleFlight::new();
        let (a, ca) = flight.run("t", 0, || Ok(Arc::new(vec![1u32])));
        let (b, cb) = flight.run("t", 1, || Ok(Arc::new(vec![2u32])));
        let (c, cc) = flight.run("u", 0, || Ok(Arc::new(vec![3u32])));
        assert_eq!(a.unwrap()[0], 1);
        assert_eq!(b.unwrap()[0], 2);
        assert_eq!(c.unwrap()[0], 3);
        assert!(!ca && !cb && !cc);
    }

    #[test]
    fn errors_are_shared_like_values() {
        let flight = SingleFlight::new();
        let barrier = Barrier::new(4);
        let fails = AtomicU64::new(0);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    barrier.wait();
                    let (res, _) = flight.run("t", 0, || {
                        std::thread::sleep(Duration::from_millis(50));
                        Err(crate::error::Error::Store("injected".into()))
                    });
                    assert!(res.is_err());
                    fails.fetch_add(1, Ordering::Relaxed);
                });
            }
        });
        assert_eq!(fails.load(Ordering::Relaxed), 4);
        // A later call retries rather than replaying the stale error.
        let (res, joined) = flight.run("t", 0, || Ok(Arc::new(vec![5u32])));
        assert_eq!(res.unwrap()[0], 5);
        assert!(!joined);
    }

    #[test]
    fn transient_errors_are_not_adopted_by_followers() {
        let flight = SingleFlight::new();
        let attempts = AtomicU64::new(0);
        let transient_failures = AtomicU64::new(0);
        let oks = AtomicU64::new(0);
        let barrier = Barrier::new(4);
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    barrier.wait();
                    let (res, _) = flight.run("t", 0, || {
                        if attempts.fetch_add(1, Ordering::Relaxed) == 0 {
                            // First leader: hold the flight long enough
                            // that every peer coalesces onto it, then fail
                            // transiently.
                            std::thread::sleep(Duration::from_millis(100));
                            Err(crate::error::Error::Transient("injected".into()))
                        } else {
                            Ok(Arc::new(vec![9u32]))
                        }
                    });
                    match res {
                        Err(e) => {
                            assert!(e.is_transient());
                            transient_failures.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(v) => {
                            assert_eq!(v[0], 9);
                            oks.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        // The first leader keeps its own transient error (engine-level
        // retry is its caller's job); every follower re-enters instead of
        // adopting it and succeeds on a fresh decode.
        assert_eq!(transient_failures.load(Ordering::Relaxed), 1, "only the first leader fails");
        assert_eq!(oks.load(Ordering::Relaxed), 3, "followers retried independently");
        assert!(attempts.load(Ordering::Relaxed) >= 2, "at least one fresh decode ran");
        assert_eq!(flight.inflight_len(), 0, "table drains");
    }

    #[test]
    fn sequential_calls_lead_their_own_flights() {
        let flight = SingleFlight::new();
        let decodes = AtomicU64::new(0);
        for _ in 0..3 {
            let (res, joined) = flight.run("t", 0, || {
                decodes.fetch_add(1, Ordering::Relaxed);
                Ok(Arc::new(vec![1u32]))
            });
            assert!(res.is_ok());
            assert!(!joined, "no concurrency, no coalescing");
        }
        assert_eq!(decodes.load(Ordering::Relaxed), 3);
    }
}
