//! Serving-side observability: the [`MetricsSnapshot`] the engine
//! reports, built as a **view over** the engine's
//! [`crate::obs::MetricsRegistry`] snapshot (ISSUE 6).
//!
//! The log-linear latency histogram that used to live here is the
//! shared [`crate::obs::hist::LatencyHistogram`] now; the re-exports
//! keep `serving::metrics::{LatencyHistogram, LatencySnapshot}` paths
//! working. Counter names (`serving.*`) are listed in the DESIGN.md §10
//! glossary.

pub use crate::obs::hist::{LatencyHistogram, LatencySnapshot};
use crate::obs::{RegistrySnapshot, SloStatus};

/// Point-in-time view of a [`crate::serving::ServingEngine`]'s counters.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Requests admitted into the queue.
    pub submitted: u64,
    /// Requests a worker decoded and answered (sheds count separately).
    pub completed: u64,
    /// Requests rejected at submit because the queue was full.
    pub shed_queue_full: u64,
    /// Requests shed at pop because their deadline had expired.
    pub shed_deadline: u64,
    /// Chunk decodes that joined another request's flight instead of
    /// decoding again (single-flight coalescing).
    pub coalesced_decodes: u64,
    /// Chunk decodes re-issued after a transient IO failure (the store's
    /// own per-read retries already exhausted).
    pub retries: u64,
    /// Requests waiting in the queue right now.
    pub queue_depth: usize,
    /// High-water mark of the queue depth.
    pub queue_depth_max: usize,
    /// Submit-to-response latency of completed requests.
    pub latency: LatencySnapshot,
    /// SLO burn-rate status, present when the engine was started with
    /// [`crate::serving::ServingConfig::slo`] configured (ISSUE 8).
    pub slo: Option<SloStatus>,
    /// Active arithmetic-decode kernel label (`scalar` / `sse2` / `avx2`
    /// / `neon`), read from the store's `store.decode_kernel{kernel=...}`
    /// info gauge; empty when the snapshot holds no store metrics.
    pub decode_kernel: String,
}

impl MetricsSnapshot {
    /// Build the view from a registry snapshot holding the `serving.*`
    /// metrics (the engine refreshes the `serving.queue_depth` gauge
    /// from the live queue just before snapshotting).
    pub fn from_snapshot(snap: &RegistrySnapshot) -> Self {
        Self {
            submitted: snap.counter("serving.submitted"),
            completed: snap.counter("serving.completed"),
            shed_queue_full: snap.counter("serving.shed_queue_full"),
            shed_deadline: snap.counter("serving.shed_deadline"),
            coalesced_decodes: snap.counter("serving.coalesced_decodes"),
            retries: snap.counter("serving.retries"),
            queue_depth: snap.gauge("serving.queue_depth") as usize,
            queue_depth_max: snap.gauge("serving.queue_depth_max") as usize,
            latency: snap.hist("serving.latency_ns"),
            slo: None,
            decode_kernel: decode_kernel_label(snap),
        }
    }

    /// Requests shed for any reason.
    pub fn shed_total(&self) -> u64 {
        self.shed_queue_full + self.shed_deadline
    }

    /// Multi-line rendering for bench/CLI output.
    pub fn render(&self) -> String {
        let mut out = format!(
            "serving: {} submitted, {} completed, {} shed ({} queue-full, {} deadline)\n\
             coalesced decodes: {}  transient retries: {}  queue depth: {} now / {} peak\n\
             latency: {}",
            self.submitted,
            self.completed,
            self.shed_total(),
            self.shed_queue_full,
            self.shed_deadline,
            self.coalesced_decodes,
            self.retries,
            self.queue_depth,
            self.queue_depth_max,
            self.latency.render()
        );
        if !self.decode_kernel.is_empty() {
            out.push_str(&format!("\ndecode kernel: {}", self.decode_kernel));
        }
        if let Some(slo) = &self.slo {
            out.push('\n');
            out.push_str(&slo.render());
        }
        out
    }
}

/// Extract the kernel label from the `store.decode_kernel{kernel="..."}`
/// info gauge a [`crate::store::StoreReader`] publishes in its registry
/// view; empty string when the snapshot carries no store metrics.
fn decode_kernel_label(snap: &RegistrySnapshot) -> String {
    const PREFIX: &str = "store.decode_kernel{kernel=\"";
    snap.gauges
        .keys()
        .find_map(|k| k.strip_prefix(PREFIX)?.strip_suffix("\"}"))
        .unwrap_or("")
        .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::MetricsRegistry;
    use std::time::Duration;

    #[test]
    fn snapshot_view_reads_the_serving_names() {
        let r = MetricsRegistry::new();
        r.counter("serving.submitted").add(9);
        r.counter("serving.completed").add(8);
        r.counter("serving.shed_queue_full").inc();
        r.gauge("serving.queue_depth_max").set_max(5);
        r.histogram("serving.latency_ns").record(Duration::from_micros(3));
        let m = MetricsSnapshot::from_snapshot(&r.snapshot());
        assert_eq!(m.submitted, 9);
        assert_eq!(m.completed, 8);
        assert_eq!(m.shed_total(), 1);
        assert_eq!(m.queue_depth_max, 5);
        assert_eq!(m.latency.count, 1);
        assert!(m.render().contains("9 submitted"));
        assert!(m.decode_kernel.is_empty(), "no store metrics in this snapshot");
    }

    #[test]
    fn decode_kernel_gauge_round_trips_through_snapshot_and_export() {
        let r = MetricsRegistry::new();
        r.counter("serving.completed").add(3);
        r.gauge("store.decode_kernel{kernel=\"avx2\"}").set(1);
        let snap = r.snapshot();
        let m = MetricsSnapshot::from_snapshot(&snap);
        assert_eq!(m.decode_kernel, "avx2");
        assert!(m.render().contains("decode kernel: avx2"));
        let text = crate::obs::prometheus_text(&snap);
        assert!(text.contains("# TYPE store_decode_kernel gauge"));
        assert!(text.contains("store_decode_kernel{kernel=\"avx2\"} 1"));
    }
}
