//! **Serving layer** — a batching, admission-controlled request front for
//! one [`crate::store::StoreHandle`].
//!
//! APack's deployment story (paper §V) is a memory path that serves
//! decompressed values on demand while the data stays compressed at rest
//! — the regime EIE demonstrated for inference from a compressed weight
//! store, extended here with the request scheduling a store under heavy
//! multi-tenant traffic needs. Raw `StoreHandle` reads give every caller
//! its own decode and unbounded queueing under overload; the serving
//! layer adds the four things between "a store" and "a service":
//!
//! 1. **A bounded queue + worker pool** ([`ServingEngine`]): clients
//!    submit [`Request`]s and block on a [`Ticket`]; a fixed pool of
//!    decode workers drains the queue. Throughput is set by workers ×
//!    per-chunk decode rate, not by how many clients pile in.
//! 2. **Chunk-level coalescing** ([`SingleFlight`]): concurrent requests
//!    resolving to the same `(tensor, chunk)` share one arithmetic
//!    decode instead of N — the request-side mirror of the store's LRU
//!    (which only helps *after* a decode lands).
//! 3. **Admission control**: a full queue or an expired deadline sheds
//!    the request with the typed [`crate::error::Error::Overloaded`]
//!    instead of letting latency grow without bound.
//! 4. **Hot-set prefetch** ([`prefetch`]): access-frequency counters
//!    (decayed every scan) drive a background thread that warms the
//!    store's chunk cache ahead of demand via
//!    [`crate::store::StoreHandle::prefetch_chunk`].
//!
//! # Request lifecycle
//!
//! ```text
//!  client                ServingEngine                         store
//!  ------                -------------                         -----
//!  submit(req) ──► admission: queue full? deadline set?
//!                  │  full ──► Err(Overloaded)  (shed, counted)
//!                  ▼
//!                bounded VecDeque ◄── workers park on condvar
//!                  │ pop
//!                  ▼
//!                deadline expired? ──► Ticket ◄ Err(Overloaded)
//!                  │ no
//!                  ▼
//!                per chunk: hot-set touch, then single-flight:
//!                  leader ─────────────► get_chunk (CRC + decode, LRU)
//!                  followers wait, share the leader's Arc
//!                  │
//!                  ▼
//!  Ticket::wait ◄─ respond (latency recorded, metrics updated)
//!
//!  prefetch thread (optional): every interval, top-K hottest chunks
//!  ──► StoreHandle::prefetch_chunk  (no-op when already resident)
//! ```
//!
//! # Observability
//!
//! All serving telemetry lives in the engine's
//! [`crate::obs::MetricsRegistry`] under `serving.*` names (glossary:
//! DESIGN.md §10); [`ServingEngine::metrics`] and
//! [`ServingEngine::stats`] are views over one registry snapshot —
//! queue depth (current + peak), shed counts (queue-full vs deadline),
//! coalesced decodes, a submit-to-response latency histogram
//! (p50/p95/p99, ~25% bucket error), and the store's
//! [`crate::store::ReadStats`] with the serving counters folded in.
//! [`ServingEngine::registry_snapshot`] merges the store's `store.*`
//! counters for the exporters ([`crate::obs::prometheus_text`],
//! [`crate::obs::SnapshotStream`]); with the span tracer enabled
//! (`serve-bench --trace`) every request records an
//! admit → queue-wait → execute → single-flight → chunk-IO → decode →
//! copy-out span tree ([`crate::obs::span`]).
//!
//! The attribution layer (ISSUE 8, DESIGN.md §12) builds on those spans:
//! when [`ServingConfig::slo`] is set the engine feeds every request
//! outcome (ok / error / shed) into a [`crate::obs::SloTracker`] whose
//! burn-rate [`crate::obs::SloStatus`] surfaces in [`MetricsSnapshot`]
//! and as `serving.slo_*` gauges, and a bounded outcome ring
//! ([`ServingEngine::request_outcomes`]) lets the tail sampler
//! ([`crate::obs::collect_exemplars`]) join drained span trees with
//! per-request latencies after a run.
//!
//! # Submodules
//!
//! - [`engine`] — [`ServingEngine`], [`ServingConfig`], [`Request`],
//!   [`Ticket`]: queue, workers, deadlines, shutdown-by-drain.
//! - [`singleflight`] — [`SingleFlight`], the in-flight decode table.
//! - [`prefetch`] — [`PrefetchConfig`] and the decayed hot-set counters.
//! - [`metrics`] — [`LatencyHistogram`], [`MetricsSnapshot`].

pub mod engine;
pub mod metrics;
pub mod prefetch;
pub mod singleflight;

pub use engine::{Request, ServingConfig, ServingEngine, Ticket};
pub use metrics::{LatencyHistogram, LatencySnapshot, MetricsSnapshot};
pub use prefetch::{HotSet, PrefetchConfig};
pub use singleflight::{ChunkResult, SingleFlight};
