//! Hot-set tracking for the serving prefetcher.
//!
//! Every chunk a request touches bumps a frequency counter; a background
//! thread (see `serving::engine`) periodically asks for the hottest
//! `(tensor, chunk)` pairs and warms the store's LRU cache via
//! [`crate::store::StoreHandle::prefetch_chunk`] — decode-ahead for the
//! traffic the engine is *about* to see, the software mirror of the
//! paper's §V premise that decode bandwidth on the memory path is cheap
//! relative to a demand stall.
//!
//! Counters **decay by half on every scan** and drop at zero, so the hot
//! set tracks recent traffic rather than all-time totals; a chunk that
//! stops being requested stops being prefetched within a few intervals.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// Prefetcher tuning. `Default` suits closed-loop serving benches; widen
/// `interval` for latency-insensitive batch traffic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrefetchConfig {
    /// How often the prefetch thread scans the hot set.
    pub interval: Duration,
    /// At most this many chunks warmed per scan.
    pub top_k: usize,
    /// Only chunks touched at least this often since the last scan
    /// qualify (1 = everything seen; higher = only sustained traffic).
    pub min_touches: u64,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        Self { interval: Duration::from_millis(2), top_k: 32, min_touches: 2 }
    }
}

/// Frequency counters over `(tensor, chunk)`, touched by workers on every
/// chunk access and drained by the prefetch thread.
#[derive(Default)]
pub struct HotSet {
    /// tensor name -> chunk index -> touches since last decay.
    counts: Mutex<HashMap<String, HashMap<u32, u64>>>,
}

impl HotSet {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one access (worker hot path: one short lock).
    pub fn touch(&self, tensor: &str, chunk: usize) {
        let mut counts = self.counts.lock().expect("hot-set lock");
        match counts.get_mut(tensor) {
            Some(inner) => *inner.entry(chunk as u32).or_insert(0) += 1,
            None => {
                counts.insert(tensor.to_string(), HashMap::from([(chunk as u32, 1u64)]));
            }
        }
    }

    /// The `top_k` hottest chunks with at least `min_touches`, hottest
    /// first (ties broken by name/index so scans are deterministic), then
    /// decay every counter by half, dropping the cold tail.
    pub fn hottest(&self, top_k: usize, min_touches: u64) -> Vec<(String, u32, u64)> {
        let mut counts = self.counts.lock().expect("hot-set lock");
        let mut flat: Vec<(String, u32, u64)> = counts
            .iter()
            .flat_map(|(name, inner)| {
                inner.iter().map(move |(&ci, &n)| (name.clone(), ci, n))
            })
            .filter(|entry| entry.2 >= min_touches.max(1))
            .collect();
        flat.sort_by(|a, b| {
            b.2.cmp(&a.2).then_with(|| (a.0.as_str(), a.1).cmp(&(b.0.as_str(), b.1)))
        });
        flat.truncate(top_k);
        for inner in counts.values_mut() {
            inner.retain(|_, n| {
                *n >>= 1;
                *n > 0
            });
        }
        counts.retain(|_, inner| !inner.is_empty());
        flat
    }

    /// Distinct chunks currently tracked (diagnostics).
    pub fn tracked(&self) -> usize {
        self.counts.lock().expect("hot-set lock").values().map(HashMap::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hottest_orders_filters_and_decays() {
        let hs = HotSet::new();
        for _ in 0..8 {
            hs.touch("a", 0);
        }
        for _ in 0..4 {
            hs.touch("a", 1);
        }
        hs.touch("b", 9);
        assert_eq!(hs.tracked(), 3);

        let hot = hs.hottest(10, 2);
        assert_eq!(hot.len(), 2, "b/9 has one touch, below min_touches=2");
        assert_eq!((hot[0].0.as_str(), hot[0].1, hot[0].2), ("a", 0, 8));
        assert_eq!((hot[1].0.as_str(), hot[1].1, hot[1].2), ("a", 1, 4));

        // Halved: 8->4, 4->2, 1->0 (dropped).
        assert_eq!(hs.tracked(), 2);
        let hot = hs.hottest(1, 1);
        assert_eq!(hot.len(), 1, "top_k truncates");
        assert_eq!((hot[0].0.as_str(), hot[0].1, hot[0].2), ("a", 0, 4));

        // Two more decays (2->1->0, 1->0) and the set drains entirely.
        hs.hottest(10, 1);
        hs.hottest(10, 1);
        assert_eq!(hs.tracked(), 0);
        assert!(hs.hottest(10, 1).is_empty());
    }

    #[test]
    fn ties_are_deterministic() {
        let hs = HotSet::new();
        hs.touch("b", 2);
        hs.touch("a", 7);
        hs.touch("a", 3);
        let hot = hs.hottest(10, 1);
        let order: Vec<(&str, u32)> =
            hot.iter().map(|e| (e.0.as_str(), e.1)).collect();
        assert_eq!(order, vec![("a", 3), ("a", 7), ("b", 2)]);
    }
}
