//! [`StoreHandle`] — the one store-access type every consumer uses.
//!
//! The CLI, the eval report, the serving example and the benches don't
//! care whether a store is one `.apackstore` file or a sharded directory,
//! nor which IO backend serves the bytes. `StoreHandle` folds
//! [`StoreReader`] and [`ShardedStoreReader`] behind one surface
//! (`get_tensor` / `get_chunk` / `get_range` / `stats` / `verify` / …),
//! auto-detected from the path: a directory opens as a sharded store, a
//! file as a single-file store.
//!
//! Since live stores (DESIGN.md §14) can gain generations while being
//! served, the handle is a **swappable snapshot**: it holds an
//! `Arc<StoreVariant>` behind an `RwLock`. Every call uses the current
//! snapshot; [`Self::pin`] hands a caller its own `Arc` so a multi-step
//! request (the serving engine's decode + range assembly) sees one
//! consistent generation even if [`Self::reload`] or
//! [`Self::compact_live`] swaps the snapshot mid-flight. The swap is a
//! single pointer flip; the superseded reader (and, after compaction, the
//! replaced inode) lives until the last pinned `Arc` drops. Decode-kernel
//! and lane-thread settings are remembered and re-applied across swaps;
//! read counters, cache contents and heat restart with the new snapshot.

use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, RwLock};

use crate::error::Result;

use super::format::TensorMeta;
use super::io::{Backend, FaultPlan};
use super::live::{compact_sharded_store, compact_store, CompactSummary};
use super::reader::{ReadStats, StoreReader, VerifyReport, DEFAULT_CACHE_VALUES};
use super::shard::ShardedStoreReader;

/// One opened generation snapshot: a single-file or sharded reader.
/// Borrow-returning accessors live here; [`StoreHandle`] adds the
/// swap/reload machinery and owned-return conveniences on top.
pub enum StoreVariant {
    Single(StoreReader),
    Sharded(ShardedStoreReader),
}

impl StoreVariant {
    /// The IO backend serving this store.
    pub fn backend(&self) -> Backend {
        match self {
            StoreVariant::Single(r) => r.backend(),
            StoreVariant::Sharded(r) => r.backend(),
        }
    }

    /// Number of shard files (1 for a single-file store).
    pub fn shard_count(&self) -> usize {
        match self {
            StoreVariant::Single(_) => 1,
            StoreVariant::Sharded(r) => r.shard_count(),
        }
    }

    /// All tensor names (write order; sharded: shard order first).
    pub fn tensor_names(&self) -> Vec<&str> {
        match self {
            StoreVariant::Single(r) => r.tensor_names(),
            StoreVariant::Sharded(r) => r.tensor_names(),
        }
    }

    /// Number of tensors in the store.
    pub fn tensor_count(&self) -> usize {
        match self {
            StoreVariant::Single(r) => r.tensor_count(),
            StoreVariant::Sharded(r) => r.tensor_count(),
        }
    }

    /// Every tensor's footer entry (same order as [`Self::tensor_names`]).
    pub fn tensor_metas(&self) -> Vec<&TensorMeta> {
        match self {
            StoreVariant::Single(r) => r.index().tensors.iter().collect(),
            StoreVariant::Sharded(r) => r.tensor_metas(),
        }
    }

    /// Metadata for one tensor.
    pub fn meta(&self, name: &str) -> Result<&TensorMeta> {
        match self {
            StoreVariant::Single(r) => r.meta(name),
            StoreVariant::Sharded(r) => r.meta(name),
        }
    }

    /// Decode one chunk (CRC-checked; cache-assisted).
    pub fn get_chunk(&self, name: &str, ci: usize) -> Result<Arc<Vec<u32>>> {
        match self {
            StoreVariant::Single(r) => r.get_chunk(name, ci),
            StoreVariant::Sharded(r) => r.get_chunk(name, ci),
        }
    }

    /// Decode a full tensor, chunks in parallel.
    pub fn get_tensor(&self, name: &str) -> Result<Vec<u32>> {
        match self {
            StoreVariant::Single(r) => r.get_tensor(name),
            StoreVariant::Sharded(r) => r.get_tensor(name),
        }
    }

    /// Decode a value range, touching only the covering chunks.
    pub fn get_range(&self, name: &str, range: Range<u64>) -> Result<Vec<u32>> {
        match self {
            StoreVariant::Single(r) => r.get_range(name, range),
            StoreVariant::Sharded(r) => r.get_range(name, range),
        }
    }

    /// Warm the chunk cache with one chunk ahead of demand (the serving
    /// layer's hot-set prefetcher drives this; see
    /// [`StoreReader::prefetch_chunk`]). Returns whether a decode happened.
    pub fn prefetch_chunk(&self, name: &str, ci: usize) -> Result<bool> {
        match self {
            StoreVariant::Single(r) => r.prefetch_chunk(name, ci),
            StoreVariant::Sharded(r) => r.prefetch_chunk(name, ci),
        }
    }

    /// Snapshot the cumulative read counters (sharded: aggregated).
    pub fn stats(&self) -> ReadStats {
        match self {
            StoreVariant::Single(r) => r.stats(),
            StoreVariant::Sharded(r) => r.stats(),
        }
    }

    /// `store.*` metrics snapshot (sharded: merged across shards).
    pub fn registry_snapshot(&self) -> crate::obs::RegistrySnapshot {
        match self {
            StoreVariant::Single(r) => r.registry_snapshot(),
            StoreVariant::Sharded(r) => r.registry_snapshot(),
        }
    }

    /// Per-chunk access heat (sharded: concatenated across shards),
    /// sorted `(tensor, chunk)` — see [`super::heat`].
    pub fn heatmap(&self) -> Vec<super::heat::ChunkHeatEntry> {
        match self {
            StoreVariant::Single(r) => r.heatmap(),
            StoreVariant::Sharded(r) => r.heatmap(),
        }
    }

    /// Pin the arithmetic-decode kernel (sharded: every shard).
    pub fn set_decode_kernel(&self, kernel: crate::apack::simd::DecodeKernel) {
        match self {
            StoreVariant::Single(r) => r.set_decode_kernel(kernel),
            StoreVariant::Sharded(r) => r.set_decode_kernel(kernel),
        }
    }

    /// The decode kernel chunk decodes run with.
    pub fn decode_kernel(&self) -> crate::apack::simd::DecodeKernel {
        match self {
            StoreVariant::Single(r) => r.decode_kernel(),
            StoreVariant::Sharded(r) => r.decode_kernel(),
        }
    }

    /// Worker-thread count for lane-parallel chunk-body-v2 decodes
    /// (0/1 = single-threaded; sharded: every shard).
    pub fn set_lane_threads(&self, threads: usize) {
        match self {
            StoreVariant::Single(r) => r.set_lane_threads(threads),
            StoreVariant::Sharded(r) => r.set_lane_threads(threads),
        }
    }

    /// Zero the read counters.
    pub fn reset_stats(&self) {
        match self {
            StoreVariant::Single(r) => r.reset_stats(),
            StoreVariant::Sharded(r) => r.reset_stats(),
        }
    }

    /// Drop all cached chunks.
    pub fn clear_cache(&self) {
        match self {
            StoreVariant::Single(r) => r.clear_cache(),
            StoreVariant::Sharded(r) => r.clear_cache(),
        }
    }

    /// Integrity pass, bail-on-first (see [`Self::verify_report`] for the
    /// classified non-bailing sweep).
    pub fn verify(&self) -> Result<VerifyReport> {
        match self {
            StoreVariant::Single(r) => r.verify(),
            StoreVariant::Sharded(r) => r.verify(),
        }
    }

    /// Classified, non-bailing integrity sweep (DESIGN.md §14): every
    /// chunk is re-read, CRC-checked and decoded; each failure becomes a
    /// [`super::verify::VerifyIssue`] and the sweep continues.
    pub fn verify_report(&self) -> VerifyReport {
        match self {
            StoreVariant::Single(r) => r.verify_report(),
            StoreVariant::Sharded(r) => r.verify_report(),
        }
    }

    /// The committed generation (sharded: the max across shards).
    pub fn generation(&self) -> u32 {
        match self {
            StoreVariant::Single(r) => r.generation(),
            StoreVariant::Sharded(r) => {
                r.shard_readers().iter().map(|s| s.generation()).max().unwrap_or(0)
            }
        }
    }
}

/// A read handle on an APackStore: single file or sharded directory,
/// swappable to a newer generation while being served (module doc).
pub struct StoreHandle {
    path: PathBuf,
    backend: Backend,
    cache_values: usize,
    plan: Option<FaultPlan>,
    inner: RwLock<Arc<StoreVariant>>,
    /// Explicitly-set decode kernel / lane threads, re-applied to every
    /// snapshot [`Self::reload`] opens.
    kernel: Mutex<Option<crate::apack::simd::DecodeKernel>>,
    lane_threads: Mutex<Option<usize>>,
}

impl StoreHandle {
    /// Open `path` with the default (mmap) backend and cache budget,
    /// auto-detecting single-file vs. sharded layout.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, Backend::default(), DEFAULT_CACHE_VALUES)
    }

    /// Open with an explicit backend and cache budget (in values; a
    /// sharded store splits the budget across shards).
    pub fn open_with(path: &Path, backend: Backend, cache_values: usize) -> Result<Self> {
        Self::open_with_plan(path, backend, cache_values, None)
    }

    /// [`Self::open_with`] with a [`FaultPlan`] wrapping all chunk IO —
    /// the fault-injection entry point ([`super::io`]). The plan carries
    /// over reloads and online compactions.
    pub fn open_with_plan(
        path: &Path,
        backend: Backend,
        cache_values: usize,
        plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        let variant = Self::open_variant(path, backend, cache_values, plan)?;
        Ok(Self {
            path: path.to_path_buf(),
            backend,
            cache_values,
            plan: plan.cloned(),
            inner: RwLock::new(Arc::new(variant)),
            kernel: Mutex::new(None),
            lane_threads: Mutex::new(None),
        })
    }

    fn open_variant(
        path: &Path,
        backend: Backend,
        cache_values: usize,
        plan: Option<&FaultPlan>,
    ) -> Result<StoreVariant> {
        if path.is_dir() {
            Ok(StoreVariant::Sharded(ShardedStoreReader::open_opts(
                path,
                backend,
                cache_values,
                plan,
            )?))
        } else {
            Ok(StoreVariant::Single(StoreReader::open_opts(
                path,
                backend,
                cache_values,
                plan,
            )?))
        }
    }

    /// Pin the current generation snapshot. The returned `Arc` keeps this
    /// exact generation (reader, cache, mmap/fd) alive and consistent no
    /// matter how many [`Self::reload`]/[`Self::compact_live`] swaps
    /// happen; drop it to release the superseded generation.
    pub fn pin(&self) -> Arc<StoreVariant> {
        self.inner.read().unwrap().clone()
    }

    /// Whether the store is a sharded directory.
    pub fn is_sharded(&self) -> bool {
        matches!(*self.pin(), StoreVariant::Sharded(_))
    }

    /// Re-open the store from disk and swap the snapshot to the newest
    /// committed generation (after an external append committed). In-flight
    /// pinned readers are undisturbed; new calls see the new generation.
    pub fn reload(&self) -> Result<()> {
        let variant =
            Self::open_variant(&self.path, self.backend, self.cache_values, self.plan.as_ref())?;
        if let Some(k) = *self.kernel.lock().unwrap() {
            variant.set_decode_kernel(k);
        }
        if let Some(t) = *self.lane_threads.lock().unwrap() {
            variant.set_lane_threads(t);
        }
        *self.inner.write().unwrap() = Arc::new(variant);
        Ok(())
    }

    /// Compact the store **while serving**: rewrite the committed
    /// generation on disk ([`compact_store`] / [`compact_sharded_store`] —
    /// crash-safe at every boundary), then swap the snapshot. Readers
    /// pinned before the swap keep decoding the old inode bit-exactly
    /// until they drop; the swap itself is one pointer flip.
    pub fn compact_live(&self) -> Result<CompactSummary> {
        let summary = if self.path.is_dir() {
            compact_sharded_store(&self.path, self.plan.as_ref())?
        } else {
            compact_store(&self.path, self.plan.as_ref())?
        };
        self.reload()?;
        Ok(summary)
    }

    /// The IO backend serving this store.
    pub fn backend(&self) -> Backend {
        self.pin().backend()
    }

    /// Number of shard files (1 for a single-file store).
    pub fn shard_count(&self) -> usize {
        self.pin().shard_count()
    }

    /// All tensor names (write order; sharded: shard order first).
    pub fn tensor_names(&self) -> Vec<String> {
        self.pin().tensor_names().into_iter().map(str::to_string).collect()
    }

    /// Number of tensors in the store.
    pub fn tensor_count(&self) -> usize {
        self.pin().tensor_count()
    }

    /// Every tensor's footer entry (same order as [`Self::tensor_names`]).
    pub fn tensor_metas(&self) -> Vec<TensorMeta> {
        self.pin().tensor_metas().into_iter().cloned().collect()
    }

    /// Metadata for one tensor (owned — for borrowed access across one
    /// consistent generation, use [`Self::pin`]).
    pub fn meta(&self, name: &str) -> Result<TensorMeta> {
        self.pin().meta(name).cloned()
    }

    /// The committed generation (sharded: the max across shards).
    pub fn generation(&self) -> u32 {
        self.pin().generation()
    }

    /// Decode one chunk (CRC-checked; cache-assisted).
    pub fn get_chunk(&self, name: &str, ci: usize) -> Result<Arc<Vec<u32>>> {
        self.pin().get_chunk(name, ci)
    }

    /// Decode a full tensor, chunks in parallel.
    pub fn get_tensor(&self, name: &str) -> Result<Vec<u32>> {
        self.pin().get_tensor(name)
    }

    /// Decode a value range, touching only the covering chunks.
    pub fn get_range(&self, name: &str, range: Range<u64>) -> Result<Vec<u32>> {
        self.pin().get_range(name, range)
    }

    /// Warm the chunk cache with one chunk ahead of demand.
    pub fn prefetch_chunk(&self, name: &str, ci: usize) -> Result<bool> {
        self.pin().prefetch_chunk(name, ci)
    }

    /// Snapshot the cumulative read counters (sharded: aggregated).
    pub fn stats(&self) -> ReadStats {
        self.pin().stats()
    }

    /// `store.*` metrics snapshot (sharded: merged across shards). The
    /// serving engine folds this into its own `serving.*` snapshot so
    /// exporters see one namespace.
    pub fn registry_snapshot(&self) -> crate::obs::RegistrySnapshot {
        self.pin().registry_snapshot()
    }

    /// Per-chunk access heat (sharded: concatenated across shards).
    pub fn heatmap(&self) -> Vec<super::heat::ChunkHeatEntry> {
        self.pin().heatmap()
    }

    /// Pin the arithmetic-decode kernel (sharded: every shard);
    /// remembered across [`Self::reload`] swaps.
    pub fn set_decode_kernel(&self, kernel: crate::apack::simd::DecodeKernel) {
        *self.kernel.lock().unwrap() = Some(kernel);
        self.pin().set_decode_kernel(kernel);
    }

    /// The decode kernel chunk decodes run with.
    pub fn decode_kernel(&self) -> crate::apack::simd::DecodeKernel {
        self.pin().decode_kernel()
    }

    /// Worker-thread count for lane-parallel chunk-body-v2 decodes;
    /// remembered across [`Self::reload`] swaps.
    pub fn set_lane_threads(&self, threads: usize) {
        *self.lane_threads.lock().unwrap() = Some(threads);
        self.pin().set_lane_threads(threads);
    }

    /// Zero the read counters.
    pub fn reset_stats(&self) {
        self.pin().reset_stats()
    }

    /// Drop all cached chunks.
    pub fn clear_cache(&self) {
        self.pin().clear_cache()
    }

    /// Integrity pass: re-read, CRC-check and decode every chunk (sharded:
    /// shards verify in parallel, chunks fan out within each). Bails on
    /// the first failure; [`Self::verify_report`] classifies them all.
    pub fn verify(&self) -> Result<VerifyReport> {
        self.pin().verify()
    }

    /// Classified, non-bailing integrity sweep (DESIGN.md §14).
    pub fn verify_report(&self) -> VerifyReport {
        self.pin().verify_report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::tablegen::TensorKind;
    use crate::coordinator::PartitionPolicy;
    use crate::models::distributions::ValueProfile;
    use crate::store::live::StoreAppender;
    use crate::store::writer::encode_tensor_with;
    use crate::store::{BodyConfig, ShardedStoreWriter, StoreWriter};

    fn tensor(n: usize, seed: u64) -> Vec<u32> {
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, seed)
    }

    #[test]
    fn handle_auto_detects_layout() {
        let base = std::env::temp_dir()
            .join(format!("apack_handle_{}", std::process::id()));
        let file_path = base.with_extension("apackstore");
        let dir_path = base.with_extension("apackstore.d");
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 128 };
        let v = tensor(3000, 5);

        let mut w = StoreWriter::create(&file_path, policy).unwrap();
        w.add_tensor("t", 8, &v, TensorKind::Weights).unwrap();
        w.finish().unwrap();
        let mut w = ShardedStoreWriter::create(&dir_path, 2, policy).unwrap();
        w.add_tensor("t", 8, &v, TensorKind::Weights).unwrap();
        w.finish().unwrap();

        let single = StoreHandle::open(&file_path).unwrap();
        let sharded = StoreHandle::open(&dir_path).unwrap();
        assert!(!single.is_sharded());
        assert!(sharded.is_sharded());
        assert_eq!(single.shard_count(), 1);
        assert_eq!(sharded.shard_count(), 2);
        assert_eq!(single.tensor_count(), 1);
        assert_eq!(sharded.tensor_count(), 1);

        // Identical data through either layout, plus uniform stats/verify.
        assert_eq!(single.get_tensor("t").unwrap(), v);
        assert_eq!(sharded.get_tensor("t").unwrap(), v);
        assert_eq!(single.get_range("t", 100..200).unwrap(), &v[100..200]);
        assert_eq!(sharded.get_range("t", 100..200).unwrap(), &v[100..200]);
        assert_eq!(single.meta("t").unwrap().n_values, 3000);
        assert_eq!(sharded.meta("t").unwrap().n_values, 3000);
        assert!(single.verify().unwrap().chunks > 0);
        assert_eq!(sharded.verify().unwrap().shards, 2);
        assert!(single.stats().bytes_read > 0);
        assert_eq!(single.tensor_metas().len(), 1);
        assert_eq!(sharded.tensor_metas().len(), 1);

        std::fs::remove_file(&file_path).ok();
        std::fs::remove_dir_all(&dir_path).ok();
    }

    #[test]
    fn pinned_snapshot_survives_reload_and_live_compaction() {
        let path = std::env::temp_dir()
            .join(format!("apack_handle_live_{}.apackstore", std::process::id()));
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 128 };
        let v0 = tensor(4000, 6);
        let v1 = tensor(4000, 7);
        let mut w = StoreWriter::create(&path, policy).unwrap();
        w.add_tensor("t", 8, &v0, TensorKind::Weights).unwrap();
        w.finish().unwrap();

        let handle = StoreHandle::open(&path).unwrap();
        assert_eq!(handle.generation(), 0);
        let pinned = handle.pin();

        // Commit a replacement externally; the handle serves the pinned
        // generation until reload.
        let t = encode_tensor_with(
            &policy,
            BodyConfig::default(),
            "t",
            8,
            &v1,
            TensorKind::Weights,
            None,
            0,
        )
        .unwrap();
        let mut app = StoreAppender::open(&path).unwrap();
        app.append_encoded(t).unwrap();
        app.commit().unwrap();
        assert_eq!(handle.get_tensor("t").unwrap(), v0);

        handle.reload().unwrap();
        assert_eq!(handle.generation(), 1);
        assert_eq!(handle.get_tensor("t").unwrap(), v1);
        // The pinned snapshot still decodes the old generation bit-exactly.
        assert_eq!(pinned.get_tensor("t").unwrap(), v0);

        // Online compaction: swap to the rewritten file; the pin still
        // reads the replaced inode.
        let summary = handle.compact_live().unwrap();
        assert!(summary.reclaimed() > 0);
        assert_eq!(handle.generation(), 2);
        assert_eq!(handle.get_tensor("t").unwrap(), v1);
        assert_eq!(pinned.get_tensor("t").unwrap(), v0);
        handle.verify().unwrap();

        std::fs::remove_file(&path).ok();
        std::fs::remove_file(crate::store::format::gen_pointer_path(&path)).ok();
    }
}
