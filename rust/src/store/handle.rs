//! [`StoreHandle`] — the one store-access type every consumer uses.
//!
//! The CLI, the eval report, the serving example and the benches don't
//! care whether a store is one `.apackstore` file or a sharded directory,
//! nor which IO backend serves the bytes. `StoreHandle` folds
//! [`StoreReader`] and [`ShardedStoreReader`] behind one surface
//! (`get_tensor` / `get_chunk` / `get_range` / `stats` / `verify` / …),
//! auto-detected from the path: a directory opens as a sharded store, a
//! file as a single-file store. This is the seam later work (async
//! serving, delta updates) plugs into without touching the callers again.

use std::ops::Range;
use std::path::Path;
use std::sync::Arc;

use crate::error::Result;

use super::format::TensorMeta;
use super::io::Backend;
use super::reader::{ReadStats, StoreReader, VerifyReport, DEFAULT_CACHE_VALUES};
use super::shard::ShardedStoreReader;

/// A read-only handle on an APackStore: single file or sharded directory.
pub enum StoreHandle {
    Single(StoreReader),
    Sharded(ShardedStoreReader),
}

impl StoreHandle {
    /// Open `path` with the default (mmap) backend and cache budget,
    /// auto-detecting single-file vs. sharded layout.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, Backend::default(), DEFAULT_CACHE_VALUES)
    }

    /// Open with an explicit backend and cache budget (in values; a
    /// sharded store splits the budget across shards).
    pub fn open_with(path: &Path, backend: Backend, cache_values: usize) -> Result<Self> {
        if path.is_dir() {
            Ok(StoreHandle::Sharded(ShardedStoreReader::open_with(
                path,
                backend,
                cache_values,
            )?))
        } else {
            Ok(StoreHandle::Single(StoreReader::open_with(path, backend, cache_values)?))
        }
    }

    /// The IO backend serving this store.
    pub fn backend(&self) -> Backend {
        match self {
            StoreHandle::Single(r) => r.backend(),
            StoreHandle::Sharded(r) => r.backend(),
        }
    }

    /// Number of shard files (1 for a single-file store).
    pub fn shard_count(&self) -> usize {
        match self {
            StoreHandle::Single(_) => 1,
            StoreHandle::Sharded(r) => r.shard_count(),
        }
    }

    /// All tensor names (write order; sharded: shard order first).
    pub fn tensor_names(&self) -> Vec<&str> {
        match self {
            StoreHandle::Single(r) => r.tensor_names(),
            StoreHandle::Sharded(r) => r.tensor_names(),
        }
    }

    /// Number of tensors in the store.
    pub fn tensor_count(&self) -> usize {
        match self {
            StoreHandle::Single(r) => r.tensor_count(),
            StoreHandle::Sharded(r) => r.tensor_count(),
        }
    }

    /// Every tensor's footer entry (same order as [`Self::tensor_names`]).
    pub fn tensor_metas(&self) -> Vec<&TensorMeta> {
        match self {
            StoreHandle::Single(r) => r.index().tensors.iter().collect(),
            StoreHandle::Sharded(r) => r.tensor_metas(),
        }
    }

    /// Metadata for one tensor.
    pub fn meta(&self, name: &str) -> Result<&TensorMeta> {
        match self {
            StoreHandle::Single(r) => r.meta(name),
            StoreHandle::Sharded(r) => r.meta(name),
        }
    }

    /// Decode one chunk (CRC-checked; cache-assisted).
    pub fn get_chunk(&self, name: &str, ci: usize) -> Result<Arc<Vec<u32>>> {
        match self {
            StoreHandle::Single(r) => r.get_chunk(name, ci),
            StoreHandle::Sharded(r) => r.get_chunk(name, ci),
        }
    }

    /// Decode a full tensor, chunks in parallel.
    pub fn get_tensor(&self, name: &str) -> Result<Vec<u32>> {
        match self {
            StoreHandle::Single(r) => r.get_tensor(name),
            StoreHandle::Sharded(r) => r.get_tensor(name),
        }
    }

    /// Decode a value range, touching only the covering chunks.
    pub fn get_range(&self, name: &str, range: Range<u64>) -> Result<Vec<u32>> {
        match self {
            StoreHandle::Single(r) => r.get_range(name, range),
            StoreHandle::Sharded(r) => r.get_range(name, range),
        }
    }

    /// Warm the chunk cache with one chunk ahead of demand (the serving
    /// layer's hot-set prefetcher drives this; see
    /// [`StoreReader::prefetch_chunk`]). Returns whether a decode happened.
    pub fn prefetch_chunk(&self, name: &str, ci: usize) -> Result<bool> {
        match self {
            StoreHandle::Single(r) => r.prefetch_chunk(name, ci),
            StoreHandle::Sharded(r) => r.prefetch_chunk(name, ci),
        }
    }

    /// Snapshot the cumulative read counters (sharded: aggregated).
    pub fn stats(&self) -> ReadStats {
        match self {
            StoreHandle::Single(r) => r.stats(),
            StoreHandle::Sharded(r) => r.stats(),
        }
    }

    /// `store.*` metrics snapshot (sharded: merged across shards). The
    /// serving engine folds this into its own `serving.*` snapshot so
    /// exporters see one namespace.
    pub fn registry_snapshot(&self) -> crate::obs::RegistrySnapshot {
        match self {
            StoreHandle::Single(r) => r.registry_snapshot(),
            StoreHandle::Sharded(r) => r.registry_snapshot(),
        }
    }

    /// Per-chunk access heat (sharded: concatenated across shards),
    /// sorted `(tensor, chunk)` — see [`super::heat`].
    pub fn heatmap(&self) -> Vec<super::heat::ChunkHeatEntry> {
        match self {
            StoreHandle::Single(r) => r.heatmap(),
            StoreHandle::Sharded(r) => r.heatmap(),
        }
    }

    /// Pin the arithmetic-decode kernel (sharded: every shard).
    pub fn set_decode_kernel(&self, kernel: crate::apack::simd::DecodeKernel) {
        match self {
            StoreHandle::Single(r) => r.set_decode_kernel(kernel),
            StoreHandle::Sharded(r) => r.set_decode_kernel(kernel),
        }
    }

    /// The decode kernel chunk decodes run with.
    pub fn decode_kernel(&self) -> crate::apack::simd::DecodeKernel {
        match self {
            StoreHandle::Single(r) => r.decode_kernel(),
            StoreHandle::Sharded(r) => r.decode_kernel(),
        }
    }

    /// Worker-thread count for lane-parallel chunk-body-v2 decodes
    /// (0/1 = single-threaded; sharded: every shard).
    pub fn set_lane_threads(&self, threads: usize) {
        match self {
            StoreHandle::Single(r) => r.set_lane_threads(threads),
            StoreHandle::Sharded(r) => r.set_lane_threads(threads),
        }
    }

    /// Zero the read counters.
    pub fn reset_stats(&self) {
        match self {
            StoreHandle::Single(r) => r.reset_stats(),
            StoreHandle::Sharded(r) => r.reset_stats(),
        }
    }

    /// Drop all cached chunks.
    pub fn clear_cache(&self) {
        match self {
            StoreHandle::Single(r) => r.clear_cache(),
            StoreHandle::Sharded(r) => r.clear_cache(),
        }
    }

    /// Integrity pass: re-read, CRC-check and decode every chunk (sharded:
    /// shards verify in parallel, chunks fan out within each).
    pub fn verify(&self) -> Result<VerifyReport> {
        match self {
            StoreHandle::Single(r) => r.verify(),
            StoreHandle::Sharded(r) => r.verify(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::tablegen::TensorKind;
    use crate::coordinator::PartitionPolicy;
    use crate::models::distributions::ValueProfile;
    use crate::store::{ShardedStoreWriter, StoreWriter};

    fn tensor(n: usize, seed: u64) -> Vec<u32> {
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, seed)
    }

    #[test]
    fn handle_auto_detects_layout() {
        let base = std::env::temp_dir()
            .join(format!("apack_handle_{}", std::process::id()));
        let file_path = base.with_extension("apackstore");
        let dir_path = base.with_extension("apackstore.d");
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 128 };
        let v = tensor(3000, 5);

        let mut w = StoreWriter::create(&file_path, policy).unwrap();
        w.add_tensor("t", 8, &v, TensorKind::Weights).unwrap();
        w.finish().unwrap();
        let mut w = ShardedStoreWriter::create(&dir_path, 2, policy).unwrap();
        w.add_tensor("t", 8, &v, TensorKind::Weights).unwrap();
        w.finish().unwrap();

        let single = StoreHandle::open(&file_path).unwrap();
        let sharded = StoreHandle::open(&dir_path).unwrap();
        assert!(matches!(single, StoreHandle::Single(_)));
        assert!(matches!(sharded, StoreHandle::Sharded(_)));
        assert_eq!(single.shard_count(), 1);
        assert_eq!(sharded.shard_count(), 2);
        assert_eq!(single.tensor_count(), 1);
        assert_eq!(sharded.tensor_count(), 1);

        // Identical data through either layout, plus uniform stats/verify.
        assert_eq!(single.get_tensor("t").unwrap(), v);
        assert_eq!(sharded.get_tensor("t").unwrap(), v);
        assert_eq!(single.get_range("t", 100..200).unwrap(), &v[100..200]);
        assert_eq!(sharded.get_range("t", 100..200).unwrap(), &v[100..200]);
        assert_eq!(single.meta("t").unwrap().n_values, 3000);
        assert_eq!(sharded.meta("t").unwrap().n_values, 3000);
        assert!(single.verify().unwrap().chunks > 0);
        assert_eq!(sharded.verify().unwrap().shards, 2);
        assert!(single.stats().bytes_read > 0);
        assert_eq!(single.tensor_metas().len(), 1);
        assert_eq!(sharded.tensor_metas().len(), 1);

        std::fs::remove_file(&file_path).ok();
        std::fs::remove_dir_all(&dir_path).ok();
    }
}
