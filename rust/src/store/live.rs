//! Live stores: crash-safe mutation of sealed stores (DESIGN.md §14).
//!
//! A classic store is write-once: [`super::writer::StoreWriter`] seals it
//! and nothing ever changes. This module makes the store *mutable without
//! ever being unopenable*: new tensor versions and tombstones are
//! committed as atomically-flipped footer **generations**, and
//! [`compact_store`] / [`compact_sharded_store`] rewrite only the live
//! generation to reclaim superseded bytes.
//!
//! # Commit protocol (single file)
//!
//! ```text
//! 1. ensure pointer   <store>.gen names the committed generation
//!                     (written tmp + fsync + rename BEFORE any data
//!                     write, so a mid-append classic EOF open is never
//!                     needed — the pointer always wins)
//! 2. append bytes     chunk blobs past the committed tail (positioned
//!                     writes; a torn tail here is invisible: the pointer
//!                     still names the old trailer)
//! 3. seal             GenRecord | footer | trailer, truncate to the new
//!                     committed length, fsync the data file
//! 4. flip             <store>.gen.tmp (write + fsync) renamed over
//!                     <store>.gen — THE commit point
//! ```
//!
//! A crash at *any* boundary leaves the previous committed generation the
//! winner on reopen; every boundary is enumerated through
//! [`FaultPlan::write_boundary`] so the crash-matrix tests can kill each
//! one in turn. Sharded stores use the MANIFEST as the pointer: each
//! dirty shard seals (steps 2–3), then one atomic v2 MANIFEST write
//! commits them all.
//!
//! # Compaction
//!
//! Compaction rewrites the committed generation's chunk bytes *verbatim*
//! (same CRCs, re-based offsets — never a re-encode) into
//! `<store>.compact.tmp`, seals it as a fresh generation with no in-file
//! parent, then: truncates the source to its committed length (so the
//! classic EOF open agrees with the pointer), removes the pointer, and
//! renames the compacted file into place. Each step preserves
//! openability: before the rename the old file opens (pointer or classic
//! EOF, same generation); after it the compacted file opens classic.
//! [`super::handle::StoreHandle::compact_live`] runs this while serving —
//! pinned readers keep the old inode alive until they drop.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::coordinator::PartitionPolicy;
use crate::error::{Error, Result};
use crate::models::zoo::ModelConfig;

use super::format::{
    crc32, gen_pointer_path, trailer_bytes, ChunkMeta, GenPointer, GenRecord, StoreFormat,
    StoreIndex, TensorMeta, GEN_RECORD_BYTES, STORE_MAGIC, TRAILER_BYTES,
};
use super::io::{Backend, FaultPlan};
use super::pipeline::{pack_zoo_into, PackOptions, TensorSink};
use super::reader::StoreReader;
use super::shard::{
    shard_file_name, shard_for_name, write_manifest_atomic, ShardEntry, ShardManifest,
    MANIFEST_FILE,
};
use super::writer::EncodedTensor;

/// Positioned write (pwrite on unix); the appender never moves a shared
/// file cursor, mirroring [`super::io::FileSource`]'s positioned reads.
fn write_all_at(file: &File, offset: u64, buf: &[u8]) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.write_all_at(buf, offset)?;
    }
    #[cfg(not(unix))]
    {
        use std::io::{Seek, SeekFrom, Write};
        let mut f = file;
        f.seek(SeekFrom::Start(offset))?;
        f.write_all(buf)?;
    }
    Ok(())
}

/// Positioned read (pread on unix).
fn read_exact_at(file: &File, offset: u64, buf: &mut [u8]) -> Result<()> {
    #[cfg(unix)]
    {
        use std::os::unix::fs::FileExt;
        file.read_exact_at(buf, offset)?;
    }
    #[cfg(not(unix))]
    {
        use std::io::{Read, Seek, SeekFrom};
        let mut f = file;
        f.seek(SeekFrom::Start(offset))?;
        f.read_exact(buf)?;
    }
    Ok(())
}

fn boundary(plan: &Option<FaultPlan>, op: &str) -> Result<()> {
    match plan {
        Some(p) => p.write_boundary(op),
        None => Ok(()),
    }
}

/// What one committed append changed.
#[derive(Debug, Clone, Copy)]
pub struct AppendSummary {
    /// The newly committed generation (max across shards for sharded
    /// stores).
    pub generation: u32,
    /// Live tensors after the commit.
    pub tensors: usize,
    /// Tensors appended under a fresh name.
    pub tensors_added: usize,
    /// Tensors appended over an existing name (the old version stays
    /// readable through its generation until compaction).
    pub tensors_replaced: usize,
    /// Tensors tombstoned out of the live index.
    pub tombstoned: usize,
    /// Chunk-blob bytes written by this append.
    pub bytes_written: u64,
    /// Committed store size after the flip (shard files + manifest for
    /// sharded stores).
    pub file_bytes: u64,
}

/// What one compaction reclaimed.
#[derive(Debug, Clone, Copy)]
pub struct CompactSummary {
    /// Generation of the compacted store (parentless — the history chain
    /// restarts here).
    pub generation: u32,
    pub tensors: usize,
    pub chunks: usize,
    /// Committed bytes before / after the rewrite.
    pub bytes_before: u64,
    pub bytes_after: u64,
}

impl CompactSummary {
    /// Bytes the rewrite reclaimed.
    pub fn reclaimed(&self) -> u64 {
        self.bytes_before.saturating_sub(self.bytes_after)
    }
}

/// One generation in a store's history chain (`store versions`).
#[derive(Debug, Clone, Copy)]
pub struct GenerationInfo {
    /// Shard index for sharded stores; `None` for single files.
    pub shard: Option<usize>,
    pub generation: u32,
    /// Absolute offset of this generation's trailer record.
    pub trailer_offset: u64,
    /// Live tensors in this generation.
    pub tensors: u32,
    /// File length when this generation was committed.
    pub committed_len: u64,
}

/// Result of sealing one new generation into a data file (before the
/// pointer/manifest flip that commits it).
#[derive(Debug, Clone, Copy)]
struct SealInfo {
    generation: u32,
    trailer_offset: u64,
    committed_len: u64,
    tensors: usize,
}

/// Appends a new footer generation to a sealed single-file store.
///
/// Opening takes a snapshot of the committed index; [`Self::append_encoded`]
/// and [`Self::tombstone`] mutate the snapshot and stream chunk bytes past
/// the committed tail; [`Self::commit`] seals the new generation and flips
/// the `<store>.gen` pointer. Dropping without committing leaves the store
/// exactly as opened — the torn tail is invisible behind the pointer.
pub struct StoreAppender {
    path: PathBuf,
    file: File,
    format: StoreFormat,
    /// Committed generation this append builds on.
    generation: u32,
    /// Committed trailer offset (becomes the new generation's parent).
    parent_trailer_offset: u64,
    /// Next byte to write (starts at the committed file length).
    write_pos: u64,
    /// The live index this append is building.
    tensors: Vec<TensorMeta>,
    plan: Option<FaultPlan>,
    added: usize,
    replaced: usize,
    tombstoned: usize,
    bytes_written: u64,
}

impl StoreAppender {
    /// Open a single-file store for appending.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_opts(path, None)
    }

    /// [`Self::open`] with a [`FaultPlan`] whose kill-point lattice covers
    /// every write/fsync/rename boundary of the append path.
    pub fn open_opts(path: &Path, plan: Option<&FaultPlan>) -> Result<Self> {
        let mut a = Self::open_shard(path, None, plan)?;
        // Make sure the pointer exists and is valid BEFORE any byte is
        // appended: once data grows past the committed trailer, the
        // classic exact-EOF open stops working, so the pointer must
        // already name the committed generation.
        let ptr_path = gen_pointer_path(path);
        let have_valid = std::fs::read(&ptr_path)
            .ok()
            .is_some_and(|b| GenPointer::from_bytes(&b).is_ok());
        if !have_valid {
            let ptr = GenPointer {
                generation: a.generation,
                trailer_offset: a.parent_trailer_offset,
                committed_len: a.parent_trailer_offset + TRAILER_BYTES as u64,
            };
            a.write_pointer(&ptr, "append.ptr_init_write", "append.ptr_init_sync",
                "append.ptr_init_rename")?;
        }
        Ok(a)
    }

    /// Open one file of a store for appending *without* sidecar-pointer
    /// management — the sharded appender's path, where the MANIFEST is the
    /// pointer. `committed` forces the trailer offset (from the manifest);
    /// `None` resolves it like [`StoreReader::open_with`].
    fn open_shard(
        path: &Path,
        committed: Option<u64>,
        plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        let reader = match committed {
            Some(at) => StoreReader::open_at(path, Backend::File, 0, at, plan)?,
            None => StoreReader::open_opts(path, Backend::File, 0, plan)?,
        };
        let generation = reader.generation();
        let parent_trailer_offset = reader.trailer_offset();
        let tensors = reader.index().tensors.clone();
        drop(reader);
        let file = std::fs::OpenOptions::new().read(true).write(true).open(path)?;
        let mut magic = [0u8; 8];
        read_exact_at(&file, 0, &mut magic)?;
        let format = StoreFormat::from_magic(&magic)?;
        Ok(Self {
            path: path.to_path_buf(),
            file,
            format,
            generation,
            parent_trailer_offset,
            write_pos: parent_trailer_offset + TRAILER_BYTES as u64,
            tensors,
            plan: plan.cloned(),
            added: 0,
            replaced: 0,
            tombstoned: 0,
            bytes_written: 0,
        })
    }

    /// The committed generation this append builds on.
    pub fn generation(&self) -> u32 {
        self.generation
    }

    /// Live tensors in the uncommitted index.
    pub fn tensor_count(&self) -> usize {
        self.tensors.len()
    }

    fn boundary(&self, op: &str) -> Result<()> {
        boundary(&self.plan, op)
    }

    /// Append a pre-encoded tensor as the live version of its name. A
    /// fresh name is an add; an existing name is a **replace** (the old
    /// version stays readable through its own generation until
    /// compaction). Bytes land past the committed tail via positioned
    /// writes — nothing committed is ever touched.
    pub fn append_encoded(&mut self, t: EncodedTensor) -> Result<()> {
        if t.name.is_empty() || t.name.len() > u16::MAX as usize {
            return Err(Error::Store(format!(
                "tensor name length {} invalid",
                t.name.len()
            )));
        }
        if self.format == StoreFormat::V1 && t.body_version != 1 {
            return Err(Error::Store(format!(
                "tensor {:?} uses body v{}, but this APACKST1 file can only \
                 describe v1 bodies",
                t.name, t.body_version
            )));
        }
        let mut metas = Vec::with_capacity(t.chunks.len());
        for chunk in &t.chunks {
            self.boundary("append.chunk")?;
            write_all_at(&self.file, self.write_pos, &chunk.body)?;
            metas.push(ChunkMeta {
                offset: self.write_pos,
                len: chunk.body.len() as u64,
                n_values: chunk.n_values,
                crc32: crc32(&chunk.body),
            });
            self.write_pos += chunk.body.len() as u64;
            self.bytes_written += chunk.body.len() as u64;
        }
        if let Some(i) = self.tensors.iter().position(|m| m.name == t.name) {
            self.tensors.remove(i);
            self.replaced += 1;
        } else {
            self.added += 1;
        }
        self.tensors.push(TensorMeta {
            name: t.name,
            bits: t.table.bits(),
            kind: t.kind,
            n_values: t.n_values,
            values_per_chunk: t.values_per_chunk,
            body_version: t.body_version,
            lanes: t.lanes,
            table: t.table,
            chunks: metas,
        });
        Ok(())
    }

    /// Remove a tensor from the live index. Returns whether the name was
    /// present; its bytes are reclaimed by the next compaction.
    pub fn tombstone(&mut self, name: &str) -> bool {
        match self.tensors.iter().position(|m| m.name == name) {
            Some(i) => {
                self.tensors.remove(i);
                self.tombstoned += 1;
                true
            }
            None => false,
        }
    }

    /// Seal the new generation into the data file: GenRecord | footer |
    /// trailer, truncate to the committed length, fsync. The generation is
    /// *not yet committed* — that is the pointer (or manifest) flip.
    fn seal(&mut self) -> Result<SealInfo> {
        let generation = self.generation + 1;
        let record = GenRecord {
            generation,
            parent_trailer_offset: self.parent_trailer_offset,
        };
        self.boundary("commit.record")?;
        write_all_at(&self.file, self.write_pos, &record.to_bytes())?;
        let footer_offset = self.write_pos + GEN_RECORD_BYTES as u64;
        let index = StoreIndex::new(std::mem::take(&mut self.tensors));
        let footer = index.to_bytes(self.format);
        self.boundary("commit.footer")?;
        write_all_at(&self.file, footer_offset, &footer)?;
        let trailer_offset = footer_offset + footer.len() as u64;
        let trailer = trailer_bytes(
            footer_offset,
            footer.len() as u64,
            crc32(&footer),
            index.tensors.len() as u32,
        );
        self.boundary("commit.trailer")?;
        write_all_at(&self.file, trailer_offset, &trailer)?;
        let committed_len = trailer_offset + TRAILER_BYTES as u64;
        // Cut any torn garbage a previous crashed append left past the new
        // trailer, so the committed trailer abuts EOF again.
        self.boundary("commit.truncate")?;
        self.file.set_len(committed_len)?;
        self.boundary("commit.sync")?;
        self.file.sync_data()?;
        let tensors = index.tensors.len();
        self.tensors = index.tensors;
        Ok(SealInfo { generation, trailer_offset, committed_len, tensors })
    }

    /// Write the sidecar pointer atomically: tmp + fsync + rename, then a
    /// best-effort directory fsync.
    fn write_pointer(
        &self,
        ptr: &GenPointer,
        op_write: &str,
        op_sync: &str,
        op_rename: &str,
    ) -> Result<()> {
        let final_path = gen_pointer_path(&self.path);
        let mut os = final_path.as_os_str().to_os_string();
        os.push(".tmp");
        let tmp = PathBuf::from(os);
        self.boundary(op_write)?;
        let mut f = File::create(&tmp)?;
        f.write_all(&ptr.to_bytes())?;
        self.boundary(op_sync)?;
        f.sync_data()?;
        drop(f);
        self.boundary(op_rename)?;
        std::fs::rename(&tmp, &final_path)?;
        if let Some(dir) = final_path.parent() {
            if let Ok(d) = File::open(dir) {
                let _ = d.sync_all();
            }
        }
        Ok(())
    }

    /// Seal the new generation and atomically flip the `<store>.gen`
    /// pointer to it — the commit point. A crash anywhere before the
    /// rename leaves the previous generation committed.
    pub fn commit(mut self) -> Result<AppendSummary> {
        let sealed = self.seal()?;
        let ptr = GenPointer {
            generation: sealed.generation,
            trailer_offset: sealed.trailer_offset,
            committed_len: sealed.committed_len,
        };
        self.write_pointer(&ptr, "commit.ptr_write", "commit.ptr_sync", "commit.ptr_rename")?;
        Ok(AppendSummary {
            generation: sealed.generation,
            tensors: sealed.tensors,
            tensors_added: self.added,
            tensors_replaced: self.replaced,
            tombstoned: self.tombstoned,
            bytes_written: self.bytes_written,
            file_bytes: sealed.committed_len,
        })
    }
}

impl TensorSink for StoreAppender {
    fn append(&mut self, t: EncodedTensor) -> Result<()> {
        self.append_encoded(t)
    }
}

/// Appends new footer generations across a sharded store. Per-shard
/// appends/seals follow [`StoreAppender`] (without sidecar pointers);
/// the single atomic v2 MANIFEST write is the commit point for all
/// shards at once.
pub struct ShardedStoreAppender {
    dir: PathBuf,
    shards: Vec<StoreAppender>,
    entries: Vec<ShardEntry>,
    dirty: Vec<bool>,
    plan: Option<FaultPlan>,
}

impl ShardedStoreAppender {
    /// Open a sharded store directory for appending.
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_opts(dir, None)
    }

    /// [`Self::open`] with a [`FaultPlan`] shared by every shard's write
    /// boundaries (one global kill-point lattice across the whole commit).
    pub fn open_opts(dir: &Path, plan: Option<&FaultPlan>) -> Result<Self> {
        let bytes = std::fs::read(dir.join(MANIFEST_FILE)).map_err(|e| {
            Error::ManifestCorrupt(format!("cannot read MANIFEST in {}: {e}", dir.display()))
        })?;
        let manifest = ShardManifest::from_bytes(&bytes)?;
        let mut shards = Vec::with_capacity(manifest.entries.len());
        for (i, e) in manifest.entries.iter().enumerate() {
            let path = dir.join(shard_file_name(i));
            if !path.exists() {
                return Err(Error::ShardMissing { shard: shard_file_name(i) });
            }
            shards.push(StoreAppender::open_shard(&path, Some(e.trailer_offset), plan)?);
        }
        let dirty = vec![false; shards.len()];
        Ok(Self {
            dir: dir.to_path_buf(),
            shards,
            entries: manifest.entries,
            dirty,
            plan: plan.cloned(),
        })
    }

    /// Live tensors across all shards' uncommitted indexes.
    pub fn tensor_count(&self) -> usize {
        self.shards.iter().map(|s| s.tensor_count()).sum()
    }

    /// Append to the tensor's home shard (same FNV-1a routing as the
    /// writer, so replaces always land on the shard holding the old
    /// version).
    pub fn append_encoded(&mut self, t: EncodedTensor) -> Result<()> {
        let s = shard_for_name(&t.name, self.shards.len());
        self.dirty[s] = true;
        self.shards[s].append_encoded(t)
    }

    /// Tombstone a tensor out of its home shard's live index.
    pub fn tombstone(&mut self, name: &str) -> bool {
        let s = shard_for_name(name, self.shards.len());
        let hit = self.shards[s].tombstone(name);
        if hit {
            self.dirty[s] = true;
        }
        hit
    }

    /// Seal every dirty shard, then atomically write the v2 MANIFEST
    /// naming the new generations — the commit point for all shards at
    /// once. Clean shards keep their old manifest entries (and write
    /// nothing).
    pub fn commit(mut self) -> Result<AppendSummary> {
        let mut entries = self.entries.clone();
        for (i, shard) in self.shards.iter_mut().enumerate() {
            if !self.dirty[i] {
                continue;
            }
            let sealed = shard.seal()?;
            entries[i] = ShardEntry {
                tensors: sealed.tensors as u32,
                file_bytes: sealed.committed_len,
                generation: sealed.generation,
                trailer_offset: sealed.trailer_offset,
            };
        }
        boundary(&self.plan, "commit.manifest")?;
        let manifest_len =
            write_manifest_atomic(&self.dir, &ShardManifest { entries: entries.clone() })?;
        Ok(AppendSummary {
            generation: entries.iter().map(|e| e.generation).max().unwrap_or(0),
            tensors: entries.iter().map(|e| e.tensors as usize).sum(),
            tensors_added: self.shards.iter().map(|s| s.added).sum(),
            tensors_replaced: self.shards.iter().map(|s| s.replaced).sum(),
            tombstoned: self.shards.iter().map(|s| s.tombstoned).sum(),
            bytes_written: self.shards.iter().map(|s| s.bytes_written).sum(),
            file_bytes: entries.iter().map(|e| e.file_bytes).sum::<u64>() + manifest_len,
        })
    }
}

impl TensorSink for ShardedStoreAppender {
    fn append(&mut self, t: EncodedTensor) -> Result<()> {
        self.append_encoded(t)
    }
}

/// Delta-ingest: encode `models` through the PR 5 pipelined packer and
/// commit them (plus `tombstones`) onto the store at `path` as one new
/// generation. Auto-detects single-file vs. sharded layout like
/// [`super::handle::StoreHandle::open`]. Existing names are replaced;
/// tombstones are applied before the appends, so a model re-shipping a
/// tombstoned name counts as an add.
pub fn append_models(
    path: &Path,
    models: &[ModelConfig],
    sample_cap: usize,
    policy: &PartitionPolicy,
    opts: &PackOptions,
    tombstones: &[String],
) -> Result<AppendSummary> {
    if path.is_dir() {
        let mut a = ShardedStoreAppender::open(path)?;
        for name in tombstones {
            a.tombstone(name);
        }
        pack_zoo_into(&mut a, models, sample_cap, policy, opts)?;
        a.commit()
    } else {
        let mut a = StoreAppender::open(path)?;
        for name in tombstones {
            a.tombstone(name);
        }
        pack_zoo_into(&mut a, models, sample_cap, policy, opts)?;
        a.commit()
    }
}

/// Rewrite the committed generation of a single-file store, dropping all
/// superseded generations. Chunk bytes are copied **verbatim** (and
/// CRC-checked in flight — compaction refuses to seal corrupt bytes);
/// only their offsets move. Every step keeps the store openable:
///
/// 1. write + fsync `<path>.compact.tmp` (a parentless generation);
/// 2. truncate the source to its committed length + fsync (the classic
///    EOF open now agrees with the pointer);
/// 3. remove the `<path>.gen` pointer (classic EOF still opens the same
///    generation);
/// 4. rename the compacted file into place (classic EOF opens the
///    compacted generation).
pub fn compact_store(path: &Path, plan: Option<&FaultPlan>) -> Result<CompactSummary> {
    let reader = StoreReader::open_with(path, Backend::File, 0)?;
    let generation = reader.generation();
    let committed_len = reader.trailer_offset() + TRAILER_BYTES as u64;
    let tensors: Vec<TensorMeta> = reader.index().tensors.clone();
    drop(reader);
    let plan = plan.cloned();

    let src = File::open(path)?;
    let bytes_before = src.metadata()?.len();
    let mut magic = [0u8; 8];
    read_exact_at(&src, 0, &mut magic)?;
    let format = StoreFormat::from_magic(&magic)?;

    let mut os = path.as_os_str().to_os_string();
    os.push(".compact.tmp");
    let tmp_path = PathBuf::from(os);
    let mut out = std::io::BufWriter::new(File::create(&tmp_path)?);
    out.write_all(&magic)?;
    let mut offset = STORE_MAGIC.len() as u64;
    let mut new_tensors = Vec::with_capacity(tensors.len());
    let mut chunk_count = 0usize;
    for t in &tensors {
        let mut chunks = Vec::with_capacity(t.chunks.len());
        for (ci, c) in t.chunks.iter().enumerate() {
            boundary(&plan, "compact.write")?;
            let mut buf = vec![0u8; c.len as usize];
            read_exact_at(&src, c.offset, &mut buf)?;
            if crc32(&buf) != c.crc32 {
                return Err(Error::Store(format!(
                    "tensor {}: chunk {ci} failed its CRC during compaction — \
                     refusing to seal corrupt bytes",
                    t.name
                )));
            }
            out.write_all(&buf)?;
            chunks.push(ChunkMeta { offset, len: c.len, n_values: c.n_values, crc32: c.crc32 });
            offset += c.len;
            chunk_count += 1;
        }
        new_tensors.push(TensorMeta { chunks, ..t.clone() });
    }
    let next_gen = generation + 1;
    boundary(&plan, "compact.record")?;
    out.write_all(&GenRecord { generation: next_gen, parent_trailer_offset: 0 }.to_bytes())?;
    let footer_offset = offset + GEN_RECORD_BYTES as u64;
    let index = StoreIndex::new(new_tensors);
    let footer = index.to_bytes(format);
    boundary(&plan, "compact.footer")?;
    out.write_all(&footer)?;
    let trailer_offset = footer_offset + footer.len() as u64;
    boundary(&plan, "compact.trailer")?;
    out.write_all(&trailer_bytes(
        footer_offset,
        footer.len() as u64,
        crc32(&footer),
        index.tensors.len() as u32,
    ))?;
    out.flush()?;
    boundary(&plan, "compact.sync")?;
    out.get_ref().sync_data()?;
    drop(out);

    // Steps 2–4: see the function doc. Order matters — each step leaves
    // the store openable at the same (or the compacted) generation.
    boundary(&plan, "compact.truncate")?;
    let fixup = std::fs::OpenOptions::new().write(true).open(path)?;
    fixup.set_len(committed_len)?;
    fixup.sync_data()?;
    drop(fixup);
    boundary(&plan, "compact.ptr_remove")?;
    match std::fs::remove_file(gen_pointer_path(path)) {
        Err(e) if e.kind() != std::io::ErrorKind::NotFound => return Err(e.into()),
        _ => {}
    }
    boundary(&plan, "compact.rename")?;
    std::fs::rename(&tmp_path, path)?;
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(CompactSummary {
        generation: next_gen,
        tensors: index.tensors.len(),
        chunks: chunk_count,
        bytes_before,
        bytes_after: trailer_offset + TRAILER_BYTES as u64,
    })
}

/// [`compact_store`] across a sharded directory: every shard is rewritten
/// (tmp + fsync + rename — shards have no sidecar pointers), then one
/// atomic v2 MANIFEST write commits the new generations. A crash between
/// shard renames is harmless: the stale manifest entries fail their
/// strict opens and fall back to the classic EOF open of the compacted
/// shard, whose *content* is identical by construction.
pub fn compact_sharded_store(dir: &Path, plan: Option<&FaultPlan>) -> Result<CompactSummary> {
    let manifest_path = dir.join(MANIFEST_FILE);
    let bytes = std::fs::read(&manifest_path).map_err(|e| {
        Error::ManifestCorrupt(format!("cannot read MANIFEST in {}: {e}", dir.display()))
    })?;
    let manifest = ShardManifest::from_bytes(&bytes)?;
    let bytes_before = manifest.entries.iter().map(|e| e.file_bytes).sum::<u64>()
        + bytes.len() as u64;
    let mut entries = Vec::with_capacity(manifest.entries.len());
    let mut tensors = 0usize;
    let mut chunks = 0usize;
    let mut generation = 0u32;
    for i in 0..manifest.entries.len() {
        let shard_path = dir.join(shard_file_name(i));
        let s = compact_store(&shard_path, plan)?;
        tensors += s.tensors;
        chunks += s.chunks;
        generation = generation.max(s.generation);
        entries.push(ShardEntry {
            tensors: s.tensors as u32,
            file_bytes: s.bytes_after,
            generation: s.generation,
            trailer_offset: s.bytes_after - TRAILER_BYTES as u64,
        });
    }
    let plan = plan.cloned();
    boundary(&plan, "compact.manifest")?;
    let manifest_len = write_manifest_atomic(dir, &ShardManifest { entries: entries.clone() })?;
    Ok(CompactSummary {
        generation,
        tensors,
        chunks,
        bytes_before,
        bytes_after: entries.iter().map(|e| e.file_bytes).sum::<u64>() + manifest_len,
    })
}

/// Walk the generation chain of the store at `path`, newest first
/// (single file: the committed generation back through each
/// [`GenRecord`]'s parent; sharded: every shard's chain, stamped with its
/// shard index). Classic write-once stores report one generation-0 entry.
pub fn store_versions(path: &Path) -> Result<Vec<GenerationInfo>> {
    if path.is_dir() {
        let bytes = std::fs::read(path.join(MANIFEST_FILE)).map_err(|e| {
            Error::ManifestCorrupt(format!(
                "cannot read MANIFEST in {}: {e}",
                path.display()
            ))
        })?;
        let manifest = ShardManifest::from_bytes(&bytes)?;
        let mut out = Vec::new();
        for (i, e) in manifest.entries.iter().enumerate() {
            let mut chain = versions_chain(&path.join(shard_file_name(i)), Some(e.trailer_offset))?;
            for g in &mut chain {
                g.shard = Some(i);
            }
            out.extend(chain);
        }
        Ok(out)
    } else {
        versions_chain(path, None)
    }
}

/// Walk one file's generation chain from its committed trailer (the
/// sidecar pointer, or EOF when there is none) back through the
/// [`GenRecord`] parent offsets.
fn versions_chain(path: &Path, committed: Option<u64>) -> Result<Vec<GenerationInfo>> {
    let file = File::open(path)?;
    let file_len = file.metadata()?.len();
    let mut at = match committed {
        Some(at) => at,
        None => {
            let ptr = std::fs::read(gen_pointer_path(path))
                .ok()
                .and_then(|b| GenPointer::from_bytes(&b).ok());
            match ptr {
                Some(p) => p.trailer_offset,
                None => file_len.checked_sub(TRAILER_BYTES as u64).ok_or_else(|| {
                    Error::Store(format!("file is {file_len} bytes, smaller than a trailer"))
                })?,
            }
        }
    };
    let mut out = Vec::new();
    loop {
        let mut buf = [0u8; TRAILER_BYTES];
        read_exact_at(&file, at, &mut buf)?;
        let trailer = super::format::parse_trailer(&buf)?;
        let record = trailer
            .footer_offset
            .checked_sub(GEN_RECORD_BYTES as u64)
            .filter(|&r| r >= STORE_MAGIC.len() as u64)
            .and_then(|r| {
                let mut rb = [0u8; GEN_RECORD_BYTES];
                read_exact_at(&file, r, &mut rb).ok()?;
                GenRecord::from_bytes(&rb)
            });
        let (generation, parent) = record
            .map(|r| (r.generation, r.parent_trailer_offset))
            .unwrap_or((0, 0));
        out.push(GenerationInfo {
            shard: None,
            generation,
            trailer_offset: at,
            tensors: trailer.tensor_count,
            committed_len: at + TRAILER_BYTES as u64,
        });
        if parent == 0 {
            break;
        }
        if parent >= at {
            return Err(Error::Store(format!(
                "generation chain does not descend: parent trailer {parent} >= {at}"
            )));
        }
        at = parent;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::tablegen::TensorKind;
    use crate::store::format::BodyConfig;
    use crate::store::io::FaultConfig;
    use crate::store::shard::ShardedStoreReader;
    use crate::store::writer::{encode_tensor_with, StoreWriter};

    fn policy() -> PartitionPolicy {
        PartitionPolicy { substreams: 4, min_per_stream: 256 }
    }

    fn tensor(n: usize, seed: u32) -> Vec<u32> {
        (0..n as u32)
            .map(|i| i.wrapping_mul(2_654_435_761).wrapping_add(seed) % 251)
            .collect()
    }

    fn encoded(name: &str, values: &[u32]) -> EncodedTensor {
        encode_tensor_with(
            &policy(),
            BodyConfig::default(),
            name,
            8,
            values,
            TensorKind::Weights,
            None,
            0,
        )
        .unwrap()
    }

    fn store_temp(tag: &str) -> PathBuf {
        std::env::temp_dir()
            .join(format!("apack_live_{tag}_{}.apackstore", std::process::id()))
    }

    fn build_store(tag: &str) -> (PathBuf, Vec<u32>, Vec<u32>) {
        let path = store_temp(tag);
        let a = tensor(6_000, 1);
        let b = tensor(900, 2);
        let mut w = StoreWriter::create(&path, policy()).unwrap();
        w.add_tensor("a", 8, &a, TensorKind::Weights).unwrap();
        w.add_tensor("b", 8, &b, TensorKind::Weights).unwrap();
        w.finish().unwrap();
        (path, a, b)
    }

    fn cleanup(path: &Path) {
        if path.is_dir() {
            std::fs::remove_dir_all(path).ok();
        } else {
            std::fs::remove_file(path).ok();
        }
        std::fs::remove_file(gen_pointer_path(path)).ok();
        let mut os = gen_pointer_path(path).into_os_string();
        os.push(".tmp");
        std::fs::remove_file(PathBuf::from(os)).ok();
    }

    #[test]
    fn append_replace_tombstone_commit_roundtrip() {
        let (path, _a, b) = build_store("roundtrip");
        let a2 = tensor(6_000, 40);
        let c = tensor(3_000, 41);
        let mut app = StoreAppender::open(&path).unwrap();
        assert_eq!(app.generation(), 0);
        app.append_encoded(encoded("a", &a2)).unwrap();
        app.append_encoded(encoded("c", &c)).unwrap();
        assert!(app.tombstone("b"));
        assert!(!app.tombstone("nonexistent"));
        let summary = app.commit().unwrap();
        assert_eq!(summary.generation, 1);
        assert_eq!(summary.tensors, 2);
        assert_eq!(summary.tensors_added, 1);
        assert_eq!(summary.tensors_replaced, 1);
        assert_eq!(summary.tombstoned, 1);
        assert!(summary.bytes_written > 0);

        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.generation(), 1);
        assert_eq!(r.get_tensor("a").unwrap(), a2);
        assert_eq!(r.get_tensor("c").unwrap(), c);
        assert!(r.meta("b").is_err());
        r.verify().unwrap();

        // The parent generation stays pinned and readable at its trailer.
        let versions = store_versions(&path).unwrap();
        assert_eq!(versions.len(), 2);
        assert_eq!((versions[0].generation, versions[1].generation), (1, 0));
        let old = StoreReader::open_at(
            &path,
            Backend::File,
            0,
            versions[1].trailer_offset,
            None,
        )
        .unwrap();
        assert_eq!(old.get_tensor("b").unwrap(), b);
        cleanup(&path);
    }

    #[test]
    fn crash_before_pointer_flip_keeps_previous_generation() {
        // Learn the boundary count from a clean run, then kill the very
        // last boundary (the pointer rename) on a fresh copy.
        let (path, a, b) = build_store("crash_learn");
        let probe = FaultPlan::new(FaultConfig::default());
        let mut app = StoreAppender::open_opts(&path, Some(&probe)).unwrap();
        app.append_encoded(encoded("c", &tensor(3_000, 50))).unwrap();
        app.commit().unwrap();
        let boundaries = probe.boundaries_seen();
        assert!(boundaries > 5, "expected a real lattice, saw {boundaries}");
        cleanup(&path);

        let (path, _, _) = build_store("crash_kill");
        let committed = std::fs::metadata(&path).unwrap().len();
        let plan = FaultPlan::new(FaultConfig {
            kill_at: Some(boundaries - 1),
            ..FaultConfig::default()
        });
        let mut app = StoreAppender::open_opts(&path, Some(&plan)).unwrap();
        app.append_encoded(encoded("c", &tensor(3_000, 50))).unwrap();
        let err = app.commit().unwrap_err();
        assert!(err.to_string().contains("injected crash"), "{err}");
        assert!(plan.kill_fired());

        // The sealed-but-uncommitted generation is a torn tail: bigger
        // file, same committed content.
        assert!(std::fs::metadata(&path).unwrap().len() > committed);
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.generation(), 0);
        assert_eq!(r.get_tensor("a").unwrap(), a);
        assert_eq!(r.get_tensor("b").unwrap(), b);
        r.verify().unwrap();
        drop(r);

        // Recovery: a fresh append overwrites the torn tail and commits.
        let c = tensor(3_000, 50);
        let mut app = StoreAppender::open(&path).unwrap();
        app.append_encoded(encoded("c", &c)).unwrap();
        let summary = app.commit().unwrap();
        assert_eq!(summary.generation, 1);
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.get_tensor("c").unwrap(), c);
        assert_eq!(r.get_tensor("a").unwrap(), a);
        cleanup(&path);
    }

    #[test]
    fn compact_reclaims_superseded_generations() {
        let (path, _a, b) = build_store("compact");
        let a2 = tensor(6_000, 60);
        let mut app = StoreAppender::open(&path).unwrap();
        app.append_encoded(encoded("a", &a2)).unwrap();
        app.commit().unwrap();

        let summary = compact_store(&path, None).unwrap();
        assert_eq!(summary.generation, 2);
        assert_eq!(summary.tensors, 2);
        assert!(summary.reclaimed() > 0, "{summary:?}");
        assert!(
            !gen_pointer_path(&path).exists(),
            "compaction must drop the stale pointer"
        );

        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.generation(), 2);
        assert_eq!(r.get_tensor("a").unwrap(), a2);
        assert_eq!(r.get_tensor("b").unwrap(), b);
        r.verify().unwrap();
        drop(r);

        // The chain restarts: one parentless generation.
        let versions = store_versions(&path).unwrap();
        assert_eq!(versions.len(), 1);
        assert_eq!(versions[0].generation, 2);
        cleanup(&path);
    }

    #[test]
    fn sharded_append_and_compact_roundtrip() {
        use crate::store::shard::ShardedStoreWriter;
        let dir = store_temp("sharded_live").with_extension("d");
        std::fs::remove_dir_all(&dir).ok();
        let names: Vec<String> = (0..6).map(|i| format!("s{i}")).collect();
        let mut w = ShardedStoreWriter::create(&dir, 3, policy()).unwrap();
        for (i, name) in names.iter().enumerate() {
            w.add_tensor(name, 8, &tensor(2_000, 70 + i as u32), TensorKind::Weights)
                .unwrap();
        }
        w.finish().unwrap();

        let s0v2 = tensor(2_000, 90);
        let extra = tensor(1_500, 91);
        let mut app = ShardedStoreAppender::open(&dir).unwrap();
        app.append_encoded(encoded("s0", &s0v2)).unwrap();
        app.append_encoded(encoded("extra", &extra)).unwrap();
        assert!(app.tombstone("s1"));
        let summary = app.commit().unwrap();
        assert!(summary.generation >= 1);
        assert_eq!(summary.tensors, 6);
        assert_eq!(summary.tensors_replaced, 1);
        assert_eq!(summary.tensors_added, 1);
        assert_eq!(summary.tombstoned, 1);

        let r = ShardedStoreReader::open(&dir).unwrap();
        assert_eq!(r.get_tensor("s0").unwrap(), s0v2);
        assert_eq!(r.get_tensor("extra").unwrap(), extra);
        assert!(r.meta("s1").is_err());
        assert_eq!(r.get_tensor("s5").unwrap(), tensor(2_000, 75));
        r.verify().unwrap();
        drop(r);

        let compacted = compact_sharded_store(&dir, None).unwrap();
        assert!(compacted.bytes_after <= compacted.bytes_before);
        let r = ShardedStoreReader::open(&dir).unwrap();
        assert_eq!(r.get_tensor("s0").unwrap(), s0v2);
        assert_eq!(r.get_tensor("extra").unwrap(), extra);
        assert!(r.meta("s1").is_err());
        r.verify().unwrap();
        drop(r);

        let versions = store_versions(&dir).unwrap();
        assert_eq!(versions.len(), 3, "one parentless generation per shard");
        assert!(versions.iter().all(|g| g.shard.is_some()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_files_reject_v2_append_bodies() {
        let path = store_temp("v1_guard");
        let mut w = StoreWriter::create_with(&path, policy(), BodyConfig::v1()).unwrap();
        w.add_tensor("a", 8, &tensor(2_000, 3), TensorKind::Weights).unwrap();
        w.finish().unwrap();
        let mut app = StoreAppender::open(&path).unwrap();
        let err = app.append_encoded(encoded("c", &tensor(1_000, 4))).unwrap_err();
        assert!(err.to_string().contains("APACKST1"), "{err}");
        cleanup(&path);
    }
}
