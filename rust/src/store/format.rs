//! The APackStore on-disk format: a single file holding many named
//! compressed tensors with O(1) random access into any chunk.
//!
//! # File layout
//!
//! ```text
//! offset 0         magic, 8 bytes: "APACKST1" or "APACKST2"
//! offset 8         chunk blobs, concatenated in write order. Each blob is
//!                  either a v1 table-less `Container` body
//!                  (`Container::body_to_bytes`):
//!                    n_values u64 | sym_bits u64 | ofs_bits u64
//!                    | symbol stream | offset stream
//!                  or a v2 multi-lane body (`apack::encode_body_v2`,
//!                  DESIGN.md §11):
//!                    version u8 (=2) | lanes u8 | pad u16 | n_values u64
//!                    | lanes × (sym_bits u32 | ofs_bits u32 | crc32 u32)
//!                    | lane payloads
//! footer_offset    footer: `StoreIndex::to_bytes`, per tensor:
//!                    name_len u16 | name UTF-8 | bits u8 | kind u8
//!                    | body_version u8 | lanes u8   (APACKST2 files only)
//!                    | n_values u64 | values_per_chunk u64
//!                    | shared SymbolTable (97 bytes, stored exactly once)
//!                    | chunk_count u32
//!                    | chunk_count × (offset u64 | len u64 | n_values u64
//!                                     | crc32 u32)
//! EOF - 28         trailer, fixed 28 bytes:
//!                    footer_offset u64 | footer_len u64 | footer_crc u32
//!                    | tensor_count u32 | trailer magic "APFT" u32
//! ```
//!
//! All integers are little-endian. Design properties:
//!
//! - **Single shared table per tensor.** Chunks carry only their streams;
//!   the 16-row symbol/probability table (paper §IV) lives once in the
//!   footer, mirroring the hardware where all substreams of a tensor share
//!   one table (§V-B).
//! - **Independently decodable chunks.** Tensors are split into
//!   fixed-value-count chunks by [`crate::coordinator::PartitionPolicy`];
//!   value index `i` lives in chunk `i / values_per_chunk`, so
//!   `get_chunk`/`get_range` touch only the bytes they need — the
//!   fine-grained random access a compression-aware memory path requires.
//! - **Corruption detection everywhere.** Every chunk carries a CRC32
//!   checked on read; the footer carries its own CRC checked on open; all
//!   offsets are bounds-checked against the chunk region before any I/O.
//! - **Appendable.** The index lives at the tail, so writers stream chunk
//!   blobs and seal the file with footer + trailer in one pass. Live
//!   stores (DESIGN.md §14) extend this: further *generations* — new
//!   chunk blobs, a [`GenRecord`], a complete fresh footer and trailer —
//!   are appended past the committed tail and committed by atomically
//!   flipping the sidecar [`GenPointer`] file; a torn tail past the
//!   pointer is ignored on open, so the last sealed generation wins.
//! - **Versioned, backward-compatible.** The leading magic names the file
//!   format ([`StoreFormat`]); per-tensor `body_version`/`lanes` footer
//!   fields exist only in `APACKST2` files, so every v1 file written by
//!   earlier builds parses byte-for-byte as before (the fields default to
//!   v1 single-stream). Readers dispatch chunk decode on the footer's
//!   `body_version` — never by sniffing blob bytes.

use std::collections::BTreeMap;
use std::ops::Range;

use crate::apack::container::META_BYTES;
use crate::apack::tablegen::TensorKind;
use crate::apack::SymbolTable;
use crate::error::{Error, Result};

/// Leading file magic ("APACKST" + format version digit) for v1 files.
pub const STORE_MAGIC: [u8; 8] = *b"APACKST1";

/// Leading file magic for v2 files (footer carries per-tensor
/// `body_version` + `lanes`; chunk bodies may use the v2 lane format).
pub const STORE_MAGIC_V2: [u8; 8] = *b"APACKST2";

/// On-disk *file* format, named by the leading magic. The only difference
/// is the footer schema: v2 footers carry two extra bytes per tensor
/// (`body_version`, `lanes`). Chunk-body framing is a per-tensor property
/// ([`TensorMeta::body_version`]), not a file property — though v1 files
/// can only describe v1 bodies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreFormat {
    V1,
    V2,
}

impl StoreFormat {
    /// The 8-byte leading magic for this format.
    pub fn magic(self) -> [u8; 8] {
        match self {
            StoreFormat::V1 => STORE_MAGIC,
            StoreFormat::V2 => STORE_MAGIC_V2,
        }
    }

    /// Recognize a leading magic; errors on anything else.
    pub fn from_magic(magic: &[u8]) -> Result<Self> {
        if magic == STORE_MAGIC {
            Ok(StoreFormat::V1)
        } else if magic == STORE_MAGIC_V2 {
            Ok(StoreFormat::V2)
        } else {
            Err(Error::Store("bad store magic".into()))
        }
    }
}

/// Which chunk-body framing a tensor's chunks use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BodyVersion {
    /// Single sequential substream per chunk (the seed format).
    V1,
    /// N independent lanes per chunk (`apack::encode_body_v2`).
    #[default]
    V2,
}

impl BodyVersion {
    pub fn as_u8(self) -> u8 {
        match self {
            BodyVersion::V1 => 1,
            BodyVersion::V2 => 2,
        }
    }

    pub fn from_u8(b: u8) -> Result<Self> {
        match b {
            1 => Ok(BodyVersion::V1),
            2 => Ok(BodyVersion::V2),
            other => Err(Error::Store(format!("unknown body version {other}"))),
        }
    }
}

/// Writer-side choice of chunk-body framing: version plus the *requested*
/// lane count for v2 bodies (each chunk clamps it via
/// [`crate::apack::lane_count`], so tiny chunks degrade gracefully — the
/// effective per-chunk count lives in the chunk header, the per-tensor
/// request in the footer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BodyConfig {
    pub version: BodyVersion,
    /// Requested lanes per chunk (v2 only; ignored for v1).
    pub lanes: u8,
}

impl Default for BodyConfig {
    fn default() -> Self {
        Self { version: BodyVersion::V2, lanes: crate::apack::DEFAULT_LANES }
    }
}

impl BodyConfig {
    /// The seed-compatible single-stream configuration: files produced
    /// with this are byte-identical to pre-v2 builds.
    pub fn v1() -> Self {
        Self { version: BodyVersion::V1, lanes: 1 }
    }

    /// v2 bodies with a specific requested lane count.
    pub fn v2(lanes: u8) -> Self {
        Self { version: BodyVersion::V2, lanes }
    }

    /// File format this configuration requires: v1 bodies keep writing
    /// v1 files (bit-compatibility with the seed), v2 bodies need the
    /// extended footer.
    pub fn store_format(self) -> StoreFormat {
        match self.version {
            BodyVersion::V1 => StoreFormat::V1,
            BodyVersion::V2 => StoreFormat::V2,
        }
    }

    /// Effective lane request normalized per body version (v1 is always
    /// exactly one lane). v2 requests are clamped to
    /// `1..=MAX_LANES` and rounded *down* to a power of two — the footer
    /// only admits power-of-two lane counts, so a raw request like 12
    /// must become 8 here rather than produce a store that can never be
    /// reopened.
    pub fn effective_lanes(self) -> u8 {
        match self.version {
            BodyVersion::V1 => 1,
            BodyVersion::V2 => {
                let capped = self.lanes.clamp(1, crate::apack::MAX_LANES);
                // Largest power of two <= capped (capped >= 1, so the
                // shift never exceeds the width).
                1u8 << (7 - capped.leading_zeros())
            }
        }
    }
}

/// Trailer magic ("APFT", little-endian u32 at EOF-4).
pub const FOOTER_MAGIC: u32 = 0x4150_4654;

/// Fixed trailer size at EOF: `footer_offset u64 | footer_len u64 |
/// footer_crc u32 | tensor_count u32 | magic u32`.
pub const TRAILER_BYTES: usize = 8 + 8 + 4 + 4 + 4;

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, the zlib polynomial) — table-driven, built at compile
// time; no external crates in this offline build.
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC32_TABLE: [u32; 256] = crc32_table();

/// CRC32 (IEEE) of `data` — the per-chunk and footer integrity check.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in data {
        c = CRC32_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// Index records.
// ---------------------------------------------------------------------------

/// One chunk's location and integrity record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChunkMeta {
    /// Absolute file offset of the chunk blob.
    pub offset: u64,
    /// Blob length in bytes.
    pub len: u64,
    /// Values encoded in this chunk.
    pub n_values: u64,
    /// CRC32 of the blob bytes.
    pub crc32: u32,
}

/// One tensor's footer entry: identity, shared table, chunk directory.
#[derive(Debug, Clone)]
pub struct TensorMeta {
    pub name: String,
    /// Value bit width (4–16).
    pub bits: u32,
    pub kind: TensorKind,
    /// Total values across chunks.
    pub n_values: u64,
    /// Fixed values per chunk (the last chunk may be shorter). Always ≥ 1.
    pub values_per_chunk: u64,
    /// Chunk-body framing version (1 = single stream, 2 = lanes). Always
    /// 1 in `APACKST1` files, where the footer has no field for it.
    pub body_version: u8,
    /// Requested lanes per chunk for v2 bodies (each chunk's header
    /// records its own effective, possibly smaller, count); 1 for v1.
    pub lanes: u8,
    /// The tensor's shared symbol/probability table, stored exactly once.
    pub table: SymbolTable,
    pub chunks: Vec<ChunkMeta>,
}

impl TensorMeta {
    /// Chunk index holding value `idx` (caller checks `idx < n_values`).
    #[inline]
    pub fn chunk_for_value(&self, idx: u64) -> usize {
        (idx / self.values_per_chunk) as usize
    }

    /// Global value-index range `[lo, hi)` covered by chunk `ci`.
    pub fn chunk_value_range(&self, ci: usize) -> Range<u64> {
        let lo = ci as u64 * self.values_per_chunk;
        let hi = (lo + self.chunks[ci].n_values).min(self.n_values);
        lo..hi
    }

    /// Total compressed payload bytes (chunk blobs only).
    pub fn compressed_bytes(&self) -> u64 {
        self.chunks.iter().map(|c| c.len).sum()
    }

    /// Raw (uncompressed) size in bits at this tensor's bit width.
    pub fn raw_bits(&self) -> u64 {
        self.n_values * self.bits as u64
    }

    /// Compressed footprint in bits under the paper's accounting: streams
    /// plus one `META_BYTES` metadata block per tensor.
    pub fn footprint_bits(&self) -> u64 {
        self.compressed_bytes() * 8 + (META_BYTES as u64) * 8
    }
}

fn kind_to_byte(kind: TensorKind) -> u8 {
    match kind {
        TensorKind::Weights => 0,
        TensorKind::Activations => 1,
    }
}

fn kind_from_byte(b: u8) -> Result<TensorKind> {
    match b {
        0 => Ok(TensorKind::Weights),
        1 => Ok(TensorKind::Activations),
        other => Err(Error::Store(format!("unknown tensor kind byte {other:#x}"))),
    }
}

/// The parsed footer: every tensor's metadata plus a name lookup.
#[derive(Debug, Clone, Default)]
pub struct StoreIndex {
    pub tensors: Vec<TensorMeta>,
    by_name: BTreeMap<String, usize>,
}

impl StoreIndex {
    pub fn new(tensors: Vec<TensorMeta>) -> Self {
        let by_name =
            tensors.iter().enumerate().map(|(i, t)| (t.name.clone(), i)).collect();
        Self { tensors, by_name }
    }

    /// Index of a tensor by name.
    pub fn position(&self, name: &str) -> Option<usize> {
        self.by_name.get(name).copied()
    }

    /// Tensor metadata by name.
    pub fn get(&self, name: &str) -> Option<&TensorMeta> {
        self.position(name).map(|i| &self.tensors[i])
    }

    /// Serialize the footer (without its CRC — the trailer carries that)
    /// in the given file format. `StoreFormat::V1` output is byte-for-byte
    /// the pre-v2 footer and therefore requires every tensor to use v1
    /// bodies (debug-asserted — the writer enforces it at append time).
    pub fn to_bytes(&self, format: StoreFormat) -> Vec<u8> {
        let mut out = Vec::new();
        for t in &self.tensors {
            let name = t.name.as_bytes();
            out.extend_from_slice(&(name.len() as u16).to_le_bytes());
            out.extend_from_slice(name);
            out.push(t.bits as u8);
            out.push(kind_to_byte(t.kind));
            match format {
                StoreFormat::V1 => {
                    debug_assert_eq!(
                        t.body_version, 1,
                        "v1 footers cannot describe v2 bodies"
                    );
                }
                StoreFormat::V2 => {
                    out.push(t.body_version);
                    out.push(t.lanes);
                }
            }
            out.extend_from_slice(&t.n_values.to_le_bytes());
            out.extend_from_slice(&t.values_per_chunk.to_le_bytes());
            out.extend_from_slice(&t.table.to_bytes());
            out.extend_from_slice(&(t.chunks.len() as u32).to_le_bytes());
            for c in &t.chunks {
                out.extend_from_slice(&c.offset.to_le_bytes());
                out.extend_from_slice(&c.len.to_le_bytes());
                out.extend_from_slice(&c.n_values.to_le_bytes());
                out.extend_from_slice(&c.crc32.to_le_bytes());
            }
        }
        out
    }

    /// Parse a footer holding `tensor_count` entries, validating every
    /// record (bounds, table invariants, per-tensor value accounting).
    /// `format` selects the schema: v1 footers carry no body fields
    /// (tensors default to single-stream v1 bodies), v2 footers carry
    /// `body_version` + `lanes` per tensor.
    pub fn from_bytes(data: &[u8], tensor_count: usize, format: StoreFormat) -> Result<Self> {
        let bad = |m: String| Error::Store(m);
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > data.len() {
                return Err(Error::Store(format!(
                    "truncated footer: need {} bytes at {}, have {}",
                    n,
                    *pos,
                    data.len()
                )));
            }
            let s = &data[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let mut tensors = Vec::with_capacity(tensor_count.min(1 << 16));
        for _ in 0..tensor_count {
            let name_len =
                u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
            let name = std::str::from_utf8(take(&mut pos, name_len)?)
                .map_err(|_| bad("tensor name is not UTF-8".into()))?
                .to_string();
            let bits = take(&mut pos, 1)?[0] as u32;
            let kind = kind_from_byte(take(&mut pos, 1)?[0])?;
            let (body_version, lanes) = match format {
                StoreFormat::V1 => (1u8, 1u8),
                StoreFormat::V2 => {
                    let bv = take(&mut pos, 1)?[0];
                    let lanes = take(&mut pos, 1)?[0];
                    BodyVersion::from_u8(bv)
                        .map_err(|_| bad(format!("tensor {name}: bad body version {bv}")))?;
                    if lanes == 0
                        || lanes > crate::apack::MAX_LANES
                        || !lanes.is_power_of_two()
                        || (bv == 1 && lanes != 1)
                    {
                        return Err(bad(format!(
                            "tensor {name}: bad lane count {lanes} for body v{bv}"
                        )));
                    }
                    (bv, lanes)
                }
            };
            let n_values = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            let values_per_chunk =
                u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            if values_per_chunk == 0 {
                return Err(bad(format!("tensor {name}: values_per_chunk is zero")));
            }
            let table = SymbolTable::from_bytes(take(&mut pos, SymbolTable::SERIALIZED_BYTES)?)?;
            if table.bits() != bits {
                return Err(bad(format!(
                    "tensor {name}: table bit width {} != declared {bits}",
                    table.bits()
                )));
            }
            let chunk_count =
                u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
            let mut chunks = Vec::with_capacity(chunk_count.min(1 << 20));
            let mut total = 0u64;
            for ci in 0..chunk_count {
                let offset = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                let len = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                let c_values = u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
                let crc = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
                // Non-last chunks hold exactly `values_per_chunk`; the
                // last at most that. Both bounds matter: `chunk_for_value`
                // divides by `values_per_chunk`, so an oversized chunk
                // would send reads past the chunk directory.
                if ci + 1 < chunk_count && c_values != values_per_chunk {
                    return Err(bad(format!(
                        "tensor {name}: chunk {ci} holds {c_values} values, \
                         expected fixed {values_per_chunk}"
                    )));
                }
                if c_values > values_per_chunk {
                    return Err(bad(format!(
                        "tensor {name}: last chunk holds {c_values} values, \
                         more than values_per_chunk {values_per_chunk}"
                    )));
                }
                total = total
                    .checked_add(c_values)
                    .ok_or_else(|| bad(format!("tensor {name}: value count overflow")))?;
                chunks.push(ChunkMeta { offset, len, n_values: c_values, crc32: crc });
            }
            if total != n_values {
                return Err(bad(format!(
                    "tensor {name}: chunks hold {total} values, header says {n_values}"
                )));
            }
            tensors.push(TensorMeta {
                name,
                bits,
                kind,
                n_values,
                values_per_chunk,
                body_version,
                lanes,
                table,
                chunks,
            });
        }
        if pos != data.len() {
            return Err(bad(format!(
                "footer has {} trailing bytes after {tensor_count} tensors",
                data.len() - pos
            )));
        }
        let idx = Self::new(tensors);
        if idx.by_name.len() != idx.tensors.len() {
            return Err(bad("duplicate tensor names in footer".into()));
        }
        Ok(idx)
    }
}

/// Build the fixed-size trailer record.
pub fn trailer_bytes(
    footer_offset: u64,
    footer_len: u64,
    footer_crc: u32,
    tensor_count: u32,
) -> [u8; TRAILER_BYTES] {
    let mut out = [0u8; TRAILER_BYTES];
    out[0..8].copy_from_slice(&footer_offset.to_le_bytes());
    out[8..16].copy_from_slice(&footer_len.to_le_bytes());
    out[16..20].copy_from_slice(&footer_crc.to_le_bytes());
    out[20..24].copy_from_slice(&tensor_count.to_le_bytes());
    out[24..28].copy_from_slice(&FOOTER_MAGIC.to_le_bytes());
    out
}

/// Parsed trailer fields.
#[derive(Debug, Clone, Copy)]
pub struct Trailer {
    pub footer_offset: u64,
    pub footer_len: u64,
    pub footer_crc: u32,
    pub tensor_count: u32,
}

/// Parse a trailer record (the last [`TRAILER_BYTES`] of the file).
pub fn parse_trailer(data: &[u8]) -> Result<Trailer> {
    if data.len() != TRAILER_BYTES {
        return Err(Error::Store(format!(
            "trailer must be {TRAILER_BYTES} bytes, got {}",
            data.len()
        )));
    }
    let magic = u32::from_le_bytes(data[24..28].try_into().unwrap());
    if magic != FOOTER_MAGIC {
        return Err(Error::Store(format!("bad trailer magic {magic:#010x}")));
    }
    Ok(Trailer {
        footer_offset: u64::from_le_bytes(data[0..8].try_into().unwrap()),
        footer_len: u64::from_le_bytes(data[8..16].try_into().unwrap()),
        footer_crc: u32::from_le_bytes(data[16..20].try_into().unwrap()),
        tensor_count: u32::from_le_bytes(data[20..24].try_into().unwrap()),
    })
}

// ---------------------------------------------------------------------------
// Generations (live stores, DESIGN.md §14).
//
// A *generation* is one committed footer+trailer. Classic write-once stores
// have exactly one, abutting EOF. A live store gains further generations by
// appending chunk blobs + a generation record + a fresh footer + trailer past
// the committed tail, then atomically flipping the sidecar *generation
// pointer* (`<store>.gen`, written tmp + fsync + rename) to the new trailer
// offset. Open order: a valid pointer wins; a missing or invalid pointer
// falls back to the classic exact-EOF trailer. A torn append tail past the
// pointed-to trailer is therefore ignored — the previous sealed generation
// wins.
// ---------------------------------------------------------------------------

/// Magic leading the sidecar generation-pointer file.
pub const GEN_POINTER_MAGIC: [u8; 8] = *b"APGN1\0\0\0";

/// Fixed size of the generation-pointer file: magic (8) | generation u32 |
/// trailer_offset u64 | committed_len u64 | crc32 u32.
pub const GEN_POINTER_BYTES: usize = 8 + 4 + 8 + 8 + 4;

/// Magic leading the in-file generation record ("APGR", little-endian).
pub const GEN_RECORD_MAGIC: u32 = 0x5247_5041;

/// Fixed size of the in-file generation record, written immediately before
/// each generation's footer: magic u32 | generation u32 |
/// parent_trailer_offset u64 | reserved u32 | crc32 u32.
pub const GEN_RECORD_BYTES: usize = 4 + 4 + 8 + 4 + 4;

/// The sidecar pointer naming the committed generation of a live store.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GenPointer {
    /// Committed generation number (0 = the original sealed store).
    pub generation: u32,
    /// Absolute offset of the committed trailer record.
    pub trailer_offset: u64,
    /// Committed file length (`trailer_offset + TRAILER_BYTES`); redundant
    /// with `trailer_offset` and cross-checked on parse.
    pub committed_len: u64,
}

impl GenPointer {
    /// Serialize (magic + fields + CRC over all preceding bytes).
    pub fn to_bytes(&self) -> [u8; GEN_POINTER_BYTES] {
        let mut out = [0u8; GEN_POINTER_BYTES];
        out[0..8].copy_from_slice(&GEN_POINTER_MAGIC);
        out[8..12].copy_from_slice(&self.generation.to_le_bytes());
        out[12..20].copy_from_slice(&self.trailer_offset.to_le_bytes());
        out[20..28].copy_from_slice(&self.committed_len.to_le_bytes());
        let crc = crc32(&out[..28]);
        out[28..32].copy_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and validate [`Self::to_bytes`] output. Any deviation —
    /// size, magic, CRC, or a `committed_len` that disagrees with
    /// `trailer_offset` — is an error; the caller falls back to the
    /// classic exact-EOF open.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let bad = |m: &str| Error::Store(format!("generation pointer: {m}"));
        if data.len() != GEN_POINTER_BYTES {
            return Err(bad(&format!(
                "must be {GEN_POINTER_BYTES} bytes, got {}",
                data.len()
            )));
        }
        if data[0..8] != GEN_POINTER_MAGIC {
            return Err(bad("bad magic"));
        }
        let stored_crc = u32::from_le_bytes(data[28..32].try_into().unwrap());
        if crc32(&data[..28]) != stored_crc {
            return Err(bad("CRC mismatch"));
        }
        let p = Self {
            generation: u32::from_le_bytes(data[8..12].try_into().unwrap()),
            trailer_offset: u64::from_le_bytes(data[12..20].try_into().unwrap()),
            committed_len: u64::from_le_bytes(data[20..28].try_into().unwrap()),
        };
        if p.committed_len != p.trailer_offset + TRAILER_BYTES as u64 {
            return Err(bad("committed_len disagrees with trailer_offset"));
        }
        Ok(p)
    }
}

/// The in-file record stamped immediately before a generation's footer,
/// chaining it to its parent for `store versions` history walks. Absent
/// (or unparseable) in classic write-once stores, which read as
/// generation 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GenRecord {
    /// This generation's number (1-based for appended generations).
    pub generation: u32,
    /// Trailer offset of the parent generation; 0 when there is no
    /// in-file parent (generation 0, or a compacted store).
    pub parent_trailer_offset: u64,
}

impl GenRecord {
    /// Serialize (magic + fields + reserved + CRC over all preceding).
    pub fn to_bytes(&self) -> [u8; GEN_RECORD_BYTES] {
        let mut out = [0u8; GEN_RECORD_BYTES];
        out[0..4].copy_from_slice(&GEN_RECORD_MAGIC.to_le_bytes());
        out[4..8].copy_from_slice(&self.generation.to_le_bytes());
        out[8..16].copy_from_slice(&self.parent_trailer_offset.to_le_bytes());
        // out[16..20] reserved, zero.
        let crc = crc32(&out[..20]);
        out[20..24].copy_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse [`Self::to_bytes`] output; `None` when the bytes are not a
    /// generation record (the caller treats the store as generation 0 —
    /// classic stores have arbitrary footer-adjacent bytes here).
    pub fn from_bytes(data: &[u8]) -> Option<Self> {
        if data.len() != GEN_RECORD_BYTES {
            return None;
        }
        if u32::from_le_bytes(data[0..4].try_into().unwrap()) != GEN_RECORD_MAGIC {
            return None;
        }
        let stored_crc = u32::from_le_bytes(data[20..24].try_into().unwrap());
        if crc32(&data[..20]) != stored_crc {
            return None;
        }
        Some(Self {
            generation: u32::from_le_bytes(data[4..8].try_into().unwrap()),
            parent_trailer_offset: u64::from_le_bytes(data[8..16].try_into().unwrap()),
        })
    }
}

/// Path of the sidecar generation-pointer file for a single-file store.
pub fn gen_pointer_path(path: &std::path::Path) -> std::path::PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".gen");
    std::path::PathBuf::from(os)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE CRC32 check values.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn effective_lanes_rounds_to_power_of_two() {
        // Non-power-of-two requests must round down: the footer rejects
        // anything else, so emitting the raw value would write stores
        // that can never be reopened.
        for (req, want) in [
            (0u8, 1u8),
            (1, 1),
            (2, 2),
            (3, 2),
            (5, 4),
            (12, 8),
            (16, 16),
            (33, 32),
            (crate::apack::MAX_LANES, crate::apack::MAX_LANES),
            (crate::apack::MAX_LANES + 1, crate::apack::MAX_LANES),
            (255, crate::apack::MAX_LANES),
        ] {
            let got = BodyConfig::v2(req).effective_lanes();
            assert_eq!(got, want, "request {req}");
            assert!(got.is_power_of_two());
        }
        assert_eq!(BodyConfig::v1().effective_lanes(), 1);
    }

    #[test]
    fn crc32_detects_single_byte_change() {
        let a = b"hello apack store".to_vec();
        let base = crc32(&a);
        for i in 0..a.len() {
            let mut b = a.clone();
            b[i] ^= 0x01;
            assert_ne!(crc32(&b), base, "flip at {i}");
        }
    }

    fn sample_index() -> StoreIndex {
        let table = SymbolTable::uniform(8);
        StoreIndex::new(vec![
            TensorMeta {
                name: "m/layer000/weights".into(),
                bits: 8,
                kind: TensorKind::Weights,
                n_values: 2500,
                values_per_chunk: 1000,
                body_version: 1,
                lanes: 1,
                table: table.clone(),
                chunks: vec![
                    ChunkMeta { offset: 8, len: 700, n_values: 1000, crc32: 1 },
                    ChunkMeta { offset: 708, len: 650, n_values: 1000, crc32: 2 },
                    ChunkMeta { offset: 1358, len: 380, n_values: 500, crc32: 3 },
                ],
            },
            TensorMeta {
                name: "m/layer000/activations".into(),
                bits: 8,
                kind: TensorKind::Activations,
                n_values: 10,
                values_per_chunk: 10,
                body_version: 1,
                lanes: 1,
                table,
                chunks: vec![ChunkMeta { offset: 1738, len: 40, n_values: 10, crc32: 4 }],
            },
        ])
    }

    #[test]
    fn index_roundtrip() {
        let idx = sample_index();
        let bytes = idx.to_bytes(StoreFormat::V1);
        let parsed = StoreIndex::from_bytes(&bytes, idx.tensors.len(), StoreFormat::V1).unwrap();
        assert_eq!(parsed.tensors.len(), 2);
        let t = parsed.get("m/layer000/weights").unwrap();
        assert_eq!(t.n_values, 2500);
        assert_eq!(t.chunks.len(), 3);
        assert_eq!(t.chunks[1].offset, 708);
        assert_eq!(t.kind, TensorKind::Weights);
        assert_eq!((t.body_version, t.lanes), (1, 1));
        assert!(parsed.get("nope").is_none());
    }

    #[test]
    fn index_roundtrip_v2() {
        let mut idx = sample_index();
        idx.tensors[0].body_version = 2;
        idx.tensors[0].lanes = 16;
        let idx = StoreIndex::new(idx.tensors);
        let bytes = idx.to_bytes(StoreFormat::V2);
        // Two extra footer bytes per tensor, nothing else.
        assert_eq!(bytes.len(), sample_index().to_bytes(StoreFormat::V1).len() + 2 * 2);
        let parsed = StoreIndex::from_bytes(&bytes, 2, StoreFormat::V2).unwrap();
        let t = parsed.get("m/layer000/weights").unwrap();
        assert_eq!((t.body_version, t.lanes), (2, 16));
        let a = parsed.get("m/layer000/activations").unwrap();
        assert_eq!((a.body_version, a.lanes), (1, 1));
        // Parsing v2 bytes with the v1 schema must fail, not misread.
        assert!(StoreIndex::from_bytes(&bytes, 2, StoreFormat::V1).is_err());
    }

    #[test]
    fn index_rejects_bad_body_fields() {
        let mut idx = sample_index();
        idx.tensors[0].body_version = 2;
        idx.tensors[0].lanes = 16;
        let idx = StoreIndex::new(idx.tensors);
        let bytes = idx.to_bytes(StoreFormat::V2);
        let name_len = "m/layer000/weights".len();
        let body_at = 2 + name_len + 2; // name_len u16 | name | bits | kind
        for (delta, what) in [(0usize, "body version"), (1usize, "lanes")] {
            for bad in [0u8, 3, 5, 65, 255] {
                let mut b = bytes.clone();
                b[body_at + delta] = bad;
                assert!(
                    StoreIndex::from_bytes(&b, 2, StoreFormat::V2).is_err(),
                    "bad {what} {bad} must not parse"
                );
            }
        }
        // v1 bodies must declare exactly one lane.
        let mut b = bytes.clone();
        b[body_at] = 1;
        assert!(StoreIndex::from_bytes(&b, 2, StoreFormat::V2).is_err());
    }

    #[test]
    fn index_rejects_corruption() {
        let idx = sample_index();
        let bytes = idx.to_bytes(StoreFormat::V1);
        // Truncation at every prefix either errors or never panics.
        for keep in [0, 1, 5, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                StoreIndex::from_bytes(&bytes[..keep], idx.tensors.len(), StoreFormat::V1)
                    .is_err(),
                "keep={keep}"
            );
        }
        // Wrong tensor count: too many -> truncated; too few -> trailing.
        assert!(StoreIndex::from_bytes(&bytes, 3, StoreFormat::V1).is_err());
        assert!(StoreIndex::from_bytes(&bytes, 1, StoreFormat::V1).is_err());
    }

    #[test]
    fn index_rejects_oversized_last_chunk() {
        // A CRC-valid hostile footer whose last chunk exceeds
        // values_per_chunk would send chunk_for_value past the chunk
        // directory — must be rejected at parse time, not panic on read.
        let table = SymbolTable::uniform(8);
        let hostile = StoreIndex::new(vec![TensorMeta {
            name: "t".into(),
            bits: 8,
            kind: TensorKind::Weights,
            n_values: 35,
            values_per_chunk: 10,
            body_version: 1,
            lanes: 1,
            table,
            chunks: vec![
                ChunkMeta { offset: 8, len: 10, n_values: 10, crc32: 0 },
                ChunkMeta { offset: 18, len: 10, n_values: 25, crc32: 0 },
            ],
        }]);
        let err = StoreIndex::from_bytes(&hostile.to_bytes(StoreFormat::V1), 1, StoreFormat::V1);
        assert!(err.is_err(), "oversized last chunk must not parse");
    }

    #[test]
    fn chunk_value_mapping() {
        let idx = sample_index();
        let t = idx.get("m/layer000/weights").unwrap();
        assert_eq!(t.chunk_for_value(0), 0);
        assert_eq!(t.chunk_for_value(999), 0);
        assert_eq!(t.chunk_for_value(1000), 1);
        assert_eq!(t.chunk_for_value(2499), 2);
        assert_eq!(t.chunk_value_range(0), 0..1000);
        assert_eq!(t.chunk_value_range(2), 2000..2500);
        assert_eq!(t.compressed_bytes(), 700 + 650 + 380);
        assert_eq!(t.raw_bits(), 2500 * 8);
    }

    #[test]
    fn trailer_roundtrip() {
        let t = trailer_bytes(1234, 567, 0xDEAD_BEEF, 24);
        let p = parse_trailer(&t).unwrap();
        assert_eq!(p.footer_offset, 1234);
        assert_eq!(p.footer_len, 567);
        assert_eq!(p.footer_crc, 0xDEAD_BEEF);
        assert_eq!(p.tensor_count, 24);
        let mut bad = t;
        bad[27] ^= 0xFF;
        assert!(parse_trailer(&bad).is_err());
        assert!(parse_trailer(&t[..20]).is_err());
    }

    #[test]
    fn gen_pointer_roundtrip_and_rejection() {
        let p = GenPointer {
            generation: 7,
            trailer_offset: 9000,
            committed_len: 9000 + TRAILER_BYTES as u64,
        };
        let bytes = p.to_bytes();
        assert_eq!(bytes.len(), GEN_POINTER_BYTES);
        assert_eq!(GenPointer::from_bytes(&bytes).unwrap(), p);
        // Any single-byte flip is caught (magic, fields or CRC).
        for i in 0..bytes.len() {
            let mut bad = bytes;
            bad[i] ^= 0x10;
            assert!(GenPointer::from_bytes(&bad).is_err(), "flip at {i}");
        }
        assert!(GenPointer::from_bytes(&bytes[..GEN_POINTER_BYTES - 1]).is_err());
        // committed_len must agree with trailer_offset even under a valid
        // CRC (a pointer hand-forged with inconsistent fields).
        let forged = GenPointer { committed_len: 9001 + TRAILER_BYTES as u64, ..p };
        assert!(GenPointer::from_bytes(&forged.to_bytes()).is_err());
    }

    #[test]
    fn gen_record_roundtrip_and_rejection() {
        let r = GenRecord { generation: 3, parent_trailer_offset: 4242 };
        let bytes = r.to_bytes();
        assert_eq!(bytes.len(), GEN_RECORD_BYTES);
        assert_eq!(GenRecord::from_bytes(&bytes), Some(r));
        // A non-record (arbitrary footer-adjacent bytes in a classic
        // store) parses as None, never as a bogus generation.
        for i in 0..bytes.len() {
            let mut bad = bytes;
            bad[i] ^= 0x04;
            assert_eq!(GenRecord::from_bytes(&bad), None, "flip at {i}");
        }
        assert_eq!(GenRecord::from_bytes(&bytes[..GEN_RECORD_BYTES - 1]), None);
        assert_eq!(GenRecord::from_bytes(&[0u8; GEN_RECORD_BYTES]), None);
    }

    #[test]
    fn gen_pointer_path_appends_suffix() {
        let p = gen_pointer_path(std::path::Path::new("/tmp/z.apackstore"));
        assert_eq!(p, std::path::PathBuf::from("/tmp/z.apackstore.gen"));
    }
}
