//! Bounded LRU cache of decompressed chunks, plus the scratch-buffer pool
//! the decode path draws from.
//!
//! The reader's hot path (paper §V: decode on the DRAM path, serve from
//! on-chip storage) keeps recently decoded chunks resident so repeated
//! `get_chunk`/`get_range` hits skip both the file read and the arithmetic
//! decode. Capacity is budgeted in **values** (4 bytes each), not entries,
//! so one huge chunk cannot silently blow the memory bound that dozens of
//! small chunks were sized for.
//!
//! Buffer ownership (DESIGN.md §8): decode targets are `Vec<u32>`s drawn
//! from a [`ScratchPool`]; cached chunks wrap theirs in an `Arc` shared
//! with clients, and [`ChunkCache::insert`]/[`ChunkCache::clear`] hand
//! evicted entries back to the caller, which recycles each into the pool
//! once the last client reference drops ([`ScratchPool::recycle`]). The
//! steady-state read path therefore allocates nothing.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Cache key: (tensor index in the store, chunk index in the tensor).
pub type ChunkKey = (u32, u32);

struct Entry {
    data: Arc<Vec<u32>>,
    /// Logical clock at last touch; smallest = least recently used.
    last_used: u64,
}

/// A bounded LRU keyed by [`ChunkKey`]. Entries are `Arc`s, so an evicted
/// chunk stays alive for any reader still holding it.
pub struct ChunkCache {
    map: HashMap<ChunkKey, Entry>,
    capacity_values: usize,
    used_values: usize,
    tick: u64,
}

impl ChunkCache {
    /// Cache budgeting at most `capacity_values` decoded values (0
    /// disables caching entirely).
    pub fn new(capacity_values: usize) -> Self {
        Self { map: HashMap::new(), capacity_values, used_values: 0, tick: 0 }
    }

    /// Look up a chunk, refreshing its recency on hit.
    pub fn get(&mut self, key: ChunkKey) -> Option<Arc<Vec<u32>>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(&key).map(|e| {
            e.last_used = tick;
            Arc::clone(&e.data)
        })
    }

    /// Insert a decoded chunk, evicting least-recently-used entries until
    /// the value budget holds. Chunks larger than the whole budget are not
    /// cached (they would evict everything for a single-use entry).
    ///
    /// Returns the evicted (and displaced) entries so the caller can
    /// recycle their buffers into a [`ScratchPool`]; usually empty.
    pub fn insert(&mut self, key: ChunkKey, data: Arc<Vec<u32>>) -> Vec<Arc<Vec<u32>>> {
        let mut evicted = Vec::new();
        let size = data.len();
        if size > self.capacity_values {
            return evicted;
        }
        self.tick += 1;
        if let Some(old) = self.map.insert(key, Entry { data, last_used: self.tick }) {
            self.used_values -= old.data.len();
            evicted.push(old.data);
        }
        self.used_values += size;
        while self.used_values > self.capacity_values {
            // O(n) LRU scan: the cache holds at most a few hundred chunks,
            // so a scan beats the bookkeeping of an intrusive list here.
            let lru = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("used_values > 0 implies non-empty map");
            if let Some(e) = self.map.remove(&lru) {
                self.used_values -= e.data.len();
                evicted.push(e.data);
            }
        }
        evicted
    }

    /// Whether a chunk is resident, without refreshing its recency (the
    /// prefetcher peeks before decoding so a warm chunk costs nothing and
    /// demand traffic alone drives the LRU order).
    pub fn contains(&self, key: ChunkKey) -> bool {
        self.map.contains_key(&key)
    }

    /// Drop every entry (used by benches to measure the cold path),
    /// returning them for scratch-pool recycling.
    pub fn clear(&mut self) -> Vec<Arc<Vec<u32>>> {
        self.used_values = 0;
        self.map.drain().map(|(_, e)| e.data).collect()
    }

    /// Number of resident chunks.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Values currently resident.
    pub fn used_values(&self) -> usize {
        self.used_values
    }

    /// Configured budget in values.
    pub fn capacity_values(&self) -> usize {
        self.capacity_values
    }
}

/// Thread-safe pool of reusable `Vec<u32>` decode buffers.
///
/// Every chunk decode on the store read path (`get_range`, `get_chunk`,
/// `prefetch_chunk`, `verify`) acquires its output buffer here instead of
/// allocating; `verify` releases directly, while cached chunks come back
/// via [`Self::recycle`] when the LRU evicts them and the last client
/// `Arc` drops. Idle memory is bounded two ways — at most `max_buffers`
/// buffers AND at most `max_retained_values` total retained capacity
/// (buffers keep their capacity across reuse, so without the byte bound a
/// verify pass over huge chunks would pin `max_buffers ×` the largest
/// chunk forever).
pub struct ScratchPool {
    bufs: Mutex<Vec<Vec<u32>>>,
    max_buffers: usize,
    max_retained_values: usize,
    acquired: AtomicU64,
    reused: AtomicU64,
}

impl ScratchPool {
    /// Pool retaining at most `max_buffers` idle buffers totalling at most
    /// `max_retained_values` of capacity.
    pub fn new(max_buffers: usize, max_retained_values: usize) -> Self {
        Self {
            bufs: Mutex::new(Vec::new()),
            max_buffers,
            max_retained_values,
            acquired: AtomicU64::new(0),
            reused: AtomicU64::new(0),
        }
    }

    /// Take a buffer resized to `n` zeroed values. The zeroing memset is
    /// deliberate: it keeps the pool safe-code-only and is cheap next to
    /// the allocation + page faults it replaces (the decode path then
    /// overwrites every slot or the buffer is released on error).
    pub fn acquire(&self, n: usize) -> Vec<u32> {
        self.acquired.fetch_add(1, Ordering::Relaxed);
        let pooled = self.bufs.lock().expect("scratch pool lock").pop();
        let mut buf = match pooled {
            Some(b) => {
                self.reused.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => Vec::new(),
        };
        buf.clear();
        buf.resize(n, 0);
        buf
    }

    /// Return a buffer to the pool (dropped if either the count or the
    /// retained-capacity bound would be exceeded; the capacity sum is an
    /// O(max_buffers) scan over at most a few dozen entries).
    pub fn release(&self, buf: Vec<u32>) {
        let mut bufs = self.bufs.lock().expect("scratch pool lock");
        let retained: usize = bufs.iter().map(|b| b.capacity()).sum();
        if bufs.len() < self.max_buffers
            && retained.saturating_add(buf.capacity()) <= self.max_retained_values
        {
            bufs.push(buf);
        }
    }

    /// Reclaim an `Arc`'d buffer if this was the last reference (evicted
    /// cache entries no client still holds); otherwise the buffer stays
    /// alive with its holders and is simply not pooled.
    pub fn recycle(&self, data: Arc<Vec<u32>>) {
        if let Ok(buf) = Arc::try_unwrap(data) {
            self.release(buf);
        }
    }

    /// Buffers handed out so far.
    pub fn acquired(&self) -> u64 {
        self.acquired.load(Ordering::Relaxed)
    }

    /// Acquisitions served from the pool instead of a fresh allocation.
    pub fn reused(&self) -> u64 {
        self.reused.load(Ordering::Relaxed)
    }

    /// Zero the reuse counters (buffers stay pooled).
    pub fn reset_counters(&self) {
        self.acquired.store(0, Ordering::Relaxed);
        self.reused.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(n: usize, fill: u32) -> Arc<Vec<u32>> {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn hit_miss_and_budget() {
        let mut c = ChunkCache::new(100);
        assert!(c.get((0, 0)).is_none());
        c.insert((0, 0), chunk(60, 1));
        c.insert((0, 1), chunk(60, 2));
        // 120 > 100: (0,0) is LRU and must be gone.
        assert!(c.get((0, 0)).is_none());
        assert_eq!(c.get((0, 1)).unwrap()[0], 2);
        assert!(c.used_values() <= 100);
    }

    #[test]
    fn get_refreshes_recency() {
        let mut c = ChunkCache::new(100);
        c.insert((0, 0), chunk(40, 1));
        c.insert((0, 1), chunk(40, 2));
        assert!(c.get((0, 0)).is_some()); // (0,1) is now LRU
        c.insert((0, 2), chunk(40, 3)); // evicts (0,1)
        assert!(c.get((0, 0)).is_some());
        assert!(c.get((0, 1)).is_none());
        assert!(c.get((0, 2)).is_some());
    }

    #[test]
    fn oversized_and_zero_capacity() {
        let mut c = ChunkCache::new(10);
        c.insert((0, 0), chunk(11, 1)); // larger than budget: not cached
        assert!(c.is_empty());
        let mut off = ChunkCache::new(0);
        off.insert((0, 0), chunk(1, 1));
        assert!(off.get((0, 0)).is_none());
    }

    #[test]
    fn reinsert_same_key_accounts_once() {
        let mut c = ChunkCache::new(100);
        c.insert((0, 0), chunk(30, 1));
        let displaced = c.insert((0, 0), chunk(50, 2));
        assert_eq!(displaced.len(), 1, "displaced entry is handed back");
        assert_eq!(displaced[0].len(), 30);
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_values(), 50);
        assert_eq!(c.get((0, 0)).unwrap()[0], 2);
    }

    #[test]
    fn insert_and_clear_return_evicted_entries() {
        let mut c = ChunkCache::new(100);
        assert!(c.insert((0, 0), chunk(60, 1)).is_empty());
        let evicted = c.insert((0, 1), chunk(60, 2));
        assert_eq!(evicted.len(), 1);
        assert_eq!(evicted[0][0], 1, "LRU entry handed back on eviction");
        let drained = c.clear();
        assert_eq!(drained.len(), 1);
        assert!(c.is_empty());
        assert_eq!(c.used_values(), 0);
    }

    #[test]
    fn scratch_pool_rejects_oversized_retention() {
        // Byte bound: a buffer whose capacity would blow the retained
        // budget is dropped instead of pooled.
        let pool = ScratchPool::new(8, 100);
        pool.release(Vec::with_capacity(60));
        pool.release(Vec::with_capacity(60)); // 120 > 100: dropped
        assert_eq!(pool.bufs.lock().unwrap().len(), 1);
        pool.release(Vec::with_capacity(30));
        assert_eq!(pool.bufs.lock().unwrap().len(), 2);
    }

    #[test]
    fn scratch_pool_reuses_and_bounds_buffers() {
        let pool = ScratchPool::new(2, 1 << 20);
        let a = pool.acquire(100);
        assert_eq!(a.len(), 100);
        assert_eq!((pool.acquired(), pool.reused()), (1, 0));
        pool.release(a);
        let b = pool.acquire(10);
        assert_eq!(b.len(), 10);
        assert_eq!((pool.acquired(), pool.reused()), (2, 1));
        // Recycle through an Arc: unique → pooled, shared → left alone.
        pool.recycle(Arc::new(b));
        let shared = Arc::new(vec![7u32; 5]);
        pool.recycle(Arc::clone(&shared));
        assert_eq!(shared[0], 7, "shared buffer must survive recycle");
        let c = pool.acquire(3);
        assert_eq!((pool.acquired(), pool.reused()), (3, 2));
        // The bound holds: releasing three keeps at most two.
        pool.release(c);
        pool.release(vec![0; 1]);
        pool.release(vec![0; 1]);
        assert_eq!(pool.bufs.lock().unwrap().len(), 2);
        pool.reset_counters();
        assert_eq!((pool.acquired(), pool.reused()), (0, 0));
    }
}
