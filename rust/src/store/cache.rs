//! Bounded LRU cache of decompressed chunks.
//!
//! The reader's hot path (paper §V: decode on the DRAM path, serve from
//! on-chip storage) keeps recently decoded chunks resident so repeated
//! `get_chunk`/`get_range` hits skip both the file read and the arithmetic
//! decode. Capacity is budgeted in **values** (4 bytes each), not entries,
//! so one huge chunk cannot silently blow the memory bound that dozens of
//! small chunks were sized for.

use std::collections::HashMap;
use std::sync::Arc;

/// Cache key: (tensor index in the store, chunk index in the tensor).
pub type ChunkKey = (u32, u32);

struct Entry {
    data: Arc<Vec<u32>>,
    /// Logical clock at last touch; smallest = least recently used.
    last_used: u64,
}

/// A bounded LRU keyed by [`ChunkKey`]. Entries are `Arc`s, so an evicted
/// chunk stays alive for any reader still holding it.
pub struct ChunkCache {
    map: HashMap<ChunkKey, Entry>,
    capacity_values: usize,
    used_values: usize,
    tick: u64,
}

impl ChunkCache {
    /// Cache budgeting at most `capacity_values` decoded values (0
    /// disables caching entirely).
    pub fn new(capacity_values: usize) -> Self {
        Self { map: HashMap::new(), capacity_values, used_values: 0, tick: 0 }
    }

    /// Look up a chunk, refreshing its recency on hit.
    pub fn get(&mut self, key: ChunkKey) -> Option<Arc<Vec<u32>>> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(&key).map(|e| {
            e.last_used = tick;
            Arc::clone(&e.data)
        })
    }

    /// Insert a decoded chunk, evicting least-recently-used entries until
    /// the value budget holds. Chunks larger than the whole budget are not
    /// cached (they would evict everything for a single-use entry).
    pub fn insert(&mut self, key: ChunkKey, data: Arc<Vec<u32>>) {
        let size = data.len();
        if size > self.capacity_values {
            return;
        }
        self.tick += 1;
        if let Some(old) = self.map.insert(key, Entry { data, last_used: self.tick }) {
            self.used_values -= old.data.len();
        }
        self.used_values += size;
        while self.used_values > self.capacity_values {
            // O(n) LRU scan: the cache holds at most a few hundred chunks,
            // so a scan beats the bookkeeping of an intrusive list here.
            let lru = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
                .expect("used_values > 0 implies non-empty map");
            if let Some(e) = self.map.remove(&lru) {
                self.used_values -= e.data.len();
            }
        }
    }

    /// Whether a chunk is resident, without refreshing its recency (the
    /// prefetcher peeks before decoding so a warm chunk costs nothing and
    /// demand traffic alone drives the LRU order).
    pub fn contains(&self, key: ChunkKey) -> bool {
        self.map.contains_key(&key)
    }

    /// Drop every entry (used by benches to measure the cold path).
    pub fn clear(&mut self) {
        self.map.clear();
        self.used_values = 0;
    }

    /// Number of resident chunks.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Values currently resident.
    pub fn used_values(&self) -> usize {
        self.used_values
    }

    /// Configured budget in values.
    pub fn capacity_values(&self) -> usize {
        self.capacity_values
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(n: usize, fill: u32) -> Arc<Vec<u32>> {
        Arc::new(vec![fill; n])
    }

    #[test]
    fn hit_miss_and_budget() {
        let mut c = ChunkCache::new(100);
        assert!(c.get((0, 0)).is_none());
        c.insert((0, 0), chunk(60, 1));
        c.insert((0, 1), chunk(60, 2));
        // 120 > 100: (0,0) is LRU and must be gone.
        assert!(c.get((0, 0)).is_none());
        assert_eq!(c.get((0, 1)).unwrap()[0], 2);
        assert!(c.used_values() <= 100);
    }

    #[test]
    fn get_refreshes_recency() {
        let mut c = ChunkCache::new(100);
        c.insert((0, 0), chunk(40, 1));
        c.insert((0, 1), chunk(40, 2));
        assert!(c.get((0, 0)).is_some()); // (0,1) is now LRU
        c.insert((0, 2), chunk(40, 3)); // evicts (0,1)
        assert!(c.get((0, 0)).is_some());
        assert!(c.get((0, 1)).is_none());
        assert!(c.get((0, 2)).is_some());
    }

    #[test]
    fn oversized_and_zero_capacity() {
        let mut c = ChunkCache::new(10);
        c.insert((0, 0), chunk(11, 1)); // larger than budget: not cached
        assert!(c.is_empty());
        let mut off = ChunkCache::new(0);
        off.insert((0, 0), chunk(1, 1));
        assert!(off.get((0, 0)).is_none());
    }

    #[test]
    fn reinsert_same_key_accounts_once() {
        let mut c = ChunkCache::new(100);
        c.insert((0, 0), chunk(30, 1));
        c.insert((0, 0), chunk(50, 2));
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_values(), 50);
        assert_eq!(c.get((0, 0)).unwrap()[0], 2);
    }
}
