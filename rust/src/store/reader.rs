//! Random-access APackStore reader.
//!
//! `get_tensor`, `get_chunk` and `get_range` decode **only the chunks they
//! touch**: the footer index maps a value range to chunk indices in O(1)
//! (fixed values per chunk), each chunk blob is fetched with one positioned
//! read through a [`ChunkSource`] backend and CRC-checked, and
//! decompression fans out over [`crate::util::par_map`] — the software
//! mirror of the replicated decode engines on the DRAM path (paper §V-B).
//! A bounded LRU ([`super::ChunkCache`]) keeps hot decoded chunks resident.
//!
//! The reader is `Sync` **with no IO lock**: chunk bytes come from a
//! [`ChunkSource`] whose `read_at`/`slice_at` are positioned and lock-free
//! (mmap zero-copy by default, `pread` on the file backend), so concurrent
//! `get_range` calls never serialize on IO. The only mutex left guards the
//! LRU cache, which is touched for nanoseconds per read.

use std::borrow::Cow;
use std::ops::Range;
use std::path::Path;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::apack::container::BodyView;
use crate::apack::lanes::BodyV2View;
use crate::apack::simd::DecodeKernel;
use crate::error::{Error, Result};
use crate::obs::{self, Counter, MetricsRegistry, RegistrySnapshot, Stage};
use crate::util::par_map;

use super::cache::{ChunkCache, ChunkKey, ScratchPool};
use super::format::{
    crc32, gen_pointer_path, parse_trailer, GenPointer, GenRecord, StoreFormat,
    StoreIndex, TensorMeta, GEN_RECORD_BYTES, STORE_MAGIC, TRAILER_BYTES,
};
use super::heat::{ChunkHeatEntry, HeatMap};
use super::io::{Backend, ChunkSource, FaultPlan};
use super::verify::{CorruptionClass, VerifyIssue};

/// Default cache budget: 4M values (16 MiB of decoded u32s).
pub const DEFAULT_CACHE_VALUES: usize = 4 << 20;

/// Cumulative read-path counters (chunk I/O only; the one-time open cost
/// of footer + trailer is excluded so tests can assert exact per-read
/// byte accounting). `backend` identifies which IO path served the bytes,
/// so mmap and file runs are comparable side by side.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReadStats {
    /// IO backend the bytes came through.
    pub backend: Backend,
    /// Compressed chunk bytes fetched from the source.
    pub bytes_read: u64,
    /// Chunks arithmetic-decoded (cache misses, prefetch and verify
    /// decodes).
    pub chunks_decoded: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Chunks decoded ahead of demand into the LRU by
    /// [`StoreReader::prefetch_chunk`]. Prefetch decodes count here and in
    /// `chunks_decoded`/`bytes_read` (the IO is real) but **not** in the
    /// hit/miss counters, so `hit_rate()` stays a demand-traffic signal.
    pub prefetched_chunks: u64,
    /// Serving-layer counter: requests that shared another request's
    /// in-flight decode instead of decoding again. Zero unless the stats
    /// come through a `serving::ServingEngine`.
    pub coalesced_reads: u64,
    /// Serving-layer counter: requests shed by admission control
    /// (queue full or deadline expired). Zero unless the stats come
    /// through a `serving::ServingEngine`.
    pub shed_requests: u64,
    /// Values arithmetic-decoded (demand, prefetch and verify decodes;
    /// excludes cache hits).
    pub values_decoded: u64,
    /// Nanoseconds spent inside chunk decodes, summed across decoding
    /// threads (concurrent decodes overlap in wall-clock time but add
    /// here), so `values_decoded` over this is the **per-thread** decode
    /// rate (`decode_mb_per_s`), not aggregate session throughput.
    pub decode_nanos: u64,
    /// Decode buffers drawn from the scratch pool.
    pub scratch_acquired: u64,
    /// Draws served by a recycled buffer instead of a fresh allocation.
    pub scratch_reused: u64,
    /// Transient read failures that were retried (and may have then
    /// succeeded) by the store-level retry loop (DESIGN.md §14).
    pub transient_retries: u64,
    /// Chunks quarantined after a non-transient read/decode failure
    /// (flagged in the heatmap; the error still propagates).
    pub quarantined_chunks: u64,
    /// Committed store generation (0 for classic write-once stores;
    /// sharded stores report the *maximum* across shards).
    pub generation: u64,
}

impl ReadStats {
    /// Cache hit rate in `[0, 1]` (0 when nothing was looked up yet).
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.cache_hits + self.cache_misses;
        if lookups == 0 {
            0.0
        } else {
            self.cache_hits as f64 / lookups as f64
        }
    }

    /// Single-stream decode throughput in MB/s of decoded output (4 bytes
    /// per value): decoded bytes over **per-thread** decode time (see
    /// `decode_nanos` — parallel decodes sum their overlapping spans, so
    /// aggregate session throughput is roughly this × decode threads).
    /// 0.0 before the first decode.
    pub fn decode_mb_per_s(&self) -> f64 {
        if self.decode_nanos == 0 {
            0.0
        } else {
            (self.values_decoded * 4) as f64 / (self.decode_nanos as f64 / 1e9) / 1e6
        }
    }

    /// Fraction of decode-buffer draws served by the scratch pool instead
    /// of the allocator, in `[0, 1]`.
    pub fn scratch_reuse_rate(&self) -> f64 {
        if self.scratch_acquired == 0 {
            0.0
        } else {
            self.scratch_reused as f64 / self.scratch_acquired as f64
        }
    }

    /// Build the stats view from a registry snapshot holding `store.*`
    /// names (DESIGN.md §10 glossary). `serving.*` names are folded in
    /// when present (snapshots that came through a
    /// `serving::ServingEngine` carry them; a bare reader's do not, so
    /// those fields read 0 exactly as before the registry refactor).
    pub fn from_snapshot(backend: Backend, snap: &RegistrySnapshot) -> Self {
        ReadStats {
            backend,
            bytes_read: snap.counter("store.bytes_read"),
            chunks_decoded: snap.counter("store.chunks_decoded"),
            cache_hits: snap.counter("store.cache_hits"),
            cache_misses: snap.counter("store.cache_misses"),
            prefetched_chunks: snap.counter("store.prefetched_chunks"),
            coalesced_reads: snap.counter("serving.coalesced_decodes"),
            shed_requests: snap.counter("serving.shed_queue_full")
                + snap.counter("serving.shed_deadline"),
            values_decoded: snap.counter("store.values_decoded"),
            decode_nanos: snap.counter("store.decode_nanos"),
            scratch_acquired: snap.counter("store.scratch_acquired"),
            scratch_reused: snap.counter("store.scratch_reused"),
            transient_retries: snap.counter("store.transient_retries"),
            quarantined_chunks: snap.counter("store.quarantined_chunks"),
            generation: snap.gauge("store.generation"),
        }
    }

    /// Fold another reader's counters into this one (sharded stores
    /// aggregate per-shard readers; backends match by construction).
    pub fn merge(&mut self, other: &ReadStats) {
        self.bytes_read += other.bytes_read;
        self.chunks_decoded += other.chunks_decoded;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.prefetched_chunks += other.prefetched_chunks;
        self.coalesced_reads += other.coalesced_reads;
        self.shed_requests += other.shed_requests;
        self.values_decoded += other.values_decoded;
        self.decode_nanos += other.decode_nanos;
        self.scratch_acquired += other.scratch_acquired;
        self.scratch_reused += other.scratch_reused;
        self.transient_retries += other.transient_retries;
        self.quarantined_chunks += other.quarantined_chunks;
        self.generation = self.generation.max(other.generation);
    }
}

/// Result of [`StoreReader::verify`] / [`StoreReader::verify_report`].
#[derive(Debug, Clone, Default)]
pub struct VerifyReport {
    /// Shard files checked (1 for a single-file store).
    pub shards: usize,
    pub tensors: usize,
    pub chunks: usize,
    /// Compressed bytes that verified clean (chunks with issues are
    /// excluded).
    pub bytes: u64,
    /// Committed generation (max across shards for sharded stores).
    pub generation: u64,
    /// Every corruption found, classified — the full-sweep alternative
    /// to `verify`'s first-error bail (DESIGN.md §14).
    pub issues: Vec<VerifyIssue>,
}

impl VerifyReport {
    /// Fold a per-shard report into an aggregate.
    pub fn merge(&mut self, other: &VerifyReport) {
        self.shards += other.shards;
        self.tensors += other.tensors;
        self.chunks += other.chunks;
        self.bytes += other.bytes;
        self.generation = self.generation.max(other.generation);
        self.issues.extend(other.issues.iter().cloned());
    }

    /// True when the sweep found no corruption.
    pub fn is_clean(&self) -> bool {
        self.issues.is_empty()
    }

    /// The most severe corruption class present (None when clean) —
    /// drives the CLI's class-specific exit code.
    pub fn worst_class(&self) -> Option<CorruptionClass> {
        self.issues.iter().map(|i| i.class).min_by_key(|c| c.severity_rank())
    }
}

/// Retry attempts for a transient read failure before giving up.
const TRANSIENT_READ_RETRIES: u32 = 4;

/// Positioned read with bounded, deterministically-jittered retries on
/// [`Error::Transient`] (DESIGN.md §14). Non-transient errors propagate
/// immediately. Each retry is counted in `retries` when provided (the
/// reader's `store.transient_retries`); open-time reads pass `None`.
pub(crate) fn read_at_retry(
    source: &dyn ChunkSource,
    offset: u64,
    buf: &mut [u8],
    retries: Option<&Counter>,
) -> Result<()> {
    let mut attempt = 0u32;
    loop {
        match source.read_at(offset, buf) {
            Ok(()) => return Ok(()),
            Err(e) if e.is_transient() && attempt < TRANSIENT_READ_RETRIES => {
                attempt += 1;
                if let Some(c) = retries {
                    c.inc();
                }
                // Deterministic jittered backoff: 50–250 µs scaled by the
                // attempt, seeded from (offset, attempt) so concurrent
                // retries against the same flaky region de-synchronize
                // without sharing RNG state.
                let mut rng =
                    crate::util::Rng64::new(offset ^ ((attempt as u64) << 48) ^ 0x5EED);
                let backoff_us = (50 + rng.below(200)) * attempt as u64;
                std::thread::sleep(std::time::Duration::from_micros(backoff_us));
            }
            Err(e) => return Err(e),
        }
    }
}

/// Atomic encoding of [`DecodeKernel`] for the reader's runtime knob.
fn kernel_code(kernel: DecodeKernel) -> u8 {
    match kernel {
        DecodeKernel::Scalar => 0,
        DecodeKernel::Simd => 1,
    }
}

/// A read-only handle on one APackStore file.
pub struct StoreReader {
    source: Box<dyn ChunkSource>,
    index: StoreIndex,
    /// First byte past the chunk region (chunks must end before this).
    chunk_region_end: u64,
    cache: Mutex<ChunkCache>,
    /// Decode buffers for every read path (see DESIGN.md §8): `verify`
    /// releases directly, cached chunks return via eviction + `recycle`.
    scratch: ScratchPool,
    /// `store.*` metrics (DESIGN.md §10). The hot path holds the
    /// pre-resolved [`Counter`] handles below — the registry map lock is
    /// only taken at open and snapshot time.
    registry: MetricsRegistry,
    chunks_decoded: Arc<Counter>,
    cache_hits: Arc<Counter>,
    cache_misses: Arc<Counter>,
    prefetched_chunks: Arc<Counter>,
    values_decoded: Arc<Counter>,
    decode_nanos: Arc<Counter>,
    transient_retries: Arc<Counter>,
    quarantined_chunks: Arc<Counter>,
    /// Committed generation this reader opened (0 = classic store).
    generation: u32,
    /// Absolute offset of the committed trailer this reader opened (the
    /// live appender resumes from here).
    trailer_offset: u64,
    /// Per-(tensor, chunk) access heat (DESIGN.md §12): the where-did-it-
    /// go companion to the aggregate counters above.
    heat: HeatMap,
    /// Decode kernel for v2 lane bodies (0 = scalar, 1 = simd; DESIGN.md
    /// §13). Defaults to [`DecodeKernel::auto`]; `--kernel` overrides
    /// per reader.
    kernel: AtomicU8,
    /// Worker threads for v2 lane decode (`> 1` switches the v2 path to
    /// `decode_into_threaded_with`; 0/1 = single-thread SoA, the
    /// default — chunk-level `par_map` already parallelizes demand
    /// reads, so lane threads are for huge-chunk / low-concurrency use).
    lane_threads: AtomicUsize,
}

impl StoreReader {
    /// Open and validate a store: magic, trailer, footer CRC, index
    /// invariants, and chunk-extent bounds. Uses the default (mmap)
    /// backend and cache budget.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, Backend::default(), DEFAULT_CACHE_VALUES)
    }

    /// Open with an explicit cache budget in values (0 disables caching).
    pub fn with_cache_capacity(path: &Path, cache_values: usize) -> Result<Self> {
        Self::open_with(path, Backend::default(), cache_values)
    }

    /// Open with an explicit IO backend and cache budget. When a sidecar
    /// generation-pointer file (`<path>.gen`, DESIGN.md §14) exists and
    /// validates, the store opens at the generation it names — any torn
    /// append tail past that trailer is ignored. A missing or invalid
    /// pointer falls back to the classic exact-EOF open, so write-once
    /// stores behave exactly as before.
    pub fn open_with(path: &Path, backend: Backend, cache_values: usize) -> Result<Self> {
        Self::open_opts(path, backend, cache_values, None)
    }

    /// [`Self::open_with`] with an optional [`FaultPlan`] wrapping the IO
    /// source (every read, open-time included, flows through the plan's
    /// injectors).
    pub fn open_opts(
        path: &Path,
        backend: Backend,
        cache_values: usize,
        plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        let ptr_path = gen_pointer_path(path);
        let pointer = std::fs::read(&ptr_path).ok().map(|b| GenPointer::from_bytes(&b));
        match pointer {
            // A valid pointer wins outright: the commit protocol only
            // flips it after the generation it names is synced, so a
            // failure at its trailer offset is real corruption, not a
            // torn tail to skip.
            Some(Ok(p)) => Self::open_resolved(
                path,
                backend,
                cache_values,
                Some(p.trailer_offset),
                plan,
            ),
            // Invalid pointer: fall back to classic; if that fails too,
            // say the pointer was part of the problem.
            Some(Err(pe)) => {
                Self::open_resolved(path, backend, cache_values, None, plan).map_err(
                    |e| {
                        Error::Store(format!(
                            "{e} (and the generation pointer {} is invalid: {pe})",
                            ptr_path.display()
                        ))
                    },
                )
            }
            None => Self::open_resolved(path, backend, cache_values, None, plan),
        }
    }

    /// Open at an explicit committed trailer offset — the sharded-store
    /// path, where the MANIFEST (not a sidecar file) names each shard's
    /// committed generation. No pointer resolution, no EOF fallback.
    pub fn open_at(
        path: &Path,
        backend: Backend,
        cache_values: usize,
        trailer_offset: u64,
        plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        Self::open_resolved(path, backend, cache_values, Some(trailer_offset), plan)
    }

    /// Shared open body: validate magic, trailer (at `trailer_offset`, or
    /// abutting EOF when `None`), footer CRC, index invariants, and
    /// chunk-extent bounds; recover the committed generation from the
    /// [`GenRecord`] preceding the footer (absent in classic stores →
    /// generation 0).
    fn open_resolved(
        path: &Path,
        backend: Backend,
        cache_values: usize,
        trailer_offset: Option<u64>,
        plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        let mut source = backend.open(path)?;
        if let Some(plan) = plan {
            source = plan.wrap(source);
        }
        let source = source;
        let file_len = source.len();
        let min_len = (STORE_MAGIC.len() + TRAILER_BYTES) as u64;
        if file_len < min_len {
            return Err(Error::Store(format!(
                "file is {file_len} bytes, smaller than magic + trailer ({min_len})"
            )));
        }
        let trailer_offset = match trailer_offset {
            Some(at) => {
                if at < STORE_MAGIC.len() as u64
                    || at.checked_add(TRAILER_BYTES as u64).is_none_or(|end| end > file_len)
                {
                    return Err(Error::Store(format!(
                        "committed trailer offset {at} outside file ({file_len} bytes)"
                    )));
                }
                at
            }
            None => file_len - TRAILER_BYTES as u64,
        };
        let mut magic = [0u8; 8];
        read_at_retry(source.as_ref(), 0, &mut magic, None)?;
        let format = StoreFormat::from_magic(&magic)?;
        let mut trailer_buf = [0u8; TRAILER_BYTES];
        read_at_retry(source.as_ref(), trailer_offset, &mut trailer_buf, None)?;
        let trailer = parse_trailer(&trailer_buf)?;
        let footer_end = trailer
            .footer_offset
            .checked_add(trailer.footer_len)
            .ok_or_else(|| Error::Store("footer extent overflows".into()))?;
        if trailer.footer_offset < STORE_MAGIC.len() as u64 || footer_end != trailer_offset {
            return Err(Error::Store(format!(
                "footer extent [{}, {footer_end}) does not abut the trailer",
                trailer.footer_offset
            )));
        }
        let mut footer = vec![0u8; trailer.footer_len as usize];
        read_at_retry(source.as_ref(), trailer.footer_offset, &mut footer, None)?;
        if crc32(&footer) != trailer.footer_crc {
            return Err(Error::Store("footer CRC mismatch".into()));
        }
        let index = StoreIndex::from_bytes(&footer, trailer.tensor_count as usize, format)?;
        // Every chunk must live inside [magic, footer).
        for t in &index.tensors {
            for (ci, c) in t.chunks.iter().enumerate() {
                let end = c
                    .offset
                    .checked_add(c.len)
                    .ok_or_else(|| Error::Store(format!(
                        "tensor {}: chunk {ci} extent overflows",
                        t.name
                    )))?;
                if c.offset < STORE_MAGIC.len() as u64 || end > trailer.footer_offset {
                    return Err(Error::Store(format!(
                        "tensor {}: chunk {ci} [{}, {end}) outside chunk region [8, {})",
                        t.name, c.offset, trailer.footer_offset
                    )));
                }
            }
        }
        // Committed generation: the GenRecord stamped just before this
        // generation's footer. Classic stores have arbitrary (or no)
        // bytes there — any parse failure reads as generation 0.
        let generation = Self::read_generation(source.as_ref(), trailer.footer_offset)
            .map(|r| r.generation)
            .unwrap_or(0);
        // Open-time IO (magic + trailer + footer) is excluded from stats.
        source.reset_bytes_read();
        // Idle scratch buffers are bounded by decode concurrency (~2
        // in-flight decodes per hardware thread), and their retained
        // capacity by the reader's own cache budget — never by store or
        // chunk size. A small floor (64K values = 256 KiB) keeps buffer
        // reuse alive on cache-disabled readers (verify passes, benches)
        // without letting an intentionally small budget pin big buffers.
        let scratch_buffers =
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4) * 2;
        let scratch_retained = cache_values.max(1 << 16);
        let registry = MetricsRegistry::new();
        Ok(Self {
            source,
            index,
            chunk_region_end: trailer.footer_offset,
            cache: Mutex::new(ChunkCache::new(cache_values)),
            scratch: ScratchPool::new(scratch_buffers, scratch_retained),
            chunks_decoded: registry.counter("store.chunks_decoded"),
            cache_hits: registry.counter("store.cache_hits"),
            cache_misses: registry.counter("store.cache_misses"),
            prefetched_chunks: registry.counter("store.prefetched_chunks"),
            values_decoded: registry.counter("store.values_decoded"),
            decode_nanos: registry.counter("store.decode_nanos"),
            transient_retries: registry.counter("store.transient_retries"),
            quarantined_chunks: registry.counter("store.quarantined_chunks"),
            generation,
            trailer_offset,
            registry,
            heat: HeatMap::new(),
            kernel: AtomicU8::new(kernel_code(DecodeKernel::auto())),
            lane_threads: AtomicUsize::new(0),
        })
    }

    /// Parse the [`GenRecord`] immediately preceding the footer at
    /// `footer_offset`, if one is present and valid.
    fn read_generation(source: &dyn ChunkSource, footer_offset: u64) -> Option<GenRecord> {
        let at = footer_offset.checked_sub(GEN_RECORD_BYTES as u64)?;
        if at < STORE_MAGIC.len() as u64 {
            return None;
        }
        let mut buf = [0u8; GEN_RECORD_BYTES];
        read_at_retry(source, at, &mut buf, None).ok()?;
        GenRecord::from_bytes(&buf)
    }

    /// The committed generation this reader opened (0 = classic
    /// write-once store or the first sealed generation).
    pub fn generation(&self) -> u32 {
        self.generation
    }

    /// Absolute offset of the committed trailer record this reader
    /// opened (the live appender resumes writing past
    /// `trailer_offset + TRAILER_BYTES`).
    pub fn trailer_offset(&self) -> u64 {
        self.trailer_offset
    }

    /// Select the decode kernel for v2 lane bodies (see
    /// [`DecodeKernel`]; the process default honors
    /// `APACK_DECODE_KERNEL`).
    pub fn set_decode_kernel(&self, kernel: DecodeKernel) {
        self.kernel.store(kernel_code(kernel), Ordering::Relaxed);
    }

    /// The decode kernel v2 lane bodies currently use.
    pub fn decode_kernel(&self) -> DecodeKernel {
        if self.kernel.load(Ordering::Relaxed) == 0 {
            DecodeKernel::Scalar
        } else {
            DecodeKernel::Simd
        }
    }

    /// Set worker threads for v2 lane decode (`> 1` decodes each chunk's
    /// lanes on that many threads, each running the active kernel; 0/1 =
    /// single-thread).
    pub fn set_lane_threads(&self, threads: usize) {
        self.lane_threads.store(threads, Ordering::Relaxed);
    }

    /// The IO backend serving this reader.
    pub fn backend(&self) -> Backend {
        self.source.backend()
    }

    /// All tensor names, in write order.
    pub fn tensor_names(&self) -> Vec<&str> {
        self.index.tensors.iter().map(|t| t.name.as_str()).collect()
    }

    /// Number of tensors in the store.
    pub fn tensor_count(&self) -> usize {
        self.index.tensors.len()
    }

    /// Metadata for one tensor.
    pub fn meta(&self, name: &str) -> Result<&TensorMeta> {
        self.index
            .get(name)
            .ok_or_else(|| Error::Store(format!("no tensor named {name:?}")))
    }

    /// The parsed footer index.
    pub fn index(&self) -> &StoreIndex {
        &self.index
    }

    /// One chunk's compressed blob, CRC-verified. Served as a zero-copy
    /// slice of the mapping when the backend supports it, otherwise read
    /// into a fresh buffer.
    fn read_chunk_bytes(&self, t: &TensorMeta, ci: usize) -> Result<Cow<'_, [u8]>> {
        let c = &t.chunks[ci];
        debug_assert!(c.offset + c.len <= self.chunk_region_end);
        let _io = obs::span_n(Stage::ChunkIo, c.len);
        let blob: Cow<'_, [u8]> = match self.source.slice_at(c.offset, c.len as usize) {
            Some(slice) => Cow::Borrowed(slice),
            None => {
                let mut buf = vec![0u8; c.len as usize];
                read_at_retry(
                    self.source.as_ref(),
                    c.offset,
                    &mut buf,
                    Some(&self.transient_retries),
                )?;
                Cow::Owned(buf)
            }
        };
        if crc32(&blob) != c.crc32 {
            return Err(Error::Store(format!(
                "tensor {}: chunk {ci} CRC mismatch — data corrupted",
                t.name
            )));
        }
        Ok(blob)
    }

    /// Fetch, CRC-check and arithmetic-decode one chunk into a
    /// scratch-pool buffer — the single decode path under `get_*`,
    /// `prefetch_chunk` and `verify`. Dispatches on the tensor's recorded
    /// body version: v1 chunks decode through [`BodyView`], v2 lane bodies
    /// through [`BodyV2View`]. Either way the decode runs straight from
    /// the (possibly mmap'd) blob: no stream copy, no fresh output
    /// allocation, decode wall-time accounted. `check_lanes` additionally
    /// runs the per-lane CRC sweep on v2 bodies (verify path only — it is
    /// deliberately off the demand/prefetch hot path).
    fn decode_chunk_scratch(
        &self,
        ti: usize,
        ci: usize,
        check_lanes: bool,
    ) -> Result<Vec<u32>> {
        let t = &self.index.tensors[ti];
        let blob = match self.read_chunk_bytes(t, ci) {
            Ok(blob) => blob,
            Err(e) => {
                // A CRC mismatch (or any other permanent read failure) is
                // corruption on disk: quarantine the chunk so operators
                // see *where*, then propagate. Transient flakes already
                // burned their retries; they stay un-quarantined.
                if !e.is_transient() {
                    self.note_quarantine(ti, ci);
                }
                return Err(e);
            }
        };
        let n_expected = t.chunks[ci].n_values;
        let count_err = |got: u64| {
            Error::Store(format!(
                "tensor {}: chunk {ci} holds {got} values, index says {n_expected}",
                t.name
            ))
        };
        let n = n_expected as usize;
        let mut buf = self.scratch.acquire(n);
        let kernel = self.decode_kernel();
        let lane_threads = self.lane_threads.load(Ordering::Relaxed);
        let t0 = Instant::now();
        // Threaded lane decode reports summed worker nanos; every other
        // path is single-thread, where wall time *is* decode time. Using
        // worker nanos keeps `decode_nanos` (and the heatmap's per-chunk
        // counter) a measure of decode work, not caller wall clock.
        let mut worker_nanos: Option<u64> = None;
        let decoded = match t.body_version {
            1 => match BodyView::parse(&blob) {
                Ok(view) if view.n_values != n_expected => Err(count_err(view.n_values)),
                Ok(view) => view.decode_into(&t.table, &mut buf),
                Err(e) => Err(e),
            },
            2 => match BodyV2View::parse(&blob) {
                Ok(view) if view.n_values != n_expected => Err(count_err(view.n_values)),
                Ok(view) => {
                    let lanes_ok =
                        if check_lanes { view.verify_lanes() } else { Ok(()) };
                    lanes_ok.and_then(|()| {
                        if lane_threads > 1 && view.lanes() > 1 {
                            view.decode_into_threaded_with(
                                &t.table,
                                &mut buf,
                                lane_threads,
                                kernel,
                            )
                            .map(|nanos| worker_nanos = Some(nanos))
                        } else {
                            view.decode_into_with(&t.table, &mut buf, kernel)
                        }
                    })
                }
                Err(e) => Err(e),
            },
            other => Err(Error::Store(format!(
                "tensor {}: unsupported chunk body version {other}",
                t.name
            ))),
        };
        let spent = worker_nanos.unwrap_or_else(|| t0.elapsed().as_nanos() as u64);
        self.decode_nanos.add(spent);
        self.heat.add_decode_nanos(ti as u32, ci as u32, spent);
        if let Err(e) = decoded {
            self.scratch.release(buf);
            // The blob passed its whole-chunk CRC but would not decode:
            // permanent corruption (or an index/body mismatch) —
            // quarantine so the heatmap and counters localize it. The
            // error itself is unchanged: single-flight shares permanent
            // failures, and `verify` classifies them by class.
            if !e.is_transient() {
                self.note_quarantine(ti, ci);
            }
            return Err(e);
        }
        self.chunks_decoded.inc();
        self.values_decoded.add(n as u64);
        Ok(buf)
    }

    /// Record a non-transient chunk failure: count it and flag the chunk
    /// in the heatmap (`store heatmap` renders the flag, the Prometheus
    /// export grows a `store_chunk_quarantined` series).
    fn note_quarantine(&self, ti: usize, ci: usize) {
        self.quarantined_chunks.inc();
        self.heat.quarantine(ti as u32, ci as u32);
    }

    /// Insert a decoded chunk, recycling whatever the LRU evicts.
    fn cache_insert(&self, key: ChunkKey, values: &Arc<Vec<u32>>) {
        let evicted =
            self.cache.lock().expect("store cache lock").insert(key, Arc::clone(values));
        for old in evicted {
            self.scratch.recycle(old);
        }
    }

    /// Decoded values of chunk `ci` of tensor index `ti`, via the cache.
    fn chunk_values(&self, ti: usize, ci: usize) -> Result<Arc<Vec<u32>>> {
        let key: ChunkKey = (ti as u32, ci as u32);
        if let Some(hit) = self.cache.lock().expect("store cache lock").get(key) {
            self.cache_hits.inc();
            self.heat.demand_hit(ti as u32, ci as u32);
            return Ok(hit);
        }
        self.cache_misses.inc();
        self.heat.demand_miss(ti as u32, ci as u32);
        let values = Arc::new(self.decode_chunk_scratch(ti, ci, false)?);
        self.cache_insert(key, &values);
        Ok(values)
    }

    /// Warm the cache with chunk `ci` of `name` if it is not resident:
    /// decode and insert, counted in `prefetched_chunks` (and, since the
    /// IO and decode are real, in `bytes_read`/`chunks_decoded`) but not
    /// in the cache hit/miss counters — `hit_rate()` keeps measuring
    /// demand traffic only. Returns whether a decode actually happened
    /// (`false`: already resident, caching disabled, or the chunk is
    /// larger than the whole cache budget and could never stay resident).
    pub fn prefetch_chunk(&self, name: &str, ci: usize) -> Result<bool> {
        let ti = self
            .index
            .position(name)
            .ok_or_else(|| Error::Store(format!("no tensor named {name:?}")))?;
        let t = &self.index.tensors[ti];
        if ci >= t.chunks.len() {
            return Err(Error::Store(format!(
                "tensor {name}: chunk {ci} out of range (has {})",
                t.chunks.len()
            )));
        }
        let key: ChunkKey = (ti as u32, ci as u32);
        {
            let cache = self.cache.lock().expect("store cache lock");
            let budget = cache.capacity_values();
            if budget == 0 || t.chunks[ci].n_values as usize > budget || cache.contains(key) {
                return Ok(false);
            }
        }
        let values = Arc::new(self.decode_chunk_scratch(ti, ci, false)?);
        self.prefetched_chunks.inc();
        self.heat.prefetch(ti as u32, ci as u32);
        self.cache_insert(key, &values);
        Ok(true)
    }

    /// Decode one chunk (CRC-checked; served from cache when resident).
    pub fn get_chunk(&self, name: &str, ci: usize) -> Result<Arc<Vec<u32>>> {
        let ti = self
            .index
            .position(name)
            .ok_or_else(|| Error::Store(format!("no tensor named {name:?}")))?;
        let t = &self.index.tensors[ti];
        if ci >= t.chunks.len() {
            return Err(Error::Store(format!(
                "tensor {name}: chunk {ci} out of range (has {})",
                t.chunks.len()
            )));
        }
        self.chunk_values(ti, ci)
    }

    /// Decode a full tensor, all chunks in parallel.
    pub fn get_tensor(&self, name: &str) -> Result<Vec<u32>> {
        let t = self.meta(name)?;
        self.get_range(name, 0..t.n_values)
    }

    /// Decode values `[range.start, range.end)` of a tensor, touching only
    /// the covering chunks (decoded in parallel, cache-assisted).
    pub fn get_range(&self, name: &str, range: Range<u64>) -> Result<Vec<u32>> {
        let ti = self
            .index
            .position(name)
            .ok_or_else(|| Error::Store(format!("no tensor named {name:?}")))?;
        let t = &self.index.tensors[ti];
        if range.start > range.end || range.end > t.n_values {
            return Err(Error::Store(format!(
                "tensor {name}: range {}..{} out of bounds (n_values {})",
                range.start, range.end, t.n_values
            )));
        }
        if range.start == range.end {
            return Ok(Vec::new());
        }
        let first = t.chunk_for_value(range.start);
        let last = t.chunk_for_value(range.end - 1);
        let indices: Vec<usize> = (first..=last).collect();
        let parts: Result<Vec<Arc<Vec<u32>>>> =
            par_map(&indices, |&ci| self.chunk_values(ti, ci)).into_iter().collect();
        let parts = parts?;
        let mut copy_out = obs::span(Stage::CopyOut);
        let mut out = Vec::with_capacity((range.end - range.start) as usize);
        for (&ci, part) in indices.iter().zip(&parts) {
            let covered = t.chunk_value_range(ci);
            let lo = range.start.max(covered.start) - covered.start;
            let hi = range.end.min(covered.end) - covered.start;
            out.extend_from_slice(&part[lo as usize..hi as usize]);
        }
        copy_out.set_count(out.len() as u64);
        Ok(out)
    }

    /// Re-read and decode every chunk of every tensor, checking CRCs and
    /// value counts; v2 lane bodies additionally get their per-lane CRCs
    /// swept before decode, so a corrupt lane is pinned to that lane's
    /// first value. Bypasses the cache (this is an integrity pass over
    /// the bytes on disk, not over what happens to be resident). All
    /// (tensor, chunk) pairs fan out over one `par_map`, so a store of
    /// many small tensors verifies as fast as one big tensor.
    ///
    /// First-error-bail compatibility shim over
    /// [`Self::verify_report`]: any issue fails the whole pass with the
    /// first (job-order) underlying error.
    pub fn verify(&self) -> Result<VerifyReport> {
        let report = self.verify_report();
        match report.issues.first() {
            Some(issue) => Err(issue.error.clone()),
            None => Ok(report),
        }
    }

    /// Full-sweep verify: like [`Self::verify`] but **never bails** — every
    /// corrupt chunk is recorded as a classified [`VerifyIssue`] (chunk
    /// CRC vs per-lane CRC, DESIGN.md §14) and the sweep continues, so one
    /// bad chunk cannot hide a second. Clean chunks still count into
    /// `bytes`.
    pub fn verify_report(&self) -> VerifyReport {
        let jobs: Vec<(usize, usize)> = self
            .index
            .tensors
            .iter()
            .enumerate()
            .flat_map(|(ti, t)| (0..t.chunks.len()).map(move |ci| (ti, ci)))
            .collect();
        let checks: Vec<std::result::Result<u64, VerifyIssue>> =
            par_map(&jobs, |&(ti, ci)| self.verify_chunk(ti, ci));
        let mut bytes = 0u64;
        let mut issues = Vec::new();
        for check in checks {
            match check {
                Ok(len) => bytes += len,
                Err(issue) => issues.push(issue),
            }
        }
        VerifyReport {
            shards: 1,
            tensors: self.index.tensors.len(),
            chunks: jobs.len(),
            bytes,
            generation: self.generation as u64,
            issues,
        }
    }

    /// Verify one chunk, classifying any failure. Stage order matches the
    /// historical first-error semantics: whole-chunk read + CRC, then the
    /// per-lane CRC sweep (v2 bodies — localizes corruption hiding behind
    /// a valid chunk CRC to one lane), then the decode itself.
    fn verify_chunk(&self, ti: usize, ci: usize) -> std::result::Result<u64, VerifyIssue> {
        let t = &self.index.tensors[ti];
        let issue = |class: CorruptionClass, detail: String, error: Error| VerifyIssue {
            class,
            shard: None,
            tensor: Some(t.name.clone()),
            chunk: Some(ci as u32),
            detail,
            error,
        };
        {
            let blob = match self.read_chunk_bytes(t, ci) {
                Ok(blob) => blob,
                Err(e) => {
                    if !e.is_transient() {
                        self.note_quarantine(ti, ci);
                    }
                    return Err(issue(
                        CorruptionClass::ChunkCrc,
                        "chunk read / whole-chunk CRC failed".into(),
                        e,
                    ));
                }
            };
            if t.body_version == 2 {
                if let Ok(view) = BodyV2View::parse(&blob) {
                    if let Err(e) = view.verify_lanes() {
                        self.note_quarantine(ti, ci);
                        return Err(issue(
                            CorruptionClass::LaneCrc,
                            "per-lane CRC sweep failed behind a valid chunk CRC".into(),
                            e,
                        ));
                    }
                }
            }
        }
        // Decode re-reads the blob (offline verify trades a second read
        // for reusing the one hot-path decode routine, quarantine
        // accounting included).
        match self.decode_chunk_scratch(ti, ci, false) {
            Ok(values) => {
                self.scratch.release(values);
                Ok(t.chunks[ci].len)
            }
            Err(e) => Err(issue(CorruptionClass::ChunkCrc, "chunk decode failed".into(), e)),
        }
    }

    /// Snapshot this reader's `store.*` metrics. The IO source and the
    /// scratch pool own their byte/draw atomics (they predate the
    /// registry and are shared with non-store users), so their live
    /// values are overlaid into the snapshot here — every exporter and
    /// stats view downstream sees one coherent namespace.
    pub fn registry_snapshot(&self) -> RegistrySnapshot {
        let mut snap = self.registry.snapshot();
        snap.counters.insert("store.bytes_read".to_string(), self.source.bytes_read());
        snap.counters.insert("store.scratch_acquired".to_string(), self.scratch.acquired());
        snap.counters.insert("store.scratch_reused".to_string(), self.scratch.reused());
        // Committed footer generation this reader pinned at open. Sharded
        // stores merge gauges by max, so the store-level view reports the
        // newest shard generation.
        snap.gauges.insert("store.generation".to_string(), self.generation as u64);
        // Info gauge: which kernel loop serves v2 decodes, as a label
        // (Prometheus `*_info` idiom). Sharded stores merge by gauge max,
        // so identical per-shard series collapse to one.
        snap.gauges.insert(
            format!(
                "store.decode_kernel{{kernel=\"{}\"}}",
                self.decode_kernel().active_label()
            ),
            1,
        );
        snap
    }

    /// Snapshot the cumulative read counters (a [`ReadStats`] view over
    /// [`StoreReader::registry_snapshot`]).
    pub fn stats(&self) -> ReadStats {
        ReadStats::from_snapshot(self.source.backend(), &self.registry_snapshot())
    }

    /// Per-chunk access heat joined with tensor identity, sorted
    /// `(tensor, chunk)` — see [`super::heat`] for the attribution rules
    /// and the rollup/render helpers.
    pub fn heatmap(&self) -> Vec<ChunkHeatEntry> {
        self.heat.entries(|ti| {
            self.index
                .tensors
                .get(ti as usize)
                .map(|t| (t.name.clone(), t.body_version, t.lanes))
        })
    }

    /// Zero the read counters (does not touch the cache; pooled scratch
    /// buffers stay pooled).
    pub fn reset_stats(&self) {
        self.source.reset_bytes_read();
        self.registry.reset();
        self.scratch.reset_counters();
    }

    /// Drop all cached chunks (benches use this to time the cold path);
    /// their buffers are recycled into the scratch pool where possible.
    pub fn clear_cache(&self) {
        let drained = self.cache.lock().expect("store cache lock").clear();
        for entry in drained {
            self.scratch.recycle(entry);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::tablegen::TensorKind;
    use crate::coordinator::PartitionPolicy;
    use crate::models::distributions::ValueProfile;
    use crate::store::format::BodyConfig;
    use crate::store::writer::encode_tensor_with;
    use crate::store::StoreWriter;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("apack_reader_{}_{tag}.apackstore", std::process::id()))
    }

    fn build_store(tag: &str, n: usize) -> (std::path::PathBuf, Vec<u32>) {
        let path = temp_path(tag);
        let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, 77);
        let policy = PartitionPolicy { substreams: 8, min_per_stream: 128 };
        let mut w = StoreWriter::create(&path, policy).unwrap();
        w.add_tensor("t", 8, &values, TensorKind::Activations).unwrap();
        w.finish().unwrap();
        (path, values)
    }

    #[test]
    fn chunk_and_range_reads_match_full_decode() {
        let (path, values) = build_store("range", 10_000);
        for backend in [Backend::Mmap, Backend::File] {
            let r = StoreReader::open_with(&path, backend, DEFAULT_CACHE_VALUES).unwrap();
            assert_eq!(r.backend(), backend);
            let full = r.get_tensor("t").unwrap();
            assert_eq!(full, values, "{backend:?}");
            let t = r.meta("t").unwrap();
            assert_eq!(t.chunks.len(), 8);
            for ci in 0..t.chunks.len() {
                let covered = t.chunk_value_range(ci);
                let chunk = r.get_chunk("t", ci).unwrap();
                assert_eq!(
                    chunk.as_slice(),
                    &values[covered.start as usize..covered.end as usize]
                );
            }
            for (lo, hi) in [(0u64, 1u64), (999, 1001), (1250, 8751), (0, 10_000), (4000, 4000)] {
                assert_eq!(
                    r.get_range("t", lo..hi).unwrap(),
                    &values[lo as usize..hi as usize],
                    "{backend:?} {lo}..{hi}"
                );
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reads_touch_only_covering_chunks() {
        let (path, _) = build_store("account", 10_000);
        for backend in [Backend::Mmap, Backend::File] {
            let r = StoreReader::open_with(&path, backend, 0).unwrap(); // no cache
            let t = r.meta("t").unwrap();
            let per = t.values_per_chunk as usize;
            assert_eq!(per, 1250);
            let chunk_bytes: Vec<u64> = t.chunks.iter().map(|c| c.len).collect();

            // One chunk -> exactly that chunk's bytes, on either backend.
            r.reset_stats();
            r.get_chunk("t", 3).unwrap();
            assert_eq!(r.stats().bytes_read, chunk_bytes[3], "{backend:?}");
            assert_eq!(r.stats().chunks_decoded, 1);
            assert_eq!(r.stats().backend, backend);

            // A range inside chunk 2 -> only chunk 2.
            r.reset_stats();
            r.get_range("t", (2 * per) as u64 + 10..(3 * per) as u64 - 10).unwrap();
            assert_eq!(r.stats().bytes_read, chunk_bytes[2], "{backend:?}");

            // A range straddling chunks 4-5 -> exactly those two.
            r.reset_stats();
            r.get_range("t", (5 * per - 1) as u64..(5 * per + 1) as u64).unwrap();
            assert_eq!(r.stats().bytes_read, chunk_bytes[4] + chunk_bytes[5]);
            assert_eq!(r.stats().chunks_decoded, 2);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn cache_serves_repeat_reads_without_io() {
        let (path, _) = build_store("cache", 10_000);
        let r = StoreReader::open(&path).unwrap();
        r.get_chunk("t", 0).unwrap();
        let cold = r.stats();
        assert_eq!(cold.cache_misses, 1);
        assert_eq!(cold.hit_rate(), 0.0);
        r.get_chunk("t", 0).unwrap();
        let warm = r.stats();
        assert_eq!(warm.cache_hits, 1);
        assert_eq!(warm.bytes_read, cold.bytes_read, "hit must not re-read disk");
        assert_eq!(warm.chunks_decoded, cold.chunks_decoded);
        assert_eq!(warm.hit_rate(), 0.5);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn prefetch_warms_cache_without_demand_counters() {
        let (path, values) = build_store("prefetch", 10_000);
        let r = StoreReader::open(&path).unwrap();
        assert!(r.prefetch_chunk("t", 2).unwrap(), "cold chunk must decode");
        let s = r.stats();
        assert_eq!(s.prefetched_chunks, 1);
        assert_eq!(s.chunks_decoded, 1);
        assert!(s.bytes_read > 0, "prefetch IO is accounted");
        assert_eq!(s.cache_hits + s.cache_misses, 0, "no demand lookups yet");
        // Resident now: a repeat prefetch is a no-op, a demand read hits.
        assert!(!r.prefetch_chunk("t", 2).unwrap());
        let covered = r.meta("t").unwrap().chunk_value_range(2);
        assert_eq!(
            r.get_chunk("t", 2).unwrap().as_slice(),
            &values[covered.start as usize..covered.end as usize]
        );
        let s = r.stats();
        assert_eq!((s.cache_hits, s.cache_misses, s.prefetched_chunks), (1, 0, 1));
        assert!(r.prefetch_chunk("nope", 0).is_err());
        assert!(r.prefetch_chunk("t", 99).is_err());
        // Caching disabled: prefetch is a no-op, not an error.
        let off = StoreReader::open_with(&path, Backend::Mmap, 0).unwrap();
        assert!(!off.prefetch_chunk("t", 0).unwrap());
        assert_eq!(off.stats().prefetched_chunks, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn errors_on_unknown_names_and_bad_ranges() {
        let (path, _) = build_store("errs", 1000);
        let r = StoreReader::open(&path).unwrap();
        assert!(r.get_tensor("nope").is_err());
        assert!(r.get_chunk("t", 99).is_err());
        assert!(r.get_range("t", 5..4).is_err());
        assert!(r.get_range("t", 0..1001).is_err());
        assert!(r.meta("nope").is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn verify_passes_clean_store() {
        let (path, _) = build_store("verify", 5000);
        let r = StoreReader::open(&path).unwrap();
        let rep = r.verify().unwrap();
        assert_eq!(rep.shards, 1);
        assert_eq!(rep.tensors, 1);
        assert_eq!(rep.chunks, r.meta("t").unwrap().chunks.len());
        assert!(rep.bytes > 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_get_range_needs_no_io_lock() {
        // Many threads over one uncached reader: every byte fetched is a
        // positioned read with no shared cursor, so results stay correct
        // under full concurrency (the old Mutex<File> would still be
        // correct, just serialized — this guards the lock-free path).
        let (path, values) = build_store("lockfree", 10_000);
        let r = StoreReader::open_with(&path, Backend::Mmap, 0).unwrap();
        let r = &r;
        let values = &values;
        std::thread::scope(|scope| {
            for tid in 0..8u64 {
                scope.spawn(move || {
                    for i in 0..40u64 {
                        let lo = (tid * 997 + i * 131) % 9_000;
                        let hi = lo + 1 + (i * 53) % 1_000;
                        assert_eq!(
                            r.get_range("t", lo..hi).unwrap(),
                            &values[lo as usize..hi as usize]
                        );
                    }
                });
            }
        });
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn body_versions_roundtrip_and_verify_through_reader() {
        // One big chunk so the v2 store actually fans out to the full
        // default lane count (small chunks degrade to fewer lanes).
        let policy = PartitionPolicy { substreams: 1, min_per_stream: 1 << 20 };
        let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, 40_000, 91);
        for (tag, body, want) in [
            ("bodyv1", BodyConfig::v1(), (1u8, 1u8)),
            ("bodyv2", BodyConfig::default(), (2u8, crate::apack::DEFAULT_LANES)),
        ] {
            let path = temp_path(tag);
            let mut w = StoreWriter::create_with(&path, policy, body).unwrap();
            w.add_tensor("t", 8, &values, TensorKind::Activations).unwrap();
            w.finish().unwrap();
            for backend in [Backend::Mmap, Backend::File] {
                let r = StoreReader::open_with(&path, backend, DEFAULT_CACHE_VALUES).unwrap();
                let t = r.meta("t").unwrap();
                assert_eq!((t.body_version, t.lanes), want, "{tag} {backend:?}");
                assert_eq!(r.get_tensor("t").unwrap(), values, "{tag} {backend:?}");
                let rep = r.verify().unwrap();
                assert_eq!((rep.tensors, rep.chunks), (1, 1), "{tag} {backend:?}");
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn kernel_knob_and_lane_threads_roundtrip_through_reader() {
        // One big v2 chunk so lanes actually fan out; every kernel ×
        // threading combination must decode bit-exactly, attribute
        // nonzero decode nanos, and expose the kernel info gauge.
        let path = temp_path("kernelknob");
        let policy = PartitionPolicy { substreams: 1, min_per_stream: 1 << 20 };
        let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, 40_000, 93);
        let mut w = StoreWriter::create_with(&path, policy, BodyConfig::default()).unwrap();
        w.add_tensor("t", 8, &values, TensorKind::Activations).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open_with(&path, Backend::Mmap, 0).unwrap();
        for kernel in [DecodeKernel::Scalar, DecodeKernel::Simd] {
            r.set_decode_kernel(kernel);
            assert_eq!(r.decode_kernel(), kernel);
            for threads in [0usize, 3] {
                r.set_lane_threads(threads);
                r.reset_stats();
                assert_eq!(r.get_tensor("t").unwrap(), values, "{kernel:?} x{threads}");
                let s = r.stats();
                assert_eq!(s.chunks_decoded, 1);
                assert!(s.decode_nanos > 0, "{kernel:?} x{threads} must attribute nanos");
            }
            let snap = r.registry_snapshot();
            let key =
                format!("store.decode_kernel{{kernel=\"{}\"}}", kernel.active_label());
            assert_eq!(snap.gauges.get(&key), Some(&1), "{kernel:?} gauge missing");
            // Heatmap decode nanos must track the counter (threaded path
            // included — worker nanos, not caller wall time).
            let heat = r.heatmap();
            assert!(heat.iter().any(|e| e.decode_nanos > 0), "{kernel:?}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn verify_catches_corrupt_lane_behind_valid_chunk_crc() {
        // Corrupt one byte of a v2 lane payload *before* append, so the
        // whole-chunk CRC (computed at append time) covers the corrupted
        // bytes and passes — only the per-lane CRC sweep can notice.
        let path = temp_path("lanecrc");
        let policy = PartitionPolicy { substreams: 1, min_per_stream: 1 << 20 };
        let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, 40_000, 92);
        let mut t = encode_tensor_with(
            &policy,
            BodyConfig::default(),
            "t",
            8,
            &values,
            TensorKind::Activations,
            None,
            0,
        )
        .unwrap();
        assert_eq!(t.chunks.len(), 1);
        let body = &mut t.chunks[0].body;
        let mid = body.len() / 2; // deep inside the lane payloads
        body[mid] ^= 0x10;
        let mut w = StoreWriter::create_with(&path, policy, BodyConfig::default()).unwrap();
        w.append_encoded(t).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&path).unwrap();
        match r.verify() {
            Err(Error::CorruptStream { .. }) => {}
            other => panic!("expected CorruptStream from lane CRC sweep, got {other:?}"),
        }
        // The non-bailing report classifies the same corruption as a
        // lane-CRC issue (behind a valid whole-chunk CRC) and flags the
        // chunk as quarantined.
        let rep = r.verify_report();
        assert!(!rep.is_clean());
        assert_eq!(rep.issues.len(), 1);
        assert_eq!(rep.issues[0].class, CorruptionClass::LaneCrc);
        assert_eq!(rep.worst_class(), Some(CorruptionClass::LaneCrc));
        assert!(r.stats().quarantined_chunks >= 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn transient_read_errors_retry_to_success() {
        use crate::store::io::{FaultConfig, FaultPlan};
        let (path, values) = build_store("retry", 5000);
        // A plan that fails every read until its 3-error budget is spent:
        // the very first open-time read absorbs the whole budget inside
        // its bounded retry loop, so the open and every later read
        // succeed without surfacing an error — on both backends (the
        // fault wrapper forces mmap through the fallible read path too).
        for backend in [Backend::Mmap, Backend::File] {
            let plan = FaultPlan::new(FaultConfig {
                read_error_rate: 1.0,
                max_injected_errors: 3,
                ..FaultConfig::default()
            });
            let r = StoreReader::open_opts(&path, backend, 0, Some(&plan)).unwrap();
            assert_eq!(r.get_tensor("t").unwrap(), values, "{backend:?}");
            assert_eq!(plan.injected_errors(), 3, "{backend:?}");
            assert!(plan.reads() > 0, "{backend:?}");
        }
        // An unbounded plan exhausts the bounded retries: the surfaced
        // error is typed transient, never corruption.
        let plan = FaultPlan::new(FaultConfig {
            read_error_rate: 1.0,
            ..FaultConfig::default()
        });
        match StoreReader::open_opts(&path, Backend::File, 0, Some(&plan)) {
            Err(e) => assert!(e.is_transient(), "expected transient, got {e:?}"),
            Ok(_) => panic!("open must fail under unbounded injected read errors"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn read_at_retry_counts_against_supplied_counter() {
        use crate::store::io::{FaultConfig, FaultPlan};
        let (path, _) = build_store("retrycount", 2000);
        let plan = FaultPlan::new(FaultConfig {
            read_error_rate: 1.0,
            max_injected_errors: 2,
            ..FaultConfig::default()
        });
        let source = plan.wrap(Backend::File.open(&path).unwrap());
        let registry = MetricsRegistry::new();
        let retries = registry.counter("store.transient_retries");
        let mut magic = [0u8; 8];
        read_at_retry(source.as_ref(), 0, &mut magic, Some(&retries)).unwrap();
        assert_eq!(&magic[..], &STORE_MAGIC[..]);
        assert_eq!(retries.get(), 2, "both injected flakes counted as retries");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn classic_store_reports_generation_zero() {
        let (path, _) = build_store("genzero", 2000);
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.generation(), 0);
        assert!(r.trailer_offset() > 0);
        assert_eq!(r.registry_snapshot().gauge("store.generation"), 0);
        assert_eq!(r.stats().generation, 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn verify_report_classifies_without_bailing() {
        let (path, _) = build_store("vreport", 10_000);
        let (off, total_chunks) = {
            let r = StoreReader::open(&path).unwrap();
            let t = r.meta("t").unwrap();
            (t.chunks[2].offset, t.chunks.len())
        };
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[off as usize + 4] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let r = StoreReader::open(&path).unwrap();
        let rep = r.verify_report();
        assert!(!rep.is_clean());
        assert_eq!(rep.issues.len(), 1, "exactly the corrupted chunk is flagged");
        let issue = &rep.issues[0];
        assert_eq!(issue.class, CorruptionClass::ChunkCrc);
        assert_eq!(issue.tensor.as_deref(), Some("t"));
        assert_eq!(issue.chunk, Some(2));
        assert_eq!(rep.chunks, total_chunks, "sweep covers every chunk");
        assert!(rep.bytes > 0, "clean chunks still count into bytes");
        assert_eq!(rep.worst_class(), Some(CorruptionClass::ChunkCrc));
        // The bail-on-first-error wrapper surfaces the same failure.
        assert!(r.verify().is_err());
        assert!(r.stats().quarantined_chunks >= 1);
        std::fs::remove_file(&path).ok();
    }
}
