//! Sequential APackStore writer: stream chunk blobs, seal with the footer
//! index and trailer. The ingest work (profile → tablegen → chunk encode)
//! is factored into [`encode_tensor`], which produces a self-contained
//! [`EncodedTensor`] that [`StoreWriter::append_encoded`] appends — the
//! seam the pipelined packer ([`super::pipeline`]) uses to overlap tensor
//! N+1's encode with tensor N's ordered append. File I/O stays sequential
//! and append-only; every stage is timed into [`PackStats`] (DESIGN.md §9).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::apack::container::encode_body;
use crate::apack::lanes::encode_body_v2;
use crate::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::apack::{Histogram, SymbolTable};
use crate::coordinator::PartitionPolicy;
use crate::error::{Error, Result};
use crate::models::zoo::ModelConfig;
use crate::obs::{self, rates, Counter, MetricsRegistry, RegistrySnapshot, Stage};
use crate::util::par_map_with;

use super::format::{
    crc32, trailer_bytes, BodyConfig, BodyVersion, ChunkMeta, StoreFormat, StoreIndex,
    TensorMeta,
};
use super::pipeline::{pack_zoo_into, PackOptions};

/// Ingest-stage timing/throughput breakdown for one pack (or one tensor,
/// before aggregation): where the `store pack` wall time went. Stage nanos
/// are **CPU time summed across pipeline workers** (they overlap under the
/// pipelined packer); `wall_nanos` is end-to-end wall time, so
/// `values_per_s` reflects what the user actually waited for.
#[derive(Debug, Clone, Copy, Default)]
pub struct PackStats {
    /// Values appended.
    pub values: u64,
    /// Raw (uncompressed) payload bits of those values.
    pub raw_bits: u64,
    /// Compressed chunk-blob bytes written.
    pub written_bytes: u64,
    /// Trace/tensor synthesis time (zoo packs; zero for direct adds).
    pub synth_nanos: u64,
    /// Histogram + Listing-1 table search time.
    pub tablegen_nanos: u64,
    /// Chunk encode time (symbol + offset streams).
    pub encode_nanos: u64,
    /// Sequential blob append time.
    pub write_nanos: u64,
    /// End-to-end wall time (writer creation → seal).
    pub wall_nanos: u64,
}

impl PackStats {
    /// Build the stats view from a registry snapshot holding `ingest.*`
    /// names (DESIGN.md §10 glossary). `wall_nanos` is not a counter —
    /// the writer stamps it from its own clock after taking the view.
    pub fn from_snapshot(snap: &RegistrySnapshot) -> Self {
        PackStats {
            values: snap.counter("ingest.values"),
            raw_bits: snap.counter("ingest.raw_bits"),
            written_bytes: snap.counter("ingest.written_bytes"),
            synth_nanos: snap.counter("ingest.synth_nanos"),
            tablegen_nanos: snap.counter("ingest.tablegen_nanos"),
            encode_nanos: snap.counter("ingest.encode_nanos"),
            write_nanos: snap.counter("ingest.write_nanos"),
            wall_nanos: 0,
        }
    }

    /// Fold another stats record in: stage times and volumes add, wall
    /// times take the max (shard writers run over the same wall clock).
    pub fn merge(&mut self, o: &PackStats) {
        self.values += o.values;
        self.raw_bits += o.raw_bits;
        self.written_bytes += o.written_bytes;
        self.synth_nanos += o.synth_nanos;
        self.tablegen_nanos += o.tablegen_nanos;
        self.encode_nanos += o.encode_nanos;
        self.write_nanos += o.write_nanos;
        self.wall_nanos = self.wall_nanos.max(o.wall_nanos);
    }

    /// Total tablegen milliseconds.
    pub fn tablegen_ms(&self) -> f64 {
        self.tablegen_nanos as f64 / 1e6
    }

    /// Encode throughput over raw value bytes.
    pub fn encode_mb_per_s(&self) -> f64 {
        rates::mb_per_s((self.raw_bits / 8) as f64, self.encode_nanos)
    }

    /// Append throughput over compressed bytes.
    pub fn write_mb_per_s(&self) -> f64 {
        rates::mb_per_s(self.written_bytes as f64, self.write_nanos)
    }

    /// End-to-end packed values per second (wall time).
    pub fn values_per_s(&self) -> f64 {
        rates::per_sec(self.values as f64, self.wall_nanos)
    }

    /// The `store pack` footer line.
    pub fn render(&self) -> String {
        format!(
            "pack stats: {} values at {:.2} Mvalues/s end-to-end — synth {:.0} ms, \
             tablegen {:.0} ms, encode {:.1} MB/s raw, write {:.1} MB/s compressed",
            self.values,
            self.values_per_s() / 1e6,
            self.synth_nanos as f64 / 1e6,
            self.tablegen_ms(),
            self.encode_mb_per_s(),
            self.write_mb_per_s()
        )
    }
}

/// One encoded chunk of an [`EncodedTensor`]: a v1
/// ([`crate::apack::Container::body_to_bytes`]) or v2
/// ([`crate::apack::encode_body_v2`]) body record plus its value count.
#[derive(Debug, Clone)]
pub struct EncodedChunk {
    pub body: Vec<u8>,
    pub n_values: u64,
}

/// A fully encoded tensor, ready for ordered append: everything
/// [`StoreWriter::append_encoded`] needs, produced off the writer by
/// [`encode_tensor`] (possibly on a pipeline worker thread).
#[derive(Debug, Clone)]
pub struct EncodedTensor {
    pub name: String,
    pub kind: TensorKind,
    pub n_values: u64,
    pub values_per_chunk: u64,
    pub table: SymbolTable,
    pub chunks: Vec<EncodedChunk>,
    /// Chunk-body framing the chunks were encoded with (1 or 2) and the
    /// requested v2 lane count — recorded into the footer at append time.
    pub body_version: u8,
    pub lanes: u8,
    /// Stage nanos attributed to this tensor (summed into [`PackStats`]
    /// at append time).
    pub synth_nanos: u64,
    pub tablegen_nanos: u64,
    pub encode_nanos: u64,
}

/// Profile (unless a table is supplied) and chunk-encode one tensor —
/// the ingest compute stage, independent of any writer so pipeline
/// workers can run it concurrently with the append stage.
///
/// `encode_threads` bounds the chunk-encode parallelism: `0` uses the
/// machine's parallelism (the serial packer's behaviour, encoding one
/// tensor's chunks in parallel), `1` encodes chunks in-line (the pipelined
/// packer's choice — tensor-level parallelism already saturates cores).
/// The encoded bytes are identical either way. Bodies use the default
/// [`BodyConfig`] (v2 lanes); see [`encode_tensor_with`] to choose.
pub fn encode_tensor(
    policy: &PartitionPolicy,
    name: &str,
    bits: u32,
    values: &[u32],
    kind: TensorKind,
    table: Option<SymbolTable>,
    encode_threads: usize,
) -> Result<EncodedTensor> {
    encode_tensor_with(
        policy,
        BodyConfig::default(),
        name,
        bits,
        values,
        kind,
        table,
        encode_threads,
    )
}

/// [`encode_tensor`] with an explicit chunk-body configuration: v1
/// single-stream bodies (the seed format, byte-identical output) or v2
/// lane bodies at a requested lane count (each chunk clamps the request
/// via [`crate::apack::lane_count`]).
#[allow(clippy::too_many_arguments)]
pub fn encode_tensor_with(
    policy: &PartitionPolicy,
    body: BodyConfig,
    name: &str,
    bits: u32,
    values: &[u32],
    kind: TensorKind,
    table: Option<SymbolTable>,
    encode_threads: usize,
) -> Result<EncodedTensor> {
    let mut tablegen_nanos = 0u64;
    let table = match table {
        Some(t) => t,
        None if values.is_empty() => SymbolTable::uniform(bits),
        None => {
            let t0 = Instant::now();
            let hist = {
                let _h = obs::span_n(Stage::Histogram, values.len() as u64);
                Histogram::from_values(bits, values)
            };
            let t = {
                let _tg = obs::span(Stage::TableGen);
                generate_table(&hist, kind, &TableGenConfig::for_bits(bits))?
            };
            tablegen_nanos = t0.elapsed().as_nanos() as u64;
            t
        }
    };
    let chunks = policy.split(values);
    let values_per_chunk = chunks.first().map(|c| c.len() as u64).unwrap_or(1).max(1);
    let threads = if encode_threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    } else {
        encode_threads
    };
    let lanes = body.effective_lanes();
    let t0 = Instant::now();
    // One Encode span per tensor (the per-chunk encode itself runs on
    // whatever worker threads `par_map_with` picks).
    let bodies: Result<Vec<Vec<u8>>> = {
        let _enc = obs::span_n(Stage::Encode, values.len() as u64);
        par_map_with(&chunks, threads, |chunk| match body.version {
            BodyVersion::V1 => encode_body(&table, chunk),
            BodyVersion::V2 => encode_body_v2(&table, chunk, lanes),
        })
        .into_iter()
        .collect()
    };
    let bodies = bodies?;
    let encode_nanos = t0.elapsed().as_nanos() as u64;
    let chunks = chunks
        .iter()
        .zip(bodies)
        .map(|(chunk, body)| EncodedChunk { body, n_values: chunk.len() as u64 })
        .collect();
    Ok(EncodedTensor {
        name: name.to_string(),
        kind,
        n_values: values.len() as u64,
        values_per_chunk,
        table,
        chunks,
        body_version: body.version.as_u8(),
        lanes,
        synth_nanos: 0,
        tablegen_nanos,
        encode_nanos,
    })
}

/// Summary returned by [`StoreWriter::finish`].
#[derive(Debug, Clone)]
pub struct StoreSummary {
    pub tensors: usize,
    pub chunks: usize,
    /// Total file size in bytes (blobs + footer + framing).
    pub file_bytes: u64,
    /// Sum of raw (uncompressed) tensor bits.
    pub raw_bits: u64,
    /// Ingest timing/throughput breakdown.
    pub pack: PackStats,
}

impl StoreSummary {
    /// Whole-store compression ratio vs. raw values.
    pub fn compression_ratio(&self) -> f64 {
        self.raw_bits as f64 / (self.file_bytes as f64 * 8.0)
    }
}

/// Writes one APackStore file. Add tensors, then call [`Self::finish`];
/// dropping a writer without finishing leaves an unreadable file (no
/// trailer), which the reader rejects — a torn write cannot masquerade as
/// a complete store.
pub struct StoreWriter {
    out: BufWriter<File>,
    /// Next blob's absolute file offset.
    offset: u64,
    tensors: Vec<TensorMeta>,
    policy: PartitionPolicy,
    /// Chunk-body configuration for tensors encoded by this writer; also
    /// fixes the file format (magic + footer schema), chosen at create
    /// time because the magic is the first write.
    body: BodyConfig,
    /// `ingest.*` metrics (DESIGN.md §10); [`PackStats`] is the view over
    /// a snapshot of this registry at [`Self::finish`] time.
    registry: MetricsRegistry,
    values: Arc<Counter>,
    raw_bits: Arc<Counter>,
    written_bytes: Arc<Counter>,
    synth_nanos: Arc<Counter>,
    tablegen_nanos: Arc<Counter>,
    encode_nanos: Arc<Counter>,
    write_nanos: Arc<Counter>,
    created: Instant,
}

impl StoreWriter {
    /// Create (truncate) the store file and write the leading magic.
    /// `policy` controls chunking: each tensor is split into
    /// `policy.shards_for(len)` fixed-value-count chunks. Bodies use the
    /// default [`BodyConfig`] (v2 lanes); see [`Self::create_with`].
    pub fn create(path: &Path, policy: PartitionPolicy) -> Result<Self> {
        Self::create_with(path, policy, BodyConfig::default())
    }

    /// [`Self::create`] with an explicit chunk-body configuration. The
    /// body version fixes the file format — `BodyConfig::v1()` writes a
    /// seed-compatible `APACKST1` file byte-identical to pre-v2 builds;
    /// v2 bodies write `APACKST2` (extended footer).
    pub fn create_with(path: &Path, policy: PartitionPolicy, body: BodyConfig) -> Result<Self> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        let magic = body.store_format().magic();
        out.write_all(&magic)?;
        let registry = MetricsRegistry::new();
        Ok(Self {
            out,
            offset: magic.len() as u64,
            tensors: Vec::new(),
            policy,
            body,
            values: registry.counter("ingest.values"),
            raw_bits: registry.counter("ingest.raw_bits"),
            written_bytes: registry.counter("ingest.written_bytes"),
            synth_nanos: registry.counter("ingest.synth_nanos"),
            tablegen_nanos: registry.counter("ingest.tablegen_nanos"),
            encode_nanos: registry.counter("ingest.encode_nanos"),
            write_nanos: registry.counter("ingest.write_nanos"),
            registry,
            created: Instant::now(),
        })
    }

    /// Reject duplicate or unstorable names — called *before* any encode
    /// work in the `add_tensor*` paths (a bad name must not cost a full
    /// tablegen + encode first) and again by [`Self::append_encoded`] for
    /// tensors encoded off-writer.
    fn validate_name(&self, name: &str) -> Result<()> {
        if self.tensors.iter().any(|m| m.name == name) {
            return Err(Error::Store(format!("duplicate tensor name {name:?}")));
        }
        if name.is_empty() || name.len() > u16::MAX as usize {
            return Err(Error::Store(format!("tensor name length {} invalid", name.len())));
        }
        Ok(())
    }

    /// Compress and append a tensor, profiling its table from the values
    /// themselves (the weights path of paper §VI).
    pub fn add_tensor(
        &mut self,
        name: &str,
        bits: u32,
        values: &[u32],
        kind: TensorKind,
    ) -> Result<()> {
        self.validate_name(name)?;
        let t =
            encode_tensor_with(&self.policy, self.body, name, bits, values, kind, None, 0)?;
        self.append_encoded(t)
    }

    /// Compress and append a tensor with a prebuilt table (e.g. an
    /// activation table profiled on pooled samples, §VII).
    pub fn add_tensor_with_table(
        &mut self,
        name: &str,
        values: &[u32],
        kind: TensorKind,
        table: SymbolTable,
    ) -> Result<()> {
        self.validate_name(name)?;
        let bits = table.bits();
        let t = encode_tensor_with(
            &self.policy,
            self.body,
            name,
            bits,
            values,
            kind,
            Some(table),
            0,
        )?;
        self.append_encoded(t)
    }

    /// Append a pre-encoded tensor: the sequential IO stage of the ingest
    /// pipeline. Validates the name, streams the chunk blobs, records the
    /// footer metadata and folds the tensor's stage timings into the
    /// writer's [`PackStats`].
    pub fn append_encoded(&mut self, t: EncodedTensor) -> Result<()> {
        self.validate_name(&t.name)?;
        if self.body.store_format() == StoreFormat::V1 && t.body_version != 1 {
            return Err(Error::Store(format!(
                "tensor {:?} uses body v{}, but this APACKST1 file can only \
                 describe v1 bodies",
                t.name, t.body_version
            )));
        }
        let t0 = Instant::now();
        let mut append = obs::span(Stage::Append);
        let mut metas = Vec::with_capacity(t.chunks.len());
        for chunk in &t.chunks {
            metas.push(ChunkMeta {
                offset: self.offset,
                len: chunk.body.len() as u64,
                n_values: chunk.n_values,
                crc32: crc32(&chunk.body),
            });
            self.out.write_all(&chunk.body)?;
            self.offset += chunk.body.len() as u64;
        }
        let appended = metas.iter().map(|m| m.len).sum::<u64>();
        append.set_count(appended);
        drop(append);
        self.write_nanos.add(t0.elapsed().as_nanos() as u64);
        self.synth_nanos.add(t.synth_nanos);
        self.tablegen_nanos.add(t.tablegen_nanos);
        self.encode_nanos.add(t.encode_nanos);
        self.values.add(t.n_values);
        self.raw_bits.add(t.n_values * t.table.bits() as u64);
        self.written_bytes.add(appended);
        self.tensors.push(TensorMeta {
            name: t.name,
            bits: t.table.bits(),
            kind: t.kind,
            n_values: t.n_values,
            values_per_chunk: t.values_per_chunk,
            body_version: t.body_version,
            lanes: t.lanes,
            table: t.table,
            chunks: metas,
        });
        Ok(())
    }

    /// The writer's chunk-body configuration (callers producing
    /// [`EncodedTensor`]s off-writer must encode with the same config for
    /// the append-time format check to pass).
    pub fn body(&self) -> BodyConfig {
        self.body
    }

    /// The writer's chunking policy (callers producing [`EncodedTensor`]s
    /// off-writer must encode with the same policy).
    pub fn policy(&self) -> PartitionPolicy {
        self.policy
    }

    /// Tensors written so far.
    pub fn tensor_count(&self) -> usize {
        self.tensors.len()
    }

    /// Snapshot the writer's `ingest.*` metrics mid-pack (the JSONL
    /// snapshot stream and `PackStats::from_snapshot` read this).
    pub fn registry_snapshot(&self) -> RegistrySnapshot {
        self.registry.snapshot()
    }

    /// Write footer + trailer, flush, and fsync. The file is only
    /// readable after this returns, and — because the seal is synced to
    /// disk before we report success — a store `finish` claimed durable
    /// really is (the live-store commit protocol of DESIGN.md §14 builds
    /// on this invariant).
    pub fn finish(mut self) -> Result<StoreSummary> {
        let index = StoreIndex::new(std::mem::take(&mut self.tensors));
        let footer = index.to_bytes(self.body.store_format());
        let footer_offset = self.offset;
        let t0 = Instant::now();
        {
            let _seal = obs::span_n(Stage::Seal, footer.len() as u64);
            self.out.write_all(&footer)?;
            self.out.write_all(&trailer_bytes(
                footer_offset,
                footer.len() as u64,
                crc32(&footer),
                index.tensors.len() as u32,
            ))?;
            self.out.flush()?;
            self.out.get_ref().sync_data()?;
        }
        self.write_nanos.add(t0.elapsed().as_nanos() as u64);
        let mut pack = PackStats::from_snapshot(&self.registry.snapshot());
        pack.wall_nanos = self.created.elapsed().as_nanos() as u64;
        let file_bytes =
            footer_offset + footer.len() as u64 + super::format::TRAILER_BYTES as u64;
        Ok(StoreSummary {
            tensors: index.tensors.len(),
            chunks: index.tensors.iter().map(|t| t.chunks.len()).sum(),
            file_bytes,
            raw_bits: index.tensors.iter().map(|t| t.raw_bits()).sum(),
            pack,
        })
    }
}

/// Estimate of the total values `pack_model_zoo`/`pack_model_zoo_sharded`
/// will store for `models` at `sample_cap` — weights plus studied
/// activations, both sample-capped. Used to clamp the shard-file count
/// before any trace is synthesized
/// ([`PartitionPolicy::file_shards_for`]).
pub fn zoo_value_estimate(models: &[ModelConfig], sample_cap: usize) -> u64 {
    let cap = sample_cap as u64;
    models
        .iter()
        .map(|cfg| {
            cfg.layers
                .iter()
                .map(|l| {
                    let w = l.weight_elems().min(cap);
                    let a = if cfg.act_profile.is_some() {
                        l.input_elems().min(cap)
                    } else {
                        0
                    };
                    w + a
                })
                .sum::<u64>()
        })
        .sum()
}

/// Pack synthesized traces of `models` into one store — the Table II zoo
/// as a servable artifact (see [`super::pipeline::encode_zoo_model`] for
/// the naming and table-profiling scheme). Pipelined by default; see
/// [`pack_model_zoo_with`].
pub fn pack_model_zoo(
    path: &Path,
    models: &[ModelConfig],
    sample_cap: usize,
    policy: PartitionPolicy,
) -> Result<StoreSummary> {
    pack_model_zoo_with(path, models, sample_cap, policy, &PackOptions::default())
}

/// [`pack_model_zoo`] with explicit [`PackOptions`] — `pipelined: false`
/// selects the serial (profile-then-encode-then-append per tensor) path,
/// kept for the `store_pack` bench's same-run baseline. Both paths
/// produce byte-identical store files.
pub fn pack_model_zoo_with(
    path: &Path,
    models: &[ModelConfig],
    sample_cap: usize,
    policy: PartitionPolicy,
    opts: &PackOptions,
) -> Result<StoreSummary> {
    let mut writer = StoreWriter::create_with(path, policy, opts.body)?;
    pack_zoo_into(&mut writer, models, sample_cap, &policy, opts)?;
    writer.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::distributions::ValueProfile;
    use crate::store::StoreReader;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("apack_writer_{}_{tag}.apackstore", std::process::id()))
    }

    fn tensor(n: usize, seed: u64) -> Vec<u32> {
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, seed)
    }

    #[test]
    fn writer_roundtrip_and_summary() {
        let path = temp_path("roundtrip");
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 256 };
        let mut w = StoreWriter::create(&path, policy).unwrap();
        let a = tensor(10_000, 1);
        let b = tensor(500, 2);
        w.add_tensor("a", 8, &a, TensorKind::Activations).unwrap();
        w.add_tensor("b", 8, &b, TensorKind::Weights).unwrap();
        let summary = w.finish().unwrap();
        assert_eq!(summary.tensors, 2);
        assert_eq!(summary.raw_bits, (10_500) * 8);
        assert!(summary.compression_ratio() > 1.0, "{}", summary.compression_ratio());
        // Pack stats account the appended volume.
        assert_eq!(summary.pack.values, 10_500);
        assert_eq!(summary.pack.raw_bits, 10_500 * 8);
        assert!(summary.pack.written_bytes > 0);
        assert!(summary.pack.wall_nanos > 0);
        assert!(summary.pack.tablegen_nanos > 0, "profiled adds must time tablegen");

        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.get_tensor("a").unwrap(), a);
        assert_eq!(r.get_tensor("b").unwrap(), b);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn duplicate_and_invalid_names_rejected() {
        let path = temp_path("dup");
        let mut w = StoreWriter::create(&path, PartitionPolicy::default()).unwrap();
        let v = tensor(100, 3);
        w.add_tensor("x", 8, &v, TensorKind::Weights).unwrap();
        assert!(w.add_tensor("x", 8, &v, TensorKind::Weights).is_err());
        assert!(w.add_tensor("", 8, &v, TensorKind::Weights).is_err());
        drop(w);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unfinished_store_is_unreadable() {
        let path = temp_path("torn");
        let mut w = StoreWriter::create(&path, PartitionPolicy::default()).unwrap();
        w.add_tensor("x", 8, &tensor(5000, 4), TensorKind::Weights).unwrap();
        drop(w); // no finish(): no trailer
        assert!(StoreReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_tensor_roundtrips() {
        let path = temp_path("empty");
        let mut w = StoreWriter::create(&path, PartitionPolicy::default()).unwrap();
        w.add_tensor("e", 8, &[], TensorKind::Weights).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.get_tensor("e").unwrap(), Vec::<u32>::new());
        assert_eq!(r.meta("e").unwrap().chunks.len(), 0);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn v1_body_config_writes_seed_compatible_store() {
        let path = temp_path("v1cfg");
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 256 };
        let mut w = StoreWriter::create_with(&path, policy, BodyConfig::v1()).unwrap();
        let a = tensor(10_000, 9);
        w.add_tensor("a", 8, &a, TensorKind::Activations).unwrap();
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"APACKST1");
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.get_tensor("a").unwrap(), a);
        assert_eq!(r.meta("a").unwrap().body_version, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn default_pack_writes_v2_lane_bodies() {
        let path = temp_path("v2def");
        let policy = PartitionPolicy { substreams: 1, min_per_stream: 1 << 20 };
        let mut w = StoreWriter::create(&path, policy).unwrap();
        let a = tensor(40_000, 5);
        w.add_tensor("a", 8, &a, TensorKind::Activations).unwrap();
        w.finish().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], b"APACKST2");
        let r = StoreReader::open(&path).unwrap();
        let m = r.meta("a").unwrap();
        assert_eq!((m.body_version, m.lanes), (2, crate::apack::DEFAULT_LANES));
        assert_eq!(r.get_tensor("a").unwrap(), a);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn non_power_of_two_lane_request_packs_and_reopens() {
        // `--lanes 12` (any non-power-of-two) must round down to 8 at
        // encode time; previously the raw value reached the footer and
        // the store could never be reopened.
        let path = temp_path("lanes12");
        let policy = PartitionPolicy { substreams: 1, min_per_stream: 1 << 20 };
        let mut w = StoreWriter::create_with(&path, policy, BodyConfig::v2(12)).unwrap();
        let a = tensor(40_000, 11);
        w.add_tensor("a", 8, &a, TensorKind::Activations).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&path).unwrap();
        let m = r.meta("a").unwrap();
        assert_eq!((m.body_version, m.lanes), (2, 8));
        assert_eq!(r.get_tensor("a").unwrap(), a);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn v1_file_rejects_v2_encoded_tensor() {
        let path = temp_path("v1rej");
        let policy = PartitionPolicy::default();
        let mut w = StoreWriter::create_with(&path, policy, BodyConfig::v1()).unwrap();
        let t = encode_tensor_with(
            &policy,
            BodyConfig::default(),
            "x",
            8,
            &tensor(5000, 2),
            TensorKind::Weights,
            None,
            1,
        )
        .unwrap();
        assert_eq!(t.body_version, 2);
        assert!(w.append_encoded(t).is_err());
        drop(w);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn encode_tensor_threads_do_not_change_bytes() {
        // Chunk-encode parallelism is a scheduling choice, not a format
        // one: 1-thread and N-thread encodes emit identical chunks.
        let policy = PartitionPolicy { substreams: 8, min_per_stream: 128 };
        let v = tensor(20_000, 7);
        let serial =
            encode_tensor(&policy, "t", 8, &v, TensorKind::Weights, None, 1).unwrap();
        let parallel =
            encode_tensor(&policy, "t", 8, &v, TensorKind::Weights, None, 0).unwrap();
        assert_eq!(serial.chunks.len(), parallel.chunks.len());
        for (a, b) in serial.chunks.iter().zip(&parallel.chunks) {
            assert_eq!(a.body, b.body);
            assert_eq!(a.n_values, b.n_values);
        }
        assert_eq!(serial.table.to_bytes(), parallel.table.to_bytes());
    }
}
