//! Sequential APackStore writer: stream chunk blobs, seal with the footer
//! index and trailer. Chunk encoding runs in parallel (one engine per
//! chunk, like the replicated hardware engines of paper §V-B); file I/O
//! stays sequential and append-only.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use crate::apack::container::compress_with_table;
use crate::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::apack::{Histogram, SymbolTable};
use crate::coordinator::PartitionPolicy;
use crate::error::{Error, Result};
use crate::eval::{EVAL_SEED, PROFILE_SAMPLES};
use crate::models::trace::ModelTrace;
use crate::models::zoo::ModelConfig;
use crate::util::par_map;

use super::format::{crc32, trailer_bytes, ChunkMeta, StoreIndex, TensorMeta, STORE_MAGIC};

/// Summary returned by [`StoreWriter::finish`].
#[derive(Debug, Clone)]
pub struct StoreSummary {
    pub tensors: usize,
    pub chunks: usize,
    /// Total file size in bytes (blobs + footer + framing).
    pub file_bytes: u64,
    /// Sum of raw (uncompressed) tensor bits.
    pub raw_bits: u64,
}

impl StoreSummary {
    /// Whole-store compression ratio vs. raw values.
    pub fn compression_ratio(&self) -> f64 {
        self.raw_bits as f64 / (self.file_bytes as f64 * 8.0)
    }
}

/// Writes one APackStore file. Add tensors, then call [`Self::finish`];
/// dropping a writer without finishing leaves an unreadable file (no
/// trailer), which the reader rejects — a torn write cannot masquerade as
/// a complete store.
pub struct StoreWriter {
    out: BufWriter<File>,
    /// Next blob's absolute file offset.
    offset: u64,
    tensors: Vec<TensorMeta>,
    policy: PartitionPolicy,
}

impl StoreWriter {
    /// Create (truncate) the store file and write the leading magic.
    /// `policy` controls chunking: each tensor is split into
    /// `policy.shards_for(len)` fixed-value-count chunks.
    pub fn create(path: &Path, policy: PartitionPolicy) -> Result<Self> {
        let file = File::create(path)?;
        let mut out = BufWriter::new(file);
        out.write_all(&STORE_MAGIC)?;
        Ok(Self { out, offset: STORE_MAGIC.len() as u64, tensors: Vec::new(), policy })
    }

    /// Compress and append a tensor, profiling its table from the values
    /// themselves (the weights path of paper §VI).
    pub fn add_tensor(
        &mut self,
        name: &str,
        bits: u32,
        values: &[u32],
        kind: TensorKind,
    ) -> Result<()> {
        let table = if values.is_empty() {
            SymbolTable::uniform(bits)
        } else {
            let hist = Histogram::from_values(bits, values);
            generate_table(&hist, kind, &TableGenConfig::for_bits(bits))?
        };
        self.add_tensor_with_table(name, values, kind, table)
    }

    /// Compress and append a tensor with a prebuilt table (e.g. an
    /// activation table profiled on pooled samples, §VII).
    pub fn add_tensor_with_table(
        &mut self,
        name: &str,
        values: &[u32],
        kind: TensorKind,
        table: SymbolTable,
    ) -> Result<()> {
        if self.tensors.iter().any(|t| t.name == name) {
            return Err(Error::Store(format!("duplicate tensor name {name:?}")));
        }
        if name.is_empty() || name.len() > u16::MAX as usize {
            return Err(Error::Store(format!("tensor name length {} invalid", name.len())));
        }
        let chunks = self.policy.split(values);
        let values_per_chunk = chunks.first().map(|c| c.len() as u64).unwrap_or(1).max(1);
        // Encode every chunk in parallel against the shared table, then
        // append the blobs in order.
        let blobs: Result<Vec<Vec<u8>>> =
            par_map(&chunks, |chunk| {
                compress_with_table(table.clone(), chunk).map(|c| c.body_to_bytes())
            })
            .into_iter()
            .collect();
        let blobs = blobs?;
        let mut metas = Vec::with_capacity(blobs.len());
        for (chunk, blob) in chunks.iter().zip(&blobs) {
            metas.push(ChunkMeta {
                offset: self.offset,
                len: blob.len() as u64,
                n_values: chunk.len() as u64,
                crc32: crc32(blob),
            });
            self.out.write_all(blob)?;
            self.offset += blob.len() as u64;
        }
        self.tensors.push(TensorMeta {
            name: name.to_string(),
            bits: table.bits(),
            kind,
            n_values: values.len() as u64,
            values_per_chunk,
            table,
            chunks: metas,
        });
        Ok(())
    }

    /// Tensors written so far.
    pub fn tensor_count(&self) -> usize {
        self.tensors.len()
    }

    /// Write footer + trailer and flush. The file is only readable after
    /// this returns.
    pub fn finish(mut self) -> Result<StoreSummary> {
        let index = StoreIndex::new(std::mem::take(&mut self.tensors));
        let footer = index.to_bytes();
        let footer_offset = self.offset;
        self.out.write_all(&footer)?;
        self.out.write_all(&trailer_bytes(
            footer_offset,
            footer.len() as u64,
            crc32(&footer),
            index.tensors.len() as u32,
        ))?;
        self.out.flush()?;
        let file_bytes =
            footer_offset + footer.len() as u64 + super::format::TRAILER_BYTES as u64;
        Ok(StoreSummary {
            tensors: index.tensors.len(),
            chunks: index.tensors.iter().map(|t| t.chunks.len()).sum(),
            file_bytes,
            raw_bits: index.tensors.iter().map(|t| t.raw_bits()).sum(),
        })
    }
}

/// Stream every zoo tensor of `models` into `add` — the shared iteration
/// behind [`pack_model_zoo`] and [`super::shard::pack_model_zoo_sharded`].
/// Per layer, weights go under `"{model}/layer{i:03}/weights"` (table
/// profiled from the values themselves); studied activations go under
/// `".../activations"` with a table profiled on the pooled samples and
/// applied to the fresh tensor (paper §VII methodology), passed to `add`
/// as the prebuilt table. `sample_cap` bounds values per tensor, exactly
/// like the evaluation studies.
pub(crate) fn for_each_zoo_tensor(
    models: &[ModelConfig],
    sample_cap: usize,
    mut add: impl FnMut(&str, u32, &[u32], TensorKind, Option<SymbolTable>) -> Result<()>,
) -> Result<()> {
    for cfg in models {
        let trace = ModelTrace::synthesize(cfg, sample_cap, PROFILE_SAMPLES, EVAL_SEED);
        for l in &trace.layers {
            add(
                &format!("{}/layer{:03}/weights", cfg.name, l.layer_idx),
                l.bits,
                &l.weights,
                TensorKind::Weights,
                None,
            )?;
            if !l.activations.is_empty() {
                let hist = Histogram::from_values(l.bits, &l.act_profile_samples);
                let table = generate_table(
                    &hist,
                    TensorKind::Activations,
                    &TableGenConfig::for_bits(l.bits),
                )?;
                add(
                    &format!("{}/layer{:03}/activations", cfg.name, l.layer_idx),
                    l.bits,
                    &l.activations,
                    TensorKind::Activations,
                    Some(table),
                )?;
            }
        }
    }
    Ok(())
}

/// Estimate of the total values `pack_model_zoo`/`pack_model_zoo_sharded`
/// will store for `models` at `sample_cap` — weights plus studied
/// activations, both sample-capped. Used to clamp the shard-file count
/// before any trace is synthesized
/// ([`PartitionPolicy::file_shards_for`]).
pub fn zoo_value_estimate(models: &[ModelConfig], sample_cap: usize) -> u64 {
    let cap = sample_cap as u64;
    models
        .iter()
        .map(|cfg| {
            cfg.layers
                .iter()
                .map(|l| {
                    let w = l.weight_elems().min(cap);
                    let a = if cfg.act_profile.is_some() {
                        l.input_elems().min(cap)
                    } else {
                        0
                    };
                    w + a
                })
                .sum::<u64>()
        })
        .sum()
}

/// Pack synthesized traces of `models` into one store — the Table II zoo
/// as a servable artifact (see [`for_each_zoo_tensor`] for the naming and
/// table-profiling scheme).
pub fn pack_model_zoo(
    path: &Path,
    models: &[ModelConfig],
    sample_cap: usize,
    policy: PartitionPolicy,
) -> Result<StoreSummary> {
    let mut writer = StoreWriter::create(path, policy)?;
    for_each_zoo_tensor(models, sample_cap, |name, bits, values, kind, table| match table {
        Some(t) => writer.add_tensor_with_table(name, values, kind, t),
        None => writer.add_tensor(name, bits, values, kind),
    })?;
    writer.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::distributions::ValueProfile;
    use crate::store::StoreReader;

    fn temp_path(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("apack_writer_{}_{tag}.apackstore", std::process::id()))
    }

    fn tensor(n: usize, seed: u64) -> Vec<u32> {
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, seed)
    }

    #[test]
    fn writer_roundtrip_and_summary() {
        let path = temp_path("roundtrip");
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 256 };
        let mut w = StoreWriter::create(&path, policy).unwrap();
        let a = tensor(10_000, 1);
        let b = tensor(500, 2);
        w.add_tensor("a", 8, &a, TensorKind::Activations).unwrap();
        w.add_tensor("b", 8, &b, TensorKind::Weights).unwrap();
        let summary = w.finish().unwrap();
        assert_eq!(summary.tensors, 2);
        assert_eq!(summary.raw_bits, (10_500) * 8);
        assert!(summary.compression_ratio() > 1.0, "{}", summary.compression_ratio());

        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.get_tensor("a").unwrap(), a);
        assert_eq!(r.get_tensor("b").unwrap(), b);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn duplicate_and_invalid_names_rejected() {
        let path = temp_path("dup");
        let mut w = StoreWriter::create(&path, PartitionPolicy::default()).unwrap();
        let v = tensor(100, 3);
        w.add_tensor("x", 8, &v, TensorKind::Weights).unwrap();
        assert!(w.add_tensor("x", 8, &v, TensorKind::Weights).is_err());
        assert!(w.add_tensor("", 8, &v, TensorKind::Weights).is_err());
        drop(w);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unfinished_store_is_unreadable() {
        let path = temp_path("torn");
        let mut w = StoreWriter::create(&path, PartitionPolicy::default()).unwrap();
        w.add_tensor("x", 8, &tensor(5000, 4), TensorKind::Weights).unwrap();
        drop(w); // no finish(): no trailer
        assert!(StoreReader::open(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_tensor_roundtrips() {
        let path = temp_path("empty");
        let mut w = StoreWriter::create(&path, PartitionPolicy::default()).unwrap();
        w.add_tensor("e", 8, &[], TensorKind::Weights).unwrap();
        w.finish().unwrap();
        let r = StoreReader::open(&path).unwrap();
        assert_eq!(r.get_tensor("e").unwrap(), Vec::<u32>::new());
        assert_eq!(r.meta("e").unwrap().chunks.len(), 0);
        std::fs::remove_file(&path).ok();
    }
}
