//! Pluggable chunk IO: the [`ChunkSource`] trait and its two backends.
//!
//! The store read stack used to funnel every chunk read through a
//! `Mutex<File>` (seek + read under the lock), which serialized the very
//! parallelism the format was designed for — independently decodable
//! chunks mirroring the replicated decode engines of paper §V-B. This
//! module replaces that with **positioned reads behind a `Sync` trait with
//! no interior mutex**, so any number of reader threads can fetch chunk
//! bytes concurrently:
//!
//! - [`MmapSource`] (the default, [`Backend::Mmap`]) maps the store file
//!   read-only and serves **zero-copy** `&[u8]` slices straight out of the
//!   page cache via [`ChunkSource::slice_at`] — no buffer allocation, no
//!   syscall per read, no lock.
//! - [`FileSource`] ([`Backend::File`]) is the plain-file comparison
//!   backend: one `pread(2)`-style positioned read per chunk
//!   (`FileExt::read_exact_at` on unix), also lock-free. It exists so the
//!   bench can quantify what the mapping buys in one run.
//!
//! Both backends count the bytes they serve in a per-backend
//! [`ChunkSource::bytes_read`] counter, which the reader folds into
//! [`super::ReadStats`] so mmap and file paths are directly comparable.
//!
//! For robustness testing, [`FaultPlan`] wraps any source with seeded,
//! deterministic fault injection — transient read errors, short reads,
//! latency spikes — and exposes a write/fsync *kill-point lattice*
//! ([`FaultPlan::write_boundary`]) that the live-store appender and
//! compactor thread their commit protocols through, so crash-matrix
//! tests can sweep every interleaving (DESIGN.md §14).

use std::fs::File;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::error::{Error, Result};

/// Which IO backend a source (and the reader above it) uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Backend {
    /// Memory-mapped, zero-copy reads (default). Fastest, but assumes the
    /// store file is immutable while open — see [`MmapSource`] for the
    /// truncation caveat.
    #[default]
    Mmap,
    /// Positioned (`pread`-style) reads from an open file descriptor.
    /// Slower per read, but robust to the file being replaced underneath.
    File,
}

impl Backend {
    /// Open `path` with this backend.
    pub fn open(self, path: &Path) -> Result<Box<dyn ChunkSource>> {
        match self {
            Backend::Mmap => Ok(Box::new(MmapSource::open(path)?)),
            Backend::File => Ok(Box::new(FileSource::open(path)?)),
        }
    }

    /// Parse a CLI spelling (`"mmap"` / `"file"`).
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "mmap" => Ok(Backend::Mmap),
            "file" => Ok(Backend::File),
            other => Err(Error::Config(format!(
                "unknown store backend {other:?} (expected mmap or file)"
            ))),
        }
    }

    /// Stable lowercase name (for stats lines and benches).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Mmap => "mmap",
            Backend::File => "file",
        }
    }
}

/// Positioned, lock-free chunk IO over one store file.
///
/// Contract: implementations are `Sync` **without an interior mutex** on
/// the read path — `read_at`/`slice_at` take `&self` and may be called
/// from any number of threads concurrently. All offsets are validated
/// against [`Self::len`]; reads past EOF are errors, never truncation.
pub trait ChunkSource: Send + Sync {
    /// Total length of the underlying file in bytes.
    fn len(&self) -> u64;

    /// True when the file is empty.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Which backend this is (per-backend accounting and reporting).
    fn backend(&self) -> Backend;

    /// Read exactly `buf.len()` bytes starting at `offset`.
    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()>;

    /// Zero-copy view of `[offset, offset + len)` if this backend can
    /// serve one (mmap can; plain files cannot). Counts toward
    /// [`Self::bytes_read`] just like `read_at`.
    fn slice_at(&self, offset: u64, len: usize) -> Option<&[u8]>;

    /// Cumulative bytes served by this source since open (or the last
    /// [`Self::reset_bytes_read`]).
    fn bytes_read(&self) -> u64;

    /// Zero the byte counter (the reader calls this after parsing the
    /// footer so stats cover chunk IO only, as before).
    fn reset_bytes_read(&self);
}

/// Bounds-check a positioned read against the file length.
fn check_extent(len: u64, offset: u64, want: usize) -> Result<()> {
    let end = offset
        .checked_add(want as u64)
        .ok_or_else(|| Error::Store(format!("read extent {offset}+{want} overflows")))?;
    if end > len {
        return Err(Error::Store(format!(
            "read [{offset}, {end}) past EOF ({len} bytes)"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// FileSource: positioned pread, no mutex.
// ---------------------------------------------------------------------------

/// Plain-file backend: one positioned read syscall per chunk.
///
/// On unix this is `pread(2)` (`FileExt::read_exact_at`), which carries its
/// own offset — no seek, no shared cursor, and therefore **no lock**: the
/// mutex the old reader wrapped around the file is gone by construction.
pub struct FileSource {
    #[cfg(unix)]
    file: File,
    /// Non-unix hosts have no positioned-read API in std; fall back to a
    /// locked seek+read (correctness over scalability off-platform).
    #[cfg(not(unix))]
    file: std::sync::Mutex<File>,
    len: u64,
    bytes: AtomicU64,
}

impl FileSource {
    /// Open `path` read-only.
    pub fn open(path: &Path) -> Result<Self> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        #[cfg(not(unix))]
        let file = std::sync::Mutex::new(file);
        Ok(Self { file, len, bytes: AtomicU64::new(0) })
    }
}

impl ChunkSource for FileSource {
    fn len(&self) -> u64 {
        self.len
    }

    fn backend(&self) -> Backend {
        Backend::File
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        check_extent(self.len, offset, buf.len())?;
        #[cfg(unix)]
        {
            use std::os::unix::fs::FileExt;
            self.file.read_exact_at(buf, offset)?;
        }
        #[cfg(not(unix))]
        {
            use std::io::{Read, Seek, SeekFrom};
            let mut f = self.file.lock().expect("file source lock");
            f.seek(SeekFrom::Start(offset))?;
            f.read_exact(buf)?;
        }
        self.bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn slice_at(&self, _offset: u64, _len: usize) -> Option<&[u8]> {
        None
    }

    fn bytes_read(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn reset_bytes_read(&self) {
        self.bytes.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// MmapSource: read-only mapping, zero-copy slices.
// ---------------------------------------------------------------------------

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 0x1;
    pub const MAP_SHARED: c_int = 0x1;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }

    /// `MAP_FAILED` is `(void *)-1`.
    pub fn map_failed() -> *mut c_void {
        usize::MAX as *mut c_void
    }
}

/// Memory-mapped backend: the whole store file mapped read-only once at
/// open; every chunk read is a bounds-checked slice of the mapping. No
/// syscall, no allocation, no lock on the read path — concurrent readers
/// scale with threads until DRAM bandwidth, which is exactly the deployment
/// the replicated hardware decoders assume (paper §V-B).
///
/// **Tradeoff vs. [`FileSource`]:** a mapping is only safe while the file
/// keeps its length. If another process truncates or rewrites the store
/// in place while it is open (e.g. re-running `store pack` onto the same
/// path), touching a mapped page past the new EOF raises SIGBUS and kills
/// the process, where the file backend would return a typed read error
/// for that one request. Long-lived servers that must survive in-place
/// repacks should either open with [`Backend::File`] or (better) pack to
/// a fresh path and swap atomically.
///
/// On non-unix hosts (no `mmap`) the file is read into a **resident
/// buffer** at open — reads stay zero-copy but memory cost is O(store
/// size); prefer [`Backend::File`] there for large stores.
pub struct MmapSource {
    #[cfg(all(unix, target_pointer_width = "64"))]
    ptr: *const u8,
    #[cfg(all(unix, target_pointer_width = "64"))]
    map_len: usize,
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    resident: Vec<u8>,
    len: u64,
    bytes: AtomicU64,
}

// SAFETY: the mapping is read-only (PROT_READ) and shared only through
// `&self` methods that hand out immutable slices; the raw pointer is never
// written through and lives until Drop.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for MmapSource {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for MmapSource {}

impl MmapSource {
    /// Map `path` read-only. Empty files map to an empty source.
    #[cfg(all(unix, target_pointer_width = "64"))]
    pub fn open(path: &Path) -> Result<Self> {
        use std::os::unix::io::AsRawFd;
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            return Ok(Self {
                ptr: std::ptr::null(),
                map_len: 0,
                len: 0,
                bytes: AtomicU64::new(0),
            });
        }
        let map_len = len as usize;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                map_len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::map_failed() || ptr.is_null() {
            return Err(Error::Io(format!(
                "mmap of {} ({len} bytes) failed",
                path.display()
            )));
        }
        // The mapping holds its own reference to the file; the fd can close.
        Ok(Self { ptr: ptr as *const u8, map_len, len, bytes: AtomicU64::new(0) })
    }

    /// Fallback without a 64-bit unix `mmap` (non-unix, or 32-bit where
    /// casting the mapping length to `usize` could truncate and the FFI
    /// `off_t` ABI differs): load the file into memory once.
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    pub fn open(path: &Path) -> Result<Self> {
        let resident = std::fs::read(path)?;
        let len = resident.len() as u64;
        Ok(Self { resident, len, bytes: AtomicU64::new(0) })
    }

    /// The whole file as a slice.
    fn data(&self) -> &[u8] {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            if self.map_len == 0 {
                return &[];
            }
            // SAFETY: ptr/map_len describe a live PROT_READ mapping owned
            // by self; it is unmapped only in Drop.
            unsafe { std::slice::from_raw_parts(self.ptr, self.map_len) }
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            &self.resident
        }
    }
}

#[cfg(all(unix, target_pointer_width = "64"))]
impl Drop for MmapSource {
    fn drop(&mut self) {
        if self.map_len != 0 {
            // SAFETY: exactly the region mmap returned at open.
            unsafe {
                sys::munmap(self.ptr as *mut std::os::raw::c_void, self.map_len);
            }
        }
    }
}

impl ChunkSource for MmapSource {
    fn len(&self) -> u64 {
        self.len
    }

    fn backend(&self) -> Backend {
        Backend::Mmap
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        check_extent(self.len, offset, buf.len())?;
        let start = offset as usize;
        buf.copy_from_slice(&self.data()[start..start + buf.len()]);
        self.bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn slice_at(&self, offset: u64, len: usize) -> Option<&[u8]> {
        if check_extent(self.len, offset, len).is_err() {
            return None;
        }
        let start = offset as usize;
        self.bytes.fetch_add(len as u64, Ordering::Relaxed);
        Some(&self.data()[start..start + len])
    }

    fn bytes_read(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    fn reset_bytes_read(&self) {
        self.bytes.store(0, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Deterministic fault injection (DESIGN.md §14).
// ---------------------------------------------------------------------------

/// Seeded fault-injection parameters. Rates are per *operation* (each
/// `read_at`, independently); the injector is fully deterministic given the
/// seed, so a failing sweep replays exactly.
#[derive(Debug, Clone, Copy)]
pub struct FaultConfig {
    /// Seed for the injection RNG (xorshift64*).
    pub seed: u64,
    /// Probability an individual `read_at` fails with a transient error.
    pub read_error_rate: f64,
    /// Probability an individual `read_at` fails as a *short read* (some
    /// bytes arrived, then the source gave up) — also transient.
    pub short_read_rate: f64,
    /// Probability an individual `read_at` sleeps [`Self::latency_spike_us`]
    /// before succeeding (tail-latency injection; never an error).
    pub latency_spike_rate: f64,
    /// Injected latency-spike duration, microseconds.
    pub latency_spike_us: u64,
    /// Total error budget: once this many errors have been injected the
    /// wrapper passes everything through (`u64::MAX` = unbounded). Lets
    /// tests pin "fails exactly N times, then succeeds".
    pub max_injected_errors: u64,
    /// Kill-point: the index (0-based) of the write/fsync boundary at
    /// which [`FaultPlan::write_boundary`] simulates a crash. `None`
    /// disables the kill lattice.
    pub kill_at: Option<u64>,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            seed: 0xFA_17,
            read_error_rate: 0.0,
            short_read_rate: 0.0,
            latency_spike_rate: 0.0,
            latency_spike_us: 200,
            max_injected_errors: u64::MAX,
            kill_at: None,
        }
    }
}

struct FaultState {
    config: FaultConfig,
    rng: AtomicU64,
    injected: AtomicU64,
    reads: AtomicU64,
    boundaries: AtomicU64,
    killed: std::sync::atomic::AtomicBool,
}

/// A shared, deterministic fault plan driving both the read path (wrap a
/// [`ChunkSource`] with [`FaultPlan::wrap`]) and the write path (the live
/// appender / compactor calls [`FaultPlan::write_boundary`] before every
/// write/fsync/rename so a kill-point lattice can sweep *every* crash
/// interleaving). Clones share one state, so a single plan can meter a
/// whole sharded store.
#[derive(Clone)]
pub struct FaultPlan {
    inner: std::sync::Arc<FaultState>,
}

impl FaultPlan {
    pub fn new(config: FaultConfig) -> Self {
        Self {
            inner: std::sync::Arc::new(FaultState {
                config,
                // xorshift64* cannot leave state 0.
                rng: AtomicU64::new(config.seed | 1),
                injected: AtomicU64::new(0),
                reads: AtomicU64::new(0),
                boundaries: AtomicU64::new(0),
                killed: std::sync::atomic::AtomicBool::new(false),
            }),
        }
    }

    /// Wrap a source so its reads flow through this plan.
    pub fn wrap(&self, inner: Box<dyn ChunkSource>) -> Box<dyn ChunkSource> {
        Box::new(FaultyChunkSource { inner, plan: self.clone() })
    }

    /// Deterministic uniform draw in [0, 1).
    fn draw(&self) -> f64 {
        let mut x = self.inner.rng.load(Ordering::Relaxed);
        loop {
            let mut y = x;
            y ^= y << 13;
            y ^= y >> 7;
            y ^= y << 17;
            match self.inner.rng.compare_exchange_weak(
                x,
                y,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => {
                    return (y.wrapping_mul(0x2545_F491_4F6C_DD1D) >> 11) as f64
                        / (1u64 << 53) as f64
                }
                Err(now) => x = now,
            }
        }
    }

    /// Deterministic Bernoulli draw with probability `rate` (no budget).
    fn should_fire(&self, rate: f64) -> bool {
        rate > 0.0 && self.draw() < rate
    }

    /// Should an error fire for an event with probability `rate`? Counts
    /// against the error budget when it does.
    fn should_inject(&self, rate: f64) -> bool {
        if !self.should_fire(rate) {
            return false;
        }
        let budget = self.inner.config.max_injected_errors;
        // Reserve a slot in the budget; back off if it is exhausted.
        let prev = self.inner.injected.fetch_add(1, Ordering::Relaxed);
        if prev >= budget {
            self.inner.injected.fetch_sub(1, Ordering::Relaxed);
            return false;
        }
        true
    }

    /// Errors injected so far.
    pub fn injected_errors(&self) -> u64 {
        self.inner.injected.load(Ordering::Relaxed)
    }

    /// `read_at` calls observed so far.
    pub fn reads(&self) -> u64 {
        self.inner.reads.load(Ordering::Relaxed)
    }

    /// Announce a write/fsync/rename boundary named `op` (e.g.
    /// `"commit.footer"`). Returns an error — simulating the process dying
    /// *before* the operation — iff the boundary counter has reached the
    /// configured kill-point; every later boundary also fails, so a killed
    /// writer cannot keep mutating the store.
    pub fn write_boundary(&self, op: &str) -> Result<()> {
        if self.inner.killed.load(Ordering::Relaxed) {
            return Err(Error::Io(format!("injected crash (already killed) at {op}")));
        }
        let idx = self.inner.boundaries.fetch_add(1, Ordering::Relaxed);
        if Some(idx) == self.inner.config.kill_at {
            self.inner.killed.store(true, Ordering::Relaxed);
            return Err(Error::Io(format!("injected crash at boundary {idx} ({op})")));
        }
        Ok(())
    }

    /// True once the kill-point fired (the lattice sweep's termination
    /// test: a run whose kill-point was never reached is the final one).
    pub fn kill_fired(&self) -> bool {
        self.inner.killed.load(Ordering::Relaxed)
    }

    /// Write/fsync boundaries announced so far.
    pub fn boundaries_seen(&self) -> u64 {
        self.inner.boundaries.load(Ordering::Relaxed)
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("config", &self.inner.config)
            .field("injected", &self.injected_errors())
            .field("reads", &self.reads())
            .field("boundaries", &self.boundaries_seen())
            .finish()
    }
}

/// A [`ChunkSource`] wrapper injecting the plan's read faults. Serves no
/// zero-copy slices — every read goes through the fallible `read_at`, so
/// mmap-backed stores see injected faults too.
struct FaultyChunkSource {
    inner: Box<dyn ChunkSource>,
    plan: FaultPlan,
}

impl ChunkSource for FaultyChunkSource {
    fn len(&self) -> u64 {
        self.inner.len()
    }

    fn backend(&self) -> Backend {
        self.inner.backend()
    }

    fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        let cfg = &self.plan.inner.config;
        self.plan.inner.reads.fetch_add(1, Ordering::Relaxed);
        if cfg.latency_spike_us > 0 && self.plan.should_fire(cfg.latency_spike_rate) {
            std::thread::sleep(std::time::Duration::from_micros(cfg.latency_spike_us));
        }
        if self.plan.should_inject(cfg.read_error_rate) {
            return Err(Error::Transient(format!(
                "injected read error at offset {offset}"
            )));
        }
        if self.plan.should_inject(cfg.short_read_rate) {
            let got = buf.len() / 2;
            return Err(Error::Transient(format!(
                "injected short read: {got} of {} bytes at offset {offset}",
                buf.len()
            )));
        }
        self.inner.read_at(offset, buf)
    }

    fn slice_at(&self, _offset: u64, _len: usize) -> Option<&[u8]> {
        None
    }

    fn bytes_read(&self) -> u64 {
        self.inner.bytes_read()
    }

    fn reset_bytes_read(&self) {
        self.inner.reset_bytes_read();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_file(tag: &str, data: &[u8]) -> std::path::PathBuf {
        let path = std::env::temp_dir()
            .join(format!("apack_io_{}_{tag}.bin", std::process::id()));
        std::fs::write(&path, data).unwrap();
        path
    }

    fn payload() -> Vec<u8> {
        (0..4096u32).map(|i| (i * 31 % 251) as u8).collect()
    }

    #[test]
    fn both_backends_read_identical_bytes() {
        let data = payload();
        let path = temp_file("ident", &data);
        for backend in [Backend::Mmap, Backend::File] {
            let src = backend.open(&path).unwrap();
            assert_eq!(src.len(), data.len() as u64);
            assert_eq!(src.backend(), backend);
            let mut buf = vec![0u8; 100];
            src.read_at(17, &mut buf).unwrap();
            assert_eq!(&buf[..], &data[17..117], "{backend:?}");
            // Whole file.
            let mut all = vec![0u8; data.len()];
            src.read_at(0, &mut all).unwrap();
            assert_eq!(all, data, "{backend:?}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn mmap_serves_zero_copy_slices_and_file_does_not() {
        let data = payload();
        let path = temp_file("slices", &data);
        let mm = MmapSource::open(&path).unwrap();
        let s = mm.slice_at(100, 50).unwrap();
        assert_eq!(s, &data[100..150]);
        assert!(mm.slice_at(data.len() as u64 - 10, 11).is_none(), "past EOF");
        let f = FileSource::open(&path).unwrap();
        assert!(f.slice_at(0, 10).is_none());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn byte_accounting_per_backend() {
        let data = payload();
        let path = temp_file("bytes", &data);
        let mm = MmapSource::open(&path).unwrap();
        let mut buf = vec![0u8; 64];
        mm.read_at(0, &mut buf).unwrap();
        mm.slice_at(64, 36).unwrap();
        assert_eq!(mm.bytes_read(), 100);
        mm.reset_bytes_read();
        assert_eq!(mm.bytes_read(), 0);

        let f = FileSource::open(&path).unwrap();
        f.read_at(5, &mut buf).unwrap();
        assert_eq!(f.bytes_read(), 64);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reads_past_eof_error_not_truncate() {
        let data = payload();
        let path = temp_file("eof", &data);
        for backend in [Backend::Mmap, Backend::File] {
            let src = backend.open(&path).unwrap();
            let mut buf = vec![0u8; 10];
            assert!(src.read_at(data.len() as u64, &mut buf).is_err(), "{backend:?}");
            assert!(src.read_at(data.len() as u64 - 5, &mut buf).is_err(), "{backend:?}");
            assert!(src.read_at(u64::MAX - 2, &mut buf).is_err(), "{backend:?}");
            // A read that exactly reaches EOF is fine.
            let mut tail = vec![0u8; 10];
            src.read_at(data.len() as u64 - 10, &mut tail).unwrap();
            assert_eq!(&tail[..], &data[data.len() - 10..]);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_is_empty_source() {
        let path = temp_file("empty", &[]);
        for backend in [Backend::Mmap, Backend::File] {
            let src = backend.open(&path).unwrap();
            assert_eq!(src.len(), 0);
            assert!(src.is_empty());
            let mut buf = [0u8; 1];
            assert!(src.read_at(0, &mut buf).is_err());
            src.read_at(0, &mut [0u8; 0]).unwrap(); // zero-length read is a no-op
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn concurrent_positioned_reads_see_consistent_bytes() {
        let data = payload();
        let path = temp_file("conc", &data);
        for backend in [Backend::Mmap, Backend::File] {
            let src = backend.open(&path).unwrap();
            let src = &src;
            let data = &data;
            std::thread::scope(|scope| {
                for t in 0..8usize {
                    scope.spawn(move || {
                        for i in 0..200usize {
                            let off = (t * 97 + i * 13) % (data.len() - 32);
                            let mut buf = [0u8; 32];
                            src.read_at(off as u64, &mut buf).unwrap();
                            assert_eq!(&buf[..], &data[off..off + 32]);
                        }
                    });
                }
            });
            assert_eq!(src.bytes_read(), 8 * 200 * 32);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fault_plan_injects_deterministically_and_respects_budget() {
        let data = payload();
        let path = temp_file("faulty", &data);
        for backend in [Backend::Mmap, Backend::File] {
            // rate 1.0 with a budget of 3: exactly three transient
            // failures, then clean pass-through.
            let plan = FaultPlan::new(FaultConfig {
                seed: 42,
                read_error_rate: 1.0,
                max_injected_errors: 3,
                ..FaultConfig::default()
            });
            let src = plan.wrap(backend.open(&path).unwrap());
            let mut buf = [0u8; 16];
            for i in 0..3 {
                let err = src.read_at(0, &mut buf).unwrap_err();
                assert!(err.is_transient(), "{backend:?} attempt {i}: {err}");
            }
            src.read_at(0, &mut buf).unwrap();
            assert_eq!(&buf[..], &data[..16], "{backend:?}");
            assert_eq!(plan.injected_errors(), 3);
            assert_eq!(plan.reads(), 4);
            // The wrapper must force even mmap through fallible reads.
            assert!(src.slice_at(0, 16).is_none(), "{backend:?}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fault_plan_short_reads_are_transient() {
        let data = payload();
        let path = temp_file("short", &data);
        let plan = FaultPlan::new(FaultConfig {
            short_read_rate: 1.0,
            max_injected_errors: 1,
            ..FaultConfig::default()
        });
        let src = plan.wrap(Backend::File.open(&path).unwrap());
        let mut buf = [0u8; 32];
        match src.read_at(0, &mut buf) {
            Err(Error::Transient(msg)) => {
                assert!(msg.contains("short read"), "{msg}")
            }
            other => panic!("expected transient short read, got {other:?}"),
        }
        src.read_at(0, &mut buf).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn kill_point_lattice_fires_once_and_stays_dead() {
        let plan = FaultPlan::new(FaultConfig { kill_at: Some(2), ..FaultConfig::default() });
        assert!(plan.write_boundary("a").is_ok());
        assert!(plan.write_boundary("b").is_ok());
        assert!(!plan.kill_fired());
        assert!(plan.write_boundary("c").is_err(), "boundary 2 is the kill-point");
        assert!(plan.kill_fired());
        // Every boundary after the kill also fails: a dead process
        // cannot keep writing.
        assert!(plan.write_boundary("d").is_err());
        assert_eq!(plan.boundaries_seen(), 3);

        // No kill-point: everything passes, the counter still counts.
        let free = FaultPlan::new(FaultConfig::default());
        for op in ["w", "x", "y"] {
            free.write_boundary(op).unwrap();
        }
        assert_eq!(free.boundaries_seen(), 3);
        assert!(!free.kill_fired());
    }

    #[test]
    fn backend_parse_roundtrip() {
        assert_eq!(Backend::parse("mmap").unwrap(), Backend::Mmap);
        assert_eq!(Backend::parse("FILE").unwrap(), Backend::File);
        assert!(Backend::parse("io_uring").is_err());
        assert_eq!(Backend::default().name(), "mmap");
        assert_eq!(Backend::File.name(), "file");
    }
}
