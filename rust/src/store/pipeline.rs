//! Pipelined zoo ingest: overlap tensor N+1's synthesis / profiling /
//! tablegen / chunk encode with tensor N's ordered sequential append
//! (DESIGN.md §9).
//!
//! The serial packer alternates a serial profile phase (trace synthesis +
//! histogram + Listing-1 search) with a parallel encode phase per tensor,
//! so cores idle during every profile. Here a pool of workers each claims
//! one **model** at a time (synthesis is per model), runs the full compute
//! stage for all of its tensors ([`encode_zoo_model`]) and ships the
//! resulting [`EncodedTensor`]s over a **bounded** channel to the single
//! append thread, which writes them in model order through a small reorder
//! buffer. The paper deploys pipelined parallel engines on both the
//! compress and decompress sides (§V-B); this is the software mirror of
//! the compress side, as PR 4's block decode was of the decompress side.
//!
//! Ordering and backpressure rules:
//!
//! - **Appends are in submission order** (model order, layer order within
//!   a model) — the pipelined packer produces a byte-identical store file
//!   to the serial packer, which is what lets `--pipeline off` stay
//!   selectable as a same-bytes baseline.
//! - **In-flight memory is bounded** by the channel capacity
//!   ([`PackOptions::in_flight`] models) plus one claimed model per
//!   worker; a worker with a finished model blocks on `send` until the
//!   appender drains.
//! - **Workers encode chunks in-line** (`encode_threads = 1`):
//!   model-level parallelism already saturates cores, and nesting a
//!   per-chunk `par_map` under every worker would oversubscribe.
//! - **Errors abort promptly and deterministically**: the first error in
//!   *append order* is returned; workers stop claiming new models, and
//!   the appender drains the channel so no worker deadlocks on a full
//!   channel mid-shutdown.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::apack::Histogram;
use crate::coordinator::PartitionPolicy;
use crate::error::Result;
use crate::eval::{EVAL_SEED, PROFILE_SAMPLES};
use crate::models::trace::{LayerTrace, ModelTrace};
use crate::models::zoo::ModelConfig;

use super::format::BodyConfig;
use super::writer::{encode_tensor_with, EncodedTensor};

/// Knobs for the zoo packers ([`super::writer::pack_model_zoo_with`] /
/// [`super::shard::pack_model_zoo_sharded_with`]).
#[derive(Debug, Clone, Copy)]
pub struct PackOptions {
    /// Overlap compute with append (default). `false` selects the serial
    /// profile→encode→append loop — same bytes, kept as the measured
    /// baseline in `benches/store_pack.rs`.
    pub pipelined: bool,
    /// Compute workers; `0` = the machine's available parallelism.
    pub workers: usize,
    /// Bounded-channel capacity in *models*; `0` = `2 × workers`. Caps
    /// in-flight memory when the appender is the bottleneck.
    pub in_flight: usize,
    /// Chunk-body configuration (version + requested v2 lane count); also
    /// picks the file format via [`BodyConfig::store_format`].
    pub body: BodyConfig,
}

impl Default for PackOptions {
    fn default() -> Self {
        Self { pipelined: true, workers: 0, in_flight: 0, body: BodyConfig::default() }
    }
}

/// Anything that can accept an ordered stream of encoded tensors — the
/// single-file [`super::writer::StoreWriter`] and the sharded
/// [`super::shard::ShardedStoreWriter`] (which routes by name hash).
pub(crate) trait TensorSink {
    fn append(&mut self, t: EncodedTensor) -> Result<()>;
}

impl TensorSink for super::writer::StoreWriter {
    fn append(&mut self, t: EncodedTensor) -> Result<()> {
        self.append_encoded(t)
    }
}

impl TensorSink for super::shard::ShardedStoreWriter {
    fn append(&mut self, t: EncodedTensor) -> Result<()> {
        self.append_encoded(t)
    }
}

/// Pooled activation-profile histogram of a layer: one histogram pass over
/// the **per-input** sample runs (the trace records their size,
/// [`LayerTrace::act_samples_per_input`]) with a single deferred prefix
/// rebuild ([`Histogram::from_value_chunks`] — the `merge_many` pooling
/// primitive), instead of a rebuild per pooled input (paper §VII: up to 9
/// profiling inputs per layer).
fn pooled_profile_histogram(l: &LayerTrace) -> Histogram {
    Histogram::from_value_chunks(
        l.bits,
        l.act_profile_samples.chunks(l.act_samples_per_input.max(1)),
    )
}

/// The full compute stage for one zoo model: synthesize its trace, then
/// per layer encode the weights tensor (`"{model}/layer{i:03}/weights"`,
/// table profiled from the values themselves) and — for studied
/// activations — `".../activations"` with a table profiled on the pooled
/// samples and applied to the fresh tensor (paper §VII methodology).
/// `sample_cap` bounds values per tensor, exactly like the evaluation
/// studies. Synthesis time is attributed to the model's first tensor.
pub(crate) fn encode_zoo_model(
    cfg: &ModelConfig,
    sample_cap: usize,
    policy: &PartitionPolicy,
    body: BodyConfig,
    encode_threads: usize,
) -> Result<Vec<EncodedTensor>> {
    let t0 = Instant::now();
    let trace = {
        let _synth = crate::obs::span(crate::obs::Stage::Synth);
        ModelTrace::synthesize(cfg, sample_cap, PROFILE_SAMPLES, EVAL_SEED)
    };
    let synth_nanos = t0.elapsed().as_nanos() as u64;
    let mut out = Vec::with_capacity(trace.layers.len() * 2);
    for l in &trace.layers {
        let mut t = encode_tensor_with(
            policy,
            body,
            &format!("{}/layer{:03}/weights", cfg.name, l.layer_idx),
            l.bits,
            &l.weights,
            TensorKind::Weights,
            None,
            encode_threads,
        )?;
        if out.is_empty() {
            t.synth_nanos = synth_nanos;
        }
        out.push(t);
        if !l.activations.is_empty() {
            let tg0 = Instant::now();
            let hist = pooled_profile_histogram(l);
            let table = generate_table(
                &hist,
                TensorKind::Activations,
                &TableGenConfig::for_bits(l.bits),
            )?;
            let tablegen_nanos = tg0.elapsed().as_nanos() as u64;
            let mut t = encode_tensor_with(
                policy,
                body,
                &format!("{}/layer{:03}/activations", cfg.name, l.layer_idx),
                l.bits,
                &l.activations,
                TensorKind::Activations,
                Some(table),
                encode_threads,
            )?;
            t.tablegen_nanos += tablegen_nanos;
            out.push(t);
        }
    }
    Ok(out)
}

/// Drive a zoo pack into `sink` — pipelined per `opts`, or the serial
/// profile→encode→append loop. Append order (and therefore the store
/// file's bytes) is identical either way.
pub(crate) fn pack_zoo_into<S: TensorSink>(
    sink: &mut S,
    models: &[ModelConfig],
    sample_cap: usize,
    policy: &PartitionPolicy,
    opts: &PackOptions,
) -> Result<()> {
    if !opts.pipelined || models.len() < 2 {
        for cfg in models {
            for t in encode_zoo_model(cfg, sample_cap, policy, opts.body, 0)? {
                sink.append(t)?;
            }
        }
        return Ok(());
    }

    let default_threads =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let workers =
        if opts.workers == 0 { default_threads } else { opts.workers }.clamp(1, models.len());
    let cap = if opts.in_flight == 0 { workers * 2 } else { opts.in_flight }.max(1);

    let next_job = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let (tx, rx) = mpsc::sync_channel::<(usize, Result<Vec<EncodedTensor>>)>(cap);
    let mut first_err = None;

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let (next_job, abort) = (&next_job, &abort);
            scope.spawn(move || loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = next_job.fetch_add(1, Ordering::Relaxed);
                if i >= models.len() {
                    break;
                }
                let result = encode_zoo_model(&models[i], sample_cap, policy, opts.body, 1);
                if result.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                // A send error means the appender is gone; just stop.
                if tx.send((i, result)).is_err() {
                    break;
                }
            });
        }
        drop(tx); // the workers hold the only senders now

        // Ordered append: claimed jobs are a dense prefix 0..k and every
        // claimed job is sent exactly once, so draining the channel while
        // releasing the reorder buffer in sequence order visits every
        // model. After an error we keep draining (workers blocked on the
        // bounded channel must unblock to exit) but append nothing more.
        let mut pending: BTreeMap<usize, Result<Vec<EncodedTensor>>> = BTreeMap::new();
        let mut next_seq = 0usize;
        for (seq, result) in rx {
            pending.insert(seq, result);
            while let Some(result) = pending.remove(&next_seq) {
                next_seq += 1;
                if first_err.is_some() {
                    continue;
                }
                match result {
                    Ok(tensors) => {
                        for t in tensors {
                            if let Err(e) = sink.append(t) {
                                first_err = Some(e);
                                abort.store(true, Ordering::Relaxed);
                                break;
                            }
                        }
                    }
                    Err(e) => {
                        first_err = Some(e);
                        abort.store(true, Ordering::Relaxed);
                    }
                }
            }
        }
    });

    match first_err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;
    use crate::store::writer::{pack_model_zoo_with, StoreWriter};
    use crate::store::StoreReader;
    use std::path::PathBuf;

    fn temp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("apack_pipe_{}_{tag}.apackstore", std::process::id()))
    }

    fn small_models() -> Vec<ModelConfig> {
        ["ncf", "bilstm", "alexnet_eyeriss"]
            .iter()
            .map(|n| model_by_name(n).expect("zoo model"))
            .collect()
    }

    #[test]
    fn pipelined_pack_is_byte_identical_to_serial() {
        let models = small_models();
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 512 };
        let serial_path = temp_path("serial");
        let piped_path = temp_path("piped");

        let serial = pack_model_zoo_with(
            &serial_path,
            &models,
            2048,
            policy,
            &PackOptions { pipelined: false, ..PackOptions::default() },
        )
        .unwrap();
        let piped = pack_model_zoo_with(
            &piped_path,
            &models,
            2048,
            policy,
            &PackOptions { pipelined: true, workers: 3, in_flight: 2, ..PackOptions::default() },
        )
        .unwrap();
        assert_eq!(serial.tensors, piped.tensors);
        assert_eq!(serial.file_bytes, piped.file_bytes);
        assert_eq!(serial.pack.values, piped.pack.values);

        let a = std::fs::read(&serial_path).unwrap();
        let b = std::fs::read(&piped_path).unwrap();
        assert_eq!(a, b, "pipelined pack must write the exact serial bytes");

        // And the packed store round-trips (verify = CRC + full decode).
        let r = StoreReader::open(&piped_path).unwrap();
        r.verify().unwrap();
        std::fs::remove_file(&serial_path).ok();
        std::fs::remove_file(&piped_path).ok();
    }

    #[test]
    fn pipelined_pack_surfaces_append_errors() {
        // A sink that rejects everything: the pipeline must return the
        // error (not hang with workers blocked on the bounded channel).
        struct Failing;
        impl TensorSink for Failing {
            fn append(&mut self, _t: EncodedTensor) -> Result<()> {
                Err(crate::error::Error::Store("sink full".into()))
            }
        }
        let models = small_models();
        let err = pack_zoo_into(
            &mut Failing,
            &models,
            512,
            &PartitionPolicy::default(),
            &PackOptions { pipelined: true, workers: 2, in_flight: 1, ..PackOptions::default() },
        )
        .unwrap_err();
        assert!(matches!(err, crate::error::Error::Store(_)));
    }

    #[test]
    fn pooled_profile_histogram_matches_flat() {
        let cfg = model_by_name("resnet18").unwrap();
        let trace = ModelTrace::synthesize(&cfg, 2048, PROFILE_SAMPLES, EVAL_SEED);
        let l = trace
            .layers
            .iter()
            .find(|l| !l.act_profile_samples.is_empty())
            .expect("resnet18 has studied activations");
        let pooled = pooled_profile_histogram(l);
        let flat = Histogram::from_values(l.bits, &l.act_profile_samples);
        assert_eq!(pooled, flat);
    }

    #[test]
    fn single_model_pack_falls_back_to_serial() {
        let models = vec![model_by_name("ncf").unwrap()];
        let path = temp_path("single");
        let mut w = StoreWriter::create(&path, PartitionPolicy::default()).unwrap();
        pack_zoo_into(&mut w, &models, 1024, &PartitionPolicy::default(), &PackOptions::default())
            .unwrap();
        let summary = w.finish().unwrap();
        assert!(summary.tensors > 0);
        StoreReader::open(&path).unwrap().verify().unwrap();
        std::fs::remove_file(&path).ok();
    }
}
