//! Sharded APackStore: one logical store hash-partitioned across N shard
//! files, for models too large (or too hot) for a single file.
//!
//! # Directory layout
//!
//! ```text
//! <store-dir>/
//!   MANIFEST           — see below
//!   shard-000.apackstore  — a complete single-file APackStore (format.rs)
//!   shard-001.apackstore
//!   ...
//! ```
//!
//! Every shard file is a self-contained APackStore (magic, chunk blobs,
//! footer index, trailer), so each shard verifies, serves and repairs
//! independently — per-shard parallel verify is just `par_map` over shard
//! readers. Tensors are routed to shards by an FNV-1a hash of their name
//! ([`shard_for_name`]); the shard count is clamped to the store's content
//! by [`crate::coordinator::PartitionPolicy::file_shards_for`], the same
//! scale-to-content heuristic that sizes substreams within a tensor.
//!
//! # Manifest format
//!
//! ```text
//! offset 0   magic, 8 bytes: "APSHMAN2" (v1: "APSHMAN1")
//! offset 8   shard_count u32
//! then       shard_count × (tensors u32 | file_bytes u64
//!                           | generation u32 | trailer_offset u64)
//!            (v1 records are 12 bytes: tensors u32 | file_bytes u64)
//! EOF - 4    crc32 of all preceding bytes
//! ```
//!
//! Little-endian throughout. Shard file names are derived
//! ([`shard_file_name`]), not stored. Failure modes are **typed**: a bad
//! manifest is [`Error::ManifestCorrupt`], a directory whose shard-file
//! count disagrees with the manifest is [`Error::ShardCountMismatch`], and
//! an expected shard file that is absent is [`Error::ShardMissing`] — a
//! torn or mixed-up store directory can never masquerade as a healthy one.
//!
//! # Durability (DESIGN.md §14)
//!
//! For a sharded store the MANIFEST **is** the generation pointer: each
//! v2 record names its shard's committed generation and trailer offset,
//! and the manifest itself is written atomically (tmp + fsync + rename).
//! On open, a shard whose on-disk size disagrees with its record is
//! re-resolved — first at the recorded trailer offset (a torn append
//! tail: the previous sealed generation wins), then at exact EOF (a
//! compaction-replaced shard) — before the mismatch is reported as
//! corruption. v1 manifests (write-once stores packed by earlier
//! versions) read as generation 0 with the trailer abutting EOF.

use std::path::{Path, PathBuf};

use crate::apack::tablegen::TensorKind;
use crate::apack::SymbolTable;
use crate::coordinator::PartitionPolicy;
use crate::error::{Error, Result};
use crate::models::zoo::ModelConfig;
use crate::util::par_map;

use super::format::{crc32, BodyConfig, TensorMeta, TRAILER_BYTES};
use super::io::{Backend, FaultPlan};
use super::pipeline::{pack_zoo_into, PackOptions};
use super::reader::{ReadStats, StoreReader, VerifyReport, DEFAULT_CACHE_VALUES};
use super::writer::{
    zoo_value_estimate, EncodedTensor, PackStats, StoreSummary, StoreWriter,
};

/// Manifest file name inside a sharded-store directory.
pub const MANIFEST_FILE: &str = "MANIFEST";

/// v1 manifest magic ("APSHMAN" + format version digit): 12-byte records
/// without generation/trailer fields. Still read; no longer written.
pub const MANIFEST_MAGIC: [u8; 8] = *b"APSHMAN1";

/// v2 manifest magic: 24-byte records carrying each shard's committed
/// generation and trailer offset (the sharded store's commit pointer).
pub const MANIFEST_MAGIC_V2: [u8; 8] = *b"APSHMAN2";

/// Derived file name of shard `i`.
pub fn shard_file_name(i: usize) -> String {
    format!("shard-{i:03}.apackstore")
}

/// True for names produced by [`shard_file_name`] (directory scans).
fn is_shard_file_name(name: &str) -> bool {
    name.starts_with("shard-") && name.ends_with(".apackstore")
}

/// Shard index a tensor name routes to: FNV-1a over the name, mod `shards`.
/// Deterministic across runs and platforms, so writer and reader agree
/// without storing a routing table.
pub fn shard_for_name(name: &str, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % shards.max(1) as u64) as usize
}

/// One shard's manifest record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardEntry {
    /// Tensors routed into this shard.
    pub tensors: u32,
    /// Shard file size in bytes at seal time.
    pub file_bytes: u64,
    /// Committed footer generation of the shard (0 for write-once shards
    /// and v1 manifests).
    pub generation: u32,
    /// Absolute offset of the shard's committed trailer record (for v1
    /// manifests: derived as `file_bytes - TRAILER_BYTES`).
    pub trailer_offset: u64,
}

/// The parsed MANIFEST of a sharded store.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ShardManifest {
    pub entries: Vec<ShardEntry>,
}

impl ShardManifest {
    /// Serialize (v2 magic + 24-byte records + CRC).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + 4 + self.entries.len() * 24 + 4);
        out.extend_from_slice(&MANIFEST_MAGIC_V2);
        out.extend_from_slice(&(self.entries.len() as u32).to_le_bytes());
        for e in &self.entries {
            out.extend_from_slice(&e.tensors.to_le_bytes());
            out.extend_from_slice(&e.file_bytes.to_le_bytes());
            out.extend_from_slice(&e.generation.to_le_bytes());
            out.extend_from_slice(&e.trailer_offset.to_le_bytes());
        }
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        out
    }

    /// Parse and validate a manifest, either version. v1 records read as
    /// generation 0 with the trailer abutting EOF.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let bad = |m: String| Error::ManifestCorrupt(m);
        if data.len() < 8 + 4 + 4 {
            return Err(bad(format!("{} bytes is too short for a manifest", data.len())));
        }
        let record_bytes = if data[0..8] == MANIFEST_MAGIC {
            12
        } else if data[0..8] == MANIFEST_MAGIC_V2 {
            24
        } else {
            return Err(bad("bad manifest magic".into()));
        };
        let count = u32::from_le_bytes(data[8..12].try_into().unwrap()) as usize;
        if count == 0 {
            return Err(bad("manifest declares zero shards".into()));
        }
        if count > 1 << 16 {
            return Err(bad(format!("manifest declares {count} shards (absurd)")));
        }
        let expect = 8 + 4 + count * record_bytes + 4;
        if data.len() != expect {
            return Err(bad(format!(
                "manifest is {} bytes, {count} shards need {expect}",
                data.len()
            )));
        }
        let body = &data[..data.len() - 4];
        let stored_crc =
            u32::from_le_bytes(data[data.len() - 4..].try_into().unwrap());
        if crc32(body) != stored_crc {
            return Err(bad("manifest CRC mismatch".into()));
        }
        let mut entries = Vec::with_capacity(count);
        for i in 0..count {
            let pos = 12 + i * record_bytes;
            let tensors = u32::from_le_bytes(data[pos..pos + 4].try_into().unwrap());
            let file_bytes =
                u64::from_le_bytes(data[pos + 4..pos + 12].try_into().unwrap());
            let (generation, trailer_offset) = if record_bytes == 24 {
                (
                    u32::from_le_bytes(data[pos + 12..pos + 16].try_into().unwrap()),
                    u64::from_le_bytes(data[pos + 16..pos + 24].try_into().unwrap()),
                )
            } else {
                let at = file_bytes.checked_sub(TRAILER_BYTES as u64).ok_or_else(|| {
                    bad(format!(
                        "shard {i}: {file_bytes} file bytes cannot hold a trailer"
                    ))
                })?;
                (0, at)
            };
            if trailer_offset.checked_add(TRAILER_BYTES as u64).is_none_or(|end| end > file_bytes)
            {
                return Err(bad(format!(
                    "shard {i}: trailer offset {trailer_offset} outside \
                     {file_bytes}-byte file"
                )));
            }
            entries.push(ShardEntry { tensors, file_bytes, generation, trailer_offset });
        }
        Ok(Self { entries })
    }
}

/// Write the MANIFEST atomically: tmp file + fsync + rename, then a
/// best-effort directory fsync so the rename itself is durable. Returns
/// the manifest's byte length. This is the sharded store's commit point
/// (DESIGN.md §14) — a crash before the rename leaves the previous
/// manifest (and thus the previous generations) in force.
pub(crate) fn write_manifest_atomic(dir: &Path, manifest: &ShardManifest) -> Result<u64> {
    let bytes = manifest.to_bytes();
    let tmp = dir.join(format!("{MANIFEST_FILE}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp)?;
        std::io::Write::write_all(&mut f, &bytes)?;
        f.sync_data()?;
    }
    std::fs::rename(&tmp, dir.join(MANIFEST_FILE))?;
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(bytes.len() as u64)
}

/// Summary returned by [`ShardedStoreWriter::finish`].
#[derive(Debug, Clone)]
pub struct ShardedStoreSummary {
    pub shards: usize,
    pub tensors: usize,
    pub chunks: usize,
    /// Total bytes on disk: all shard files plus the manifest.
    pub file_bytes: u64,
    /// Sum of raw (uncompressed) tensor bits.
    pub raw_bits: u64,
    /// Ingest breakdown aggregated across shard writers (stage times add,
    /// wall is the max — the shards share one wall clock).
    pub pack: PackStats,
    pub per_shard: Vec<StoreSummary>,
}

impl ShardedStoreSummary {
    /// Whole-store compression ratio vs. raw values.
    pub fn compression_ratio(&self) -> f64 {
        self.raw_bits as f64 / (self.file_bytes as f64 * 8.0)
    }
}

/// Writes a sharded store: N independent [`StoreWriter`]s, tensors routed
/// by [`shard_for_name`], sealed with the MANIFEST. Like the single-file
/// writer, dropping without [`Self::finish`] leaves no manifest, so a torn
/// write cannot open as a healthy sharded store.
pub struct ShardedStoreWriter {
    dir: PathBuf,
    writers: Vec<StoreWriter>,
}

impl ShardedStoreWriter {
    /// Create (or reset) a sharded store directory with `shards` files.
    /// Stale shard files and manifests from a previous pack are removed,
    /// so repacking with a different shard count cannot leave a directory
    /// that fails the count check.
    pub fn create(dir: &Path, shards: usize, policy: PartitionPolicy) -> Result<Self> {
        Self::create_with(dir, shards, policy, BodyConfig::default())
    }

    /// [`Self::create`] with an explicit chunk-body configuration, applied
    /// to every shard file uniformly (mixed-version shard directories are
    /// never produced).
    pub fn create_with(
        dir: &Path,
        shards: usize,
        policy: PartitionPolicy,
        body: BodyConfig,
    ) -> Result<Self> {
        if shards == 0 {
            return Err(Error::Config("sharded store needs at least one shard".into()));
        }
        if shards > 1 << 16 {
            return Err(Error::Config(format!("{shards} shard files is absurd")));
        }
        std::fs::create_dir_all(dir)?;
        for entry in std::fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if name == MANIFEST_FILE || is_shard_file_name(&name) {
                std::fs::remove_file(entry.path())?;
            }
        }
        let writers: Result<Vec<StoreWriter>> = (0..shards)
            .map(|i| StoreWriter::create_with(&dir.join(shard_file_name(i)), policy, body))
            .collect();
        Ok(Self { dir: dir.to_path_buf(), writers: writers? })
    }

    /// Number of shard files.
    pub fn shard_count(&self) -> usize {
        self.writers.len()
    }

    /// Tensors written so far, across all shards.
    pub fn tensor_count(&self) -> usize {
        self.writers.iter().map(|w| w.tensor_count()).sum()
    }

    /// Compress and append a tensor to its home shard, profiling the table
    /// from the values (duplicate names are rejected by the home shard —
    /// equal names always route identically).
    pub fn add_tensor(
        &mut self,
        name: &str,
        bits: u32,
        values: &[u32],
        kind: TensorKind,
    ) -> Result<()> {
        let s = shard_for_name(name, self.writers.len());
        self.writers[s].add_tensor(name, bits, values, kind)
    }

    /// Compress and append a tensor with a prebuilt table.
    pub fn add_tensor_with_table(
        &mut self,
        name: &str,
        values: &[u32],
        kind: TensorKind,
        table: SymbolTable,
    ) -> Result<()> {
        let s = shard_for_name(name, self.writers.len());
        self.writers[s].add_tensor_with_table(name, values, kind, table)
    }

    /// Append a pre-encoded tensor to its home shard (the pipelined
    /// packer's sink; equal names always route identically, so duplicate
    /// rejection still works shard-locally).
    pub fn append_encoded(&mut self, t: EncodedTensor) -> Result<()> {
        let s = shard_for_name(&t.name, self.writers.len());
        self.writers[s].append_encoded(t)
    }

    /// Seal every shard file, then write the MANIFEST atomically. The
    /// store is only openable as a sharded store after this returns.
    pub fn finish(self) -> Result<ShardedStoreSummary> {
        let mut per_shard = Vec::with_capacity(self.writers.len());
        for w in self.writers {
            per_shard.push(w.finish()?);
        }
        let manifest = ShardManifest {
            entries: per_shard
                .iter()
                .map(|s| ShardEntry {
                    tensors: s.tensors as u32,
                    file_bytes: s.file_bytes,
                    generation: 0,
                    trailer_offset: s.file_bytes - TRAILER_BYTES as u64,
                })
                .collect(),
        };
        let manifest_len = write_manifest_atomic(&self.dir, &manifest)?;
        let mut pack = PackStats::default();
        for s in &per_shard {
            pack.merge(&s.pack);
        }
        Ok(ShardedStoreSummary {
            shards: per_shard.len(),
            tensors: per_shard.iter().map(|s| s.tensors).sum(),
            chunks: per_shard.iter().map(|s| s.chunks).sum(),
            file_bytes: per_shard.iter().map(|s| s.file_bytes).sum::<u64>()
                + manifest_len,
            raw_bits: per_shard.iter().map(|s| s.raw_bits).sum(),
            pack,
            per_shard,
        })
    }
}

/// Read-only handle on a sharded store directory: the same
/// `get_tensor` / `get_chunk` / `get_range` / `stats` / `verify` surface
/// as [`StoreReader`], routed by tensor-name hash. Lookups are O(1): the
/// name hashes straight to its home shard, whose own footer index resolves
/// it.
pub struct ShardedStoreReader {
    readers: Vec<StoreReader>,
}

impl ShardedStoreReader {
    /// Open with the default (mmap) backend and cache budget.
    pub fn open(dir: &Path) -> Result<Self> {
        Self::open_with(dir, Backend::default(), DEFAULT_CACHE_VALUES)
    }

    /// Open and cross-validate manifest vs. directory vs. shard footers.
    /// The cache budget is split evenly across shards.
    pub fn open_with(dir: &Path, backend: Backend, cache_values: usize) -> Result<Self> {
        Self::open_opts(dir, backend, cache_values, None)
    }

    /// [`Self::open_with`] with an optional [`FaultPlan`] wrapping every
    /// shard's IO source (one shared plan meters the whole store).
    pub fn open_opts(
        dir: &Path,
        backend: Backend,
        cache_values: usize,
        plan: Option<&FaultPlan>,
    ) -> Result<Self> {
        let manifest_path = dir.join(MANIFEST_FILE);
        let manifest_bytes = std::fs::read(&manifest_path).map_err(|e| {
            Error::ManifestCorrupt(format!("cannot read {}: {e}", manifest_path.display()))
        })?;
        let manifest = ShardManifest::from_bytes(&manifest_bytes)?;
        let n = manifest.entries.len();

        // The directory must hold exactly the manifest's shard files: a
        // different count means a torn pack or a mixed-up directory.
        let mut found = 0usize;
        for entry in std::fs::read_dir(dir)? {
            let name = entry?.file_name();
            if is_shard_file_name(&name.to_string_lossy()) {
                found += 1;
            }
        }
        if found != n {
            return Err(Error::ShardCountMismatch { manifest: n, found });
        }
        for i in 0..n {
            if !dir.join(shard_file_name(i)).is_file() {
                return Err(Error::ShardMissing { shard: shard_file_name(i) });
            }
        }

        let per_shard_cache = cache_values / n;
        let mut readers = Vec::with_capacity(n);
        for (i, entry) in manifest.entries.iter().enumerate() {
            let path = dir.join(shard_file_name(i));
            let disk = std::fs::metadata(&path)?.len();
            let reader = if disk == entry.file_bytes {
                // Sizes agree: the manifest's commit point is
                // authoritative; any failure there is real corruption.
                StoreReader::open_at(&path, backend, per_shard_cache, entry.trailer_offset, plan)?
            } else {
                // Sizes disagree. Two recoverable shapes (DESIGN.md §14):
                // a torn append tail (the file grew past the committed
                // generation before a crash — the recorded trailer still
                // resolves) or a compaction-replaced shard (the file was
                // atomically swapped — its own trailer abuts EOF). Only
                // when neither resolves is the mismatch corruption.
                StoreReader::open_at(&path, backend, per_shard_cache, entry.trailer_offset, plan)
                    .or_else(|_| StoreReader::open_opts(&path, backend, per_shard_cache, plan))
                    .map_err(|_| {
                        Error::ManifestCorrupt(format!(
                            "shard {i} is {disk} bytes on disk, manifest says {}",
                            entry.file_bytes
                        ))
                    })?
            };
            if reader.tensor_count() != entry.tensors as usize {
                return Err(Error::ManifestCorrupt(format!(
                    "shard {i} holds {} tensors, manifest says {}",
                    reader.tensor_count(),
                    entry.tensors
                )));
            }
            for name in reader.tensor_names() {
                if shard_for_name(name, n) != i {
                    return Err(Error::Store(format!(
                        "tensor {name:?} found in shard {i} but routes to shard {} — \
                         shard files shuffled?",
                        shard_for_name(name, n)
                    )));
                }
            }
            readers.push(reader);
        }
        Ok(Self { readers })
    }

    /// The IO backend serving every shard.
    pub fn backend(&self) -> Backend {
        self.readers[0].backend()
    }

    /// Number of shard files.
    pub fn shard_count(&self) -> usize {
        self.readers.len()
    }

    /// Per-shard readers, in shard order (report/eval introspection).
    pub fn shard_readers(&self) -> &[StoreReader] {
        &self.readers
    }

    /// All tensor names: shard order, write order within each shard.
    pub fn tensor_names(&self) -> Vec<&str> {
        self.readers.iter().flat_map(|r| r.tensor_names()).collect()
    }

    /// Total tensors across shards.
    pub fn tensor_count(&self) -> usize {
        self.readers.iter().map(|r| r.tensor_count()).sum()
    }

    /// Every tensor's footer entry, shard order.
    pub fn tensor_metas(&self) -> Vec<&TensorMeta> {
        self.readers.iter().flat_map(|r| r.index().tensors.iter()).collect()
    }

    /// The shard reader owning `name`.
    fn home(&self, name: &str) -> &StoreReader {
        &self.readers[shard_for_name(name, self.readers.len())]
    }

    /// Metadata for one tensor.
    pub fn meta(&self, name: &str) -> Result<&TensorMeta> {
        self.home(name).meta(name)
    }

    /// Decode one chunk of a tensor (CRC-checked, cache-assisted).
    pub fn get_chunk(&self, name: &str, ci: usize) -> Result<std::sync::Arc<Vec<u32>>> {
        self.home(name).get_chunk(name, ci)
    }

    /// Decode a full tensor.
    pub fn get_tensor(&self, name: &str) -> Result<Vec<u32>> {
        self.home(name).get_tensor(name)
    }

    /// Warm the home shard's cache with one chunk
    /// (see [`StoreReader::prefetch_chunk`]).
    pub fn prefetch_chunk(&self, name: &str, ci: usize) -> Result<bool> {
        self.home(name).prefetch_chunk(name, ci)
    }

    /// Decode a value range of a tensor.
    pub fn get_range(&self, name: &str, range: std::ops::Range<u64>) -> Result<Vec<u32>> {
        self.home(name).get_range(name, range)
    }

    /// Aggregate read counters across shards (one shared backend).
    pub fn stats(&self) -> ReadStats {
        let mut agg = ReadStats { backend: self.backend(), ..Default::default() };
        for r in &self.readers {
            agg.merge(&r.stats());
        }
        agg
    }

    /// Merged `store.*` metrics snapshot across shards (counters sum; see
    /// [`crate::obs::RegistrySnapshot::merge`]).
    pub fn registry_snapshot(&self) -> crate::obs::RegistrySnapshot {
        let mut agg = crate::obs::RegistrySnapshot::default();
        for r in &self.readers {
            agg.merge(&r.registry_snapshot());
        }
        agg
    }

    /// Concatenated per-chunk access heat across shards (tensor names are
    /// globally unique — each lives on exactly one shard — so entries
    /// never collide), re-sorted `(tensor, chunk)`.
    pub fn heatmap(&self) -> Vec<super::heat::ChunkHeatEntry> {
        let mut out: Vec<super::heat::ChunkHeatEntry> =
            self.readers.iter().flat_map(|r| r.heatmap()).collect();
        out.sort_by(|a, b| (&a.tensor, a.chunk).cmp(&(&b.tensor, b.chunk)));
        out
    }

    /// Select the v2 decode kernel on every shard reader.
    pub fn set_decode_kernel(&self, kernel: crate::apack::simd::DecodeKernel) {
        for r in &self.readers {
            r.set_decode_kernel(kernel);
        }
    }

    /// The v2 decode kernel in use (uniform across shards — the setters
    /// only ever apply to all of them).
    pub fn decode_kernel(&self) -> crate::apack::simd::DecodeKernel {
        self.readers[0].decode_kernel()
    }

    /// Set v2 lane-decode worker threads on every shard reader.
    pub fn set_lane_threads(&self, threads: usize) {
        for r in &self.readers {
            r.set_lane_threads(threads);
        }
    }

    /// Zero every shard's read counters.
    pub fn reset_stats(&self) {
        for r in &self.readers {
            r.reset_stats();
        }
    }

    /// Drop every shard's cached chunks.
    pub fn clear_cache(&self) {
        for r in &self.readers {
            r.clear_cache();
        }
    }

    /// Integrity pass over every shard **in parallel** (each shard further
    /// fans its chunks out): re-read, CRC-check and decode everything.
    /// First-error-bail compatibility shim over [`Self::verify_report`].
    pub fn verify(&self) -> Result<VerifyReport> {
        let report = self.verify_report();
        match report.issues.first() {
            Some(issue) => Err(issue.error.clone()),
            None => Ok(report),
        }
    }

    /// Full classified sweep across every shard (never bails); each
    /// issue is stamped with its shard index.
    pub fn verify_report(&self) -> VerifyReport {
        let reports: Vec<VerifyReport> = par_map(&self.readers, |r| r.verify_report());
        let mut agg = VerifyReport::default();
        for (i, mut rep) in reports.into_iter().enumerate() {
            for issue in &mut rep.issues {
                issue.shard = Some(i);
            }
            agg.merge(&rep);
        }
        agg
    }
}

/// Pack the zoo into a sharded store at `dir`. `requested_shards` is
/// clamped to the store's estimated content by
/// [`PartitionPolicy::file_shards_for`] (a tiny store collapses to fewer
/// files), mirroring how substream counts scale within a tensor.
/// Pipelined by default; see [`pack_model_zoo_sharded_with`].
pub fn pack_model_zoo_sharded(
    dir: &Path,
    models: &[ModelConfig],
    sample_cap: usize,
    policy: PartitionPolicy,
    requested_shards: usize,
) -> Result<ShardedStoreSummary> {
    pack_model_zoo_sharded_with(
        dir,
        models,
        sample_cap,
        policy,
        requested_shards,
        &PackOptions::default(),
    )
}

/// [`pack_model_zoo_sharded`] with explicit [`PackOptions`] —
/// `pipelined: false` selects the serial path; both produce byte-identical
/// shard files.
pub fn pack_model_zoo_sharded_with(
    dir: &Path,
    models: &[ModelConfig],
    sample_cap: usize,
    policy: PartitionPolicy,
    requested_shards: usize,
    opts: &PackOptions,
) -> Result<ShardedStoreSummary> {
    let shards = policy.file_shards_for(requested_shards, zoo_value_estimate(models, sample_cap));
    let mut writer = ShardedStoreWriter::create_with(dir, shards, policy, opts.body)?;
    pack_zoo_into(&mut writer, models, sample_cap, &policy, opts)?;
    writer.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::distributions::ValueProfile;

    fn temp_dir(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("apack_shard_{}_{tag}.apackstore.d", std::process::id()))
    }

    fn tensor(n: usize, seed: u64) -> Vec<u32> {
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, seed)
    }

    #[test]
    fn manifest_roundtrip_and_rejection() {
        let m = ShardManifest {
            entries: vec![
                ShardEntry {
                    tensors: 3,
                    file_bytes: 1234,
                    generation: 7,
                    trailer_offset: 1206,
                },
                ShardEntry { tensors: 0, file_bytes: 40, generation: 0, trailer_offset: 12 },
            ],
        };
        let bytes = m.to_bytes();
        assert_eq!(ShardManifest::from_bytes(&bytes).unwrap(), m);

        // Any single-byte flip is caught (magic, counts, records or CRC).
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x40;
            assert!(
                matches!(ShardManifest::from_bytes(&bad), Err(Error::ManifestCorrupt(_))),
                "flip at {i}"
            );
        }
        // Truncations too.
        for keep in [0, 4, 11, bytes.len() - 1] {
            assert!(matches!(
                ShardManifest::from_bytes(&bytes[..keep]),
                Err(Error::ManifestCorrupt(_))
            ));
        }
    }

    #[test]
    fn v1_manifest_still_parses_as_generation_zero() {
        // Hand-build a v1 manifest (12-byte records, "APSHMAN1" magic):
        // pre-live-store packs must stay openable, reading as generation 0
        // with the trailer abutting EOF.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&MANIFEST_MAGIC);
        bytes.extend_from_slice(&2u32.to_le_bytes());
        for (tensors, file_bytes) in [(3u32, 1234u64), (0, 40)] {
            bytes.extend_from_slice(&tensors.to_le_bytes());
            bytes.extend_from_slice(&file_bytes.to_le_bytes());
        }
        let crc = crc32(&bytes);
        bytes.extend_from_slice(&crc.to_le_bytes());
        let m = ShardManifest::from_bytes(&bytes).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert_eq!(m.entries[0].generation, 0);
        assert_eq!(m.entries[0].trailer_offset, 1234 - TRAILER_BYTES as u64);
        assert_eq!(m.entries[1].trailer_offset, 40 - TRAILER_BYTES as u64);
        // A v1 record whose file cannot even hold a trailer is typed
        // corruption, not an underflow.
        let mut tiny = Vec::new();
        tiny.extend_from_slice(&MANIFEST_MAGIC);
        tiny.extend_from_slice(&1u32.to_le_bytes());
        tiny.extend_from_slice(&1u32.to_le_bytes());
        tiny.extend_from_slice(&10u64.to_le_bytes());
        let crc = crc32(&tiny);
        tiny.extend_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            ShardManifest::from_bytes(&tiny),
            Err(Error::ManifestCorrupt(_))
        ));
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        for shards in 1..=8usize {
            for name in ["a", "m/layer000/weights", "m/layer001/activations", ""] {
                let s = shard_for_name(name, shards);
                assert!(s < shards);
                assert_eq!(s, shard_for_name(name, shards), "stable");
            }
        }
        // The zoo-style names actually spread across 4 shards.
        let mut used = [false; 4];
        for i in 0..64 {
            used[shard_for_name(&format!("model/layer{i:03}/weights"), 4)] = true;
        }
        assert!(used.iter().all(|&u| u), "hash must use every shard: {used:?}");
    }

    #[test]
    fn sharded_write_read_roundtrip() {
        let dir = temp_dir("roundtrip");
        let policy = PartitionPolicy { substreams: 4, min_per_stream: 128 };
        let mut w = ShardedStoreWriter::create(&dir, 3, policy).unwrap();
        let tensors: Vec<(String, Vec<u32>)> =
            (0..10).map(|i| (format!("t{i:02}"), tensor(2000 + 517 * i, i as u64))).collect();
        for (name, v) in &tensors {
            w.add_tensor(name, 8, v, TensorKind::Weights).unwrap();
        }
        assert_eq!(w.tensor_count(), 10);
        let summary = w.finish().unwrap();
        assert_eq!(summary.shards, 3);
        assert_eq!(summary.tensors, 10);
        assert!(summary.compression_ratio() > 1.0);

        let r = ShardedStoreReader::open(&dir).unwrap();
        assert_eq!(r.shard_count(), 3);
        assert_eq!(r.tensor_count(), 10);
        for (name, v) in &tensors {
            assert_eq!(&r.get_tensor(name).unwrap(), v, "{name}");
            let meta = r.meta(name).unwrap();
            assert_eq!(meta.n_values, v.len() as u64);
        }
        assert!(r.get_tensor("absent").is_err());
        let rep = r.verify().unwrap();
        assert_eq!(rep.shards, 3);
        assert_eq!(rep.tensors, 10);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_names_rejected_across_shards() {
        let dir = temp_dir("dup");
        let mut w =
            ShardedStoreWriter::create(&dir, 4, PartitionPolicy::default()).unwrap();
        let v = tensor(500, 9);
        w.add_tensor("same", 8, &v, TensorKind::Weights).unwrap();
        assert!(w.add_tensor("same", 8, &v, TensorKind::Weights).is_err());
        drop(w);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unfinished_sharded_store_is_unopenable() {
        let dir = temp_dir("torn");
        let mut w =
            ShardedStoreWriter::create(&dir, 2, PartitionPolicy::default()).unwrap();
        w.add_tensor("x", 8, &tensor(3000, 4), TensorKind::Weights).unwrap();
        drop(w); // no finish(): no MANIFEST
        assert!(matches!(
            ShardedStoreReader::open(&dir),
            Err(Error::ManifestCorrupt(_))
        ));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn repack_with_fewer_shards_cleans_stale_files() {
        let dir = temp_dir("repack");
        let policy = PartitionPolicy { substreams: 2, min_per_stream: 64 };
        let mut w = ShardedStoreWriter::create(&dir, 4, policy).unwrap();
        w.add_tensor("a", 8, &tensor(1000, 1), TensorKind::Weights).unwrap();
        w.finish().unwrap();
        // Repack with 2 shards into the same directory.
        let mut w = ShardedStoreWriter::create(&dir, 2, policy).unwrap();
        w.add_tensor("a", 8, &tensor(1000, 1), TensorKind::Weights).unwrap();
        w.finish().unwrap();
        let r = ShardedStoreReader::open(&dir).unwrap();
        assert_eq!(r.shard_count(), 2, "stale shard-002/003 must be gone");
        std::fs::remove_dir_all(&dir).ok();
    }
}
