//! **Chunk-access heatmaps** — per-tensor / per-chunk demand, prefetch
//! and decode-cost counters behind every [`super::StoreReader`]
//! (DESIGN.md §12).
//!
//! The reader's aggregate counters (`store.cache_hits`, …) say *how
//! much* traffic the store saw; the heatmap says *where*: which chunks
//! are hot, which tensors the prefetcher actually helps, where decode
//! nanos concentrate. The [`HeatMap`] is a sharded counter map —
//! [`HEAT_SHARDS`] mutexed `HashMap<(tensor, chunk), Cell>` shards,
//! key-hashed so concurrent readers on different chunks rarely contend
//! — updated on paths that already hold or just released the chunk-cache
//! lock, so the marginal cost is one short-critical-section hash update
//! per chunk access (measured by the attribution overhead gate,
//! EXPERIMENTS.md).
//!
//! Attribution rules:
//!
//! - `demand_hits` / `demand_misses` — `get_*` traffic through the LRU,
//!   mirroring the reader's hit/miss counters per chunk.
//! - `prefetches` — decodes issued by [`super::StoreReader::prefetch_chunk`]
//!   (already-resident no-ops are not counted).
//! - `decode_nanos` — decode time of **every** decode of the chunk
//!   (demand miss, prefetch, or verify sweep), since decode cost is a
//!   property of the chunk, not of who asked. Single-thread decodes
//!   contribute wall time; threaded lane decodes contribute the summed
//!   per-worker lane nanos (actual decode work), not the caller's wall
//!   clock — so the heatmap never under-reports a threaded decode.
//! - A prefetched chunk that later takes a demand **hit** counts as an
//!   effective prefetch; [`TensorHeatSummary::prefetch_efficacy`] is the
//!   per-tensor fraction of prefetched chunks that were ever hit.

use std::collections::hash_map::DefaultHasher;
use std::collections::{BTreeMap, HashMap};
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

use crate::obs::export::{prom_label_value, prom_metric_name};
use crate::util::json::Json;

/// Mutex shards in one [`HeatMap`].
pub const HEAT_SHARDS: usize = 16;

#[derive(Debug, Default, Clone, Copy)]
struct Cell {
    demand_hits: u64,
    demand_misses: u64,
    prefetches: u64,
    decode_nanos: u64,
    /// Set when the chunk failed a non-transient read/decode (DESIGN.md
    /// §14): the error still propagated, but the heatmap remembers
    /// *which* chunk is damaged.
    quarantined: bool,
}

/// Sharded `(tensor index, chunk index) → counters` map.
#[derive(Debug)]
pub struct HeatMap {
    shards: Vec<Mutex<HashMap<(u32, u32), Cell>>>,
}

impl Default for HeatMap {
    fn default() -> Self {
        Self::new()
    }
}

impl HeatMap {
    /// An empty map with [`HEAT_SHARDS`] shards.
    pub fn new() -> HeatMap {
        HeatMap { shards: (0..HEAT_SHARDS).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    fn with_cell(&self, ti: u32, ci: u32, f: impl FnOnce(&mut Cell)) {
        let mut h = DefaultHasher::new();
        (ti, ci).hash(&mut h);
        let shard = (h.finish() as usize) % self.shards.len();
        let mut map = self.shards[shard].lock().expect("heat shard lock");
        f(map.entry((ti, ci)).or_default());
    }

    /// Count a demand read served from the chunk cache.
    pub fn demand_hit(&self, ti: u32, ci: u32) {
        self.with_cell(ti, ci, |c| c.demand_hits += 1);
    }

    /// Count a demand read that had to decode.
    pub fn demand_miss(&self, ti: u32, ci: u32) {
        self.with_cell(ti, ci, |c| c.demand_misses += 1);
    }

    /// Count a prefetch-issued decode.
    pub fn prefetch(&self, ti: u32, ci: u32) {
        self.with_cell(ti, ci, |c| c.prefetches += 1);
    }

    /// Accumulate decode wall time for one chunk.
    pub fn add_decode_nanos(&self, ti: u32, ci: u32, nanos: u64) {
        self.with_cell(ti, ci, |c| c.decode_nanos += nanos);
    }

    /// Flag a chunk as quarantined after a non-transient read/decode
    /// failure (sticky — corruption does not heal on its own).
    pub fn quarantine(&self, ti: u32, ci: u32) {
        self.with_cell(ti, ci, |c| c.quarantined = true);
    }

    fn snapshot(&self) -> Vec<((u32, u32), Cell)> {
        let mut out = Vec::new();
        for shard in &self.shards {
            let map = shard.lock().expect("heat shard lock");
            out.extend(map.iter().map(|(k, v)| (*k, *v)));
        }
        out
    }

    /// Join the raw cells against tensor metadata into presentable
    /// entries, sorted `(tensor, chunk)`.
    pub fn entries(
        &self,
        resolve: impl Fn(u32) -> Option<(String, u8, u8)>,
    ) -> Vec<ChunkHeatEntry> {
        let mut out: Vec<ChunkHeatEntry> = self
            .snapshot()
            .into_iter()
            .filter_map(|((ti, ci), c)| {
                let (tensor, body_version, lanes) = resolve(ti)?;
                Some(ChunkHeatEntry {
                    tensor,
                    chunk: ci,
                    body_version,
                    lanes,
                    demand_hits: c.demand_hits,
                    demand_misses: c.demand_misses,
                    prefetches: c.prefetches,
                    decode_nanos: c.decode_nanos,
                    quarantined: c.quarantined,
                })
            })
            .collect();
        out.sort_by(|a, b| (&a.tensor, a.chunk).cmp(&(&b.tensor, b.chunk)));
        out
    }
}

/// One chunk's heat, joined with tensor identity.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkHeatEntry {
    /// Owning tensor name.
    pub tensor: String,
    /// Chunk index within the tensor.
    pub chunk: u32,
    /// The tensor's chunk-body framing version (1 or 2).
    pub body_version: u8,
    /// Requested lanes per chunk (1 for v1 bodies).
    pub lanes: u8,
    /// Demand reads served from the chunk cache.
    pub demand_hits: u64,
    /// Demand reads that decoded.
    pub demand_misses: u64,
    /// Prefetch-issued decodes.
    pub prefetches: u64,
    /// Summed decode wall time (all decode paths).
    pub decode_nanos: u64,
    /// The chunk failed a non-transient read/decode at least once.
    pub quarantined: bool,
}

impl ChunkHeatEntry {
    /// Total accesses of any kind — the table's heat ordering key.
    pub fn touches(&self) -> u64 {
        self.demand_hits + self.demand_misses + self.prefetches
    }
}

/// Per-tensor rollup of chunk heat, including prefetch efficacy.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorHeatSummary {
    /// Tensor name.
    pub tensor: String,
    /// Chunk-body framing version.
    pub body_version: u8,
    /// Requested lanes per chunk.
    pub lanes: u8,
    /// Chunks with any recorded access.
    pub chunks_touched: usize,
    /// Summed demand hits.
    pub demand_hits: u64,
    /// Summed demand misses.
    pub demand_misses: u64,
    /// Summed prefetch decodes.
    pub prefetches: u64,
    /// Summed decode wall time.
    pub decode_nanos: u64,
    /// Chunks that were prefetched at least once.
    pub prefetched_chunks: usize,
    /// Prefetched chunks that later (or ever) took a demand hit.
    pub prefetched_then_hit: usize,
}

impl TensorHeatSummary {
    /// Fraction of prefetched chunks that were ever demand-hit; `None`
    /// when nothing was prefetched.
    pub fn prefetch_efficacy(&self) -> Option<f64> {
        if self.prefetched_chunks == 0 {
            None
        } else {
            Some(self.prefetched_then_hit as f64 / self.prefetched_chunks as f64)
        }
    }
}

/// Roll chunk entries up per tensor, hottest (most demand traffic) first.
pub fn summarize(entries: &[ChunkHeatEntry]) -> Vec<TensorHeatSummary> {
    let mut by_tensor: BTreeMap<&str, TensorHeatSummary> = BTreeMap::new();
    for e in entries {
        let s = by_tensor.entry(&e.tensor).or_insert_with(|| TensorHeatSummary {
            tensor: e.tensor.clone(),
            body_version: e.body_version,
            lanes: e.lanes,
            chunks_touched: 0,
            demand_hits: 0,
            demand_misses: 0,
            prefetches: 0,
            decode_nanos: 0,
            prefetched_chunks: 0,
            prefetched_then_hit: 0,
        });
        s.chunks_touched += 1;
        s.demand_hits += e.demand_hits;
        s.demand_misses += e.demand_misses;
        s.prefetches += e.prefetches;
        s.decode_nanos += e.decode_nanos;
        if e.prefetches > 0 {
            s.prefetched_chunks += 1;
            if e.demand_hits > 0 {
                s.prefetched_then_hit += 1;
            }
        }
    }
    let mut out: Vec<TensorHeatSummary> = by_tensor.into_values().collect();
    out.sort_by(|a, b| {
        (b.demand_hits + b.demand_misses, &a.tensor)
            .cmp(&(a.demand_hits + a.demand_misses, &b.tensor))
    });
    out
}

/// The top-K hottest chunks as an aligned table.
pub fn render_top_chunks(entries: &[ChunkHeatEntry], k: usize) -> String {
    let mut hottest: Vec<&ChunkHeatEntry> = entries.iter().collect();
    hottest.sort_by(|a, b| {
        (b.touches(), &a.tensor, a.chunk).cmp(&(a.touches(), &b.tensor, b.chunk))
    });
    let rows: Vec<Vec<String>> = hottest
        .iter()
        .take(k)
        .map(|e| {
            vec![
                e.tensor.clone(),
                e.chunk.to_string(),
                format!("v{}", e.body_version),
                e.lanes.to_string(),
                e.demand_hits.to_string(),
                e.demand_misses.to_string(),
                e.prefetches.to_string(),
                format!("{:.3}", e.decode_nanos as f64 / 1e6),
                if e.quarantined { "yes".to_string() } else { "-".to_string() },
            ]
        })
        .collect();
    crate::eval::render_table(
        &format!("hottest chunks (top {})", rows.len()),
        &[
            "tensor",
            "chunk",
            "body",
            "lanes",
            "hits",
            "misses",
            "prefetches",
            "decode ms",
            "quarantined",
        ],
        &rows,
    )
}

/// Per-tensor rollup (with prefetch efficacy) as an aligned table.
pub fn render_tensor_summary(summaries: &[TensorHeatSummary]) -> String {
    let rows: Vec<Vec<String>> = summaries
        .iter()
        .map(|s| {
            vec![
                s.tensor.clone(),
                format!("v{}", s.body_version),
                s.lanes.to_string(),
                s.chunks_touched.to_string(),
                s.demand_hits.to_string(),
                s.demand_misses.to_string(),
                s.prefetches.to_string(),
                match s.prefetch_efficacy() {
                    Some(e) => format!("{:.0}%", e * 100.0),
                    None => "-".to_string(),
                },
                format!("{:.3}", s.decode_nanos as f64 / 1e6),
            ]
        })
        .collect();
    crate::eval::render_table(
        "tensor heat (prefetch efficacy = prefetched chunks later hit)",
        &[
            "tensor",
            "body",
            "lanes",
            "chunks",
            "hits",
            "misses",
            "prefetches",
            "efficacy",
            "decode ms",
        ],
        &rows,
    )
}

/// The full heatmap as one JSON document (`store heatmap --json`).
pub fn heatmap_json(store: &str, entries: &[ChunkHeatEntry]) -> Json {
    let summaries = summarize(entries);
    let chunk_json = |e: &ChunkHeatEntry| {
        let mut m = BTreeMap::new();
        m.insert("chunk".to_string(), Json::Num(e.chunk as f64));
        m.insert("demand_hits".to_string(), Json::Num(e.demand_hits as f64));
        m.insert("demand_misses".to_string(), Json::Num(e.demand_misses as f64));
        m.insert("prefetches".to_string(), Json::Num(e.prefetches as f64));
        m.insert("decode_nanos".to_string(), Json::Num(e.decode_nanos as f64));
        m.insert("quarantined".to_string(), Json::Bool(e.quarantined));
        Json::Obj(m)
    };
    let tensors = summaries
        .iter()
        .map(|s| {
            let mut m = BTreeMap::new();
            m.insert("tensor".to_string(), Json::Str(s.tensor.clone()));
            m.insert("body_version".to_string(), Json::Num(s.body_version as f64));
            m.insert("lanes".to_string(), Json::Num(s.lanes as f64));
            m.insert("chunks_touched".to_string(), Json::Num(s.chunks_touched as f64));
            m.insert("demand_hits".to_string(), Json::Num(s.demand_hits as f64));
            m.insert("demand_misses".to_string(), Json::Num(s.demand_misses as f64));
            m.insert("prefetches".to_string(), Json::Num(s.prefetches as f64));
            m.insert(
                "prefetch_efficacy".to_string(),
                match s.prefetch_efficacy() {
                    Some(e) => Json::Num(e),
                    None => Json::Null,
                },
            );
            m.insert("decode_nanos".to_string(), Json::Num(s.decode_nanos as f64));
            m.insert(
                "chunks".to_string(),
                Json::Arr(
                    entries
                        .iter()
                        .filter(|e| e.tensor == s.tensor)
                        .map(chunk_json)
                        .collect(),
                ),
            );
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("store".to_string(), Json::Str(store.to_string()));
    root.insert("tensors".to_string(), Json::Arr(tensors));
    Json::Obj(root)
}

/// Prometheus exposition text for the heatmap: per-chunk counters with
/// `tensor`/`chunk` labels. Tensor names are arbitrary strings, so label
/// values go through [`prom_label_value`] — the hostile-name test in
/// `obs::export` pins the escaping.
pub fn heatmap_prometheus_text(entries: &[ChunkHeatEntry]) -> String {
    let mut out = String::new();
    let series = [
        ("store_chunk_demand_hits", |e: &ChunkHeatEntry| e.demand_hits),
        ("store_chunk_demand_misses", |e: &ChunkHeatEntry| e.demand_misses),
        ("store_chunk_prefetches", |e: &ChunkHeatEntry| e.prefetches),
        ("store_chunk_decode_nanos", |e: &ChunkHeatEntry| e.decode_nanos),
    ];
    for (name, value) in series {
        let n = prom_metric_name(name);
        out.push_str(&format!("# TYPE {n} counter\n"));
        for e in entries {
            out.push_str(&format!(
                "{n}{{tensor=\"{}\",chunk=\"{}\"}} {}\n",
                prom_label_value(&e.tensor),
                e.chunk,
                value(e),
            ));
        }
    }
    // Quarantine flag (0/1) — a gauge, not a counter: it marks current
    // damage, it does not accumulate.
    let n = prom_metric_name("store_chunk_quarantined");
    out.push_str(&format!("# TYPE {n} gauge\n"));
    for e in entries {
        out.push_str(&format!(
            "{n}{{tensor=\"{}\",chunk=\"{}\"}} {}\n",
            prom_label_value(&e.tensor),
            e.chunk,
            u64::from(e.quarantined),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn resolve(ti: u32) -> Option<(String, u8, u8)> {
        match ti {
            0 => Some(("alpha".to_string(), 2, 16)),
            1 => Some(("beta".to_string(), 1, 1)),
            _ => None,
        }
    }

    #[test]
    fn counters_accumulate_per_chunk() {
        let heat = HeatMap::new();
        heat.demand_miss(0, 0);
        heat.add_decode_nanos(0, 0, 500);
        heat.demand_hit(0, 0);
        heat.demand_hit(0, 0);
        heat.prefetch(0, 3);
        heat.add_decode_nanos(0, 3, 700);
        heat.demand_hit(1, 0);
        let entries = heat.entries(resolve);
        assert_eq!(entries.len(), 3);
        let e00 = &entries[0];
        assert_eq!((e00.tensor.as_str(), e00.chunk), ("alpha", 0));
        assert_eq!((e00.demand_hits, e00.demand_misses, e00.decode_nanos), (2, 1, 500));
        let e03 = &entries[1];
        assert_eq!((e03.prefetches, e03.decode_nanos), (1, 700));
        assert_eq!(entries[2].tensor, "beta");
    }

    #[test]
    fn unknown_tensor_indices_are_dropped() {
        let heat = HeatMap::new();
        heat.demand_hit(7, 0);
        assert!(heat.entries(resolve).is_empty());
    }

    #[test]
    fn summary_computes_prefetch_efficacy() {
        let heat = HeatMap::new();
        // alpha: chunk 0 prefetched then hit, chunk 1 prefetched never
        // hit, chunk 2 demand-only.
        heat.prefetch(0, 0);
        heat.demand_hit(0, 0);
        heat.prefetch(0, 1);
        heat.demand_miss(0, 2);
        heat.demand_hit(1, 0);
        let sums = summarize(&heat.entries(resolve));
        let alpha = sums.iter().find(|s| s.tensor == "alpha").unwrap();
        assert_eq!(alpha.chunks_touched, 3);
        assert_eq!((alpha.prefetched_chunks, alpha.prefetched_then_hit), (2, 1));
        assert_eq!(alpha.prefetch_efficacy(), Some(0.5));
        let beta = sums.iter().find(|s| s.tensor == "beta").unwrap();
        assert_eq!(beta.prefetch_efficacy(), None);
    }

    #[test]
    fn hostile_tensor_name_exposition_stays_parseable() {
        let heat = HeatMap::new();
        heat.demand_hit(0, 0);
        heat.prefetch(0, 1);
        let entries = heat.entries(|_| Some(("foo{bar=\"baz\n\"}".to_string(), 2, 16)));
        let text = heatmap_prometheus_text(&entries);
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            // Exposition shape: `name{labels} value` on one line with a
            // terminal numeric value — a raw newline in the tensor name
            // would break this split.
            let (head, value) = line.rsplit_once(' ').expect("value after space");
            assert!(value.parse::<f64>().is_ok(), "bad value in {line:?}");
            assert!(head.starts_with("store_chunk_"), "bad series in {line:?}");
            assert!(head.ends_with('}'), "unterminated labels in {line:?}");
        }
        assert!(text.contains("tensor=\"foo{bar=\\\"baz\\n\\\"}\""));
    }

    #[test]
    fn quarantine_flag_is_sticky_and_exported() {
        let heat = HeatMap::new();
        heat.demand_miss(0, 2);
        heat.quarantine(0, 2);
        heat.quarantine(0, 2); // idempotent
        heat.demand_hit(0, 0);
        let entries = heat.entries(resolve);
        let bad = entries.iter().find(|e| e.chunk == 2).unwrap();
        assert!(bad.quarantined);
        let ok = entries.iter().find(|e| e.chunk == 0).unwrap();
        assert!(!ok.quarantined);
        let table = render_top_chunks(&entries, 10);
        assert!(table.contains("quarantined"));
        let prom = heatmap_prometheus_text(&entries);
        assert!(prom.contains("store_chunk_quarantined{tensor=\"alpha\",chunk=\"2\"} 1"));
        assert!(prom.contains("store_chunk_quarantined{tensor=\"alpha\",chunk=\"0\"} 0"));
        let doc = heatmap_json("zoo.apackstore", &entries).to_string();
        assert!(doc.contains("\"quarantined\":true"));
    }

    #[test]
    fn renders_and_json_round_trip() {
        let heat = HeatMap::new();
        heat.demand_miss(0, 0);
        heat.prefetch(0, 1);
        let entries = heat.entries(resolve);
        let table = render_top_chunks(&entries, 10);
        assert!(table.contains("alpha"));
        let summary = render_tensor_summary(&summarize(&entries));
        assert!(summary.contains("efficacy"));
        let doc = heatmap_json("zoo.apackstore", &entries).to_string();
        let parsed = Json::parse(&doc).expect("heatmap json parses");
        assert_eq!(
            parsed.get("tensors").and_then(|t| t.as_arr()).map(|a| a.len()),
            Some(1)
        );
    }
}
