//! Classified store verification (DESIGN.md §14).
//!
//! `store verify` historically bailed on the first broken byte it met,
//! which tells an operator *that* a store is damaged but not *what kind*
//! of damage it is or how much of it there is. This module adds the
//! classified, non-bailing sweep: every corruption found is recorded as a
//! [`VerifyIssue`] tagged with a [`CorruptionClass`], the sweep continues
//! past it, and the CLI maps the worst class present to a distinct exit
//! code (plus a `--json` machine-readable report) so scripts can branch
//! on footer-vs-chunk-vs-lane damage without parsing prose.
//!
//! Classes, from most to least structural:
//!
//! | class                 | exit code | meaning                                     |
//! |-----------------------|-----------|---------------------------------------------|
//! | `Footer`              | 10        | a store/shard footer, trailer or index is unreadable |
//! | `Manifest`            | 11        | the sharded MANIFEST is corrupt/inconsistent |
//! | `GenerationPointer`   | 14        | the `<store>.gen` sidecar fails validation  |
//! | `ChunkCrc`            | 12        | a chunk failed its whole-chunk CRC or decode |
//! | `LaneCrc`             | 13        | a v2 lane CRC failed behind a valid chunk CRC |

use std::path::Path;

use crate::error::Error;
use crate::store::handle::StoreHandle;
use crate::store::io::Backend;
use crate::store::reader::VerifyReport;
use crate::util::json::Json;

/// What kind of corruption a [`VerifyIssue`] describes. Ordered by
/// structural severity: footer damage makes a whole file unreadable,
/// manifest damage a whole directory, a bad generation pointer loses the
/// commit point (but the classic fallback may still open), and chunk/lane
/// CRC failures are localized to one chunk (lane CRC even to one lane).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CorruptionClass {
    /// A store (or shard) footer, trailer, magic or index failed
    /// validation — the file cannot be opened at all.
    Footer,
    /// The sharded store's MANIFEST is unreadable, fails its CRC, or
    /// disagrees with the directory contents.
    Manifest,
    /// A chunk failed its whole-chunk CRC, or decoded inconsistently.
    ChunkCrc,
    /// A v2 lane body failed a per-lane CRC behind a *valid* whole-chunk
    /// CRC (PR 7 localization: the damage is pinned to one lane).
    LaneCrc,
    /// The `<store>.gen` generation-pointer sidecar exists but fails
    /// validation (the classic exact-EOF fallback may still open the
    /// store).
    GenerationPointer,
}

impl CorruptionClass {
    /// The CLI exit code for a verify run whose *worst* issue is this
    /// class (0 stays "clean"; 1 stays the generic usage/IO failure).
    pub fn exit_code(self) -> u8 {
        match self {
            CorruptionClass::Footer => 10,
            CorruptionClass::Manifest => 11,
            CorruptionClass::ChunkCrc => 12,
            CorruptionClass::LaneCrc => 13,
            CorruptionClass::GenerationPointer => 14,
        }
    }

    /// Severity order (0 = most severe). Drives
    /// [`VerifyReport::worst_class`].
    pub fn severity_rank(self) -> u8 {
        match self {
            CorruptionClass::Footer => 0,
            CorruptionClass::Manifest => 1,
            CorruptionClass::GenerationPointer => 2,
            CorruptionClass::ChunkCrc => 3,
            CorruptionClass::LaneCrc => 4,
        }
    }

    /// Stable machine-readable label (JSON report, Prometheus labels).
    pub fn label(self) -> &'static str {
        match self {
            CorruptionClass::Footer => "footer",
            CorruptionClass::Manifest => "manifest",
            CorruptionClass::ChunkCrc => "chunk-crc",
            CorruptionClass::LaneCrc => "lane-crc",
            CorruptionClass::GenerationPointer => "generation-pointer",
        }
    }
}

impl std::fmt::Display for CorruptionClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

/// One classified corruption found by a verify sweep. Location fields are
/// filled as precisely as the class allows: a footer issue has no tensor,
/// a lane-CRC issue names tensor + chunk (the lane is in the error text).
#[derive(Debug, Clone)]
pub struct VerifyIssue {
    pub class: CorruptionClass,
    /// Shard index for sharded stores (None for single-file stores and
    /// directory-level issues).
    pub shard: Option<usize>,
    /// Tensor name, when the damage is localized to one tensor.
    pub tensor: Option<String>,
    /// Chunk index within the tensor, when localized to one chunk.
    pub chunk: Option<u32>,
    /// Human-readable summary of what check failed.
    pub detail: String,
    /// The underlying typed error (kept so `verify`'s bail-on-first
    /// compatibility shim surfaces exactly what it always did).
    pub error: Error,
}

impl VerifyIssue {
    /// One-line rendering for the CLI's human report.
    pub fn render(&self) -> String {
        let mut loc = String::new();
        if let Some(s) = self.shard {
            loc.push_str(&format!("shard {s} "));
        }
        if let Some(t) = &self.tensor {
            loc.push_str(&format!("tensor {t} "));
        }
        if let Some(c) = self.chunk {
            loc.push_str(&format!("chunk {c} "));
        }
        format!("[{}] {}{} — {}", self.class, loc, self.detail, self.error)
    }
}

/// Map an open-level error to the corruption class it evidences.
pub fn classify_open_error(e: &Error) -> CorruptionClass {
    match e {
        Error::ManifestCorrupt(_)
        | Error::ShardMissing { .. }
        | Error::ShardCountMismatch { .. } => CorruptionClass::Manifest,
        _ => CorruptionClass::Footer,
    }
}

/// Full classified verify of the store at `path` (single file or sharded
/// directory — auto-detected like [`StoreHandle::open`]). Never errors:
/// a store too broken to open becomes a report whose issues carry the
/// open failure, classified. An invalid generation-pointer sidecar is
/// reported even when the classic exact-EOF fallback opens the store
/// fine (the commit point is lost; the data is not).
pub fn verify_store(path: &Path, backend: Backend) -> VerifyReport {
    use crate::store::format::{gen_pointer_path, GenPointer};

    let mut pointer_issue = None;
    if !path.is_dir() {
        let ptr_path = gen_pointer_path(path);
        if let Ok(bytes) = std::fs::read(&ptr_path) {
            if let Err(pe) = GenPointer::from_bytes(&bytes) {
                pointer_issue = Some(VerifyIssue {
                    class: CorruptionClass::GenerationPointer,
                    shard: None,
                    tensor: None,
                    chunk: None,
                    detail: format!("generation pointer {} fails validation", ptr_path.display()),
                    error: pe,
                });
            }
        }
    }
    let mut report = match StoreHandle::open_with(path, backend, 0) {
        Ok(store) => store.verify_report(),
        Err(e) => {
            let mut rep = VerifyReport::default();
            rep.issues.push(VerifyIssue {
                class: classify_open_error(&e),
                shard: None,
                tensor: None,
                chunk: None,
                detail: "store failed to open".into(),
                error: e,
            });
            rep
        }
    };
    if let Some(issue) = pointer_issue {
        report.issues.push(issue);
    }
    report
}

/// Machine-readable verify report (`store verify --json`).
pub fn verify_report_json(store: &str, report: &VerifyReport) -> Json {
    let issues: Vec<Json> = report
        .issues
        .iter()
        .map(|i| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("class".to_string(), Json::Str(i.class.label().to_string()));
            m.insert("exit_code".to_string(), Json::Num(i.class.exit_code() as f64));
            m.insert(
                "shard".to_string(),
                i.shard.map_or(Json::Null, |s| Json::Num(s as f64)),
            );
            m.insert(
                "tensor".to_string(),
                i.tensor.as_ref().map_or(Json::Null, |t| Json::Str(t.clone())),
            );
            m.insert(
                "chunk".to_string(),
                i.chunk.map_or(Json::Null, |c| Json::Num(c as f64)),
            );
            m.insert("detail".to_string(), Json::Str(i.detail.clone()));
            m.insert("error".to_string(), Json::Str(i.error.to_string()));
            Json::Obj(m)
        })
        .collect();
    let mut m = std::collections::BTreeMap::new();
    m.insert("store".to_string(), Json::Str(store.to_string()));
    m.insert("clean".to_string(), Json::Bool(report.is_clean()));
    m.insert("shards".to_string(), Json::Num(report.shards as f64));
    m.insert("tensors".to_string(), Json::Num(report.tensors as f64));
    m.insert("chunks".to_string(), Json::Num(report.chunks as f64));
    m.insert("clean_bytes".to_string(), Json::Num(report.bytes as f64));
    m.insert("generation".to_string(), Json::Num(report.generation as f64));
    m.insert(
        "worst_class".to_string(),
        report.worst_class().map_or(Json::Null, |c| Json::Str(c.label().to_string())),
    );
    m.insert(
        "exit_code".to_string(),
        Json::Num(report.worst_class().map_or(0, |c| c.exit_code()) as f64),
    );
    m.insert("issues".to_string(), Json::Arr(issues));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exit_codes_and_ranks_are_distinct() {
        let all = [
            CorruptionClass::Footer,
            CorruptionClass::Manifest,
            CorruptionClass::ChunkCrc,
            CorruptionClass::LaneCrc,
            CorruptionClass::GenerationPointer,
        ];
        let mut codes: Vec<u8> = all.iter().map(|c| c.exit_code()).collect();
        codes.sort_unstable();
        codes.dedup();
        assert_eq!(codes.len(), all.len(), "exit codes must be distinct");
        assert!(codes.iter().all(|&c| c >= 10), "codes 0/1 are reserved");
        let mut ranks: Vec<u8> = all.iter().map(|c| c.severity_rank()).collect();
        ranks.sort_unstable();
        ranks.dedup();
        assert_eq!(ranks.len(), all.len(), "severity ranks must be distinct");
    }

    #[test]
    fn open_errors_classify_by_layer() {
        assert_eq!(
            classify_open_error(&Error::ManifestCorrupt("x".into())),
            CorruptionClass::Manifest
        );
        assert_eq!(
            classify_open_error(&Error::ShardMissing { shard: "s".into() }),
            CorruptionClass::Manifest
        );
        assert_eq!(
            classify_open_error(&Error::ShardCountMismatch { manifest: 2, found: 1 }),
            CorruptionClass::Manifest
        );
        assert_eq!(
            classify_open_error(&Error::Store("footer CRC mismatch".into())),
            CorruptionClass::Footer
        );
    }

    #[test]
    fn json_report_shape() {
        let mut rep = VerifyReport {
            shards: 1,
            tensors: 2,
            chunks: 9,
            bytes: 1234,
            generation: 3,
            issues: Vec::new(),
        };
        let clean = verify_report_json("m.apackstore", &rep).to_string();
        assert!(clean.contains("\"clean\":true"));
        assert!(clean.contains("\"exit_code\":0"));
        rep.issues.push(VerifyIssue {
            class: CorruptionClass::LaneCrc,
            shard: Some(1),
            tensor: Some("t".into()),
            chunk: Some(4),
            detail: "per-lane CRC sweep failed".into(),
            error: Error::CorruptStream { position: 7 },
        });
        let j = verify_report_json("m.apackstore", &rep);
        let s = j.to_string();
        assert!(s.contains("\"clean\":false"));
        assert!(s.contains("\"worst_class\":\"lane-crc\""));
        assert!(s.contains("\"exit_code\":13"));
        // The document round-trips through the parser.
        let parsed = Json::parse(&s).unwrap();
        assert_eq!(parsed.get("generation").unwrap().as_usize().unwrap(), 3);
        assert_eq!(parsed.get("issues").unwrap().as_arr().unwrap().len(), 1);
    }
}
