//! **APackStore** — a persistent, random-access compressed tensor store.
//!
//! APack's premise is that compressed tensors live *at rest* and are
//! decoded on demand on the DRAM path (paper §V). This module turns the
//! codec into that servable artifact: one file holds many named tensors,
//! each split into independently decodable fixed-value-count chunks
//! (sharded by [`crate::coordinator::PartitionPolicy`], like the
//! substreams the replicated hardware engines consume) with one shared
//! [`crate::apack::SymbolTable`] per tensor stored exactly once in the
//! footer index.
//!
//! - [`format`] — the on-disk layout: magic, chunk blobs, footer index
//!   with per-chunk CRC32s, fixed trailer. See its module docs for the
//!   byte-level specification.
//! - [`writer`] — [`StoreWriter`] (streaming, parallel chunk encode) and
//!   [`pack_model_zoo`] (the 24 Table-II models into one store).
//! - [`reader`] — [`StoreReader`]: `get_tensor` / `get_chunk` /
//!   `get_range` decode only the chunks they touch, in parallel, with
//!   corruption detection on every read and byte-accounted I/O stats.
//! - [`cache`] — [`ChunkCache`], the bounded LRU of decoded chunks behind
//!   the reader's hot path.

pub mod cache;
pub mod format;
pub mod reader;
pub mod writer;

pub use cache::ChunkCache;
pub use format::{crc32, ChunkMeta, StoreIndex, TensorMeta};
pub use reader::{ReadStats, StoreReader, VerifyReport, DEFAULT_CACHE_VALUES};
pub use writer::{pack_model_zoo, StoreSummary, StoreWriter};
