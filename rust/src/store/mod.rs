//! **APackStore** — a persistent, random-access compressed tensor store.
//!
//! APack's premise is that compressed tensors live *at rest* and are
//! decoded on demand on the DRAM path (paper §V). This module turns the
//! codec into that servable artifact: named tensors split into
//! independently decodable fixed-value-count chunks (sharded by
//! [`crate::coordinator::PartitionPolicy`], like the substreams the
//! replicated hardware engines consume) with one shared
//! [`crate::apack::SymbolTable`] per tensor stored exactly once in the
//! footer index.
//!
//! # Store layouts
//!
//! A store is either **one file** or a **sharded directory**; both are
//! opened uniformly through [`StoreHandle`]:
//!
//! ```text
//! single file:   model.apackstore           (format.rs: magic | chunk
//!                                            blobs | footer index | trailer)
//!                                           magic APACKST1: v1 single-stream
//!                                            chunk bodies; APACKST2: chunk
//!                                            body v2 lane bodies, footer
//!                                            records body version + lanes
//!                                            per tensor ([`BodyConfig`])
//!
//! sharded dir:   model.apackstore.d/
//!                  MANIFEST                 (shard.rs: magic | shard_count
//!                                            | per-shard records | crc32)
//!                  shard-000.apackstore     (each a complete single-file
//!                  shard-001.apackstore      store; tensors routed here by
//!                  ...                       FNV-1a name hash)
//! ```
//!
//! Tensors are hash-partitioned across shard files by
//! [`shard_for_name`]; the shard count scales with content via
//! [`crate::coordinator::PartitionPolicy::file_shards_for`]. Each shard is
//! self-contained, so shards verify in parallel and can later be placed on
//! different nodes.
//!
//! # The `ChunkSource` contract
//!
//! All chunk IO flows through the [`ChunkSource`] trait ([`io`]):
//! positioned `read_at(offset, len)` reads, `Sync`, and **no interior
//! mutex on the read path** — concurrent `get_range` calls never serialize
//! on IO. Two backends implement it: [`MmapSource`] ([`Backend::Mmap`],
//! the default) serves zero-copy slices of a read-only mapping, and
//! [`FileSource`] ([`Backend::File`]) issues one `pread`-style positioned
//! read per chunk. Both count bytes per backend so the paths are
//! comparable in one run.
//!
//! # The `StoreHandle` contract
//!
//! [`StoreHandle`] is the single type every consumer (CLI, eval report,
//! benches, the serving layer) holds. It presents the same surface over
//! either layout — `get_tensor` / `get_chunk` / `get_range` / `meta` /
//! `stats` / `verify` / `clear_cache` / `prefetch_chunk` — with
//! identical semantics: bit-exact decode, reads touch only covering
//! chunks, every read is CRC-checked, stats aggregate across shards.
//! [`crate::serving::ServingEngine`] builds request scheduling
//! (batching, coalescing, admission control, prefetch) on top of this
//! surface without the store knowing.
//!
//! Telemetry: each reader owns a [`crate::obs::MetricsRegistry`] with
//! `store.*` counters and each writer one with `ingest.*` counters
//! (glossary: DESIGN.md §10); `ReadStats` / `PackStats` are views over
//! registry snapshots, chunk IO and decode record
//! [`crate::obs::span`]s when tracing is on, and
//! `StoreHandle::registry_snapshot` merges across shards for the
//! exporters.
//!
//! # Submodules
//!
//! - [`format`] — single-file on-disk layout: magic, chunk blobs, footer
//!   index with per-chunk CRC32s, fixed trailer.
//! - [`io`] — [`ChunkSource`] and the mmap / positioned-file backends.
//! - [`writer`] — [`StoreWriter`] (streaming chunk append, [`PackStats`]
//!   stage accounting), [`encode_tensor`]/[`EncodedTensor`] (the ingest
//!   compute stage) and [`pack_model_zoo`] (the 24 Table-II models into
//!   one store).
//! - [`pipeline`] — the pipelined zoo packer: compute workers overlap
//!   tensor N+1's synthesis/tablegen/encode with tensor N's ordered
//!   append over a bounded channel (DESIGN.md §9).
//! - [`shard`] — the MANIFEST format, [`ShardedStoreWriter`] /
//!   [`ShardedStoreReader`], and [`pack_model_zoo_sharded`].
//! - [`reader`] — [`StoreReader`]: lock-free random access over one file
//!   with corruption detection on every read and byte-accounted IO stats.
//! - [`handle`] — [`StoreHandle`], the uniform entry point.
//! - [`cache`] — [`ChunkCache`], the bounded LRU of decoded chunks behind
//!   the readers' hot path, and [`ScratchPool`], the recycled decode
//!   buffers every read path draws from (DESIGN.md §8).
//! - [`live`] — crash-safe mutation: [`StoreAppender`] /
//!   [`ShardedStoreAppender`] commit new tensor versions and tombstones
//!   as atomically-flipped footer generations, and
//!   [`compact_store`]/[`compact_sharded_store`] reclaim superseded
//!   generations (online via `StoreHandle::compact_live`).
//! - [`verify`] — classified, non-bailing corruption sweeps
//!   ([`CorruptionClass`], [`VerifyIssue`], [`verify_store`]).
//!
//! # Durability
//!
//! Mutation follows the commit protocol in **DESIGN.md §14**: body bytes
//! → fsync → new footer generation + trailer → fsync → atomic pointer
//! flip (the `<store>.gen` sidecar for single files, the MANIFEST for
//! sharded directories). A crash at *any* boundary leaves the previous
//! sealed generation the winner on reopen; the kill-point lattice in
//! [`io::FaultPlan`] sweeps every such boundary in the tests. Transient
//! read errors are retried with bounded jittered backoff; permanent
//! chunk corruption is quarantined in the heatmap and classified by
//! [`verify::verify_store`].

pub mod cache;
pub mod format;
pub mod handle;
pub mod heat;
pub mod io;
pub mod live;
pub mod pipeline;
pub mod reader;
pub mod shard;
pub mod verify;
pub mod writer;

pub use cache::{ChunkCache, ScratchPool};
pub use format::{
    crc32, BodyConfig, BodyVersion, ChunkMeta, StoreFormat, StoreIndex, TensorMeta,
};
pub use handle::{StoreHandle, StoreVariant};
pub use heat::{ChunkHeatEntry, HeatMap, TensorHeatSummary};
pub use io::{Backend, ChunkSource, FaultConfig, FaultPlan, FileSource, MmapSource};
pub use live::{
    append_models, compact_sharded_store, compact_store, store_versions, AppendSummary,
    CompactSummary, GenerationInfo, ShardedStoreAppender, StoreAppender,
};
pub use pipeline::PackOptions;
pub use reader::{ReadStats, StoreReader, VerifyReport, DEFAULT_CACHE_VALUES};
pub use verify::{verify_report_json, verify_store, CorruptionClass, VerifyIssue};
pub use shard::{
    pack_model_zoo_sharded, pack_model_zoo_sharded_with, shard_file_name, shard_for_name,
    ShardEntry, ShardManifest, ShardedStoreReader, ShardedStoreSummary, ShardedStoreWriter,
    MANIFEST_FILE,
};
pub use writer::{
    encode_tensor, encode_tensor_with, pack_model_zoo, pack_model_zoo_with, zoo_value_estimate,
    EncodedChunk, EncodedTensor, PackStats, StoreSummary, StoreWriter,
};
