//! Coordinator metrics: bytes in/out, per-tensor records, throughput.


/// Aggregate metrics across all coordinator operations.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorMetrics {
    pub values_compressed: u64,
    pub values_decompressed: u64,
    pub compressed_bits: u64,
    pub tensors_compressed: u64,
    pub tensors_decompressed: u64,
}

impl CoordinatorMetrics {
    pub fn record_compress(&mut self, values: usize, bits: u64) {
        self.values_compressed += values as u64;
        self.compressed_bits += bits;
        self.tensors_compressed += 1;
    }

    pub fn record_decompress(&mut self, values: usize) {
        self.values_decompressed += values as u64;
        self.tensors_decompressed += 1;
    }

    /// Average compressed bits per value.
    pub fn bits_per_value(&self) -> f64 {
        if self.values_compressed == 0 {
            0.0
        } else {
            self.compressed_bits as f64 / self.values_compressed as f64
        }
    }
}

/// A per-tensor record (used by the CLI and the e2e example report).
#[derive(Debug, Clone)]
pub struct TensorMetrics {
    pub name: String,
    pub n_values: u64,
    pub raw_bits: u64,
    pub compressed_bits: u64,
}

impl TensorMetrics {
    pub fn ratio(&self) -> f64 {
        self.raw_bits as f64 / self.compressed_bits.max(1) as f64
    }

    /// Normalized traffic (the paper's Fig 5 quantity): compressed/raw.
    pub fn normalized_traffic(&self) -> f64 {
        self.compressed_bits as f64 / self.raw_bits.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bits_per_value_math() {
        let mut m = CoordinatorMetrics::default();
        m.record_compress(100, 400);
        m.record_compress(100, 200);
        assert!((m.bits_per_value() - 3.0).abs() < 1e-12);
        assert_eq!(m.tensors_compressed, 2);
    }

    #[test]
    fn tensor_metrics_ratios() {
        let t = TensorMetrics {
            name: "w".into(),
            n_values: 1000,
            raw_bits: 8000,
            compressed_bits: 4000,
        };
        assert!((t.ratio() - 2.0).abs() < 1e-12);
        assert!((t.normalized_traffic() - 0.5).abs() < 1e-12);
    }
}
