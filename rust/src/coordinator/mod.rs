//! The L3 coordinator: partitions tensors into independent substreams,
//! drives a pool of software "engines" (one APack encoder/decoder each) in
//! parallel, and keeps the metrics the evaluation consumes.
//!
//! This mirrors the deployment of paper §V-B: the input tensor is split
//! into several subtensors whose streams are encoded/decoded independently
//! by replicated engines; all substreams of a tensor share one probability
//! table.

pub mod metrics;
pub mod pool;

pub use metrics::{CoordinatorMetrics, TensorMetrics};
pub use pool::EnginePool;


use crate::apack::container::{compress_with_table, Container};
use crate::apack::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::apack::{Histogram, SymbolTable};
use crate::error::{Error, Result};

/// A tensor compressed as several independently decodable substreams
/// sharing one table (paper §V-B "Replication").
#[derive(Debug, Clone)]
pub struct ShardedContainer {
    pub table: SymbolTable,
    /// Total value count across shards.
    pub n_values: u64,
    /// Per-shard containers (each with its own symbol/offset streams).
    pub shards: Vec<Container>,
}

impl ShardedContainer {
    /// Total compressed footprint in bits. The table/metadata is charged
    /// once per tensor (shards share it in hardware); per-shard framing
    /// adds a 32-bit length each.
    pub fn footprint_bits(&self) -> u64 {
        let streams: u64 =
            self.shards.iter().map(|s| s.symbol_bits + s.offset_bits + 32).sum();
        streams + (crate::apack::container::META_BYTES as u64) * 8
    }

    /// Compression ratio vs. raw storage.
    pub fn compression_ratio(&self) -> f64 {
        let raw = self.n_values * self.table.bits() as u64;
        raw as f64 / self.footprint_bits() as f64
    }

    /// Binary serialization: `magic | n_values | shard_count | table
    /// (SymbolTable::to_bytes, stored once) | per-shard (len u64 |
    /// Container::body_to_bytes)`.
    ///
    /// The shared table is written exactly once at the sharded level —
    /// matching [`Self::footprint_bits`], which charges the metadata block
    /// once per tensor — instead of duplicating it into every shard.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(&0x4150_5332u32.to_le_bytes()); // "APS2"
        out.extend_from_slice(&self.n_values.to_le_bytes());
        out.extend_from_slice(&(self.shards.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.table.to_bytes());
        for s in &self.shards {
            let b = s.body_to_bytes();
            out.extend_from_slice(&(b.len() as u64).to_le_bytes());
            out.extend_from_slice(&b);
        }
        out
    }

    /// Parse [`Self::to_bytes`] output.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let bad = |m: &str| Error::BadContainer(m.to_string());
        let header = 16 + SymbolTable::SERIALIZED_BYTES;
        if data.len() < header || data[0..4] != 0x4150_5332u32.to_le_bytes() {
            return Err(bad("bad sharded-container header"));
        }
        let n_values = u64::from_le_bytes(data[4..12].try_into().unwrap());
        let count = u32::from_le_bytes(data[12..16].try_into().unwrap()) as usize;
        let table = SymbolTable::from_bytes(&data[16..])?;
        let mut pos = header;
        let mut shards = Vec::with_capacity(count.min(1 << 20));
        for _ in 0..count {
            if pos + 8 > data.len() {
                return Err(bad("truncated shard length"));
            }
            let len = u64::from_le_bytes(data[pos..pos + 8].try_into().unwrap()) as usize;
            pos += 8;
            if pos + len > data.len() {
                return Err(bad("truncated shard body"));
            }
            shards.push(Container::body_from_bytes(table.clone(), &data[pos..pos + len])?);
            pos += len;
        }
        if pos != data.len() {
            return Err(bad(&format!(
                "{} trailing bytes after last shard",
                data.len() - pos
            )));
        }
        let total: u64 = shards.iter().map(|s| s.n_values).sum();
        if total != n_values {
            return Err(bad(&format!("shard value counts sum to {total}, expected {n_values}")));
        }
        Ok(Self { table, n_values, shards })
    }
}

/// How to split a tensor into substreams.
#[derive(Debug, Clone, Copy)]
pub struct PartitionPolicy {
    /// Number of substreams (paper: matches engine replication, 64).
    pub substreams: u32,
    /// Minimum values per substream (tiny tensors use fewer streams).
    pub min_per_stream: usize,
}

impl Default for PartitionPolicy {
    fn default() -> Self {
        Self { substreams: 64, min_per_stream: 1024 }
    }
}

impl PartitionPolicy {
    /// Effective shard count for a tensor length.
    pub fn shards_for(&self, len: usize) -> usize {
        if len == 0 {
            return 1;
        }
        let max_by_min = len.div_ceil(self.min_per_stream).max(1);
        (self.substreams as usize).min(max_by_min)
    }

    /// Split `values` into contiguous chunks, one per shard.
    pub fn split<'v>(&self, values: &'v [u32]) -> Vec<&'v [u32]> {
        let shards = self.shards_for(values.len());
        let per = values.len().div_ceil(shards).max(1);
        values.chunks(per).collect()
    }

    /// Effective number of shard **files** for a store expected to hold
    /// `total_values` values — the same scale-to-content heuristic as
    /// [`Self::shards_for`], lifted one level up: each shard file should
    /// receive enough values to feed a full complement of its own
    /// substreams (`substreams × min_per_stream`), otherwise the requested
    /// count is clamped down. A store too small to fill one file's
    /// substreams still gets one shard.
    pub fn file_shards_for(&self, requested: usize, total_values: u64) -> usize {
        if requested <= 1 {
            return 1;
        }
        let per_file_floor =
            (self.substreams as u64).saturating_mul(self.min_per_stream as u64).max(1);
        let max_by_content = (total_values / per_file_floor).max(1) as usize;
        requested.min(max_by_content)
    }
}

/// Coordinator facade: profile → table → parallel shard encode, and the
/// reverse. Parallelism uses the rayon pool (sized like the engine array
/// in deployment).
pub struct Coordinator {
    pub policy: PartitionPolicy,
    pub metrics: CoordinatorMetrics,
}

impl Coordinator {
    pub fn new(policy: PartitionPolicy) -> Self {
        Self { policy, metrics: CoordinatorMetrics::default() }
    }

    /// Compress a tensor: generate its table from `profile` (or from the
    /// tensor itself if `None`) and encode all shards in parallel.
    pub fn compress(
        &mut self,
        bits: u32,
        values: &[u32],
        kind: TensorKind,
        profile: Option<&Histogram>,
    ) -> Result<ShardedContainer> {
        let table = match profile {
            Some(h) => generate_table(h, kind, &TableGenConfig::for_bits(bits))?,
            None => {
                let h = Histogram::from_values(bits, values);
                generate_table(&h, kind, &TableGenConfig::for_bits(bits))?
            }
        };
        self.compress_with_table(table, values)
    }

    /// Compress with a prebuilt table.
    pub fn compress_with_table(
        &mut self,
        table: SymbolTable,
        values: &[u32],
    ) -> Result<ShardedContainer> {
        let _span = crate::obs::span_n(crate::obs::Stage::Compress, values.len() as u64);
        let chunks = self.policy.split(values);
        let shards: Result<Vec<Container>> =
            crate::util::par_map(&chunks, |chunk| compress_with_table(&table, chunk))
                .into_iter()
                .collect();
        let shards = shards?;
        let sc = ShardedContainer { table, n_values: values.len() as u64, shards };
        self.metrics.record_compress(values.len(), sc.footprint_bits());
        Ok(sc)
    }

    /// Decompress all shards in parallel, each directly into its disjoint
    /// sub-slice of one pre-sized output buffer — no per-shard `Vec`
    /// allocation, no reassembly concat (the software mirror of the
    /// replicated engines all writing one DRAM destination, paper §V-B).
    pub fn decompress(&mut self, sc: &ShardedContainer) -> Result<Vec<u32>> {
        let _span = crate::obs::span_n(crate::obs::Stage::Decompress, sc.n_values);
        let total: u64 = sc.shards.iter().map(|s| s.n_values).sum();
        if total != sc.n_values {
            return Err(Error::BadContainer(format!(
                "shard value counts sum to {total}, expected {}",
                sc.n_values
            )));
        }
        let mut out = vec![0u32; sc.n_values as usize];
        let mut jobs: Vec<(&Container, &mut [u32])> = Vec::with_capacity(sc.shards.len());
        let mut rest: &mut [u32] = &mut out;
        for shard in &sc.shards {
            let (slice, tail) = rest.split_at_mut(shard.n_values as usize);
            rest = tail;
            jobs.push((shard, slice));
        }
        let results: Result<Vec<()>> =
            crate::util::par_map_owned(jobs, |(shard, slice)| shard.decode_into(slice))
                .into_iter()
                .collect();
        results?;
        self.metrics.record_decompress(out.len());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::distributions::ValueProfile;

    fn tensor(n: usize, seed: u64) -> Vec<u32> {
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, seed)
    }

    #[test]
    fn sharded_roundtrip_various_sizes() {
        let mut c = Coordinator::new(PartitionPolicy::default());
        for n in [1usize, 100, 1024, 1025, 100_000] {
            let v = tensor(n, n as u64);
            let sc = c.compress(8, &v, TensorKind::Activations, None).unwrap();
            assert_eq!(c.decompress(&sc).unwrap(), v, "n={n}");
        }
    }

    #[test]
    fn shard_count_respects_policy() {
        let p = PartitionPolicy { substreams: 64, min_per_stream: 1024 };
        assert_eq!(p.shards_for(100), 1);
        assert_eq!(p.shards_for(2048), 2);
        assert_eq!(p.shards_for(1 << 20), 64);
        let v = tensor(1 << 16, 3);
        assert_eq!(p.split(&v).len(), 64);
        // Chunks reassemble exactly.
        let total: usize = p.split(&v).iter().map(|c| c.len()).sum();
        assert_eq!(total, v.len());
    }

    #[test]
    fn file_shard_heuristic_scales_with_content() {
        let p = PartitionPolicy { substreams: 64, min_per_stream: 1024 };
        // 64×1024 = 65536 values fill one shard file's substreams.
        assert_eq!(p.file_shards_for(1, 0), 1);
        assert_eq!(p.file_shards_for(4, 0), 1, "empty store collapses to one shard");
        assert_eq!(p.file_shards_for(4, 65_536), 1);
        assert_eq!(p.file_shards_for(4, 4 * 65_536), 4);
        assert_eq!(p.file_shards_for(4, 1 << 30), 4, "request is the ceiling");
        assert_eq!(p.file_shards_for(8, 3 * 65_536), 3);
    }

    #[test]
    fn profiled_table_applies_to_fresh_data() {
        let mut c = Coordinator::new(PartitionPolicy::default());
        let profile_data = tensor(50_000, 1);
        let fresh = tensor(50_000, 2);
        let h = Histogram::from_values(8, &profile_data);
        let sc = c.compress(8, &fresh, TensorKind::Activations, Some(&h)).unwrap();
        assert_eq!(c.decompress(&sc).unwrap(), fresh);
        assert!(sc.compression_ratio() > 1.2, "ratio {}", sc.compression_ratio());
    }

    #[test]
    fn metrics_accumulate() {
        let mut c = Coordinator::new(PartitionPolicy::default());
        let v = tensor(10_000, 9);
        let sc = c.compress(8, &v, TensorKind::Weights, None).unwrap();
        c.decompress(&sc).unwrap();
        assert_eq!(c.metrics.values_compressed, 10_000);
        assert_eq!(c.metrics.values_decompressed, 10_000);
        assert!(c.metrics.compressed_bits > 0);
    }

    #[test]
    fn serialization_stores_table_once() {
        let v = tensor(1 << 17, 11);
        let mut c = Coordinator::new(PartitionPolicy { substreams: 64, min_per_stream: 1 });
        let sc = c.compress(8, &v, TensorKind::Activations, None).unwrap();
        assert_eq!(sc.shards.len(), 64);
        let bytes = sc.to_bytes();

        // The serialized form now agrees with the footprint model (which
        // charges the table/metadata once per tensor): streams + one table
        // + per-shard framing, NOT 64 copies of the table.
        let stream_bytes: usize =
            sc.shards.iter().map(|s| s.symbols.len() + s.offsets.len()).sum();
        let framing = 16 + SymbolTable::SERIALIZED_BYTES + 32 * sc.shards.len();
        assert_eq!(bytes.len(), stream_bytes + framing);

        // And it is strictly smaller than serializing every shard as a
        // standalone container (the old, table-duplicating layout): the
        // saving is at least one table record per extra shard.
        let duplicated: usize = sc.shards.iter().map(|s| s.to_bytes().len()).sum();
        assert!(
            duplicated - bytes.len()
                >= (sc.shards.len() - 1) * (SymbolTable::SERIALIZED_BYTES - 8),
            "serialized {} vs duplicated {duplicated}",
            bytes.len()
        );

        // Footprint model and serialized size stay within the per-shard
        // framing slack (footprint charges 32 bits/shard vs 32 bytes here).
        let footprint_bytes = (sc.footprint_bits() / 8) as usize;
        let slack = 32 * sc.shards.len() + crate::apack::container::META_BYTES + 64;
        assert!(
            bytes.len().abs_diff(footprint_bytes) <= slack,
            "serialized {} vs footprint {footprint_bytes} (slack {slack})",
            bytes.len()
        );

        let rt = ShardedContainer::from_bytes(&bytes).unwrap();
        let mut c2 = Coordinator::new(PartitionPolicy::default());
        assert_eq!(c2.decompress(&rt).unwrap(), v);

        // Exact-length framing: trailing garbage after the last shard is
        // rejected, same as the body/footer parsers.
        let mut slack = bytes.clone();
        slack.extend_from_slice(&[0u8; 7]);
        assert!(ShardedContainer::from_bytes(&slack).is_err());
    }

    #[test]
    fn sharding_overhead_is_small() {
        // Sharded vs unsharded footprint within 5% for a large tensor.
        let v = tensor(1 << 18, 5);
        let mut c64 = Coordinator::new(PartitionPolicy { substreams: 64, min_per_stream: 1 });
        let mut c1 = Coordinator::new(PartitionPolicy { substreams: 1, min_per_stream: 1 });
        let s64 = c64.compress(8, &v, TensorKind::Activations, None).unwrap();
        let s1 = c1.compress(8, &v, TensorKind::Activations, None).unwrap();
        let ratio = s64.footprint_bits() as f64 / s1.footprint_bits() as f64;
        assert!(ratio < 1.05, "sharding overhead ratio {ratio}");
    }
}
