//! A long-lived engine pool: worker threads each owning a decode/encode
//! "engine", fed through bounded channels with backpressure — the software
//! analogue of the replicated hardware units sitting at the memory
//! controller (paper §V-B), used by the async serving path of the e2e
//! example.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::apack::container::Container;
use crate::error::{Error, Result};

/// A worker's write destination: one disjoint sub-slice of the caller's
/// pre-sized output buffer, passed as a raw region because the pool's
/// workers are long-lived (`'static`) threads that can't hold scoped
/// borrows.
///
/// SAFETY contract (upheld by [`EnginePool::decode_shards`]): regions of
/// concurrently in-flight jobs never overlap, and the buffer they point
/// into outlives every job — the submitter drains one reply per sent job
/// before returning, and a reply is only observable after the worker has
/// finished (or never started) writing.
struct OutRegion {
    ptr: *mut u32,
    len: usize,
}

unsafe impl Send for OutRegion {}

/// A unit of work: decode a shard into its output region (the index is
/// kept for error reporting).
struct Job {
    shard_idx: usize,
    container: Container,
    out: OutRegion,
    reply: mpsc::Sender<(usize, Result<()>)>,
}

/// Fixed pool of decoder workers with a bounded queue (backpressure:
/// submits block when all engines are busy and the queue is full, like the
/// hardware stalling the memory controller).
pub struct EnginePool {
    tx: Option<mpsc::SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    /// Jobs processed (shared counter, for metrics/tests).
    processed: Arc<Mutex<u64>>,
}

impl EnginePool {
    /// Spawn `engines` workers with a queue depth of `queue` jobs.
    pub fn new(engines: usize, queue: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue);
        let rx = Arc::new(Mutex::new(rx));
        let processed = Arc::new(Mutex::new(0u64));
        let workers = (0..engines.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let processed = Arc::clone(&processed);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            // SAFETY: see OutRegion — disjoint region of a
                            // buffer the submitter keeps alive until this
                            // reply is drained.
                            let out = unsafe {
                                std::slice::from_raw_parts_mut(job.out.ptr, job.out.len)
                            };
                            let result = job.container.decode_into(out);
                            *processed.lock().unwrap() += 1;
                            // Receiver may be gone if the caller bailed.
                            let _ = job.reply.send((job.shard_idx, result));
                        }
                        Err(_) => break, // channel closed: shut down
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers, processed }
    }

    /// Decode a set of shards through the pool, each worker writing its
    /// shard directly into the shard's disjoint sub-slice of one pre-sized
    /// output buffer — the shards land in order by construction, with no
    /// per-shard `Vec` and no reassembly concat.
    pub fn decode_shards(&self, shards: &[Container]) -> Result<Vec<u32>> {
        let total: usize = shards.iter().map(|s| s.n_values as usize).sum();
        let mut out = vec![0u32; total];
        let (reply_tx, reply_rx) = mpsc::channel();
        let tx = self.tx.as_ref().expect("pool is live");
        let base = out.as_mut_ptr();
        let mut offset = 0usize;
        let mut sent = 0usize;
        let mut first_err: Option<Error> = None;
        for (i, c) in shards.iter().enumerate() {
            let len = c.n_values as usize;
            // SAFETY: [offset, offset+len) regions are disjoint across
            // jobs and `out` stays alive through the drain loop below.
            let region = OutRegion { ptr: unsafe { base.add(offset) }, len };
            offset += len;
            let job =
                Job { shard_idx: i, container: c.clone(), out: region, reply: reply_tx.clone() };
            if tx.send(job).is_err() {
                first_err = Some(Error::Runtime("engine pool shut down".into()));
                break;
            }
            sent += 1;
        }
        drop(reply_tx);
        // Drain EVERY outstanding reply — even after an error — so no
        // worker still holds a pointer into `out` when we return.
        for _ in 0..sent {
            match reply_rx.recv() {
                Ok((_idx, res)) => {
                    if let Err(e) = res {
                        first_err.get_or_insert(e);
                    }
                }
                // All senders gone: no job (and thus no region pointer)
                // can still be live anywhere.
                Err(_) => {
                    first_err.get_or_insert(Error::Runtime("engine pool workers died".into()));
                    break;
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(out),
        }
    }

    /// Total jobs processed by the pool.
    pub fn processed(&self) -> u64 {
        *self.processed.lock().unwrap()
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // Close the queue, then join workers.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::tablegen::TensorKind;
    use crate::coordinator::{Coordinator, PartitionPolicy};
    use crate::models::distributions::ValueProfile;

    fn sharded(n: usize) -> (Vec<u32>, crate::coordinator::ShardedContainer) {
        let v = ValueProfile::Sparse { sparsity: 0.6, q: 0.85 }.sample(8, n, 11);
        let mut c =
            Coordinator::new(PartitionPolicy { substreams: 16, min_per_stream: 256 });
        let sc = c.compress(8, &v, TensorKind::Weights, None).unwrap();
        (v, sc)
    }

    #[test]
    fn pool_decodes_in_order() {
        let (v, sc) = sharded(50_000);
        let pool = EnginePool::new(8, 32);
        let got = pool.decode_shards(&sc.shards).unwrap();
        assert_eq!(got, v);
        assert_eq!(pool.processed() as usize, sc.shards.len());
    }

    #[test]
    fn pool_survives_multiple_batches() {
        let pool = EnginePool::new(4, 8);
        for n in [1000usize, 5000, 20_000] {
            let (v, sc) = sharded(n);
            assert_eq!(pool.decode_shards(&sc.shards).unwrap(), v);
        }
    }

    #[test]
    fn single_engine_pool_works() {
        let (v, sc) = sharded(10_000);
        let pool = EnginePool::new(1, 1);
        assert_eq!(pool.decode_shards(&sc.shards).unwrap(), v);
    }
}
