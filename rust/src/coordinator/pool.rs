//! A long-lived engine pool: worker threads each owning a decode/encode
//! "engine", fed through bounded channels with backpressure — the software
//! analogue of the replicated hardware units sitting at the memory
//! controller (paper §V-B), used by the async serving path of the e2e
//! example.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::apack::container::Container;
use crate::error::{Error, Result};

/// A unit of work: decode a shard (identified by its index so results can
/// be reassembled in order).
struct Job {
    shard_idx: usize,
    container: Container,
    reply: mpsc::Sender<(usize, Result<Vec<u32>>)>,
}

/// Fixed pool of decoder workers with a bounded queue (backpressure:
/// submits block when all engines are busy and the queue is full, like the
/// hardware stalling the memory controller).
pub struct EnginePool {
    tx: Option<mpsc::SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    /// Jobs processed (shared counter, for metrics/tests).
    processed: Arc<Mutex<u64>>,
}

impl EnginePool {
    /// Spawn `engines` workers with a queue depth of `queue` jobs.
    pub fn new(engines: usize, queue: usize) -> Self {
        let (tx, rx) = mpsc::sync_channel::<Job>(queue);
        let rx = Arc::new(Mutex::new(rx));
        let processed = Arc::new(Mutex::new(0u64));
        let workers = (0..engines.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let processed = Arc::clone(&processed);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            let result = job.container.decode();
                            *processed.lock().unwrap() += 1;
                            // Receiver may be gone if the caller bailed.
                            let _ = job.reply.send((job.shard_idx, result));
                        }
                        Err(_) => break, // channel closed: shut down
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers, processed }
    }

    /// Decode a set of shards through the pool, reassembling in order.
    pub fn decode_shards(&self, shards: &[Container]) -> Result<Vec<u32>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        let tx = self.tx.as_ref().expect("pool is live");
        for (i, c) in shards.iter().enumerate() {
            tx.send(Job { shard_idx: i, container: c.clone(), reply: reply_tx.clone() })
                .map_err(|_| Error::Runtime("engine pool shut down".into()))?;
        }
        drop(reply_tx);
        let mut parts: Vec<Option<Vec<u32>>> = vec![None; shards.len()];
        for _ in 0..shards.len() {
            let (idx, res) = reply_rx
                .recv()
                .map_err(|_| Error::Runtime("engine pool workers died".into()))?;
            parts[idx] = Some(res?);
        }
        let mut out = Vec::new();
        for p in parts {
            out.extend(p.expect("all shards replied"));
        }
        Ok(out)
    }

    /// Total jobs processed by the pool.
    pub fn processed(&self) -> u64 {
        *self.processed.lock().unwrap()
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        // Close the queue, then join workers.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::tablegen::TensorKind;
    use crate::coordinator::{Coordinator, PartitionPolicy};
    use crate::models::distributions::ValueProfile;

    fn sharded(n: usize) -> (Vec<u32>, crate::coordinator::ShardedContainer) {
        let v = ValueProfile::Sparse { sparsity: 0.6, q: 0.85 }.sample(8, n, 11);
        let mut c =
            Coordinator::new(PartitionPolicy { substreams: 16, min_per_stream: 256 });
        let sc = c.compress(8, &v, TensorKind::Weights, None).unwrap();
        (v, sc)
    }

    #[test]
    fn pool_decodes_in_order() {
        let (v, sc) = sharded(50_000);
        let pool = EnginePool::new(8, 32);
        let got = pool.decode_shards(&sc.shards).unwrap();
        assert_eq!(got, v);
        assert_eq!(pool.processed() as usize, sc.shards.len());
    }

    #[test]
    fn pool_survives_multiple_batches() {
        let pool = EnginePool::new(4, 8);
        for n in [1000usize, 5000, 20_000] {
            let (v, sc) = sharded(n);
            assert_eq!(pool.decode_shards(&sc.shards).unwrap(), v);
        }
    }

    #[test]
    fn single_engine_pool_works() {
        let (v, sc) = sharded(10_000);
        let pool = EnginePool::new(1, 1);
        assert_eq!(pool.decode_shards(&sc.shards).unwrap(), v);
    }
}
