//! The APack codec (paper §IV–§VI).
//!
//! Submodules:
//! - [`bitstream`] — MSB-first bit reader/writer used by both streams.
//! - [`table`] — the 16-row symbol + probability-count table.
//! - [`encoder`] / [`decoder`] — the finite-precision arithmetic coder
//!   modelled exactly on the hardware of paper §V (16-bit HI/LO windows,
//!   underflow-bit counter, 10-bit counts, 16×10 multiply dropping the low
//!   10 bits).
//! - [`histogram`] — value histograms and CDFs (Fig 2).
//! - [`tablegen`] — the heuristic table search of paper §VI (Listing 1).
//! - [`container`] — the on-"disk"/on-DRAM representation: metadata + the
//!   two streams, with substream framing for parallel engines.
//! - [`lanes`] — chunk body **v2**: N independent per-chunk substreams
//!   sharing one table, with struct-of-arrays and threaded lane-parallel
//!   decode (DESIGN.md §11).
//! - [`simd`] — the SIMD lane-parallel decode kernel behind both v2
//!   decode paths: runtime-dispatched AVX2/SSE2/NEON tiers over a shared
//!   round-major driver, scalar fallback pinned bit-identical
//!   (DESIGN.md §13).

pub mod bitserial;
pub mod bitstream;
pub mod container;
pub mod decoder;
pub mod encoder;
pub mod histogram;
pub mod lanes;
pub mod simd;
pub mod table;
pub mod tablegen;

pub use container::{compress, decompress, encode_body, BodyView, Container};
pub use lanes::{
    encode_body_v2, lane_count, lane_range, BodyV2View, DEFAULT_LANES, MAX_LANES,
    MIN_VALUES_PER_LANE,
};
pub use decoder::{ApackDecoder, ResolveMode};
pub use encoder::ApackEncoder;
pub use simd::{decode_jobs, DecodeKernel, LaneJob};
pub use histogram::Histogram;
pub use table::{SymbolTable, TableRow, PROB_BITS, PROB_MAX};
pub use tablegen::{generate_table, generate_table_seed, TableGenConfig, TensorKind};

/// Number of rows in the symbol / probability-count tables. The paper found
/// 16 sufficient across 4-, 8- and 16-bit models (§IV).
pub const NUM_ROWS: usize = 16;
