//! SIMD lane-parallel decode kernel for chunk body v2 (DESIGN.md §13).
//!
//! Body v2 (see [`super::lanes`]) splits a chunk into N independent arithmetic-coded
//! substreams. The SoA decoder retires one lane-step per scalar iteration; this module
//! packs K lanes' decoder state (HI/LO/CODE as u16 registers widened to u32 vector
//! lanes) and advances all K per iteration.
//!
//! ## Structure
//!
//! [`decode_jobs`] is the one round-major driver shared by every kernel tier: each
//! round advances every still-active lane by one value. Per round, a *classify* step
//! computes the count `k = ((d + 1) << PROB_BITS - 1) / range` for a block of lanes at
//! once (this is the expensive part: a 32-bit division per lane), and a *completion*
//! step runs per lane **in lane order**: corrupt-count check, LUT row resolution,
//! range narrowing, offset-bit splice, value-range check, and the renormalization
//! loop that shifts fresh bits into CODE. Completion is the only step that touches the
//! per-lane bit cursors, so its strict lane ordering makes every tier consume bits in
//! exactly the same sequence as the scalar loop.
//!
//! ## Divergence handling
//!
//! Lanes diverge two ways inside a round: a lane's count can exceed `PROB_MAX`
//! (corrupt stream), and a lane may or may not need renormalization. Both are resolved
//! movemask-style: the wide classify step emits per-lane bitmasks (`_mm256_movemask_ps`
//! over the comparison results) and the completion loop branches per lane on its mask
//! bit. Corrupt counts are clamped to `PROB_MAX` before the LUT gather (the LUT's last
//! slot is a valid sentinel row), so the gather itself never reads out of bounds; the
//! corrupt lane then fails in lane order, yielding the same `CorruptStream` position as
//! the scalar loop.
//!
//! ## Bit-exactness
//!
//! The only vectorized arithmetic that could diverge from the scalar loop is the count
//! division, computed here in f64. It is exact: `num = ((d + 1) << 10) - 1 < 2^26` and
//! `range ∈ (2^14, 2^16]` are both exactly representable, the true quotient is at
//! distance ≥ 1/range ≥ 2^-16 from the nearest wrong integer, and the f64 rounding
//! error of one division of such operands is < 2^-27 — so truncating the f64 quotient
//! equals the integer division for every reachable operand pair, including corrupt
//! streams (pinned by an exhaustive-grid test below). Everything else is u16/u32
//! arithmetic identical to the scalar loop, and bit consumption order is fixed by the
//! lane-ordered completion step. The `range > 2^14` lower bound holds on *all* inputs
//! (even corrupt ones) because the renorm loop only exits with `hi - lo + 1 > 2^14`.
//!
//! ## Dispatch
//!
//! [`DecodeKernel::auto`] honors `APACK_DECODE_KERNEL=scalar|simd` (default `simd`);
//! the SIMD path then picks an ISA tier at runtime: AVX2 (8-wide classify with LUT
//! gathers) via `is_x86_feature_detected!`, else SSE2 (4 lanes, paired f64 divisions —
//! baseline on x86_64), NEON on aarch64 (4 lanes, paired f64 divisions), and the
//! scalar loop everywhere else and for trailing lanes. The scalar fallback is pinned
//! bit-identical by property tests and a forced-scalar CI leg.

use std::sync::OnceLock;

use super::bitstream::BitReader;
use super::lanes::MAX_LANES;
#[cfg(target_arch = "x86_64")]
use super::table::COUNT_LUT_LEN;
use super::table::{SymbolTable, PROB_BITS, PROB_MAX};
use super::NUM_ROWS;
use crate::error::{Error, Result};

const LANE_SLOTS: usize = MAX_LANES as usize;
#[cfg(target_arch = "x86_64")]
const LANES_AVX2: usize = 8;
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
const LANES_PAIR: usize = 4;
const TOP_BIT: u16 = 0x8000;
const SECOND_BIT: u16 = 0x4000;

/// Which decode kernel family to run. `Scalar` is the SoA reference loop; `Simd`
/// dispatches to the best ISA tier detected at runtime (and degrades to the scalar
/// loop on architectures without a tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeKernel {
    Scalar,
    Simd,
}

impl DecodeKernel {
    /// Process-wide default: `APACK_DECODE_KERNEL=scalar` forces the scalar loop,
    /// anything else (including unset) selects SIMD with runtime detection.
    pub fn auto() -> Self {
        static CHOICE: OnceLock<DecodeKernel> = OnceLock::new();
        *CHOICE.get_or_init(|| match std::env::var("APACK_DECODE_KERNEL") {
            Ok(v) if v.eq_ignore_ascii_case("scalar") => DecodeKernel::Scalar,
            _ => DecodeKernel::Simd,
        })
    }

    pub fn from_name(name: &str) -> Option<Self> {
        if name.eq_ignore_ascii_case("scalar") {
            Some(DecodeKernel::Scalar)
        } else if name.eq_ignore_ascii_case("simd") {
            Some(DecodeKernel::Simd)
        } else {
            None
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DecodeKernel::Scalar => "scalar",
            DecodeKernel::Simd => "simd",
        }
    }

    /// The label of the loop that will actually run: `scalar`, or the detected ISA
    /// tier (`avx2`/`sse2`/`neon`, degrading to `scalar` off x86_64/aarch64).
    pub fn active_label(self) -> &'static str {
        match self {
            DecodeKernel::Scalar => "scalar",
            DecodeKernel::Simd => active_isa().label(),
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Isa {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Sse2,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

impl Isa {
    fn label(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            #[cfg(target_arch = "x86_64")]
            Isa::Sse2 => "sse2",
            #[cfg(target_arch = "x86_64")]
            Isa::Avx2 => "avx2",
            #[cfg(target_arch = "aarch64")]
            Isa::Neon => "neon",
        }
    }
}

fn active_isa() -> Isa {
    static ISA: OnceLock<Isa> = OnceLock::new();
    *ISA.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2") {
                Isa::Avx2
            } else {
                Isa::Sse2
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            Isa::Neon
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            Isa::Scalar
        }
    })
}

/// One lane's decode work: its symbol and offset bit cursors, the output sub-slice it
/// fills, and the absolute index of its first value (for `CorruptStream` positions).
pub struct LaneJob<'d, 'o> {
    pub sym: BitReader<'d>,
    pub ofs: BitReader<'d>,
    pub out: &'o mut [u32],
    pub base: usize,
}

/// u32-widened LUTs for the AVX2 gather path: `row_of_k32[k]` is the row index for
/// count `k` (last slot is the row-15 sentinel reached only by clamped corrupt
/// counts), `cum32[i]` the cumulative count below row `i`.
#[cfg(target_arch = "x86_64")]
struct SimdLuts {
    row_of_k32: [u32; COUNT_LUT_LEN],
    cum32: [u32; NUM_ROWS + 1],
}

#[cfg(target_arch = "x86_64")]
impl SimdLuts {
    fn build(table: &SymbolTable) -> Self {
        let mut row_of_k32 = [0u32; COUNT_LUT_LEN];
        for (k, slot) in row_of_k32.iter_mut().enumerate() {
            *slot = table.row_for_count(k as u16) as u32;
        }
        let mut cum32 = [0u32; NUM_ROWS + 1];
        for i in 0..NUM_ROWS {
            cum32[i + 1] = table.rows()[i].hi_cnt as u32;
        }
        Self { row_of_k32, cum32 }
    }
}

/// Decode every job to completion, round-major: each round advances every lane whose
/// output still has a value to fill. Jobs must be ordered by non-increasing output
/// length (true for `lane_range` partitions and any contiguous subset of them), so the
/// active set each round is a prefix.
///
/// All kernel tiers consume each lane's bit streams in the same order and report the
/// same `CorruptStream { position: base + round }` for the first failing lane in
/// (round, lane) order.
pub fn decode_jobs(
    kernel: DecodeKernel,
    table: &SymbolTable,
    jobs: &mut [LaneJob<'_, '_>],
) -> Result<()> {
    let lanes = jobs.len();
    if lanes == 0 {
        return Ok(());
    }
    debug_assert!(lanes <= LANE_SLOTS);
    debug_assert!(jobs.windows(2).all(|w| w[0].out.len() >= w[1].out.len()));

    let mut cum = [0u16; NUM_ROWS + 1];
    for i in 0..NUM_ROWS {
        cum[i + 1] = table.rows()[i].hi_cnt;
    }
    debug_assert_eq!(cum[NUM_ROWS], PROB_MAX);

    let mut hi = [0xFFFFu16; LANE_SLOTS];
    let mut lo = [0u16; LANE_SLOTS];
    let mut code = [0u16; LANE_SLOTS];
    for (l, j) in jobs.iter_mut().enumerate() {
        code[l] = j.sym.read_bits(16) as u16;
    }

    let isa = match kernel {
        DecodeKernel::Scalar => Isa::Scalar,
        DecodeKernel::Simd => active_isa(),
    };
    #[cfg(target_arch = "x86_64")]
    let luts = if isa == Isa::Avx2 {
        Some(SimdLuts::build(table))
    } else {
        None
    };

    let max_len = jobs.iter().map(|j| j.out.len()).max().unwrap_or(0);
    for round in 0..max_len {
        let active = jobs.iter().take_while(|j| j.out.len() > round).count();
        let mut l = 0usize;
        #[cfg(target_arch = "x86_64")]
        {
            if isa == Isa::Avx2 {
                let luts = luts.as_ref().expect("AVX2 LUTs built at dispatch");
                while l + LANES_AVX2 <= active {
                    // SAFETY: Isa::Avx2 is only selected when
                    // is_x86_feature_detected!("avx2") held.
                    let fail = unsafe {
                        step8_avx2(table, luts, jobs, l, round, &mut hi, &mut lo, &mut code)
                    };
                    if let Some(bad) = fail {
                        return Err(Error::CorruptStream {
                            position: jobs[bad].base + round,
                        });
                    }
                    l += LANES_AVX2;
                }
            } else if isa == Isa::Sse2 {
                while l + LANES_PAIR <= active {
                    // SAFETY: SSE2 is part of the x86_64 baseline.
                    let fail = unsafe {
                        step4_sse2(table, &cum, jobs, l, round, &mut hi, &mut lo, &mut code)
                    };
                    if let Some(bad) = fail {
                        return Err(Error::CorruptStream {
                            position: jobs[bad].base + round,
                        });
                    }
                    l += LANES_PAIR;
                }
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if isa == Isa::Neon {
                while l + LANES_PAIR <= active {
                    // SAFETY: NEON is part of the aarch64 baseline.
                    let fail = unsafe {
                        step4_neon(table, &cum, jobs, l, round, &mut hi, &mut lo, &mut code)
                    };
                    if let Some(bad) = fail {
                        return Err(Error::CorruptStream {
                            position: jobs[bad].base + round,
                        });
                    }
                    l += LANES_PAIR;
                }
            }
        }
        while l < active {
            let j = &mut jobs[l];
            let ok = lane_step(
                table,
                &cum,
                &mut hi[l],
                &mut lo[l],
                &mut code[l],
                &mut j.sym,
                &mut j.ofs,
                &mut j.out[round],
            );
            if !ok {
                return Err(Error::CorruptStream {
                    position: jobs[l].base + round,
                });
            }
            l += 1;
        }
    }
    Ok(())
}

/// One scalar lane-step: classify (count division) + completion. Bit-identical to the
/// pre-SIMD SoA loop; the SIMD tiers replace only the classify half.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn lane_step(
    table: &SymbolTable,
    cum: &[u16; NUM_ROWS + 1],
    hi: &mut u16,
    lo: &mut u16,
    code: &mut u16,
    sym_in: &mut BitReader<'_>,
    ofs_in: &mut BitReader<'_>,
    slot: &mut u32,
) -> bool {
    let range = (*hi - *lo) as u32 + 1;
    let d = code.wrapping_sub(*lo) as u32;
    let k = (((d + 1) << PROB_BITS) - 1) / range;
    finish_from_k(table, cum, k, hi, lo, code, sym_in, ofs_in, slot)
}

/// Completion from a precomputed count `k`: corrupt check, LUT row, range narrowing,
/// then [`complete_lane`]. Shared by the scalar loop and the pair-division tiers.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn finish_from_k(
    table: &SymbolTable,
    cum: &[u16; NUM_ROWS + 1],
    k: u32,
    hi: &mut u16,
    lo: &mut u16,
    code: &mut u16,
    sym_in: &mut BitReader<'_>,
    ofs_in: &mut BitReader<'_>,
    slot: &mut u32,
) -> bool {
    if k >= cum[NUM_ROWS] as u32 {
        return false;
    }
    let idx = table.row_for_count(k as u16);
    let range = (*hi - *lo) as u32 + 1;
    let s_lo = (range * cum[idx] as u32) >> PROB_BITS;
    let s_hi = (range * cum[idx + 1] as u32) >> PROB_BITS;
    let nh0 = (*lo as u32 + s_hi - 1) as u16;
    let nl0 = (*lo as u32 + s_lo) as u16;
    complete_lane(table, idx, nh0, nl0, true, hi, lo, code, sym_in, ofs_in, slot)
}

/// Offset splice, value-range check, and the renormalization loop; writes the lane's
/// new HI/LO/CODE back. `needs_renorm` lets the AVX2 tier skip the loop entry for
/// lanes its movemask proved converged (the loop would exit immediately anyway —
/// skipping it is a pure branch elision, not an arithmetic change).
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn complete_lane(
    table: &SymbolTable,
    idx: usize,
    nh0: u16,
    nl0: u16,
    needs_renorm: bool,
    hi: &mut u16,
    lo: &mut u16,
    code: &mut u16,
    sym_in: &mut BitReader<'_>,
    ofs_in: &mut BitReader<'_>,
    slot: &mut u32,
) -> bool {
    let row = &table.rows()[idx];
    let value = if row.ol > 0 {
        if ofs_in.bits_remaining() < row.ol as usize {
            return false;
        }
        row.v_min + ofs_in.read_bits(row.ol) as u32
    } else {
        row.v_min
    };
    if value > row.v_max {
        return false;
    }
    *slot = value;
    let mut nh = nh0;
    let mut nl = nl0;
    let mut nc = *code;
    if needs_renorm {
        loop {
            let diff = nh ^ nl;
            if diff & TOP_BIT == 0 {
                let k = (diff as u32 | 1).leading_zeros() - 16;
                nl <<= k;
                nh = (nh << k) | ((1u32 << k) as u16).wrapping_sub(1);
                nc = (nc << k) | sym_in.read_bits(k) as u16;
            } else if nl & SECOND_BIT != 0 && nh & SECOND_BIT == 0 {
                nc = ((nc ^ SECOND_BIT) << 1) | sym_in.read_bit() as u16;
                nl = (nl & (SECOND_BIT - 1)) << 1;
                nh = ((nh | SECOND_BIT) << 1) | 1;
            } else {
                break;
            }
        }
    }
    *hi = nh;
    *lo = nl;
    *code = nc;
    true
}

/// AVX2 tier: classify 8 lanes at once — widen HI/LO/CODE to 32-bit vector lanes,
/// compute the count division in two f64 halves, gather row indices and cumulative
/// counts, narrow the ranges, and derive corrupt/renorm movemasks — then complete the
/// 8 lanes in lane order with the slim scalar tail.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn step8_avx2(
    table: &SymbolTable,
    luts: &SimdLuts,
    jobs: &mut [LaneJob<'_, '_>],
    l0: usize,
    round: usize,
    hi: &mut [u16; LANE_SLOTS],
    lo: &mut [u16; LANE_SLOTS],
    code: &mut [u16; LANE_SLOTS],
) -> Option<usize> {
    use std::arch::x86_64::*;

    let hi_v = _mm256_cvtepu16_epi32(_mm_loadu_si128(hi[l0..].as_ptr() as *const __m128i));
    let lo_v = _mm256_cvtepu16_epi32(_mm_loadu_si128(lo[l0..].as_ptr() as *const __m128i));
    let code_v = _mm256_cvtepu16_epi32(_mm_loadu_si128(code[l0..].as_ptr() as *const __m128i));

    let one = _mm256_set1_epi32(1);
    let m16 = _mm256_set1_epi32(0xFFFF);
    let range = _mm256_add_epi32(_mm256_sub_epi32(hi_v, lo_v), one);
    let d = _mm256_and_si256(_mm256_sub_epi32(code_v, lo_v), m16);
    let dp1 = _mm256_add_epi32(d, one);
    let num = _mm256_sub_epi32(_mm256_slli_epi32::<{ PROB_BITS as i32 }>(dp1), one);

    // Exact f64 division per the module-level proof; truncation == integer division.
    let num_lo = _mm256_cvtepi32_pd(_mm256_castsi256_si128(num));
    let num_hi = _mm256_cvtepi32_pd(_mm256_extracti128_si256::<1>(num));
    let range_lo = _mm256_cvtepi32_pd(_mm256_castsi256_si128(range));
    let range_hi = _mm256_cvtepi32_pd(_mm256_extracti128_si256::<1>(range));
    let k_lo = _mm256_cvttpd_epi32(_mm256_div_pd(num_lo, range_lo));
    let k_hi = _mm256_cvttpd_epi32(_mm256_div_pd(num_hi, range_hi));
    let k = _mm256_inserti128_si256::<1>(_mm256_castsi128_si256(k_lo), k_hi);

    let prob_max = _mm256_set1_epi32(PROB_MAX as i32);
    let corrupt = _mm256_cmpgt_epi32(k, _mm256_sub_epi32(prob_max, one));
    let corrupt_mask = _mm256_movemask_ps(_mm256_castsi256_ps(corrupt)) as u32;
    // Clamp before the gather so corrupt counts read the valid sentinel slot.
    let kc = _mm256_min_epi32(k, prob_max);
    let idx = _mm256_i32gather_epi32::<4>(luts.row_of_k32.as_ptr() as *const i32, kc);
    let cum_lo = _mm256_i32gather_epi32::<4>(luts.cum32.as_ptr() as *const i32, idx);
    let cum_hi =
        _mm256_i32gather_epi32::<4>(luts.cum32.as_ptr() as *const i32, _mm256_add_epi32(idx, one));
    let s_lo = _mm256_srli_epi32::<{ PROB_BITS as i32 }>(_mm256_mullo_epi32(range, cum_lo));
    let s_hi = _mm256_srli_epi32::<{ PROB_BITS as i32 }>(_mm256_mullo_epi32(range, cum_hi));
    let nh = _mm256_and_si256(_mm256_sub_epi32(_mm256_add_epi32(lo_v, s_hi), one), m16);
    let nl = _mm256_and_si256(_mm256_add_epi32(lo_v, s_lo), m16);

    let top = _mm256_set1_epi32(TOP_BIT as i32);
    let second = _mm256_set1_epi32(SECOND_BIT as i32);
    let zero = _mm256_setzero_si256();
    let diff_top = _mm256_and_si256(_mm256_xor_si256(nh, nl), top);
    let shift_needed = _mm256_cmpeq_epi32(diff_top, zero);
    let nl_second = _mm256_cmpeq_epi32(_mm256_and_si256(nl, second), second);
    let nh_second = _mm256_cmpeq_epi32(_mm256_and_si256(nh, second), zero);
    let renorm = _mm256_or_si256(shift_needed, _mm256_and_si256(nl_second, nh_second));
    let renorm_mask = _mm256_movemask_ps(_mm256_castsi256_ps(renorm)) as u32;

    let mut idx_a = [0u32; LANES_AVX2];
    let mut nh_a = [0u32; LANES_AVX2];
    let mut nl_a = [0u32; LANES_AVX2];
    _mm256_storeu_si256(idx_a.as_mut_ptr() as *mut __m256i, idx);
    _mm256_storeu_si256(nh_a.as_mut_ptr() as *mut __m256i, nh);
    _mm256_storeu_si256(nl_a.as_mut_ptr() as *mut __m256i, nl);

    for i in 0..LANES_AVX2 {
        let l = l0 + i;
        if corrupt_mask & (1 << i) != 0 {
            return Some(l);
        }
        let j = &mut jobs[l];
        let ok = complete_lane(
            table,
            idx_a[i] as usize,
            nh_a[i] as u16,
            nl_a[i] as u16,
            renorm_mask & (1 << i) != 0,
            &mut hi[l],
            &mut lo[l],
            &mut code[l],
            &mut j.sym,
            &mut j.ofs,
            &mut j.out[round],
        );
        if !ok {
            return Some(l);
        }
    }
    None
}

/// SSE2 tier: vectorize only the count division (two `_mm_div_pd` pairs for 4 lanes);
/// everything else runs through [`finish_from_k`]. SSE2 lacks the 32-bit gather and
/// multiply primitives the AVX2 tier leans on, so the division — the long-latency op —
/// is the only profitable vector piece.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn step4_sse2(
    table: &SymbolTable,
    cum: &[u16; NUM_ROWS + 1],
    jobs: &mut [LaneJob<'_, '_>],
    l0: usize,
    round: usize,
    hi: &mut [u16; LANE_SLOTS],
    lo: &mut [u16; LANE_SLOTS],
    code: &mut [u16; LANE_SLOTS],
) -> Option<usize> {
    use std::arch::x86_64::*;

    let mut k = [0u32; LANES_PAIR];
    for p in 0..2 {
        let a = l0 + p * 2;
        let b = a + 1;
        let r0 = (hi[a] - lo[a]) as u32 + 1;
        let r1 = (hi[b] - lo[b]) as u32 + 1;
        let n0 = ((code[a].wrapping_sub(lo[a]) as u32 + 1) << PROB_BITS) - 1;
        let n1 = ((code[b].wrapping_sub(lo[b]) as u32 + 1) << PROB_BITS) - 1;
        let q = _mm_div_pd(_mm_set_pd(n1 as f64, n0 as f64), _mm_set_pd(r1 as f64, r0 as f64));
        let ki = _mm_cvttpd_epi32(q);
        k[p * 2] = _mm_cvtsi128_si32(ki) as u32;
        k[p * 2 + 1] = _mm_cvtsi128_si32(_mm_shuffle_epi32::<0x55>(ki)) as u32;
    }
    for (i, ki) in k.iter().enumerate() {
        let l = l0 + i;
        let j = &mut jobs[l];
        let ok = finish_from_k(
            table,
            cum,
            *ki,
            &mut hi[l],
            &mut lo[l],
            &mut code[l],
            &mut j.sym,
            &mut j.ofs,
            &mut j.out[round],
        );
        if !ok {
            return Some(l);
        }
    }
    None
}

/// NEON tier: same shape as [`step4_sse2`] — paired f64 divisions (FCVTZU truncates,
/// matching integer division per the exactness proof), scalar completion.
#[cfg(target_arch = "aarch64")]
#[allow(clippy::too_many_arguments)]
unsafe fn step4_neon(
    table: &SymbolTable,
    cum: &[u16; NUM_ROWS + 1],
    jobs: &mut [LaneJob<'_, '_>],
    l0: usize,
    round: usize,
    hi: &mut [u16; LANE_SLOTS],
    lo: &mut [u16; LANE_SLOTS],
    code: &mut [u16; LANE_SLOTS],
) -> Option<usize> {
    use std::arch::aarch64::*;

    let mut k = [0u32; LANES_PAIR];
    for p in 0..2 {
        let a = l0 + p * 2;
        let b = a + 1;
        let r = [
            ((hi[a] - lo[a]) as u32 + 1) as f64,
            ((hi[b] - lo[b]) as u32 + 1) as f64,
        ];
        let n = [
            ((((code[a].wrapping_sub(lo[a]) as u32) + 1) << PROB_BITS) - 1) as f64,
            ((((code[b].wrapping_sub(lo[b]) as u32) + 1) << PROB_BITS) - 1) as f64,
        ];
        let q = vdivq_f64(vld1q_f64(n.as_ptr()), vld1q_f64(r.as_ptr()));
        let ki = vcvtq_u64_f64(q);
        k[p * 2] = vgetq_lane_u64::<0>(ki) as u32;
        k[p * 2 + 1] = vgetq_lane_u64::<1>(ki) as u32;
    }
    for (i, ki) in k.iter().enumerate() {
        let l = l0 + i;
        let j = &mut jobs[l];
        let ok = finish_from_k(
            table,
            cum,
            *ki,
            &mut hi[l],
            &mut lo[l],
            &mut code[l],
            &mut j.sym,
            &mut j.ofs,
            &mut j.out[round],
        );
        if !ok {
            return Some(l);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::lanes::{encode_body_v2, BodyV2View};
    use crate::apack::tablegen::{table_for_tensor, TensorKind};
    use crate::models::distributions::ValueProfile;

    #[test]
    fn kernel_parsing_and_labels() {
        assert_eq!(DecodeKernel::from_name("scalar"), Some(DecodeKernel::Scalar));
        assert_eq!(DecodeKernel::from_name("SIMD"), Some(DecodeKernel::Simd));
        assert_eq!(DecodeKernel::from_name("gpu"), None);
        assert_eq!(DecodeKernel::Scalar.name(), "scalar");
        assert_eq!(DecodeKernel::Simd.name(), "simd");
        assert_eq!(DecodeKernel::Scalar.active_label(), "scalar");
        let simd = DecodeKernel::Simd.active_label();
        assert!(
            ["scalar", "sse2", "avx2", "neon"].contains(&simd),
            "unexpected ISA label {simd}"
        );
    }

    /// Pins the module-level exactness claim: truncated f64 division equals integer
    /// division for every reachable (num, range) shape, sweeping a dense grid plus
    /// the edge rows of each range.
    #[test]
    fn f64_division_is_exact_for_all_reachable_operands() {
        let mut checked = 0u64;
        let mut range = (1u32 << 14) + 1;
        while range <= 1 << 16 {
            let edge = [0u32, 1, (range - 1) & 0xFFFF, 0xFFFE, 0xFFFF];
            for d in (0..=0xFFFFu32).step_by(131).chain(edge) {
                let num = ((d + 1) << PROB_BITS) - 1;
                let f = (num as f64 / range as f64) as u32;
                assert_eq!(f, num / range, "num={num} range={range}");
                checked += 1;
            }
            range += 7;
        }
        assert!(checked > 1_000_000);
    }

    #[test]
    fn simd_and_scalar_kernels_agree_on_a_smoke_tensor() {
        let values = ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, 40_000, 77);
        let table = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
        let body = encode_body_v2(&table, &values, 16).unwrap();
        let view = BodyV2View::parse(&body).unwrap();
        for kernel in [DecodeKernel::Scalar, DecodeKernel::Simd] {
            let mut out = vec![0u32; values.len()];
            view.decode_into_with(&table, &mut out, kernel).unwrap();
            assert_eq!(out, values, "kernel {:?}", kernel);
        }
    }
}
