//! The on-DRAM representation of a compressed tensor (paper §IV):
//! metadata (value count + the range/probability tables, quoted at 298
//! bytes) plus the two independent streams — arithmetically coded symbols
//! and verbatim offsets. Both streams are read/written sequentially, which
//! is what makes the scheme DRAM-friendly.


use super::bitstream::BitReader;
use super::decoder::ApackDecoder;
use super::encoder::ApackEncoder;
use super::table::SymbolTable;
use super::tablegen::{generate_table, TableGenConfig, TensorKind};
use crate::error::{Error, Result};

/// Metadata footprint charged per tensor in footprint accounting (paper
/// §IV: range table + probability table + symbol count = 298 bytes).
pub const META_BYTES: usize = 298;

/// A compressed tensor: the symbol/offset streams plus enough metadata to
/// reverse them.
#[derive(Debug, Clone)]
pub struct Container {
    /// The per-tensor table (part of the metadata block in hardware).
    pub table: SymbolTable,
    /// Number of encoded values (terminates decoding, paper §IV).
    pub n_values: u64,
    /// Arithmetically coded symbol stream.
    pub symbols: Vec<u8>,
    /// Exact bit length of `symbols`.
    pub symbol_bits: u64,
    /// Verbatim offset stream.
    pub offsets: Vec<u8>,
    /// Exact bit length of `offsets`.
    pub offset_bits: u64,
}

impl Container {
    /// Total compressed footprint in **bits**, including the 298-byte
    /// metadata block the paper charges per tensor.
    pub fn footprint_bits(&self) -> u64 {
        self.symbol_bits + self.offset_bits + (META_BYTES as u64) * 8
    }

    /// Compression ratio versus storing `n_values` at `bits` each.
    pub fn compression_ratio(&self) -> f64 {
        let raw = self.n_values * self.table.bits() as u64;
        raw as f64 / self.footprint_bits() as f64
    }

    /// Decode the full tensor into a fresh vector.
    pub fn decode(&self) -> Result<Vec<u32>> {
        let mut out = vec![0u32; self.n_values as usize];
        self.decode_into(&mut out)?;
        Ok(out)
    }

    /// Decode the full tensor into a caller-owned slice (the allocation-free
    /// read path: coordinator, engine pool and store decode shards/chunks
    /// into disjoint sub-slices of one pre-sized buffer). `out.len()` must
    /// equal `n_values`. An offset stream exhausted mid-value surfaces as
    /// `Error::CorruptStream` — never as fabricated zero offsets.
    pub fn decode_into(&self, out: &mut [u32]) -> Result<()> {
        let view = BodyView {
            n_values: self.n_values,
            symbols: &self.symbols,
            symbol_bits: self.symbol_bits,
            offsets: &self.offsets,
            offset_bits: self.offset_bits,
        };
        view.decode_into(&self.table, out)
    }

    /// Serialize to a flat byte buffer (little-endian framing). Layout:
    /// `magic u32 | table (SymbolTable::to_bytes) | body (body_to_bytes)`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            4 + SymbolTable::SERIALIZED_BYTES + 24 + self.symbols.len() + self.offsets.len(),
        );
        out.extend_from_slice(&0x4150_434Bu32.to_le_bytes()); // "APCK"
        out.extend_from_slice(&self.table.to_bytes());
        out.extend_from_slice(&self.body_to_bytes());
        out
    }

    /// Parse [`Self::to_bytes`] output.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        let err = |m: &str| Error::BadContainer(m.to_string());
        if data.len() < 4 + SymbolTable::SERIALIZED_BYTES {
            return Err(err("truncated header"));
        }
        let magic = u32::from_le_bytes(data[0..4].try_into().unwrap());
        if magic != 0x4150_434B {
            return Err(err("bad magic"));
        }
        let table = SymbolTable::from_bytes(&data[4..])?;
        Self::body_from_bytes(table, &data[4 + SymbolTable::SERIALIZED_BYTES..])
    }

    /// Serialize only the table-independent part: `n_values u64 |
    /// sym_bits u64 | ofs_bits u64 | symbols | offsets`. This is the
    /// per-shard/per-chunk record used where many streams share one table
    /// ([`crate::coordinator::ShardedContainer`], [`crate::store`]) so the
    /// table is not duplicated into every shard.
    pub fn body_to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(24 + self.symbols.len() + self.offsets.len());
        out.extend_from_slice(&self.n_values.to_le_bytes());
        out.extend_from_slice(&self.symbol_bits.to_le_bytes());
        out.extend_from_slice(&self.offset_bits.to_le_bytes());
        out.extend_from_slice(&self.symbols);
        out.extend_from_slice(&self.offsets);
        out
    }

    /// Parse [`Self::body_to_bytes`] output against a shared `table`.
    /// Rejects both truncated and over-long input — chunk records are
    /// exact-length so byte-level corruption cannot hide in slack space.
    pub fn body_from_bytes(table: SymbolTable, data: &[u8]) -> Result<Self> {
        let view = BodyView::parse(data)?;
        Ok(Self {
            table,
            n_values: view.n_values,
            symbols: view.symbols.to_vec(),
            symbol_bits: view.symbol_bits,
            offsets: view.offsets.to_vec(),
            offset_bits: view.offset_bits,
        })
    }
}

/// A parsed-but-borrowed body record: the stream slices point into the
/// caller's buffer (e.g. an mmap'd store chunk), so the zero-copy decode
/// path never duplicates the compressed bytes.
#[derive(Debug, Clone, Copy)]
pub struct BodyView<'a> {
    pub n_values: u64,
    pub symbols: &'a [u8],
    pub symbol_bits: u64,
    pub offsets: &'a [u8],
    pub offset_bits: u64,
}

impl<'a> BodyView<'a> {
    /// Parse a [`Container::body_to_bytes`] record without copying the
    /// streams. Same exact-length validation as
    /// [`Container::body_from_bytes`].
    pub fn parse(data: &'a [u8]) -> Result<Self> {
        let err = |m: &str| Error::BadContainer(m.to_string());
        if data.len() < 24 {
            return Err(err("truncated shard body header"));
        }
        let n_values = u64::from_le_bytes(data[0..8].try_into().unwrap());
        let symbol_bits = u64::from_le_bytes(data[8..16].try_into().unwrap());
        let offset_bits = u64::from_le_bytes(data[16..24].try_into().unwrap());
        let sym_len = (symbol_bits as usize).div_ceil(8);
        let ofs_len = (offset_bits as usize).div_ceil(8);
        let expected = 24usize
            .checked_add(sym_len)
            .and_then(|n| n.checked_add(ofs_len))
            .ok_or_else(|| err("shard body stream lengths overflow"))?;
        if data.len() != expected {
            return Err(err(&format!(
                "shard body length mismatch: {} bytes, expected {expected}",
                data.len()
            )));
        }
        Ok(Self {
            n_values,
            symbols: &data[24..24 + sym_len],
            symbol_bits,
            offsets: &data[24 + sym_len..],
            offset_bits,
        })
    }

    /// Decode the record into a caller-owned slice (`out.len()` must equal
    /// `n_values`) straight from the borrowed streams — the store's
    /// hot read path: no stream copy, no output allocation.
    pub fn decode_into(&self, table: &SymbolTable, out: &mut [u32]) -> Result<()> {
        if out.len() as u64 != self.n_values {
            return Err(Error::BadContainer(format!(
                "decode_into slice holds {} values, body has {}",
                out.len(),
                self.n_values
            )));
        }
        let sym = BitReader::new(self.symbols, self.symbol_bits as usize);
        let mut ofs = BitReader::new(self.offsets, self.offset_bits as usize);
        let mut dec = ApackDecoder::new(table, sym)?;
        dec.decode_into(out, &mut ofs)
    }
}

/// One-shot compression: profile the tensor, generate its table (paper §VI)
/// and encode.
pub fn compress(bits: u32, values: &[u32], kind: TensorKind) -> Result<Container> {
    let hist = super::histogram::Histogram::from_values(bits, values);
    let table = generate_table(&hist, kind, &TableGenConfig::for_bits(bits))?;
    compress_with_table(&table, values)
}

/// Compress with a pre-generated table (e.g. an activation table built from
/// profiling samples, applied to fresh inference activations). Borrows the
/// table — callers encoding many chunks/shards against one table no longer
/// clone it per call (the resulting `Container` clones it exactly once,
/// and the heavy value→row LUT inside is `Arc`-shared; DESIGN.md §9).
pub fn compress_with_table(table: &SymbolTable, values: &[u32]) -> Result<Container> {
    let (symbols, symbol_bits, offsets, offset_bits) = ApackEncoder::encode_all(table, values)?;
    Ok(Container {
        table: table.clone(),
        n_values: values.len() as u64,
        symbols,
        symbol_bits: symbol_bits as u64,
        offsets,
        offset_bits: offset_bits as u64,
    })
}

/// Encode a chunk straight to its [`Container::body_to_bytes`] record —
/// the store writer's ingest hot path: no `Container`, no table clone,
/// one output buffer. Byte-identical to
/// `compress_with_table(table, values)?.body_to_bytes()`.
pub fn encode_body(table: &SymbolTable, values: &[u32]) -> Result<Vec<u8>> {
    let (symbols, symbol_bits, offsets, offset_bits) = ApackEncoder::encode_all(table, values)?;
    let mut out = Vec::with_capacity(24 + symbols.len() + offsets.len());
    out.extend_from_slice(&(values.len() as u64).to_le_bytes());
    out.extend_from_slice(&(symbol_bits as u64).to_le_bytes());
    out.extend_from_slice(&(offset_bits as u64).to_le_bytes());
    out.extend_from_slice(&symbols);
    out.extend_from_slice(&offsets);
    Ok(out)
}

/// One-shot decompression.
pub fn decompress(c: &Container) -> Result<Vec<u32>> {
    c.decode()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tensor() -> Vec<u32> {
        let mut v = Vec::new();
        let mut s = 7u64;
        for _ in 0..10_000 {
            s = s.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
            let r = (s >> 33) as u32;
            v.push(if r % 3 == 0 { 0 } else { r % 256 });
        }
        v
    }

    #[test]
    fn compress_decompress_roundtrip() {
        let values = tensor();
        let c = compress(8, &values, TensorKind::Activations).unwrap();
        assert_eq!(c.decode().unwrap(), values);
        assert!(c.compression_ratio() > 1.0, "ratio {}", c.compression_ratio());
    }

    #[test]
    fn serialization_roundtrip() {
        let values = tensor();
        let c = compress(8, &values, TensorKind::Weights).unwrap();
        let bytes = c.to_bytes();
        let c2 = Container::from_bytes(&bytes).unwrap();
        assert_eq!(c2.n_values, c.n_values);
        assert_eq!(c2.symbol_bits, c.symbol_bits);
        assert_eq!(c2.decode().unwrap(), values);
    }

    #[test]
    fn from_bytes_rejects_garbage() {
        assert!(Container::from_bytes(&[1, 2, 3]).is_err());
        let values = tensor();
        let c = compress(8, &values, TensorKind::Weights).unwrap();
        let mut bytes = c.to_bytes();
        bytes[0] ^= 0xFF; // magic
        assert!(Container::from_bytes(&bytes).is_err());
        let mut short = c.to_bytes();
        short.truncate(short.len() - 10);
        assert!(Container::from_bytes(&short).is_err());
    }

    #[test]
    fn body_roundtrip_shares_table() {
        let values = tensor();
        let c = compress(8, &values, TensorKind::Activations).unwrap();
        let body = c.body_to_bytes();
        let c2 = Container::body_from_bytes(c.table.clone(), &body).unwrap();
        assert_eq!(c2.decode().unwrap(), values);
        // Exact-length framing: slack or truncation is rejected.
        let mut long = body.clone();
        long.push(0);
        assert!(Container::body_from_bytes(c.table.clone(), &long).is_err());
        assert!(Container::body_from_bytes(c.table.clone(), &body[..body.len() - 1]).is_err());
    }

    #[test]
    fn decode_into_and_body_view_match_decode() {
        let values = tensor();
        let c = compress(8, &values, TensorKind::Activations).unwrap();
        let mut out = vec![0u32; values.len()];
        c.decode_into(&mut out).unwrap();
        assert_eq!(out, values);
        // Wrong-size slice is rejected before any decode work.
        let mut short = vec![0u32; values.len() - 1];
        assert!(c.decode_into(&mut short).is_err());
        // Zero-copy body view decodes identically from borrowed streams.
        let body = c.body_to_bytes();
        let view = BodyView::parse(&body).unwrap();
        assert_eq!(view.n_values, c.n_values);
        let mut out2 = vec![0u32; values.len()];
        view.decode_into(&c.table, &mut out2).unwrap();
        assert_eq!(out2, values);
        assert!(BodyView::parse(&body[..body.len() - 1]).is_err());
    }

    #[test]
    fn encode_body_matches_container_body() {
        let values = tensor();
        let c = compress(8, &values, TensorKind::Weights).unwrap();
        let direct = encode_body(&c.table, &values).unwrap();
        assert_eq!(direct, c.body_to_bytes());
        let view = BodyView::parse(&direct).unwrap();
        let mut out = vec![0u32; values.len()];
        view.decode_into(&c.table, &mut out).unwrap();
        assert_eq!(out, values);
    }

    #[test]
    fn footprint_includes_metadata() {
        let values = vec![0u32; 16];
        let c = compress(8, &values, TensorKind::Weights).unwrap();
        assert!(c.footprint_bits() >= (META_BYTES as u64) * 8);
    }
}
