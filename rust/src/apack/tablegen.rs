//! Probability-count table generation (paper §VI, Listing 1).
//!
//! Given a per-tensor histogram, a heuristic search picks the 16-range
//! partition of the value space that minimizes the *estimated* encoded
//! footprint (per-range entropy for the symbol stream + `OL` raw bits per
//! value for the offset stream + metadata). The search:
//!
//! 1. initializes the partition uniformly,
//! 2. repeatedly calls `search`, which tries moving each movable boundary
//!    (`v_min` of rows 1..N) one value at a time across its free interval,
//!    recursing (depth ≤ `DEPTH_MAX` = 2) on the neighbours of a moved
//!    boundary, keeping the best configuration found,
//! 3. stops when a full round improves the footprint by less than the 1%
//!    `THRESHOLD`.
//!
//! The production `search` evaluates candidates **incrementally**: a
//! boundary move changes exactly two ranges, so per-range bit
//! contributions are cached and only those two are recomputed per
//! candidate (DESIGN.md §9). The pre-incremental full-recompute search is
//! kept verbatim behind [`generate_table_seed`]; the two are
//! property-tested to produce byte-identical tables.
//!
//! Once the partition is fixed, the 10-bit probability counts are assigned
//! proportionally to range masses (largest-remainder rounding), giving every
//! non-empty range at least one count. For **activations** a final
//! adjustment "steals" one count for every empty range too, since profiling
//! cannot prove a value never occurs at inference time (paper §VI "Final
//! Adjustment for Activations"); for **weights** empty ranges legitimately
//! keep a zero count (they are statically known).

use super::histogram::Histogram;
use super::table::{offset_len, SymbolTable, PROB_MAX};
use super::NUM_ROWS;
use crate::error::Result;

/// Whether the tensor's values are statically known (weights) or only
/// profiled (activations). Controls the zero-count final adjustment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Statically known: empty ranges may keep probability zero.
    Weights,
    /// Profiled: every range must keep a non-zero count.
    Activations,
}

/// Search hyper-parameters (paper defaults).
#[derive(Debug, Clone, Copy)]
pub struct TableGenConfig {
    /// Maximum recursion depth of `search` (paper: 2).
    pub depth_max: u32,
    /// Continue another round while `new/old < threshold` (paper: 0.99,
    /// i.e. ≥1% improvement required).
    pub threshold: f64,
    /// Neighbourhood radius for recursive refinement (paper: 1).
    pub around_radius: u32,
    /// Boundary movement stride. 1 for ≤ 8-bit models; for wider value
    /// spaces a coarse stride pass (e.g. `2^(bits-8)`) followed by a
    /// stride-1 refinement keeps the search tractable (our extension — the
    /// paper only reports 4/8/16-bit models without detailing the 16-bit
    /// search cost).
    pub stride: u32,
}

impl Default for TableGenConfig {
    fn default() -> Self {
        Self { depth_max: 2, threshold: 0.99, around_radius: 1, stride: 1 }
    }
}

impl TableGenConfig {
    /// Paper-default configuration for a bit width (coarse stride for 16b).
    pub fn for_bits(bits: u32) -> Self {
        let stride = if bits > 10 { 1 << (bits - 8) } else { 1 };
        Self { stride, ..Self::default() }
    }
}

/// Partition state during the search: the movable `v_min` boundaries.
/// `Copy` (it is 17 words) so the hot search tracks its best candidate by
/// plain assignment instead of a `Clone` call per improvement.
#[derive(Clone, Copy)]
struct Partition {
    v_mins: [u32; NUM_ROWS],
    value_max: u32,
}

impl Partition {
    fn uniform(bits: u32) -> Self {
        let n_values = 1u64 << bits;
        let mut v_mins = [0u32; NUM_ROWS];
        for (i, v) in v_mins.iter_mut().enumerate() {
            *v = ((n_values * i as u64) / NUM_ROWS as u64) as u32;
        }
        Self { v_mins, value_max: SymbolTable::value_max_for(bits) }
    }

    #[inline]
    fn v_max(&self, i: usize) -> u32 {
        if i + 1 < NUM_ROWS {
            self.v_mins[i + 1] - 1
        } else {
            self.value_max
        }
    }
}

/// Estimated footprint in bits of encoding `hist` with partition `p`:
/// per-range entropy + offset bits + metadata (paper §VI: "calculating the
/// entropy of each range").
fn encoded_size(hist: &Histogram, p: &Partition) -> f64 {
    let total = hist.total();
    if total == 0 {
        return 0.0;
    }
    let total_f = total as f64;
    let mut bits = 0.0;
    for i in 0..NUM_ROWS {
        let mass = hist.range_mass(p.v_mins[i], p.v_max(i));
        if mass == 0 {
            continue;
        }
        let prob = mass as f64 / total_f;
        let ol = offset_len(p.v_max(i) - p.v_mins[i] + 1) as f64;
        bits += mass as f64 * (-prob.log2() + ol);
    }
    bits + METADATA_BITS as f64
}

/// Metadata footprint charged per tensor (paper §IV: "a total of 298
/// bytes" for the range + probability tables + symbol count).
pub const METADATA_BITS: usize = 298 * 8;

/// Range `i`'s exact contribution to [`encoded_size`]: the same
/// floating-point expression, term for term, so a sum of contributions in
/// index order is **bit-identical** to the from-scratch accumulation
/// (empty ranges contribute `0.0`, and `x + 0.0 == x` exactly for the
/// non-negative partials this sum produces). This is what lets the
/// incremental search below claim identical results to the seed search.
#[inline]
fn range_contrib(hist: &Histogram, p: &Partition, i: usize, total_f: f64) -> f64 {
    let mass = hist.range_mass(p.v_mins[i], p.v_max(i));
    if mass == 0 {
        return 0.0;
    }
    let prob = mass as f64 / total_f;
    let ol = offset_len(p.v_max(i) - p.v_mins[i] + 1) as f64;
    mass as f64 * (-prob.log2() + ol)
}

/// All [`NUM_ROWS`] contributions of a partition.
fn contribs_for(hist: &Histogram, p: &Partition, total_f: f64) -> [f64; NUM_ROWS] {
    let mut c = [0.0; NUM_ROWS];
    for (i, slot) in c.iter_mut().enumerate() {
        *slot = range_contrib(hist, p, i, total_f);
    }
    c
}

/// Footprint from per-range contributions — equals
/// `encoded_size(hist, p)` bit-for-bit when `hist.total() > 0` (see
/// [`range_contrib`] for why).
#[inline]
fn size_from_contribs(contrib: &[f64; NUM_ROWS]) -> f64 {
    let mut bits = 0.0;
    for &c in contrib {
        bits += c;
    }
    bits + METADATA_BITS as f64
}

/// The recursive boundary search (paper Listing 1, `search`) —
/// **incremental** evaluation: moving boundary `i` changes exactly two
/// ranges (`i-1`, whose `v_max` follows the boundary, and `i`, whose
/// `v_min` is it), so each candidate updates two cached contributions and
/// re-sums instead of recomputing entropy over all 16 rows. Candidate
/// order, comparisons and returned partitions are identical to
/// [`search_seed`] (property-tested: `prop_incremental_tablegen_matches_seed`).
///
/// `around = None` allows all boundaries; otherwise only boundaries within
/// `around_radius` of `around` are tried.
fn search(
    hist: &Histogram,
    pt: &Partition,
    contrib: &[f64; NUM_ROWS],
    minsize: f64,
    depth: u32,
    around: Option<usize>,
    cfg: &TableGenConfig,
) -> (Partition, f64) {
    let total_f = hist.total() as f64;
    let mut best = *pt;
    let mut best_size = minsize;
    let mut try_pt = *pt;
    let mut try_contrib = *contrib;

    for i in 1..NUM_ROWS {
        if let Some(a) = around {
            if (i as i64 - a as i64).unsigned_abs() as u32 > cfg.around_radius {
                continue;
            }
        }
        let save = try_pt.v_mins[i];
        let (save_prev, save_this) = (try_contrib[i - 1], try_contrib[i]);

        // Move the boundary DOWN one stride at a time, keeping rows
        // non-empty (v_min strictly increasing).
        let floor = try_pt.v_mins[i - 1] + 1;
        while try_pt.v_mins[i] > floor {
            try_pt.v_mins[i] = try_pt.v_mins[i].saturating_sub(cfg.stride).max(floor);
            try_contrib[i - 1] = range_contrib(hist, &try_pt, i - 1, total_f);
            try_contrib[i] = range_contrib(hist, &try_pt, i, total_f);
            let s = size_from_contribs(&try_contrib);
            if s < best_size {
                best = try_pt;
                best_size = s;
            }
            if depth < cfg.depth_max {
                let (p, s) =
                    search(hist, &try_pt, &try_contrib, best_size, depth + 1, Some(i), cfg);
                if s < best_size {
                    best = p;
                    best_size = s;
                }
            }
        }
        try_pt.v_mins[i] = save;
        try_contrib[i - 1] = save_prev;
        try_contrib[i] = save_this;

        // Move the boundary UP.
        let ceil = if i + 1 < NUM_ROWS { try_pt.v_mins[i + 1] - 1 } else { try_pt.value_max };
        while try_pt.v_mins[i] < ceil {
            try_pt.v_mins[i] = (try_pt.v_mins[i] + cfg.stride).min(ceil);
            try_contrib[i - 1] = range_contrib(hist, &try_pt, i - 1, total_f);
            try_contrib[i] = range_contrib(hist, &try_pt, i, total_f);
            let s = size_from_contribs(&try_contrib);
            if s < best_size {
                best = try_pt;
                best_size = s;
            }
            if depth < cfg.depth_max {
                let (p, s) =
                    search(hist, &try_pt, &try_contrib, best_size, depth + 1, Some(i), cfg);
                if s < best_size {
                    best = p;
                    best_size = s;
                }
            }
        }
        try_pt.v_mins[i] = save;
        try_contrib[i - 1] = save_prev;
        try_contrib[i] = save_this;
    }
    (best, best_size)
}

/// The pre-incremental boundary search, kept verbatim as the **reference
/// implementation**: every candidate is evaluated by a full
/// [`encoded_size`] recomputation. [`generate_table_seed`] drives it; the
/// equivalence property test and the `store_pack` ingest bench compare
/// the incremental path against it.
fn search_seed(
    hist: &Histogram,
    pt: &Partition,
    minsize: f64,
    depth: u32,
    around: Option<usize>,
    cfg: &TableGenConfig,
) -> (Partition, f64) {
    let mut best = *pt;
    let mut best_size = minsize;
    let mut try_pt = *pt;

    for i in 1..NUM_ROWS {
        if let Some(a) = around {
            if (i as i64 - a as i64).unsigned_abs() as u32 > cfg.around_radius {
                continue;
            }
        }
        let save = try_pt.v_mins[i];

        let floor = try_pt.v_mins[i - 1] + 1;
        while try_pt.v_mins[i] > floor {
            try_pt.v_mins[i] = try_pt.v_mins[i].saturating_sub(cfg.stride).max(floor);
            let s = encoded_size(hist, &try_pt);
            if s < best_size {
                best = try_pt;
                best_size = s;
            }
            if depth < cfg.depth_max {
                let (p, s) = search_seed(hist, &try_pt, best_size, depth + 1, Some(i), cfg);
                if s < best_size {
                    best = p;
                    best_size = s;
                }
            }
        }
        try_pt.v_mins[i] = save;

        let ceil = if i + 1 < NUM_ROWS { try_pt.v_mins[i + 1] - 1 } else { try_pt.value_max };
        while try_pt.v_mins[i] < ceil {
            try_pt.v_mins[i] = (try_pt.v_mins[i] + cfg.stride).min(ceil);
            let s = encoded_size(hist, &try_pt);
            if s < best_size {
                best = try_pt;
                best_size = s;
            }
            if depth < cfg.depth_max {
                let (p, s) = search_seed(hist, &try_pt, best_size, depth + 1, Some(i), cfg);
                if s < best_size {
                    best = p;
                    best_size = s;
                }
            }
        }
        try_pt.v_mins[i] = save;
    }
    (best, best_size)
}

/// `findPT` (paper Listing 1): iterate `search` until the improvement per
/// round drops below the threshold, then assign probability counts. Uses
/// the incremental boundary search (O(1) contribution deltas per
/// candidate); the resulting tables are byte-identical to
/// [`generate_table_seed`].
pub fn generate_table(hist: &Histogram, kind: TensorKind, cfg: &TableGenConfig) -> Result<SymbolTable> {
    let bits = hist.bits();
    let mut pt = Partition::uniform(bits);
    if hist.total() == 0 {
        // Degenerate empty histogram: no candidate can beat `encoded_size
        // == 0.0`, so the seed flow keeps the uniform partition — return
        // it directly (assign_counts falls back to uniform counts too).
        return assign_counts(hist, &pt, kind);
    }
    let total_f = hist.total() as f64;
    let mut size = encoded_size(hist, &pt);
    loop {
        let contrib = contribs_for(hist, &pt, total_f);
        let (new_pt, new_size) = search(hist, &pt, &contrib, size, 1, None, cfg);
        pt = new_pt;
        if size <= 0.0 || new_size / size >= cfg.threshold {
            size = new_size;
            break;
        }
        size = new_size;
    }
    // Stride-1 refinement round for coarse searches.
    if cfg.stride > 1 {
        let fine = TableGenConfig { stride: 1, depth_max: 1, ..*cfg };
        let contrib = contribs_for(hist, &pt, total_f);
        let (new_pt, _) = search(hist, &pt, &contrib, size, 1, None, &fine);
        pt = new_pt;
    }
    assign_counts(hist, &pt, kind)
}

/// The seed (pre-incremental) `findPT`, kept selectable: drives
/// [`search_seed`] exactly as the original implementation did. Used by the
/// equivalence property test (`generate_table` must produce byte-identical
/// tables) and as the tablegen baseline in `benches/store_pack.rs`.
pub fn generate_table_seed(
    hist: &Histogram,
    kind: TensorKind,
    cfg: &TableGenConfig,
) -> Result<SymbolTable> {
    let bits = hist.bits();
    let mut pt = Partition::uniform(bits);
    let mut size = encoded_size(hist, &pt);
    loop {
        let (new_pt, new_size) = search_seed(hist, &pt, size, 1, None, cfg);
        pt = new_pt;
        if size <= 0.0 || new_size / size >= cfg.threshold {
            size = new_size;
            break;
        }
        size = new_size;
    }
    if cfg.stride > 1 {
        let fine = TableGenConfig { stride: 1, depth_max: 1, ..*cfg };
        let (new_pt, _) = search_seed(hist, &pt, size, 1, None, &fine);
        pt = new_pt;
    }
    assign_counts(hist, &pt, kind)
}

/// Partition the 10-bit count space `[0, PROB_MAX]` proportionally to range
/// masses (largest-remainder rounding), guaranteeing ≥1 count per non-empty
/// range, then apply the activation final adjustment.
fn assign_counts(hist: &Histogram, p: &Partition, kind: TensorKind) -> Result<SymbolTable> {
    let mut mass = [0u64; NUM_ROWS];
    for i in 0..NUM_ROWS {
        mass[i] = hist.range_mass(p.v_mins[i], p.v_max(i));
    }
    let total: u64 = mass.iter().sum();
    let budget = PROB_MAX as u64; // 0x3FF counts across all rows

    let mut counts = [0u64; NUM_ROWS];
    if total == 0 {
        // Degenerate (empty tensor): fall back to uniform counts.
        for (i, c) in counts.iter_mut().enumerate() {
            *c = budget * (i as u64 + 1) / NUM_ROWS as u64
                - budget * i as u64 / NUM_ROWS as u64;
        }
    } else {
        // Largest-remainder apportionment with a floor of 1 for non-empty
        // rows.
        let mut floors = [0u64; NUM_ROWS];
        let mut remainders: Vec<(usize, f64)> = Vec::with_capacity(NUM_ROWS);
        let mut assigned = 0u64;
        for i in 0..NUM_ROWS {
            let exact = mass[i] as f64 / total as f64 * budget as f64;
            let fl = exact.floor() as u64;
            floors[i] = if mass[i] > 0 { fl.max(1) } else { 0 };
            assigned += floors[i];
            remainders.push((i, exact - fl as f64));
        }
        // Distribute leftover counts by largest remainder; recover overage
        // (possible due to the floor-of-1 rule) from the largest rows.
        if assigned <= budget {
            remainders.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let mut left = budget - assigned;
            let mut ri = 0;
            while left > 0 {
                let (i, _) = remainders[ri % remainders.len()];
                if mass[i] > 0 {
                    floors[i] += 1;
                    left -= 1;
                }
                ri += 1;
            }
        } else {
            let mut over = assigned - budget;
            while over > 0 {
                let i = (0..NUM_ROWS)
                    .filter(|&i| floors[i] > 1)
                    .max_by_key(|&i| floors[i])
                    .expect("count budget must be recoverable");
                floors[i] -= 1;
                over -= 1;
            }
        }
        counts = floors;
    }

    // Final adjustment for activations: profiling cannot prove absence, so
    // steal one count from the largest row for each zero-count row.
    if kind == TensorKind::Activations {
        for i in 0..NUM_ROWS {
            if counts[i] == 0 {
                let donor = (0..NUM_ROWS)
                    .filter(|&j| counts[j] > 1)
                    .max_by_key(|&j| counts[j])
                    .expect("some row must have spare counts");
                counts[donor] -= 1;
                counts[i] += 1;
            }
        }
    }

    debug_assert_eq!(counts.iter().sum::<u64>(), PROB_MAX as u64);
    let mut hi_cnts = [0u16; NUM_ROWS];
    let mut acc = 0u64;
    for i in 0..NUM_ROWS {
        acc += counts[i];
        hi_cnts[i] = acc as u16;
    }
    SymbolTable::new(hist.bits(), p.v_mins, hi_cnts)
}

/// Convenience: profile a tensor and generate its table with the default
/// configuration for its bit width.
pub fn table_for_tensor(bits: u32, values: &[u32], kind: TensorKind) -> Result<SymbolTable> {
    let hist = Histogram::from_values(bits, values);
    generate_table(&hist, kind, &TableGenConfig::for_bits(bits))
}

/// Estimated compressed footprint in bits for `hist` under `table`
/// (symbol-entropy model + offsets + metadata) — used by the evaluation
/// harness when comparing with the exact encoder output.
pub fn estimate_bits(hist: &Histogram, table: &SymbolTable) -> f64 {
    let p = Partition {
        v_mins: {
            let mut v = [0u32; NUM_ROWS];
            for (i, r) in table.rows().iter().enumerate() {
                v[i] = r.v_min;
            }
            v
        },
        value_max: table.value_max(),
    };
    encoded_size(hist, &p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::bitstream::BitReader;
    use crate::apack::decoder::ApackDecoder;
    use crate::apack::encoder::ApackEncoder;

    fn skewed_tensor(n: usize) -> Vec<u32> {
        // ~50% zeros, geometric tail near 0, mirrored tail near 255 — the
        // shape of Fig 2.
        let mut out = Vec::with_capacity(n);
        let mut state = 0x12345678u64;
        for _ in 0..n {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let r = (state >> 33) as u32;
            let v = match r % 100 {
                0..=49 => 0,
                50..=69 => r % 4,
                70..=84 => 255 - (r % 4),
                85..=94 => r % 16,
                _ => r % 256,
            };
            out.push(v);
        }
        out
    }

    #[test]
    fn generated_table_is_valid_and_roundtrips() {
        let values = skewed_tensor(20_000);
        let t = table_for_tensor(8, &values, TensorKind::Weights).unwrap();
        let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
        let mut ofs_r = BitReader::new(&ofs, ob);
        let got =
            ApackDecoder::decode_all(&t, BitReader::new(&sym, sb), &mut ofs_r, values.len())
                .unwrap();
        assert_eq!(got, values);
    }

    #[test]
    fn generated_table_beats_uniform_on_skewed_data() {
        let values = skewed_tensor(20_000);
        let hist = Histogram::from_values(8, &values);
        let uniform = SymbolTable::uniform(8);
        let tuned =
            generate_table(&hist, TensorKind::Weights, &TableGenConfig::default()).unwrap();
        let (_, sb_u, _, ob_u) = ApackEncoder::encode_all(&uniform, &values).unwrap();
        let (_, sb_t, _, ob_t) = ApackEncoder::encode_all(&tuned, &values).unwrap();
        let bits_u = sb_u + ob_u;
        let bits_t = sb_t + ob_t;
        assert!(
            (bits_t as f64) < 0.8 * bits_u as f64,
            "tuned {bits_t} vs uniform {bits_u} bits"
        );
        // And materially beats the raw 8 bits/value format.
        assert!((bits_t as f64) < 0.6 * (values.len() * 8) as f64);
    }

    #[test]
    fn activation_tables_cover_every_row() {
        let values = skewed_tensor(10_000);
        let hist = Histogram::from_values(8, &values);
        let t =
            generate_table(&hist, TensorKind::Activations, &TableGenConfig::default()).unwrap();
        for i in 0..NUM_ROWS {
            assert!(
                t.rows()[i].hi_cnt > t.lo_cnt(i),
                "activation table row {i} has zero count"
            );
        }
        // Consequently any 8-bit value is encodable.
        let mut enc = ApackEncoder::new(&t);
        let mut s = crate::apack::bitstream::BitWriter::new();
        let mut o = crate::apack::bitstream::BitWriter::new();
        for v in 0..=255u32 {
            enc.encode_value(v, &mut s, &mut o).unwrap();
        }
    }

    #[test]
    fn weight_tables_may_zero_out_absent_ranges() {
        // Tensor with a huge hole in the middle, like Table I.
        let mut values = vec![0u32; 5000];
        values.extend(std::iter::repeat(255u32).take(4000));
        values.extend((0..64).map(|i| i % 4));
        let hist = Histogram::from_values(8, &values);
        let t = generate_table(&hist, TensorKind::Weights, &TableGenConfig::default()).unwrap();
        let any_zero_row = (0..NUM_ROWS).any(|i| t.rows()[i].hi_cnt == t.lo_cnt(i));
        assert!(any_zero_row, "expected zero-probability rows for the hole:\n{}", t.render());
    }

    #[test]
    fn estimate_tracks_actual_encoding() {
        let values = skewed_tensor(30_000);
        let hist = Histogram::from_values(8, &values);
        let t = generate_table(&hist, TensorKind::Weights, &TableGenConfig::default()).unwrap();
        let est = estimate_bits(&hist, &t);
        let (_, sb, _, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
        let actual = (sb + ob + METADATA_BITS) as f64;
        let ratio = actual / est;
        assert!(
            (0.9..1.1).contains(&ratio),
            "estimate {est:.0} vs actual {actual:.0} (ratio {ratio:.3})"
        );
    }

    #[test]
    fn incremental_search_matches_seed_search() {
        // The incremental search must pick the exact same partitions (and
        // therefore tables) as the full-recompute seed search — 8-bit
        // stride-1 and 16-bit coarse+refine alike, for both tensor kinds
        // and for the degenerate empty histogram.
        let values = skewed_tensor(20_000);
        let hist = Histogram::from_values(8, &values);
        for kind in [TensorKind::Weights, TensorKind::Activations] {
            let cfg = TableGenConfig::for_bits(8);
            let inc = generate_table(&hist, kind, &cfg).unwrap();
            let seed = generate_table_seed(&hist, kind, &cfg).unwrap();
            assert_eq!(inc.to_bytes(), seed.to_bytes(), "{kind:?}");
        }

        let wide: Vec<u32> = values.iter().map(|v| v * 257).collect();
        let hist16 = Histogram::from_values(16, &wide);
        let cfg16 = TableGenConfig::for_bits(16);
        let inc = generate_table(&hist16, TensorKind::Activations, &cfg16).unwrap();
        let seed = generate_table_seed(&hist16, TensorKind::Activations, &cfg16).unwrap();
        assert_eq!(inc.to_bytes(), seed.to_bytes(), "16-bit coarse");

        let empty = Histogram::new(8);
        let inc = generate_table(&empty, TensorKind::Weights, &TableGenConfig::default()).unwrap();
        let seed =
            generate_table_seed(&empty, TensorKind::Weights, &TableGenConfig::default()).unwrap();
        assert_eq!(inc.to_bytes(), seed.to_bytes(), "empty histogram");
    }

    #[test]
    fn sixteen_bit_coarse_search_terminates_and_roundtrips() {
        let mut values = Vec::new();
        let mut state = 99u64;
        for _ in 0..5000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let r = (state >> 40) as u32;
            values.push(if r % 4 == 0 { r % 65536 } else { r % 128 });
        }
        let t = table_for_tensor(16, &values, TensorKind::Activations).unwrap();
        let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
        let mut ofs_r = BitReader::new(&ofs, ob);
        let got =
            ApackDecoder::decode_all(&t, BitReader::new(&sym, sb), &mut ofs_r, values.len())
                .unwrap();
        assert_eq!(got, values);
    }
}
