//! MSB-first bit stream writer/reader.
//!
//! Both APack streams (arithmetically coded symbols and verbatim offsets)
//! are bit-granular and written/read most-significant-bit first, matching
//! the hardware's shift-register orientation (paper §V: "most significant
//! bit first"). A 64-bit accumulator keeps the hot path branch-light.

/// Append-only MSB-first bit writer backed by a `Vec<u8>`.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Accumulator; bits enter at the low end and are flushed from the top.
    acc: u64,
    /// Number of valid bits currently in `acc` (0..=63).
    n: u32,
}

impl BitWriter {
    /// Create an empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// Create an empty writer with capacity for `bits` bits.
    pub fn with_capacity_bits(bits: usize) -> Self {
        Self { buf: Vec::with_capacity(bits / 8 + 8), acc: 0, n: 0 }
    }

    /// Append a single bit (`true` = 1).
    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        self.push_bits(bit as u64, 1);
    }

    /// Append the low `count` bits of `bits`, MSB of that field first.
    /// `count` must be ≤ 57 so the accumulator never overflows before the
    /// flush check.
    #[inline]
    pub fn push_bits(&mut self, bits: u64, count: u32) {
        debug_assert!(count <= 57);
        debug_assert!(count == 64 || bits < (1u64 << count));
        self.acc = (self.acc << count) | bits;
        self.n += count;
        if self.n >= 8 {
            // Flush all whole bytes in one extend (perf: avoids per-byte
            // Vec::push — EXPERIMENTS.md §Perf iteration 6).
            let k = (self.n / 8) as usize;
            let shifted =
                if self.n == 64 { self.acc } else { self.acc << (64 - self.n) };
            self.buf.extend_from_slice(&shifted.to_be_bytes()[..k]);
            self.n -= (k as u32) * 8;
        }
    }

    /// Append `count` copies of `bit` (used for underflow-bit bursts).
    #[inline]
    pub fn push_repeated(&mut self, bit: bool, mut count: u32) {
        let pattern = if bit { u64::MAX >> 16 } else { 0 }; // 48 ones
        while count > 48 {
            self.push_bits(pattern, 48);
            count -= 48;
        }
        if count > 0 {
            self.push_bits(if bit { (1u64 << count) - 1 } else { 0 }, count);
        }
    }

    /// Total number of bits written so far.
    #[inline]
    pub fn len_bits(&self) -> usize {
        self.buf.len() * 8 + self.n as usize
    }

    /// Flush the accumulator (zero-padding the final byte) and return the
    /// byte buffer together with the exact bit length.
    pub fn finish(mut self) -> (Vec<u8>, usize) {
        let bits = self.len_bits();
        if self.n > 0 {
            let pad = 8 - self.n;
            self.acc <<= pad;
            self.buf.push(self.acc as u8);
            self.n = 0;
        }
        (self.buf, bits)
    }
}

/// MSB-first bit reader over a byte slice.
///
/// Reads past the end of the underlying data return `0` bits. This is
/// deliberate: the arithmetic-coder flush (see [`super::encoder`]) emits a
/// disambiguating prefix such that *any* continuation decodes the final
/// symbol correctly, so the decoder may freely over-read its 16-bit CODE
/// window — both the initial `CODE` prime and the renormalization refills —
/// near the end of the stream, exactly as the hardware, whose CODE shift
/// register keeps shifting whatever is on the bus once the stream is
/// exhausted.
///
/// The zero-latch is **only** correct for the symbol stream. Offset bits
/// carry verbatim payload, so fabricating zeros there would silently decode
/// wrong values; the decoder checks [`Self::bits_remaining`] before every
/// offset read and surfaces exhaustion as `Error::CorruptStream` instead.
#[derive(Debug, Clone)]
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Next bit index.
    pos: usize,
    /// Total addressable bits.
    len_bits: usize,
    acc: u64,
    /// Valid bits in `acc`.
    n: u32,
    byte_pos: usize,
}

impl<'a> BitReader<'a> {
    /// Reader over `len_bits` bits of `data`.
    pub fn new(data: &'a [u8], len_bits: usize) -> Self {
        debug_assert!(len_bits <= data.len() * 8);
        Self { data, pos: 0, len_bits, acc: 0, n: 0, byte_pos: 0 }
    }

    /// Number of real (non-padding) bits remaining.
    #[inline]
    pub fn bits_remaining(&self) -> usize {
        self.len_bits.saturating_sub(self.pos)
    }

    /// Current bit position.
    #[inline]
    pub fn position(&self) -> usize {
        self.pos
    }

    #[inline]
    fn refill(&mut self) {
        // Fast path: pull several bytes with one unaligned load.
        if self.byte_pos + 8 <= self.data.len() {
            let want = ((64 - self.n) / 8) as usize;
            if want > 0 {
                let chunk = u64::from_be_bytes(
                    self.data[self.byte_pos..self.byte_pos + 8].try_into().unwrap(),
                );
                self.acc = if want == 8 {
                    chunk
                } else {
                    (self.acc << (want * 8)) | (chunk >> (64 - want * 8))
                };
                self.byte_pos += want;
                self.n += (want as u32) * 8;
            }
            return;
        }
        while self.n <= 56 && self.byte_pos < self.data.len() {
            self.acc = (self.acc << 8) | self.data[self.byte_pos] as u64;
            self.byte_pos += 1;
            self.n += 8;
        }
    }

    /// Read a single bit; returns 0 past the end of the stream.
    #[inline]
    pub fn read_bit(&mut self) -> u32 {
        self.read_bits(1) as u32
    }

    /// Read `count` (≤ 57) bits MSB-first; bits past the end read as 0.
    #[inline]
    pub fn read_bits(&mut self, count: u32) -> u64 {
        debug_assert!(count <= 57);
        if count == 0 {
            return 0;
        }
        if self.n < count {
            self.refill();
        }
        let avail = self.len_bits.saturating_sub(self.pos).min(self.n as usize) as u32;
        self.pos += count as usize;
        if avail >= count {
            self.n -= count;
            (self.acc >> self.n) & ((1u64 << count) - 1).min(u64::MAX)
        } else {
            // Partially or fully past the end: take what is real, pad zeros.
            let real = if avail > 0 {
                self.n -= avail;
                (self.acc >> self.n) & ((1u64 << avail) - 1)
            } else {
                0
            };
            real << (count - avail)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_single_bits() {
        let mut w = BitWriter::new();
        let pattern = [true, false, true, true, false, false, true, false, true, true, true];
        for &b in &pattern {
            w.push_bit(b);
        }
        let (bytes, bits) = w.finish();
        assert_eq!(bits, pattern.len());
        let mut r = BitReader::new(&bytes, bits);
        for &b in &pattern {
            assert_eq!(r.read_bit(), b as u32);
        }
    }

    #[test]
    fn roundtrip_multi_bit_fields() {
        let mut w = BitWriter::new();
        let fields: &[(u64, u32)] =
            &[(0x3, 2), (0x1ff, 9), (0, 1), (0xdeadbeef, 32), (0x15, 5), (1, 1)];
        for &(v, c) in fields {
            w.push_bits(v, c);
        }
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        for &(v, c) in fields {
            assert_eq!(r.read_bits(c), v, "field ({v:#x},{c})");
        }
    }

    #[test]
    fn over_read_returns_zero_padding() {
        let mut w = BitWriter::new();
        w.push_bits(0b101, 3);
        let (bytes, bits) = w.finish();
        let mut r = BitReader::new(&bytes, bits);
        // 3 real bits then zero padding: reading 8 gives 1010_0000 >> ... =
        // 0b101 followed by five 0s.
        assert_eq!(r.read_bits(8), 0b1010_0000);
        assert_eq!(r.read_bits(16), 0);
        assert_eq!(r.bits_remaining(), 0);
    }

    #[test]
    fn repeated_bits() {
        let mut w = BitWriter::new();
        w.push_repeated(true, 100);
        w.push_repeated(false, 3);
        w.push_bit(true);
        let (bytes, bits) = w.finish();
        assert_eq!(bits, 104);
        let mut r = BitReader::new(&bytes, bits);
        for _ in 0..100 {
            assert_eq!(r.read_bit(), 1);
        }
        for _ in 0..3 {
            assert_eq!(r.read_bit(), 0);
        }
        assert_eq!(r.read_bit(), 1);
    }

    #[test]
    fn len_bits_tracks_writes() {
        let mut w = BitWriter::new();
        assert_eq!(w.len_bits(), 0);
        w.push_bits(0x7, 3);
        assert_eq!(w.len_bits(), 3);
        w.push_bits(0xffff, 16);
        assert_eq!(w.len_bits(), 19);
    }
}
