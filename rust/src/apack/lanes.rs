//! Chunk body **v2**: one chunk's values split into N independent
//! arithmetic-coded substreams ("lanes") sharing the tensor's single
//! [`SymbolTable`], so one chunk decodes data-parallel — the software
//! mirror of the paper's replicated decoder engines that keep up with
//! DRAM bandwidth (§V-B), baked into the *format* instead of the
//! scheduler (DESIGN.md §11).
//!
//! # On-disk layout (one chunk blob)
//!
//! ```text
//! header, 12 bytes:   version u8 (= 2) | lanes u8 | pad u16 (= 0)
//!                     | n_values u64
//! directory:          lanes × { sym_bits u32 | ofs_bits u32 | crc32 u32 }
//! payloads:           lanes × ( symbol bytes | offset bytes ), in lane
//!                     order, each stream byte-aligned
//! ```
//!
//! Per-lane value counts are **not** stored: the split is the
//! deterministic function [`lane_range`] of `(n_values, lanes)` (first
//! `n % lanes` lanes take one extra value), so the directory stays 12
//! bytes per lane. Byte lengths derive from the bit lengths
//! (`ceil(bits/8)`). The per-lane CRC covers that lane's payload bytes and
//! is checked only on the `verify` path ([`BodyV2View::verify_lanes`]) —
//! the demand decode path relies on the store's whole-chunk CRC, keeping
//! lane fan-out pure win on the hot path.
//!
//! # Lane-count selection
//!
//! [`lane_count`] clamps the requested count to a power of two in
//! `1..=`[`MAX_LANES`], then halves while a lane would hold fewer than
//! [`MIN_VALUES_PER_LANE`] values — tiny chunks degrade gracefully down to
//! one lane (whose body costs exactly the v1 header: 12 header + 12
//! directory bytes vs. v1's 24-byte header), and every multi-lane body
//! guarantees `n >= lanes × MIN_VALUES_PER_LANE`, which
//! [`BodyV2View::parse`] re-checks as a directory-consistency invariant.
//!
//! # Decode paths
//!
//! Both paths run the round-major kernel driver
//! [`decode_jobs`](super::simd::decode_jobs) (DESIGN.md §13), which
//! advances every lane one value per round and dispatches per block of
//! lanes to a scalar loop or a runtime-detected SIMD tier — selectable
//! via [`DecodeKernel`] (`APACK_DECODE_KERNEL=scalar|simd`):
//!
//! - [`BodyV2View::decode_into`] / [`BodyV2View::decode_into_with`] —
//!   single-thread decode over all lanes at once: `HI`/`LO`/`CODE` live
//!   in struct-of-arrays lane state, so the kernel advances up to a full
//!   vector width of lanes per iteration.
//! - [`BodyV2View::decode_into_threaded`] /
//!   [`BodyV2View::decode_into_threaded_with`] — partitions the lanes
//!   into contiguous groups (one per worker) on
//!   [`crate::util::par_map_owned_with`] threads; **each worker runs the
//!   same kernel** over its lane group, so SIMD and threading compose.
//!   Returns the summed per-worker decode nanos so callers (the store
//!   reader's heatmap) can attribute actual decode cost rather than
//!   caller wall time.
//!
//! Both are bit-exact with per-lane sequential decode, including
//! `CorruptStream` positions: a lane-`l` corruption at within-lane value
//! `p` surfaces at global position `lane_range(..).start + p`.

use super::bitstream::BitReader;
use super::encoder::ApackEncoder;
use super::simd::{decode_jobs, DecodeKernel, LaneJob};
use super::table::SymbolTable;
use crate::error::{Error, Result};
use crate::obs::{self, Stage};
use crate::store::format::crc32;
use crate::util::par_map_owned_with;

use std::ops::Range;
use std::time::Instant;

/// Default lane count for new v2 bodies (the paper's hardware deploys 16
/// decoder lanes per engine cluster; the hot-path bench sweeps 1..64).
pub const DEFAULT_LANES: u8 = 16;

/// Hard cap on lanes per chunk body (keeps the directory and the decoder's
/// fixed lane-state arrays small).
pub const MAX_LANES: u8 = 64;

/// Minimum values per lane before [`lane_count`] halves the lane count:
/// below this, per-lane coder flush + directory overhead stops paying for
/// the parallelism.
pub const MIN_VALUES_PER_LANE: usize = 1024;

/// First body byte of every v2 chunk blob (v1 bodies start with a
/// `n_values u64` little-endian header instead; the store dispatches on
/// the footer's per-tensor `body_version`, never by sniffing).
pub const BODY_V2_VERSION: u8 = 2;

/// Fixed v2 body header: `version u8 | lanes u8 | pad u16 | n_values u64`.
pub const HEADER_BYTES: usize = 12;

/// One directory entry: `sym_bits u32 | ofs_bits u32 | crc32 u32`.
pub const DIR_ENTRY_BYTES: usize = 12;

/// Effective lane count for `n` values at a requested lane count: the
/// request rounds *down* to a power of two clamped to `1..=`[`MAX_LANES`],
/// then halves while any lane would hold fewer than
/// [`MIN_VALUES_PER_LANE`] values. Guarantees the result is a power of
/// two and that `result == 1 || n >= result × MIN_VALUES_PER_LANE`.
pub fn lane_count(n: usize, requested: u8) -> u8 {
    let capped = requested.clamp(1, MAX_LANES);
    // Largest power of two <= capped (capped >= 1, so this never shifts
    // past the width).
    let mut lanes = 1u8 << (7 - capped.leading_zeros());
    while lanes > 1 && n < lanes as usize * MIN_VALUES_PER_LANE {
        lanes /= 2;
    }
    lanes
}

/// Value-index range of lane `lane` in the deterministic contiguous split
/// of `n` values across `lanes` lanes: lane `l` gets `n / lanes` values,
/// plus one extra for the first `n % lanes` lanes. This function is the
/// *only* definition of the split — encoder, both decoders, and the
/// verify path all derive per-lane counts from it, which is what lets the
/// directory omit them.
pub fn lane_range(n: usize, lanes: usize, lane: usize) -> Range<usize> {
    debug_assert!(lane < lanes);
    let q = n / lanes;
    let r = n % lanes;
    let start = lane * q + lane.min(r);
    let len = q + usize::from(lane < r);
    start..start + len
}

/// Encode `values` as a v2 chunk body with (up to) `requested_lanes`
/// lanes, all sharing `table`. The effective lane count is
/// [`lane_count`]`(values.len(), requested_lanes)` and is recorded in the
/// body header. Each lane is an independent [`ApackEncoder`] run over its
/// [`lane_range`] slice.
pub fn encode_body_v2(
    table: &SymbolTable,
    values: &[u32],
    requested_lanes: u8,
) -> Result<Vec<u8>> {
    let n = values.len();
    let lanes = lane_count(n, requested_lanes) as usize;

    let mut dir = Vec::with_capacity(lanes * DIR_ENTRY_BYTES);
    let mut payload = Vec::new();
    for l in 0..lanes {
        let r = lane_range(n, lanes, l);
        let (sym, sym_bits, ofs, ofs_bits) = ApackEncoder::encode_all(table, &values[r])?;
        if sym_bits > u32::MAX as usize || ofs_bits > u32::MAX as usize {
            return Err(Error::BadContainer(format!(
                "lane {l} stream exceeds the u32 bit-length directory field \
                 ({sym_bits} sym bits, {ofs_bits} ofs bits)"
            )));
        }
        let start = payload.len();
        payload.extend_from_slice(&sym);
        payload.extend_from_slice(&ofs);
        dir.extend_from_slice(&(sym_bits as u32).to_le_bytes());
        dir.extend_from_slice(&(ofs_bits as u32).to_le_bytes());
        dir.extend_from_slice(&crc32(&payload[start..]).to_le_bytes());
    }

    let mut out = Vec::with_capacity(HEADER_BYTES + dir.len() + payload.len());
    out.push(BODY_V2_VERSION);
    out.push(lanes as u8);
    out.extend_from_slice(&[0u8; 2]); // pad
    out.extend_from_slice(&(n as u64).to_le_bytes());
    out.extend_from_slice(&dir);
    out.extend_from_slice(&payload);
    Ok(out)
}

/// One parsed directory entry plus its resolved payload offset.
#[derive(Debug, Clone, Copy, Default)]
struct LaneEntry {
    sym_bits: u32,
    ofs_bits: u32,
    crc: u32,
    /// Byte offset of this lane's payload (symbols then offsets) within
    /// the body's payload region.
    start: usize,
}

impl LaneEntry {
    #[inline]
    fn sym_len(&self) -> usize {
        (self.sym_bits as usize).div_ceil(8)
    }
    #[inline]
    fn ofs_len(&self) -> usize {
        (self.ofs_bits as usize).div_ceil(8)
    }
}

/// A parsed-but-borrowed v2 body: directory in fixed arrays, payload as a
/// slice of the caller's buffer (e.g. an mmap'd store chunk) — the v2
/// mirror of [`super::container::BodyView`], allocation-free to parse.
#[derive(Debug, Clone, Copy)]
pub struct BodyV2View<'a> {
    /// Total values across all lanes.
    pub n_values: u64,
    lanes: usize,
    entries: [LaneEntry; MAX_LANES as usize],
    payload: &'a [u8],
}

impl<'a> BodyV2View<'a> {
    /// Parse an [`encode_body_v2`] record without copying the streams.
    /// Exact-length framing (slack or truncation is rejected) plus
    /// directory-consistency checks: version byte, power-of-two lane
    /// count within bounds, zero pad, and the [`lane_count`] invariant
    /// `lanes == 1 || n_values >= lanes × MIN_VALUES_PER_LANE`.
    pub fn parse(data: &'a [u8]) -> Result<Self> {
        let err = |m: String| Error::BadContainer(m);
        if data.len() < HEADER_BYTES {
            return Err(err("truncated v2 body header".into()));
        }
        if data[0] != BODY_V2_VERSION {
            return Err(err(format!("bad v2 body version byte {}", data[0])));
        }
        let lanes = data[1] as usize;
        if lanes == 0 || lanes > MAX_LANES as usize || !lanes.is_power_of_two() {
            return Err(err(format!("bad v2 lane count {lanes}")));
        }
        if data[2] != 0 || data[3] != 0 {
            return Err(err("nonzero v2 header pad".into()));
        }
        let n_values = u64::from_le_bytes(data[4..12].try_into().unwrap());
        if lanes > 1 && (n_values as usize) < lanes * MIN_VALUES_PER_LANE {
            return Err(err(format!(
                "v2 directory inconsistent: {lanes} lanes over {n_values} values \
                 violates the {MIN_VALUES_PER_LANE}-values-per-lane floor"
            )));
        }
        let dir_end = HEADER_BYTES + lanes * DIR_ENTRY_BYTES;
        if data.len() < dir_end {
            return Err(err("truncated v2 lane directory".into()));
        }
        let mut entries = [LaneEntry::default(); MAX_LANES as usize];
        let mut offset = 0usize;
        for (l, e) in entries.iter_mut().enumerate().take(lanes) {
            let at = HEADER_BYTES + l * DIR_ENTRY_BYTES;
            e.sym_bits = u32::from_le_bytes(data[at..at + 4].try_into().unwrap());
            e.ofs_bits = u32::from_le_bytes(data[at + 4..at + 8].try_into().unwrap());
            e.crc = u32::from_le_bytes(data[at + 8..at + 12].try_into().unwrap());
            e.start = offset;
            offset = offset
                .checked_add(e.sym_len() + e.ofs_len())
                .ok_or_else(|| err("v2 lane payload lengths overflow".into()))?;
        }
        let expected = dir_end
            .checked_add(offset)
            .ok_or_else(|| err("v2 body length overflows".into()))?;
        if data.len() != expected {
            return Err(err(format!(
                "v2 body length mismatch: {} bytes, expected {expected}",
                data.len()
            )));
        }
        Ok(Self { n_values, lanes, entries, payload: &data[dir_end..] })
    }

    /// Lane count recorded in the header.
    #[inline]
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Header + directory bytes (the v2 framing overhead the zoo matrix
    /// test bounds against payload bytes).
    #[inline]
    pub fn directory_bytes(&self) -> usize {
        HEADER_BYTES + self.lanes * DIR_ENTRY_BYTES
    }

    /// Value-index range lane `l` decodes to (the deterministic split).
    #[inline]
    pub fn lane_values(&self, l: usize) -> Range<usize> {
        lane_range(self.n_values as usize, self.lanes, l)
    }

    /// Lane `l`'s `(symbols, offsets)` payload slices.
    #[inline]
    fn lane_streams(&self, l: usize) -> (&'a [u8], &'a [u8]) {
        let e = &self.entries[l];
        let sym = &self.payload[e.start..e.start + e.sym_len()];
        let ofs = &self.payload[e.start + e.sym_len()..e.start + e.sym_len() + e.ofs_len()];
        (sym, ofs)
    }

    /// Check every lane's payload CRC32 — the `verify` path's lane-granular
    /// corruption localization (the store's whole-chunk CRC says *that* a
    /// chunk is bad; this says *which lane*). A mismatch in lane `k`
    /// surfaces as `CorruptStream` positioned at that lane's first value —
    /// a stable position independent of where inside the lane the bytes
    /// were damaged.
    pub fn verify_lanes(&self) -> Result<()> {
        for l in 0..self.lanes {
            let e = &self.entries[l];
            let bytes = &self.payload[e.start..e.start + e.sym_len() + e.ofs_len()];
            if crc32(bytes) != e.crc {
                return Err(Error::CorruptStream { position: self.lane_values(l).start });
            }
        }
        Ok(())
    }

    /// Build one [`LaneJob`] per lane over disjoint sub-slices of `out`
    /// (the [`lane_range`] split), each with fresh bit cursors. The jobs
    /// carry non-increasing output lengths — the active-prefix invariant
    /// [`decode_jobs`] relies on — and that also holds for any contiguous
    /// subsequence, which is what lets the threaded path hand contiguous
    /// lane groups to workers.
    fn lane_jobs<'o>(&self, out: &'o mut [u32]) -> Vec<LaneJob<'a, 'o>> {
        let n = out.len();
        let mut jobs = Vec::with_capacity(self.lanes);
        let mut rest = out;
        for l in 0..self.lanes {
            let e = &self.entries[l];
            let (sym, ofs) = self.lane_streams(l);
            let r = lane_range(n, self.lanes, l);
            let (head, tail) = rest.split_at_mut(r.len());
            jobs.push(LaneJob {
                sym: BitReader::new(sym, e.sym_bits as usize),
                ofs: BitReader::new(ofs, e.ofs_bits as usize),
                out: head,
                base: r.start,
            });
            rest = tail;
        }
        debug_assert!(rest.is_empty());
        jobs
    }

    /// Single-thread lane-parallel decode with the process-default kernel
    /// ([`DecodeKernel::auto`]). See [`Self::decode_into_with`].
    pub fn decode_into(&self, table: &SymbolTable, out: &mut [u32]) -> Result<()> {
        self.decode_into_with(table, out, DecodeKernel::auto())
    }

    /// Single-thread lane-parallel decode: struct-of-arrays lane state,
    /// round-major [`decode_jobs`] driver advancing every lane one value
    /// per round with the chosen kernel (scalar loop or runtime-detected
    /// SIMD tier), bit-identical either way and to every
    /// [`super::decoder::ResolveMode`] (DESIGN.md invariant 3). Emits one
    /// `Decode` span with a `DecodeLanes` child carrying the lane count
    /// and tagged with the active kernel label, so traces and profiles
    /// attribute the fan-out to the loop that actually ran.
    pub fn decode_into_with(
        &self,
        table: &SymbolTable,
        out: &mut [u32],
        kernel: DecodeKernel,
    ) -> Result<()> {
        if out.len() as u64 != self.n_values {
            return Err(Error::BadContainer(format!(
                "decode_into slice holds {} values, v2 body has {}",
                out.len(),
                self.n_values
            )));
        }
        let _span = obs::span_n(Stage::Decode, out.len() as u64);
        let _fan =
            obs::span_n_tagged(Stage::DecodeLanes, self.lanes as u64, kernel.active_label());
        let mut jobs = self.lane_jobs(out);
        decode_jobs(kernel, table, &mut jobs)
    }

    /// Threaded lane decode with the process-default kernel. See
    /// [`Self::decode_into_threaded_with`].
    pub fn decode_into_threaded(
        &self,
        table: &SymbolTable,
        out: &mut [u32],
        threads: usize,
    ) -> Result<u64> {
        self.decode_into_threaded_with(table, out, threads, DecodeKernel::auto())
    }

    /// Threaded lane decode: the lanes split into contiguous groups (one
    /// per worker, `threads == 0` uses the machine's parallelism, capped
    /// at the lane count) and each worker runs the same [`decode_jobs`]
    /// kernel over its group's disjoint output sub-slices — SIMD inside
    /// each worker, workers in parallel. Bit-identical to
    /// [`Self::decode_into_with`]; on corruption the first failing lane
    /// *in group order* is reported, its position rebased to the lane's
    /// start. Opens the `DecodeLanes` span (tagged with the kernel label)
    /// on the calling thread and threads its id to the workers
    /// ([`obs::with_parent`]), so each group's `Decode` span lands as a
    /// child of `DecodeLanes` — span-forest coverage holds on the lane
    /// path. Returns the **summed worker decode nanos** (actual lane
    /// work, not caller wall time) for heatmap attribution.
    pub fn decode_into_threaded_with(
        &self,
        table: &SymbolTable,
        out: &mut [u32],
        threads: usize,
        kernel: DecodeKernel,
    ) -> Result<u64> {
        if out.len() as u64 != self.n_values {
            return Err(Error::BadContainer(format!(
                "decode_into_threaded slice holds {} values, v2 body has {}",
                out.len(),
                self.n_values
            )));
        }
        // Cross-thread fan-out span: begun here, finished after the
        // workers join; its id parents every worker-group Decode span.
        let fan = obs::ManualSpan::begin_tagged(Stage::DecodeLanes, kernel.active_label());
        let fan_id = fan.as_ref().map(|s| s.id()).unwrap_or(0);
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|t| t.get()).unwrap_or(4)
        } else {
            threads
        };
        let threads = threads.min(self.lanes).max(1);

        let mut jobs = self.lane_jobs(out);
        let group_size = self.lanes.div_ceil(threads);
        let mut groups: Vec<Vec<LaneJob<'_, '_>>> = Vec::with_capacity(threads);
        while jobs.len() > group_size {
            let tail = jobs.split_off(group_size);
            groups.push(std::mem::replace(&mut jobs, tail));
        }
        groups.push(jobs);

        let result = par_map_owned_with(groups, threads, |mut group| -> Result<u64> {
            obs::with_parent(fan_id, || {
                let vals: u64 = group.iter().map(|j| j.out.len() as u64).sum();
                let _span = obs::span_n_tagged(Stage::Decode, vals, kernel.active_label());
                let t0 = Instant::now();
                decode_jobs(kernel, table, &mut group)?;
                Ok(t0.elapsed().as_nanos() as u64)
            })
        })
        .into_iter()
        .collect::<Result<Vec<u64>>>()
        .map(|nanos| nanos.iter().sum());
        if let Some(f) = fan {
            f.finish_with(self.lanes as u64);
        }
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::container::encode_body;
    use crate::apack::container::BodyView;
    use crate::models::distributions::ValueProfile;

    fn tensor(n: usize, seed: u64) -> Vec<u32> {
        ValueProfile::ReluActivation { sparsity: 0.5, q: 0.93, noise_floor: 0.01 }
            .sample(8, n, seed)
    }

    fn table_for(values: &[u32]) -> SymbolTable {
        crate::apack::tablegen::table_for_tensor(
            8,
            values,
            crate::apack::tablegen::TensorKind::Activations,
        )
        .unwrap()
    }

    #[test]
    fn lane_count_selection() {
        // Requests round down to powers of two and clamp to MAX_LANES.
        assert_eq!(lane_count(1 << 20, 16), 16);
        assert_eq!(lane_count(1 << 20, 17), 16);
        assert_eq!(lane_count(1 << 20, 31), 16);
        assert_eq!(lane_count(1 << 20, 255), 64);
        assert_eq!(lane_count(1 << 20, 0), 1);
        // Tiny chunks degrade: each lane keeps >= MIN_VALUES_PER_LANE.
        assert_eq!(lane_count(16 * MIN_VALUES_PER_LANE, 16), 16);
        assert_eq!(lane_count(16 * MIN_VALUES_PER_LANE - 1, 16), 8);
        assert_eq!(lane_count(MIN_VALUES_PER_LANE, 16), 1);
        assert_eq!(lane_count(MIN_VALUES_PER_LANE - 1, 16), 1);
        assert_eq!(lane_count(0, 64), 1);
        for n in [0usize, 1, 1023, 1024, 4096, 100_000] {
            for req in 1..=255u8 {
                let l = lane_count(n, req);
                assert!(l.is_power_of_two() && l <= MAX_LANES);
                assert!(l == 1 || n >= l as usize * MIN_VALUES_PER_LANE, "n={n} req={req}");
            }
        }
    }

    #[test]
    fn lane_ranges_tile_exactly() {
        for n in [0usize, 1, 5, 16, 17, 1024, 12_345] {
            for lanes in [1usize, 2, 4, 8, 16, 64] {
                let mut next = 0usize;
                for l in 0..lanes {
                    let r = lane_range(n, lanes, l);
                    assert_eq!(r.start, next, "n={n} lanes={lanes} l={l}");
                    next = r.end;
                }
                assert_eq!(next, n);
            }
        }
    }

    #[test]
    fn v2_roundtrip_single_and_multi_lane() {
        for n in [0usize, 1, 100, 1024, 20_000] {
            let values = tensor(n.max(1), 9);
            let values = &values[..n];
            let table = table_for(&tensor(4096, 9));
            let body = encode_body_v2(&table, values, DEFAULT_LANES).unwrap();
            let view = BodyV2View::parse(&body).unwrap();
            assert_eq!(view.n_values as usize, n);
            assert_eq!(view.lanes(), lane_count(n, DEFAULT_LANES) as usize);
            view.verify_lanes().unwrap();
            let mut soa = vec![0u32; n];
            view.decode_into(&table, &mut soa).unwrap();
            assert_eq!(soa, values);
            let mut thr = vec![0u32; n];
            view.decode_into_threaded(&table, &mut thr, 0).unwrap();
            assert_eq!(thr, values);
        }
    }

    #[test]
    fn v2_single_lane_body_is_v1_sized() {
        // One lane: 12-byte header + 12-byte directory == v1's 24-byte
        // header, and the streams are the very same encoder output.
        let values = tensor(500, 3);
        let table = table_for(&values);
        let v1 = encode_body(&table, &values).unwrap();
        let v2 = encode_body_v2(&table, &values, 16).unwrap();
        assert_eq!(v2.len(), v1.len());
        assert_eq!(&v2[HEADER_BYTES + DIR_ENTRY_BYTES..], &v1[24..]);
    }

    #[test]
    fn v2_matches_v1_decode_bit_exactly() {
        let values = tensor(40_000, 11);
        let table = table_for(&values);
        let v1 = encode_body(&table, &values).unwrap();
        let v2 = encode_body_v2(&table, &values, 16).unwrap();
        let mut from_v1 = vec![0u32; values.len()];
        BodyView::parse(&v1).unwrap().decode_into(&table, &mut from_v1).unwrap();
        let mut from_v2 = vec![0u32; values.len()];
        BodyV2View::parse(&v2).unwrap().decode_into(&table, &mut from_v2).unwrap();
        assert_eq!(from_v1, values);
        assert_eq!(from_v2, values);
    }

    #[test]
    fn parse_rejects_malformed_bodies() {
        let values = tensor(20_000, 5);
        let table = table_for(&values);
        let body = encode_body_v2(&table, &values, 16).unwrap();
        assert!(BodyV2View::parse(&body[..HEADER_BYTES - 1]).is_err());
        assert!(BodyV2View::parse(&body[..body.len() - 1]).is_err(), "truncated");
        let mut long = body.clone();
        long.push(0);
        assert!(BodyV2View::parse(&long).is_err(), "slack");
        let mut bad_version = body.clone();
        bad_version[0] = 1;
        assert!(BodyV2View::parse(&bad_version).is_err());
        let mut bad_lanes = body.clone();
        bad_lanes[1] = 3; // not a power of two
        assert!(BodyV2View::parse(&bad_lanes).is_err());
        let mut bad_pad = body.clone();
        bad_pad[2] = 1;
        assert!(BodyV2View::parse(&bad_pad).is_err());
        // Directory inconsistency: 16 lanes over too few values.
        let mut starved = body.clone();
        starved[4..12].copy_from_slice(&100u64.to_le_bytes());
        assert!(BodyV2View::parse(&starved).is_err());
    }

    #[test]
    fn corrupt_offset_stream_positions_match_across_decoders() {
        // Truncate the last lane's offset stream so its final offset read
        // fails: SoA and threaded decode must report the same global
        // CorruptStream position as sequential per-lane decode would.
        let values = tensor(20_000, 13);
        let table = table_for(&values);
        let body = encode_body_v2(&table, &values, 4).unwrap();
        let view = BodyV2View::parse(&body).unwrap();
        assert_eq!(view.lanes(), 4);
        // Rewrite lane 3's ofs_bits down by the final row read; easiest
        // robust corruption: zero out lane 3's directory ofs_bits so every
        // offset read in that lane fails immediately (if the lane reads
        // offsets at all — with a ReLU profile at 8 bits it always does).
        let mut cut = body.clone();
        let at = HEADER_BYTES + 3 * DIR_ENTRY_BYTES;
        // Keep framing consistent: shrink ofs_bits to 0 *and* drop that
        // lane's offset bytes from the payload tail.
        let e_ofs_bits =
            u32::from_le_bytes(cut[at + 4..at + 8].try_into().unwrap()) as usize;
        let drop = e_ofs_bits.div_ceil(8);
        cut[at + 4..at + 8].copy_from_slice(&0u32.to_le_bytes());
        cut.truncate(cut.len() - drop);
        let view = BodyV2View::parse(&cut).unwrap();

        let mut out = vec![0u32; values.len()];
        let soa = view.decode_into(&table, &mut out).unwrap_err();
        let mut out = vec![0u32; values.len()];
        let thr = view.decode_into_threaded(&table, &mut out, 2).unwrap_err();
        let (Error::CorruptStream { position: p_soa }, Error::CorruptStream { position: p_thr }) =
            (&soa, &thr)
        else {
            panic!("expected CorruptStream, got {soa:?} / {thr:?}");
        };
        assert_eq!(p_soa, p_thr);
        let lane3 = lane_range(values.len(), 4, 3);
        assert!(lane3.contains(p_soa), "position {p_soa} outside lane 3 {lane3:?}");
    }

    #[test]
    fn kernel_knob_is_bit_exact_across_paths() {
        let values = tensor(30_000, 33);
        let table = table_for(&values);
        let body = encode_body_v2(&table, &values, 8).unwrap();
        let view = BodyV2View::parse(&body).unwrap();
        for kernel in [DecodeKernel::Scalar, DecodeKernel::Simd] {
            let mut soa = vec![0u32; values.len()];
            view.decode_into_with(&table, &mut soa, kernel).unwrap();
            assert_eq!(soa, values, "kernel {kernel:?} single-thread");
            let mut thr = vec![0u32; values.len()];
            let nanos = view.decode_into_threaded_with(&table, &mut thr, 3, kernel).unwrap();
            assert_eq!(thr, values, "kernel {kernel:?} threaded");
            assert!(nanos > 0, "threaded decode must report worker nanos");
        }
    }

    #[test]
    fn flipped_bit_in_lane_k_fails_lane_k_crc_at_stable_position() {
        let values = tensor(20_000, 21);
        let table = table_for(&values);
        let body = encode_body_v2(&table, &values, 16).unwrap();
        let view = BodyV2View::parse(&body).unwrap();
        let lanes = view.lanes();
        assert!(lanes >= 16);
        let dir_end = HEADER_BYTES + lanes * DIR_ENTRY_BYTES;
        for k in 0..lanes {
            let e = view.entries[k];
            let mid = dir_end + e.start + (e.sym_len() + e.ofs_len()) / 2;
            let mut bad = body.clone();
            bad[mid] ^= 0x10;
            let bad_view = BodyV2View::parse(&bad).unwrap();
            let err = bad_view.verify_lanes().unwrap_err();
            let Error::CorruptStream { position } = err else {
                panic!("lane {k}: expected CorruptStream, got {err:?}");
            };
            assert_eq!(
                position,
                lane_range(values.len(), lanes, k).start,
                "lane {k} CRC failure must surface at that lane's first value"
            );
        }
        view.verify_lanes().unwrap();
    }
}
