//! Bit-serial hardware reference model of the APack encoder/decoder.
//!
//! The paper's Fig 3/4 hardware performs all the updates of one value in a
//! single combinatorial step; Nelson's software formulation (which the
//! paper says APack's coder is based on) "updates and produces one bit at
//! a time". This module implements that one-bit-per-step formulation with
//! the registers named exactly as in the figures (HI, LO, CODE, UBC) and
//! each micro-step made explicit, serving as the *reference semantics*
//! against which the optimized [`super::encoder`]/[`super::decoder`]
//! (which batch common-prefix bits) are property-tested for bit-exact
//! equivalence (DESIGN.md invariant 3 extended).
//!
//! It is deliberately unoptimized — clarity over speed — and is also used
//! by the engine cycle model's micro-step statistics (bits emitted per
//! value drive the pipelined engine's occupancy).

use super::bitstream::{BitReader, BitWriter};
use super::table::{SymbolTable, PROB_BITS};
use super::NUM_ROWS;
use crate::error::{Error, Result};

const TOP_BIT: u16 = 0x8000;
const SECOND_BIT: u16 = 0x4000;

/// Per-value micro-step statistics (consumed by the engine model).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepStats {
    /// Common-prefix bits written to the symbol stream this value.
    pub prefix_bits: u32,
    /// Underflow bits recorded (entered UBC) this value.
    pub underflow_bits: u32,
    /// Offset bits written this value.
    pub offset_bits: u32,
}

/// The bit-serial encoder: registers as in paper Fig 3.
#[derive(Debug, Clone)]
pub struct BitSerialEncoder<'t> {
    table: &'t SymbolTable,
    cum: [u16; NUM_ROWS + 1],
    /// 16-bit HI register (initialized 0xFFFF).
    pub hi: u16,
    /// 16-bit LO register (initialized 0x0000).
    pub lo: u16,
    /// 5-bit underflow bit counter.
    pub ubc: u32,
}

impl<'t> BitSerialEncoder<'t> {
    /// New encoder over a validated table.
    pub fn new(table: &'t SymbolTable) -> Self {
        let mut cum = [0u16; NUM_ROWS + 1];
        for i in 0..NUM_ROWS {
            cum[i + 1] = table.rows()[i].hi_cnt;
        }
        Self { table, cum, hi: 0xFFFF, lo: 0x0000, ubc: 0 }
    }

    /// Encode one value, one register-transfer micro-step at a time.
    pub fn encode_value(
        &mut self,
        v: u32,
        sym_out: &mut BitWriter,
        ofs_out: &mut BitWriter,
    ) -> Result<StepStats> {
        let mut stats = StepStats::default();

        // SYMBOL Lookup (Fig 3b): 16 parallel comparators; the matching
        // row is the last whose v_min <= IN.
        let idx = self.table.lookup(v)?;
        let row = self.table.rows()[idx];
        let (c_lo, c_hi) = (self.cum[idx], self.cum[idx + 1]);
        if c_hi == c_lo {
            return Err(Error::ValueNotCovered(v));
        }
        // Offset: IN - base, trimmed by the mask block to `ob` bits.
        if row.ol > 0 {
            ofs_out.push_bits((v - row.v_min) as u64, row.ol);
            stats.offset_bits = row.ol;
        }

        // PCNT Table (Fig 3c): scale boundaries with the current range,
        // dropping the low PROB_BITS partial products.
        let range = (self.hi - self.lo) as u32 + 1;
        let s_hi = (range * c_hi as u32) >> PROB_BITS;
        let s_lo = (range * c_lo as u32) >> PROB_BITS;

        // HI/LO/CODE Gen (Fig 3d): offset into position.
        let mut t_hi = (self.lo as u32 + s_hi - 1) as u16;
        let mut t_lo = (self.lo as u32 + s_lo) as u16;

        // One bit per micro-step, exactly Nelson's loop.
        loop {
            if (t_hi ^ t_lo) & TOP_BIT == 0 {
                // Common Prefix Detection: XOR + LD1 found MSb equal.
                let bit = t_hi & TOP_BIT != 0;
                sym_out.push_bit(bit);
                stats.prefix_bits += 1;
                // Flush pending underflow bits as the inverse of the bit.
                while self.ubc > 0 {
                    sym_out.push_bit(!bit);
                    self.ubc -= 1;
                }
            } else if t_lo & SECOND_BIT != 0 && t_hi & SECOND_BIT == 0 {
                // 01PREFIX: record one underflow bit, drop second MSbs.
                self.ubc += 1;
                stats.underflow_bits += 1;
                t_lo &= SECOND_BIT - 1;
                t_hi |= SECOND_BIT;
            } else {
                break;
            }
            // Final HI and LO generation: slide the 16-bit windows.
            t_lo <<= 1;
            t_hi = (t_hi << 1) | 1; // HI has an infinite suffix of 1s
        }
        self.hi = t_hi;
        self.lo = t_lo;
        Ok(stats)
    }

    /// Flush: second MSB of LO, then UBC+1 inverse bits (Nelson).
    pub fn finish(mut self, sym_out: &mut BitWriter) {
        let bit = self.lo & SECOND_BIT != 0;
        sym_out.push_bit(bit);
        self.ubc += 1;
        while self.ubc > 0 {
            sym_out.push_bit(!bit);
            self.ubc -= 1;
        }
    }
}

/// The bit-serial decoder: registers as in paper Fig 4.
#[derive(Debug, Clone)]
pub struct BitSerialDecoder<'t, 'a> {
    table: &'t SymbolTable,
    cum: [u16; NUM_ROWS + 1],
    pub hi: u16,
    pub lo: u16,
    /// 16-bit CODE register sliding over the encoded symbol stream.
    pub code: u16,
    sym_in: BitReader<'a>,
    count: usize,
}

impl<'t, 'a> BitSerialDecoder<'t, 'a> {
    /// Prime CODE with 16 stream bits.
    pub fn new(table: &'t SymbolTable, mut sym_in: BitReader<'a>) -> Self {
        let mut cum = [0u16; NUM_ROWS + 1];
        for i in 0..NUM_ROWS {
            cum[i + 1] = table.rows()[i].hi_cnt;
        }
        let code = sym_in.read_bits(16) as u16;
        Self { table, cum, hi: 0xFFFF, lo: 0x0000, code, sym_in, count: 0 }
    }

    /// Decode one value, one micro-step at a time.
    pub fn decode_value(&mut self, ofs_in: &mut BitReader<'_>) -> Result<u32> {
        // PCNT Table (Fig 4b): 16 parallel scaled-boundary comparisons.
        // `wrapping_sub` keeps a corrupt CODE < LO a detectable huge `d`
        // instead of a debug-build panic, as in the optimized decoder.
        let range = (self.hi - self.lo) as u32 + 1;
        let d = self.code.wrapping_sub(self.lo) as u32;
        let mut found = None;
        for i in 0..NUM_ROWS {
            let s_lo = (range * self.cum[i] as u32) >> PROB_BITS;
            let s_hi = (range * self.cum[i + 1] as u32) >> PROB_BITS;
            if s_hi > s_lo && d >= s_lo && d < s_hi {
                found = Some((i, s_lo, s_hi));
                break;
            }
        }
        let (idx, s_lo, s_hi) =
            found.ok_or(Error::CorruptStream { position: self.count })?;

        // SYMBOL Gen (Fig 4c): base + offset. Same contract as the
        // optimized decoder (DESIGN.md invariant 3): an exhausted offset
        // stream is a corrupt stream, never fabricated zero offsets.
        let row = self.table.rows()[idx];
        let offset = if row.ol > 0 {
            if ofs_in.bits_remaining() < row.ol as usize {
                return Err(Error::CorruptStream { position: self.count });
            }
            ofs_in.read_bits(row.ol) as u32
        } else {
            0
        };
        let value = row.v_min + offset;
        if value > row.v_max {
            return Err(Error::CorruptStream { position: self.count });
        }

        // HI/LO/CODE Adj (Fig 4d).
        let mut t_hi = (self.lo as u32 + s_hi - 1) as u16;
        let mut t_lo = (self.lo as u32 + s_lo) as u16;
        let mut code = self.code;
        loop {
            if (t_hi ^ t_lo) & TOP_BIT == 0 {
                // discard the shared MSb
            } else if t_lo & SECOND_BIT != 0 && t_hi & SECOND_BIT == 0 {
                code ^= SECOND_BIT;
                t_lo &= SECOND_BIT - 1;
                t_hi |= SECOND_BIT;
            } else {
                break;
            }
            t_lo <<= 1;
            t_hi = (t_hi << 1) | 1;
            code = (code << 1) | self.sym_in.read_bit() as u16;
        }
        self.hi = t_hi;
        self.lo = t_lo;
        self.code = code;
        self.count += 1;
        Ok(value)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apack::decoder::ApackDecoder;
    use crate::apack::encoder::ApackEncoder;
    use crate::apack::tablegen::{table_for_tensor, TensorKind};
    use crate::util::Rng64;

    fn tensor(seed: u64, n: usize) -> Vec<u32> {
        let mut rng = Rng64::new(seed);
        (0..n)
            .map(|_| match rng.below(4) {
                0 => 0,
                1 => 255 - rng.below(4) as u32,
                _ => rng.below(256) as u32,
            })
            .collect()
    }

    /// The optimized encoder's stream is bit-for-bit identical with the
    /// bit-serial reference.
    #[test]
    fn optimized_encoder_is_bit_exact_with_reference() {
        for seed in 0..10u64 {
            let values = tensor(seed, 3000);
            let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();

            let mut ref_enc = BitSerialEncoder::new(&t);
            let mut rs = BitWriter::new();
            let mut ro = BitWriter::new();
            for &v in &values {
                ref_enc.encode_value(v, &mut rs, &mut ro).unwrap();
            }
            ref_enc.finish(&mut rs);
            let (ref_sym, ref_sb) = rs.finish();
            let (ref_ofs, ref_ob) = ro.finish();

            let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
            assert_eq!((sb, ob), (ref_sb, ref_ob), "seed {seed}: stream lengths");
            assert_eq!(sym, ref_sym, "seed {seed}: symbol stream");
            assert_eq!(ofs, ref_ofs, "seed {seed}: offset stream");
        }
    }

    /// Cross-decoding: reference decoder reads optimized-encoder streams
    /// and vice versa.
    #[test]
    fn cross_decode_reference_and_optimized() {
        for seed in 20..26u64 {
            let values = tensor(seed, 2000);
            let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
            let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();

            // Reference decoder on optimized stream.
            let mut rd = BitSerialDecoder::new(&t, BitReader::new(&sym, sb));
            let mut ofs_r = BitReader::new(&ofs, ob);
            for (i, &v) in values.iter().enumerate() {
                assert_eq!(rd.decode_value(&mut ofs_r).unwrap(), v, "seed {seed} idx {i}");
            }

            // Optimized decoder on reference stream (already known equal,
            // but assert the full path anyway).
            let mut od = ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap();
            let mut ofs_r = BitReader::new(&ofs, ob);
            for &v in &values {
                assert_eq!(od.decode_value(&mut ofs_r).unwrap(), v);
            }
        }
    }

    /// Corrupt-stream contract matches the optimized decoder (DESIGN.md
    /// invariant 3): on a truncated offset stream, the bit-serial
    /// reference errors with `CorruptStream` at the same position instead
    /// of fabricating zero offsets.
    #[test]
    fn reference_decoder_corrupt_positions_match_optimized() {
        let values = tensor(31, 2000);
        let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
        let (sym, sb, ofs, ob) = ApackEncoder::encode_all(&t, &values).unwrap();
        assert!(ob > 0);
        let truncated = ob / 3;

        let outcome = |decode: &mut dyn FnMut(&mut BitReader<'_>) -> Result<u32>| {
            let mut ofs_r = BitReader::new(&ofs, truncated);
            for i in 0..values.len() {
                match decode(&mut ofs_r) {
                    Ok(_) => {}
                    Err(Error::CorruptStream { position }) => return (i, position),
                    Err(e) => panic!("unexpected error {e}"),
                }
            }
            panic!("truncated offsets must error");
        };
        let mut rd = BitSerialDecoder::new(&t, BitReader::new(&sym, sb));
        let reference = outcome(&mut |o| rd.decode_value(o));
        let mut od = ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap();
        let optimized = outcome(&mut |o| od.decode_value(o));
        assert_eq!(reference, optimized);
    }

    /// Register trajectories match: after each value, (HI, LO, UBC) of the
    /// reference equals the optimized encoder's internal state.
    #[test]
    fn register_trajectories_match() {
        let values = tensor(77, 1500);
        let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
        let mut a = BitSerialEncoder::new(&t);
        let mut b = ApackEncoder::new(&t);
        let (mut s1, mut o1, mut s2, mut o2) =
            (BitWriter::new(), BitWriter::new(), BitWriter::new(), BitWriter::new());
        for (i, &v) in values.iter().enumerate() {
            a.encode_value(v, &mut s1, &mut o1).unwrap();
            b.encode_value(v, &mut s2, &mut o2).unwrap();
            assert_eq!((a.hi, a.lo, a.ubc), (b.hi(), b.lo(), b.ubc()), "value {i}");
        }
    }

    /// Step statistics are conserved: prefix bits summed over values +
    /// flush equals the symbol stream length.
    #[test]
    fn step_stats_account_for_every_bit() {
        let values = tensor(5, 4000);
        let t = table_for_tensor(8, &values, TensorKind::Activations).unwrap();
        let mut enc = BitSerialEncoder::new(&t);
        let mut s = BitWriter::new();
        let mut o = BitWriter::new();
        let mut prefix = 0u64;
        let mut under = 0u64;
        let mut offs = 0u64;
        for &v in &values {
            let st = enc.encode_value(v, &mut s, &mut o).unwrap();
            prefix += st.prefix_bits as u64;
            under += st.underflow_bits as u64;
            offs += st.offset_bits as u64;
        }
        enc.finish(&mut s);
        let (_, sb) = s.finish();
        let (_, ob) = o.finish();
        // Every recorded underflow bit is written exactly once as an
        // inverse (after a later prefix bit, or at flush), so:
        // symbol stream = prefix + underflow + flush (1 bit + 1 inverse).
        assert_eq!(sb as u64, prefix + under + 2);
        assert_eq!(ob as u64, offs);
    }
}
