//! The APack symbol + probability-count table (paper §IV, Table I).
//!
//! The table partitions the `bits`-wide value space into [`NUM_ROWS`]
//! contiguous, non-overlapping ranges `[v_min, v_max]`. Every value `v` in a
//! range is encoded as the pair `(row index, v - v_min)` where the offset
//! takes `OL = ceil(log2(v_max - v_min + 1))` bits. Each row additionally
//! carries a probability-count range `[low, high)` over the 10-bit count
//! space; the arithmetic coder narrows its working interval proportionally
//! to that range.
//!
//! Matching the hardware (§V), only `v_max` (as `base`), `OL` and the
//! *exclusive high* count are stored per row; a row's low count is the
//! previous row's high (0 for row 0) and `v_min[i] = v_max[i-1] + 1`.


use std::sync::{Arc, OnceLock};

use super::NUM_ROWS;
use crate::error::{Error, Result};

/// Width of the probability counts in bits (paper: `m = 10`).
pub const PROB_BITS: u32 = 10;
/// The full probability-count span `(0x0, 0x3FF)` assigned across all rows
/// (paper §IV / Table I: the last row's high count is `0x3FF`).
pub const PROB_MAX: u16 = (1 << PROB_BITS) - 1; // 0x3FF

/// One row of the combined symbol/probability table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TableRow {
    /// Smallest value mapped to this row (inclusive).
    pub v_min: u32,
    /// Largest value mapped to this row (inclusive).
    pub v_max: u32,
    /// Offset length in bits for this row: `ceil(log2(v_max - v_min + 1))`.
    pub ol: u32,
    /// Exclusive high probability-count boundary. The row's count range is
    /// `[prev.hi_cnt, hi_cnt)`; an empty range (`hi_cnt == prev.hi_cnt`)
    /// marks a symbol that never occurs (Table I rows 4–12).
    pub hi_cnt: u16,
}

impl TableRow {
    /// Number of distinct values covered by this row.
    #[inline]
    pub fn span(&self) -> u32 {
        self.v_max - self.v_min + 1
    }
}

/// Offset length for a range covering `span` values.
#[inline]
pub fn offset_len(span: u32) -> u32 {
    debug_assert!(span >= 1);
    32 - (span - 1).leading_zeros()
}

/// Number of entries in the decoder's count→row LUT: one per point of the
/// probability-count space.
pub const COUNT_LUT_LEN: usize = 1 << PROB_BITS;

/// The full APack per-tensor table.
#[derive(Clone)]
pub struct SymbolTable {
    rows: [TableRow; NUM_ROWS],
    /// Value bit width this table was built for (4, 8, or 16 in the paper).
    bits: u32,
    /// Count→row LUT for the decoder's `ResolveMode::Lut` fast path: entry
    /// `k` is the index of the row whose `[lo_cnt, hi_cnt)` range contains
    /// `k`. Built once per table (the decode-side mirror of
    /// [`Self::value_lut`]), it turns symbol resolution into one 32-bit
    /// division plus one byte load instead of a 16-row scan. Entry
    /// [`PROB_MAX`] is never produced by a valid `CODE` (the scaled top
    /// boundary is exclusive) and points at the last row as a sentinel.
    row_of_k: [u8; COUNT_LUT_LEN],
    /// Value→row LUT for the *encoder's* SYMBOL Lookup fast path: entry
    /// `v` is the row containing value `v` (256 B for 8-bit tables, 64 KiB
    /// for 16-bit). Owned by the table and shared by every encoder over it
    /// — instead of being rebuilt per [`super::encoder::ApackEncoder`] —
    /// but built **lazily** on the first [`Self::value_lut`] call, so
    /// decode-only tables (e.g. the footer tables a store reader parses at
    /// open) never pay for it. `Arc` inside so clones of an initialized
    /// table share the allocation (DESIGN.md §9).
    value_lut: OnceLock<Arc<[u8]>>,
}

// Manual impls so the derived forms don't drag the LUTs (both fully
// determined by `rows`) through comparisons and debug output.
impl PartialEq for SymbolTable {
    fn eq(&self, other: &Self) -> bool {
        self.bits == other.bits && self.rows == other.rows
    }
}
impl Eq for SymbolTable {}

impl std::fmt::Debug for SymbolTable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SymbolTable")
            .field("bits", &self.bits)
            .field("rows", &self.rows)
            .finish()
    }
}

impl SymbolTable {
    /// Build and validate a table from `(v_min, hi_cnt)` pairs. `v_min`s
    /// must start at 0 and be strictly increasing; `hi_cnt`s must be
    /// monotone non-decreasing and end exactly at [`PROB_MAX`].
    pub fn new(bits: u32, v_mins: [u32; NUM_ROWS], hi_cnts: [u16; NUM_ROWS]) -> Result<Self> {
        // The 16-row table needs at least 16 distinct values (paper studies
        // 4-, 8- and 16-bit models).
        if !(4..=16).contains(&bits) {
            return Err(Error::InvalidTable(format!("unsupported bit width {bits}")));
        }
        let vmax_all = Self::value_max_for(bits);
        if v_mins[0] != 0 {
            return Err(Error::InvalidTable(format!(
                "row 0 v_min must be 0, got {:#x}",
                v_mins[0]
            )));
        }
        let mut rows = [TableRow { v_min: 0, v_max: 0, ol: 0, hi_cnt: 0 }; NUM_ROWS];
        let mut prev_cnt: u16 = 0;
        for i in 0..NUM_ROWS {
            let v_min = v_mins[i];
            let v_max = if i + 1 < NUM_ROWS { v_mins[i + 1].wrapping_sub(1) } else { vmax_all };
            if i + 1 < NUM_ROWS && v_mins[i + 1] <= v_min {
                return Err(Error::InvalidTable(format!(
                    "v_min not strictly increasing at row {}: {:#x} -> {:#x}",
                    i,
                    v_min,
                    v_mins[i + 1]
                )));
            }
            if v_min > vmax_all {
                return Err(Error::InvalidTable(format!(
                    "row {i} v_min {v_min:#x} exceeds value max {vmax_all:#x}"
                )));
            }
            let hi_cnt = hi_cnts[i];
            if hi_cnt < prev_cnt {
                return Err(Error::InvalidTable(format!(
                    "hi_cnt not monotone at row {i}: {prev_cnt:#x} -> {hi_cnt:#x}"
                )));
            }
            if hi_cnt > PROB_MAX {
                return Err(Error::InvalidTable(format!(
                    "hi_cnt {hi_cnt:#x} exceeds PROB_MAX at row {i}"
                )));
            }
            rows[i] = TableRow { v_min, v_max, ol: offset_len(v_max - v_min + 1), hi_cnt };
            prev_cnt = hi_cnt;
        }
        if rows[NUM_ROWS - 1].hi_cnt != PROB_MAX {
            return Err(Error::InvalidTable(format!(
                "last hi_cnt must be {PROB_MAX:#x}, got {:#x}",
                rows[NUM_ROWS - 1].hi_cnt
            )));
        }
        // Count→row LUT: rows partition [0, PROB_MAX), so every k below
        // PROB_MAX lands in exactly one (possibly shared-boundary) range;
        // empty rows cover no k, matching the cumulative-scan semantics.
        let mut row_of_k = [0u8; COUNT_LUT_LEN];
        let mut lo = 0usize;
        for (i, row) in rows.iter().enumerate() {
            for slot in row_of_k[lo..row.hi_cnt as usize].iter_mut() {
                *slot = i as u8;
            }
            lo = row.hi_cnt as usize;
        }
        row_of_k[PROB_MAX as usize] = (NUM_ROWS - 1) as u8; // unreachable sentinel
        Ok(Self { rows, bits, row_of_k, value_lut: OnceLock::new() })
    }

    /// Uniform table: the value space split evenly with counts proportional
    /// to span — the starting point of the table search (paper Listing 1
    /// line 38) and a safe always-valid default.
    pub fn uniform(bits: u32) -> Self {
        let n_values = 1u64 << bits;
        let mut v_mins = [0u32; NUM_ROWS];
        let mut hi_cnts = [0u16; NUM_ROWS];
        for i in 0..NUM_ROWS {
            v_mins[i] = ((n_values * i as u64) / NUM_ROWS as u64) as u32;
            hi_cnts[i] = (((PROB_MAX as u64) * (i as u64 + 1)) / NUM_ROWS as u64) as u16;
        }
        Self::new(bits, v_mins, hi_cnts).expect("uniform table is always valid")
    }

    /// Largest representable value for a bit width.
    #[inline]
    pub fn value_max_for(bits: u32) -> u32 {
        if bits == 32 {
            u32::MAX
        } else {
            (1u32 << bits) - 1
        }
    }

    /// Value bit width.
    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Largest representable value.
    #[inline]
    pub fn value_max(&self) -> u32 {
        Self::value_max_for(self.bits)
    }

    /// All rows.
    #[inline]
    pub fn rows(&self) -> &[TableRow; NUM_ROWS] {
        &self.rows
    }

    /// The row whose probability-count range `[lo_cnt, hi_cnt)` contains
    /// `k` — one LUT load (decoder `ResolveMode::Lut`). `k` must be below
    /// [`PROB_MAX`]; valid arithmetic-coder states never produce
    /// `k == PROB_MAX` (the scaled top boundary is exclusive).
    #[inline]
    pub fn row_for_count(&self, k: u16) -> usize {
        self.row_of_k[k as usize] as usize
    }

    /// The encoder-side value→row LUT: entry `v` is the index of the row
    /// containing value `v` (one slot per representable value). Built on
    /// first use (decode-only tables never pay for it), then shared by
    /// every [`super::encoder::ApackEncoder`] over the table; indexing
    /// with `v ≤ value_max()` is exact, larger values are the caller's
    /// out-of-range error.
    pub fn value_lut(&self) -> &[u8] {
        self.value_lut.get_or_init(|| {
            // The matching row is the last whose v_min ≤ v (SYMBOL
            // Lookup, Fig 3b). One pass over the value space.
            let mut lut = vec![0u8; self.value_max() as usize + 1];
            let mut row = 0usize;
            for (v, slot) in lut.iter_mut().enumerate() {
                while row + 1 < NUM_ROWS && self.rows[row + 1].v_min as usize <= v {
                    row += 1;
                }
                *slot = row as u8;
            }
            lut.into()
        })
    }

    /// Row `i`'s inclusive-low probability count (the previous row's high).
    #[inline]
    pub fn lo_cnt(&self, i: usize) -> u16 {
        if i == 0 {
            0
        } else {
            self.rows[i - 1].hi_cnt
        }
    }

    /// Probability (fraction of the count space) assigned to row `i`.
    pub fn probability(&self, i: usize) -> f64 {
        (self.rows[i].hi_cnt - self.lo_cnt(i)) as f64 / PROB_MAX as f64
    }

    /// Map a value to its row index ("SYMBOL Lookup", Fig 3b: the matching
    /// row is the last whose `v_min` is ≤ the input). Errors if the value
    /// exceeds the table's bit width.
    #[inline]
    pub fn lookup(&self, v: u32) -> Result<usize> {
        if v > self.value_max() {
            return Err(Error::ValueOutOfRange { value: v, bits: self.bits });
        }
        // 16 rows: branchless-ish linear scan mirrors the 16-comparator
        // hardware and beats binary search at this size.
        let mut idx = 0usize;
        for (i, row) in self.rows.iter().enumerate() {
            idx = if v >= row.v_min { i } else { idx };
        }
        Ok(idx)
    }

    /// Byte length of [`Self::to_bytes`] output: one bit-width byte plus
    /// `NUM_ROWS × (v_min u32 + hi_cnt u16)`.
    pub const SERIALIZED_BYTES: usize = 1 + NUM_ROWS * 6;

    /// Serialize the table to its canonical byte form (little-endian):
    /// `bits u8 | NUM_ROWS × (v_min u32, hi_cnt u16)`. This is the single
    /// shared-table record used by [`crate::coordinator::ShardedContainer`]
    /// and the [`crate::store`] footer, so a tensor's table is stored
    /// exactly once no matter how many shards/chunks reference it.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(Self::SERIALIZED_BYTES);
        out.push(self.bits as u8);
        for r in &self.rows {
            out.extend_from_slice(&r.v_min.to_le_bytes());
            out.extend_from_slice(&r.hi_cnt.to_le_bytes());
        }
        out
    }

    /// Parse a table from the first [`Self::SERIALIZED_BYTES`] bytes of
    /// `data`, running full [`Self::new`] validation.
    pub fn from_bytes(data: &[u8]) -> Result<Self> {
        if data.len() < Self::SERIALIZED_BYTES {
            return Err(Error::InvalidTable(format!(
                "serialized table needs {} bytes, got {}",
                Self::SERIALIZED_BYTES,
                data.len()
            )));
        }
        let bits = data[0] as u32;
        let mut v_mins = [0u32; NUM_ROWS];
        let mut hi_cnts = [0u16; NUM_ROWS];
        for i in 0..NUM_ROWS {
            let at = 1 + i * 6;
            v_mins[i] = u32::from_le_bytes(data[at..at + 4].try_into().unwrap());
            hi_cnts[i] = u16::from_le_bytes(data[at + 4..at + 6].try_into().unwrap());
        }
        Self::new(bits, v_mins, hi_cnts)
    }

    /// Serialized metadata footprint in **bits**, following the hardware
    /// encoding (§V: symbol table rows of 11b = 8b base + 3b OL for 8-bit
    /// models, probability rows of 10b) plus a 32-bit symbol count. The
    /// paper quotes 298 bytes total per tensor including framing; we account
    /// the same constant in footprint models (see `container::META_BYTES`).
    pub fn metadata_bits(&self) -> usize {
        let base_bits = self.bits as usize;
        let ol_bits = if self.bits <= 8 { 3 } else { 4 };
        NUM_ROWS * (base_bits + ol_bits) + NUM_ROWS * PROB_BITS as usize + 32
    }

    /// Render the table in the format of paper Table I (for the `table`
    /// CLI subcommand / `eval::table1`).
    pub fn render(&self) -> String {
        let mut s = String::from(
            "IDX | v_min | v_max | OL | low   | high  | p\n----+-------+-------+----+-------+-------+-------\n",
        );
        for i in 0..NUM_ROWS {
            let r = &self.rows[i];
            s.push_str(&format!(
                "{:3} | {:#04x}  | {:#04x}  | {:2} | {:#05x} | {:#05x} | {:.4}\n",
                i,
                r.v_min,
                r.v_max,
                r.ol,
                self.lo_cnt(i),
                r.hi_cnt,
                self.probability(i)
            ));
        }
        s
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    /// The example table from paper Table I (BILSTM weight layer).
    pub(crate) fn paper_table1() -> SymbolTable {
        let v_mins = [
            0x00, 0x04, 0x08, 0x10, 0x40, 0x50, 0x60, 0x70, 0x80, 0x90, 0xA0, 0xB0, 0xC0, 0xD0,
            0xF4, 0xFC,
        ];
        let hi_cnts = [
            0x1EB, 0x229, 0x238, 0x23A, 0x23A, 0x23A, 0x23A, 0x23A, 0x23A, 0x23A, 0x23A, 0x23A,
            0x23A, 0x23C, 0x276, 0x3FF,
        ];
        SymbolTable::new(8, v_mins, hi_cnts).unwrap()
    }

    #[test]
    fn paper_table_i_roundtrips_fields() {
        let t = paper_table1();
        let r = t.rows();
        // Spot-check against the printed Table I.
        assert_eq!(r[0].v_max, 0x03);
        assert_eq!(r[0].ol, 2);
        assert_eq!(r[2].v_max, 0x0F);
        assert_eq!(r[2].ol, 3);
        assert_eq!(r[3].v_max, 0x3F);
        assert_eq!(r[3].ol, 6);
        assert_eq!(r[13].v_min, 0xD0);
        assert_eq!(r[13].v_max, 0xF3);
        assert_eq!(r[13].ol, 6);
        assert_eq!(r[15].v_max, 0xFF);
        assert_eq!(r[15].ol, 2);
        // Probabilities match the paper's printed values.
        assert!((t.probability(0) - 0.4795).abs() < 5e-4);
        assert!((t.probability(1) - 0.0605).abs() < 5e-4);
        assert!((t.probability(15) - 0.3838).abs() < 5e-4);
        // Zero-probability middle rows.
        for i in 4..=12 {
            assert_eq!(t.probability(i), 0.0);
        }
    }

    #[test]
    fn lookup_maps_every_value_to_containing_row() {
        let t = paper_table1();
        for v in 0u32..=0xFF {
            let i = t.lookup(v).unwrap();
            assert!(t.rows()[i].v_min <= v && v <= t.rows()[i].v_max, "v={v:#x} -> row {i}");
        }
    }

    #[test]
    fn count_lut_matches_range_partition() {
        // Every k in [0, PROB_MAX) must map to the unique row whose
        // [lo_cnt, hi_cnt) contains it — including across the empty rows of
        // Table I (rows 4–12 cover no counts and must never be returned).
        for t in [paper_table1(), SymbolTable::uniform(4), SymbolTable::uniform(8)] {
            for k in 0..PROB_MAX {
                let i = t.row_for_count(k);
                assert!(
                    t.lo_cnt(i) <= k && k < t.rows()[i].hi_cnt,
                    "k={k:#x} -> row {i} [{:#x},{:#x})",
                    t.lo_cnt(i),
                    t.rows()[i].hi_cnt
                );
            }
        }
    }

    #[test]
    fn value_lut_matches_lookup_scan() {
        // The encoder's value→row LUT agrees with the 16-comparator scan
        // on every representable value, for skewed and uniform tables.
        for t in [paper_table1(), SymbolTable::uniform(4), SymbolTable::uniform(8)] {
            let lut = t.value_lut();
            assert_eq!(lut.len() as u64, t.value_max() as u64 + 1);
            for v in 0..=t.value_max() {
                assert_eq!(lut[v as usize] as usize, t.lookup(v).unwrap(), "v={v:#x}");
            }
        }
        // Shared, not copied: cloning an initialized table carries the
        // same Arc'd allocation.
        let t = paper_table1();
        let built = t.value_lut();
        assert_eq!(built.len(), 256);
        let c = t.clone();
        assert!(std::ptr::eq(t.value_lut(), c.value_lut()));
    }

    #[test]
    fn lookup_rejects_out_of_range() {
        let t = paper_table1();
        assert!(matches!(t.lookup(0x100), Err(Error::ValueOutOfRange { .. })));
    }

    #[test]
    fn uniform_tables_valid_for_all_widths() {
        assert!(SymbolTable::new(2, [0; NUM_ROWS], [PROB_MAX; NUM_ROWS]).is_err());
        for bits in [4, 6, 8, 12, 16] {
            let t = SymbolTable::uniform(bits);
            assert_eq!(t.rows()[NUM_ROWS - 1].hi_cnt, PROB_MAX);
            assert_eq!(t.rows()[NUM_ROWS - 1].v_max, SymbolTable::value_max_for(bits));
            // Every value maps somewhere.
            let max = t.value_max().min(4096);
            for v in 0..=max {
                t.lookup(v).unwrap();
            }
        }
    }

    #[test]
    fn new_rejects_bad_tables() {
        // Non-zero first v_min.
        let mut v = [0u32; NUM_ROWS];
        for (i, x) in v.iter_mut().enumerate() {
            *x = (i as u32) * 16 + 1;
        }
        let mut c = [0u16; NUM_ROWS];
        for (i, x) in c.iter_mut().enumerate() {
            *x = ((i as u32 + 1) * 64 - 1).min(PROB_MAX as u32) as u16;
        }
        c[NUM_ROWS - 1] = PROB_MAX;
        assert!(SymbolTable::new(8, v, c).is_err());

        // Non-monotone counts.
        let t = SymbolTable::uniform(8);
        let v_mins: Vec<u32> = t.rows().iter().map(|r| r.v_min).collect();
        let mut cnts: Vec<u16> = t.rows().iter().map(|r| r.hi_cnt).collect();
        cnts[5] = cnts[6] + 1;
        cnts[5] = cnts[5].max(cnts[6]); // keep but swap to force violation at 6
        let mut v_arr = [0u32; NUM_ROWS];
        v_arr.copy_from_slice(&v_mins);
        let mut c_arr = [0u16; NUM_ROWS];
        c_arr.copy_from_slice(&cnts);
        c_arr[6] = c_arr[5].saturating_sub(1);
        // restore last
        c_arr[NUM_ROWS - 1] = PROB_MAX;
        assert!(SymbolTable::new(8, v_arr, c_arr).is_err() || c_arr[6] >= c_arr[5]);

        // Last count not PROB_MAX.
        let mut c2 = [0u16; NUM_ROWS];
        for (i, x) in c2.iter_mut().enumerate() {
            *x = (i as u16 + 1) * 10;
        }
        let mut v2 = [0u32; NUM_ROWS];
        for (i, x) in v2.iter_mut().enumerate() {
            *x = i as u32 * 16;
        }
        assert!(SymbolTable::new(8, v2, c2).is_err());
    }

    #[test]
    fn serialization_roundtrips_and_validates() {
        let t = paper_table1();
        let bytes = t.to_bytes();
        assert_eq!(bytes.len(), SymbolTable::SERIALIZED_BYTES);
        assert_eq!(SymbolTable::from_bytes(&bytes).unwrap(), t);
        // Truncated input is rejected.
        assert!(SymbolTable::from_bytes(&bytes[..bytes.len() - 1]).is_err());
        // Corrupted counts fail validation (force non-monotone hi_cnt).
        let mut bad = bytes.clone();
        bad[1 + 4] = 0xFF;
        bad[1 + 5] = 0x03; // row 0 hi_cnt = PROB_MAX, row 1 smaller -> invalid
        assert!(SymbolTable::from_bytes(&bad).is_err());
    }

    #[test]
    fn offset_len_matches_paper_examples() {
        assert_eq!(offset_len(4), 2); // [0x00,0x03]
        assert_eq!(offset_len(8), 3); // [0x08,0x0F]
        assert_eq!(offset_len(0x30), 6); // [0x10,0x3F]
        assert_eq!(offset_len(0x24), 6); // [0xD0,0xF3]
        assert_eq!(offset_len(1), 0); // singleton range: no offset bits
        assert_eq!(offset_len(256), 8);
    }

    #[test]
    fn metadata_bits_accounting() {
        let t = SymbolTable::uniform(8);
        // 16*(8+3) + 16*10 + 32 = 176 + 160 + 32 = 368 bits
        assert_eq!(t.metadata_bits(), 368);
        let t16 = SymbolTable::uniform(16);
        assert_eq!(t16.metadata_bits(), 16 * 20 + 160 + 32);
    }
}
