//! The APack encoder (paper §V, Fig 3).
//!
//! A software model of the hardware encoder that is *bit-exact* with respect
//! to the architecture the paper describes:
//!
//! - two 16-bit registers `HI`/`LO` hold a sliding window into the
//!   arbitrary-precision range boundaries (`HI` conceptually suffixed by
//!   infinite 1s, `LO` by infinite 0s);
//! - probability counts are 10-bit; the range scaling is a 16×10 multiply
//!   whose low [`PROB_BITS`] bits are discarded (the hardware omits the
//!   partial products that would produce them);
//! - a 5-bit `UBC` register counts pending underflow bits, detected by the
//!   `01PREFIX` block (LO of form `01…`, HI of form `10…`);
//! - common-prefix bits of `HI`/`LO` are shifted out into the encoded symbol
//!   stream each step ("Common Prefix Detection" + "Final HI and LO
//!   generation").
//!
//! The renormalization is the classic Witten–Neal–Cleary scheme (the paper
//! cites Nelson's implementation as its basis), executed here one bit per
//! loop iteration; the hardware performs all iterations of one value in a
//! single combinatorial step, which produces the identical bit stream.

use super::bitstream::BitWriter;
use super::table::{SymbolTable, PROB_BITS};
use super::NUM_ROWS;
use crate::error::{Error, Result};

const TOP_BIT: u16 = 0x8000;
const SECOND_BIT: u16 = 0x4000;

/// Streaming APack encoder for one (sub)stream.
///
/// Feed values with [`encode_value`](Self::encode_value) (symbol bits go to
/// the symbol writer, raw offset bits to the offset writer), then call
/// [`finish`](Self::finish) to flush the disambiguating tail.
#[derive(Debug, Clone)]
pub struct ApackEncoder<'t> {
    table: &'t SymbolTable,
    /// Cumulative count boundaries: `cum[i]..cum[i+1]` is row i's range.
    cum: [u16; NUM_ROWS + 1],
    /// Direct value→row map — the software fast path for the hardware's
    /// 16-comparator SYMBOL Lookup (perf: replaces a 16-iteration scan per
    /// value with one load; see EXPERIMENTS.md §Perf iteration 1).
    row_lut: Vec<u8>,
    hi: u16,
    lo: u16,
    /// Underflow bit counter (hardware: 5-bit UBC register).
    ubc: u32,
    /// Values encoded so far.
    count: u64,
}

impl<'t> ApackEncoder<'t> {
    /// New encoder over a validated table. `HI`/`LO` initialize to
    /// `0xFFFF`/`0x0000` (paper §V).
    pub fn new(table: &'t SymbolTable) -> Self {
        let mut cum = [0u16; NUM_ROWS + 1];
        for i in 0..NUM_ROWS {
            cum[i + 1] = table.rows()[i].hi_cnt;
        }
        // One byte per representable value: 256 B for 8-bit tables, 64 KiB
        // for 16-bit — built once per tensor, amortized over the stream.
        let n_values = table.value_max() as usize + 1;
        let mut row_lut = vec![0u8; n_values];
        let mut row = 0usize;
        for (v, slot) in row_lut.iter_mut().enumerate() {
            while row + 1 < NUM_ROWS && table.rows()[row + 1].v_min as usize <= v {
                row += 1;
            }
            *slot = row as u8;
        }
        Self { table, cum, row_lut, hi: 0xFFFF, lo: 0x0000, ubc: 0, count: 0 }
    }

    /// Number of values encoded so far.
    #[inline]
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Current HI register (exposed for hardware cross-checks).
    #[inline]
    pub fn hi(&self) -> u16 {
        self.hi
    }

    /// Current LO register.
    #[inline]
    pub fn lo(&self) -> u16 {
        self.lo
    }

    /// Pending underflow bit count (UBC register).
    #[inline]
    pub fn ubc(&self) -> u32 {
        self.ubc
    }

    /// Encode one value: emits its offset verbatim and narrows the
    /// arithmetic-coder range by its symbol's probability-count range.
    ///
    /// Errors if the value is out of range for the table's bit width or if
    /// it maps to a row with a zero probability count (which the table
    /// generator only produces for values that never occur — attempting to
    /// encode one is a caller bug or a table/tensor mismatch).
    pub fn encode_value(
        &mut self,
        v: u32,
        sym_out: &mut BitWriter,
        ofs_out: &mut BitWriter,
    ) -> Result<()> {
        // SYMBOL Lookup (Fig 3b): row index + offset emission. The LUT is
        // exact for in-range values; out-of-range errors like lookup().
        if v >= self.row_lut.len() as u32 {
            return Err(Error::ValueOutOfRange { value: v, bits: self.table.bits() });
        }
        let idx = self.row_lut[v as usize] as usize;
        debug_assert_eq!(idx, self.table.lookup(v).unwrap());
        let row = &self.table.rows()[idx];
        let (cum_lo, cum_hi) = (self.cum[idx], self.cum[idx + 1]);
        if cum_hi == cum_lo {
            return Err(Error::ValueNotCovered(v));
        }
        if row.ol > 0 {
            ofs_out.push_bits((v - row.v_min) as u64, row.ol);
        }

        // PCNT Table scaling (Fig 3c): 16×10 multiply, drop low 10 bits.
        let range = (self.hi - self.lo) as u32 + 1;
        let t_hi = self.lo as u32 + ((range * cum_hi as u32) >> PROB_BITS) - 1;
        let t_lo = self.lo as u32 + ((range * cum_lo as u32) >> PROB_BITS);
        debug_assert!(t_hi <= 0xFFFF && t_lo <= t_hi);
        let mut hi = t_hi as u16;
        let mut lo = t_lo as u16;

        // HI/LO/CODE Gen (Fig 3d): shift out the common prefix, absorb
        // underflow prefixes into UBC. The common-prefix bits are emitted
        // in one batch per pass (leading-zeros of HI^LO), exactly what the
        // hardware's LD1 block does in a single step — bit-identical to
        // the one-bit-per-iteration loop (EXPERIMENTS.md §Perf iter. 2).
        loop {
            let diff = hi ^ lo;
            if diff & TOP_BIT == 0 {
                // k common MSBs (1 ≤ k ≤ 16): emit them all at once.
                let k = (diff as u32 | 1).leading_zeros() - 16;
                let bits = (hi >> (16 - k)) as u64;
                if self.ubc > 0 {
                    // Pending underflow bits follow the FIRST output bit.
                    let first = bits >> (k - 1);
                    sym_out.push_bit(first == 1);
                    sym_out.push_repeated(first == 0, self.ubc);
                    self.ubc = 0;
                    if k > 1 {
                        sym_out.push_bits(bits & ((1 << (k - 1)) - 1), k - 1);
                    }
                } else {
                    sym_out.push_bits(bits, k);
                }
                lo <<= k;
                hi = (hi << k) | ((1u32 << k) as u16).wrapping_sub(1); // suffix of 1s
            } else if lo & SECOND_BIT != 0 && hi & SECOND_BIT == 0 {
                // 01PREFIX: LO = 01…, HI = 10… — converging around 1/2.
                self.ubc += 1;
                lo = (lo & (SECOND_BIT - 1)) << 1;
                hi = ((hi | SECOND_BIT) << 1) | 1;
            } else {
                break;
            }
        }
        self.hi = hi;
        self.lo = lo;
        self.count += 1;
        Ok(())
    }

    /// Flush the coder state: writes the second-MSB of `LO` followed by the
    /// pending underflow bits plus one, inverted (Nelson's flush). Any
    /// continuation of the stream after these bits — including the zero
    /// padding a [`super::bitstream::BitReader`] synthesizes — decodes the
    /// final symbol correctly.
    pub fn finish(mut self, sym_out: &mut BitWriter) -> u64 {
        let bit = self.lo & SECOND_BIT != 0;
        sym_out.push_bit(bit);
        sym_out.push_repeated(!bit, self.ubc + 1);
        self.ubc = 0;
        self.count
    }

    /// Encode a full tensor into fresh symbol/offset streams. Returns
    /// `(symbol_bytes, symbol_bits, offset_bytes, offset_bits)`.
    pub fn encode_all(
        table: &SymbolTable,
        values: &[u32],
    ) -> Result<(Vec<u8>, usize, Vec<u8>, usize)> {
        let mut enc = ApackEncoder::new(table);
        let mut sym = BitWriter::with_capacity_bits(values.len() * 4);
        let mut ofs = BitWriter::with_capacity_bits(values.len() * 4);
        for &v in values {
            enc.encode_value(v, &mut sym, &mut ofs)?;
        }
        enc.finish(&mut sym);
        let (sym_bytes, sym_bits) = sym.finish();
        let (ofs_bytes, ofs_bits) = ofs.finish();
        Ok((sym_bytes, sym_bits, ofs_bytes, ofs_bits))
    }
}

#[cfg(test)]
mod tests {
    use super::super::bitstream::BitReader;
    use super::super::decoder::ApackDecoder;
    use super::*;
    use crate::apack::table::PROB_MAX;

    fn roundtrip(table: &SymbolTable, values: &[u32]) {
        let (sym, sym_bits, ofs, ofs_bits) = ApackEncoder::encode_all(table, values).unwrap();
        let mut dec =
            ApackDecoder::new(table, BitReader::new(&sym, sym_bits)).expect("decoder init");
        let mut ofs_r = BitReader::new(&ofs, ofs_bits);
        for (i, &v) in values.iter().enumerate() {
            let got = dec.decode_value(&mut ofs_r).unwrap_or_else(|e| panic!("at {i}: {e}"));
            assert_eq!(got, v, "value {i}");
        }
    }

    #[test]
    fn roundtrip_uniform_table_all_byte_values() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0u32..=255).collect();
        roundtrip(&t, &values);
    }

    #[test]
    fn roundtrip_single_value() {
        let t = SymbolTable::uniform(8);
        roundtrip(&t, &[42]);
    }

    #[test]
    fn roundtrip_repeated_extremes() {
        let t = SymbolTable::uniform(8);
        let mut v = vec![0u32; 1000];
        v.extend(std::iter::repeat(255u32).take(1000));
        roundtrip(&t, &v);
    }

    #[test]
    fn roundtrip_paper_table_on_matching_distribution() {
        // Values drawn only from non-zero-probability rows of Table I.
        let t = crate::apack::table::tests::paper_table1();
        let mut values = Vec::new();
        for rep in 0..500u32 {
            values.push(rep % 4); // row 0
            values.push(0xFC + (rep % 4)); // row 15
            if rep % 8 == 0 {
                values.push(0x04 + (rep % 4)); // row 1
                values.push(0xF4 + (rep % 8)); // row 14
            }
            if rep % 100 == 0 {
                values.push(0x10 + (rep % 0x30)); // row 3 (p=0.002)
                values.push(0xD0 + (rep % 0x24)); // row 13
            }
        }
        roundtrip(&t, &values);
    }

    #[test]
    fn zero_probability_row_rejected() {
        let t = crate::apack::table::tests::paper_table1();
        let mut enc = ApackEncoder::new(&t);
        let mut s = BitWriter::new();
        let mut o = BitWriter::new();
        // 0x55 lies in row 5 which has an empty count range in Table I.
        assert!(matches!(
            enc.encode_value(0x55, &mut s, &mut o),
            Err(Error::ValueNotCovered(0x55))
        ));
    }

    #[test]
    fn skewed_table_compresses_skewed_data() {
        // A table putting ~94% of the count space on [0,3] should encode a
        // stream of zeros in well under 1 bit/value.
        let mut v_mins = [0u32; NUM_ROWS];
        let mut cnts = [0u16; NUM_ROWS];
        for i in 0..NUM_ROWS {
            v_mins[i] = if i == 0 { 0 } else { (i as u32) * 17 };
            cnts[i] = if i == 0 { 960 } else { 960 + ((PROB_MAX - 960) / 15) * i as u16 };
        }
        cnts[NUM_ROWS - 1] = PROB_MAX;
        let t = SymbolTable::new(8, v_mins, cnts).unwrap();
        let values = vec![0u32; 10_000];
        let (_, sym_bits, _, ofs_bits) = ApackEncoder::encode_all(&t, &values).unwrap();
        // Entropy bound: -log2(960/1023) ≈ 0.092 b/sym + 5b offset... but
        // offset is ceil(log2(17)) = 5 bits for row 0 here.
        assert!(
            (sym_bits as f64) < 0.12 * values.len() as f64,
            "symbol stream too large: {sym_bits} bits for {} values",
            values.len()
        );
        assert_eq!(ofs_bits, values.len() * 5);
        roundtrip(&t, &values);
    }

    #[test]
    fn underflow_stress() {
        // A two-row near-50/50 split keeps HI/LO converging around 0.5,
        // exercising the UBC path heavily.
        let mut v_mins = [0u32; NUM_ROWS];
        let mut cnts = [0u16; NUM_ROWS];
        for i in 0..NUM_ROWS {
            v_mins[i] = i as u32; // rows 0..14 cover single values, row 15 the rest
            cnts[i] = if i == 0 { 512 } else { 512 + i as u16 };
        }
        cnts[NUM_ROWS - 1] = PROB_MAX;
        let t = SymbolTable::new(8, v_mins, cnts).unwrap();
        // Alternate row 0 and row 15 symbols.
        let mut values = Vec::new();
        for i in 0..5000 {
            values.push(if i % 2 == 0 { 0 } else { 200 });
        }
        roundtrip(&t, &values);
    }

    #[test]
    fn four_bit_and_sixteen_bit_widths() {
        let t4 = SymbolTable::uniform(4);
        let v4: Vec<u32> = (0..16).cycle().take(500).collect();
        roundtrip(&t4, &v4);

        let t16 = SymbolTable::uniform(16);
        let v16: Vec<u32> = (0..65536u32).step_by(97).cycle().take(2000).collect();
        roundtrip(&t16, &v16);
    }

    #[test]
    fn empty_stream_roundtrip() {
        let t = SymbolTable::uniform(8);
        let (sym, sym_bits, _, _) = ApackEncoder::encode_all(&t, &[]).unwrap();
        // Flush always emits at least 2 bits.
        assert!(sym_bits >= 2);
        let dec = ApackDecoder::new(&t, BitReader::new(&sym, sym_bits));
        assert!(dec.is_ok());
    }
}
