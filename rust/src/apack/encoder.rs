//! The APack encoder (paper §V, Fig 3).
//!
//! A software model of the hardware encoder that is *bit-exact* with respect
//! to the architecture the paper describes:
//!
//! - two 16-bit registers `HI`/`LO` hold a sliding window into the
//!   arbitrary-precision range boundaries (`HI` conceptually suffixed by
//!   infinite 1s, `LO` by infinite 0s);
//! - probability counts are 10-bit; the range scaling is a 16×10 multiply
//!   whose low [`PROB_BITS`] bits are discarded (the hardware omits the
//!   partial products that would produce them);
//! - a 5-bit `UBC` register counts pending underflow bits, detected by the
//!   `01PREFIX` block (LO of form `01…`, HI of form `10…`);
//! - common-prefix bits of `HI`/`LO` are shifted out into the encoded symbol
//!   stream each step ("Common Prefix Detection" + "Final HI and LO
//!   generation").
//!
//! The renormalization is the classic Witten–Neal–Cleary scheme (the paper
//! cites Nelson's implementation as its basis), executed here one bit per
//! loop iteration; the hardware performs all iterations of one value in a
//! single combinatorial step, which produces the identical bit stream.
//!
//! Two call granularities share the same state machine (mirroring the
//! decoder, DESIGN.md §9): [`ApackEncoder::encode_value`] is the per-value
//! reference path and [`ApackEncoder::encode_into`] is the block fast path
//! that keeps `HI`/`LO`/`UBC` in locals across a whole input slice. The
//! two are bit-identical, including the error raised (and the bits already
//! committed) on an unencodable value.

use super::bitstream::BitWriter;
use super::table::{SymbolTable, PROB_BITS};
use super::NUM_ROWS;
use crate::error::{Error, Result};

const TOP_BIT: u16 = 0x8000;
const SECOND_BIT: u16 = 0x4000;

/// Streaming APack encoder for one (sub)stream.
///
/// Feed values with [`encode_value`](Self::encode_value) (the per-value
/// reference path; symbol bits go to the symbol writer, raw offset bits to
/// the offset writer) or a whole slice at a time with
/// [`encode_into`](Self::encode_into) (the block fast path, bit-identical),
/// then call [`finish`](Self::finish) to flush the disambiguating tail.
#[derive(Debug, Clone)]
pub struct ApackEncoder<'t> {
    table: &'t SymbolTable,
    /// Cumulative count boundaries: `cum[i]..cum[i+1]` is row i's range.
    cum: [u16; NUM_ROWS + 1],
    hi: u16,
    lo: u16,
    /// Underflow bit counter (hardware: 5-bit UBC register).
    ubc: u32,
    /// Values encoded so far.
    count: u64,
}

impl<'t> ApackEncoder<'t> {
    /// New encoder over a validated table. `HI`/`LO` initialize to
    /// `0xFFFF`/`0x0000` (paper §V). The value→row SYMBOL-Lookup LUT
    /// (the software fast path for the hardware's 16 comparators; see
    /// EXPERIMENTS.md §Perf iteration 1) is owned by the table — built
    /// lazily on first use and shared by every encoder over it —
    /// so constructing an encoder is O(1).
    pub fn new(table: &'t SymbolTable) -> Self {
        let mut cum = [0u16; NUM_ROWS + 1];
        for i in 0..NUM_ROWS {
            cum[i + 1] = table.rows()[i].hi_cnt;
        }
        Self { table, cum, hi: 0xFFFF, lo: 0x0000, ubc: 0, count: 0 }
    }

    /// Number of values encoded so far.
    #[inline]
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Current HI register (exposed for hardware cross-checks).
    #[inline]
    pub fn hi(&self) -> u16 {
        self.hi
    }

    /// Current LO register.
    #[inline]
    pub fn lo(&self) -> u16 {
        self.lo
    }

    /// Pending underflow bit count (UBC register).
    #[inline]
    pub fn ubc(&self) -> u32 {
        self.ubc
    }

    /// Encode one value: emits its offset verbatim and narrows the
    /// arithmetic-coder range by its symbol's probability-count range.
    ///
    /// Errors if the value is out of range for the table's bit width or if
    /// it maps to a row with a zero probability count (which the table
    /// generator only produces for values that never occur — attempting to
    /// encode one is a caller bug or a table/tensor mismatch).
    pub fn encode_value(
        &mut self,
        v: u32,
        sym_out: &mut BitWriter,
        ofs_out: &mut BitWriter,
    ) -> Result<()> {
        // SYMBOL Lookup (Fig 3b): row index + offset emission. The LUT is
        // exact for in-range values; out-of-range errors like lookup().
        let lut = self.table.value_lut();
        if v >= lut.len() as u32 {
            return Err(Error::ValueOutOfRange { value: v, bits: self.table.bits() });
        }
        let idx = lut[v as usize] as usize;
        debug_assert_eq!(idx, self.table.lookup(v).unwrap());
        let row = &self.table.rows()[idx];
        let (cum_lo, cum_hi) = (self.cum[idx], self.cum[idx + 1]);
        if cum_hi == cum_lo {
            return Err(Error::ValueNotCovered(v));
        }
        if row.ol > 0 {
            ofs_out.push_bits((v - row.v_min) as u64, row.ol);
        }

        // PCNT Table scaling (Fig 3c): 16×10 multiply, drop low 10 bits.
        let range = (self.hi - self.lo) as u32 + 1;
        let t_hi = self.lo as u32 + ((range * cum_hi as u32) >> PROB_BITS) - 1;
        let t_lo = self.lo as u32 + ((range * cum_lo as u32) >> PROB_BITS);
        debug_assert!(t_hi <= 0xFFFF && t_lo <= t_hi);
        let mut hi = t_hi as u16;
        let mut lo = t_lo as u16;

        // HI/LO/CODE Gen (Fig 3d): shift out the common prefix, absorb
        // underflow prefixes into UBC. The common-prefix bits are emitted
        // in one batch per pass (leading-zeros of HI^LO), exactly what the
        // hardware's LD1 block does in a single step — bit-identical to
        // the one-bit-per-iteration loop (EXPERIMENTS.md §Perf iter. 2).
        loop {
            let diff = hi ^ lo;
            if diff & TOP_BIT == 0 {
                // k common MSBs (1 ≤ k ≤ 16): emit them all at once.
                let k = (diff as u32 | 1).leading_zeros() - 16;
                let bits = (hi >> (16 - k)) as u64;
                if self.ubc > 0 {
                    // Pending underflow bits follow the FIRST output bit.
                    let first = bits >> (k - 1);
                    sym_out.push_bit(first == 1);
                    sym_out.push_repeated(first == 0, self.ubc);
                    self.ubc = 0;
                    if k > 1 {
                        sym_out.push_bits(bits & ((1 << (k - 1)) - 1), k - 1);
                    }
                } else {
                    sym_out.push_bits(bits, k);
                }
                lo <<= k;
                hi = (hi << k) | ((1u32 << k) as u16).wrapping_sub(1); // suffix of 1s
            } else if lo & SECOND_BIT != 0 && hi & SECOND_BIT == 0 {
                // 01PREFIX: LO = 01…, HI = 10… — converging around 1/2.
                self.ubc += 1;
                lo = (lo & (SECOND_BIT - 1)) << 1;
                hi = ((hi | SECOND_BIT) << 1) | 1;
            } else {
                break;
            }
        }
        self.hi = hi;
        self.lo = lo;
        self.count += 1;
        Ok(())
    }

    /// Block fast path: encode a whole slice of values.
    ///
    /// Bit-identical to calling [`Self::encode_value`] once per element —
    /// including which error is raised first and the exact bits already
    /// written when it is — but keeps `HI`/`LO`/`UBC` and the cumulative
    /// counts in locals across the block and resolves the SYMBOL Lookup
    /// through the table's shared value→row LUT, so the per-value cost is
    /// one load, one multiply pair and the batched renormalization pushes
    /// (DESIGN.md §9). On error the encoder state (and both writers)
    /// reflect the values encoded before the offending one, exactly as the
    /// per-value loop would leave them.
    pub fn encode_into(
        &mut self,
        values: &[u32],
        sym_out: &mut BitWriter,
        ofs_out: &mut BitWriter,
    ) -> Result<()> {
        // The tracer's single Encode site (mirror of
        // `ApackDecoder::decode_into`): one span per block, one relaxed
        // atomic load when tracing is off.
        let _span = crate::obs::span_n(crate::obs::Stage::Encode, values.len() as u64);
        let table = self.table;
        let lut = table.value_lut();
        let rows = table.rows();
        let cum = self.cum;
        let (mut hi, mut lo) = (self.hi, self.lo);
        let mut ubc = self.ubc;
        let mut done = 0u64;
        let mut failed = None;
        for &v in values {
            // SYMBOL Lookup (Fig 3b) via the shared LUT.
            if v >= lut.len() as u32 {
                failed = Some(Error::ValueOutOfRange { value: v, bits: table.bits() });
                break;
            }
            let idx = lut[v as usize] as usize;
            debug_assert_eq!(idx, table.lookup(v).unwrap());
            let row = &rows[idx];
            let (cum_lo, cum_hi) = (cum[idx], cum[idx + 1]);
            if cum_hi == cum_lo {
                failed = Some(Error::ValueNotCovered(v));
                break;
            }
            if row.ol > 0 {
                ofs_out.push_bits((v - row.v_min) as u64, row.ol);
            }

            // PCNT Table scaling (Fig 3c) on block locals.
            let range = (hi - lo) as u32 + 1;
            let t_hi = lo as u32 + ((range * cum_hi as u32) >> PROB_BITS) - 1;
            let t_lo = lo as u32 + ((range * cum_lo as u32) >> PROB_BITS);
            debug_assert!(t_hi <= 0xFFFF && t_lo <= t_hi);
            hi = t_hi as u16;
            lo = t_lo as u16;

            // HI/LO/CODE Gen (Fig 3d), same batched renormalization as
            // `encode_value`, on locals.
            loop {
                let diff = hi ^ lo;
                if diff & TOP_BIT == 0 {
                    let k = (diff as u32 | 1).leading_zeros() - 16;
                    let bits = (hi >> (16 - k)) as u64;
                    if ubc > 0 {
                        let first = bits >> (k - 1);
                        sym_out.push_bit(first == 1);
                        sym_out.push_repeated(first == 0, ubc);
                        ubc = 0;
                        if k > 1 {
                            sym_out.push_bits(bits & ((1 << (k - 1)) - 1), k - 1);
                        }
                    } else {
                        sym_out.push_bits(bits, k);
                    }
                    lo <<= k;
                    hi = (hi << k) | ((1u32 << k) as u16).wrapping_sub(1);
                } else if lo & SECOND_BIT != 0 && hi & SECOND_BIT == 0 {
                    ubc += 1;
                    lo = (lo & (SECOND_BIT - 1)) << 1;
                    hi = ((hi | SECOND_BIT) << 1) | 1;
                } else {
                    break;
                }
            }
            done += 1;
        }
        self.hi = hi;
        self.lo = lo;
        self.ubc = ubc;
        self.count += done;
        match failed {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Flush the coder state: writes the second-MSB of `LO` followed by the
    /// pending underflow bits plus one, inverted (Nelson's flush). Any
    /// continuation of the stream after these bits — including the zero
    /// padding a [`super::bitstream::BitReader`] synthesizes — decodes the
    /// final symbol correctly.
    pub fn finish(mut self, sym_out: &mut BitWriter) -> u64 {
        let bit = self.lo & SECOND_BIT != 0;
        sym_out.push_bit(bit);
        sym_out.push_repeated(!bit, self.ubc + 1);
        self.ubc = 0;
        self.count
    }

    /// Encode a full tensor into fresh symbol/offset streams. Returns
    /// `(symbol_bytes, symbol_bits, offset_bytes, offset_bits)`.
    /// Delegates to the block fast path ([`Self::encode_into`]) — there is
    /// exactly one bulk encode loop to keep in sync with the decoder, and
    /// `encode_value` remains as its per-value reference.
    pub fn encode_all(
        table: &SymbolTable,
        values: &[u32],
    ) -> Result<(Vec<u8>, usize, Vec<u8>, usize)> {
        let mut enc = ApackEncoder::new(table);
        let mut sym = BitWriter::with_capacity_bits(values.len() * 4);
        let mut ofs = BitWriter::with_capacity_bits(values.len() * 4);
        enc.encode_into(values, &mut sym, &mut ofs)?;
        enc.finish(&mut sym);
        let (sym_bytes, sym_bits) = sym.finish();
        let (ofs_bytes, ofs_bits) = ofs.finish();
        Ok((sym_bytes, sym_bits, ofs_bytes, ofs_bits))
    }
}

#[cfg(test)]
mod tests {
    use super::super::bitstream::BitReader;
    use super::super::decoder::ApackDecoder;
    use super::*;
    use crate::apack::table::PROB_MAX;

    fn roundtrip(table: &SymbolTable, values: &[u32]) {
        let (sym, sym_bits, ofs, ofs_bits) = ApackEncoder::encode_all(table, values).unwrap();
        let mut dec =
            ApackDecoder::new(table, BitReader::new(&sym, sym_bits)).expect("decoder init");
        let mut ofs_r = BitReader::new(&ofs, ofs_bits);
        for (i, &v) in values.iter().enumerate() {
            let got = dec.decode_value(&mut ofs_r).unwrap_or_else(|e| panic!("at {i}: {e}"));
            assert_eq!(got, v, "value {i}");
        }
    }

    #[test]
    fn roundtrip_uniform_table_all_byte_values() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0u32..=255).collect();
        roundtrip(&t, &values);
    }

    #[test]
    fn roundtrip_single_value() {
        let t = SymbolTable::uniform(8);
        roundtrip(&t, &[42]);
    }

    #[test]
    fn roundtrip_repeated_extremes() {
        let t = SymbolTable::uniform(8);
        let mut v = vec![0u32; 1000];
        v.extend(std::iter::repeat(255u32).take(1000));
        roundtrip(&t, &v);
    }

    #[test]
    fn roundtrip_paper_table_on_matching_distribution() {
        // Values drawn only from non-zero-probability rows of Table I.
        let t = crate::apack::table::tests::paper_table1();
        let mut values = Vec::new();
        for rep in 0..500u32 {
            values.push(rep % 4); // row 0
            values.push(0xFC + (rep % 4)); // row 15
            if rep % 8 == 0 {
                values.push(0x04 + (rep % 4)); // row 1
                values.push(0xF4 + (rep % 8)); // row 14
            }
            if rep % 100 == 0 {
                values.push(0x10 + (rep % 0x30)); // row 3 (p=0.002)
                values.push(0xD0 + (rep % 0x24)); // row 13
            }
        }
        roundtrip(&t, &values);
    }

    /// Encode with the per-value reference loop (the pre-block path).
    fn encode_per_value(
        table: &SymbolTable,
        values: &[u32],
    ) -> Result<(Vec<u8>, usize, Vec<u8>, usize)> {
        let mut enc = ApackEncoder::new(table);
        let mut sym = BitWriter::new();
        let mut ofs = BitWriter::new();
        for &v in values {
            enc.encode_value(v, &mut sym, &mut ofs)?;
        }
        enc.finish(&mut sym);
        let (sb, sbits) = sym.finish();
        let (ob, obits) = ofs.finish();
        Ok((sb, sbits, ob, obits))
    }

    #[test]
    fn block_encode_bit_identical_to_per_value() {
        let tables = [
            SymbolTable::uniform(4),
            SymbolTable::uniform(8),
            SymbolTable::uniform(16),
            crate::apack::table::tests::paper_table1(),
        ];
        for (ti, t) in tables.iter().enumerate() {
            let max = t.value_max();
            // Mix of runs and jumps so renorm + UBC paths all fire; for the
            // paper table stay on covered rows (0..4 and the top).
            let values: Vec<u32> = (0..5000u32)
                .map(|i| {
                    if ti == 3 {
                        if i % 3 == 0 { i % 4 } else { max - (i % 4) }
                    } else {
                        (i.wrapping_mul(2654435761) >> 16) % (max + 1)
                    }
                })
                .collect();
            let reference = encode_per_value(t, &values).unwrap();
            let block = ApackEncoder::encode_all(t, &values).unwrap();
            assert_eq!(block, reference, "table {ti}");

            // And split across multiple encode_into calls at odd points.
            for split in [0usize, 1, values.len() / 3, values.len()] {
                let mut enc = ApackEncoder::new(t);
                let mut sym = BitWriter::new();
                let mut ofs = BitWriter::new();
                enc.encode_into(&values[..split], &mut sym, &mut ofs).unwrap();
                enc.encode_into(&values[split..], &mut sym, &mut ofs).unwrap();
                assert_eq!(enc.count(), values.len() as u64);
                enc.finish(&mut sym);
                let (sb, sbits) = sym.finish();
                let (ob, obits) = ofs.finish();
                assert_eq!((sb, sbits, ob, obits), reference, "table {ti} split {split}");
            }
        }
    }

    #[test]
    fn block_encode_error_matches_per_value() {
        // 0x55 hits a zero-probability row of Table I: both paths must
        // fail with the same error after committing the same prefix bits.
        let t = crate::apack::table::tests::paper_table1();
        let mut values: Vec<u32> = (0..100).map(|i| i % 4).collect();
        values.push(0x55);
        values.extend((0..50).map(|i| 0xFC + i % 4));

        let run_block = {
            let mut enc = ApackEncoder::new(&t);
            let mut sym = BitWriter::new();
            let mut ofs = BitWriter::new();
            let err = enc.encode_into(&values, &mut sym, &mut ofs).unwrap_err();
            (err, enc.count(), enc.hi(), enc.lo(), enc.ubc(), sym.len_bits(), ofs.len_bits())
        };
        let run_per_value = {
            let mut enc = ApackEncoder::new(&t);
            let mut sym = BitWriter::new();
            let mut ofs = BitWriter::new();
            let mut err = None;
            for &v in &values {
                if let Err(e) = enc.encode_value(v, &mut sym, &mut ofs) {
                    err = Some(e);
                    break;
                }
            }
            let err = err.expect("per-value loop must reject 0x55");
            (err, enc.count(), enc.hi(), enc.lo(), enc.ubc(), sym.len_bits(), ofs.len_bits())
        };
        assert_eq!(run_block, run_per_value);
        assert!(matches!(run_block.0, Error::ValueNotCovered(0x55)));
        assert_eq!(run_block.1, 100, "values before the bad one are committed");

        // Out-of-range values too.
        let t8 = SymbolTable::uniform(8);
        let mut enc = ApackEncoder::new(&t8);
        let (mut s, mut o) = (BitWriter::new(), BitWriter::new());
        assert!(matches!(
            enc.encode_into(&[1, 2, 0x100], &mut s, &mut o),
            Err(Error::ValueOutOfRange { value: 0x100, bits: 8 })
        ));
        assert_eq!(enc.count(), 2);
    }

    #[test]
    fn zero_probability_row_rejected() {
        let t = crate::apack::table::tests::paper_table1();
        let mut enc = ApackEncoder::new(&t);
        let mut s = BitWriter::new();
        let mut o = BitWriter::new();
        // 0x55 lies in row 5 which has an empty count range in Table I.
        assert!(matches!(
            enc.encode_value(0x55, &mut s, &mut o),
            Err(Error::ValueNotCovered(0x55))
        ));
    }

    #[test]
    fn skewed_table_compresses_skewed_data() {
        // A table putting ~94% of the count space on [0,3] should encode a
        // stream of zeros in well under 1 bit/value.
        let mut v_mins = [0u32; NUM_ROWS];
        let mut cnts = [0u16; NUM_ROWS];
        for i in 0..NUM_ROWS {
            v_mins[i] = if i == 0 { 0 } else { (i as u32) * 17 };
            cnts[i] = if i == 0 { 960 } else { 960 + ((PROB_MAX - 960) / 15) * i as u16 };
        }
        cnts[NUM_ROWS - 1] = PROB_MAX;
        let t = SymbolTable::new(8, v_mins, cnts).unwrap();
        let values = vec![0u32; 10_000];
        let (_, sym_bits, _, ofs_bits) = ApackEncoder::encode_all(&t, &values).unwrap();
        // Entropy bound: -log2(960/1023) ≈ 0.092 b/sym + 5b offset... but
        // offset is ceil(log2(17)) = 5 bits for row 0 here.
        assert!(
            (sym_bits as f64) < 0.12 * values.len() as f64,
            "symbol stream too large: {sym_bits} bits for {} values",
            values.len()
        );
        assert_eq!(ofs_bits, values.len() * 5);
        roundtrip(&t, &values);
    }

    #[test]
    fn underflow_stress() {
        // A two-row near-50/50 split keeps HI/LO converging around 0.5,
        // exercising the UBC path heavily.
        let mut v_mins = [0u32; NUM_ROWS];
        let mut cnts = [0u16; NUM_ROWS];
        for i in 0..NUM_ROWS {
            v_mins[i] = i as u32; // rows 0..14 cover single values, row 15 the rest
            cnts[i] = if i == 0 { 512 } else { 512 + i as u16 };
        }
        cnts[NUM_ROWS - 1] = PROB_MAX;
        let t = SymbolTable::new(8, v_mins, cnts).unwrap();
        // Alternate row 0 and row 15 symbols.
        let mut values = Vec::new();
        for i in 0..5000 {
            values.push(if i % 2 == 0 { 0 } else { 200 });
        }
        roundtrip(&t, &values);
    }

    #[test]
    fn four_bit_and_sixteen_bit_widths() {
        let t4 = SymbolTable::uniform(4);
        let v4: Vec<u32> = (0..16).cycle().take(500).collect();
        roundtrip(&t4, &v4);

        let t16 = SymbolTable::uniform(16);
        let v16: Vec<u32> = (0..65536u32).step_by(97).cycle().take(2000).collect();
        roundtrip(&t16, &v16);
    }

    #[test]
    fn empty_stream_roundtrip() {
        let t = SymbolTable::uniform(8);
        let (sym, sym_bits, _, _) = ApackEncoder::encode_all(&t, &[]).unwrap();
        // Flush always emits at least 2 bits.
        assert!(sym_bits >= 2);
        let dec = ApackDecoder::new(&t, BitReader::new(&sym, sym_bits));
        assert!(dec.is_ok());
    }
}
