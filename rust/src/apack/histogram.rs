//! Value histograms and cumulative distributions.
//!
//! The table generator (paper §VI) and the footprint estimator both consume
//! a per-tensor histogram `h(i)` = number of occurrences of value `i`. The
//! CDF view regenerates paper Fig 2.


/// Histogram over a `bits`-wide unsigned value space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    bits: u32,
    counts: Vec<u64>,
    total: u64,
    /// Prefix sums: `prefix[i] = sum(counts[..i])`, length `counts.len()+1`.
    /// Gives O(1) range mass queries for the table search.
    prefix: Vec<u64>,
}

impl Histogram {
    /// Empty histogram for a bit width.
    pub fn new(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "unsupported bit width {bits}");
        let n = 1usize << bits;
        Self { bits, counts: vec![0; n], total: 0, prefix: vec![0; n + 1] }
    }

    /// Build from a tensor of values (all must fit in `bits`).
    pub fn from_values(bits: u32, values: &[u32]) -> Self {
        let mut h = Self::new(bits);
        let mask = (1u32 << bits) - 1;
        for &v in values {
            debug_assert!(v <= mask, "value {v:#x} exceeds {bits}-bit space");
            h.counts[(v & mask) as usize] += 1;
        }
        h.total = values.len() as u64;
        h.rebuild_prefix();
        h
    }

    /// Build directly from counts.
    pub fn from_counts(bits: u32, counts: Vec<u64>) -> Self {
        assert_eq!(counts.len(), 1usize << bits);
        let total = counts.iter().sum();
        let mut h = Self { bits, counts, total, prefix: Vec::new() };
        h.rebuild_prefix();
        h
    }

    fn rebuild_prefix(&mut self) {
        let mut prefix = Vec::with_capacity(self.counts.len() + 1);
        let mut acc = 0u64;
        prefix.push(0);
        for &c in &self.counts {
            acc += c;
            prefix.push(acc);
        }
        self.prefix = prefix;
    }

    /// Merge another histogram (used to pool several activation samples,
    /// paper §VII "up to 9 input activation samples per layer").
    /// Equivalent to `merge_many(once(other))` — one prefix rebuild.
    pub fn merge(&mut self, other: &Histogram) {
        self.merge_many(std::iter::once(other));
    }

    /// Merge several histograms with a **single** deferred prefix rebuild
    /// — pooling N activation samples costs one O(2^bits) prefix pass
    /// instead of N (`merge` per sample rebuilt every time). This is the
    /// ingest path's pooling primitive (`store::pipeline`, DESIGN.md §9).
    pub fn merge_many<'a>(&mut self, others: impl IntoIterator<Item = &'a Histogram>) {
        for other in others {
            assert_eq!(self.bits, other.bits);
            for (a, b) in self.counts.iter_mut().zip(&other.counts) {
                *a += *b;
            }
            self.total += other.total;
        }
        self.rebuild_prefix();
    }

    /// Histogram of several value slices pooled together — counts
    /// accumulated across all chunks, then one prefix rebuild. Equal to
    /// `from_values` over the concatenation (and to building per-chunk
    /// histograms and [`Self::merge_many`]-ing them), without the
    /// intermediate allocations or rebuilds.
    pub fn from_value_chunks<'a>(
        bits: u32,
        chunks: impl IntoIterator<Item = &'a [u32]>,
    ) -> Self {
        let mut h = Self::new(bits);
        let mask = (1u32 << bits) - 1;
        for chunk in chunks {
            for &v in chunk {
                debug_assert!(v <= mask, "value {v:#x} exceeds {bits}-bit space");
                h.counts[(v & mask) as usize] += 1;
            }
            h.total += chunk.len() as u64;
        }
        h.rebuild_prefix();
        h
    }

    /// Value bit width.
    #[inline]
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Total number of counted values.
    #[inline]
    pub fn total(&self) -> u64 {
        self.total
    }

    /// Raw counts.
    #[inline]
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Occurrences of values in `[lo, hi]` inclusive — O(1) via prefix sums.
    #[inline]
    pub fn range_mass(&self, lo: u32, hi: u32) -> u64 {
        debug_assert!(lo <= hi && (hi as usize) < self.counts.len());
        self.prefix[hi as usize + 1] - self.prefix[lo as usize]
    }

    /// Fraction of values equal to zero.
    pub fn sparsity(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[0] as f64 / self.total as f64
        }
    }

    /// Shannon entropy in bits/value of the exact value distribution — the
    /// lower bound any lossless scheme (including ideal AC with a full
    /// 2^bits-entry table) could achieve.
    pub fn entropy(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let total = self.total as f64;
        self.counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / total;
                -p * p.log2()
            })
            .sum()
    }

    /// Cumulative distribution `(value, fraction ≤ value)` — Fig 2 series.
    pub fn cdf(&self) -> Vec<(u32, f64)> {
        let total = self.total.max(1) as f64;
        self.prefix[1..]
            .iter()
            .enumerate()
            .map(|(v, &acc)| (v as u32, acc as f64 / total))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_ranges() {
        let h = Histogram::from_values(8, &[0, 0, 1, 5, 255, 255, 255]);
        assert_eq!(h.total(), 7);
        assert_eq!(h.range_mass(0, 0), 2);
        assert_eq!(h.range_mass(0, 1), 3);
        assert_eq!(h.range_mass(2, 254), 1);
        assert_eq!(h.range_mass(0, 255), 7);
        assert!((h.sparsity() - 2.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn entropy_uniform_and_deterministic() {
        let v: Vec<u32> = (0..256).collect();
        let h = Histogram::from_values(8, &v);
        assert!((h.entropy() - 8.0).abs() < 1e-9);
        let h0 = Histogram::from_values(8, &[7; 100]);
        assert_eq!(h0.entropy(), 0.0);
    }

    #[test]
    fn merge_pools_samples() {
        let mut a = Histogram::from_values(8, &[1, 2, 3]);
        let b = Histogram::from_values(8, &[3, 4]);
        a.merge(&b);
        assert_eq!(a.total(), 5);
        assert_eq!(a.counts()[3], 2);
        assert_eq!(a.range_mass(1, 4), 5);
    }

    #[test]
    fn merge_many_equals_sequential_merges() {
        let samples: Vec<Vec<u32>> =
            (0..9u32).map(|s| (0..200).map(|i| (i * (s + 3)) % 256).collect()).collect();
        let hists: Vec<Histogram> =
            samples.iter().map(|v| Histogram::from_values(8, v)).collect();

        let mut sequential = Histogram::new(8);
        for h in &hists {
            sequential.merge(h);
        }
        let mut pooled = Histogram::new(8);
        pooled.merge_many(&hists);
        assert_eq!(pooled, sequential);

        // And straight from the chunks, no intermediate histograms.
        let chunked =
            Histogram::from_value_chunks(8, samples.iter().map(|v| v.as_slice()));
        assert_eq!(chunked, sequential);
        let flat: Vec<u32> = samples.concat();
        assert_eq!(chunked, Histogram::from_values(8, &flat));
    }

    #[test]
    fn cdf_monotone_ends_at_one() {
        let h = Histogram::from_values(8, &[0, 10, 10, 200, 255]);
        let cdf = h.cdf();
        assert_eq!(cdf.len(), 256);
        let mut prev = 0.0;
        for &(_, f) in &cdf {
            assert!(f >= prev);
            prev = f;
        }
        assert!((cdf[255].1 - 1.0).abs() < 1e-12);
    }
}
