//! The APack decoder (paper §V-A, Fig 4).
//!
//! Mirrors [`super::encoder`]: 16-bit `HI`/`LO` windows plus a 16-bit `CODE`
//! register that slides over the encoded symbol stream. Each step:
//!
//! 1. "PCNT Table" (Fig 4b): find the row whose *scaled* probability-count
//!    range contains `CODE`. The hardware compares `CODE` against every
//!    row's scaled boundary in parallel; we model that row scan exactly, and
//!    additionally provide a division-based fast path used on the software
//!    hot path — the two are proven equivalent (`debug_assert` + property
//!    tests, DESIGN.md invariant 3).
//! 2. "SYMBOL Gen" (Fig 4c): emit `v_min[row] + offset`, consuming
//!    `OL[row]` bits from the offset stream.
//! 3. "HI/LO/CODE Adj" (Fig 4d): renormalize, consuming fresh symbol-stream
//!    bits into `CODE` and applying the underflow transform (`CODE ^=
//!    0x4000`) in lockstep with the encoder.

use super::bitstream::BitReader;
use super::table::{SymbolTable, PROB_BITS};
use super::NUM_ROWS;
use crate::error::{Error, Result};

const TOP_BIT: u16 = 0x8000;
const SECOND_BIT: u16 = 0x4000;

/// Which symbol-resolution circuit to model. Both produce identical results
/// on every valid stream; `RowScan` mirrors the 16-comparator hardware and
/// is also the faster software path (a 16-row multiply/compare scan beats
/// one integer division per value — EXPERIMENTS.md §Perf iteration 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResolveMode {
    /// Parallel comparison of CODE against each row's scaled boundaries, as
    /// the hardware PCNT Table block does.
    #[default]
    RowScan,
    /// Invert the scaling with one division, then a cumulative-count lookup.
    Division,
}

/// Streaming APack decoder for one (sub)stream.
#[derive(Debug, Clone)]
pub struct ApackDecoder<'t, 'a> {
    table: &'t SymbolTable,
    cum: [u16; NUM_ROWS + 1],
    hi: u16,
    lo: u16,
    code: u16,
    sym_in: BitReader<'a>,
    mode: ResolveMode,
    /// Values decoded so far (for error reporting).
    count: usize,
}

impl<'t, 'a> ApackDecoder<'t, 'a> {
    /// New decoder: primes the 16-bit `CODE` register from the symbol
    /// stream (reading past a short stream pads with zeros, as the
    /// hardware's shift register would latch an idle bus).
    pub fn new(table: &'t SymbolTable, mut sym_in: BitReader<'a>) -> Result<Self> {
        let mut cum = [0u16; NUM_ROWS + 1];
        for i in 0..NUM_ROWS {
            cum[i + 1] = table.rows()[i].hi_cnt;
        }
        let code = sym_in.read_bits(16) as u16;
        Ok(Self {
            table,
            cum,
            hi: 0xFFFF,
            lo: 0x0000,
            code,
            sym_in,
            mode: ResolveMode::default(),
            count: 0,
        })
    }

    /// Select the symbol-resolution model (see [`ResolveMode`]).
    pub fn with_mode(mut self, mode: ResolveMode) -> Self {
        self.mode = mode;
        self
    }

    /// Values decoded so far.
    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    /// Hardware model: scan rows in order, pick the first whose scaled
    /// upper boundary exceeds CODE. Returns `(row, scaled_lo, scaled_hi)`
    /// so the narrowing step reuses the boundaries instead of recomputing
    /// them. Consecutive rows share a boundary, so the scan needs one
    /// multiply per row (empty rows fall out naturally: their scaled span
    /// is empty). Matches the parallel-comparator PCNT block bit-for-bit.
    #[inline]
    fn resolve_row_scan(&self, range: u32) -> Option<(usize, u32, u32)> {
        let d = (self.code - self.lo) as u32;
        let mut s_lo = 0u32; // cum[0] == 0 scales to 0
        for i in 0..NUM_ROWS {
            let s_hi = (range * self.cum[i + 1] as u32) >> PROB_BITS;
            if d < s_hi {
                return Some((i, s_lo, s_hi));
            }
            s_lo = s_hi;
        }
        None
    }

    /// Alternative path: invert the floor-scaling with one division.
    /// `k = floor(((d+1) << PROB_BITS - 1) / range)` is the largest count
    /// `c` with `floor(range*c >> PROB_BITS) <= d`; the matching row is the
    /// one whose cumulative range contains `k`.
    #[inline]
    fn resolve_division(&self, range: u32) -> Option<(usize, u32, u32)> {
        let d = (self.code - self.lo) as u32;
        // (d+1) ≤ 2^16, so the scaled dividend fits u32 — a 32-bit divide
        // is markedly cheaper than 64-bit (EXPERIMENTS.md §Perf iter. 3).
        let k = (((d + 1) << PROB_BITS) - 1) / range;
        if k >= self.cum[NUM_ROWS] as u32 {
            return None;
        }
        let k = k as u16;
        // 16 entries: linear scan is faster than binary search here.
        let mut idx = 0usize;
        for i in 0..NUM_ROWS {
            idx = if k >= self.cum[i] { i } else { idx };
        }
        // k >= cum[idx] and k < cum[idx+1] implies the row is non-empty.
        let s_lo = (range * self.cum[idx] as u32) >> PROB_BITS;
        let s_hi = (range * self.cum[idx + 1] as u32) >> PROB_BITS;
        Some((idx, s_lo, s_hi))
    }

    /// Decode one value, consuming offset bits from `ofs_in`.
    pub fn decode_value(&mut self, ofs_in: &mut BitReader<'_>) -> Result<u32> {
        let range = (self.hi - self.lo) as u32 + 1;
        let (idx, s_lo, s_hi) = match self.mode {
            ResolveMode::RowScan => self.resolve_row_scan(range),
            ResolveMode::Division => {
                let r = self.resolve_division(range);
                debug_assert_eq!(r, self.resolve_row_scan(range), "resolver divergence");
                r
            }
        }
        .ok_or(Error::CorruptStream { position: self.count })?;

        // SYMBOL Gen: reconstruct the value.
        let row = &self.table.rows()[idx];
        let offset = if row.ol > 0 { ofs_in.read_bits(row.ol) as u32 } else { 0 };
        let value = row.v_min + offset;
        if value > row.v_max {
            // Offset escaped the row's span: corrupt offset stream. (The
            // encoder never produces this; the hardware would simply emit a
            // wrong value — the software model is stricter.)
            return Err(Error::CorruptStream { position: self.count });
        }

        // HI/LO/CODE Adj: narrow (reusing the resolver's scaled bounds)
        // then renormalize in lockstep with the encoder.
        let t_hi = self.lo as u32 + s_hi - 1;
        let t_lo = self.lo as u32 + s_lo;
        let mut hi = t_hi as u16;
        let mut lo = t_lo as u16;
        let mut code = self.code;
        // Renormalize in lockstep with the encoder. Common-prefix bits are
        // discarded in one batch per pass (mirroring the encoder's LD1
        // batching); underflow steps stay per-bit. Bit-identical to the
        // one-bit loop (EXPERIMENTS.md §Perf iter. 3).
        loop {
            let diff = hi ^ lo;
            if diff & TOP_BIT == 0 {
                let k = (diff as u32 | 1).leading_zeros() - 16;
                lo <<= k;
                hi = (hi << k) | ((1u32 << k) as u16).wrapping_sub(1);
                code = (code << k) | self.sym_in.read_bits(k) as u16;
            } else if lo & SECOND_BIT != 0 && hi & SECOND_BIT == 0 {
                // Underflow: remove the second MSB from all three.
                code = ((code ^ SECOND_BIT) << 1) | self.sym_in.read_bit() as u16;
                lo = (lo & (SECOND_BIT - 1)) << 1;
                hi = ((hi | SECOND_BIT) << 1) | 1;
            } else {
                break;
            }
        }
        self.hi = hi;
        self.lo = lo;
        self.code = code;
        self.count += 1;
        Ok(value)
    }

    /// Decode exactly `n` values into a vector.
    pub fn decode_all(
        table: &SymbolTable,
        sym: BitReader<'a>,
        ofs: &mut BitReader<'_>,
        n: usize,
    ) -> Result<Vec<u32>> {
        let mut dec = ApackDecoder::new(table, sym)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(dec.decode_value(ofs)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::encoder::ApackEncoder;
    use super::*;

    fn encode(table: &SymbolTable, values: &[u32]) -> (Vec<u8>, usize, Vec<u8>, usize) {
        ApackEncoder::encode_all(table, values).unwrap()
    }

    #[test]
    fn row_scan_and_division_agree_on_long_stream() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..20_000u32).map(|i| (i * 2654435761) >> 24).collect();
        let (sym, sb, ofs, ob) = encode(&t, &values);

        for mode in [ResolveMode::RowScan, ResolveMode::Division] {
            let mut dec =
                ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap().with_mode(mode);
            let mut ofs_r = BitReader::new(&ofs, ob);
            for (i, &v) in values.iter().enumerate() {
                assert_eq!(dec.decode_value(&mut ofs_r).unwrap(), v, "mode {mode:?} idx {i}");
            }
        }
    }

    #[test]
    fn corrupt_symbol_stream_detected_or_mismatches() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..512u32).map(|i| i % 256).collect();
        let (mut sym, sb, ofs, ob) = encode(&t, &values);
        // Flip a bit early in the symbol stream.
        sym[1] ^= 0x40;
        let mut dec = ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap();
        let mut ofs_r = BitReader::new(&ofs, ob);
        let mut diverged = false;
        for &v in &values {
            match dec.decode_value(&mut ofs_r) {
                Ok(got) if got != v => {
                    diverged = true;
                    break;
                }
                Err(_) => {
                    diverged = true;
                    break;
                }
                Ok(_) => {}
            }
        }
        assert!(diverged, "bit flip must corrupt the decode");
    }

    #[test]
    fn decode_all_helper() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..100).map(|i| (i * 37) % 256).collect();
        let (sym, sb, ofs, ob) = encode(&t, &values);
        let mut ofs_r = BitReader::new(&ofs, ob);
        let got =
            ApackDecoder::decode_all(&t, BitReader::new(&sym, sb), &mut ofs_r, values.len())
                .unwrap();
        assert_eq!(got, values);
    }
}
