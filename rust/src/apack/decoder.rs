//! The APack decoder (paper §V-A, Fig 4).
//!
//! Mirrors [`super::encoder`]: 16-bit `HI`/`LO` windows plus a 16-bit `CODE`
//! register that slides over the encoded symbol stream. Each step:
//!
//! 1. "PCNT Table" (Fig 4b): find the row whose *scaled* probability-count
//!    range contains `CODE`. The hardware compares `CODE` against every
//!    row's scaled boundary in parallel; we model that row scan exactly and
//!    additionally provide two software fast paths — a division that inverts
//!    the scaling, and a division + count→row LUT ([`ResolveMode::Lut`],
//!    the default). All three are proven bit-identical (`debug_assert` +
//!    property tests, DESIGN.md invariant 3).
//! 2. "SYMBOL Gen" (Fig 4c): emit `v_min[row] + offset`, consuming
//!    `OL[row]` bits from the offset stream. An exhausted offset stream is
//!    a corrupt stream, not a zero offset (see [`super::bitstream`]).
//! 3. "HI/LO/CODE Adj" (Fig 4d): renormalize, consuming fresh symbol-stream
//!    bits into `CODE` and applying the underflow transform (`CODE ^=
//!    0x4000`) in lockstep with the encoder.
//!
//! Two call granularities share the same state machine:
//! [`ApackDecoder::decode_value`] is the per-value reference path, and
//! [`ApackDecoder::decode_into`] is the block fast path that keeps
//! `HI`/`LO`/`CODE` in locals across a whole output slice and hoists the
//! per-value mode dispatch out of the loop (DESIGN.md §8). The two are
//! bit-identical, including `CorruptStream` positions.

use super::bitstream::BitReader;
use super::table::{SymbolTable, PROB_BITS};
use super::NUM_ROWS;
use crate::error::{Error, Result};

const TOP_BIT: u16 = 0x8000;
const SECOND_BIT: u16 = 0x4000;

/// Which symbol-resolution circuit to model. All three produce identical
/// results on every stream — including identical `CorruptStream` positions
/// on corrupt input (DESIGN.md invariant 3). `RowScan` mirrors the
/// 16-comparator hardware; `Lut` is the software hot path (one 32-bit
/// division plus one LUT load, no data-dependent branching — see the
/// `codec_hot_path` bench and DESIGN.md §8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResolveMode {
    /// Parallel comparison of CODE against each row's scaled boundaries, as
    /// the hardware PCNT Table block does.
    RowScan,
    /// Invert the scaling with one division, then a cumulative-count scan.
    Division,
    /// Invert the scaling with one division, then map the recovered count
    /// to its row through the table's precomputed count→row LUT
    /// ([`SymbolTable::row_for_count`]).
    #[default]
    Lut,
}

impl ResolveMode {
    /// All modes, for exhaustive equivalence sweeps.
    pub const ALL: [ResolveMode; 3] =
        [ResolveMode::RowScan, ResolveMode::Division, ResolveMode::Lut];
}

/// Streaming APack decoder for one (sub)stream.
#[derive(Debug, Clone)]
pub struct ApackDecoder<'t, 'a> {
    table: &'t SymbolTable,
    cum: [u16; NUM_ROWS + 1],
    hi: u16,
    lo: u16,
    code: u16,
    sym_in: BitReader<'a>,
    mode: ResolveMode,
    /// Values decoded so far (for error reporting).
    count: usize,
}

impl<'t, 'a> ApackDecoder<'t, 'a> {
    /// New decoder: primes the 16-bit `CODE` register from the symbol
    /// stream (reading past a short stream pads with zeros, as the
    /// hardware's shift register would latch an idle bus — the one place
    /// the symbol stream's zero-latch is load-bearing by design).
    pub fn new(table: &'t SymbolTable, mut sym_in: BitReader<'a>) -> Result<Self> {
        let mut cum = [0u16; NUM_ROWS + 1];
        for i in 0..NUM_ROWS {
            cum[i + 1] = table.rows()[i].hi_cnt;
        }
        let code = sym_in.read_bits(16) as u16;
        Ok(Self {
            table,
            cum,
            hi: 0xFFFF,
            lo: 0x0000,
            code,
            sym_in,
            mode: ResolveMode::default(),
            count: 0,
        })
    }

    /// Select the symbol-resolution model (see [`ResolveMode`]).
    pub fn with_mode(mut self, mode: ResolveMode) -> Self {
        self.mode = mode;
        self
    }

    /// Values decoded so far.
    #[inline]
    pub fn count(&self) -> usize {
        self.count
    }

    /// Hardware model: scan rows in order, pick the first whose scaled
    /// upper boundary exceeds CODE. Returns `(row, scaled_lo, scaled_hi)`
    /// so the narrowing step reuses the boundaries instead of recomputing
    /// them. Consecutive rows share a boundary, so the scan needs one
    /// multiply per row (empty rows fall out naturally: their scaled span
    /// is empty). Matches the parallel-comparator PCNT block bit-for-bit.
    #[inline]
    fn resolve_row_scan(&self, range: u32) -> Option<(usize, u32, u32)> {
        let d = self.code.wrapping_sub(self.lo) as u32;
        let mut s_lo = 0u32; // cum[0] == 0 scales to 0
        for i in 0..NUM_ROWS {
            let s_hi = (range * self.cum[i + 1] as u32) >> PROB_BITS;
            if d < s_hi {
                return Some((i, s_lo, s_hi));
            }
            s_lo = s_hi;
        }
        None
    }

    /// Division path: invert the floor-scaling with one division.
    /// `k = floor(((d+1) << PROB_BITS - 1) / range)` is the largest count
    /// `c` with `floor(range*c >> PROB_BITS) <= d`; the matching row is the
    /// one whose cumulative range contains `k`.
    #[inline]
    fn resolve_division(&self, range: u32) -> Option<(usize, u32, u32)> {
        let d = self.code.wrapping_sub(self.lo) as u32;
        // (d+1) ≤ 2^16, so the scaled dividend fits u32 — a 32-bit divide
        // is markedly cheaper than 64-bit.
        let k = (((d + 1) << PROB_BITS) - 1) / range;
        if k >= self.cum[NUM_ROWS] as u32 {
            return None;
        }
        let k = k as u16;
        // 16 entries: linear scan is faster than binary search here.
        let mut idx = 0usize;
        for i in 0..NUM_ROWS {
            idx = if k >= self.cum[i] { i } else { idx };
        }
        // k >= cum[idx] and k < cum[idx+1] implies the row is non-empty.
        let s_lo = (range * self.cum[idx] as u32) >> PROB_BITS;
        let s_hi = (range * self.cum[idx + 1] as u32) >> PROB_BITS;
        Some((idx, s_lo, s_hi))
    }

    /// LUT path: same division as [`Self::resolve_division`], then one load
    /// from the table's count→row LUT instead of the cumulative scan. The
    /// recovered `k` satisfies `cum[idx] <= k < cum[idx+1]` exactly when the
    /// scan would pick `idx`, so the two are equivalent by construction.
    #[inline]
    fn resolve_lut(&self, range: u32) -> Option<(usize, u32, u32)> {
        let d = self.code.wrapping_sub(self.lo) as u32;
        let k = (((d + 1) << PROB_BITS) - 1) / range;
        if k >= self.cum[NUM_ROWS] as u32 {
            return None;
        }
        let idx = self.table.row_for_count(k as u16);
        let s_lo = (range * self.cum[idx] as u32) >> PROB_BITS;
        let s_hi = (range * self.cum[idx + 1] as u32) >> PROB_BITS;
        Some((idx, s_lo, s_hi))
    }

    #[inline]
    fn resolve(&self, range: u32) -> Option<(usize, u32, u32)> {
        match self.mode {
            ResolveMode::RowScan => self.resolve_row_scan(range),
            ResolveMode::Division => {
                let r = self.resolve_division(range);
                debug_assert_eq!(r, self.resolve_row_scan(range), "resolver divergence");
                r
            }
            ResolveMode::Lut => {
                let r = self.resolve_lut(range);
                debug_assert_eq!(r, self.resolve_row_scan(range), "resolver divergence");
                r
            }
        }
    }

    /// Decode one value, consuming offset bits from `ofs_in`. This is the
    /// per-value reference path; [`Self::decode_into`] is the block fast
    /// path with identical semantics.
    pub fn decode_value(&mut self, ofs_in: &mut BitReader<'_>) -> Result<u32> {
        let range = (self.hi - self.lo) as u32 + 1;
        let (idx, s_lo, s_hi) =
            self.resolve(range).ok_or(Error::CorruptStream { position: self.count })?;

        // SYMBOL Gen: reconstruct the value. Offset bits are verbatim
        // payload: running out mid-value means the stream lies about its
        // length, so fail loudly instead of latching zeros.
        let row = &self.table.rows()[idx];
        let offset = if row.ol > 0 {
            if ofs_in.bits_remaining() < row.ol as usize {
                return Err(Error::CorruptStream { position: self.count });
            }
            ofs_in.read_bits(row.ol) as u32
        } else {
            0
        };
        let value = row.v_min + offset;
        if value > row.v_max {
            // Offset escaped the row's span: corrupt offset stream. (The
            // encoder never produces this; the hardware would simply emit a
            // wrong value — the software model is stricter.)
            return Err(Error::CorruptStream { position: self.count });
        }

        // HI/LO/CODE Adj: narrow (reusing the resolver's scaled bounds)
        // then renormalize in lockstep with the encoder.
        let t_hi = self.lo as u32 + s_hi - 1;
        let t_lo = self.lo as u32 + s_lo;
        let mut hi = t_hi as u16;
        let mut lo = t_lo as u16;
        let mut code = self.code;
        // Renormalize in lockstep with the encoder. Common-prefix bits are
        // discarded in one batch per pass (mirroring the encoder's LD1
        // batching); underflow steps stay per-bit. Bit-identical to the
        // one-bit loop.
        loop {
            let diff = hi ^ lo;
            if diff & TOP_BIT == 0 {
                let k = (diff as u32 | 1).leading_zeros() - 16;
                lo <<= k;
                hi = (hi << k) | ((1u32 << k) as u16).wrapping_sub(1);
                code = (code << k) | self.sym_in.read_bits(k) as u16;
            } else if lo & SECOND_BIT != 0 && hi & SECOND_BIT == 0 {
                // Underflow: remove the second MSB from all three.
                code = ((code ^ SECOND_BIT) << 1) | self.sym_in.read_bit() as u16;
                lo = (lo & (SECOND_BIT - 1)) << 1;
                hi = ((hi | SECOND_BIT) << 1) | 1;
            } else {
                break;
            }
        }
        self.hi = hi;
        self.lo = lo;
        self.code = code;
        self.count += 1;
        Ok(value)
    }

    /// Block fast path: decode exactly `out.len()` values into `out`.
    ///
    /// Bit-identical to calling [`Self::decode_value`] once per slot —
    /// including the position carried by `Error::CorruptStream` — but
    /// keeps `HI`/`LO`/`CODE` and the cumulative-count array in locals for
    /// the whole block, resolves the [`ResolveMode`] dispatch once instead
    /// of per value, and raises exactly one error surface per block. On
    /// error the decoder state reflects the values decoded so far, and
    /// `out[..error.position - count_before]` holds their decoded values.
    pub fn decode_into(&mut self, out: &mut [u32], ofs_in: &mut BitReader<'_>) -> Result<()> {
        // The tracer's single Decode site: every block decode (store
        // chunks, coordinator shards, benches) funnels through here. One
        // span per block, one relaxed atomic load when tracing is off —
        // this is the call site the CI overhead gate measures.
        let _span = crate::obs::span_n(crate::obs::Stage::Decode, out.len() as u64);
        match self.mode {
            ResolveMode::RowScan => self.decode_block::<0>(out, ofs_in),
            ResolveMode::Division => self.decode_block::<1>(out, ofs_in),
            ResolveMode::Lut => self.decode_block::<2>(out, ofs_in),
        }
    }

    /// Monomorphized block loop (`MODE`: 0 = RowScan, 1 = Division,
    /// 2 = Lut) so the resolver inlines with no per-value dispatch.
    fn decode_block<const MODE: u8>(
        &mut self,
        out: &mut [u32],
        ofs_in: &mut BitReader<'_>,
    ) -> Result<()> {
        let table = self.table;
        let rows = table.rows();
        let cum = self.cum;
        let (mut hi, mut lo, mut code) = (self.hi, self.lo, self.code);
        let sym_in = &mut self.sym_in;
        let mut done = 0usize;
        let mut corrupt = false;
        for slot in out.iter_mut() {
            let range = (hi - lo) as u32 + 1;
            let d = code.wrapping_sub(lo) as u32;
            // Resolve the symbol (see the resolve_* methods for the math;
            // this repeats them on block locals).
            let idx;
            let s_lo;
            let s_hi;
            if MODE == 0 {
                let mut r = NUM_ROWS;
                let mut sl = 0u32;
                let mut sh = 0u32;
                for i in 0..NUM_ROWS {
                    sl = sh;
                    sh = (range * cum[i + 1] as u32) >> PROB_BITS;
                    if d < sh {
                        r = i;
                        break;
                    }
                }
                if r == NUM_ROWS {
                    corrupt = true;
                    break;
                }
                idx = r;
                s_lo = sl;
                s_hi = sh;
            } else {
                let k = (((d + 1) << PROB_BITS) - 1) / range;
                if k >= cum[NUM_ROWS] as u32 {
                    corrupt = true;
                    break;
                }
                idx = if MODE == 1 {
                    let mut r = 0usize;
                    for i in 0..NUM_ROWS {
                        r = if k as u16 >= cum[i] { i } else { r };
                    }
                    r
                } else {
                    table.row_for_count(k as u16)
                };
                s_lo = (range * cum[idx] as u32) >> PROB_BITS;
                s_hi = (range * cum[idx + 1] as u32) >> PROB_BITS;
            }

            // SYMBOL Gen (exhausted offset stream = corrupt, never zeros).
            let row = &rows[idx];
            let value = if row.ol > 0 {
                if ofs_in.bits_remaining() < row.ol as usize {
                    corrupt = true;
                    break;
                }
                row.v_min + ofs_in.read_bits(row.ol) as u32
            } else {
                row.v_min
            };
            if value > row.v_max {
                corrupt = true;
                break;
            }
            *slot = value;

            // HI/LO/CODE Adj on block locals, in lockstep with the encoder.
            hi = (lo as u32 + s_hi - 1) as u16;
            lo = (lo as u32 + s_lo) as u16;
            loop {
                let diff = hi ^ lo;
                if diff & TOP_BIT == 0 {
                    let k = (diff as u32 | 1).leading_zeros() - 16;
                    lo <<= k;
                    hi = (hi << k) | ((1u32 << k) as u16).wrapping_sub(1);
                    code = (code << k) | sym_in.read_bits(k) as u16;
                } else if lo & SECOND_BIT != 0 && hi & SECOND_BIT == 0 {
                    code = ((code ^ SECOND_BIT) << 1) | sym_in.read_bit() as u16;
                    lo = (lo & (SECOND_BIT - 1)) << 1;
                    hi = ((hi | SECOND_BIT) << 1) | 1;
                } else {
                    break;
                }
            }
            done += 1;
        }
        self.hi = hi;
        self.lo = lo;
        self.code = code;
        self.count += done;
        if corrupt {
            return Err(Error::CorruptStream { position: self.count });
        }
        Ok(())
    }

    /// Decode exactly `n` values into a vector. Delegates to the block
    /// fast path ([`Self::decode_into`]) — there is exactly one decode
    /// loop to keep in sync with the encoder.
    pub fn decode_all(
        table: &SymbolTable,
        sym: BitReader<'a>,
        ofs: &mut BitReader<'_>,
        n: usize,
    ) -> Result<Vec<u32>> {
        let mut dec = ApackDecoder::new(table, sym)?;
        let mut out = vec![0u32; n];
        dec.decode_into(&mut out, ofs)?;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::super::encoder::ApackEncoder;
    use super::*;

    fn encode(table: &SymbolTable, values: &[u32]) -> (Vec<u8>, usize, Vec<u8>, usize) {
        ApackEncoder::encode_all(table, values).unwrap()
    }

    #[test]
    fn all_resolvers_agree_on_long_stream() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..20_000u32).map(|i| (i * 2654435761) >> 24).collect();
        let (sym, sb, ofs, ob) = encode(&t, &values);

        for mode in ResolveMode::ALL {
            let mut dec =
                ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap().with_mode(mode);
            let mut ofs_r = BitReader::new(&ofs, ob);
            for (i, &v) in values.iter().enumerate() {
                assert_eq!(dec.decode_value(&mut ofs_r).unwrap(), v, "mode {mode:?} idx {i}");
            }
        }
    }

    #[test]
    fn block_decode_matches_per_value() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..10_000u32).map(|i| (i * 2654435761) >> 24).collect();
        let (sym, sb, ofs, ob) = encode(&t, &values);
        for mode in ResolveMode::ALL {
            // Per-value reference.
            let mut dec =
                ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap().with_mode(mode);
            let mut ofs_r = BitReader::new(&ofs, ob);
            let reference: Vec<u32> =
                values.iter().map(|_| dec.decode_value(&mut ofs_r).unwrap()).collect();
            assert_eq!(reference, values);
            // Block path, including split across multiple blocks.
            for split in [0usize, 1, values.len() / 3, values.len()] {
                let mut dec =
                    ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap().with_mode(mode);
                let mut ofs_r = BitReader::new(&ofs, ob);
                let mut out = vec![0u32; values.len()];
                let (a, b) = out.split_at_mut(split);
                dec.decode_into(a, &mut ofs_r).unwrap();
                dec.decode_into(b, &mut ofs_r).unwrap();
                assert_eq!(out, values, "mode {mode:?} split {split}");
                assert_eq!(dec.count(), values.len());
            }
        }
    }

    #[test]
    fn exhausted_offset_stream_is_corrupt_not_zero() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..2000u32).map(|i| (i * 37) % 256).collect();
        let (sym, sb, ofs, ob) = encode(&t, &values);
        assert!(ob > 0);
        // Truncate the offset stream: both paths must error with the SAME
        // position, not fabricate zero offsets.
        let truncated = ob / 3;
        let per_value_err = {
            let mut dec = ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap();
            let mut ofs_r = BitReader::new(&ofs, truncated);
            let mut err = None;
            for _ in 0..values.len() {
                if let Err(e) = dec.decode_value(&mut ofs_r) {
                    err = Some(e);
                    break;
                }
            }
            err.expect("truncated offsets must error")
        };
        let mut dec = ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap();
        let mut ofs_r = BitReader::new(&ofs, truncated);
        let mut out = vec![0u32; values.len()];
        let block_err = dec.decode_into(&mut out, &mut ofs_r).unwrap_err();
        assert_eq!(per_value_err, block_err);
        assert!(matches!(block_err, Error::CorruptStream { .. }));
    }

    #[test]
    fn corrupt_symbol_stream_detected_or_mismatches() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..512u32).map(|i| i % 256).collect();
        let (mut sym, sb, ofs, ob) = encode(&t, &values);
        // Flip a bit early in the symbol stream.
        sym[1] ^= 0x40;
        let mut dec = ApackDecoder::new(&t, BitReader::new(&sym, sb)).unwrap();
        let mut ofs_r = BitReader::new(&ofs, ob);
        let mut diverged = false;
        for &v in &values {
            match dec.decode_value(&mut ofs_r) {
                Ok(got) if got != v => {
                    diverged = true;
                    break;
                }
                Err(_) => {
                    diverged = true;
                    break;
                }
                Ok(_) => {}
            }
        }
        assert!(diverged, "bit flip must corrupt the decode");
    }

    #[test]
    fn decode_all_helper() {
        let t = SymbolTable::uniform(8);
        let values: Vec<u32> = (0..100).map(|i| (i * 37) % 256).collect();
        let (sym, sb, ofs, ob) = encode(&t, &values);
        let mut ofs_r = BitReader::new(&ofs, ob);
        let got =
            ApackDecoder::decode_all(&t, BitReader::new(&sym, sb), &mut ofs_r, values.len())
                .unwrap();
        assert_eq!(got, values);
    }
}
