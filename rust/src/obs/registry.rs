//! Named metrics registry: atomic counters, gauges and latency
//! histograms behind `"component.metric"` names, snapshotted into one
//! [`RegistrySnapshot`] the exporters and the legacy stat structs
//! (`ReadStats`, `PackStats`, `MetricsSnapshot`) are views over
//! (ISSUE 6; DESIGN.md §10).
//!
//! Registration is get-or-create and hands back an `Arc` handle, so hot
//! paths update a pre-fetched atomic — the registry's map lock is only
//! taken at registration and snapshot time. Registries are
//! **per-component** (one per `StoreReader`, `StoreWriter`,
//! `ServingEngine`), not process-global: two readers don't share
//! counters, and snapshots [`RegistrySnapshot::merge`] across components
//! exactly where the old structs used to `merge`.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::hist::{LatencyHistogram, LatencySnapshot};

/// Monotonically increasing atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Only [`MetricsRegistry::reset`] should call this — counters are
    /// monotonic within a measurement window.
    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Last-write-wins atomic gauge (with a `set_max` high-water helper).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Hist(Arc<LatencyHistogram>),
}

/// One component's named metrics.
#[derive(Default)]
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create the counter `name`. Panics if `name` is already
    /// registered as a different kind (names are compile-time constants
    /// owned by one component — see the DESIGN.md §10 glossary).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Get-or-create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Get-or-create the latency histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<LatencyHistogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Hist(Arc::new(LatencyHistogram::new())))
        {
            Metric::Hist(h) => Arc::clone(h),
            _ => panic!("metric {name:?} already registered with a different kind"),
        }
    }

    /// Point-in-time values of every registered metric.
    pub fn snapshot(&self) -> RegistrySnapshot {
        let m = self.metrics.lock().unwrap();
        let mut snap = RegistrySnapshot::default();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => {
                    snap.counters.insert(name.clone(), c.get());
                }
                Metric::Gauge(g) => {
                    snap.gauges.insert(name.clone(), g.get());
                }
                Metric::Hist(h) => {
                    snap.hists.insert(name.clone(), h.snapshot());
                }
            }
        }
        snap
    }

    /// Zero every counter, gauge and histogram (new measurement window).
    pub fn reset(&self) {
        let m = self.metrics.lock().unwrap();
        for metric in m.values() {
            match metric {
                Metric::Counter(c) => c.reset(),
                Metric::Gauge(g) => g.set(0),
                Metric::Hist(h) => h.reset(),
            }
        }
    }
}

/// Point-in-time registry values; what the exporters serialize and the
/// legacy stat structs are built from.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RegistrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, u64>,
    pub hists: BTreeMap<String, LatencySnapshot>,
}

impl RegistrySnapshot {
    /// Counter value, 0 when absent.
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, 0 when absent.
    pub fn gauge(&self, name: &str) -> u64 {
        self.gauges.get(name).copied().unwrap_or(0)
    }

    /// Histogram snapshot, empty when absent.
    pub fn hist(&self, name: &str) -> LatencySnapshot {
        self.hists.get(name).copied().unwrap_or_default()
    }

    /// Fold another component's snapshot in: counters sum, gauges take
    /// the max (high-water semantics across shards), histograms keep the
    /// first registered (per-component distributions don't merge
    /// losslessly at the snapshot level).
    pub fn merge(&mut self, other: &RegistrySnapshot) {
        for (name, v) in &other.counters {
            *self.counters.entry(name.clone()).or_insert(0) += v;
        }
        for (name, v) in &other.gauges {
            let e = self.gauges.entry(name.clone()).or_insert(0);
            *e = (*e).max(*v);
        }
        for (name, h) in &other.hists {
            self.hists.entry(name.clone()).or_insert(*h);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn get_or_create_returns_the_same_atomic() {
        let r = MetricsRegistry::new();
        let a = r.counter("x.hits");
        let b = r.counter("x.hits");
        a.add(3);
        b.inc();
        assert_eq!(r.snapshot().counter("x.hits"), 4);
        assert_eq!(r.snapshot().counter("x.misses"), 0);
    }

    #[test]
    fn snapshot_covers_all_kinds_and_reset_zeroes() {
        let r = MetricsRegistry::new();
        r.counter("c").add(7);
        r.gauge("g").set_max(9);
        r.histogram("h").record(Duration::from_micros(5));
        let s = r.snapshot();
        assert_eq!(s.counter("c"), 7);
        assert_eq!(s.gauge("g"), 9);
        assert_eq!(s.hist("h").count, 1);
        r.reset();
        let s = r.snapshot();
        assert_eq!((s.counter("c"), s.gauge("g"), s.hist("h").count), (0, 0, 0));
    }

    #[test]
    fn merge_sums_counters_maxes_gauges() {
        let (a, b) = (MetricsRegistry::new(), MetricsRegistry::new());
        a.counter("c").add(2);
        b.counter("c").add(5);
        a.gauge("g").set(10);
        b.gauge("g").set(4);
        b.counter("only_b").inc();
        let mut s = a.snapshot();
        s.merge(&b.snapshot());
        assert_eq!(s.counter("c"), 7);
        assert_eq!(s.gauge("g"), 10);
        assert_eq!(s.counter("only_b"), 1);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn kind_mismatch_panics() {
        let r = MetricsRegistry::new();
        r.counter("x");
        r.gauge("x");
    }
}
