//! **Span-forest attribution profiles** — fold drained [`SpanEvent`]s
//! into a weighted per-stage-path profile (DESIGN.md §12).
//!
//! The tracer answers "what happened"; this module answers "which stage
//! owns the time". Each completed span contributes its wall-clock
//! duration to the *stage path* leading to it (`request;execute;decode`),
//! and its **self time** — duration minus the summed durations of its
//! direct children — to the same path. Self time is what a flamegraph
//! renders, so [`Profile::collapsed_stack`] emits the standard
//! collapsed-stack text (`path self_nanos` per line) that
//! `flamegraph.pl` / speedscope / inferno all consume.
//!
//! Folding rules (tested in this file):
//!
//! - A span's path is the stage names from its root ancestor down to
//!   itself, `;`-joined. Spans whose parent id is unknown (parent 0, or
//!   a parent dropped by the ring) are roots of their own path.
//! - `total_ns` sums durations per path; `self_ns` subtracts direct
//!   children only (grandchildren are already inside the children).
//! - Children that ran *in parallel* on worker threads (the v2 lane
//!   fan-out) can sum to more than the parent's wall clock; self time
//!   saturates at zero rather than going negative.
//! - p50/p99 are per-path nearest-rank percentiles over span durations.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;

use super::trace::SpanEvent;

/// Aggregated timing for one stage path (e.g. `request;execute;decode`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PathStats {
    /// Number of spans folded into this path.
    pub count: u64,
    /// Summed wall-clock duration of those spans.
    pub total_ns: u64,
    /// Summed duration minus direct-children durations (saturating).
    pub self_ns: u64,
    /// Summed `count` payloads (values/bytes, per the stage's convention).
    pub units: u64,
    /// Nearest-rank p50 of span durations on this path.
    pub p50_ns: u64,
    /// Nearest-rank p99 of span durations on this path.
    pub p99_ns: u64,
}

/// A folded span forest: stage path → [`PathStats`].
#[derive(Debug, Clone, Default)]
pub struct Profile {
    paths: BTreeMap<String, PathStats>,
    /// Spans folded (events with `end >= start`; all of them, in practice).
    pub span_count: usize,
}

/// Walking a parent chain deeper than this aborts to a root path —
/// a cycle can only come from ring corruption, never from the RAII API.
const MAX_DEPTH: usize = 64;

/// One path frame for a span: the stage name, suffixed `[tag]` when the
/// span carries an attribution tag — `decode_lanes[avx2]` — so profiles
/// split e.g. kernel variants into distinct rows.
fn frame(e: &SpanEvent) -> String {
    if e.tag.is_empty() {
        e.stage.name().to_string()
    } else {
        format!("{}[{}]", e.stage.name(), e.tag)
    }
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

impl Profile {
    /// Fold a drained span forest into per-path aggregates.
    pub fn from_events(events: &[SpanEvent]) -> Profile {
        let index: HashMap<u64, usize> =
            events.iter().enumerate().map(|(i, e)| (e.id, i)).collect();
        // Direct-children duration per parent index, for self time.
        let mut child_ns = vec![0u64; events.len()];
        for e in events {
            if let Some(&pi) = index.get(&e.parent) {
                child_ns[pi] = child_ns[pi].saturating_add(e.duration_ns());
            }
        }
        let mut durations: BTreeMap<String, Vec<u64>> = BTreeMap::new();
        let mut paths: BTreeMap<String, PathStats> = BTreeMap::new();
        for (i, e) in events.iter().enumerate() {
            let mut names = vec![frame(e)];
            let mut cur = e.parent;
            for _ in 0..MAX_DEPTH {
                let Some(&pi) = index.get(&cur) else { break };
                names.push(frame(&events[pi]));
                cur = events[pi].parent;
            }
            names.reverse();
            let path = names.join(";");
            let dur = e.duration_ns();
            let s = paths.entry(path.clone()).or_default();
            s.count += 1;
            s.total_ns = s.total_ns.saturating_add(dur);
            s.self_ns = s.self_ns.saturating_add(dur.saturating_sub(child_ns[i]));
            s.units = s.units.saturating_add(e.count);
            durations.entry(path).or_default().push(dur);
        }
        for (path, ds) in &mut durations {
            ds.sort_unstable();
            let s = paths.get_mut(path).expect("path recorded");
            s.p50_ns = percentile(ds, 0.50);
            s.p99_ns = percentile(ds, 0.99);
        }
        Profile { paths, span_count: events.len() }
    }

    /// True when no spans were folded.
    pub fn is_empty(&self) -> bool {
        self.paths.is_empty()
    }

    /// Stats for one exact stage path (`"request;execute"`).
    pub fn get(&self, path: &str) -> Option<&PathStats> {
        self.paths.get(path)
    }

    /// All `(path, stats)` rows in lexicographic path order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &PathStats)> {
        self.paths.iter().map(|(p, s)| (p.as_str(), s))
    }

    /// Summed self time across every path — the profile's total weight,
    /// equal to the summed duration of root spans (no double counting).
    pub fn total_self_ns(&self) -> u64 {
        self.paths.values().map(|s| s.self_ns).sum()
    }

    /// The attribution table: one row per stage path, heaviest self
    /// time first, printed under `serve-bench` / `store get` footers.
    pub fn render(&self) -> String {
        let total = self.total_self_ns().max(1) as f64;
        let mut rows: Vec<(&str, &PathStats)> = self.iter().collect();
        rows.sort_by(|a, b| b.1.self_ns.cmp(&a.1.self_ns).then(a.0.cmp(b.0)));
        let body: Vec<Vec<String>> = rows
            .iter()
            .map(|(path, s)| {
                vec![
                    path.to_string(),
                    s.count.to_string(),
                    format!("{:.3}", s.total_ns as f64 / 1e6),
                    format!("{:.3}", s.self_ns as f64 / 1e6),
                    format!("{:.1}", 100.0 * s.self_ns as f64 / total),
                    format!("{:.1}", s.p50_ns as f64 / 1e3),
                    format!("{:.1}", s.p99_ns as f64 / 1e3),
                ]
            })
            .collect();
        crate::eval::render_table(
            "stage attribution (self time)",
            &["stage path", "count", "total ms", "self ms", "self %", "p50 us", "p99 us"],
            &body,
        )
    }

    /// Collapsed-stack text (`path self_nanos` per line, `;`-separated
    /// frames) — the input format of every flamegraph renderer.
    pub fn collapsed_stack(&self) -> String {
        let mut out = String::new();
        for (path, s) in self.iter() {
            if s.self_ns > 0 {
                out.push_str(&format!("{path} {}\n", s.self_ns));
            }
        }
        out
    }

    /// Write [`Self::collapsed_stack`] to `path` (`--profile-out`).
    pub fn write_collapsed(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.collapsed_stack())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Stage;

    fn ev(id: u64, parent: u64, stage: Stage, start_ns: u64, end_ns: u64) -> SpanEvent {
        SpanEvent { id, parent, stage, start_ns, end_ns, tid: 1, count: 0, tag: "" }
    }

    /// Hand-built forest with known self/total nanos:
    ///
    /// ```text
    /// request [0..100]
    /// ├── queue_wait [0..30]
    /// └── execute [30..90]
    ///     └── decode [40..80]
    /// ```
    fn forest() -> Vec<SpanEvent> {
        vec![
            ev(1, 0, Stage::Request, 0, 100),
            ev(2, 1, Stage::QueueWait, 0, 30),
            ev(3, 1, Stage::Execute, 30, 90),
            ev(4, 3, Stage::Decode, 40, 80),
        ]
    }

    #[test]
    fn folding_is_exact_on_hand_built_forest() {
        let p = Profile::from_events(&forest());
        assert_eq!(p.span_count, 4);
        let req = p.get("request").unwrap();
        assert_eq!((req.count, req.total_ns, req.self_ns), (1, 100, 10));
        assert_eq!((req.p50_ns, req.p99_ns), (100, 100));
        let qw = p.get("request;queue_wait").unwrap();
        assert_eq!((qw.total_ns, qw.self_ns), (30, 30));
        let ex = p.get("request;execute").unwrap();
        assert_eq!((ex.total_ns, ex.self_ns), (60, 20));
        let de = p.get("request;execute;decode").unwrap();
        assert_eq!((de.total_ns, de.self_ns), (40, 40));
        // Self times partition the root's wall clock exactly.
        assert_eq!(p.total_self_ns(), 100);
    }

    #[test]
    fn orphans_root_their_own_path_and_parallel_children_saturate() {
        let events = vec![
            // Parent whose two children overlap in time (threaded lanes):
            // children sum to 120 > parent's 100 — self saturates at 0.
            ev(1, 0, Stage::DecodeLanes, 0, 100),
            ev(2, 1, Stage::Decode, 0, 60),
            ev(3, 1, Stage::Decode, 0, 60),
            // Orphan: parent id never drained — becomes its own root.
            ev(4, 999, Stage::ChunkIo, 0, 7),
        ];
        let p = Profile::from_events(&events);
        assert_eq!(p.get("decode_lanes").unwrap().self_ns, 0);
        let lanes = p.get("decode_lanes;decode").unwrap();
        assert_eq!((lanes.count, lanes.total_ns), (2, 120));
        assert_eq!(p.get("chunk_io").unwrap().total_ns, 7);
    }

    #[test]
    fn tagged_spans_fold_into_suffixed_frames() {
        let mut fan = ev(1, 0, Stage::DecodeLanes, 0, 100);
        fan.tag = "avx2";
        let events = vec![fan, ev(2, 1, Stage::Decode, 0, 80)];
        let p = Profile::from_events(&events);
        assert_eq!(p.get("decode_lanes[avx2]").unwrap().self_ns, 20);
        assert_eq!(p.get("decode_lanes[avx2];decode").unwrap().total_ns, 80);
        assert!(p.get("decode_lanes").is_none(), "tagged frame must not alias untagged");
    }

    #[test]
    fn collapsed_stack_and_table_render() {
        let p = Profile::from_events(&forest());
        let stacks = p.collapsed_stack();
        assert!(stacks.contains("request;execute;decode 40\n"));
        assert!(stacks.contains("request 10\n"));
        let table = p.render();
        assert!(table.contains("stage path"));
        assert!(table.contains("request;execute;decode"));
    }

    #[test]
    fn percentiles_use_nearest_rank() {
        let events: Vec<SpanEvent> =
            (0..100).map(|i| ev(i + 1, 0, Stage::Decode, 0, (i + 1) * 10)).collect();
        let p = Profile::from_events(&events);
        let d = p.get("decode").unwrap();
        assert_eq!(d.p50_ns, 510); // round(99 * 0.5) = rank 50 → 51st sample
        assert_eq!(d.p99_ns, 990);
    }
}
