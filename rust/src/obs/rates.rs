//! Rate derivation helpers — the one place `values/s`, `MB/s` and `GB/s`
//! are computed from a count and an elapsed-nanoseconds counter
//! (deduplicated out of `ReadStats`, `PackStats` and the bench JSON
//! emitters; ISSUE 6).
//!
//! All helpers guard the zero-duration case the same way (clamp elapsed
//! to 1e-12 s), so a not-yet-timed stat renders as a huge-but-finite
//! rate instead of `inf`/`NaN`. Callers that want "0 until timed"
//! semantics (e.g. `ReadStats::decode_mb_per_s`) check `nanos == 0`
//! themselves first.

/// `count` per second over `nanos` elapsed nanoseconds.
pub fn per_sec(count: f64, nanos: u64) -> f64 {
    count / (nanos as f64 / 1e9).max(1e-12)
}

/// Megabytes (1e6 bytes) per second.
pub fn mb_per_s(bytes: f64, nanos: u64) -> f64 {
    per_sec(bytes, nanos) / 1e6
}

/// Gigabytes (1e9 bytes) per second.
pub fn gb_per_s(bytes: f64, nanos: u64) -> f64 {
    per_sec(bytes, nanos) / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_are_consistent() {
        let one_sec = 1_000_000_000u64;
        assert!((per_sec(100.0, one_sec) - 100.0).abs() < 1e-9);
        assert!((mb_per_s(2_000_000.0, one_sec) - 2.0).abs() < 1e-9);
        assert!((gb_per_s(3_000_000_000.0, one_sec) - 3.0).abs() < 1e-9);
        // Half the time, double the rate.
        assert!((per_sec(100.0, one_sec / 2) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn zero_duration_is_finite() {
        assert!(per_sec(1.0, 0).is_finite());
        assert!(mb_per_s(1.0, 0).is_finite());
        assert!(gb_per_s(1.0, 0).is_finite());
    }
}
