//! Structured span tracer: per-thread event buffers with RAII guards,
//! near-zero cost when disabled (ISSUE 6; DESIGN.md §10).
//!
//! # Design
//!
//! - **Disabled path**: every entry point ([`span`], [`span_n`],
//!   [`span_under`], [`ManualSpan::begin`], [`record`]) does exactly one
//!   relaxed [`AtomicBool`] load and returns a no-op guard. No
//!   allocation, no lock, no clock read. Hot-path call sites (one span
//!   per *block* decode/encode, never per value) keep the enabled-mode
//!   overhead under the 3% CI budget too.
//! - **Enabled path**: each thread lazily registers one [`Ring`] — a
//!   bounded `Vec<SpanEvent>` behind a `Mutex` only that thread pushes
//!   to, so the lock is uncontended on the record path (a drain takes it
//!   briefly from the collecting thread). Events past the per-thread cap
//!   are dropped and counted ([`dropped`]), never reallocated without
//!   bound.
//! - **Span identity**: ids come from one global counter (0 = "no
//!   parent"/root). Intra-thread nesting is implicit via a thread-local
//!   parent stack; cross-thread spans (a serving request that is
//!   admitted on the client thread and executed on a worker) use
//!   [`ManualSpan`], which is `Send` and carries its id explicitly so
//!   children on other threads can attach via [`span_under`].
//! - **Timestamps**: nanoseconds since a process-wide epoch pinned at
//!   first use, so events from all threads share one axis (what the
//!   Chrome trace exporter needs). `Instant::duration_since` saturates,
//!   so an `Instant` captured before the epoch (e.g. a queue-entry time
//!   from before `enable()`) clamps to 0 instead of panicking.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Per-thread event cap: past this, new events are dropped (and counted)
/// rather than growing the buffer without bound. 64K events × 72 B ≈
/// 4.5 MiB per recording thread, worst case.
const MAX_EVENTS_PER_THREAD: usize = 1 << 16;

/// Pipeline stage a span measures — the full request path (serving admit
/// → queue wait → single-flight → chunk IO → arithmetic decode →
/// copy-out) and the full ingest path (synth → histogram → tablegen →
/// encode → append → seal), plus the coordinator's batch entry points.
/// DESIGN.md §10 is the taxonomy reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Whole serving request: submit → response filled (cross-thread).
    Request,
    /// Admission control on the submitting thread (queue-bound check).
    Admit,
    /// Time spent queued between admit and a worker picking the request
    /// up (recorded at pop via [`record`]; spans two threads).
    QueueWait,
    /// Worker-side execution of one request (decode + assembly).
    Execute,
    /// Single-flight resolution of one `(tensor, chunk)` — the leader's
    /// decode or a follower's wait on the leader.
    SingleFlight,
    /// Compressed-chunk read (mmap slice or pread) + CRC check.
    ChunkIo,
    /// Arithmetic block decode (`ApackDecoder::decode_into`).
    Decode,
    /// Lane fan-out of one chunk-body-v2 decode: `count` carries the lane
    /// count, so Chrome traces show how wide each chunk decoded
    /// (`BodyV2View::decode_into[_threaded]`, DESIGN.md §11).
    DecodeLanes,
    /// Assembling decoded chunks into the caller's contiguous range.
    CopyOut,
    /// Background hot-set prefetch sweep.
    Prefetch,
    /// Ingest: synthetic trace generation for one model.
    Synth,
    /// Ingest: value histogram construction.
    Histogram,
    /// Ingest: Listing-1 symbol-table search.
    TableGen,
    /// Arithmetic block encode (`ApackEncoder::encode_into`).
    Encode,
    /// Ingest: blob + metadata append into the store file.
    Append,
    /// Ingest: footer/trailer write and flush (`StoreWriter::finish`).
    Seal,
    /// Coordinator batch compress (all substreams of one tensor).
    Compress,
    /// Coordinator batch decompress (all substreams of one tensor).
    Decompress,
}

impl Stage {
    pub const ALL: [Stage; 18] = [
        Stage::Request,
        Stage::Admit,
        Stage::QueueWait,
        Stage::Execute,
        Stage::SingleFlight,
        Stage::ChunkIo,
        Stage::Decode,
        Stage::DecodeLanes,
        Stage::CopyOut,
        Stage::Prefetch,
        Stage::Synth,
        Stage::Histogram,
        Stage::TableGen,
        Stage::Encode,
        Stage::Append,
        Stage::Seal,
        Stage::Compress,
        Stage::Decompress,
    ];

    /// Stable name used by the exporters and DESIGN.md §10.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Request => "request",
            Stage::Admit => "admit",
            Stage::QueueWait => "queue_wait",
            Stage::Execute => "execute",
            Stage::SingleFlight => "single_flight",
            Stage::ChunkIo => "chunk_io",
            Stage::Decode => "decode",
            Stage::DecodeLanes => "decode_lanes",
            Stage::CopyOut => "copy_out",
            Stage::Prefetch => "prefetch",
            Stage::Synth => "synth",
            Stage::Histogram => "histogram",
            Stage::TableGen => "tablegen",
            Stage::Encode => "encode",
            Stage::Append => "append",
            Stage::Seal => "seal",
            Stage::Compress => "compress",
            Stage::Decompress => "decompress",
        }
    }
}

/// One completed span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Unique nonzero span id.
    pub id: u64,
    /// Parent span id; 0 = root.
    pub parent: u64,
    pub stage: Stage,
    /// Nanoseconds since the process trace epoch.
    pub start_ns: u64,
    /// Nanoseconds since the process trace epoch; `>= start_ns`.
    pub end_ns: u64,
    /// Recording thread (dense tracer-assigned index, not the OS tid).
    pub tid: u64,
    /// Stage-specific payload size: values decoded/encoded, bytes read
    /// or written, chunks prefetched. 0 when not meaningful.
    pub count: u64,
    /// Free-form static attribution tag (`""` = untagged). Used by the
    /// lane decode path to carry the active kernel label
    /// (`scalar`/`sse2`/`avx2`/`neon`), so profiles and traces attribute
    /// `decode_lanes` time to the loop that actually ran.
    pub tag: &'static str,
}

impl SpanEvent {
    pub fn duration_ns(&self) -> u64 {
        self.end_ns.saturating_sub(self.start_ns)
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static RINGS: Mutex<Vec<Arc<Ring>>> = Mutex::new(Vec::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();

/// One thread's event buffer. Only the owning thread pushes; `drain` /
/// `clear` lock it briefly from the collecting thread.
struct Ring {
    tid: u64,
    events: Mutex<Vec<SpanEvent>>,
}

struct Local {
    ring: Option<Arc<Ring>>,
    /// Open intra-thread span ids, innermost last.
    stack: Vec<u64>,
}

thread_local! {
    static LOCAL: RefCell<Local> = RefCell::new(Local { ring: None, stack: Vec::new() });
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn ns_since_epoch(t: Instant) -> u64 {
    // Saturates to 0 for instants captured before the epoch.
    u64::try_from(t.duration_since(epoch()).as_nanos()).unwrap_or(u64::MAX)
}

fn next_id() -> u64 {
    NEXT_ID.fetch_add(1, Ordering::Relaxed)
}

/// Push a finished event into the calling thread's ring (registering the
/// ring on first use). `ev.tid` is overwritten with the ring's id.
fn emit(mut ev: SpanEvent) {
    LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let ring = l.ring.get_or_insert_with(|| {
            let ring = Arc::new(Ring {
                tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
                events: Mutex::new(Vec::new()),
            });
            RINGS.lock().unwrap().push(Arc::clone(&ring));
            ring
        });
        ev.tid = ring.tid;
        let mut events = ring.events.lock().unwrap();
        if events.len() >= MAX_EVENTS_PER_THREAD {
            DROPPED.fetch_add(1, Ordering::Relaxed);
        } else {
            events.push(ev);
        }
    });
}

/// Is tracing on? (One relaxed load — the entire disabled-path cost.)
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on (pins the trace epoch on first call).
pub fn enable() {
    let _ = epoch();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn tracing off. In-flight guards created while enabled still record
/// on drop; new call sites go back to the one-load no-op path.
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Events dropped because a thread's buffer was full.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// Discard all buffered events and the drop counter.
pub fn clear() {
    for ring in RINGS.lock().unwrap().iter() {
        ring.events.lock().unwrap().clear();
    }
    DROPPED.store(0, Ordering::Relaxed);
}

/// Collect (and remove) every buffered event from every thread, sorted
/// by start time. Threads keep recording into their (now empty) rings.
pub fn drain() -> Vec<SpanEvent> {
    let mut out = Vec::new();
    for ring in RINGS.lock().unwrap().iter() {
        out.append(&mut ring.events.lock().unwrap());
    }
    out.sort_by_key(|e| (e.start_ns, e.id));
    out
}

/// An in-flight span on one open guard (intra-thread).
struct ActiveSpan {
    id: u64,
    parent: u64,
    stage: Stage,
    start: Instant,
    count: u64,
    tag: &'static str,
}

/// RAII span: records a [`SpanEvent`] on drop. `None` inside = tracing
/// was disabled at creation and the whole guard is a no-op.
pub struct SpanGuard(Option<ActiveSpan>);

impl SpanGuard {
    /// This span's id (0 when tracing is disabled) — pass to
    /// [`span_under`] on another thread to attach children.
    pub fn id(&self) -> u64 {
        self.0.as_ref().map(|s| s.id).unwrap_or(0)
    }

    /// Set the payload count after the fact (e.g. bytes actually read).
    pub fn set_count(&mut self, count: u64) {
        if let Some(s) = &mut self.0 {
            s.count = count;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(s) = self.0.take() {
            let end = Instant::now();
            LOCAL.with(|l| {
                let mut l = l.borrow_mut();
                if let Some(pos) = l.stack.iter().rposition(|&id| id == s.id) {
                    l.stack.remove(pos);
                }
            });
            emit(SpanEvent {
                id: s.id,
                parent: s.parent,
                stage: s.stage,
                start_ns: ns_since_epoch(s.start),
                end_ns: ns_since_epoch(end),
                tid: 0,
                count: s.count,
                tag: s.tag,
            });
        }
    }
}

/// Open a span nested under the thread's current innermost span.
#[inline]
pub fn span(stage: Stage) -> SpanGuard {
    span_n(stage, 0)
}

/// [`span`] with a payload count known up front.
#[inline]
pub fn span_n(stage: Stage, count: u64) -> SpanGuard {
    span_n_tagged(stage, count, "")
}

/// [`span_n`] with an attribution tag (see [`SpanEvent::tag`]).
#[inline]
pub fn span_n_tagged(stage: Stage, count: u64, tag: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    let id = next_id();
    let parent = LOCAL.with(|l| {
        let mut l = l.borrow_mut();
        let parent = l.stack.last().copied().unwrap_or(0);
        l.stack.push(id);
        parent
    });
    SpanGuard(Some(ActiveSpan { id, parent, stage, start: Instant::now(), count, tag }))
}

/// Open a span under an **explicit** parent id (from a [`ManualSpan`] on
/// another thread, or 0 for a root). The span still joins this thread's
/// stack so intra-thread children nest under it.
pub fn span_under(stage: Stage, parent: u64, count: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard(None);
    }
    let id = next_id();
    LOCAL.with(|l| l.borrow_mut().stack.push(id));
    SpanGuard(Some(ActiveSpan { id, parent, stage, start: Instant::now(), count, tag: "" }))
}

/// A cross-thread span: begun on one thread, finished on another (e.g. a
/// serving request admitted on the client thread and answered by a
/// worker). `Send`, carries its id explicitly, and does **not** join any
/// thread's parent stack — attach children with [`span_under`].
#[derive(Debug)]
pub struct ManualSpan {
    id: u64,
    parent: u64,
    stage: Stage,
    start: Instant,
    tag: &'static str,
}

impl ManualSpan {
    /// `None` when tracing is disabled (one relaxed load).
    pub fn begin(stage: Stage) -> Option<ManualSpan> {
        Self::begin_tagged(stage, "")
    }

    /// [`Self::begin`] with an attribution tag (see [`SpanEvent::tag`]).
    pub fn begin_tagged(stage: Stage, tag: &'static str) -> Option<ManualSpan> {
        if !enabled() {
            return None;
        }
        let parent = LOCAL.with(|l| l.borrow().stack.last().copied().unwrap_or(0));
        Some(ManualSpan { id: next_id(), parent, stage, start: Instant::now(), tag })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Record the span, ending now, into the **finishing** thread's ring.
    pub fn finish(self) {
        self.finish_with(0)
    }

    /// [`finish`] with a payload count.
    pub fn finish_with(self, count: u64) {
        let end = Instant::now();
        emit(SpanEvent {
            id: self.id,
            parent: self.parent,
            stage: self.stage,
            start_ns: ns_since_epoch(self.start),
            end_ns: ns_since_epoch(end),
            tid: 0,
            count,
            tag: self.tag,
        });
    }
}

/// Record a span from two already-captured instants (e.g. queue wait:
/// `enqueued → popped`, where the start predates the worker seeing the
/// item). An `Instant` captured before the trace epoch clamps to 0.
pub fn record(stage: Stage, parent: u64, start: Instant, end: Instant, count: u64) {
    if !enabled() {
        return;
    }
    emit(SpanEvent {
        id: next_id(),
        parent,
        stage,
        start_ns: ns_since_epoch(start),
        end_ns: ns_since_epoch(end),
        tid: 0,
        count,
        tag: "",
    });
}

/// Run `f` with `parent` installed as this thread's innermost span id:
/// any spans `f` opens via [`span`] / [`span_n`] (and their children)
/// attach under `parent` instead of rooting at 0. This is the seam that
/// lets a worker thread parent its spans under a fan-out span held by
/// the dispatching thread (the v2 threaded lane decode, DESIGN.md §11),
/// without opening a redundant wrapper span on the worker.
pub fn with_parent<T>(parent: u64, f: impl FnOnce() -> T) -> T {
    if !enabled() || parent == 0 {
        return f();
    }
    struct PopOnDrop(u64);
    impl Drop for PopOnDrop {
        fn drop(&mut self) {
            LOCAL.with(|l| {
                let mut l = l.borrow_mut();
                if let Some(pos) = l.stack.iter().rposition(|&id| id == self.0) {
                    l.stack.remove(pos);
                }
            });
        }
    }
    LOCAL.with(|l| l.borrow_mut().stack.push(parent));
    let _pop = PopOnDrop(parent);
    f()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The tracer is process-global: tests that flip it must not overlap.
    // (Integration-level invariants live in rust/tests/obs.rs behind the
    // same discipline; these unit tests cover the guard mechanics.)
    static TRACER: Mutex<()> = Mutex::new(());

    #[test]
    fn disabled_guards_are_free_and_silent() {
        let _g = TRACER.lock().unwrap();
        disable();
        let before = NEXT_ID.load(Ordering::Relaxed);
        {
            let s = span(Stage::Decode);
            assert_eq!(s.id(), 0);
            assert!(ManualSpan::begin(Stage::Request).is_none());
            record(Stage::QueueWait, 0, Instant::now(), Instant::now(), 0);
        }
        // No ids were allocated: the disabled path never got past the
        // one relaxed load.
        assert_eq!(NEXT_ID.load(Ordering::Relaxed), before);
    }

    #[test]
    fn nesting_and_cross_thread_parents() {
        let _g = TRACER.lock().unwrap();
        enable();
        let req = ManualSpan::begin(Stage::Request).expect("enabled");
        let req_id = req.id();
        let exec_id;
        let dec_id;
        {
            let outer = span_under(Stage::Execute, req_id, 0);
            exec_id = outer.id();
            assert_ne!(exec_id, 0);
            let mut inner = span(Stage::Decode);
            dec_id = inner.id();
            inner.set_count(42);
        }
        req.finish_with(1);
        disable();
        // Other lib tests may have recorded spans of their own while
        // tracing was on — select ours by id, don't count.
        let events = drain();
        let by_id = |id: u64| events.iter().find(|e| e.id == id).copied().unwrap();
        let (reqe, exec, dec) = (by_id(req_id), by_id(exec_id), by_id(dec_id));
        assert_eq!(exec.parent, req_id);
        assert_eq!(exec.stage, Stage::Execute);
        assert_eq!(dec.parent, exec_id, "inner span nests under the open guard");
        assert_eq!(dec.count, 42);
        assert_eq!(reqe.stage, Stage::Request);
        assert_eq!(reqe.count, 1);
        for e in [reqe, exec, dec] {
            assert!(e.end_ns >= e.start_ns);
            assert_ne!(e.tid, 0);
        }
    }
}
