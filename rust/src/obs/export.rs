//! Telemetry exporters (ISSUE 6; DESIGN.md §10):
//!
//! - [`chrome_trace`] / [`write_chrome_trace`] — Chrome trace-event JSON
//!   (complete `"ph": "X"` events, µs timestamps), loadable in
//!   `chrome://tracing` and Perfetto. Wired to `--trace out.json` on
//!   `store pack|get` and `serve-bench`.
//! - [`prometheus_text`] — Prometheus exposition-format text dump of a
//!   [`RegistrySnapshot`] (counters, gauges, histograms as summaries).
//! - [`SnapshotStream`] — background thread appending one JSON line per
//!   interval to a file (long-run monitoring; `--snapshot-jsonl`).
//! - [`request_coverage`] — the acceptance metric: median fraction of
//!   each `Request` span's wall clock covered by its direct children.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::Write as _;
use std::path::Path;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::util::json::Json;

use super::hist::LatencySnapshot;
use super::registry::RegistrySnapshot;
use super::trace::{SpanEvent, Stage};

/// Serialize span events as a Chrome trace-event JSON document
/// (`{"traceEvents": [...]}`; one complete `"X"` event per span, `ts` /
/// `dur` in microseconds, span id / parent id / payload count in `args`).
pub fn chrome_trace(events: &[SpanEvent]) -> Json {
    let trace_events: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut args = BTreeMap::new();
            args.insert("id".to_string(), Json::Num(e.id as f64));
            args.insert("parent".to_string(), Json::Num(e.parent as f64));
            args.insert("count".to_string(), Json::Num(e.count as f64));
            if !e.tag.is_empty() {
                // Attribution tag (e.g. the decode kernel label) rides in
                // `args` so event names stay stable for tooling that
                // matches on stage names.
                args.insert("tag".to_string(), Json::Str(e.tag.to_string()));
            }
            let mut m = BTreeMap::new();
            m.insert("name".to_string(), Json::Str(e.stage.name().to_string()));
            m.insert("ph".to_string(), Json::Str("X".to_string()));
            m.insert("ts".to_string(), Json::Num(e.start_ns as f64 / 1e3));
            m.insert("dur".to_string(), Json::Num(e.duration_ns() as f64 / 1e3));
            m.insert("pid".to_string(), Json::Num(1.0));
            m.insert("tid".to_string(), Json::Num(e.tid as f64));
            m.insert("args".to_string(), Json::Obj(args));
            Json::Obj(m)
        })
        .collect();
    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(trace_events));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Write [`chrome_trace`] JSON to `path`.
pub fn write_chrome_trace(path: &Path, events: &[SpanEvent]) -> crate::Result<()> {
    std::fs::write(path, chrome_trace(events).to_string() + "\n")?;
    Ok(())
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]` (and must not start
/// with a digit); our dotted registry names (`store.cache_hits`) map
/// dots (and anything else) to `_`. Public because the store heatmap
/// exposition (`store::heat`) builds labelled series from tensor names.
pub fn prom_metric_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    if out.chars().next().is_none_or(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

/// Escape one Prometheus label **value** per the exposition format:
/// `\` → `\\`, `"` → `\"`, newline → `\n`. Other control characters are
/// not escapable in the format at all, so they sanitize to `_` — a
/// hostile tensor name (`foo{bar="baz\n"}`) must never break the dump
/// into unparseable lines.
pub fn prom_label_value(value: &str) -> String {
    let mut out = String::with_capacity(value.len());
    for c in value.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c if c.is_control() => out.push('_'),
            c => out.push(c),
        }
    }
    out
}

/// Registry keys may carry an inline label set (`store.decode_kernel
/// {kernel="avx2"}` — written without the space): sanitize only the
/// metric-name part, keep the `{...}` label block verbatim (label values
/// are escaped by whoever built the key, via [`prom_label_value`]).
/// Returns `(bare_name, full_series_name)` — `# TYPE` lines take the
/// bare name, sample lines the full series.
fn prom_series(name: &str) -> (String, String) {
    match name.split_once('{') {
        Some((base, labels)) => {
            let bare = prom_metric_name(base);
            let series = format!("{bare}{{{labels}");
            (bare, series)
        }
        None => {
            let bare = prom_metric_name(name);
            (bare.clone(), bare)
        }
    }
}

/// Prometheus exposition-format text dump of a registry snapshot.
/// Histograms are exported as summaries (p50/p95/p99 quantiles in
/// seconds plus `_sum`/`_count`), matching how latency histograms are
/// conventionally scraped.
pub fn prometheus_text(snap: &RegistrySnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let (bare, series) = prom_series(name);
        out.push_str(&format!("# TYPE {bare} counter\n{series} {v}\n"));
    }
    for (name, v) in &snap.gauges {
        let (bare, series) = prom_series(name);
        out.push_str(&format!("# TYPE {bare} gauge\n{series} {v}\n"));
    }
    for (name, h) in &snap.hists {
        let (n, _) = prom_series(name);
        out.push_str(&format!("# TYPE {n} summary\n"));
        for (q, d) in [(0.5, h.p50), (0.95, h.p95), (0.99, h.p99)] {
            out.push_str(&format!("{n}{{quantile=\"{q}\"}} {}\n", d.as_secs_f64()));
        }
        let sum_s = h.mean.as_secs_f64() * h.count as f64;
        out.push_str(&format!("{n}_sum {sum_s}\n{n}_count {}\n", h.count));
    }
    out
}

fn hist_json(h: &LatencySnapshot) -> Json {
    let mut m = BTreeMap::new();
    m.insert("count".to_string(), Json::Num(h.count as f64));
    m.insert("mean_ns".to_string(), Json::Num(h.mean.as_nanos() as f64));
    m.insert("p50_ns".to_string(), Json::Num(h.p50.as_nanos() as f64));
    m.insert("p95_ns".to_string(), Json::Num(h.p95.as_nanos() as f64));
    m.insert("p99_ns".to_string(), Json::Num(h.p99.as_nanos() as f64));
    m.insert("max_ns".to_string(), Json::Num(h.max.as_nanos() as f64));
    Json::Obj(m)
}

/// One JSONL snapshot line (compact JSON, no trailing newline).
pub fn jsonl_line(seq: u64, snap: &RegistrySnapshot) -> String {
    let nums =
        |m: &BTreeMap<String, u64>| m.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64)));
    let mut root = BTreeMap::new();
    root.insert("seq".to_string(), Json::Num(seq as f64));
    root.insert("counters".to_string(), Json::Obj(nums(&snap.counters).collect()));
    root.insert("gauges".to_string(), Json::Obj(nums(&snap.gauges).collect()));
    root.insert(
        "hists".to_string(),
        Json::Obj(snap.hists.iter().map(|(k, h)| (k.clone(), hist_json(h))).collect()),
    );
    Json::Obj(root).to_string()
}

/// Background thread that appends one [`jsonl_line`] per `interval` to a
/// file, plus a final line at shutdown. Stops (and writes the last line)
/// on drop.
pub struct SnapshotStream {
    stop: Arc<(Mutex<bool>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl SnapshotStream {
    /// Start streaming `source()` snapshots to `path` (truncates any
    /// existing file).
    pub fn start<F>(path: &Path, interval: Duration, source: F) -> crate::Result<SnapshotStream>
    where
        F: Fn() -> RegistrySnapshot + Send + 'static,
    {
        let mut file = File::create(path)?;
        let stop = Arc::new((Mutex::new(false), Condvar::new()));
        let thread_stop = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("apack-obs-jsonl".to_string())
            .spawn(move || {
                let (lock, cv) = &*thread_stop;
                let mut seq = 0u64;
                let mut stopped = lock.lock().unwrap();
                loop {
                    let done = *stopped;
                    // The stop mutex guards only the flag; `source` never
                    // touches it, so holding it across the write is safe.
                    let line = jsonl_line(seq, &source());
                    seq += 1;
                    let _ = writeln!(file, "{line}");
                    let _ = file.flush();
                    if done {
                        return;
                    }
                    stopped = cv.wait_timeout(stopped, interval).unwrap().0;
                }
            })
            .map_err(|e| crate::Error::Io(e.to_string()))?;
        Ok(SnapshotStream { stop, handle: Some(handle) })
    }
}

impl Drop for SnapshotStream {
    fn drop(&mut self) {
        let (lock, cv) = &*self.stop;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Median over `Request` spans of the fraction of each request's wall
/// clock covered by its **direct** children (admit + queue wait +
/// execute), clamped to 1.0 per request. `None` when the events hold no
/// request span with nonzero duration. The ISSUE-6 acceptance bar is
/// `>= 0.95` at the median for a `serve-bench --trace` run.
pub fn request_coverage(events: &[SpanEvent]) -> Option<f64> {
    let mut covered: BTreeMap<u64, u64> = events
        .iter()
        .filter(|e| e.stage == Stage::Request && e.duration_ns() > 0)
        .map(|e| (e.id, 0u64))
        .collect();
    for e in events {
        if e.stage != Stage::Request {
            if let Some(c) = covered.get_mut(&e.parent) {
                *c += e.duration_ns();
            }
        }
    }
    let mut fractions: Vec<f64> = events
        .iter()
        .filter(|e| e.stage == Stage::Request && e.duration_ns() > 0)
        .map(|e| (covered[&e.id] as f64 / e.duration_ns() as f64).min(1.0))
        .collect();
    if fractions.is_empty() {
        return None;
    }
    fractions.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Some(fractions[fractions.len() / 2])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64, parent: u64, stage: Stage, start_ns: u64, end_ns: u64) -> SpanEvent {
        SpanEvent { id, parent, stage, start_ns, end_ns, tid: 1, count: 0, tag: "" }
    }

    #[test]
    fn chrome_trace_round_trips_through_the_parser() {
        let events =
            [ev(1, 0, Stage::Request, 0, 4000), ev(2, 1, Stage::Execute, 1000, 3000)];
        let doc = chrome_trace(&events).to_string();
        let parsed = Json::parse(&doc).unwrap();
        let arr = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "request");
        assert_eq!(arr[1].get("dur").unwrap().as_f64().unwrap(), 2.0); // µs
        assert_eq!(
            arr[1].get("args").unwrap().get("parent").unwrap().as_usize().unwrap(),
            1
        );
    }

    #[test]
    fn hostile_names_stay_parseable() {
        // Metric names: everything outside [a-zA-Z0-9_:] sanitizes to
        // `_`, and a leading digit gets a `_` prefix.
        assert_eq!(prom_metric_name("foo{bar=\"baz\n\"}"), "foo_bar__baz___");
        assert_eq!(prom_metric_name("9lives"), "_9lives");
        // Label values: the three escapable characters escape, other
        // control characters sanitize — the output must be single-line
        // with balanced quoting.
        let v = prom_label_value("foo{bar=\"baz\n\"}\\tail\rend");
        assert_eq!(v, "foo{bar=\\\"baz\\n\\\"}\\\\tail_end");
        assert!(!v.contains('\n'));
        let line = format!("store_chunk_demand_hits{{tensor=\"{v}\"}} 3");
        assert_eq!(line.lines().count(), 1, "exposition line must not split");
    }

    #[test]
    fn prometheus_text_has_types_and_sanitized_names() {
        let mut snap = RegistrySnapshot::default();
        snap.counters.insert("store.cache_hits".to_string(), 12);
        snap.gauges.insert("serving.queue_depth".to_string(), 3);
        snap.hists.insert("serving.latency_ns".to_string(), LatencySnapshot::default());
        let text = prometheus_text(&snap);
        assert!(text.contains("# TYPE store_cache_hits counter"));
        assert!(text.contains("store_cache_hits 12"));
        assert!(text.contains("# TYPE serving_queue_depth gauge"));
        assert!(text.contains("# TYPE serving_latency_ns summary"));
        assert!(text.contains("serving_latency_ns_count 0"));
        assert!(!text.contains("store.cache_hits"), "dots must be sanitized");
    }

    #[test]
    fn labeled_gauge_keys_keep_their_label_block() {
        let mut snap = RegistrySnapshot::default();
        snap.gauges.insert("store.decode_kernel{kernel=\"avx2\"}".to_string(), 1);
        let text = prometheus_text(&snap);
        assert!(text.contains("# TYPE store_decode_kernel gauge"), "{text}");
        assert!(text.contains("store_decode_kernel{kernel=\"avx2\"} 1"), "{text}");
        assert!(!text.contains("store_decode_kernel_kernel"), "labels must not sanitize");
    }

    #[test]
    fn tagged_spans_carry_the_tag_in_chrome_args() {
        let mut tagged = ev(3, 0, Stage::DecodeLanes, 0, 500);
        tagged.tag = "avx2";
        let doc = chrome_trace(&[tagged, ev(4, 3, Stage::Decode, 0, 400)]).to_string();
        let parsed = Json::parse(&doc).unwrap();
        let arr = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // Name stays the bare stage (tooling matches on it); the tag
        // rides in args, and untagged events omit the key entirely.
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "decode_lanes");
        assert_eq!(arr[0].get("args").unwrap().get("tag").unwrap().as_str().unwrap(), "avx2");
        assert!(arr[1].get("args").unwrap().get("tag").is_none());
    }

    #[test]
    fn jsonl_line_is_one_parsable_object() {
        let mut snap = RegistrySnapshot::default();
        snap.counters.insert("a.b".to_string(), 5);
        let line = jsonl_line(7, &snap);
        assert!(!line.contains('\n'));
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.get("seq").unwrap().as_usize().unwrap(), 7);
        assert_eq!(j.get("counters").unwrap().get("a.b").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn coverage_is_median_of_clamped_fractions() {
        // Request 1: children cover 100%; request 5: children cover 50%;
        // request 8: no children (0%).
        let events = [
            ev(1, 0, Stage::Request, 0, 1000),
            ev(2, 1, Stage::QueueWait, 0, 600),
            ev(3, 1, Stage::Execute, 600, 1000),
            ev(4, 3, Stage::Decode, 600, 1000), // grandchild: not counted
            ev(5, 0, Stage::Request, 0, 1000),
            ev(6, 5, Stage::Execute, 0, 500),
            ev(8, 0, Stage::Request, 0, 1000),
        ];
        let cov = request_coverage(&events).unwrap();
        assert!((cov - 0.5).abs() < 1e-9, "median of [0, 0.5, 1.0] is 0.5, got {cov}");
        assert_eq!(request_coverage(&[]), None);
    }
}
