//! Concurrent log-linear latency histogram — the quantile substrate every
//! subsystem shares (generalized out of `serving/metrics.rs`, ISSUE 6).
//!
//! Every power-of-two octave of nanoseconds is split into 4 sub-buckets,
//! so quantile estimates carry at most ~25% relative error while `record`
//! stays one atomic increment (no lock on the worker hot path). Quantiles
//! are read as the **upper bound** of the bucket the target rank lands in,
//! i.e. conservatively.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// 4 sub-buckets per octave.
const SUB: usize = 4;
/// Bucket count: indices 0..4 are exact (0–3 ns), then 4 per octave up to
/// the u64 nanosecond range. 256 covers every index `bucket_index` emits.
const BUCKETS: usize = 256;

/// Concurrent log-linear latency histogram.
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
    max_nanos: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
            max_nanos: AtomicU64::new(0),
        }
    }

    /// Bucket index for a nanosecond value (log-linear, monotone).
    fn bucket_index(nanos: u64) -> usize {
        if nanos < SUB as u64 {
            return nanos as usize;
        }
        let msb = 63 - nanos.leading_zeros() as usize; // >= 2 here
        let sub = ((nanos >> (msb - 2)) & 0b11) as usize;
        ((msb - 1) * SUB + sub).min(BUCKETS - 1)
    }

    /// Inclusive upper bound (nanos) of bucket `i` — what quantiles report.
    fn bucket_bound(i: usize) -> u64 {
        if i < SUB {
            return i as u64;
        }
        let msb = i / SUB + 1;
        let sub = (i % SUB) as u64;
        (1u64 << msb) + (sub + 1) * (1u64 << (msb - 2)) - 1
    }

    /// Record one observation (an atomic increment; safe from any thread).
    pub fn record(&self, latency: Duration) {
        let nanos = u64::try_from(latency.as_nanos()).unwrap_or(u64::MAX);
        self.buckets[Self::bucket_index(nanos)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.max_nanos.fetch_max(nanos, Ordering::Relaxed);
    }

    /// Observations recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Zero every bucket and summary counter (`MetricsRegistry::reset`).
    /// Not atomic with respect to concurrent `record`s — reset between
    /// measurement windows, not during one.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_nanos.store(0, Ordering::Relaxed);
        self.max_nanos.store(0, Ordering::Relaxed);
    }

    /// Latency at quantile `q` in `[0, 1]` (upper bucket bound, clamped
    /// to the recorded maximum so `p99 <= max` always holds; ZERO when
    /// empty). Concurrent `record`s can skew an in-flight read by a few
    /// observations — snapshots are monitoring data, not a barrier.
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count.load(Ordering::Relaxed);
        if total == 0 {
            return Duration::ZERO;
        }
        let max = self.max_nanos.load(Ordering::Relaxed);
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return Duration::from_nanos(Self::bucket_bound(i).min(max));
            }
        }
        Duration::from_nanos(max)
    }

    /// One consistent-enough view of the distribution.
    pub fn snapshot(&self) -> LatencySnapshot {
        let count = self.count.load(Ordering::Relaxed);
        let mean = if count == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.sum_nanos.load(Ordering::Relaxed) / count)
        };
        LatencySnapshot {
            count,
            mean,
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: Duration::from_nanos(self.max_nanos.load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time latency distribution.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencySnapshot {
    pub count: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

impl LatencySnapshot {
    /// One-line rendering for bench/CLI output.
    pub fn render(&self) -> String {
        format!(
            "p50 {:?}  p95 {:?}  p99 {:?}  max {:?}  (mean {:?}, n={})",
            self.p50, self.p95, self.p99, self.max, self.mean, self.count
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_bound_covers() {
        // Strictly increasing sample latencies spanning the u64 range.
        let mut samples: Vec<u64> = (0..16).collect();
        for shift in 4..60u32 {
            for k in 0..4u64 {
                samples.push((1u64 << shift) + k * (1u64 << (shift - 2)));
            }
        }
        let mut prev = 0usize;
        for &n in &samples {
            let i = LatencyHistogram::bucket_index(n);
            assert!(i >= prev, "monotone at {n}: {i} < {prev}");
            prev = i;
            let bound = LatencyHistogram::bucket_bound(i);
            assert!(bound >= n, "bound {bound} must cover {n}");
            // Log-linear: the bound overshoots by at most ~25% + 1.
            assert!(bound <= n + n / 4 + 1, "bound {bound} too loose for {n}");
        }
    }

    #[test]
    fn quantiles_are_ordered_and_plausible() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        // p50 of uniform 1..=1000µs is ~500µs; allow the 25% bucket error.
        let p50 = s.p50.as_micros() as f64;
        assert!((450.0..=650.0).contains(&p50), "p50 {p50}µs");
        let p99 = s.p99.as_micros() as f64;
        assert!((950.0..=1300.0).contains(&p99), "p99 {p99}µs");
        assert_eq!(s.max, Duration::from_micros(1000));
        assert!(s.render().contains("p95"));
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = LatencyHistogram::new();
        std::thread::scope(|scope| {
            for t in 0..4 {
                let h = &h;
                scope.spawn(move || {
                    for i in 0..1000u64 {
                        h.record(Duration::from_nanos(t * 1000 + i));
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
    }

    #[test]
    fn reset_zeroes_everything() {
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(7));
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.snapshot(), LatencySnapshot::default());
    }
}
