//! **Tail-based exemplar sampling** — keep the span trees worth keeping
//! (DESIGN.md §12).
//!
//! Tracing every request is cheap to record but expensive to retain; a
//! production store wants a bounded set of *exemplars* — full span trees
//! for the requests that explain the tail. The [`ExemplarRing`] holds at
//! most `capacity` request trees and admits only the interesting ones:
//!
//! - any request that **errored** or was **shed** (queue-full or
//!   deadline-expired) is always interesting;
//! - an OK request is interesting only if its latency sits in the
//!   **slowest decile** of OK latencies observed so far (nearest-rank
//!   p90 over a bounded reservoir of recent latencies);
//! - when the ring is full, the least interesting resident (fastest OK
//!   first, then fastest non-OK) is evicted iff the newcomer outranks it.
//!
//! The serving engine records one [`RequestRecord`] per completed or
//! shed request while tracing is on; [`collect_exemplars`] joins those
//! records against a drained span forest (grouping spans under their
//! root `Request` span) and replays them through the ring. Retained
//! exemplars dump as Chrome trace JSON (`serve-bench --exemplars`), so
//! a "why was this request slow" trace survives without keeping the
//! whole run's telemetry.

use std::collections::HashMap;
use std::path::Path;

use super::export;
use super::trace::SpanEvent;
use crate::util::json::Json;

/// How one request left the serving engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOutcome {
    /// Served successfully.
    Ok,
    /// Failed with a store/codec error.
    Error,
    /// Shed at admission: queue already full.
    ShedQueueFull,
    /// Shed at pop: deadline expired before a worker picked it up.
    ShedDeadline,
}

impl RequestOutcome {
    /// Snake-case label for tables and JSON.
    pub fn name(self) -> &'static str {
        match self {
            RequestOutcome::Ok => "ok",
            RequestOutcome::Error => "error",
            RequestOutcome::ShedQueueFull => "shed_queue_full",
            RequestOutcome::ShedDeadline => "shed_deadline",
        }
    }

    /// Retention rank: non-OK outcomes always outrank OK ones.
    fn rank(self) -> u8 {
        match self {
            RequestOutcome::Ok => 0,
            _ => 1,
        }
    }
}

/// One per-request outcome record, fed by the serving engine.
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    /// The request's root `Request` span id (0 when tracing was off).
    pub span_id: u64,
    /// Submit-to-outcome latency.
    pub latency_ns: u64,
    /// How the request ended.
    pub outcome: RequestOutcome,
}

/// A retained request: its outcome plus the full span subtree.
#[derive(Debug, Clone)]
pub struct Exemplar {
    /// Root `Request` span id.
    pub span_id: u64,
    /// Submit-to-outcome latency.
    pub latency_ns: u64,
    /// How the request ended.
    pub outcome: RequestOutcome,
    /// Every drained span whose root ancestor is `span_id`.
    pub events: Vec<SpanEvent>,
}

/// Bounded reservoir of recent OK latencies backing the decile estimate.
const LATENCY_RESERVOIR: usize = 1024;

/// Bounded, tail-biased ring of request exemplars.
#[derive(Debug)]
pub struct ExemplarRing {
    capacity: usize,
    entries: Vec<Exemplar>,
    ok_latencies: Vec<u64>,
    reservoir_pos: usize,
    observed: u64,
    evicted: u64,
}

fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

impl ExemplarRing {
    /// A ring retaining at most `capacity` exemplars (min 1).
    pub fn new(capacity: usize) -> ExemplarRing {
        ExemplarRing {
            capacity: capacity.max(1),
            entries: Vec::new(),
            ok_latencies: Vec::new(),
            reservoir_pos: 0,
            observed: 0,
            evicted: 0,
        }
    }

    /// Current slowest-decile admission threshold for OK requests.
    pub fn decile_threshold_ns(&self) -> u64 {
        let mut sorted = self.ok_latencies.clone();
        sorted.sort_unstable();
        percentile(&sorted, 0.90)
    }

    /// Offer one completed request. Returns true when it was retained.
    pub fn observe(
        &mut self,
        span_id: u64,
        outcome: RequestOutcome,
        latency_ns: u64,
        events: Vec<SpanEvent>,
    ) -> bool {
        self.observed += 1;
        if outcome == RequestOutcome::Ok {
            if self.ok_latencies.len() < LATENCY_RESERVOIR {
                self.ok_latencies.push(latency_ns);
            } else {
                self.ok_latencies[self.reservoir_pos] = latency_ns;
                self.reservoir_pos = (self.reservoir_pos + 1) % LATENCY_RESERVOIR;
            }
            if latency_ns < self.decile_threshold_ns() {
                return false; // not in the slowest decile
            }
        }
        let exemplar = Exemplar { span_id, latency_ns, outcome, events };
        if self.entries.len() < self.capacity {
            self.entries.push(exemplar);
            return true;
        }
        // Full: evict the least interesting resident iff outranked.
        let key = |e: &Exemplar| (e.outcome.rank(), e.latency_ns);
        let victim = self
            .entries
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| key(e))
            .map(|(i, _)| i)
            .expect("ring non-empty at capacity");
        if key(&exemplar) > key(&self.entries[victim]) {
            self.entries[victim] = exemplar;
            self.evicted += 1;
            true
        } else {
            false
        }
    }

    /// Retained exemplars, slowest / most severe first.
    pub fn exemplars(&self) -> Vec<&Exemplar> {
        let mut out: Vec<&Exemplar> = self.entries.iter().collect();
        out.sort_by(|a, b| {
            (b.outcome.rank(), b.latency_ns).cmp(&(a.outcome.rank(), a.latency_ns))
        });
        out
    }

    /// Requests offered to the ring so far.
    pub fn observed(&self) -> u64 {
        self.observed
    }

    /// Residents displaced by more interesting newcomers.
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// One-line summary for bench footers.
    pub fn render(&self) -> String {
        let ex = self.exemplars();
        let bad = ex.iter().filter(|e| e.outcome != RequestOutcome::Ok).count();
        let slowest = ex.first().map(|e| e.latency_ns).unwrap_or(0);
        format!(
            "exemplars: retained {} of {} observed ({} errored/shed, slowest {:.3} ms, \
             decile >= {:.3} ms)",
            ex.len(),
            self.observed,
            bad,
            slowest as f64 / 1e6,
            self.decile_threshold_ns() as f64 / 1e6,
        )
    }

    /// All retained span trees as one Chrome trace document. Each
    /// exemplar's outcome and latency ride along in a metadata counter
    /// via the span `args`, so the trace stands alone.
    pub fn chrome_trace(&self) -> Json {
        let events: Vec<SpanEvent> =
            self.exemplars().iter().flat_map(|e| e.events.iter().copied()).collect();
        export::chrome_trace(&events)
    }

    /// Write [`Self::chrome_trace`] to `path` (`serve-bench --exemplars`).
    pub fn write_chrome_trace(&self, path: &Path) -> crate::Result<()> {
        std::fs::write(path, self.chrome_trace().to_string() + "\n")?;
        Ok(())
    }
}

/// Join engine outcome records against a drained span forest and replay
/// them through a fresh ring: each record's span subtree is every event
/// whose root ancestor is the record's `span_id`.
pub fn collect_exemplars(
    events: &[SpanEvent],
    records: &[RequestRecord],
    capacity: usize,
) -> ExemplarRing {
    let index: HashMap<u64, u64> = events.iter().map(|e| (e.id, e.parent)).collect();
    let root_of = |mut id: u64| -> u64 {
        for _ in 0..64 {
            match index.get(&id) {
                Some(&parent) if parent != 0 && index.contains_key(&parent) => id = parent,
                _ => break,
            }
        }
        id
    };
    let mut groups: HashMap<u64, Vec<SpanEvent>> = HashMap::new();
    for e in events {
        groups.entry(root_of(e.id)).or_default().push(*e);
    }
    let mut ring = ExemplarRing::new(capacity);
    for r in records {
        let tree = groups.get(&r.span_id).cloned().unwrap_or_default();
        ring.observe(r.span_id, r.outcome, r.latency_ns, tree);
    }
    ring
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::Stage;

    fn ev(id: u64, parent: u64, start_ns: u64, end_ns: u64) -> SpanEvent {
        SpanEvent { id, parent, stage: Stage::Request, start_ns, end_ns, tid: 1, count: 0, tag: "" }
    }

    #[test]
    fn slowest_kept_fast_evicted() {
        let mut ring = ExemplarRing::new(4);
        for lat in 1..=100u64 {
            ring.observe(lat, RequestOutcome::Ok, lat * 1000, Vec::new());
        }
        let kept: Vec<u64> = ring.exemplars().iter().map(|e| e.latency_ns).collect();
        assert_eq!(kept, vec![100_000, 99_000, 98_000, 97_000]);
        assert_eq!(ring.observed(), 100);
        assert!(ring.evicted() > 0);
    }

    #[test]
    fn errored_and_shed_always_outrank_ok() {
        let mut ring = ExemplarRing::new(2);
        for lat in 1..=50u64 {
            ring.observe(lat, RequestOutcome::Ok, lat * 1000, Vec::new());
        }
        // A fast errored request must displace a slow OK resident.
        assert!(ring.observe(900, RequestOutcome::Error, 10, Vec::new()));
        assert!(ring.observe(901, RequestOutcome::ShedDeadline, 5, Vec::new()));
        let outcomes: Vec<RequestOutcome> =
            ring.exemplars().iter().map(|e| e.outcome).collect();
        assert!(outcomes.iter().all(|o| *o != RequestOutcome::Ok));
    }

    #[test]
    fn fast_ok_requests_are_rejected_once_decile_is_known() {
        let mut ring = ExemplarRing::new(8);
        for lat in 1..=100u64 {
            ring.observe(lat, RequestOutcome::Ok, lat * 1_000_000, Vec::new());
        }
        // Decile threshold now ~90 ms; a 1 ms request is boring.
        assert!(!ring.observe(500, RequestOutcome::Ok, 1_000_000, Vec::new()));
    }

    #[test]
    fn collect_joins_subtrees_under_request_roots() {
        let events = vec![
            ev(1, 0, 0, 100),
            ev(2, 1, 10, 60),
            ev(3, 2, 20, 40),
            ev(10, 0, 0, 10),
        ];
        let records = vec![
            RequestRecord { span_id: 1, latency_ns: 100, outcome: RequestOutcome::Ok },
            RequestRecord { span_id: 10, latency_ns: 10, outcome: RequestOutcome::Error },
        ];
        let ring = collect_exemplars(&events, &records, 8);
        let ex = ring.exemplars();
        assert_eq!(ex.len(), 2);
        let slow = ex.iter().find(|e| e.span_id == 1).unwrap();
        assert_eq!(slow.events.len(), 3, "grandchild joins via root ancestor");
        let doc = ring.chrome_trace().to_string();
        let parsed = Json::parse(&doc).expect("chrome trace parses");
        assert!(parsed.get("traceEvents").is_some());
    }
}
