//! **SLO burn-rate tracking** — multi-window error-budget monitoring for
//! the serving engine (DESIGN.md §12).
//!
//! Two objectives, both fractions of requests over a rolling window:
//!
//! - **availability** — the fraction of requests that are served at all
//!   (not shed by admission control, not errored);
//! - **latency** — the fraction of *served* requests that finish under
//!   the target latency. Shed requests count against availability only,
//!   so one overload doesn't burn both budgets twice.
//!
//! Each objective keeps a **fast** and a **slow** rolling window (the
//! SRE multi-window pattern): the burn rate is the window's bad-request
//! ratio divided by the error budget (`1 - objective`), i.e. `1.0`
//! means the budget is being spent exactly as fast as it accrues. An
//! objective is **breaching** only when *both* windows burn above the
//! threshold — the fast window makes the alarm responsive, the slow
//! window keeps one blip from tripping it.
//!
//! Windows are bucketed rings ([`BUCKETS`] buckets per window) indexed
//! by absolute bucket number, so recording and querying are O(1) and
//! O(BUCKETS); time is injectable (`record_at` / `status_at`) so the
//! window arithmetic is testable against synthetic outcome streams.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use super::registry::RegistrySnapshot;
use super::sampler::RequestOutcome;

/// Buckets per rolling window: granularity is `window / BUCKETS`.
pub const BUCKETS: usize = 30;

/// SLO objectives and window shape for one serving engine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// A served request is "fast" when it finishes within this.
    pub latency_target: Duration,
    /// Fraction of served requests that must be fast (e.g. `0.99`).
    pub latency_objective: f64,
    /// Fraction of all requests that must be served (e.g. `0.999`).
    pub availability_objective: f64,
    /// Responsive window (SRE "fast"), e.g. 10 s.
    pub fast_window: Duration,
    /// Confirming window (SRE "slow"), e.g. 60 s.
    pub slow_window: Duration,
    /// Both windows must burn above this to breach (1.0 = budget spent
    /// exactly as fast as it accrues).
    pub burn_threshold: f64,
}

impl Default for SloConfig {
    fn default() -> SloConfig {
        SloConfig {
            latency_target: Duration::from_millis(50),
            latency_objective: 0.99,
            availability_objective: 0.99,
            fast_window: Duration::from_secs(10),
            slow_window: Duration::from_secs(60),
            burn_threshold: 1.0,
        }
    }
}

/// One rolling window: a ring of per-bucket (good, bad) counts indexed
/// by absolute bucket number, expired buckets zeroed on advance.
#[derive(Debug)]
struct BurnWindow {
    bucket_ns: u64,
    head: u64,
    good: [u64; BUCKETS],
    bad: [u64; BUCKETS],
}

impl BurnWindow {
    fn new(window: Duration) -> BurnWindow {
        BurnWindow {
            bucket_ns: (window.as_nanos() as u64 / BUCKETS as u64).max(1),
            head: 0,
            good: [0; BUCKETS],
            bad: [0; BUCKETS],
        }
    }

    fn advance(&mut self, abs: u64) {
        if abs <= self.head {
            return;
        }
        let steps = (abs - self.head).min(BUCKETS as u64);
        for i in 1..=steps {
            let slot = ((self.head + i) % BUCKETS as u64) as usize;
            self.good[slot] = 0;
            self.bad[slot] = 0;
        }
        self.head = abs;
    }

    fn record(&mut self, now_ns: u64, good: bool) {
        let abs = now_ns / self.bucket_ns;
        self.advance(abs);
        if abs < self.head.saturating_sub(BUCKETS as u64 - 1) {
            return; // older than the whole window (out-of-order record)
        }
        let slot = (abs % BUCKETS as u64) as usize;
        if good {
            self.good[slot] += 1;
        } else {
            self.bad[slot] += 1;
        }
    }

    fn bad_ratio_at(&mut self, now_ns: u64) -> f64 {
        self.advance(now_ns / self.bucket_ns);
        let good: u64 = self.good.iter().sum();
        let bad: u64 = self.bad.iter().sum();
        if good + bad == 0 {
            0.0
        } else {
            bad as f64 / (good + bad) as f64
        }
    }
}

#[derive(Debug)]
struct ObjectiveWindows {
    objective: f64,
    good: u64,
    total: u64,
    fast: BurnWindow,
    slow: BurnWindow,
}

impl ObjectiveWindows {
    fn new(objective: f64, cfg: &SloConfig) -> ObjectiveWindows {
        ObjectiveWindows {
            objective,
            good: 0,
            total: 0,
            fast: BurnWindow::new(cfg.fast_window),
            slow: BurnWindow::new(cfg.slow_window),
        }
    }

    fn record(&mut self, now_ns: u64, good: bool) {
        self.good += good as u64;
        self.total += 1;
        self.fast.record(now_ns, good);
        self.slow.record(now_ns, good);
    }

    fn status_at(&mut self, now_ns: u64, threshold: f64) -> ObjectiveStatus {
        let budget = (1.0 - self.objective).max(1e-9);
        let fast_burn = self.fast.bad_ratio_at(now_ns) / budget;
        let slow_burn = self.slow.bad_ratio_at(now_ns) / budget;
        ObjectiveStatus {
            objective: self.objective,
            good: self.good,
            total: self.total,
            fast_burn,
            slow_burn,
            breaching: fast_burn > threshold && slow_burn > threshold,
        }
    }
}

/// Point-in-time view of one objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ObjectiveStatus {
    /// The configured objective fraction.
    pub objective: f64,
    /// Lifetime good-request count.
    pub good: u64,
    /// Lifetime request count.
    pub total: u64,
    /// Fast-window bad ratio / error budget.
    pub fast_burn: f64,
    /// Slow-window bad ratio / error budget.
    pub slow_burn: f64,
    /// True when both windows burn above the threshold.
    pub breaching: bool,
}

/// Point-in-time view of both objectives — surfaced in
/// [`crate::serving::MetricsSnapshot`] and as Prometheus gauges.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloStatus {
    /// The latency target served requests are judged against.
    pub target_latency: Duration,
    /// The configured burn threshold.
    pub burn_threshold: f64,
    /// Latency objective status.
    pub latency: ObjectiveStatus,
    /// Availability objective status.
    pub availability: ObjectiveStatus,
}

impl SloStatus {
    /// True when either objective is breaching.
    pub fn breaching(&self) -> bool {
        self.latency.breaching || self.availability.breaching
    }

    /// Multi-line breach report for `serve-bench`.
    pub fn render(&self) -> String {
        let row = |name: &str, o: &ObjectiveStatus| {
            format!(
                "  {name:<13} objective {:.3}  good {}/{}  burn fast {:.2}x slow {:.2}x  {}",
                o.objective,
                o.good,
                o.total,
                o.fast_burn,
                o.slow_burn,
                if o.breaching { "BREACHING" } else { "ok" },
            )
        };
        format!(
            "slo: latency target {:.1} ms, burn threshold {:.1}x\n{}\n{}",
            self.target_latency.as_secs_f64() * 1e3,
            self.burn_threshold,
            row("latency", &self.latency),
            row("availability", &self.availability),
        )
    }

    /// Overlay the status onto a registry snapshot as gauges (burn rates
    /// in thousandths, since gauges are integral), for the Prometheus
    /// and JSONL exporters.
    pub fn overlay_gauges(&self, snap: &mut RegistrySnapshot) {
        let milli = |x: f64| (x * 1000.0) as u64;
        let g = &mut snap.gauges;
        g.insert("serving.slo_latency_burn_fast_x1000".into(), milli(self.latency.fast_burn));
        g.insert("serving.slo_latency_burn_slow_x1000".into(), milli(self.latency.slow_burn));
        g.insert(
            "serving.slo_availability_burn_fast_x1000".into(),
            milli(self.availability.fast_burn),
        );
        g.insert(
            "serving.slo_availability_burn_slow_x1000".into(),
            milli(self.availability.slow_burn),
        );
        g.insert("serving.slo_breaching".into(), self.breaching() as u64);
    }
}

/// Thread-safe SLO tracker fed by per-request outcomes.
#[derive(Debug)]
pub struct SloTracker {
    cfg: SloConfig,
    epoch: Instant,
    inner: Mutex<Inner>,
}

#[derive(Debug)]
struct Inner {
    latency: ObjectiveWindows,
    availability: ObjectiveWindows,
}

impl SloTracker {
    /// A tracker with its epoch at construction time.
    pub fn new(cfg: SloConfig) -> SloTracker {
        SloTracker {
            cfg,
            epoch: Instant::now(),
            inner: Mutex::new(Inner {
                latency: ObjectiveWindows::new(cfg.latency_objective, &cfg),
                availability: ObjectiveWindows::new(cfg.availability_objective, &cfg),
            }),
        }
    }

    /// The configured objectives.
    pub fn config(&self) -> &SloConfig {
        &self.cfg
    }

    /// Record one request outcome at wall-clock "now".
    pub fn record(&self, outcome: RequestOutcome, latency: Duration) {
        let now_ns = self.epoch.elapsed().as_nanos() as u64;
        self.record_at(now_ns, outcome, latency.as_nanos() as u64);
    }

    /// Record with an injected timestamp (nanos since the tracker's
    /// epoch) — the test seam for synthetic outcome streams.
    pub fn record_at(&self, now_ns: u64, outcome: RequestOutcome, latency_ns: u64) {
        let mut inner = self.inner.lock().expect("slo lock");
        inner.availability.record(now_ns, outcome == RequestOutcome::Ok);
        if outcome == RequestOutcome::Ok {
            let fast = latency_ns <= self.cfg.latency_target.as_nanos() as u64;
            inner.latency.record(now_ns, fast);
        }
    }

    /// Status at wall-clock "now".
    pub fn status(&self) -> SloStatus {
        let now_ns = self.epoch.elapsed().as_nanos() as u64;
        self.status_at(now_ns)
    }

    /// Status with an injected timestamp (test seam).
    pub fn status_at(&self, now_ns: u64) -> SloStatus {
        let mut inner = self.inner.lock().expect("slo lock");
        SloStatus {
            target_latency: self.cfg.latency_target,
            burn_threshold: self.cfg.burn_threshold,
            latency: inner.latency.status_at(now_ns, self.cfg.burn_threshold),
            availability: inner.availability.status_at(now_ns, self.cfg.burn_threshold),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MS: u64 = 1_000_000;
    const S: u64 = 1_000_000_000;

    fn cfg(availability: f64) -> SloConfig {
        SloConfig {
            latency_target: Duration::from_millis(10),
            latency_objective: 0.5,
            availability_objective: availability,
            fast_window: Duration::from_secs(10),
            slow_window: Duration::from_secs(60),
            burn_threshold: 1.0,
        }
    }

    #[test]
    fn availability_breaches_exactly_past_the_budget() {
        // Budget 0.1: 1 bad in 10 burns at exactly 1.0x (not breaching,
        // threshold is strict); a second bad tips both windows over.
        let t = SloTracker::new(cfg(0.9));
        for i in 0..9 {
            t.record_at(S + i * MS, RequestOutcome::Ok, MS);
        }
        t.record_at(S + 9 * MS, RequestOutcome::ShedQueueFull, 0);
        let st = t.status_at(S + 10 * MS);
        assert!((st.availability.fast_burn - 1.0).abs() < 1e-9);
        assert!(!st.availability.breaching);
        t.record_at(S + 10 * MS, RequestOutcome::ShedDeadline, 0);
        let st = t.status_at(S + 11 * MS);
        assert!(st.availability.fast_burn > 1.0 && st.availability.slow_burn > 1.0);
        assert!(st.availability.breaching);
        assert!(st.breaching());
        assert_eq!(st.availability.good, 9);
        assert_eq!(st.availability.total, 11);
    }

    #[test]
    fn fast_window_forgets_and_clears_the_breach() {
        let t = SloTracker::new(cfg(0.9));
        for _ in 0..10 {
            t.record_at(0, RequestOutcome::Error, 0);
        }
        // Inside both windows: breaching.
        assert!(t.status_at(5 * S).availability.breaching);
        // Past the 10 s fast window: fast burn drops to zero, and the
        // multi-window AND clears the breach even though the slow
        // window still remembers.
        let st = t.status_at(15 * S);
        assert_eq!(st.availability.fast_burn, 0.0);
        assert!(st.availability.slow_burn > 1.0);
        assert!(!st.availability.breaching);
        // Past the 60 s slow window too: fully forgotten.
        let st = t.status_at(70 * S);
        assert_eq!(st.availability.slow_burn, 0.0);
    }

    #[test]
    fn latency_counts_served_requests_only() {
        // Objective 0.5 → budget 0.5. 3 fast + 3 slow: ratio 0.5,
        // burn exactly 1.0 — not breaching. Two more slow: 5/8 slow,
        // burn 1.25 — breaching. Sheds never touch the latency SLI.
        let t = SloTracker::new(cfg(0.9));
        for i in 0..3u64 {
            t.record_at(S + i, RequestOutcome::Ok, 5 * MS);
            t.record_at(S + i, RequestOutcome::Ok, 15 * MS);
        }
        assert!(!t.status_at(2 * S).latency.breaching);
        t.record_at(S + 10, RequestOutcome::Ok, 15 * MS);
        t.record_at(S + 11, RequestOutcome::Ok, 15 * MS);
        let st = t.status_at(2 * S);
        assert!((st.latency.fast_burn - 1.25).abs() < 1e-9);
        assert!(st.latency.breaching);
        t.record_at(S + 12, RequestOutcome::ShedDeadline, 999 * MS);
        assert_eq!(t.status_at(2 * S).latency.total, 8, "sheds don't count");
    }

    #[test]
    fn gauges_overlay_in_milli_units() {
        let t = SloTracker::new(cfg(0.9));
        t.record_at(0, RequestOutcome::Error, 0);
        let st = t.status_at(MS);
        let mut snap = RegistrySnapshot::default();
        st.overlay_gauges(&mut snap);
        assert_eq!(snap.gauges["serving.slo_availability_burn_fast_x1000"], 10_000);
        assert_eq!(snap.gauges["serving.slo_breaching"], 1);
    }
}
