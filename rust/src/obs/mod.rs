//! **Observability substrate** — unified telemetry for codec → store →
//! serving (ISSUE 6; DESIGN.md §10).
//!
//! Three pieces, std-only like everything else in the tree (§1):
//!
//! 1. [`trace`] — a structured span tracer: per-thread event buffers,
//!    RAII guards, cross-thread [`ManualSpan`]s, one relaxed atomic load
//!    on the disabled path. Instruments the full request path (admit →
//!    queue wait → single-flight → chunk IO → arithmetic decode →
//!    copy-out) and the full ingest path (synth → histogram → tablegen →
//!    encode → append → seal).
//! 2. [`registry`] — named atomic counters/gauges plus the log-linear
//!    [`LatencyHistogram`] (generalized out of `serving/metrics.rs`).
//!    `ReadStats`, `PackStats` and `MetricsSnapshot` are views over
//!    [`RegistrySnapshot`]s; [`rates`] holds the shared values/s / MB/s
//!    derivations.
//! 3. [`export`] — Chrome trace-event JSON (`--trace`, loadable in
//!    `chrome://tracing` / Perfetto), Prometheus exposition text
//!    (`--prom`), periodic JSONL snapshots (`--snapshot-jsonl`).
//! 4. The **attribution layer** (ISSUE 8; DESIGN.md §12), which
//!    interprets the above: [`profile`] folds drained span forests into
//!    per-stage-path self/total-time profiles (attribution tables,
//!    collapsed-stack flamegraph text); [`sampler`] retains a bounded
//!    ring of tail exemplars (slowest-decile / errored / shed request
//!    span trees); [`slo`] tracks latency + availability objectives
//!    with SRE-style fast/slow rolling burn-rate windows.
//!
//! # Overhead budget
//!
//! Disabled: one relaxed `AtomicBool` load per call site, CI-gated < 3%
//! on the codec hot path (`benches/codec_hot_path.rs`). Enabled: span
//! sites are block-granular (one span per chunk decode / encode / IO,
//! never per value), so recording is amortized over thousands of values.

pub mod export;
pub mod hist;
pub mod profile;
pub mod rates;
pub mod registry;
pub mod sampler;
pub mod slo;
pub mod trace;

pub use export::{
    chrome_trace, jsonl_line, prom_label_value, prom_metric_name, prometheus_text,
    request_coverage, write_chrome_trace, SnapshotStream,
};
pub use hist::{LatencyHistogram, LatencySnapshot};
pub use profile::{PathStats, Profile};
pub use registry::{Counter, Gauge, MetricsRegistry, RegistrySnapshot};
pub use sampler::{collect_exemplars, Exemplar, ExemplarRing, RequestOutcome, RequestRecord};
pub use slo::{ObjectiveStatus, SloConfig, SloStatus, SloTracker};
pub use trace::{
    clear, disable, drain, dropped, enable, enabled, record, span, span_n, span_n_tagged,
    span_under, with_parent, ManualSpan, SpanEvent, SpanGuard, Stage,
};
