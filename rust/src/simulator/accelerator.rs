//! TensorCore-based accelerator model (paper Table III + §VII-C).
//!
//! An analytical per-layer model: with double-buffered off-chip transfers,
//! a layer's time is `max(compute_time, memory_time)`. Compression scales
//! only the memory term, which is exactly the mechanism behind the paper's
//! speedup claim ("avoiding stalls for off-chip transfers"): memory-bound
//! layers speed up by the compression ratio until they become
//! compute-bound; compute-bound layers (BERT, pruned AlexNet/GoogLeNet at
//! high ratios) see little speedup but still save energy.


use super::dram::{DramConfig, DramPowerModel};
use crate::models::zoo::{LayerShape, ModelConfig};

/// Accelerator configuration (paper Table III).
#[derive(Debug, Clone, Copy)]
pub struct AcceleratorConfig {
    /// Number of tensor cores.
    pub tensor_cores: u32,
    /// PEs per tensor core (4×4).
    pub pes_per_core: u32,
    /// MACs per PE per cycle.
    pub macs_per_pe: u32,
    /// Clock frequency, GHz.
    pub freq_ghz: f64,
    /// On-chip buffer: activations/weights/output, bytes each.
    pub act_buffer_bytes: u64,
    pub weight_buffer_bytes: u64,
    pub out_buffer_bytes: u64,
    /// Achievable fraction of DRAM peak bandwidth for streaming tensors.
    pub dram_utilization: f64,
    /// DRAM configuration.
    pub dram: DramConfig,
}

impl AcceleratorConfig {
    /// Table III: 64 TCs × 16 PEs × 4 MACs @ 1 GHz = 4096 MACs/cycle
    /// = 8.2 TOPS int8 (2 ops per MAC); 256 KB × 16 banks per buffer;
    /// 8 GB dual-channel DDR4-3200.
    pub fn paper() -> Self {
        Self {
            tensor_cores: 64,
            pes_per_core: 16,
            macs_per_pe: 4,
            freq_ghz: 1.0,
            act_buffer_bytes: 256 * 1024 * 16,
            weight_buffer_bytes: 256 * 1024 * 16,
            out_buffer_bytes: 256 * 1024 * 16,
            dram_utilization: 0.90,
            dram: DramConfig::ddr4_3200_dual(),
        }
    }

    /// Peak MACs per cycle.
    pub fn macs_per_cycle(&self) -> u64 {
        self.tensor_cores as u64 * self.pes_per_core as u64 * self.macs_per_pe as u64
    }

    /// Peak int8 TOPS (2 ops per MAC).
    pub fn peak_tops(&self) -> f64 {
        self.macs_per_cycle() as f64 * 2.0 * self.freq_ghz * 1e9 / 1e12
    }
}

/// Per-layer simulation result.
#[derive(Debug, Clone, Copy)]
pub struct LayerSimResult {
    pub compute_s: f64,
    pub memory_s: f64,
    /// max(compute, memory) — double-buffered overlap.
    pub time_s: f64,
    pub dram_read_bytes: u64,
    pub dram_write_bytes: u64,
    pub macs: u64,
}

/// Traffic multipliers from a compression scheme, per tensor kind
/// (1.0 = uncompressed; < 1.0 = compressed).
#[derive(Debug, Clone, Copy)]
pub struct TrafficScaling {
    pub weights: f64,
    pub activations: f64,
}

impl TrafficScaling {
    pub const NONE: TrafficScaling = TrafficScaling { weights: 1.0, activations: 1.0 };
}

/// The analytical accelerator simulator.
#[derive(Debug, Clone, Copy)]
pub struct AcceleratorSim {
    pub cfg: AcceleratorConfig,
}

impl AcceleratorSim {
    pub fn new(cfg: AcceleratorConfig) -> Self {
        Self { cfg }
    }

    /// Compute-array efficiency for a layer (mapping losses: depthwise and
    /// small layers underutilize a 4096-MAC array).
    fn compute_efficiency(&self, layer: &LayerShape) -> f64 {
        match layer {
            LayerShape::DwConv { .. } => 0.25, // no input-channel reuse
            LayerShape::Rnn { .. } => 0.70,
            LayerShape::Fc { n, .. } => {
                if *n >= 64 {
                    0.85
                } else {
                    0.45 // batch-1 GEMV
                }
            }
            LayerShape::Embedding { .. } => 1.0, // MAC-free
            LayerShape::Conv { cout, .. } => {
                if *cout >= 64 {
                    0.85
                } else {
                    0.6
                }
            }
        }
    }

    /// Simulate one layer. `bits` is the datatype width; weights and input
    /// activations are read from off-chip once, outputs written once
    /// (paper §VII-B assumption for edge inference, citing [57]).
    pub fn simulate_layer(
        &self,
        layer: &LayerShape,
        bits: u32,
        scaling: TrafficScaling,
    ) -> LayerSimResult {
        let c = &self.cfg;
        let macs = layer.macs();
        let eff = self.compute_efficiency(layer);
        let compute_s =
            macs as f64 / (c.macs_per_cycle() as f64 * eff) / (c.freq_ghz * 1e9);

        let bytes_per_elem = bits as f64 / 8.0;
        let w_bytes = (layer.weight_elems() as f64 * bytes_per_elem * scaling.weights) as u64;
        let in_bytes =
            (layer.input_elems() as f64 * bytes_per_elem * scaling.activations) as u64;
        let out_bytes =
            (layer.output_elems() as f64 * bytes_per_elem * scaling.activations) as u64;
        let read = w_bytes + in_bytes;
        let write = out_bytes;
        let bw = c.dram.peak_bandwidth() * c.dram_utilization;
        let memory_s = (read + write) as f64 / bw;

        LayerSimResult {
            compute_s,
            memory_s,
            time_s: compute_s.max(memory_s),
            dram_read_bytes: read,
            dram_write_bytes: write,
            macs,
        }
    }

    /// Simulate a whole model; returns per-layer results.
    pub fn simulate_model(
        &self,
        model: &ModelConfig,
        per_layer_scaling: &dyn Fn(usize) -> TrafficScaling,
    ) -> Vec<LayerSimResult> {
        model
            .layers
            .iter()
            .enumerate()
            .map(|(i, l)| self.simulate_layer(l, model.bits_for(i), per_layer_scaling(i)))
            .collect()
    }

    /// Total inference latency.
    pub fn total_time(results: &[LayerSimResult]) -> f64 {
        results.iter().map(|r| r.time_s).sum()
    }

    /// DRAM power model bound to this accelerator's DRAM config.
    pub fn dram_model(&self) -> DramPowerModel {
        DramPowerModel::new(self.cfg.dram)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;

    #[test]
    fn paper_config_is_8_2_tops() {
        let c = AcceleratorConfig::paper();
        assert_eq!(c.macs_per_cycle(), 4096);
        assert!((c.peak_tops() - 8.192).abs() < 0.01);
    }

    #[test]
    fn compression_speeds_up_memory_bound_layers_only() {
        let sim = AcceleratorSim::new(AcceleratorConfig::paper());
        // A fat FC layer (batch 1) is memory-bound.
        let fc = LayerShape::Fc { cin: 4096, cout: 4096, n: 1 };
        let base = sim.simulate_layer(&fc, 8, TrafficScaling::NONE);
        assert!(base.memory_s > base.compute_s, "FC should be memory-bound");
        let comp = sim.simulate_layer(&fc, 8, TrafficScaling { weights: 0.5, activations: 0.5 });
        assert!(comp.time_s < base.time_s * 0.6);

        // A big conv is compute-bound; compression ~no speedup.
        let cv = LayerShape::Conv { cin: 256, cout: 256, k: 3, s: 1, h: 56, w: 56 };
        let base_c = sim.simulate_layer(&cv, 8, TrafficScaling::NONE);
        assert!(base_c.compute_s > base_c.memory_s, "conv should be compute-bound");
        let comp_c =
            sim.simulate_layer(&cv, 8, TrafficScaling { weights: 0.5, activations: 0.5 });
        assert!((comp_c.time_s / base_c.time_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn model_speedup_bounded_by_compression() {
        let sim = AcceleratorSim::new(AcceleratorConfig::paper());
        let model = model_by_name("resnet18").unwrap();
        let base = sim.simulate_model(&model, &|_| TrafficScaling::NONE);
        let half = TrafficScaling { weights: 0.5, activations: 0.5 };
        let comp = sim.simulate_model(&model, &|_| half);
        let speedup = AcceleratorSim::total_time(&base) / AcceleratorSim::total_time(&comp);
        assert!(speedup >= 1.0 && speedup <= 2.0, "speedup {speedup}");
    }

    #[test]
    fn traffic_accounting_matches_tensor_sizes() {
        let sim = AcceleratorSim::new(AcceleratorConfig::paper());
        let l = LayerShape::Conv { cin: 16, cout: 32, k: 3, s: 1, h: 8, w: 8 };
        let r = sim.simulate_layer(&l, 8, TrafficScaling::NONE);
        assert_eq!(r.dram_read_bytes, l.weight_elems() + l.input_elems());
        assert_eq!(r.dram_write_bytes, l.output_elems());
    }
}
