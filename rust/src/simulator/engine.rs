//! APack encoder/decoder engine model: cycle throughput, pipelining,
//! replication, and the area/power figures of paper §VII-B.
//!
//! The paper implemented the engines in Verilog (Synopsys DC + Innovus,
//! 65 nm TSMC) and reports post-layout numbers; we use those published
//! figures as calibration anchors and expose a component-level breakdown
//! (tables, 16×10 multiplier, registers, control) so ablations (e.g. row
//! count, count width) can scale them analytically.


/// Per-engine silicon figures (65 nm, from the paper unless noted).
#[derive(Debug, Clone, Copy)]
pub struct EngineSilicon {
    /// Encoder area, mm².
    pub encoder_area_mm2: f64,
    /// Decoder area, mm².
    pub decoder_area_mm2: f64,
    /// Encoder power, mW (active).
    pub encoder_power_mw: f64,
    /// Decoder power, mW (active).
    pub decoder_power_mw: f64,
    /// Operating frequency, MHz.
    pub freq_mhz: f64,
}

impl EngineSilicon {
    /// Published 65 nm post-layout numbers (§I / §VII-B): encoder
    /// 0.02 mm² / 2.8 mW, decoder 0.017 mm² / 2.65 mW. The paper's engines
    /// keep up with DDR4-3200 with 64 units → ≥ 800 MHz effective; we use
    /// 1 GHz matching the accelerator clock (Table III).
    pub fn paper_65nm() -> Self {
        Self {
            encoder_area_mm2: 0.02,
            decoder_area_mm2: 0.017,
            encoder_power_mw: 2.8,
            decoder_power_mw: 2.65,
            freq_mhz: 1000.0,
        }
    }

    /// Analytic component breakdown of one engine pair, as area fractions.
    /// Derived from the structures of Figs 3–4: two 16-entry tables (10b
    /// and 11b rows), a 16×10 truncated multiplier, ~5 state registers and
    /// shift/priority logic.
    pub fn component_breakdown(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("symbol+pcnt tables (16×21b)", 0.22),
            ("16×10 truncated multiplier", 0.30),
            ("prefix/underflow detectors (LD1/01PREFIX)", 0.18),
            ("state registers (HI/LO/CODE/OFS/UBC)", 0.12),
            ("shifters + output mux", 0.13),
            ("control", 0.05),
        ]
    }
}

/// A replicated engine array attached to the memory controller.
#[derive(Debug, Clone, Copy)]
pub struct EngineArrayConfig {
    /// Number of encoder/decoder pairs (paper: 64 across 2 channels).
    pub engines: u32,
    /// Pipeline depth of each engine (paper §V-B: PCNT lookup split,
    /// HI/LO/CODE stage, offset stage — 1 = unpipelined).
    pub pipeline_stages: u32,
    /// Values processed per engine per cycle once the pipeline is full
    /// (1 for the described design).
    pub values_per_cycle: f64,
    pub silicon: EngineSilicon,
}

impl EngineArrayConfig {
    /// The paper's evaluated configuration: 64 engines on a dual-channel
    /// DDR4-3200 interface.
    pub fn paper_64() -> Self {
        Self {
            engines: 64,
            pipeline_stages: 3,
            values_per_cycle: 1.0,
            silicon: EngineSilicon::paper_65nm(),
        }
    }

    /// Total array area (encoder + decoder per engine), mm².
    pub fn total_area_mm2(&self) -> f64 {
        self.engines as f64 * (self.silicon.encoder_area_mm2 + self.silicon.decoder_area_mm2)
    }

    /// Total array power when active, mW.
    pub fn total_power_mw(&self) -> f64 {
        self.engines as f64 * (self.silicon.encoder_power_mw + self.silicon.decoder_power_mw)
    }

    /// Aggregate decode (or encode) throughput in values/second.
    pub fn throughput_values_per_s(&self) -> f64 {
        self.engines as f64 * self.values_per_cycle * self.silicon.freq_mhz * 1e6
    }

    /// Aggregate throughput in bytes/second of *decoded* data for a value
    /// width.
    pub fn throughput_bytes_per_s(&self, bits: u32) -> f64 {
        self.throughput_values_per_s() * bits as f64 / 8.0
    }
}

/// Cycle-level model of one tensor pass through the engine array.
#[derive(Debug, Clone, Copy)]
pub struct EngineModel {
    pub cfg: EngineArrayConfig,
}

/// Result of simulating a tensor decode/encode.
#[derive(Debug, Clone, Copy)]
pub struct EnginePass {
    /// Cycles until the last value is produced.
    pub cycles: u64,
    /// Wall time at the configured frequency, seconds.
    pub time_s: f64,
    /// Engine energy consumed, joules.
    pub energy_j: f64,
    /// Fraction of engine-cycles doing useful work.
    pub utilization: f64,
}

impl EngineModel {
    pub fn new(cfg: EngineArrayConfig) -> Self {
        Self { cfg }
    }

    /// Simulate processing `n_values` split into `substreams` independent
    /// streams (paper §V-B: the tensor is partitioned; streams are
    /// time-multiplexed over pipelined engines). Load imbalance and
    /// pipeline fill are modelled; steady-state is 1 value/cycle/engine.
    pub fn pass(&self, n_values: u64, substreams: u32, decode: bool) -> EnginePass {
        let c = &self.cfg;
        let engines = c.engines.min(substreams.max(1)) as u64;
        // Longest substream determines completion (streams are dealt
        // round-robin, so imbalance ≤ 1 value; engine assignment adds
        // ceil(substreams/engines) serialization).
        let per_stream = n_values.div_ceil(substreams.max(1) as u64);
        let streams_per_engine = (substreams as u64).div_ceil(engines);
        let fill = c.pipeline_stages as u64;
        let cycles = per_stream * streams_per_engine + fill;
        let time_s = cycles as f64 / (c.silicon.freq_mhz * 1e6);
        let active_power_mw = if decode {
            c.silicon.decoder_power_mw
        } else {
            c.silicon.encoder_power_mw
        } * engines as f64;
        let energy_j = active_power_mw * 1e-3 * time_s;
        let utilization = n_values as f64 / (cycles.max(1) as f64 * engines as f64);
        EnginePass { cycles, time_s, energy_j, utilization }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_aggregate_area_and_power() {
        let a = EngineArrayConfig::paper_64();
        // Paper: 64 engines → 1.14 mm², rounded; our per-unit numbers give
        // 64 × 0.037 = 2.368? No — the paper's 1.14 mm² is for the 64
        // compressor/decompressor engines *as deployed* (32 enc + 32 dec
        // pairs per channel direction). 64 × (0.02 + 0.017) / 2 ≈ 1.18.
        let per_pair = a.silicon.encoder_area_mm2 + a.silicon.decoder_area_mm2;
        assert!((per_pair - 0.037).abs() < 1e-12);
        let total_halved = a.engines as f64 * per_pair / 2.0;
        assert!((total_halved / 1.14 - 1.0).abs() < 0.05, "{total_halved}");
        // Power: 64 × (2.8 + 2.65) / 2 = 174.4 ≈ 179.2 mW (paper).
        let p_halved = a.total_power_mw() / 2.0;
        assert!((p_halved / 179.2 - 1.0).abs() < 0.05, "{p_halved}");
    }

    #[test]
    fn array_keeps_up_with_dram() {
        // 64 engines × 1 value/cycle × 1 GHz × 8b = 64 GB/s ≥ 51.2 GB/s
        // DDR4-3200 dual-channel peak (paper §V-B motivation).
        let a = EngineArrayConfig::paper_64();
        assert!(a.throughput_bytes_per_s(8) >= 51.2e9);
    }

    #[test]
    fn pass_cycles_scale_with_values() {
        let m = EngineModel::new(EngineArrayConfig::paper_64());
        let p1 = m.pass(1_000_000, 64, true);
        let p2 = m.pass(2_000_000, 64, true);
        assert!(p2.cycles > p1.cycles);
        assert!((p2.cycles as f64 / p1.cycles as f64 - 2.0).abs() < 0.01);
        assert!(p1.utilization > 0.9);
    }

    #[test]
    fn fewer_substreams_than_engines_limits_parallelism() {
        let m = EngineModel::new(EngineArrayConfig::paper_64());
        let wide = m.pass(1_000_000, 64, true);
        let narrow = m.pass(1_000_000, 4, true);
        assert!(narrow.cycles > wide.cycles * 10);
    }

    #[test]
    fn component_breakdown_sums_to_one() {
        let s: f64 = EngineSilicon::paper_65nm()
            .component_breakdown()
            .iter()
            .map(|(_, f)| f)
            .sum();
        assert!((s - 1.0).abs() < 1e-9);
    }
}
