//! Hardware models: DDR4 DRAM timing/power, the APack engine
//! cycle/area/power model, and the TensorCore accelerator of paper
//! Table III.

pub mod accelerator;
pub mod dram;
pub mod energy;
pub mod engine;
pub mod memsys;

pub use accelerator::{AcceleratorConfig, AcceleratorSim, LayerSimResult};
pub use dram::{DramConfig, DramPowerModel};
pub use energy::{EnergyBreakdown, EnergyModel};
pub use engine::{EngineArrayConfig, EngineModel};
