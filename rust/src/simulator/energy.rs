//! End-to-end energy accounting (paper Figs 6 and 8).
//!
//! Combines: on-chip compute energy (int8 MACs + SRAM buffer accesses at
//! 65 nm), off-chip DRAM energy (Micron-style model, [`super::dram`]), and
//! the APack engine overhead ([`super::engine`]). Fig 6 considers only the
//! off-chip component; Fig 8 is total energy efficiency.


use super::accelerator::{AcceleratorSim, LayerSimResult};
use super::dram::DramPowerModel;
use super::engine::EngineArrayConfig;

/// 65 nm on-chip energy constants (per-operation, picojoules). Values in
/// the range established by Horowitz's ISSCC'14 survey, scaled to 65 nm:
/// an 8-bit MAC ≈ 0.5 pJ (add 0.03 + mul 0.2, ×65/45 scaling, + pipeline
/// overhead), a 256 KB SRAM access ≈ 10 pJ/byte.
#[derive(Debug, Clone, Copy)]
pub struct EnergyConstants {
    /// Energy per int8 MAC, pJ.
    pub mac_pj: f64,
    /// Energy per byte read/written from a 256 KB on-chip buffer bank, pJ.
    pub sram_pj_per_byte: f64,
    /// On-chip data movement per MAC operand re-fetches, folded as a
    /// multiplier on SRAM traffic (dataflow reuse factor).
    pub sram_traffic_per_dram_byte: f64,
}

impl Default for EnergyConstants {
    fn default() -> Self {
        Self { mac_pj: 0.5, sram_pj_per_byte: 10.0, sram_traffic_per_dram_byte: 4.0 }
    }
}

/// Energy breakdown for one inference, joules.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    pub compute_j: f64,
    pub sram_j: f64,
    pub dram_j: f64,
    pub engine_j: f64,
}

impl EnergyBreakdown {
    /// Total on-chip + off-chip energy.
    pub fn total_j(&self) -> f64 {
        self.compute_j + self.sram_j + self.dram_j + self.engine_j
    }

    /// Off-chip component only (Fig 6's quantity): DRAM + engine overhead.
    pub fn offchip_j(&self) -> f64 {
        self.dram_j + self.engine_j
    }
}

/// The combined energy model.
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub constants: EnergyConstants,
    pub dram: DramPowerModel,
    /// Engine array; `None` for the no-compression baseline (no overhead).
    pub engines: Option<EngineArrayConfig>,
}

impl EnergyModel {
    pub fn new(sim: &AcceleratorSim, engines: Option<EngineArrayConfig>) -> Self {
        Self { constants: EnergyConstants::default(), dram: sim.dram_model(), engines }
    }

    /// Energy for an inference described by per-layer simulation results.
    /// `total_time_s` is the end-to-end latency (for DRAM background power
    /// and engine active energy).
    pub fn inference_energy(
        &self,
        layers: &[LayerSimResult],
        total_time_s: f64,
    ) -> EnergyBreakdown {
        let c = &self.constants;
        let macs: u64 = layers.iter().map(|l| l.macs).sum();
        let read: u64 = layers.iter().map(|l| l.dram_read_bytes).sum();
        let write: u64 = layers.iter().map(|l| l.dram_write_bytes).sum();

        let compute_j = macs as f64 * c.mac_pj * 1e-12;
        // On-chip SRAM traffic scales with the *uncompressed* data the
        // datapath sees; approximated from DRAM traffic × reuse factor.
        // (Compression does not change it: decompression happens at the
        // memory controller, §I.)
        let sram_j =
            (read + write) as f64 * c.sram_traffic_per_dram_byte * c.sram_pj_per_byte * 1e-12;
        let dram_j = self.dram.traffic_energy(read, write, total_time_s).total_j();
        // Engines are active while data streams: charge them for the
        // memory-transfer portion of the run.
        let engine_j = self
            .engines
            .map(|e| {
                let memory_time: f64 = layers.iter().map(|l| l.memory_s).sum();
                e.total_power_mw() * 1e-3 * memory_time
            })
            .unwrap_or(0.0);
        EnergyBreakdown { compute_j, sram_j, dram_j, engine_j }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::zoo::model_by_name;
    use crate::simulator::accelerator::{AcceleratorConfig, TrafficScaling};

    fn setup() -> (AcceleratorSim, EnergyModel, EnergyModel) {
        let sim = AcceleratorSim::new(AcceleratorConfig::paper());
        let base = EnergyModel::new(&sim, None);
        let apack = EnergyModel::new(&sim, Some(EngineArrayConfig::paper_64()));
        (sim, base, apack)
    }

    #[test]
    fn compression_reduces_offchip_energy_despite_engine_overhead() {
        let (sim, base_m, apack_m) = setup();
        let model = model_by_name("resnet50").unwrap();
        let base = sim.simulate_model(&model, &|_| TrafficScaling::NONE);
        let comp = sim.simulate_model(&model, &|_| TrafficScaling {
            weights: 0.6,
            activations: 0.48,
        });
        let tb = AcceleratorSim::total_time(&base);
        let tc = AcceleratorSim::total_time(&comp);
        let eb = base_m.inference_energy(&base, tb);
        let ec = apack_m.inference_energy(&comp, tc);
        assert!(ec.offchip_j() < eb.offchip_j(), "{} vs {}", ec.offchip_j(), eb.offchip_j());
        assert!(ec.total_j() < eb.total_j());
        // Compute energy unchanged by compression.
        assert!((ec.compute_j - eb.compute_j).abs() / eb.compute_j < 1e-12);
    }

    #[test]
    fn engine_overhead_is_small_fraction_of_dram() {
        let (sim, _, apack_m) = setup();
        let model = model_by_name("resnet18").unwrap();
        let res = sim.simulate_model(&model, &|_| TrafficScaling::NONE);
        let t = AcceleratorSim::total_time(&res);
        let e = apack_m.inference_energy(&res, t);
        let frac = e.engine_j / e.dram_j;
        // Paper: 4.7% power overhead vs DRAM at 90% utilization.
        assert!(frac < 0.15, "engine/dram energy fraction {frac}");
    }

    #[test]
    fn energy_breakdown_components_positive() {
        let (sim, base_m, _) = setup();
        let model = model_by_name("mobilenet_v2").unwrap();
        let res = sim.simulate_model(&model, &|_| TrafficScaling::NONE);
        let e = base_m.inference_energy(&res, AcceleratorSim::total_time(&res));
        assert!(e.compute_j > 0.0 && e.sram_j > 0.0 && e.dram_j > 0.0);
        assert_eq!(e.engine_j, 0.0);
    }
}
